//! Domain example: semantic segmentation (the paper's §VI-D workload).
//!
//! Trains segnet_mini on procedural blob scenes across 2 nodes, comparing
//! LGC-PS against DGC and the baseline — the same three-way comparison
//! Table VI's CamVid column makes — and reports pixel accuracy + rates.
//!
//!   cargo run --release --example segmentation [steps]

use lgc::config::{Method, TrainConfig};
use lgc::coordinator;
use lgc::runtime::Engine;
use lgc::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(240);
    let engine = Engine::open_default()?;

    let mut table = Table::new(&[
        "method",
        "pixel acc",
        "info size (MB/iter/node)",
        "ratio",
    ]);
    for method in [Method::Baseline, Method::Dgc, Method::LgcPs] {
        let cfg = TrainConfig {
            model: "segnet_mini".into(),
            method,
            nodes: 2,
            steps,
            lr: 0.05,
            eval_every: (steps / 8).max(10),
            verbose: true,
            ..Default::default()
        }
        .scaled_phases();
        let r = coordinator::train(&engine, cfg)?;
        table.row(&[
            method.name().into(),
            format!("{:.4}", r.final_eval.1),
            format!("{:.6}", r.info_size_mb()),
            format!("{:.0}x", r.compression_ratio()),
        ]);
    }
    println!("\nsegnet_mini on synth-camvid (2 nodes, {steps} steps):");
    table.print();
    Ok(())
}
