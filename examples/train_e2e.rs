//! End-to-end driver (the EXPERIMENTS.md §E2E run): train the transformer
//! LM on the synthetic Markov corpus across 4 simulated nodes with LGC
//! (ring-allreduce instance) for several hundred steps, logging the loss
//! curve, and cross-check against the uncompressed baseline.
//!
//! This exercises every layer of the stack in one run:
//!   L1: Pallas conv1d/deconv1d inside the AE encode/decode HLOs
//!   L2: transformer fwd/bwd + AE train-step HLOs
//!   L3: ring-allreduce latent exchange, EF memories, ledger, scheduler
//!
//! Scale note (DESIGN.md §2): the paper-scale model would be ~100M params;
//! CPU-PJRT interpret throughput pins this driver at transformer_mini
//! (~0.4M params). Structure, not scale, is what this run validates.
//!
//!   cargo run --release --example train_e2e [steps]

use lgc::config::{Method, TrainConfig};
use lgc::coordinator;
use lgc::metrics::Csv;
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let engine = Engine::open_default()?;

    let mut csv = Csv::new(
        "results/e2e_transformer.csv",
        &["method", "iter", "train_loss", "train_acc"],
    );
    let mut finals = Vec::new();

    for method in [Method::LgcRar, Method::Baseline] {
        let cfg = TrainConfig {
            model: "transformer_mini".into(),
            method,
            nodes: 4,
            steps,
            lr: 0.05,
            eval_every: (steps / 10).max(10),
            verbose: true,
            ..Default::default()
        }
        .scaled_phases();
        println!(
            "\n=== e2e: transformer_mini ({} params), {} nodes, {} steps, {} ===",
            engine.manifest.model("transformer_mini").n_params,
            cfg.nodes,
            cfg.steps,
            method.name()
        );
        let r = coordinator::train(&engine, cfg)?;
        for p in &r.curve {
            csv.row(&[
                method.name().into(),
                p.iter.to_string(),
                format!("{}", p.train_loss),
                format!("{}", p.train_acc),
            ]);
        }
        println!(
            "{}: loss {:.4} -> {:.4} | eval acc {:.4} | {:.4} MB/iter/node | CR {:.0}x",
            method.name(),
            r.curve.first().unwrap().train_loss,
            r.final_train_loss(),
            r.final_eval.1,
            r.info_size_mb(),
            r.compression_ratio()
        );
        finals.push((method, r));
    }
    csv.finish()?;
    println!("\nloss curves -> results/e2e_transformer.csv");

    // The e2e acceptance criterion: LGC must track the baseline's loss
    // trajectory (within a tolerance band) at a far lower rate.
    let (lgc, base) = (&finals[0].1, &finals[1].1);
    let gap = lgc.final_train_loss() - base.final_train_loss();
    println!(
        "final-loss gap LGC vs baseline: {gap:+.4} (paper: <=0.2); \
         rate reduction {:.0}x",
        lgc.compression_ratio()
    );
    Ok(())
}
