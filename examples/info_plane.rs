//! §III information-plane analysis (the experiment that motivates LGC):
//! measure how much of one node's gradient information is shared with
//! another node's gradient, per layer, during real training.
//!
//!   cargo run --release --example info_plane [model] [steps]
//!
//! Prints the per-layer mean entropy / MI table (Fig. 4's view) and the
//! overall MI/H ratio (the paper's "~80% of information is common" claim).

use lgc::exp::info_plane::{fig3_fig4, per_layer_means};
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resnet_mini".into());
    let steps: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let engine = Engine::open_default()?;
    let rows = fig3_fig4(&engine, &model, steps, 256)?;

    // Fig 3's view: MI and H over iterations for a couple of layers.
    let means = per_layer_means(&rows);
    let probe_layers: Vec<usize> = means
        .iter()
        .map(|(l, _, _)| *l)
        .filter(|l| l % 4 == 1)
        .take(3)
        .collect();
    println!("\nper-iteration traces (layers {probe_layers:?}):");
    println!("{:>5} {:>8} {:>10} {:>10}", "iter", "layer", "H(bits)", "MI(bits)");
    for r in rows.iter().filter(|r| probe_layers.contains(&r.layer)) {
        if r.iter % (steps / 10).max(1) == 0 {
            println!(
                "{:>5} {:>8} {:>10.3} {:>10.3}",
                r.iter, r.layer, r.h, r.mi
            );
        }
    }
    Ok(())
}
