//! Quickstart: train a small CNN with LGC on 2 simulated nodes and print
//! what the framework measured.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! This is the 60-second tour: pick a model + method, run the three-phase
//! schedule, read compression ratios off the byte ledger.

use lgc::config::{Method, TrainConfig};
use lgc::coordinator;
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // The engine loads AOT artifacts (HLO text lowered by `make artifacts`)
    // and compiles them on the PJRT CPU client, lazily, per module.
    let engine = Engine::open_default()?;
    println!("platform: {}", engine.platform());

    let cfg = TrainConfig {
        model: "convnet5".into(),
        method: Method::LgcPs,
        nodes: 2,
        steps: 120,
        eval_every: 20,
        verbose: true,
        ..Default::default()
    }
    .scaled_phases();

    println!(
        "training {} with {} on {} nodes, {} steps (phases: {} dense / {} top-k+AE / rest compressed)",
        cfg.model, cfg.method.name(), cfg.nodes, cfg.steps, cfg.warmup_iters, cfg.ae_train_iters
    );
    let r = coordinator::train(&engine, cfg)?;

    println!("\nfinal eval:  loss {:.4}  acc {:.4}", r.final_eval.0, r.final_eval.1);
    println!(
        "steady-state uplink: {:.4} MB/iter/node  ->  compression ratio {:.0}x vs dense",
        r.info_size_mb(),
        r.compression_ratio()
    );
    println!("\nwire breakdown:\n{}", r.ledger.summary());
    if let Some((rec0, _)) = r.ae_losses.first() {
        let (rec1, _) = r.ae_losses.last().unwrap();
        println!(
            "autoencoder rec-loss: {rec0:.4} -> {rec1:.4} over {} online steps",
            r.ae_losses.len()
        );
    }
    Ok(())
}
