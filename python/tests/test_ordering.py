"""The DESIGN.md §6.7 claim behind the leader-signed-order protocol:

a 1-D conv autoencoder can learn monotone-envelope value-vectors but not
index-ordered (position-iid) ones.  This test pins the empirical basis of
that protocol decision so a regression in the kernels/AE silently breaking
it would be caught here, not in a 20-minute rust experiment.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import autoencoder as ae

jax.config.update("jax_platform_name", "cpu")

MU = 96
KEY = jax.random.PRNGKey(0)


def _value_vectors(rng, K, t, ordered):
    """Correlated heavy-tailed top-k value vectors, optionally sorted in
    the leader's signed-descending order (the protocol's arrangement)."""
    base = rng.standard_t(3, size=MU) * (1 + 0.1 * np.sin(t))
    vs = [base + 0.3 * rng.standard_t(3, size=MU) for _ in range(K)]
    order = np.argsort(-vs[0]) if ordered else np.arange(MU)
    out = []
    for v in vs:
        v = v[order]
        v = v / np.sqrt((v ** 2).mean())
        out.append(v)
    return jnp.asarray(np.stack(out), jnp.float32)


def _train(ordered, steps=150, lr=1e-2):
    rng = np.random.default_rng(0)
    ep = ae.init_params(ae.enc_param_shapes(), KEY)
    dp = ae.init_params(ae.dec_param_shapes(ps=False), KEY)
    step = jax.jit(ae.rar_train_step)
    last = []
    for t in range(steps):
        g = _value_vectors(rng, 2, t, ordered)
        ep, dp, loss = step(ep, dp, g, lr)
        last.append(float(loss))
    return float(np.mean(last[-10:]))


@pytest.mark.slow
def test_leader_order_makes_vectors_learnable():
    ordered = _train(ordered=True)
    unordered = _train(ordered=False)
    # Ordered vectors compress well below the predict-zero level (~1.0);
    # unordered ones are stuck near it.
    assert ordered < 0.6, f"ordered rec loss {ordered}"
    assert unordered > 0.8, f"unordered rec loss {unordered}"
    assert ordered < unordered * 0.7


def test_monotone_signal_single_batch_overfit():
    """Sanity: the AE can overfit one fixed smooth signal fast."""
    x = jnp.asarray(
        np.sort(np.random.default_rng(1).standard_t(3, size=MU))[::-1].copy(),
        jnp.float32,
    )
    x = x / jnp.sqrt(jnp.mean(x ** 2))
    g = jnp.stack([x, x])
    ep = ae.init_params(ae.enc_param_shapes(), KEY)
    dp = ae.init_params(ae.dec_param_shapes(ps=False), KEY)
    step = jax.jit(ae.rar_train_step)
    loss0 = None
    for _ in range(250):
        ep, dp, loss = step(ep, dp, g, 1e-2)
        loss0 = loss0 if loss0 is not None else float(loss)
    assert float(loss) < 0.5 * loss0, f"{loss0} -> {float(loss)}"
