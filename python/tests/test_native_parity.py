"""Parity tests pinning the rust native backend (rust/src/runtime/native/)
to the JAX reference semantics.

The container building PRs for this repo has no rust toolchain, so the
native backend's hand-written forward/backward kernels are validated the
same way PR 3 validated its DEFLATE rewrite: a line-faithful Python
transliteration (same loops, same index arithmetic as the rust source)
is diffed against jax.vjp / value_and_grad over the repo's own oracles
(kernels/ref.py, the autoencoder.py formulas).  If these tests fail
after touching ref.py / autoencoder.py / the rust native kernels, the
two sides have diverged.

Run: python -m pytest python/tests/test_native_parity.py
"""
import os
import sys
import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile.kernels import ref  # noqa: E402

jax.config.update("jax_enable_x64", False)
rng = np.random.default_rng(0)

FAIL = []


def check(name, a, b, tol=2e-5):
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    if a.shape != b.shape:
        FAIL.append(f"{name}: shape {a.shape} vs {b.shape}")
        print(f"FAIL {name}: shape {a.shape} vs {b.shape}")
        return
    denom = np.maximum(np.abs(b), 1.0)
    err = np.max(np.abs(a - b) / denom) if a.size else 0.0
    status = "ok  " if err <= tol else "FAIL"
    if err > tol:
        FAIL.append(f"{name}: max rel err {err:.3e}")
    print(f"{status} {name}: max rel err {err:.3e}")


# ---------------------------------------------------------------------------
# ops.rs transliteration (literal loops, same index arithmetic)
# ---------------------------------------------------------------------------

LEAKY = 0.01


def conv1d_out_len(n, k, stride):
    pad = 2 if k == 3 else 0
    return (n + pad - k) // stride + 1


def conv1d_fwd(x, cin, n, w, b, cout, k, stride):
    pad = 1 if k == 3 else 0
    n_out = conv1d_out_len(n, k, stride)
    out = np.zeros(cout * n_out, np.float32)
    for o in range(cout):
        for c in range(cin):
            for j in range(n_out):
                base = stride * j - pad
                acc = np.float32(0)
                for t in range(k):
                    p = base + t
                    if 0 <= p < n:
                        acc += w[(o * cin + c) * k + t] * x[c * n + p]
                out[o * n_out + j] += acc
        for j in range(n_out):
            out[o * n_out + j] += b[o]
    return out


def conv1d_bwd(x, cin, n, w, cout, k, stride, dz):
    pad = 1 if k == 3 else 0
    n_out = conv1d_out_len(n, k, stride)
    dx = np.zeros(cin * n, np.float32)
    dw = np.zeros(cout * cin * k, np.float32)
    db = np.zeros(cout, np.float32)
    for o in range(cout):
        db[o] += dz[o * n_out:(o + 1) * n_out].sum()
        for c in range(cin):
            wbase = (o * cin + c) * k
            for j in range(n_out):
                dzj = dz[o * n_out + j]
                base = stride * j - pad
                for t in range(k):
                    p = base + t
                    if 0 <= p < n:
                        dw[wbase + t] += dzj * x[c * n + p]
                        dx[c * n + p] += dzj * w[wbase + t]
    return dx, dw, db


def deconv1d_fwd(x, cin, n, w, b, cout, stride):
    if stride == 1:
        return conv1d_fwd(x, cin, n, w, b, cout, 3, 1)
    n_out = 2 * n
    out = np.zeros(cout * n_out, np.float32)
    for o in range(cout):
        for c in range(cin):
            for j in range(n_out):
                acc = np.float32(0)
                for t in range(3):
                    p = j + t
                    if p % 2 == 1 and p >= 1 and (p - 1) // 2 < n:
                        acc += w[(o * cin + c) * 3 + t] * x[c * n + (p - 1) // 2]
                out[o * n_out + j] += acc
        for j in range(n_out):
            out[o * n_out + j] += b[o]
    return out


def deconv1d_bwd(x, cin, n, w, cout, stride, dz):
    if stride == 1:
        return conv1d_bwd(x, cin, n, w, cout, 3, 1, dz)
    n_out = 2 * n
    dx = np.zeros(cin * n, np.float32)
    dw = np.zeros(cout * cin * 3, np.float32)
    db = np.zeros(cout, np.float32)
    for o in range(cout):
        db[o] += dz[o * n_out:(o + 1) * n_out].sum()
        for c in range(cin):
            wbase = (o * cin + c) * 3
            for j in range(n_out):
                dzj = dz[o * n_out + j]
                for t in range(3):
                    p = j + t
                    if p % 2 == 1 and p >= 1 and (p - 1) // 2 < n:
                        i = (p - 1) // 2
                        dw[wbase + t] += dzj * x[c * n + i]
                        dx[c * n + i] += dzj * w[wbase + t]
    return dx, dw, db


def leaky_fwd(z):
    return np.where(z >= 0, z, LEAKY * z).astype(np.float32)


def leaky_bwd(z, dh):
    return np.where(z >= 0, dh, LEAKY * dh).astype(np.float32)


def relu_fwd(z):
    return np.maximum(z, 0).astype(np.float32)


def relu_bwd(z, dh):
    return np.where(z > 0, dh, 0).astype(np.float32)


def dense_fwd(h, batch, fin, w, b, fout):
    out = np.zeros(batch * fout, np.float32)
    for bi in range(batch):
        for o in range(fout):
            out[bi * fout + o] = b[o] + np.dot(
                w[o * fin:(o + 1) * fin], h[bi * fin:(bi + 1) * fin])
    return out


def dense_bwd(h, batch, fin, w, fout, dz):
    dh = np.zeros(batch * fin, np.float32)
    dw = np.zeros(fout * fin, np.float32)
    db = np.zeros(fout, np.float32)
    for bi in range(batch):
        for o in range(fout):
            dzo = dz[bi * fout + o]
            db[o] += dzo
            dw[o * fin:(o + 1) * fin] += dzo * h[bi * fin:(bi + 1) * fin]
            dh[bi * fin:(bi + 1) * fin] += dzo * w[o * fin:(o + 1) * fin]
    return dh, dw, db


def softmax_xent_and_acc(logits, batch, classes, y):
    loss = np.float32(0)
    correct = 0
    dlogits = np.zeros(batch * classes, np.float32)
    for bi in range(batch):
        row = logits[bi * classes:(bi + 1) * classes]
        argmax = int(np.argmax(row))
        label = int(y[bi])
        if argmax == label:
            correct += 1
        maxv = row.max()
        log_z = maxv + np.log(np.exp(row - maxv).sum())
        loss += log_z - row[label]
        for c in range(classes):
            p = np.exp(row[c] - log_z)
            dlogits[bi * classes + c] = (p - (1.0 if c == label else 0.0)) / batch
    return loss / batch, correct / batch, dlogits


def gap_fwd(h, ch, n):
    return np.array([h[c * n:(c + 1) * n].mean() for c in range(ch)], np.float32)


def gap_bwd(dfeat, ch, n):
    dh = np.zeros(ch * n, np.float32)
    for c in range(ch):
        dh[c * n:(c + 1) * n] = dfeat[c] / n
    return dh


def mse_and_grad(a, b, scale):
    n = max(len(a), 1)
    d = a - b
    return (d * d).sum() / n, (scale * 2.0 * d / n).astype(np.float32)


# ---------------------------------------------------------------------------
# 1. conv/deconv fwd + bwd vs ref.py + jax.vjp
# ---------------------------------------------------------------------------

for (cin, n, cout, k, stride) in [(1, 16, 64, 3, 2), (64, 8, 128, 3, 2),
                                  (256, 2, 64, 3, 2), (64, 1, 4, 1, 1),
                                  (33, 16, 1, 1, 1), (3, 32, 16, 3, 2)]:
    x = rng.standard_normal((cin, n)).astype(np.float32)
    w = rng.standard_normal((cout, cin, k)).astype(np.float32) * 0.5
    b = rng.standard_normal(cout).astype(np.float32) * 0.1
    mine = conv1d_fwd(x.ravel(), cin, n, w.ravel(), b, cout, k, stride)
    oracle = np.asarray(ref.conv1d(jnp.array(x), jnp.array(w), jnp.array(b), stride))
    check(f"conv1d_fwd cin={cin} n={n} cout={cout} k={k} s={stride}",
          mine.reshape(oracle.shape), oracle)

    n_out = conv1d_out_len(n, k, stride)
    dz = rng.standard_normal((cout, n_out)).astype(np.float32)
    dx, dw, db = conv1d_bwd(x.ravel(), cin, n, w.ravel(), cout, k, stride, dz.ravel())
    _, vjp = jax.vjp(lambda xx, ww, bb: ref.conv1d(xx, ww, bb, stride),
                     jnp.array(x), jnp.array(w), jnp.array(b))
    gx, gw, gb = vjp(jnp.array(dz))
    check(f"conv1d_bwd dx  ({cin},{n},{cout},{k},{stride})", dx.reshape(x.shape), gx)
    check(f"conv1d_bwd dw  ({cin},{n},{cout},{k},{stride})", dw.reshape(w.shape), gw)
    check(f"conv1d_bwd db  ({cin},{n},{cout},{k},{stride})", db, gb)

for (cin, n, cout, stride) in [(4, 1, 4, 1), (4, 1, 32, 2), (32, 2, 64, 2),
                               (64, 4, 128, 2), (128, 8, 32, 2)]:
    x = rng.standard_normal((cin, n)).astype(np.float32)
    w = rng.standard_normal((cout, cin, 3)).astype(np.float32) * 0.5
    b = rng.standard_normal(cout).astype(np.float32) * 0.1
    mine = deconv1d_fwd(x.ravel(), cin, n, w.ravel(), b, cout, stride)
    oracle = np.asarray(ref.deconv1d(jnp.array(x), jnp.array(w), jnp.array(b), stride))
    check(f"deconv1d_fwd cin={cin} n={n} cout={cout} s={stride}",
          mine.reshape(oracle.shape), oracle)
    dz = rng.standard_normal(oracle.shape).astype(np.float32)
    dx, dw, db = deconv1d_bwd(x.ravel(), cin, n, w.ravel(), cout, stride, dz.ravel())
    _, vjp = jax.vjp(lambda xx, ww, bb: ref.deconv1d(xx, ww, bb, stride),
                     jnp.array(x), jnp.array(w), jnp.array(b))
    gx, gw, gb = vjp(jnp.array(dz))
    check(f"deconv1d_bwd dx ({cin},{n},{cout},{stride})", dx.reshape(x.shape), gx)
    check(f"deconv1d_bwd dw ({cin},{n},{cout},{stride})", dw.reshape(w.shape), gw)
    check(f"deconv1d_bwd db ({cin},{n},{cout},{stride})", db, gb)

# ---------------------------------------------------------------------------
# 2. ae.rs transliteration vs autoencoder.py formulas (ref ops + jax.grad)
# ---------------------------------------------------------------------------

ENC_SPEC = [(64, 1, 3, 2), (128, 64, 3, 2), (256, 128, 3, 2), (64, 256, 3, 2),
            (4, 64, 1, 1)]
DEC_SPEC = [(4, 4, 3, 1), (32, 4, 3, 2), (64, 32, 3, 2), (128, 64, 3, 2),
            (32, 128, 3, 2)]
LATENT_CH, DOWN = 4, 16


def enc_shapes():
    s = []
    for (cout, cin, k, _) in ENC_SPEC:
        s += [(cout, cin, k), (cout,)]
    return s


def dec_shapes(ps):
    s = []
    for (cout, cin, k, _) in DEC_SPEC:
        s += [(cout, cin, k), (cout,)]
    s += [(1, DEC_SPEC[-1][0] + (1 if ps else 0), 1), (1,)]
    return s


def init(shapes):
    out = []
    for s in shapes:
        if len(s) > 1:
            fan_in = int(np.prod(s[1:]))
            out.append((rng.standard_normal(s) * np.sqrt(2.0 / fan_in)).astype(np.float32))
        else:
            out.append(np.zeros(s, np.float32))
    return out


# -- transliteration of ae.rs --

def t_encode_fwd(params, g, mu):
    h, n = np.array(g, np.float32), mu
    inputs, preacts, lens = [], [], []
    latent = None
    for i, (cout, cin, k, stride) in enumerate(ENC_SPEC):
        w, b = params[2 * i], params[2 * i + 1]
        inputs.append(h.copy())
        lens.append(n)
        z = conv1d_fwd(h, cin, n, w.ravel(), b, cout, k, stride)
        n = conv1d_out_len(n, k, stride)
        if i < len(ENC_SPEC) - 1:
            h = leaky_fwd(z)
            preacts.append(z)
        else:
            latent = z
    return latent, (inputs, preacts, lens)


def t_encode_bwd(params, trace, dlatent, d_params):
    inputs, preacts, lens = trace
    dz = np.array(dlatent, np.float32)
    for i in reversed(range(len(ENC_SPEC))):
        cout, cin, k, stride = ENC_SPEC[i]
        dh, dw, db = conv1d_bwd(inputs[i], cin, lens[i], params[2 * i].ravel(),
                                cout, k, stride, dz)
        d_params[2 * i] += dw.reshape(d_params[2 * i].shape)
        d_params[2 * i + 1] += db
        if i > 0:
            dz = leaky_bwd(preacts[i - 1], dh)


def t_decode_fwd(params, latent, mu, innovation=None):
    h, n = np.array(latent, np.float32), mu // DOWN
    inputs, preacts, lens = [], [], []
    for i, (cout, cin, k, stride) in enumerate(DEC_SPEC):
        w, b = params[2 * i], params[2 * i + 1]
        inputs.append(h.copy())
        lens.append(n)
        z = deconv1d_fwd(h, cin, n, w.ravel(), b, cout, stride)
        n *= stride
        h = leaky_fwd(z)
        preacts.append(z)
    final_cin = DEC_SPEC[-1][0]
    if innovation is not None:
        h = np.concatenate([h, np.array(innovation, np.float32)])
        final_cin += 1
    final_in = h
    rec = conv1d_fwd(final_in, final_cin, mu, params[10].ravel(), params[11], 1, 1, 1)
    return rec, (inputs, preacts, lens, final_in, final_cin)


def t_decode_bwd(params, trace, mu, drec, d_params):
    inputs, preacts, lens, final_in, final_cin = trace
    dfinal, dwf, dbf = conv1d_bwd(final_in, final_cin, mu, params[10].ravel(),
                                  1, 1, 1, drec)
    d_params[10] += dwf.reshape(d_params[10].shape)
    d_params[11] += dbf
    dh = dfinal[:DEC_SPEC[-1][0] * mu].copy()
    for i in reversed(range(len(DEC_SPEC))):
        cout, cin, k, stride = DEC_SPEC[i]
        dz = leaky_bwd(preacts[i], dh)
        dh, dw, db = deconv1d_bwd(inputs[i], cin, lens[i], params[2 * i].ravel(),
                                  cout, stride, dz)
        d_params[2 * i] += dw.reshape(d_params[2 * i].shape)
        d_params[2 * i + 1] += db
    return dh


def t_rar_train_step(enc, dec, grads, mu, lr):
    k = len(grads)
    lat_n = LATENT_CH * (mu // DOWN)
    lat_avg = np.zeros(lat_n, np.float32)
    traces = []
    for g in grads:
        lat, tr = t_encode_fwd(enc, g, mu)
        lat_avg += lat
        traces.append(tr)
    lat_avg /= k
    rec, dtr = t_decode_fwd(dec, lat_avg, mu, None)
    target = np.mean(np.stack(grads), axis=0).astype(np.float32)
    loss, drec = mse_and_grad(rec, target, 1.0)
    d_dec = [np.zeros_like(p) for p in dec]
    dlat_avg = t_decode_bwd(dec, dtr, mu, drec, d_dec)
    dlat_each = dlat_avg / k
    d_enc = [np.zeros_like(p) for p in enc]
    for tr in traces:
        t_encode_bwd(enc, tr, dlat_each, d_enc)
    enc2 = [p - lr * g for p, g in zip(enc, d_enc)]
    dec2 = [p - lr * g for p, g in zip(dec, d_dec)]
    return enc2, dec2, loss


def t_ps_train_step(enc, dec_stacked, grads, innovs, mu, ridx, lr, lam1, lam2):
    k = len(grads)
    lat_n = LATENT_CH * (mu // DOWN)
    encs, traces = [], []
    for g in grads:
        lat, tr = t_encode_fwd(enc, g, mu)
        encs.append(lat)
        traces.append(tr)
    npairs = max(k * (k - 1) // 2, 1)
    sim = np.float32(0)
    d_enc_lat = [np.zeros(lat_n, np.float32) for _ in range(k)]
    for a in range(k):
        for b2 in range(a + 1, k):
            d = encs[a] - encs[b2]
            sim += (d * d).sum() / lat_n
            g = lam2 * 2.0 * d / (lat_n * npairs)
            d_enc_lat[a] += g
            d_enc_lat[b2] -= g
    sim /= npairs
    rec_loss = np.float32(0)
    d_dec = [np.zeros_like(p) for p in dec_stacked]
    d_common = np.zeros(lat_n, np.float32)
    for node in range(k):
        dp = [s.reshape(k, -1)[node].reshape(shape) for s, shape in
              zip(dec_stacked, dec_shapes(True))]
        rec, tr = t_decode_fwd(dp, encs[ridx], mu, innovs[node])
        l, drec = mse_and_grad(rec, np.array(grads[node], np.float32), lam1 / k)
        rec_loss += l
        d_dp = [np.zeros_like(p) for p in dp]
        dlat = t_decode_bwd(dp, tr, mu, drec, d_dp)
        d_common += dlat
        for dst, src in zip(d_dec, d_dp):
            dst.reshape(k, -1)[node] += src.ravel()
    rec_loss /= k
    d_enc_lat[ridx] += d_common
    d_enc = [np.zeros_like(p) for p in enc]
    for tr, dlat in zip(traces, d_enc_lat):
        t_encode_bwd(enc, tr, dlat, d_enc)
    enc2 = [p - lr * g for p, g in zip(enc, d_enc)]
    dec2 = [p - lr * g for p, g in zip(dec_stacked, d_dec)]
    return enc2, dec2, rec_loss, sim


# -- jax oracles replicating autoencoder.py with ref ops --

def j_encode(ep, g):
    h = g
    for i, (_, _, _, stride) in enumerate(ENC_SPEC):
        w, b = ep[2 * i], ep[2 * i + 1]
        h = ref.conv1d(h, w, b, stride)
        if i < len(ENC_SPEC) - 1:
            h = ref.leaky_relu(h)
    return h


def j_decode(dp, latent, innovation=None):
    h = latent
    for i, (_, _, _, stride) in enumerate(DEC_SPEC):
        w, b = dp[2 * i], dp[2 * i + 1]
        h = ref.deconv1d(h, w, b, stride)
        h = ref.leaky_relu(h)
    if innovation is not None:
        h = jnp.concatenate([h, innovation], axis=0)
    return ref.conv1d(h, dp[-2], dp[-1], 1)


def j_rar_train_step(ep, dp, grads, lr):
    k = grads.shape[0]

    def loss_fn(e, d):
        lats = [j_encode(e, grads[i][None, :]) for i in range(k)]
        lat_avg = sum(lats) / float(k)
        rec = j_decode(d, lat_avg)[0]
        target = jnp.mean(grads, axis=0)
        return jnp.mean((rec - target) ** 2)

    loss, (ge, gd) = jax.value_and_grad(loss_fn, argnums=(0, 1))(ep, dp)
    return ([p - lr * g for p, g in zip(ep, ge)],
            [p - lr * g for p, g in zip(dp, gd)], loss)


def j_ps_train_step(ep, dps, grads, innovs, ridx, lr, lam1, lam2):
    k = grads.shape[0]

    def loss_fn(e, d):
        encs = [j_encode(e, grads[i][None, :]) for i in range(k)]
        sim = 0.0
        npairs = max(k * (k - 1) // 2, 1)
        for a in range(k):
            for b2 in range(a + 1, k):
                sim = sim + jnp.mean((encs[a] - encs[b2]) ** 2)
        sim = sim / npairs
        enc_stack = jnp.stack(encs)
        g_common = jnp.take(enc_stack, ridx, axis=0)
        rec = 0.0
        for i in range(k):
            dp_i = [p[i] for p in d]
            rec_i = j_decode(dp_i, g_common, innovs[i][None, :])[0]
            rec = rec + jnp.mean((rec_i - grads[i]) ** 2)
        rec = rec / k
        return lam1 * rec + lam2 * sim, (rec, sim)

    (_, (rec, sim)), (ge, gd) = jax.value_and_grad(
        loss_fn, argnums=(0, 1), has_aux=True)(ep, dps)
    return ([p - lr * g for p, g in zip(ep, ge)],
            [p - lr * g for p, g in zip(dps, gd)], rec, sim)


MU = 16
enc_p = init(enc_shapes())
dec_p = init(dec_shapes(False))
g = (rng.standard_normal(MU)).astype(np.float32)

lat_mine, _ = t_encode_fwd(enc_p, g, MU)
lat_jax = np.asarray(j_encode([jnp.array(p) for p in enc_p], jnp.array(g)[None, :]))
check("ae encode fwd", lat_mine.reshape(lat_jax.shape), lat_jax)

rec_mine, _ = t_decode_fwd(dec_p, lat_mine, MU, None)
rec_jax = np.asarray(j_decode([jnp.array(p) for p in dec_p], jnp.array(lat_jax)))
check("ae decode fwd (rar)", rec_mine.reshape(rec_jax.shape), rec_jax)

dec_ps_p = init(dec_shapes(True))
innov = rng.standard_normal(MU).astype(np.float32)
rec_mine, _ = t_decode_fwd(dec_ps_p, lat_mine, MU, innov)
rec_jax = np.asarray(j_decode([jnp.array(p) for p in dec_ps_p],
                              jnp.array(lat_jax), jnp.array(innov)[None, :]))
check("ae decode fwd (ps+innov)", rec_mine.reshape(rec_jax.shape), rec_jax)

# RAR train step parity
K = 3
grads = rng.standard_normal((K, MU)).astype(np.float32)
e2_m, d2_m, loss_m = t_rar_train_step(enc_p, dec_p, list(grads), MU, 1e-2)
e2_j, d2_j, loss_j = j_rar_train_step([jnp.array(p) for p in enc_p],
                                      [jnp.array(p) for p in dec_p],
                                      jnp.array(grads), 1e-2)
check("rar train loss", loss_m, loss_j, tol=1e-4)
for i, (a, b) in enumerate(zip(e2_m, e2_j)):
    check(f"rar enc'[{i}]", a, np.asarray(b), tol=1e-4)
for i, (a, b) in enumerate(zip(d2_m, d2_j)):
    check(f"rar dec'[{i}]", a, np.asarray(b), tol=1e-4)

# PS train step parity (stacked decoders)
dec_stacked = [np.stack([init([s])[0] for _ in range(K)]) for s in dec_shapes(True)]
innovs = rng.standard_normal((K, MU)).astype(np.float32)
ridx = 1
e2_m, d2_m, rec_m, sim_m = t_ps_train_step(
    enc_p, [d.reshape(K, -1).ravel() if False else d for d in dec_stacked],
    list(grads), list(innovs), MU, ridx, 1e-2, 1.0, 0.5)
e2_j, d2_j, rec_j, sim_j = j_ps_train_step(
    [jnp.array(p) for p in enc_p], [jnp.array(d) for d in dec_stacked],
    jnp.array(grads), jnp.array(innovs), ridx, 1e-2, 1.0, 0.5)
check("ps train rec loss", rec_m, rec_j, tol=1e-4)
check("ps train sim loss", sim_m, sim_j, tol=1e-4)
for i, (a, b) in enumerate(zip(e2_m, e2_j)):
    check(f"ps enc'[{i}]", a, np.asarray(b), tol=1e-4)
for i, (a, b) in enumerate(zip(d2_m, d2_j)):
    check(f"ps dec'[{i}]", a.reshape(np.asarray(b).shape), np.asarray(b), tol=1e-4)

# ---------------------------------------------------------------------------
# 3. models.rs transliteration vs jnp autodiff
# ---------------------------------------------------------------------------

def t_mlp_grad_step(dims, params, x, y, batch):
    n_layers = len(dims) - 1
    h = x.ravel().copy()
    layer_in, preacts = [], []
    for l in range(n_layers):
        fin, fout = dims[l], dims[l + 1]
        layer_in.append(h.copy())
        z = dense_fwd(h, batch, fin, params[2 * l].ravel(), params[2 * l + 1], fout)
        if l < n_layers - 1:
            h = relu_fwd(z)
            preacts.append(z)
        else:
            h = z
    loss, acc, dz = softmax_xent_and_acc(h, batch, dims[-1], y)
    grads = [np.zeros_like(p) for p in params]
    for l in reversed(range(n_layers)):
        fin, fout = dims[l], dims[l + 1]
        dh, dw, db = dense_bwd(layer_in[l], batch, fin, params[2 * l].ravel(), fout, dz)
        grads[2 * l] = dw.reshape(params[2 * l].shape)
        grads[2 * l + 1] = db
        if l > 0:
            dz = relu_bwd(preacts[l - 1], dh)
    return loss, acc, grads


def j_mlp_loss(params, x, y, dims):
    h = x
    n_layers = len(dims) - 1
    for l in range(n_layers):
        w, b = params[2 * l], params[2 * l + 1]
        z = h @ w.T + b
        h = jnp.maximum(z, 0.0) if l < n_layers - 1 else z
    logp = jax.nn.log_softmax(h, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


DIMS = [64, 96, 96, 64, 10]
mlp_shapes = []
for a, b2 in zip(DIMS[:-1], DIMS[1:]):
    mlp_shapes += [(b2, a), (b2,)]
mlp_p = init(mlp_shapes)
B = 8
x = rng.standard_normal((B, DIMS[0])).astype(np.float32)
y = rng.integers(0, 10, B)
loss_m, acc_m, grads_m = t_mlp_grad_step(DIMS, mlp_p, x, y, B)
loss_j, grads_j = jax.value_and_grad(j_mlp_loss)(
    [jnp.array(p) for p in mlp_p], jnp.array(x), jnp.array(y), DIMS)
check("mlp loss", loss_m, loss_j, tol=1e-4)
for i, (a, b2) in enumerate(zip(grads_m, grads_j)):
    check(f"mlp grad[{i}]", a, np.asarray(b2), tol=1e-4)


def t_conv_grad_step(layers, input_len, classes, params, x, y, batch):
    n_conv = len(layers)
    feat_ch = layers[-1][1]
    ex_len = layers[0][0] * input_len
    xf = x.ravel()
    traces, feats = [], []
    for bi in range(batch):
        h = xf[bi * ex_len:(bi + 1) * ex_len].copy()
        n = input_len
        ins, pre, lens = [], [], []
        for l, (cin, cout, stride) in enumerate(layers):
            ins.append(h.copy())
            lens.append(n)
            z = conv1d_fwd(h, cin, n, params[2 * l].ravel(), params[2 * l + 1],
                           cout, 3, stride)
            n = conv1d_out_len(n, 3, stride)
            h = relu_fwd(z)
            pre.append(z)
        feats.append(gap_fwd(h, feat_ch, n))
        traces.append((ins, pre, lens, n))
    feats = np.concatenate(feats)
    wf, bf = params[-2], params[-1]
    logits = dense_fwd(feats, batch, feat_ch, wf.ravel(), bf, classes)
    loss, acc, dlogits = softmax_xent_and_acc(logits, batch, classes, y)
    grads = [np.zeros_like(p) for p in params]
    dfeats, dwf, dbf = dense_bwd(feats, batch, feat_ch, wf.ravel(), classes, dlogits)
    grads[-2] = dwf.reshape(wf.shape)
    grads[-1] = dbf
    for bi, (ins, pre, lens, n_last) in enumerate(traces):
        dh = gap_bwd(dfeats[bi * feat_ch:(bi + 1) * feat_ch], feat_ch, n_last)
        for l in reversed(range(n_conv)):
            cin, cout, stride = layers[l]
            dz = relu_bwd(pre[l], dh)
            dh, dw, db = conv1d_bwd(ins[l], cin, lens[l], params[2 * l].ravel(),
                                    cout, 3, stride, dz)
            grads[2 * l] += dw.reshape(grads[2 * l].shape)
            grads[2 * l + 1] += db
    return loss, acc, grads


def j_conv_loss(params, x, y, layers):
    n_conv = len(layers)

    def per_example(xe):
        h = xe
        for l, (_, _, stride) in enumerate(layers):
            w, b = params[2 * l], params[2 * l + 1]
            h = jnp.maximum(ref.conv1d(h, w, b, stride), 0.0)
        return jnp.mean(h, axis=1)

    feats = jax.vmap(per_example)(x)
    logits = feats @ params[-2].T + params[-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


LAYERS = [(3, 16, 2), (16, 24, 2), (24, 32, 2)]
conv_shapes = []
for cin, cout, _ in LAYERS:
    conv_shapes += [(cout, cin, 3), (cout,)]
conv_shapes += [(10, 32), (10,)]
conv_p = init(conv_shapes)
xc = rng.standard_normal((B, 3, 32)).astype(np.float32)
yc = rng.integers(0, 10, B)
loss_m, acc_m, grads_m = t_conv_grad_step(LAYERS, 32, 10, conv_p, xc, yc, B)
loss_j, grads_j = jax.value_and_grad(j_conv_loss)(
    [jnp.array(p) for p in conv_p], jnp.array(xc), jnp.array(yc), LAYERS)
check("convnet loss", loss_m, loss_j, tol=1e-4)
for i, (a, b2) in enumerate(zip(grads_m, grads_j)):
    check(f"convnet grad[{i}]", a, np.asarray(b2), tol=1e-4)

# softmax acc parity with common.py semantics
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "compile"))
from models.common import softmax_xent_and_acc as j_sm  # noqa: E402
logits = rng.standard_normal((6, 5)).astype(np.float32)
yl = rng.integers(0, 5, 6)
l_m, a_m, _ = softmax_xent_and_acc(logits.ravel(), 6, 5, yl)
l_j, a_j = j_sm(jnp.array(logits), jnp.array(yl))
check("softmax loss parity", l_m, l_j, tol=1e-5)
check("softmax acc parity", a_m, a_j, tol=0)

def test_native_parity():
    assert not FAIL, FAIL

