"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

hypothesis sweeps shapes/strides/values; assert_allclose against ref.py is
the CORE correctness signal for the compute hot path (the same HLO the rust
runtime executes at every training iteration).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (conv1d, conv1d_pallas, deconv1d, deconv1d_pallas,
                             ref, sparsify_pallas)

jax.config.update("jax_platform_name", "cpu")

F32 = jnp.float32


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, F32)


# ---------------------------------------------------------------------------
# conv1d
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    cin=st.sampled_from([1, 3, 4, 32, 64]),
    cout=st.sampled_from([1, 4, 32, 64]),
    n_half=st.integers(2, 40),
    stride=st.sampled_from([1, 2]),
    k=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv1d_matches_ref(cin, cout, n_half, stride, k, seed):
    n = 2 * n_half  # stride-2 convs require even length
    rng = np.random.default_rng(seed)
    x = _arr(rng, (cin, n))
    w = _arr(rng, (cout, cin, k), scale=0.5)
    b = _arr(rng, (cout,))
    got = conv1d_pallas(x, w, b, stride)
    want = ref.conv1d(x, w, b, stride)
    assert got.shape == want.shape == (cout, ref.conv1d_out_len(n, k, stride))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_conv1d_fused_activation(seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (8, 32)), _arr(rng, (16, 8, 3)), _arr(rng, (16,))
    got = conv1d_pallas(x, w, b, 2, fuse_act=True)
    want = ref.leaky_relu(ref.conv1d(x, w, b, 2))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv1d_odd_length_tile():
    # n_out = 57 (prime-ish) exercises the non-power-of-two tile picker.
    rng = np.random.default_rng(0)
    x, w, b = _arr(rng, (4, 114)), _arr(rng, (8, 4, 3)), _arr(rng, (8,))
    got = conv1d_pallas(x, w, b, 2)
    np.testing.assert_allclose(got, ref.conv1d(x, w, b, 2), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv1d_vjp_matches_ref_grad(stride, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (6, 24)), _arr(rng, (5, 6, 3)), _arr(rng, (5,))

    def f(x_, w_, b_):
        return jnp.sum(conv1d(x_, w_, b_, stride) ** 2)

    def fr(x_, w_, b_):
        return jnp.sum(ref.conv1d(x_, w_, b_, stride) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# deconv1d
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    cin=st.sampled_from([2, 4, 32, 128]),
    cout=st.sampled_from([1, 4, 32]),
    n=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_deconv1d_matches_ref(cin, cout, n, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (cin, n))
    w = _arr(rng, (cout, cin, 3), scale=0.5)
    b = _arr(rng, (cout,))
    got = deconv1d_pallas(x, w, b, 2)
    want = ref.deconv1d(x, w, b, 2)
    assert got.shape == want.shape == (cout, 2 * n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_deconv1d_stride1_delegates_to_conv():
    rng = np.random.default_rng(1)
    x, w, b = _arr(rng, (4, 16)), _arr(rng, (4, 4, 3)), _arr(rng, (4,))
    np.testing.assert_allclose(deconv1d_pallas(x, w, b, 1),
                               ref.conv1d(x, w, b, 1), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_deconv1d_vjp_matches_ref_grad(seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (4, 12)), _arr(rng, (6, 4, 3)), _arr(rng, (6,))

    def f(x_, w_, b_):
        return jnp.sum(deconv1d(x_, w_, b_, 2) ** 2)

    def fr(x_, w_, b_):
        return jnp.sum(ref.deconv1d(x_, w_, b_, 2) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


def test_deconv_inverts_conv_shape():
    """Encoder downsample x16 and decoder upsample x16 must round-trip mu."""
    rng = np.random.default_rng(2)
    mu = 256
    h = _arr(rng, (1, mu))
    for cout, cin, k, s in [(64, 1, 3, 2), (128, 64, 3, 2), (256, 128, 3, 2),
                            (64, 256, 3, 2), (4, 64, 1, 1)]:
        h = conv1d_pallas(h, _arr(rng, (cout, cin, k), 0.1),
                          jnp.zeros((cout,), F32), s)
    assert h.shape == (4, mu // 16)
    for cout, cin, k, s in [(4, 4, 3, 1), (32, 4, 3, 2), (64, 32, 3, 2),
                            (128, 64, 3, 2), (32, 128, 3, 2)]:
        h = deconv1d_pallas(h, _arr(rng, (cout, cin, k), 0.1),
                            jnp.zeros((cout,), F32), s)
    assert h.shape == (32, mu)


# ---------------------------------------------------------------------------
# sparsify
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    n=st.sampled_from([16, 96, 512, 1000, 4096]),
    thr=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparsify_matches_ref(n, thr, seed):
    rng = np.random.default_rng(seed)
    g, acc = _arr(rng, (n,)), _arr(rng, (n,))
    t = jnp.asarray([thr], F32)
    gsp, acc2 = sparsify_pallas(g, acc, t)
    rsp, racc2 = ref.sparsify(g, acc, thr)
    np.testing.assert_allclose(gsp, rsp, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(acc2, racc2, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([64, 480]), seed=st.integers(0, 2**31 - 1))
def test_sparsify_invariants(n, seed):
    """Property: g_sp + acc' == g + acc (lossless split), supports disjoint."""
    rng = np.random.default_rng(seed)
    g, acc = _arr(rng, (n,)), _arr(rng, (n,))
    t = jnp.asarray([0.8], F32)
    gsp, acc2 = sparsify_pallas(g, acc, t)
    np.testing.assert_allclose(gsp + acc2, g + acc, rtol=1e-6, atol=1e-6)
    assert not np.any((np.abs(np.asarray(gsp)) > 0)
                      & (np.abs(np.asarray(acc2)) > 0))


def test_sparsify_zero_threshold_sends_everything():
    rng = np.random.default_rng(3)
    g, acc = _arr(rng, (128,)), _arr(rng, (128,))
    gsp, acc2 = sparsify_pallas(g, acc, jnp.asarray([0.0], F32))
    np.testing.assert_allclose(gsp, g + acc, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(acc2, jnp.zeros(128), atol=1e-7)
