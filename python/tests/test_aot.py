"""AOT pipeline: manifest consistency + HLO text parses structural checks.

These tests run against a freshly-emitted single-model artifact dir (tmp),
so they don't depend on `make artifacts` having been run.
"""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        subprocess.check_call(
            [sys.executable, "-m", "compile.aot", "--out", ART],
            cwd=os.path.join(os.path.dirname(__file__), ".."))
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_all_models(manifest):
    for name in ["convnet5", "resnet_mini", "resnet_mini_deep",
                 "segnet_mini", "transformer_mini"]:
        assert name in manifest["models"]


def test_every_module_file_exists(manifest):
    for name, mod in manifest["modules"].items():
        path = os.path.join(ART, mod["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_model_module_io_shapes(manifest):
    for name, m in manifest["models"].items():
        gs = manifest["modules"][m["grad_step"]]
        n_p = len(m["params"])
        assert len(gs["inputs"]) == n_p + 2      # params + x + y
        assert len(gs["outputs"]) == n_p + 2     # loss + acc + grads
        assert gs["outputs"][0] == [] and gs["outputs"][1] == []
        assert gs["outputs"][2:] == m["params"]
        ev = manifest["modules"][m["evaluate"]]
        assert len(ev["outputs"]) == 2


def test_mu_is_downsample_aligned(manifest):
    down = manifest["ae"]["down"]
    for name, m in manifest["models"].items():
        assert m["mu"] % down == 0
        # mu must cover alpha * n_mid
        assert m["mu"] >= manifest["alpha"] * m["n_mid"]


def test_param_groups_partition(manifest):
    for name, m in manifest["models"].items():
        all_idx = sorted(m["first_param_idx"] + m["mid_param_idx"]
                         + m["last_param_idx"])
        assert all_idx == list(range(len(m["params"]))), name


def test_ae_variants_cover_model_mus(manifest):
    from compile.aot import AE_CONFIGS
    for name, ks in AE_CONFIGS.items():
        mu = manifest["models"][name]["mu"]
        var = manifest["ae"]["variants"][str(mu)]
        for k in ks:
            assert str(k) in var["train_rar"], (name, k)
            assert str(k) in var["train_ps"], (name, k)


def test_ae_module_shapes(manifest):
    for mu_s, var in manifest["ae"]["variants"].items():
        mu = int(mu_s)
        enc = manifest["modules"][var["enc"]]
        assert enc["inputs"][-1] == [1, mu]
        assert enc["outputs"][0] == [manifest["ae"]["latent_ch"],
                                     mu // manifest["ae"]["down"]]
        dec = manifest["modules"][var["dec_rar"]]
        assert dec["outputs"][0] == [1, mu]
        dps = manifest["modules"][var["dec_ps"]]
        assert dps["inputs"][-1] == [1, mu]       # innovation input


def test_sparsify_module_covers_mid_params(manifest):
    for name, m in manifest["models"].items():
        sp = manifest["modules"][m["sparsify"]]
        assert sp["inputs"][0] == [m["n_mid"]]
        assert sp["outputs"] == [[m["n_mid"]], [m["n_mid"]]]


def test_fingerprint_present(manifest):
    assert len(manifest["fingerprint"]) == 64
