"""L2 correctness: primary models — shapes, gradients, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import MODELS

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(11)


def _batch(spec, key):
    b = spec.batch
    if spec.input_dtype == "i32":
        x = jax.random.randint(key, (b,) + spec.input_shape, 0,
                               spec.num_classes)
        y = jax.random.randint(key, (b,) + spec.input_shape, 0,
                               spec.num_classes)
    elif spec.name == "segnet_mini":
        x = jax.random.normal(key, (b,) + spec.input_shape)
        y = jax.random.randint(
            key, (b, spec.input_shape[0] * spec.input_shape[1]), 0,
            spec.num_classes)
    else:
        x = jax.random.normal(key, (b,) + spec.input_shape)
        y = jax.random.randint(key, (b,), 0, spec.num_classes)
    return x, y


@pytest.mark.parametrize("name", list(MODELS))
def test_grad_step_shapes(name):
    spec = MODELS[name]
    params = spec.init(KEY)
    assert [p.shape for p in params] == [tuple(s) for s in spec.param_shapes()]
    x, y = _batch(spec, KEY)
    loss, acc, grads = jax.jit(spec.grad_step)(params, x, y)
    assert loss.shape == () and acc.shape == ()
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0


@pytest.mark.parametrize("name", list(MODELS))
def test_initial_loss_near_uniform(name):
    """Fresh init should score ~= -log(1/C): catches logits-scale bugs."""
    spec = MODELS[name]
    params = spec.init(KEY)
    x, y = _batch(spec, KEY)
    loss, _ = jax.jit(spec.evaluate)(params, x, y)
    expect = np.log(spec.num_classes)
    assert abs(float(loss) - expect) < 0.7 * expect


@pytest.mark.parametrize("name", list(MODELS))
def test_gradients_nonzero_everywhere(name):
    """Every parameter must receive gradient signal (no dead branches)."""
    spec = MODELS[name]
    params = spec.init(KEY)
    x, y = _batch(spec, KEY)
    _, _, grads = jax.jit(spec.grad_step)(params, x, y)
    for i, g in enumerate(grads):
        assert float(jnp.max(jnp.abs(g))) > 0, f"param {i} has zero gradient"


@pytest.mark.parametrize("name", ["convnet5", "transformer_mini"])
def test_sgd_reduces_loss(name):
    """Train on *separable* synthetic data (class-conditional means), the
    same structure the rust data substrate generates — random labels on
    random inputs are not learnable through a GAP bottleneck."""
    spec = MODELS[name]
    params = spec.init(KEY)
    if spec.input_dtype == "i32":
        x, y = _batch(spec, KEY)
    else:
        y = jax.random.randint(KEY, (spec.batch,), 0, spec.num_classes)
        means = jax.random.normal(KEY, (spec.num_classes,) + spec.input_shape)
        x = means[y] + 0.3 * jax.random.normal(KEY, (spec.batch,) + spec.input_shape)
    step = jax.jit(spec.grad_step)
    lr = 0.3 if spec.input_dtype == "f32" else 0.1
    loss0 = None
    for _ in range(150):
        loss, _, grads = step(params, x, y)
        loss0 = loss0 if loss0 is not None else float(loss)
        params = [p - lr * g for p, g in zip(params, grads)]
    assert float(loss) < loss0 * 0.5


@pytest.mark.parametrize("name", list(MODELS))
def test_layer_of_param_structure(name):
    spec = MODELS[name]
    layers = spec.layer_of_param
    assert len(layers) == len(spec.param_shapes())
    # Monotone non-decreasing, starts at 0, contiguous layer ids.
    assert layers[0] == 0
    assert all(b - a in (0, 1) for a, b in zip(layers, layers[1:]))


def test_resnet_has_residual_structure():
    """Fig. 4 depends on residual adds; deep variant must add layers."""
    assert MODELS["resnet_mini_deep"].n_params() > MODELS["resnet_mini"].n_params()
    assert max(MODELS["resnet_mini_deep"].layer_of_param) > \
        max(MODELS["resnet_mini"].layer_of_param)
