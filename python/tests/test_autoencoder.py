"""L2 correctness: LGC autoencoder shapes, losses, and convergence (§IV)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import autoencoder as ae

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(7)


def _enc():
    return ae.init_params(ae.enc_param_shapes(), KEY)


def _dec(ps=False):
    return ae.init_params(ae.dec_param_shapes(ps=ps), KEY)


@pytest.mark.parametrize("mu", [96, 256, 432, 704, 1088])
def test_encode_shape(mu):
    lat = ae.encode(_enc(), jax.random.normal(KEY, (1, mu)))
    assert lat.shape == (ae.LATENT_CH, mu // ae.DOWN)


@pytest.mark.parametrize("mu", [96, 256])
def test_decode_rar_shape(mu):
    lat = jax.random.normal(KEY, (ae.LATENT_CH, mu // ae.DOWN))
    rec = ae.decode(_dec(), lat)
    assert rec.shape == (1, mu)


@pytest.mark.parametrize("mu", [96, 256])
def test_decode_ps_shape_uses_innovation(mu):
    lat = jax.random.normal(KEY, (ae.LATENT_CH, mu // ae.DOWN))
    innov = jax.random.normal(KEY, (1, mu))
    dp = _dec(ps=True)
    rec0 = ae.decode(dp, lat, jnp.zeros((1, mu)))
    rec1 = ae.decode(dp, lat, innov)
    assert rec0.shape == (1, mu)
    # The innovation channel must actually influence the reconstruction.
    assert float(jnp.max(jnp.abs(rec0 - rec1))) > 0.0


def test_latent_is_4x_compression_of_mu():
    """The paper's rate math: latent floats = mu/4 (4 ch x mu/16 length)."""
    mu = 512
    lat = ae.encode(_enc(), jnp.zeros((1, mu)))
    assert lat.size == mu // 4


def test_rar_train_step_reduces_loss():
    # Smooth (sorted) inputs at lr 1e-2: the regime the LGC protocol
    # actually feeds the AE (leader-signed order, DESIGN.md SS6.7).
    base = jnp.sort(jax.random.normal(KEY, (256,)))[::-1]
    grads = jnp.stack([base + 0.05 * jax.random.normal(jax.random.PRNGKey(i), (256,))
                       for i in range(4)])
    ep, dp = _enc(), _dec()
    first = None
    for _ in range(60):
        ep, dp, loss = ae.rar_train_step(ep, dp, grads, 1e-2)
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.8, f"{first} -> {float(loss)}" 


def test_ps_train_step_reduces_both_losses():
    k = 2
    grads = jax.random.normal(KEY, (k, 256)) * 0.1
    innov = grads * (jnp.abs(grads) > 0.25)
    ep = _enc()
    dps = [jnp.stack([p] * k) for p in _dec(ps=True)]
    rec0 = sim0 = None
    for i in range(60):
        ridx = jnp.int32(i % k)
        ep, dps, rec, sim = ae.ps_train_step(
            ep, dps, grads, innov, ridx, 1e-2, 1.0, 0.5)
        if rec0 is None:
            rec0, sim0 = float(rec), float(sim)
    assert float(rec) < rec0
    assert float(sim) < sim0 * 1.5  # sim loss must not blow up


def test_ps_similarity_loss_zero_for_identical_gradients():
    grads = jnp.tile(jax.random.normal(KEY, (1, 256)) * 0.1, (3, 1))
    innov = jnp.zeros_like(grads)
    ep = _enc()
    dps = [jnp.stack([p] * 3) for p in _dec(ps=True)]
    _, _, _, sim = ae.ps_train_step(ep, dps, grads, innov, jnp.int32(0),
                                    0.0, 1.0, 1.0)
    assert float(sim) < 1e-8


def test_ps_ridx_selects_common_representation():
    """With lr=0 the step is pure evaluation; different ridx must generally
    give different reconstruction losses (different encodings chosen)."""
    grads = jax.random.normal(KEY, (2, 256)) * 0.5
    innov = jnp.zeros_like(grads)
    ep = _enc()
    dps = [jnp.stack([p] * 2) for p in _dec(ps=True)]
    _, _, rec0, _ = ae.ps_train_step(ep, dps, grads, innov, jnp.int32(0),
                                     0.0, 1.0, 0.0)
    _, _, rec1, _ = ae.ps_train_step(ep, dps, grads, innov, jnp.int32(1),
                                     0.0, 1.0, 0.0)
    assert float(rec0) != pytest.approx(float(rec1))


def test_param_shapes_match_spec_tables():
    """Paper Tables I/II filter counts (with the DESIGN.md §7 deviation)."""
    enc = ae.enc_param_shapes()
    assert [s[0] for s in enc[::2]] == [64, 128, 256, 64, 4]
    dec = ae.dec_param_shapes(ps=False)
    assert [s[0] for s in dec[::2]] == [4, 32, 64, 128, 32, 1]
    dec_ps = ae.dec_param_shapes(ps=True)
    assert dec_ps[-2] == (1, 33, 1)  # +1 innovation channel


def test_init_he_scaling():
    params = ae.init_params(ae.enc_param_shapes(), KEY)
    w2 = params[2]  # (128, 64, 3): fan_in 192
    std = float(jnp.std(w2))
    assert 0.5 * np.sqrt(2 / 192) < std < 2.0 * np.sqrt(2 / 192)
    assert float(jnp.max(jnp.abs(params[1]))) == 0.0  # bias zeros
