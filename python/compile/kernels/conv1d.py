"""L1 Pallas kernel: strided 1-D convolution (LGC autoencoder hot-spot).

The LGC encoder (paper Table I) is five 1-D convolutions over the
sparsified-gradient vector; at steady state (phase 3) this runs on every
node at every training iteration, so it is the compute hot path of the
whole system.  The kernel is written for the TPU mental model:

  * the weight tensor (cout, cin, k) is tiny (<=256x128x3 f32 ~ 384 KB) and
    is pinned whole in VMEM for every grid step;
  * the output is tiled along the length dimension; each grid step produces
    one (cout, TILE) tile with a single (cout x cin*k) @ (cin*k x TILE)
    contraction, which is the shape the MXU systolic array wants (the
    paper's GPU formulation was a cuDNN conv; a pointwise CUDA-style port
    would waste the MXU — see DESIGN.md §Hardware-Adaptation);
  * the input row is small (mu <= a few thousand floats), so it is kept
    fully VMEM-resident and each grid step dynamic-slices its stride-2
    window out of it.  On a real TPU with large mu the x BlockSpec would
    stream overlapping halo tiles instead; the schedule is documented in
    DESIGN.md §9.

interpret=True always: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).

Differentiation: pallas_call has no autodiff rule, so `conv1d` is wrapped
in jax.custom_vjp with the backward pass derived from the pure-jnp oracle
(kernels/ref.py) via jax.vjp — correct by construction given fwd parity,
which pytest asserts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

_SLOPE = 0.01  # leaky-relu negative slope (shared with ref.leaky_relu)


def _pick_tile(n_out: int, cap: int = 128) -> int:
    """Largest divisor of n_out that is <= cap (grid must tile exactly)."""
    for t in range(min(cap, n_out), 0, -1):
        if n_out % t == 0:
            return t
    return 1


def _conv1d_kernel(x_ref, w_ref, b_ref, o_ref, *, stride, k, pad, tile, fuse_act):
    """One grid step: compute a (cout, tile) output tile.

    cols[c, j, t] = xpad[c, stride*(j0 + j) + t]  gathered with strided
    slices, then contracted against w as an einsum -> MXU-shaped GEMM.
    """
    j0 = pl.program_id(0)
    x = x_ref[...]                      # (cin, n), VMEM-resident
    w = w_ref[...]                      # (cout, cin, k)
    b = b_ref[...]                      # (cout,)
    cin = x.shape[0]
    xp = jnp.pad(x, ((0, 0), (pad, pad)))
    span = (tile - 1) * stride + k      # input window feeding this tile
    win = jax.lax.dynamic_slice(xp, (0, j0 * tile * stride), (cin, span))
    # (cin, tile, k): one strided slice per tap.
    cols = jnp.stack(
        [jax.lax.slice(win, (0, t), (cin, t + (tile - 1) * stride + 1), (1, stride))
         for t in range(k)],
        axis=-1,
    )
    z = jnp.einsum("ock,ctk->ot", w, cols, preferred_element_type=jnp.float32)
    z = z + b[:, None]
    if fuse_act:
        z = jnp.where(z >= 0, z, _SLOPE * z)
    o_ref[...] = z.astype(o_ref.dtype)


def conv1d_pallas(x, w, b, stride: int, fuse_act: bool = False):
    """Forward-only Pallas conv1d.  x (cin, n) -> (cout, n_out)."""
    cin, n = x.shape
    cout, cin_w, k = w.shape
    assert cin == cin_w, (cin, cin_w)
    assert k in (1, 3) and stride in (1, 2), (k, stride)
    pad = 1 if k == 3 else 0
    n_out = ref.conv1d_out_len(n, k, stride)
    tile = _pick_tile(n_out)
    kernel = functools.partial(
        _conv1d_kernel, stride=stride, k=k, pad=pad, tile=tile, fuse_act=fuse_act
    )
    return pl.pallas_call(
        kernel,
        grid=(n_out // tile,),
        in_specs=[
            pl.BlockSpec((cin, n), lambda j: (0, 0)),        # x: pinned whole
            pl.BlockSpec((cout, cin, k), lambda j: (0, 0, 0)),  # w: pinned whole
            pl.BlockSpec((cout,), lambda j: (0,)),           # b: pinned whole
        ],
        out_specs=pl.BlockSpec((cout, tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((cout, n_out), x.dtype),
        interpret=True,
    )(x, w, b)


# ---------------------------------------------------------------------------
# Differentiable wrapper: fwd = Pallas kernel, bwd = vjp of the jnp oracle.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def conv1d(x, w, b, stride: int):
    """Differentiable strided conv1d whose forward pass is the Pallas kernel."""
    return conv1d_pallas(x, w, b, stride)


def _conv1d_fwd(x, w, b, stride):
    return conv1d_pallas(x, w, b, stride), (x, w, b)


def _conv1d_bwd(stride, res, dz):
    x, w, b = res
    _, vjp = jax.vjp(lambda x_, w_, b_: ref.conv1d(x_, w_, b_, stride), x, w, b)
    return vjp(dz)


conv1d.defvjp(_conv1d_fwd, _conv1d_bwd)
