"""L1 Pallas kernel: fused threshold-sparsify + error-feedback update.

The inner loop of Algorithm 1 (both communication patterns):

    u    = grad + acc            # add back the accumulated residual
    mask = |u| >= thr
    g_sp = u * mask              # transmitted sparse gradient
    acc' = u * (1 - mask)        # residual carried to the next iteration

Fusing the three elementwise passes into one kernel halves HBM traffic on
the full-length gradient vector (read g, read acc, write g_sp, write acc'
— versus two separate mask/select passes).  The threshold is computed by
the rust coordinator (exact top-k selection, see rust/src/compress/topk.rs)
and passed as a (1,)-shaped operand.

Tiled along the vector; purely elementwise, so each grid step touches one
(TILE,) block of each operand — no halos, no pinned tensors.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv1d import _pick_tile


def _sparsify_kernel(g_ref, acc_ref, thr_ref, gsp_ref, acc_out_ref):
    u = g_ref[...] + acc_ref[...]
    thr = thr_ref[0]
    keep = jnp.abs(u) >= thr
    gsp_ref[...] = jnp.where(keep, u, 0.0).astype(gsp_ref.dtype)
    acc_out_ref[...] = jnp.where(keep, 0.0, u).astype(acc_out_ref.dtype)


def sparsify_pallas(g, acc, thr):
    """g, acc: (n,); thr: (1,) -> (g_sparse, acc_next), both (n,)."""
    (n,) = g.shape
    assert acc.shape == (n,) and thr.shape == (1,)
    tile = _pick_tile(n, cap=1024)
    return pl.pallas_call(
        _sparsify_kernel,
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda j: (j,)),
            pl.BlockSpec((tile,), lambda j: (j,)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda j: (j,)),
            pl.BlockSpec((tile,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), g.dtype),
            jax.ShapeDtypeStruct((n,), g.dtype),
        ],
        interpret=True,
    )(g, acc, thr)
