"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact functional twin here,
implemented with stock jax.lax / jnp primitives.  pytest (python/tests/)
sweeps shapes and dtypes with hypothesis and asserts allclose between the
kernel (interpret=True) and these oracles.  The custom-vjp backward passes
of the kernels are *derived* from these oracles via jax.vjp, so matching
forward semantics here is the single correctness contract.

Conventions (shared with conv1d.py / deconv1d.py):
  x : (cin, n)        channel-major 1-D signal
  w : (cout, cin, k)  k in {1, 3}
  b : (cout,)
  stride 2 convs use padding (1, 1)  -> n_out = n // 2   (n even)
  stride 1 k3 convs use padding (1, 1) -> n_out = n      ("SAME")
  stride 1 k1 convs use no padding     -> n_out = n
  stride 2 deconvs use lhs_dilation=2, padding (1, 2) -> n_out = 2 * n
"""

import jax
import jax.numpy as jnp


def conv1d_out_len(n: int, k: int, stride: int) -> int:
    """Output length of conv1d under the padding conventions above."""
    pad = 2 if k == 3 else 0
    return (n + pad - k) // stride + 1


def conv1d(x, w, b, stride: int):
    """Reference strided 1-D convolution (cross-correlation), channel-major.

    out[o, j] = b[o] + sum_{c,t} w[o, c, t] * xpad[c, stride*j + t]
    """
    k = w.shape[2]
    pad = (1, 1) if k == 3 else (0, 0)
    # lax conv wants NCH; add a unit batch dim.
    out = jax.lax.conv_general_dilated(
        x[None, :, :].astype(jnp.float32),
        # OIH layout: (cout, cin, k)
        w.astype(jnp.float32),
        window_strides=(stride,),
        padding=[pad],
        dimension_numbers=("NCH", "OIH", "NCH"),
    )[0]
    return out + b[:, None]


def deconv1d(x, w, b, stride: int):
    """Reference transposed 1-D convolution.

    stride == 2: zero-interleave the input (values at odd positions of a
    (cin, 2n+2) buffer), then run a k=3, stride-1 valid conv -> (cout, 2n).
    Equivalent to lax lhs_dilation=2 with padding (1, 2).
    stride == 1: plain "SAME" k3 conv (used by the first decoder layer).
    """
    if stride == 1:
        return conv1d(x, w, b, 1)
    out = jax.lax.conv_general_dilated(
        x[None, :, :].astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1,),
        padding=[(1, 2)],
        lhs_dilation=(2,),
        dimension_numbers=("NCH", "OIH", "NCH"),
    )[0]
    return out + b[:, None]


def leaky_relu(x, slope: float = 0.01):
    """LeakyReLU used between autoencoder layers (paper cites [52])."""
    return jnp.where(x >= 0, x, slope * x)


def sparsify(g, acc, thr):
    """Reference fused sparsify + error-feedback update (Algorithm 1 core).

    u      = g + acc                   (gradient + locally accumulated residual)
    mask   = |u| >= thr
    g_sp   = u * mask                  (the transmitted sparse gradient)
    acc'   = u * (1 - mask)            (residual kept for the next iteration)
    """
    u = g + acc
    mask = (jnp.abs(u) >= thr).astype(u.dtype)
    return u * mask, u * (1.0 - mask)
