"""L1 Pallas kernel: stride-2 transposed 1-D convolution (LGC decoder).

The LGC decoder (paper Table II) upsamples the 4-channel latent back to the
mu-length gradient vector with stride-2 transposed convs.  The kernel
realizes the transpose as zero-interleave + stride-1 k3 conv, entirely in
VMEM:

  xz (cin, 2n+2), xz[:, 2i+1] = x[:, i]        (zero-interleave, pad 1/2)
  out[o, j] = b[o] + sum_{c,t} w[o, c, t] * xz[c, j + t],  j in [0, 2n)

which matches lax.conv_general_dilated(lhs_dilation=2, padding=(1,2)) —
the oracle in kernels/ref.py.  Tiling mirrors conv1d.py: weights pinned in
VMEM, output tiled along length, one MXU-shaped einsum per grid step.

stride == 1 (first decoder layer) delegates to the conv1d kernel.

Differentiation: custom_vjp with the backward derived from the oracle,
same scheme as conv1d.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .conv1d import _pick_tile, conv1d


def _deconv1d_kernel(x_ref, w_ref, b_ref, o_ref, *, tile):
    j0 = pl.program_id(0)
    x = x_ref[...]                        # (cin, n)
    w = w_ref[...]                        # (cout, cin, 3)
    b = b_ref[...]
    cin, n = x.shape
    # Zero-interleave with the (1, 2) padding baked in: length 2n + 2,
    # values at odd positions 1, 3, ..., 2n-1.
    xz = jnp.zeros((cin, 2 * n + 2), x.dtype)
    xz = xz.at[:, 1:2 * n:2].set(x)
    win = jax.lax.dynamic_slice(xz, (0, j0 * tile), (cin, tile + 2))
    cols = jnp.stack([win[:, t:t + tile] for t in range(3)], axis=-1)  # (cin, tile, 3)
    z = jnp.einsum("ock,ctk->ot", w, cols, preferred_element_type=jnp.float32)
    o_ref[...] = (z + b[:, None]).astype(o_ref.dtype)


def deconv1d_pallas(x, w, b, stride: int):
    """Forward-only Pallas transposed conv1d.  x (cin, n) -> (cout, 2n)."""
    if stride == 1:
        # First decoder layer is stride-1 "SAME"; reuse the conv kernel.
        from .conv1d import conv1d_pallas

        return conv1d_pallas(x, w, b, 1)
    cin, n = x.shape
    cout, cin_w, k = w.shape
    assert cin == cin_w and k == 3 and stride == 2, (x.shape, w.shape, stride)
    n_out = 2 * n
    tile = _pick_tile(n_out)
    kernel = functools.partial(_deconv1d_kernel, tile=tile)
    return pl.pallas_call(
        kernel,
        grid=(n_out // tile,),
        in_specs=[
            pl.BlockSpec((cin, n), lambda j: (0, 0)),
            pl.BlockSpec((cout, cin, k), lambda j: (0, 0, 0)),
            pl.BlockSpec((cout,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((cout, tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((cout, n_out), x.dtype),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def deconv1d(x, w, b, stride: int):
    """Differentiable transposed conv1d; forward pass is the Pallas kernel."""
    return deconv1d_pallas(x, w, b, stride)


def _deconv1d_fwd(x, w, b, stride):
    return deconv1d_pallas(x, w, b, stride), (x, w, b)


def _deconv1d_bwd(stride, res, dz):
    x, w, b = res
    _, vjp = jax.vjp(lambda x_, w_, b_: ref.deconv1d(x_, w_, b_, stride), x, w, b)
    return vjp(dz)


deconv1d.defvjp(_deconv1d_fwd, _deconv1d_bwd)
