"""L1 Pallas kernels for the LGC compute hot-spot + their jnp oracles.

conv1d   — strided 1-D conv (encoder layers, paper Table I)
deconv1d — stride-2 transposed 1-D conv (decoder layers, paper Table II)
sparsify — fused threshold-sparsify + error-feedback update (Algorithm 1)
ref      — pure-jnp oracles; the single correctness contract for all three
"""

from .conv1d import conv1d, conv1d_pallas
from .deconv1d import deconv1d, deconv1d_pallas
from .sparsify import sparsify_pallas
from . import ref

__all__ = ["conv1d", "conv1d_pallas", "deconv1d", "deconv1d_pallas",
           "sparsify_pallas", "ref"]
