"""Shared plumbing for the flat-parameter model interface."""

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ModelSpec:
    """A model exposed to aot.py / the rust runtime with flat parameters.

    `loss_and_acc(params, x, y) -> (loss, acc)` is the only model-specific
    piece; grad_step / evaluate derive from it.
    """

    name: str
    param_shapes_: List[Tuple[int, ...]]
    layer_of_param: List[int]          # layer index per param (info plane)
    input_shape: Tuple[int, ...]       # per-example, e.g. (16, 16, 3)
    input_dtype: str                   # "f32" | "i32" (token ids)
    num_classes: int
    batch: int
    loss_and_acc: Callable = None

    def param_shapes(self):
        return list(self.param_shapes_)

    def n_params(self) -> int:
        total = 0
        for s in self.param_shapes_:
            n = 1
            for d in s:
                n *= d
            total += n
        return total

    def init(self, key):
        return he_init(self.param_shapes_, key)

    def grad_step(self, params, x, y):
        """(loss, acc, grads) — the per-node per-iteration HLO entry point."""
        def f(ps):
            loss, acc = self.loss_and_acc(ps, x, y)
            return loss, acc
        (loss, acc), grads = jax.value_and_grad(f, has_aux=True)(params)
        return loss, acc, grads

    def evaluate(self, params, x, y):
        return self.loss_and_acc(params, x, y)


def he_init(shapes: Sequence[Tuple[int, ...]], key):
    """He-normal for weights (rank > 1), zeros for biases (rank 1).

    fan_in = prod(shape[1:]) — the same rule the rust side replays from the
    manifest so both runtimes produce identically-distributed inits.
    """
    params = []
    for shape in shapes:
        key, sub = jax.random.split(key)
        if len(shape) > 1:
            fan_in = 1
            for d in shape[1:]:
                fan_in *= d
            params.append(jax.random.normal(sub, shape, jnp.float32)
                          * jnp.sqrt(2.0 / fan_in))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def conv2d(x, w, stride: int = 1):
    """x (B, H, W, C), w (kh, kw, cin, cout), SAME padding."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def softmax_xent_and_acc(logits, y):
    """logits (B, C) or (B, P, C) flattened; y int labels of matching rank."""
    if logits.ndim == 3:
        logits = logits.reshape(-1, logits.shape[-1])
        y = y.reshape(-1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc
