"""transformer_mini: decoder-only LM for the end-to-end driver.

The paper's method is model-agnostic; the e2e example (examples/train_e2e.rs)
trains this transformer with LGC on a synthetic Markov corpus to prove all
layers compose on a modern workload.  Sized for CPU-PJRT throughput
(~0.8M params at the default d_model=128; the paper's ResNet50 scale is a
documented substitution, DESIGN.md §2).

Pre-LN blocks: LN -> causal MHA -> residual; LN -> MLP(4x, gelu) -> residual;
learned positional embeddings; weight-tied output head is *not* used (a
separate unembedding keeps the flat-param interface uniform).
"""

import jax
import jax.numpy as jnp

from .common import ModelSpec, softmax_xent_and_acc

_VOCAB = 64
_SEQ = 32
_D = 128
_HEADS = 4
_LAYERS = 2
_MLP = 4 * _D


def _shapes():
    shapes, layer_of = [], []
    shapes += [(_VOCAB, _D)]           # token embedding
    layer_of += [0]
    shapes += [(_SEQ, _D)]             # positional embedding
    layer_of += [0]
    li = 1
    for _ in range(_LAYERS):
        # ln1 scale/bias, wq, wk, wv, wo, ln2 scale/bias, w1, b1, w2, b2
        shapes += [(_D,), (_D,),
                   (_D, _D), (_D, _D), (_D, _D), (_D, _D),
                   (_D,), (_D,),
                   (_D, _MLP), (_MLP,), (_MLP, _D), (_D,)]
        layer_of += [li] * 12
        li += 1
    shapes += [(_D,), (_D,)]           # final LN
    layer_of += [li, li]
    shapes += [(_D, _VOCAB), (_VOCAB,)]  # unembedding
    layer_of += [li + 1, li + 1]
    return shapes, layer_of


def _ln(h, scale, bias):
    # (1 + scale) parameterization: the flat-param init rule zeroes all
    # rank-1 tensors, so the effective initial gain is 1, not 0.
    m = jnp.mean(h, axis=-1, keepdims=True)
    v = jnp.var(h, axis=-1, keepdims=True)
    return (h - m) / jnp.sqrt(v + 1e-5) * (1.0 + scale) + bias


def _loss_and_acc(params, x, y):
    """x (B, S) int32 tokens; y (B, S) int32 next-token targets."""
    b, s = x.shape
    it = iter(range(len(params)))
    p = lambda: params[next(it)]
    emb, pos = p(), p()
    h = emb[x] + pos[None, :, :]
    dh = _D // _HEADS
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    for _ in range(_LAYERS):
        g1, b1 = p(), p()
        wq, wk, wv, wo = p(), p(), p(), p()
        g2, b2 = p(), p()
        w1, bb1, w2, bb2 = p(), p(), p(), p()
        z = _ln(h, g1, b1)
        q = (z @ wq).reshape(b, s, _HEADS, dh)
        k = (z @ wk).reshape(b, s, _HEADS, dh)
        v = (z @ wv).reshape(b, s, _HEADS, dh)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
        att = jnp.where(mask[None, None], att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, _D)
        h = h + o @ wo
        z = _ln(h, g2, b2)
        h = h + jax.nn.gelu(z @ w1 + bb1) @ w2 + bb2
    gf, bf = p(), p()
    wu, bu = p(), p()
    logits = _ln(h, gf, bf) @ wu + bu            # (B, S, V)
    return softmax_xent_and_acc(logits, y)


def transformer_mini_spec(batch: int = 8) -> ModelSpec:
    shapes, layer_of = _shapes()
    return ModelSpec(
        name="transformer_mini",
        param_shapes_=shapes,
        layer_of_param=layer_of,
        input_shape=(_SEQ,),
        input_dtype="i32",
        num_classes=_VOCAB,
        batch=batch,
        loss_and_acc=_loss_and_acc,
    )
