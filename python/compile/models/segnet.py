"""segnet_mini: encoder-decoder dense predictor (PSPNet/CamVid stand-in).

24x24x3 input -> per-pixel logits over 8 classes.  Encoder: three convs
(two stride-2); decoder: two nearest-upsample+conv stages (resize-conv in
place of transposed conv2d — avoids checkerboard artifacts and keeps the
jax graph simple); final 1x1 conv classifier.  "Pixel accuracy" is the
paper's §VI-D metric.
"""

import jax.numpy as jnp

from .common import ModelSpec, conv2d, softmax_xent_and_acc

_CLASSES = 8
_ENC = [(3, 32, 2), (32, 64, 2), (64, 64, 1)]   # (cin, cout, stride)
_DEC = [(64, 48), (48, 32)]                      # upsample x2 then conv


def _shapes():
    shapes, layer_of = [], []
    li = 0
    for cin, cout, _ in _ENC:
        shapes += [(3, 3, cin, cout), (cout,)]
        layer_of += [li, li]
        li += 1
    for cin, cout in _DEC:
        shapes += [(3, 3, cin, cout), (cout,)]
        layer_of += [li, li]
        li += 1
    shapes += [(1, 1, _DEC[-1][1], _CLASSES), (_CLASSES,)]
    layer_of += [li, li]
    return shapes, layer_of


def _upsample2(h):
    b, hh, ww, c = h.shape
    h = jnp.broadcast_to(h[:, :, None, :, None, :], (b, hh, 2, ww, 2, c))
    return h.reshape(b, hh * 2, ww * 2, c)


def _loss_and_acc(params, x, y):
    i = 0
    h = x
    for _, _, stride in _ENC:
        h = jnp.maximum(conv2d(h, params[2 * i], stride) + params[2 * i + 1], 0.0)
        i += 1
    for _ in _DEC:
        h = _upsample2(h)
        h = jnp.maximum(conv2d(h, params[2 * i], 1) + params[2 * i + 1], 0.0)
        i += 1
    logits = conv2d(h, params[2 * i], 1) + params[2 * i + 1]  # (B, H, W, C)
    return softmax_xent_and_acc(logits.reshape(logits.shape[0], -1, _CLASSES),
                                y)


def segnet_mini_spec(batch: int = 8) -> ModelSpec:
    shapes, layer_of = _shapes()
    return ModelSpec(
        name="segnet_mini",
        param_shapes_=shapes,
        layer_of_param=layer_of,
        input_shape=(24, 24, 3),
        input_dtype="f32",
        num_classes=_CLASSES,
        batch=batch,
        loss_and_acc=_loss_and_acc,
    )
