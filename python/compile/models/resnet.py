"""resnet_mini / resnet_mini_deep: residual CNNs (ResNet50/101 stand-ins).

Residual element-wise adds are structurally load-bearing for the paper's
Fig. 4 observation (MI/entropy peaks on layers that follow residual sums),
so the minis keep the exact block topology: stem conv, three stages of
basic blocks (two 3x3 convs + identity/projection skip), stride-2 stage
transitions with 1x1 projection, GAP, fc.

blocks_per_stage=2 -> 15 convs (~0.9M params, "ResNet50" stand-in)
blocks_per_stage=3 -> 21 convs (~1.3M params, "ResNet101" stand-in)
"""

import jax.numpy as jnp

from .common import ModelSpec, conv2d, softmax_xent_and_acc

_WIDTHS = [32, 64, 128]
_CLASSES = 10


def _plan(blocks_per_stage):
    """Emit the conv layer list: (kind, cin, cout, stride) with kinds
    'stem' | 'a' | 'b' | 'proj'."""
    plan = [("stem", 3, _WIDTHS[0], 1)]
    cin = _WIDTHS[0]
    for si, width in enumerate(_WIDTHS):
        for bi in range(blocks_per_stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            plan.append(("a", cin, width, stride))
            plan.append(("b", width, width, 1))
            if cin != width or stride != 1:
                plan.append(("proj", cin, width, stride))
            cin = width
    return plan


def _shapes(blocks_per_stage):
    shapes, layer_of = [], []
    for li, (kind, cin, cout, _) in enumerate(_plan(blocks_per_stage)):
        k = 1 if kind == "proj" else 3
        shapes += [(k, k, cin, cout), (cout,)]
        layer_of += [li, li]
    n_layers = len(_plan(blocks_per_stage))
    shapes += [(_WIDTHS[-1], _CLASSES), (_CLASSES,)]
    layer_of += [n_layers, n_layers]
    return shapes, layer_of


def _loss_and_acc_factory(blocks_per_stage):
    plan = _plan(blocks_per_stage)

    def loss_and_acc(params, x, y):
        def cv(i, h, stride):
            return conv2d(h, params[2 * i], stride) + params[2 * i + 1]

        i = 0
        h = jnp.maximum(cv(0, x, plan[0][3]), 0.0)
        i = 1
        while i < len(plan):
            kind, cin, cout, stride = plan[i]
            assert kind == "a"
            z = jnp.maximum(cv(i, h, stride), 0.0)
            z = cv(i + 1, z, 1)
            if i + 2 < len(plan) and plan[i + 2][0] == "proj":
                skip = cv(i + 2, h, stride)
                i += 3
            else:
                skip = h
                i += 2
            h = jnp.maximum(z + skip, 0.0)     # the residual sum (Fig. 4)
        h = jnp.mean(h, axis=(1, 2))
        logits = h @ params[-2] + params[-1]
        return softmax_xent_and_acc(logits, y)

    return loss_and_acc


def resnet_mini_spec(blocks_per_stage: int = 2, name: str = "resnet_mini",
                     batch: int = 16) -> ModelSpec:
    shapes, layer_of = _shapes(blocks_per_stage)
    return ModelSpec(
        name=name,
        param_shapes_=shapes,
        layer_of_param=layer_of,
        input_shape=(16, 16, 3),
        input_dtype="f32",
        num_classes=_CLASSES,
        batch=batch,
        loss_and_acc=_loss_and_acc_factory(blocks_per_stage),
    )
