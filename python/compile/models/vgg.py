"""vgg11_mini: the paper's VGG11 (§VI-E) — 11 conv layers + fc, scaled.

Used by the Fig. 12 large-scale information-plane experiment (paper:
VGG11 on Food101 across 16 nodes).  Plain conv stacks with max-pool
stand-ins realized as stride-2 convs (pooling-free keeps the flat-param
gradient analysis uniform); ReLU after every conv like the original.
"""

import jax.numpy as jnp

from .common import ModelSpec, conv2d, softmax_xent_and_acc

# (cin, cout, stride) x 11 — stride-2 where VGG11 max-pools.
_LAYERS = [
    (3, 16, 1),
    (16, 32, 2),
    (32, 64, 1),
    (64, 64, 2),
    (64, 96, 1),
    (96, 96, 2),
    (96, 128, 1),
    (128, 128, 1),
    (128, 128, 2),
    (128, 128, 1),
    (128, 128, 1),
]
_CLASSES = 10


def _shapes():
    shapes, layer_of = [], []
    for li, (cin, cout, _) in enumerate(_LAYERS):
        shapes += [(3, 3, cin, cout), (cout,)]
        layer_of += [li, li]
    shapes += [(_LAYERS[-1][1], _CLASSES), (_CLASSES,)]
    layer_of += [len(_LAYERS), len(_LAYERS)]
    return shapes, layer_of


def _loss_and_acc(params, x, y):
    h = x
    for li, (_, _, stride) in enumerate(_LAYERS):
        h = jnp.maximum(conv2d(h, params[2 * li], stride) + params[2 * li + 1], 0.0)
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ params[-2] + params[-1]
    return softmax_xent_and_acc(logits, y)


def vgg11_mini_spec(batch: int = 16) -> ModelSpec:
    shapes, layer_of = _shapes()
    return ModelSpec(
        name="vgg11_mini",
        param_shapes_=shapes,
        layer_of_param=layer_of,
        input_shape=(16, 16, 3),
        input_dtype="f32",
        num_classes=_CLASSES,
        batch=batch,
        loss_and_acc=_loss_and_acc,
    )
