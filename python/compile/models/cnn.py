"""ConvNet5: the paper's 5-conv custom CNN (§VI-E), BN-free (DESIGN.md §10).

16x16x3 input, 10 classes.  conv(24,s1) conv(32,s2) conv(48,s2) conv(64,s2)
conv(64,s1) -> global-average-pool -> fc.  ~80K params.
"""

import jax.numpy as jnp

from .common import ModelSpec, conv2d, softmax_xent_and_acc

_LAYERS = [  # (cin, cout, stride)
    (3, 24, 1),
    (24, 32, 2),
    (32, 48, 2),
    (48, 64, 2),
    (64, 64, 1),
]
_CLASSES = 10


def _shapes():
    shapes, layer_of = [], []
    for li, (cin, cout, _) in enumerate(_LAYERS):
        shapes += [(3, 3, cin, cout), (cout,)]
        layer_of += [li, li]
    shapes += [(_LAYERS[-1][1], _CLASSES), (_CLASSES,)]
    layer_of += [len(_LAYERS), len(_LAYERS)]
    return shapes, layer_of


def _loss_and_acc(params, x, y):
    h = x
    for li, (_, _, stride) in enumerate(_LAYERS):
        w, b = params[2 * li], params[2 * li + 1]
        h = jnp.maximum(conv2d(h, w, stride) + b, 0.0)
    h = jnp.mean(h, axis=(1, 2))                      # GAP (B, C)
    logits = h @ params[-2] + params[-1]
    return softmax_xent_and_acc(logits, y)


def convnet5_spec(batch: int = 16) -> ModelSpec:
    shapes, layer_of = _shapes()
    return ModelSpec(
        name="convnet5",
        param_shapes_=shapes,
        layer_of_param=layer_of,
        input_shape=(16, 16, 3),
        input_dtype="f32",
        num_classes=_CLASSES,
        batch=batch,
        loss_and_acc=_loss_and_acc,
    )
