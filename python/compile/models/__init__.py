"""L2 primary models (the networks being trained in a distributed manner).

Scaled-down stand-ins for the paper's workloads (DESIGN.md §2):

  convnet5        — 5-conv CNN + fc        (paper's ConvNet5, §VI-E)
  resnet_mini     — residual CNN, 2 blocks/stage  (ResNet50 stand-in)
  resnet_mini_deep— residual CNN, 3 blocks/stage  (ResNet101 stand-in)
  segnet_mini     — encoder-decoder dense predictor (PSPNet stand-in)
  transformer_mini— decoder-only LM (e2e driver workload)
  vgg11_mini      — 11-conv VGG (paper's VGG11, §VI-E / Fig. 12)

Every model exposes the same flat-parameter interface consumed by aot.py
and the rust runtime:

  spec = MODELS[name]
  spec.param_shapes()            -> [shape, ...]      (flat order)
  spec.init(key)                 -> [array, ...]
  spec.grad_step(params, x, y)   -> (loss, acc, [grad, ...])
  spec.evaluate(params, x, y)    -> (loss, acc)
  spec.layer_of_param            -> [layer_idx, ...]  (per param, for the
                                     per-layer info-plane analysis and the
                                     first/last-layer exclusion rule §VI-A)
"""

from .common import ModelSpec
from .cnn import convnet5_spec
from .resnet import resnet_mini_spec
from .segnet import segnet_mini_spec
from .transformer import transformer_mini_spec
from .vgg import vgg11_mini_spec

MODELS = {
    "convnet5": convnet5_spec(),
    "resnet_mini": resnet_mini_spec(blocks_per_stage=2),
    "resnet_mini_deep": resnet_mini_spec(blocks_per_stage=3, name="resnet_mini_deep"),
    "segnet_mini": segnet_mini_spec(),
    "transformer_mini": transformer_mini_spec(),
    "vgg11_mini": vgg11_mini_spec(),
}

__all__ = ["MODELS", "ModelSpec"]
