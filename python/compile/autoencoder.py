"""L2: the LGC gradient-compression autoencoders (paper §IV, Tables I & II).

Two instances, matching the two communication patterns:

  * PS  (§IV-A, "decoupling"): one shared encoder E_c, K per-node decoders
    D_c^k.  The decoder receives the compressed common representation g^c
    plus the node's *innovation* vector (dense-scattered top-10%-of-top-k),
    concatenated as an extra channel before the final 1x1 conv.
    Training loss: lambda1 * L_rec + lambda2 * L_sim   (eqs. 5-7).
  * RAR (§IV-B, "aggregation"): one shared encoder + one shared decoder;
    the K latents are averaged and the decoder reconstructs the *average*
    gradient (eqs. 8-11).

Architecture (paper Table I/II, one documented deviation — DESIGN.md §7):
  encoder: conv(64,k3,s2) conv(128,k3,s2) conv(256,k3,s2) conv(64,k3,s2)
           conv(4,k1,s1), leaky-relu between layers  ->  latent (4, mu/16)
  decoder: deconv(4,k3,s1) deconv(32,k3,s2) deconv(64,k3,s2)
           deconv(128,k3,s2) deconv(32,k3,s2) [concat innovation] conv(1,k1)

All convs are the L1 Pallas kernels (kernels/conv1d.py, deconv1d.py), so
every entry point lowered by aot.py carries the kernels in its HLO.

Parameter layout (the flat order the rust runtime uses, see aot.py):
  encoder: [w1, b1, ..., w5, b5]                          (10 arrays)
  decoder: [w1, b1, ..., w5, b5, wf, bf]                  (12 arrays)
PS decoders are stacked along a leading K axis (same 12 arrays, K-leading).
"""

import jax
import jax.numpy as jnp

from .kernels import conv1d, deconv1d
from .kernels.ref import leaky_relu

# (cout, cin, k, stride) per layer.
ENC_SPEC = [
    (64, 1, 3, 2),
    (128, 64, 3, 2),
    (256, 128, 3, 2),
    (64, 256, 3, 2),
    (4, 64, 1, 1),
]
# Five deconvs; the first is stride-1 (paper's Table II lists five stride-2
# deconvs, which cannot invert a 16x-downsampling encoder — DESIGN.md §7).
DEC_SPEC = [
    (4, 4, 3, 1),
    (32, 4, 3, 2),
    (64, 32, 3, 2),
    (128, 64, 3, 2),
    (32, 128, 3, 2),
]
LATENT_CH = 4
DOWN = 16  # total encoder downsampling; mu must be a multiple of this.


def enc_param_shapes():
    shapes = []
    for cout, cin, k, _ in ENC_SPEC:
        shapes += [(cout, cin, k), (cout,)]
    return shapes


def dec_param_shapes(ps: bool):
    """ps=True adds the innovation channel to the final 1x1 conv input."""
    shapes = []
    for cout, cin, k, _ in DEC_SPEC:
        shapes += [(cout, cin, k), (cout,)]
    final_cin = DEC_SPEC[-1][0] + (1 if ps else 0)
    shapes += [(1, final_cin, 1), (1,)]
    return shapes


def init_params(shapes, key):
    """He-normal init (fan-in = prod of all dims but the first for weights)."""
    params = []
    for shape in shapes:
        key, sub = jax.random.split(key)
        if len(shape) > 1:
            fan_in = 1
            for d in shape[1:]:
                fan_in *= d
            params.append(jax.random.normal(sub, shape, jnp.float32)
                          * jnp.sqrt(2.0 / fan_in))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def encode(enc_params, g):
    """g (1, mu) -> latent (4, mu/16).  E_c of eqs. (3)/(8)."""
    h = g
    for i, (_, _, _, stride) in enumerate(ENC_SPEC):
        w, b = enc_params[2 * i], enc_params[2 * i + 1]
        h = conv1d(h, w, b, stride)
        if i < len(ENC_SPEC) - 1:
            h = leaky_relu(h)
    return h


def decode(dec_params, latent, innovation=None):
    """latent (4, mu/16) [+ innovation (1, mu)] -> g_rec (1, mu).

    innovation != None selects the PS decoder D_c^k (eq. 4): the dense
    innovation vector is concatenated as an extra channel before the final
    1x1 conv, exactly as Fig. 5(a) describes.
    """
    h = latent
    for i, (_, _, _, stride) in enumerate(DEC_SPEC):
        w, b = dec_params[2 * i], dec_params[2 * i + 1]
        h = deconv1d(h, w, b, stride)
        h = leaky_relu(h)
    if innovation is not None:
        h = jnp.concatenate([h, innovation], axis=0)
    wf, bf = dec_params[-2], dec_params[-1]
    return conv1d(h, wf, bf, 1)


def _sgd(params, grads, lr):
    return [p - lr * g for p, g in zip(params, grads)]


# ---------------------------------------------------------------------------
# RAR train step (eq. 11): decoder targets the average gradient.
# ---------------------------------------------------------------------------

def rar_train_step(enc_params, dec_params, grads, lr):
    """grads (K, mu).  Returns (enc', dec', rec_loss)."""
    k_nodes = grads.shape[0]

    def loss_fn(ep, dp):
        latents = [encode(ep, grads[k][None, :]) for k in range(k_nodes)]
        lat_avg = sum(latents) / float(k_nodes)
        rec = decode(dp, lat_avg)[0]
        target = jnp.mean(grads, axis=0)
        # Mean (not the paper's sum): keeps the SGD step size independent
        # of mu and K, which the fixed lr=1e-3 of SS VI-A requires once
        # inputs are RMS-normalized (see rust compress/autoencoder.rs).
        return jnp.mean((rec - target) ** 2)

    loss, (g_enc, g_dec) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
        enc_params, dec_params)
    return _sgd(enc_params, g_enc, lr), _sgd(dec_params, g_dec, lr), loss


# ---------------------------------------------------------------------------
# PS train step (eqs. 5-7): K decoders, similarity + reconstruction loss.
# ---------------------------------------------------------------------------

def ps_train_step(enc_params, dec_params_stacked, grads, innovations, ridx,
                  lr, lam1, lam2):
    """grads, innovations: (K, mu); dec_params_stacked: 12 arrays, K-leading.

    ridx (traced i32 scalar) picks which node's encoding is used as the
    common representation this iteration (the paper chooses randomly; the
    rust coordinator draws it and passes it in).
    Returns (enc', decs', rec_loss, sim_loss).
    """
    k_nodes = grads.shape[0]

    def loss_fn(ep, dps):
        encs = [encode(ep, grads[k][None, :]) for k in range(k_nodes)]
        sim = 0.0
        npairs = max(k_nodes * (k_nodes - 1) // 2, 1)
        for a in range(k_nodes):
            for b in range(a + 1, k_nodes):
                sim = sim + jnp.mean((encs[a] - encs[b]) ** 2)
        sim = sim / npairs  # mean over pairs (scale-stable; see rar note)
        enc_stack = jnp.stack(encs)                       # (K, 4, mu/16)
        g_common = jnp.take(enc_stack, ridx, axis=0)      # dynamic choice
        rec = 0.0
        for k in range(k_nodes):
            dp_k = [p[k] for p in dps]
            rec_k = decode(dp_k, g_common, innovations[k][None, :])[0]
            rec = rec + jnp.mean((rec_k - grads[k]) ** 2)
        rec = rec / k_nodes
        return lam1 * rec + lam2 * sim, (rec, sim)

    (_, (rec, sim)), (g_enc, g_dec) = jax.value_and_grad(
        loss_fn, argnums=(0, 1), has_aux=True)(enc_params, dec_params_stacked)
    return (_sgd(enc_params, g_enc, lr), _sgd(dec_params_stacked, g_dec, lr),
            rec, sim)
