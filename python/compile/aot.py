"""AOT pipeline: lower every entry point to HLO text + write the manifest.

python runs ONCE (`make artifacts`); after that the rust binary is
self-contained.  Interchange is HLO *text*, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` crate binds) rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Modules emitted (see DESIGN.md §5-6):
  {model}_grad_step        (params..., x, y) -> (loss, acc, grads...)
  {model}_eval             (params..., x, y) -> (loss, acc)
  {model}_sparsify         (g, acc, thr)     -> (g_sp, acc')   [mid params]
  ae_enc_{mu}              (enc..., g (1,mu))            -> latent
  ae_dec_rar_{mu}          (dec..., latent)              -> rec (1,mu)
  ae_dec_ps_{mu}           (dec..., latent, innov (1,mu))-> rec (1,mu)
  ae_train_rar_{mu}_k{K}   (enc..., dec..., grads (K,mu), lr)
                           -> (enc'..., dec'..., loss)
  ae_train_ps_{mu}_k{K}    (enc..., decs(K-stacked)..., grads, innovs,
                            ridx, lr, lam1, lam2)
                           -> (enc'..., decs'..., rec_loss, sim_loss)

manifest.json records every module's I/O shapes/dtypes plus the model and
autoencoder metadata the rust side needs (param shapes for He-init replay,
per-param layer indices for the info-plane analysis and the first/last
layer rules, mu / eligible-parameter bookkeeping).
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import autoencoder as ae
from .kernels.sparsify import sparsify_pallas
from .models import MODELS

# (model, K) pairs that actually run LGC in the experiment suite
# (DESIGN.md §5).  Info-plane-only configs (K=16/22) need no autoencoder.
AE_CONFIGS = {
    "convnet5": [2, 4],
    "resnet_mini": [2, 4, 8],
    "resnet_mini_deep": [4],
    "segnet_mini": [2],
    "transformer_mini": [4],
}
ALPHA = 1e-3          # top-k sparsity (paper: alpha = 0.1%)
F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dt(dtype) -> str:
    return {jnp.float32: "f32", jnp.int32: "i32"}[dtype]


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.modules = {}

    def emit(self, name: str, fn, in_specs):
        """Lower fn(*in_specs) and record the module in the manifest."""
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *in_specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        flat_out, _ = jax.tree_util.tree_flatten(out_avals)
        self.modules[name] = {
            "file": fname,
            "inputs": [list(s.shape) for s in in_specs],
            "input_dtypes": [_dt(s.dtype.type) for s in in_specs],
            "outputs": [list(a.shape) for a in flat_out],
            "output_dtypes": [_dt(a.dtype.type) for a in flat_out],
        }
        print(f"  {name}: {len(in_specs)} in / {len(flat_out)} out, "
              f"{len(text)/1e6:.2f} MB hlo", flush=True)


def pad16(x: int) -> int:
    return max(16, ((x + 15) // 16) * 16)


def model_meta(m):
    """Split params into first-layer / middle / last-layer groups (§VI-A)."""
    last_layer = max(m.layer_of_param)
    first_idx = [i for i, l in enumerate(m.layer_of_param) if l == 0]
    last_idx = [i for i, l in enumerate(m.layer_of_param) if l == last_layer]
    mid_idx = [i for i, l in enumerate(m.layer_of_param)
               if l not in (0, last_layer)]
    sz = lambda s: int(jnp.prod(jnp.array(s))) if s else 1
    n_mid = sum(sz(m.param_shapes()[i]) for i in mid_idx)
    mu = pad16(int(-(-ALPHA * n_mid // 1)))  # ceil then pad to DOWN multiple
    return {
        "params": [list(s) for s in m.param_shapes()],
        "layer_of_param": list(m.layer_of_param),
        "n_params": m.n_params(),
        "n_mid": n_mid,
        "mu": mu,
        "first_param_idx": first_idx,
        "mid_param_idx": mid_idx,
        "last_param_idx": last_idx,
        "batch": m.batch,
        "input_shape": list(m.input_shape),
        "input_dtype": m.input_dtype,
        "num_classes": m.num_classes,
        "grad_step": f"{m.name}_grad_step",
        "evaluate": f"{m.name}_eval",
        "sparsify": f"{m.name}_sparsify",
    }


def io_specs(m):
    """(param_specs, x_spec, y_spec) for a model's grad_step/eval."""
    batch = m.batch
    if m.input_dtype == "i32":
        x_spec = spec((batch,) + tuple(m.input_shape), I32)
        y_spec = spec((batch,) + tuple(m.input_shape), I32)
    elif m.name == "segnet_mini":
        x_spec = spec((batch,) + tuple(m.input_shape))
        y_spec = spec((batch, m.input_shape[0] * m.input_shape[1]), I32)
    else:
        x_spec = spec((batch,) + tuple(m.input_shape))
        y_spec = spec((batch,), I32)
    return [spec(s) for s in m.param_shapes()], x_spec, y_spec


def emit_model(em: Emitter, m):
    n_p = len(m.param_shapes())
    p_specs, x_spec, y_spec = io_specs(m)

    def grad_step(*args):
        params, x, y = list(args[:n_p]), args[n_p], args[n_p + 1]
        loss, acc, grads = m.grad_step(params, x, y)
        return (loss, acc, *grads)

    def evaluate(*args):
        params, x, y = list(args[:n_p]), args[n_p], args[n_p + 1]
        return m.evaluate(params, x, y)

    em.emit(f"{m.name}_grad_step", grad_step, p_specs + [x_spec, y_spec])
    em.emit(f"{m.name}_eval", evaluate, p_specs + [x_spec, y_spec])

    meta = model_meta(m)
    n_mid = meta["n_mid"]
    em.emit(f"{m.name}_sparsify", sparsify_pallas,
            [spec((n_mid,)), spec((n_mid,)), spec((1,))])
    return meta


def emit_ae(em: Emitter, mu: int, ks):
    enc_shapes = ae.enc_param_shapes()
    dec_shapes_rar = ae.dec_param_shapes(ps=False)
    dec_shapes_ps = ae.dec_param_shapes(ps=True)
    ne, nr, np_ = len(enc_shapes), len(dec_shapes_rar), len(dec_shapes_ps)
    lat = (ae.LATENT_CH, mu // ae.DOWN)

    def enc(*args):
        return (ae.encode(list(args[:ne]), args[ne]),)

    em.emit(f"ae_enc_{mu}", enc, [spec(s) for s in enc_shapes] + [spec((1, mu))])

    def dec_rar(*args):
        return (ae.decode(list(args[:nr]), args[nr]),)

    em.emit(f"ae_dec_rar_{mu}", dec_rar,
            [spec(s) for s in dec_shapes_rar] + [spec(lat)])

    def dec_ps(*args):
        return (ae.decode(list(args[:np_]), args[np_], args[np_ + 1]),)

    em.emit(f"ae_dec_ps_{mu}", dec_ps,
            [spec(s) for s in dec_shapes_ps] + [spec(lat), spec((1, mu))])

    variants = {"enc": f"ae_enc_{mu}", "dec_rar": f"ae_dec_rar_{mu}",
                "dec_ps": f"ae_dec_ps_{mu}", "train_rar": {}, "train_ps": {}}

    for k in ks:
        def train_rar(*args, _k=k):
            ep = list(args[:ne])
            dp = list(args[ne:ne + nr])
            grads, lr = args[ne + nr], args[ne + nr + 1]
            ep2, dp2, loss = ae.rar_train_step(ep, dp, grads, lr)
            return (*ep2, *dp2, loss)

        em.emit(f"ae_train_rar_{mu}_k{k}", train_rar,
                [spec(s) for s in enc_shapes] +
                [spec(s) for s in dec_shapes_rar] +
                [spec((k, mu)), spec((), F32)])

        def train_ps(*args, _k=k):
            ep = list(args[:ne])
            dps = list(args[ne:ne + np_])
            grads, innovs, ridx, lr, lam1, lam2 = args[ne + np_:]
            ep2, dps2, rec, sim = ae.ps_train_step(
                ep, dps, grads, innovs, ridx, lr, lam1, lam2)
            return (*ep2, *dps2, rec, sim)

        em.emit(f"ae_train_ps_{mu}_k{k}", train_ps,
                [spec(s) for s in enc_shapes] +
                [spec((k,) + tuple(s)) for s in dec_shapes_ps] +
                [spec((k, mu)), spec((k, mu)), spec((), I32),
                 spec((), F32), spec((), F32), spec((), F32)])

        variants["train_rar"][str(k)] = f"ae_train_rar_{mu}_k{k}"
        variants["train_ps"][str(k)] = f"ae_train_ps_{mu}_k{k}"
    return variants


def source_fingerprint() -> str:
    """Hash of the compile-path sources; lets `make artifacts` skip cleanly."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated model subset (debugging)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    em = Emitter(args.out)
    manifest = {"version": 1, "alpha": ALPHA, "models": {}, "ae": {
        "enc_shapes": [list(s) for s in ae.enc_param_shapes()],
        "dec_shapes_rar": [list(s) for s in ae.dec_param_shapes(ps=False)],
        "dec_shapes_ps": [list(s) for s in ae.dec_param_shapes(ps=True)],
        "latent_ch": ae.LATENT_CH,
        "down": ae.DOWN,
        "variants": {},
    }}

    names = list(MODELS) if not args.only else args.only.split(",")
    mus = {}
    for name in names:
        print(f"model {name}:", flush=True)
        meta = emit_model(em, MODELS[name])
        manifest["models"][name] = meta
        mus.setdefault(meta["mu"], set()).update(AE_CONFIGS.get(name, []))

    for mu, ks in sorted(mus.items()):
        if not ks:
            continue
        print(f"autoencoder mu={mu} K={sorted(ks)}:", flush=True)
        manifest["ae"]["variants"][str(mu)] = emit_ae(em, mu, sorted(ks))

    manifest["modules"] = em.modules
    manifest["fingerprint"] = source_fingerprint()
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(em.modules)} modules + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
