"""L2 registry facade: primary models + LGC autoencoder entry points.

The rust coordinator never imports python; everything it needs is lowered
by aot.py into artifacts/*.hlo.txt and described in artifacts/manifest.json.
This module just re-exports the pieces aot.py lowers:

  models.MODELS[name].grad_step / evaluate      (per-node compute)
  autoencoder.encode / decode / *_train_step    (LGC compressor, §IV)
  kernels.*                                     (L1 Pallas hot-spots)
"""

from . import autoencoder
from .models import MODELS
from .kernels import conv1d, deconv1d, sparsify_pallas, ref

__all__ = ["MODELS", "autoencoder", "conv1d", "deconv1d", "sparsify_pallas",
           "ref"]
