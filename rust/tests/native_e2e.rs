//! End-to-end tests on the native CPU backend — the no-artifacts, no-PJRT
//! twin of `tests/integration.rs`.
//!
//! Everything here runs unconditionally from a clean checkout: the native
//! backend synthesizes its manifest in memory and executes every module
//! contract in pure Rust, so there is no skip path.  Coverage:
//!
//! * full three-phase `coordinator::train` for Baseline, top-k
//!   (SparseGd), and both LGC strategies — with the AE actually training
//!   (decreasing `train_losses`) and the learned encode/decode executing
//!   in phase 3 (the ISSUE-4 acceptance bar);
//! * per-method train smoke across all eight methods;
//! * §6.5 thread-count invariance extended past the codec layer: loss
//!   curves and ledger totals bit-identical between 1-thread and
//!   N-thread *full native runs* (grad steps + AE included);
//! * checkpoint save/load through a native training run (resumed run
//!   bit-identical to uninterrupted) + CRC corruption rejection;
//! * the runtime-level contracts (shape validation, AE roundtrips,
//!   sparsify semantics) against the native engine.

use lgc::config::{Method, TrainConfig};
use lgc::coordinator::{self, scheduler::Phase};
use lgc::model::{Group, Model};
use lgc::runtime::{Engine, Tensor};

fn engine() -> Engine {
    Engine::native().expect("native engine always constructs")
}

fn tiny_cfg(model: &str, method: Method, nodes: usize) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        method,
        nodes,
        steps: 12,
        warmup_iters: 4,
        ae_train_iters: 4,
        eval_every: 0,
        eval_batches: 2,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// Runtime-level
// ---------------------------------------------------------------------------

#[test]
fn native_manifest_covers_reference_models() {
    let e = engine();
    for m in ["convnet_mini", "mlp_mini"] {
        assert!(e.manifest.models.contains_key(m), "{m}");
    }
    assert!(e.platform().contains("native"));
}

#[test]
fn grad_step_executes_and_returns_finite_loss() {
    let e = engine();
    for name in ["convnet_mini", "mlp_mini"] {
        let meta = e.manifest.model(name).clone();
        let model = Model::new(&meta, 1);
        let data = lgc::data::for_model(&meta, 2);
        let batch = data.batch(0, 0);
        let (loss, acc, grads) = model.grad_step(&e, &batch).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "{name}");
        assert!((0.0..=1.0).contains(&acc));
        assert_eq!(grads.len(), meta.params.len());
        for (g, shape) in grads.iter().zip(&meta.params) {
            assert_eq!(&g.dims, shape);
        }
        // Deterministic across calls.
        let (loss2, _, grads2) = model.grad_step(&e, &batch).unwrap();
        assert_eq!(loss, loss2);
        assert_eq!(grads[0].as_f32(), grads2[0].as_f32());
    }
}

#[test]
fn engine_validates_shapes_and_dtypes() {
    let e = engine();
    let meta = e.manifest.model("convnet_mini").clone();
    // Wrong arity.
    assert!(e.run(&meta.sparsify, &[Tensor::zeros(vec![3])]).is_err());
    // Wrong shape.
    let n = meta.n_mid;
    let err = e.run(
        &meta.sparsify,
        &[Tensor::zeros(vec![n + 1]), Tensor::zeros(vec![n]), Tensor::zeros(vec![1])],
    );
    assert!(err.is_err());
    // Wrong dtype.
    let err = e.run(
        &meta.sparsify,
        &[
            Tensor::i32(vec![n], vec![0; n]),
            Tensor::zeros(vec![n]),
            Tensor::zeros(vec![1]),
        ],
    );
    assert!(err.is_err());
    // Unknown module.
    assert!(e.run("no_such_module", &[]).is_err());
}

#[test]
fn sparsify_module_matches_rust_semantics() {
    let e = engine();
    let meta = e.manifest.model("convnet_mini").clone();
    let n = meta.n_mid;
    let mut rng = lgc::util::rng::Rng::new(3);
    let g = rng.normal_vec(n, 1.0);
    let acc = rng.normal_vec(n, 0.5);
    let thr = 0.8f32;
    let out = e
        .run(
            &meta.sparsify,
            &[
                Tensor::f32(vec![n], g.clone()),
                Tensor::f32(vec![n], acc.clone()),
                Tensor::f32(vec![1], vec![thr]),
            ],
        )
        .unwrap();
    let (gsp, acc2) = (out[0].as_f32(), out[1].as_f32());
    for i in 0..n {
        let u = g[i] + acc[i];
        if u.abs() >= thr {
            assert_eq!(gsp[i], u);
            assert_eq!(acc2[i], 0.0);
        } else {
            assert_eq!(gsp[i], 0.0);
            assert_eq!(acc2[i], u);
        }
    }
}

// ---------------------------------------------------------------------------
// Autoencoder through the engine contract
// ---------------------------------------------------------------------------

#[test]
fn ae_encode_decode_roundtrip_shapes() {
    use lgc::compress::autoencoder::{AeCompressor, Pattern};
    let e = engine();
    let mu = e.manifest.model("convnet_mini").mu;
    let ae = AeCompressor::new(&e, mu, 2, Pattern::RingAllreduce, 7).unwrap();
    let mut rng = lgc::util::rng::Rng::new(8);
    let g = rng.normal_vec(mu, 0.01);
    let (latent, scale) = ae.encode(&e, &g).unwrap();
    assert_eq!(latent.len(), mu / 4); // 4 ch x mu/16 (the paper's rate math)
    let rec = ae.decode_rar(&e, &latent, scale).unwrap();
    assert_eq!(rec.len(), mu);
    assert!(rec.iter().all(|x| x.is_finite()));
}

#[test]
fn ae_online_training_reduces_reconstruction_loss() {
    use lgc::compress::autoencoder::{AeCompressor, Pattern};
    let e = engine();
    let mu = e.manifest.model("convnet_mini").mu;
    let mut ae = AeCompressor::new(&e, mu, 2, Pattern::RingAllreduce, 7).unwrap();
    let mut rng = lgc::util::rng::Rng::new(9);
    let base = rng.normal_vec(mu, 0.1);
    let grads: Vec<Vec<f32>> = (0..2)
        .map(|_| base.iter().map(|x| x + 0.02 * rng.normal()).collect())
        .collect();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let (rec, _) = ae.train_step(&e, &grads, None, 0, 1e-2, 1.0, 0.0).unwrap();
        first = first.or(Some(rec));
        last = rec;
    }
    assert!(last < first.unwrap(), "{last} !< {first:?}");
}

#[test]
fn ae_ps_decoder_uses_innovation_channel_and_per_node_weights() {
    use lgc::compress::autoencoder::{AeCompressor, Pattern};
    let e = engine();
    let mu = e.manifest.model("convnet_mini").mu;
    let ae = AeCompressor::new(&e, mu, 2, Pattern::ParamServer, 7).unwrap();
    let mut rng = lgc::util::rng::Rng::new(10);
    let g = rng.normal_vec(mu, 0.01);
    let (latent, scale) = ae.encode(&e, &g).unwrap();
    let zero_innov = vec![0.0f32; mu];
    let big_innov: Vec<f32> = (0..mu).map(|i| if i % 7 == 0 { 1.0 } else { 0.0 }).collect();
    let r0 = ae.decode_ps(&e, 0, &latent, &zero_innov, scale).unwrap();
    let r1 = ae.decode_ps(&e, 0, &latent, &big_innov, scale).unwrap();
    let diff: f32 = r0.iter().zip(&r1).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 0.0);
    let r_node1 = ae.decode_ps(&e, 1, &latent, &zero_innov, scale).unwrap();
    let diff01: f32 = r0.iter().zip(&r_node1).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff01 > 0.0);
}

// ---------------------------------------------------------------------------
// Full training loops
// ---------------------------------------------------------------------------

#[test]
fn every_method_trains_without_error_and_accounts_bytes() {
    let e = engine();
    for m in Method::all() {
        let r = coordinator::train(&e, tiny_cfg("convnet_mini", m, 2)).unwrap();
        assert_eq!(r.curve.len(), 12, "{}", m.name());
        assert!(r.final_eval.0.is_finite());
        assert!(r.ledger.total() > 0, "{} sent nothing", m.name());
        assert!(
            r.curve.iter().all(|p| p.train_loss.is_finite()),
            "{} diverged",
            m.name()
        );
    }
}

/// The ISSUE-4 acceptance bar: one full three-phase run per headline
/// method, from a clean checkout, no skips — and for the LGC strategies
/// the AE train-loss trace decreases over phase 2 and the learned
/// encode/decode actually executes in phase 3.
#[test]
fn three_phase_train_acceptance_all_headline_methods() {
    let e = engine();
    let cfg_of = |method: Method| {
        let mut cfg = tiny_cfg("convnet_mini", method, 2);
        cfg.steps = 24;
        cfg.warmup_iters = 6;
        cfg.ae_train_iters = 8;
        // Force the readiness gate open so phase 3 runs the *learned*
        // path even at this tiny AE budget.
        cfg.ae_gate = f32::INFINITY;
        cfg
    };
    for method in [Method::Baseline, Method::SparseGd, Method::LgcPs, Method::LgcRar] {
        let r = coordinator::train(&e, cfg_of(method)).unwrap();
        assert_eq!(r.phase_iters, [6, 8, 10], "{}", method.name());
        assert!(r.curve.iter().all(|p| p.train_loss.is_finite()), "{}", method.name());
        match method {
            Method::LgcPs | Method::LgcRar => {
                // AE trained online during phase 2 (inner steps per iter).
                assert!(
                    r.ae_losses.len() >= 8,
                    "{}: only {} AE steps",
                    method.name(),
                    r.ae_losses.len()
                );
                // ... and its reconstruction loss decreased over phase 2.
                let rec: Vec<f32> = r.ae_losses.iter().map(|(l, _)| *l).collect();
                let q = (rec.len() / 4).max(1);
                let head: f32 = rec[..q].iter().sum::<f32>() / q as f32;
                let tail: f32 = rec[rec.len() - q..].iter().sum::<f32>() / q as f32;
                assert!(
                    tail < head,
                    "{}: AE loss not decreasing ({head:.4} -> {tail:.4})",
                    method.name()
                );
                // The learned path executed: phase 3 charged latent bytes.
                let latent = r
                    .ledger
                    .per_kind
                    .get(&lgc::metrics::Kind::Latent)
                    .copied()
                    .unwrap_or(0);
                assert!(latent > 0, "{}: no latent traffic in phase 3", method.name());
            }
            _ => assert!(r.ae_losses.is_empty(), "{}", method.name()),
        }
    }
}

#[test]
fn mlp_workload_trains_with_lgc_rar() {
    let e = engine();
    let mut cfg = tiny_cfg("mlp_mini", Method::LgcRar, 4);
    cfg.ae_gate = f32::INFINITY;
    let r = coordinator::train(&e, cfg).unwrap();
    assert!(r.final_eval.0.is_finite());
    assert!(!r.ae_losses.is_empty());
}

#[test]
fn unknown_model_name_falls_back_to_reference_workload() {
    let e = engine();
    // The presets name the PJRT models; the native manifest substitutes.
    let r = coordinator::train(&e, tiny_cfg("resnet_mini", Method::Dgc, 2)).unwrap();
    assert_eq!(r.model, "convnet_mini");
}

#[test]
fn training_is_deterministic_given_seed() {
    let e = engine();
    let run = || coordinator::train(&e, tiny_cfg("convnet_mini", Method::LgcPs, 2)).unwrap();
    let a = run();
    let b = run();
    assert_eq!(a.final_eval, b.final_eval);
    assert_eq!(a.ledger.total(), b.ledger.total());
    assert_eq!(a.ledger.iter_bytes, b.ledger.iter_bytes);
    let la: Vec<f32> = a.curve.iter().map(|p| p.train_loss).collect();
    let lb: Vec<f32> = b.curve.iter().map(|p| p.train_loss).collect();
    assert_eq!(la, lb);
}

/// §6.5 invariance extended past the codec layer: the *full* native run
/// (grad steps, EF, AE training, learned encode/decode, ledger) is
/// bit-identical for any thread count.
#[test]
fn training_is_thread_count_invariant_end_to_end() {
    let e = engine();
    let run_with = |method: Method, threads: usize| {
        let mut cfg = tiny_cfg("convnet_mini", method, 4);
        cfg.threads = threads;
        cfg.ae_gate = f32::INFINITY; // exercise the learned phase-3 path
        coordinator::train(&e, cfg).unwrap()
    };
    for method in [Method::Dgc, Method::LgcPs, Method::LgcRar] {
        let seq = run_with(method, 1);
        for threads in [2, 4] {
            let par = run_with(method, threads);
            assert_eq!(
                seq.ledger.iter_bytes,
                par.ledger.iter_bytes,
                "{} threads={threads}: per-iteration bytes drifted",
                method.name()
            );
            assert_eq!(seq.ledger.total(), par.ledger.total(), "{}", method.name());
            let ls: Vec<f32> = seq.curve.iter().map(|p| p.train_loss).collect();
            let lp: Vec<f32> = par.curve.iter().map(|p| p.train_loss).collect();
            assert_eq!(ls, lp, "{} threads={threads}: loss curve drifted", method.name());
            // DESIGN.md §11: the network trace — and therefore every
            // modeled time the speedup sweep derives from it — is
            // bit-identical too.
            assert_eq!(
                seq.net,
                par.net,
                "{} threads={threads}: network trace drifted",
                method.name()
            );
            assert_eq!(seq.net.iter_comm_s(), par.net.iter_comm_s(), "{}", method.name());
        }
    }
}

/// The simulated fabric end-to-end (ISSUE-5 acceptance): the recorded
/// trace carries the ledger's measured bytes, and at low bandwidth the
/// compressed methods' modeled iteration time beats Baseline's.
#[test]
fn modeled_speedup_from_measured_bytes_favors_lgc_at_low_bandwidth() {
    use lgc::net::LinkModel;
    let e = engine();
    let run = |method: Method| {
        let mut cfg = tiny_cfg("convnet_mini", method, 4);
        cfg.steps = 24;
        cfg.warmup_iters = 6;
        cfg.ae_train_iters = 8;
        cfg.ae_gate = f32::INFINITY;
        coordinator::train(&e, cfg).unwrap()
    };
    let base = run(Method::Baseline);
    // The fabric saw exactly what the ledger measured.
    assert_eq!(base.net.uplink_bytes, base.ledger.total());
    assert_eq!(base.net.trace.len(), base.ledger.iter_bytes.len());
    let slow = LinkModel::from_mbits(50.0, 50e-6);
    let base_comm = base.steady_comm_s_at(slow, 8);
    assert!(base_comm > 0.0);
    for method in [Method::LgcPs, Method::LgcRar] {
        let r = run(method);
        assert_eq!(r.net.uplink_bytes, r.ledger.total(), "{}", method.name());
        let comm = r.steady_comm_s_at(slow, 8);
        assert!(
            comm < base_comm / 2.0,
            "{}: modeled steady comm {comm} not well below baseline {base_comm}",
            method.name()
        );
    }
    // A straggler slows the modeled clock but never changes the bytes.
    let nominal = run(Method::LgcRar);
    let mut cfg = tiny_cfg("convnet_mini", Method::LgcRar, 4);
    cfg.steps = 24;
    cfg.warmup_iters = 6;
    cfg.ae_train_iters = 8;
    cfg.ae_gate = f32::INFINITY;
    cfg.straggler_spec = vec![(0, 3.0)];
    let straggled = coordinator::train(&e, cfg).unwrap();
    assert_eq!(straggled.ledger.iter_bytes, nominal.ledger.iter_bytes);
    assert!(
        straggled.net.iter_comm_s().iter().sum::<f64>()
            > nominal.net.iter_comm_s().iter().sum::<f64>()
    );
}

#[test]
fn lgc_rar_counts_one_time_weight_broadcast() {
    let e = engine();
    let mut cfg = tiny_cfg("convnet_mini", Method::LgcRar, 2);
    cfg.ae_gate = f32::INFINITY;
    let r = coordinator::train(&e, cfg).unwrap();
    let ae_bytes = r
        .ledger
        .per_kind
        .get(&lgc::metrics::Kind::AeWeights)
        .copied()
        .unwrap_or(0);
    assert!(ae_bytes > 0, "RAR must count the one-time AE weight broadcast");
}

#[test]
fn phases_progress_dense_topk_compressed() {
    let cfg = tiny_cfg("convnet_mini", Method::LgcPs, 2);
    assert_eq!(coordinator::scheduler::phase_and_alpha(&cfg, 0).0, Phase::Dense);
    assert_eq!(coordinator::scheduler::phase_and_alpha(&cfg, 5).0, Phase::TopK);
    assert_eq!(coordinator::scheduler::phase_and_alpha(&cfg, 9).0, Phase::Compressed);
    let e = engine();
    let r = coordinator::train(&e, cfg.clone()).unwrap();
    assert_eq!(r.phase_iters, [4, 4, 4]);
    assert!(r.ae_losses.len() >= 4 * cfg.ae_inner_steps);
}

// ---------------------------------------------------------------------------
// Bucketed pipeline (DESIGN.md §13) end-to-end in the simulator
// ---------------------------------------------------------------------------

const BUCKETABLE: [Method; 4] =
    [Method::Baseline, Method::SparseGd, Method::Dgc, Method::Threshold];

/// The tentpole's reference bar: `--buckets N --no-overlap` is bit-exact
/// legacy — loss curve, final eval, ledger, and network trace — for every
/// bucketable strategy and any bucket count, including the size-targeted
/// `--bucket-bytes` policy.
#[test]
fn bucketed_no_overlap_is_bit_identical_to_legacy() {
    let e = engine();
    for method in BUCKETABLE {
        let legacy = coordinator::train(&e, tiny_cfg("convnet_mini", method, 2)).unwrap();
        let mut variants: Vec<TrainConfig> = [2usize, 7, 32]
            .iter()
            .map(|&b| {
                let mut cfg = tiny_cfg("convnet_mini", method, 2);
                cfg.buckets = b;
                cfg.overlap = false;
                cfg
            })
            .collect();
        let mut by_bytes = tiny_cfg("convnet_mini", method, 2);
        by_bytes.bucket_bytes = 4096;
        by_bytes.overlap = false;
        variants.push(by_bytes);
        for cfg in variants {
            let tag =
                format!("{} buckets={} bytes={}", method.name(), cfg.buckets, cfg.bucket_bytes);
            let r = coordinator::train(&e, cfg).unwrap();
            let la: Vec<f32> = legacy.curve.iter().map(|p| p.train_loss).collect();
            let lb: Vec<f32> = r.curve.iter().map(|p| p.train_loss).collect();
            assert_eq!(la, lb, "{tag}: loss curve drifted");
            assert_eq!(legacy.final_eval, r.final_eval, "{tag}");
            assert_eq!(legacy.ledger.iter_bytes, r.ledger.iter_bytes, "{tag}: bytes drifted");
            assert_eq!(legacy.ledger.total(), r.ledger.total(), "{tag}");
            assert_eq!(legacy.ledger.per_kind, r.ledger.per_kind, "{tag}");
            assert_eq!(legacy.ledger.per_node, r.ledger.per_node, "{tag}");
            assert_eq!(legacy.net, r.net, "{tag}: network trace drifted");
        }
    }
}

/// Overlapped mode re-frames the mid exchange per bucket: Indices byte
/// totals may differ (one coded header per bucket), but selection,
/// values, EF state, and the aggregated means are untouched — so the
/// training curve and final eval must match legacy exactly, and pricing
/// the bucket-tagged trace under the pipelined schedule must come in
/// strictly below the barrier at low bandwidth.
#[test]
fn overlapped_buckets_keep_curves_and_beat_the_barrier() {
    use lgc::coordinator::bucket::BucketPlan;
    use lgc::net::LinkModel;
    let e = engine();
    for method in [Method::Baseline, Method::SparseGd] {
        let legacy = coordinator::train(&e, tiny_cfg("convnet_mini", method, 2)).unwrap();
        let mut cfg = tiny_cfg("convnet_mini", method, 2);
        cfg.buckets = 8;
        assert!(cfg.overlap, "overlap is the default");
        let r = coordinator::train(&e, cfg.clone()).unwrap();
        let la: Vec<f32> = legacy.curve.iter().map(|p| p.train_loss).collect();
        let lb: Vec<f32> = r.curve.iter().map(|p| p.train_loss).collect();
        assert_eq!(la, lb, "{}: overlap changed the training curve", method.name());
        assert_eq!(legacy.final_eval, r.final_eval, "{}", method.name());
        // Value payloads are framing-independent; only index headers move.
        assert_eq!(
            legacy.ledger.per_kind.get(&lgc::metrics::Kind::Values),
            r.ledger.per_kind.get(&lgc::metrics::Kind::Values),
            "{}",
            method.name()
        );

        let meta = e.manifest.model(&r.model).clone();
        let model = Model::new(&meta, cfg.seed);
        let layers: Vec<std::ops::Range<usize>> =
            model.layer_slices(Group::Mid).into_iter().map(|(_, l)| l).collect();
        let plan = BucketPlan::for_group(meta.n_mid, &layers, &cfg);
        assert!(plan.len() >= 2, "convnet_mini mid must split into buckets");
        let compute_s = 0.02f64;
        let per_bucket: Vec<f64> = plan
            .ranges()
            .iter()
            .map(|l| compute_s * (l.end - l.start) as f64 / meta.n_mid as f64)
            .collect();
        let fabric = r.net.fabric.with_link(LinkModel::from_mbits(50.0, 50e-6));
        let seq = r.net.iter_comm_s_under(&fabric);
        let piped = r.net.pipelined_iter_s_under(&fabric, &per_bucket);
        assert_eq!(seq.len(), piped.len());
        // No schedule beats the compute-bound or comm-bound floors...
        for (c, p) in seq.iter().zip(&piped) {
            assert!(*p >= compute_s - 1e-12, "{}: beat compute floor", method.name());
            assert!(*p >= *c - 1e-12, "{}: beat comm floor", method.name());
        }
        // ...but overlap strictly beats the barrier on the steady tail.
        let w = 4.min(seq.len());
        let barrier: f64 = seq[seq.len() - w..].iter().map(|c| compute_s + c).sum();
        let overlapped: f64 = piped[piped.len() - w..].iter().sum();
        assert!(
            overlapped < barrier,
            "{}: pipelined {overlapped} !< barrier {barrier}",
            method.name()
        );
    }
}

/// The overlapped schedule keeps the §6.5 determinism contract: curves,
/// ledgers, and the bucket-tagged network trace (hence the overlap CSV
/// derived from it) are bit-identical for any worker-thread count.
#[test]
fn overlapped_buckets_are_thread_count_invariant() {
    let e = engine();
    let run_with = |threads: usize| {
        let mut cfg = tiny_cfg("convnet_mini", Method::SparseGd, 4);
        cfg.buckets = 8;
        cfg.threads = threads;
        coordinator::train(&e, cfg).unwrap()
    };
    let seq = run_with(1);
    for threads in [2, 4] {
        let par = run_with(threads);
        assert_eq!(seq.ledger.iter_bytes, par.ledger.iter_bytes, "threads={threads}");
        let ls: Vec<f32> = seq.curve.iter().map(|p| p.train_loss).collect();
        let lp: Vec<f32> = par.curve.iter().map(|p| p.train_loss).collect();
        assert_eq!(ls, lp, "threads={threads}");
        assert_eq!(seq.net, par.net, "threads={threads}: bucket-tagged trace drifted");
    }
}

// ---------------------------------------------------------------------------
// Checkpointing through a native training run
// ---------------------------------------------------------------------------

/// Dense single-node SGD steps driven through the native engine;
/// momentum on so the optimizer state (velocity) matters.
fn dense_steps(e: &Engine, model: &mut Model, from: usize, to: usize) {
    let meta = model.meta.clone();
    let data = lgc::data::for_model(&meta, 5);
    for it in from..to {
        let batch = data.batch(0, it);
        let (_, _, grads) = model.grad_step(e, &batch).unwrap();
        let updates = [
            (Group::First, model.flatten_group(&grads, Group::First)),
            (Group::Mid, model.flatten_group(&grads, Group::Mid)),
            (Group::Last, model.flatten_group(&grads, Group::Last)),
        ];
        model.apply_update(&updates, 0.05);
    }
}

#[test]
fn checkpoint_resume_is_bit_identical_to_uninterrupted_run() {
    let e = engine();
    let meta = e.manifest.model("convnet_mini").clone();
    let path = std::env::temp_dir().join(format!("lgc_native_ckpt_{}", std::process::id()));

    // Uninterrupted: 6 steps straight through.
    let mut straight = Model::new(&meta, 9);
    straight.momentum = 0.9;
    dense_steps(&e, &mut straight, 0, 6);

    // Interrupted: 3 steps, checkpoint, fresh model resumes 3..6.
    let mut first_half = Model::new(&meta, 9);
    first_half.momentum = 0.9;
    dense_steps(&e, &mut first_half, 0, 3);
    first_half.save_checkpoint(&path).unwrap();
    let mut resumed = Model::new(&meta, 1234); // different init, fully overwritten
    resumed.momentum = 0.9;
    resumed.load_checkpoint(&path).unwrap();
    dense_steps(&e, &mut resumed, 3, 6);

    for (a, b) in straight.params.iter().zip(&resumed.params) {
        assert_eq!(a, b, "resumed run drifted from uninterrupted run");
    }
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Fault tolerance (DESIGN.md §14): crash-safe resume + survivor continuation
// ---------------------------------------------------------------------------

/// Every deterministic output of two runs must match to the bit (the
/// resume acceptance bar; wall-clock fields are exempt by design).
fn assert_runs_bit_identical(a: &coordinator::TrainResult, b: &coordinator::TrainResult) {
    assert_eq!(a.curve.len(), b.curve.len(), "curve lengths");
    for (p, q) in a.curve.iter().zip(&b.curve) {
        assert_eq!(p.iter, q.iter);
        assert_eq!(p.train_loss.to_bits(), q.train_loss.to_bits(), "loss at iter {}", p.iter);
        assert_eq!(p.train_acc.to_bits(), q.train_acc.to_bits(), "acc at iter {}", p.iter);
    }
    assert_eq!(a.evals.len(), b.evals.len(), "eval counts");
    for ((i1, l1, a1), (i2, l2, a2)) in a.evals.iter().zip(&b.evals) {
        assert_eq!(i1, i2);
        assert_eq!(l1.to_bits(), l2.to_bits(), "eval loss at iter {i1}");
        assert_eq!(a1.to_bits(), a2.to_bits(), "eval acc at iter {i1}");
    }
    assert_eq!(a.final_eval.0.to_bits(), b.final_eval.0.to_bits(), "final eval loss");
    assert_eq!(a.final_eval.1.to_bits(), b.final_eval.1.to_bits(), "final eval acc");
    assert_eq!(a.phase_iters, b.phase_iters, "phase iteration counts");
    assert_eq!(a.ledger, b.ledger, "byte ledgers");
    assert_eq!(a.net, b.net, "net fabric reports");
    assert_eq!(a.ae_losses.len(), b.ae_losses.len(), "AE loss trace lengths");
    for (i, ((r1, s1), (r2, s2))) in a.ae_losses.iter().zip(&b.ae_losses).enumerate() {
        assert_eq!(r1.to_bits(), r2.to_bits(), "AE rec loss {i}");
        assert_eq!(s1.to_bits(), s2.to_bits(), "AE sim loss {i}");
    }
}

/// The §14 resume acceptance bar, per strategy: run A straight through;
/// run B with `--ckpt-every` snapshots and an injected crash exactly at
/// the phase-2/phase-3 boundary; run C resumes B's snapshot and must be
/// bit-identical to A — curve, evals, ledger, net trace, AE trace, and
/// the final model checkpoint bytes on disk.
#[test]
fn crash_resume_is_bit_identical_for_every_strategy() {
    let e = engine();
    for method in [Method::Baseline, Method::SparseGd, Method::LgcPs, Method::LgcRar] {
        let base = || {
            let mut cfg = tiny_cfg("convnet_mini", method, 2);
            cfg.steps = 24;
            cfg.warmup_iters = 6;
            cfg.ae_train_iters = 8;
            cfg.ae_gate = f32::INFINITY;
            cfg.eval_every = 6;
            cfg
        };
        let tmp = std::env::temp_dir();
        let pid = std::process::id();
        let path_a = tmp.join(format!("lgc_resume_a_{pid}_{}", method.name()));
        let path_b = tmp.join(format!("lgc_resume_b_{pid}_{}", method.name()));

        // A: uninterrupted reference, final model checkpoint to path_a.
        let mut cfg_a = base();
        cfg_a.checkpoint = Some(path_a.to_string_lossy().into_owned());
        let a = coordinator::train(&e, cfg_a).unwrap();

        // B: snapshots every 7 iterations (so the last one lands at the
        // it=13 boundary), then a planned crash at iteration 14 — the
        // first compressed-phase iteration, where EF memories, the
        // latched AE gate, and the trained encoder all matter.
        let mut cfg_b = base();
        cfg_b.checkpoint = Some(path_b.to_string_lossy().into_owned());
        cfg_b.ckpt_every = 7;
        cfg_b.faults = Some("iter=14:crash".into());
        let err = coordinator::train(&e, cfg_b).unwrap_err();
        assert!(
            format!("{err:#}").contains("injected crash at iteration 14"),
            "{}: {err:#}",
            method.name()
        );
        assert!(path_b.exists(), "{}: crash must leave the snapshot intact", method.name());

        // C: resume B's snapshot; the crash directive is dropped.
        let mut cfg_c = base();
        cfg_c.checkpoint = Some(path_b.to_string_lossy().into_owned());
        cfg_c.ckpt_every = 7;
        cfg_c.resume = Some(path_b.to_string_lossy().into_owned());
        let c = coordinator::train(&e, cfg_c).unwrap();

        assert_runs_bit_identical(&a, &c);
        assert!(c.fault_events.is_empty(), "{}", method.name());
        // On completion the final model checkpoint overwrites the
        // training-state snapshot — and matches A's byte for byte.
        assert_eq!(
            std::fs::read(&path_a).unwrap(),
            std::fs::read(&path_b).unwrap(),
            "{}: final checkpoints diverged",
            method.name()
        );
        std::fs::remove_file(&path_a).ok();
        std::fs::remove_file(&path_b).ok();
    }
}

/// A resumed run refuses a snapshot written under a materially different
/// configuration (method swapped), naming both fingerprints.
#[test]
fn resume_rejects_checkpoint_from_different_config() {
    let e = engine();
    let tmp = std::env::temp_dir().join(format!("lgc_resume_fp_{}", std::process::id()));
    let mut cfg = tiny_cfg("convnet_mini", Method::SparseGd, 2);
    cfg.checkpoint = Some(tmp.to_string_lossy().into_owned());
    cfg.ckpt_every = 4;
    cfg.faults = Some("iter=8:crash".into());
    coordinator::train(&e, cfg).unwrap_err();
    let mut other = tiny_cfg("convnet_mini", Method::Baseline, 2);
    other.checkpoint = Some(tmp.to_string_lossy().into_owned());
    other.resume = Some(tmp.to_string_lossy().into_owned());
    let err = coordinator::train(&e, other).unwrap_err();
    assert!(
        format!("{err:#}").contains("different configuration"),
        "{err:#}"
    );
    std::fs::remove_file(&tmp).ok();
}

/// The ISSUE-8 sim chaos bar: K=8 nodes under `--on-fault continue`
/// survive a kill/stall/corrupt-frame plan and the run still clears the
/// `--assert-improves` bar (final train loss below the first).
#[test]
fn chaos_plan_with_eight_nodes_continues_and_improves() {
    let e = engine();
    let mut cfg = tiny_cfg("mlp_mini", Method::SparseGd, 8);
    cfg.steps = 24;
    cfg.on_fault = lgc::config::OnFault::Continue;
    cfg.faults =
        Some("iter=4:kill=5;iter=9:stall=2:100ms;iter=15:corrupt-frame=7;iter=18:kill=1".into());
    let r = coordinator::train(&e, cfg).unwrap();
    let kinds: Vec<&str> = r.fault_events.iter().map(|ev| ev.kind.as_str()).collect();
    assert_eq!(kinds, ["kill", "stall", "corrupt-frame", "kill"]);
    assert!(r.fault_events[0].detail.contains("7 survivors"), "{}", r.fault_events[0].detail);
    assert!(r.fault_events[3].detail.contains("6 survivors"), "{}", r.fault_events[3].detail);
    assert_eq!(r.curve.len(), 24);
    assert!(r.curve.iter().all(|p| p.train_loss.is_finite()), "survivor math diverged");
    // The --assert-improves bar from the CLI, applied directly.
    assert!(
        r.final_train_loss() < r.curve[0].train_loss,
        "chaos run did not improve: {} !< {}",
        r.final_train_loss(),
        r.curve[0].train_loss
    );
}

#[test]
fn checkpoint_rejects_crc_corruption() {
    let e = engine();
    let meta = e.manifest.model("mlp_mini").clone();
    let path = std::env::temp_dir().join(format!("lgc_native_ckpt_bad_{}", std::process::id()));
    let mut model = Model::new(&meta, 9);
    model.momentum = 0.9;
    dense_steps(&e, &mut model, 0, 2);
    model.save_checkpoint(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&path, &bytes).unwrap();
    let mut fresh = Model::new(&meta, 1);
    let err = fresh.load_checkpoint(&path);
    assert!(err.is_err(), "corrupted checkpoint must be rejected");
    assert!(format!("{:#}", err.unwrap_err()).contains("CRC"));
    std::fs::remove_file(&path).ok();
}
