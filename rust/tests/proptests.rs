//! Property-based tests on coordinator invariants.
//!
//! The offline crate set has no proptest, so this uses the in-tree
//! deterministic RNG for randomized case generation with fixed seeds
//! (shrinking is traded for reproducibility: every failure prints the
//! case seed, and re-running with it is exact).

use lgc::compress::{index_coding, topk, Correction, FeedbackMemory};
use lgc::coordinator::ring;
use lgc::info;
use lgc::metrics::{Kind, Ledger};
use lgc::util::rng::Rng;

const CASES: u64 = 200;

/// Random sorted unique index set over [0, n).
fn random_indices(rng: &mut Rng, n: usize, k: usize) -> Vec<u32> {
    let mut set = std::collections::BTreeSet::new();
    while set.len() < k.min(n) {
        set.insert(rng.below(n) as u32);
    }
    set.into_iter().collect()
}

#[test]
fn prop_index_coding_roundtrips() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x1D0 + case);
        let n = 16 + rng.below(1_000_000);
        let k = 1 + rng.below((n / 10).max(1));
        let idx = random_indices(&mut rng, n, k);
        let bytes = index_coding::encode(&idx, n).unwrap_or_else(|e| {
            panic!("case {case}: encode failed: {e}");
        });
        let back = index_coding::decode(&bytes, n).unwrap();
        assert_eq!(back, idx, "case {case} n={n} k={k}");
    }
}

#[test]
fn prop_index_coding_beats_raw_u32_when_sparse() {
    for case in 0..50 {
        let mut rng = Rng::new(0x1D1 + case);
        let n = 100_000 + rng.below(900_000);
        let k = n / 1000; // 0.1% sparsity, the paper's operating point
        let idx = random_indices(&mut rng, n, k);
        let bytes = index_coding::encode(&idx, n).unwrap();
        assert!(
            bytes.len() < idx.len() * 4,
            "case {case}: coded {} >= raw {}",
            bytes.len(),
            idx.len() * 4
        );
    }
}

#[test]
fn prop_topk_is_exact_partial_sort() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x701 + case);
        let n = 2 + rng.below(5000);
        let k = 1 + rng.below(n);
        let g = rng.normal_vec(n, 1.0);
        let sel = topk::top_k(&g, k);
        assert_eq!(sel.indices.len(), k, "case {case}");
        // Every selected magnitude >= every unselected magnitude.
        let selected: std::collections::BTreeSet<u32> =
            sel.indices.iter().copied().collect();
        let min_sel = sel
            .values
            .iter()
            .map(|v| v.abs())
            .fold(f32::INFINITY, f32::min);
        for (i, v) in g.iter().enumerate() {
            if !selected.contains(&(i as u32)) {
                assert!(
                    v.abs() <= min_sel + 1e-7,
                    "case {case}: unselected |{v}| > selected min {min_sel}"
                );
            }
        }
    }
}

#[test]
fn prop_error_feedback_conserves_gradient_mass() {
    // transmitted + residual == sum of accumulated gradients (plain EF),
    // across multiple rounds.
    for case in 0..60 {
        let mut rng = Rng::new(0xEF + case);
        let n = 16 + rng.below(2000);
        let mut fb = FeedbackMemory::new(n, Correction::Plain, 0.0);
        let mut injected = vec![0.0f64; n];
        let mut transmitted = vec![0.0f64; n];
        for _ in 0..5 {
            let g = rng.normal_vec(n, 1.0);
            for (a, b) in injected.iter_mut().zip(&g) {
                *a += *b as f64;
            }
            fb.accumulate(&g);
            let k = 1 + rng.below(n / 4 + 1);
            let sel = fb.select_and_clear(k);
            for (&i, &v) in sel.indices.iter().zip(&sel.values) {
                transmitted[i as usize] += v as f64;
            }
        }
        for i in 0..n {
            let resid = fb.memory()[i] as f64;
            assert!(
                (transmitted[i] + resid - injected[i]).abs() < 1e-3,
                "case {case} coord {i}"
            );
        }
    }
}

#[test]
fn prop_ring_allreduce_equals_direct_sum() {
    for case in 0..60 {
        let mut rng = Rng::new(0x516 + case);
        let k = 2 + rng.below(9);
        let n = k + rng.below(4000);
        let vecs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(n, 1.0)).collect();
        let mut work = vecs.clone();
        let mut ledger = Ledger::new();
        let got = ring::ring_allreduce_sum(&mut work, &mut ledger, Kind::Dense);
        for j in 0..n {
            let want: f32 = vecs.iter().map(|v| v[j]).sum();
            assert!(
                (got[j] - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "case {case} k={k} n={n} j={j}"
            );
        }
        // Byte cost: 2(K-1)/K * size per node, within chunk-rounding slop.
        let per_node = *ledger.per_node.get(&0).unwrap() as f64;
        let ideal = 2.0 * (k as f64 - 1.0) / k as f64 * (n * 4) as f64;
        assert!(
            (per_node - ideal).abs() <= 8.0 * (k as f64 - 1.0) * 2.0,
            "case {case}: per_node={per_node} ideal={ideal}"
        );
    }
}

#[test]
fn prop_scatter_gather_inverse() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x5CA + case);
        let n = 8 + rng.below(3000);
        let k = 1 + rng.below(n);
        let idx = random_indices(&mut rng, n, k);
        let vals: Vec<f32> = (0..idx.len()).map(|_| rng.normal()).collect();
        let dense = topk::scatter(n, &idx, &vals);
        assert_eq!(topk::gather(&dense, &idx), vals, "case {case}");
    }
}

#[test]
fn prop_mi_bounds() {
    // 0 <= MI <= min(H(a), H(b)) for arbitrary correlated inputs.
    for case in 0..40 {
        let mut rng = Rng::new(0x311 + case);
        let n = 5000 + rng.below(20_000);
        let rho = rng.uniform();
        let a = rng.normal_vec(n, 1.0);
        let b: Vec<f32> = a
            .iter()
            .map(|x| rho * x + (1.0 - rho) * rng.normal())
            .collect();
        let ip = info::info_plane(&a, &b, 32);
        assert!(ip.mi >= 0.0, "case {case}");
        assert!(
            ip.mi <= ip.h_a.min(ip.h_b) + 1e-9,
            "case {case}: mi={} ha={} hb={}",
            ip.mi,
            ip.h_a,
            ip.h_b
        );
    }
}

#[test]
fn prop_quantizer_error_bounded_by_bucket_norm() {
    use lgc::compress::quantize;
    for case in 0..60 {
        let mut rng = Rng::new(0x4A + case);
        let n = 64 + rng.below(4000);
        let levels = 1 + rng.below(255) as u32;
        let bucket = 16 + rng.below(512);
        let g = rng.normal_vec(n, 1.0);
        let p = quantize::qsgd(&g, levels, bucket, &mut rng);
        for (chunk_i, chunk) in g.chunks(bucket).enumerate() {
            let norm = chunk.iter().map(|x| x * x).sum::<f32>().sqrt();
            for (j, &x) in chunk.iter().enumerate() {
                let q = p.dequant[chunk_i * bucket + j];
                assert!(
                    (q - x).abs() <= norm / levels as f32 + 1e-5,
                    "case {case}: |{q} - {x}| > {}",
                    norm / levels as f32
                );
            }
        }
    }
}
