//! Property-based tests on coordinator invariants.
//!
//! The offline crate set has no proptest, so this uses the in-tree
//! deterministic RNG for randomized case generation with fixed seeds
//! (shrinking is traded for reproducibility: every failure prints the
//! case seed, and re-running with it is exact).

use lgc::compress::index_coding::IndexCodec;
use lgc::compress::{f16, index_coding, topk, Correction, FeedbackMemory};
use lgc::coordinator::{parallel, ring};
use lgc::info;
use lgc::metrics::{Kind, Ledger, NodeLedger};
use lgc::util::rng::Rng;

const CASES: u64 = 200;

/// Random sorted unique index set over [0, n).
fn random_indices(rng: &mut Rng, n: usize, k: usize) -> Vec<u32> {
    let mut set = std::collections::BTreeSet::new();
    while set.len() < k.min(n) {
        set.insert(rng.below(n) as u32);
    }
    set.into_iter().collect()
}

#[test]
fn prop_index_coding_roundtrips() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x1D0 + case);
        let n = 16 + rng.below(1_000_000);
        let k = 1 + rng.below((n / 10).max(1));
        let idx = random_indices(&mut rng, n, k);
        let bytes = index_coding::encode(&idx, n).unwrap_or_else(|e| {
            panic!("case {case}: encode failed: {e}");
        });
        let back = index_coding::decode(&bytes, n).unwrap();
        assert_eq!(back, idx, "case {case} n={n} k={k}");
    }
}

#[test]
fn prop_index_coding_beats_raw_u32_when_sparse() {
    for case in 0..50 {
        let mut rng = Rng::new(0x1D1 + case);
        let n = 100_000 + rng.below(900_000);
        let k = n / 1000; // 0.1% sparsity, the paper's operating point
        let idx = random_indices(&mut rng, n, k);
        let bytes = index_coding::encode(&idx, n).unwrap();
        assert!(
            bytes.len() < idx.len() * 4,
            "case {case}: coded {} >= raw {}",
            bytes.len(),
            idx.len() * 4
        );
    }
}

#[test]
fn prop_index_coding_universe_boundaries() {
    // Extremes of the index universe: empty selections, singleton at
    // u32::MAX (largest encodable index; varint path must emit the full
    // 5-byte LEB128), and mixed sets touching both ends.
    let huge = u32::MAX as usize + 1;
    for n in [1usize, 100, 1_000_000, huge] {
        let bytes = index_coding::encode(&[], n).unwrap();
        assert_eq!(index_coding::decode(&bytes, n).unwrap(), Vec::<u32>::new(), "n={n}");
    }
    let idx = vec![u32::MAX];
    let bytes = index_coding::encode(&idx, huge).unwrap();
    assert_eq!(index_coding::decode(&bytes, huge).unwrap(), idx);

    let idx = vec![0u32, 1, 12_345, u32::MAX - 1, u32::MAX];
    let bytes = index_coding::encode(&idx, huge).unwrap();
    assert_eq!(index_coding::decode(&bytes, huge).unwrap(), idx);

    // u32::MAX is out of universe for n == u32::MAX (valid: 0..n-1).
    assert!(index_coding::encode(&[u32::MAX], u32::MAX as usize).is_err());

    // Order-significant coding at the same extremes.
    let idx = vec![u32::MAX, 0u32, u32::MAX - 1];
    let bytes = index_coding::encode_ordered(&idx).unwrap();
    assert_eq!(index_coding::decode_ordered(&bytes).unwrap(), idx);
    let bytes = index_coding::encode_ordered(&[]).unwrap();
    assert_eq!(index_coding::decode_ordered(&bytes).unwrap(), Vec::<u32>::new());
}

// ---------------------------------------------------------------------------
// f16 round trips vs a bit-exact reference
// ---------------------------------------------------------------------------

/// Exact value of an f16 bit pattern, computed independently of the
/// implementation under test (f64 holds every f16 value exactly).
fn ref_f16_value(h: u16) -> f64 {
    let sign = if h & 0x8000 != 0 { -1.0 } else { 1.0 };
    let exp = ((h >> 10) & 0x1F) as i32;
    let frac = (h & 0x3FF) as f64;
    match exp {
        0 => sign * frac * 2f64.powi(-24),
        0x1F => {
            if frac == 0.0 {
                sign * f64::INFINITY
            } else {
                f64::NAN
            }
        }
        e => sign * (1.0 + frac / 1024.0) * 2f64.powi(e - 15),
    }
}

/// Bit-exact round-to-nearest-even f32 -> f16 reference: for positive
/// values the f16 grid is monotone in the bit pattern, so binary-search
/// the bracketing patterns and resolve ties to the even pattern.  Returns
/// `None` for NaN inputs (any NaN payload is acceptable).
fn ref_f32_to_f16(x: f32) -> Option<u16> {
    if x.is_nan() {
        return None;
    }
    let sign = if x.is_sign_negative() { 0x8000u16 } else { 0 };
    let ax = x.abs() as f64;
    if ax == 0.0 {
        return Some(sign);
    }
    let max_finite = ref_f16_value(0x7BFF); // 65504
    if ax >= max_finite {
        // RNE at the overflow boundary: the grid step above 65504 is 32,
        // so values < 65520 round down; >= 65520 round to infinity (the
        // tie goes to 0x7C00, the "even" pattern after 0x7BFF).
        return Some(if ax < max_finite + 16.0 { sign | 0x7BFF } else { sign | 0x7C00 });
    }
    let (mut lo, mut hi) = (0u16, 0x7BFEu16);
    while lo < hi {
        let mid = (lo + hi).div_ceil(2);
        if ref_f16_value(mid) <= ax {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    let d_lo = ax - ref_f16_value(lo);
    let d_hi = ref_f16_value(lo + 1) - ax;
    let pick = if d_lo < d_hi {
        lo
    } else if d_hi < d_lo {
        lo + 1
    } else if lo % 2 == 0 {
        lo
    } else {
        lo + 1
    };
    Some(sign | pick)
}

#[test]
fn prop_f16_decode_matches_reference_for_all_patterns() {
    // Exhaustive: every one of the 65536 f16 bit patterns.
    for h in 0..=u16::MAX {
        let got = f16::f16_bits_to_f32(h);
        let want = ref_f16_value(h);
        if want.is_nan() {
            assert!(got.is_nan(), "bits={h:#06x}: {got} should be NaN");
        } else {
            assert_eq!(got as f64, want, "bits={h:#06x}");
        }
    }
}

#[test]
fn prop_f16_encode_matches_reference() {
    // Deterministic boundary sweep: every f16 grid value, its exact
    // midpoints with both neighbours (ties-to-even), and nudges across
    // the subnormal/normal and overflow boundaries.
    let mut cases: Vec<f32> = vec![
        0.0,
        -0.0,
        f32::INFINITY,
        f32::NEG_INFINITY,
        65504.0,   // max finite f16
        65519.9,   // below the overflow tie
        65520.0,   // the overflow tie itself -> inf
        65520.1,
        1e9,
        2f32.powi(-24),        // smallest subnormal
        2f32.powi(-25),        // tie between 0 and the smallest subnormal
        2f32.powi(-14),        // smallest normal
        2f32.powi(-14) * 0.999,
        1e-10,
        f32::MIN_POSITIVE,     // deep underflow
    ];
    for h in (0u16..0x7C00).step_by(7) {
        let v = ref_f16_value(h);
        let v_next = ref_f16_value(h + 1);
        cases.push(v as f32);
        cases.push(((v + v_next) / 2.0) as f32); // exact tie
        cases.push((v + (v_next - v) * 0.25) as f32);
        cases.push((v + (v_next - v) * 0.75) as f32);
    }
    let mut rng = Rng::new(0xF16);
    for _ in 0..20_000 {
        let scale = (rng.uniform() * 40.0 - 25.0).exp2();
        cases.push(rng.normal() * scale);
    }
    for (i, &x) in cases.iter().enumerate() {
        let got = f16::f32_to_f16_bits(x);
        let want = ref_f32_to_f16(x).expect("no NaNs in this sweep");
        assert_eq!(
            got, want,
            "case {i}: x={x:e} got={got:#06x} want={want:#06x}"
        );
        cases_negative(x, i);
    }
    // NaN maps to some NaN.
    assert!(f16::f16_bits_to_f32(f16::f32_to_f16_bits(f32::NAN)).is_nan());

    fn cases_negative(x: f32, i: usize) {
        let got = f16::f32_to_f16_bits(-x);
        let want = ref_f32_to_f16(-x).unwrap();
        assert_eq!(got, want, "case {i} (negated): x={:e}", -x);
    }
}

#[test]
fn prop_f16_quantize_roundtrip_is_idempotent() {
    // Dequantized values are exactly representable, so a second pass
    // through the wire format must be the identity.
    let mut rng = Rng::new(0x1D3);
    let vals: Vec<f32> = (0..5000).map(|_| rng.normal() * 8.0).collect();
    let (once, bytes) = f16::quantize_f16(&vals);
    assert_eq!(bytes, vals.len() * 2);
    let (twice, _) = f16::quantize_f16(&once);
    assert_eq!(once, twice);
}

#[test]
fn prop_topk_is_exact_partial_sort() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x701 + case);
        let n = 2 + rng.below(5000);
        let k = 1 + rng.below(n);
        let g = rng.normal_vec(n, 1.0);
        let sel = topk::top_k(&g, k);
        assert_eq!(sel.indices.len(), k, "case {case}");
        // Every selected magnitude >= every unselected magnitude.
        let selected: std::collections::BTreeSet<u32> =
            sel.indices.iter().copied().collect();
        let min_sel = sel
            .values
            .iter()
            .map(|v| v.abs())
            .fold(f32::INFINITY, f32::min);
        for (i, v) in g.iter().enumerate() {
            if !selected.contains(&(i as u32)) {
                assert!(
                    v.abs() <= min_sel + 1e-7,
                    "case {case}: unselected |{v}| > selected min {min_sel}"
                );
            }
        }
    }
}

#[test]
fn prop_error_feedback_conserves_gradient_mass() {
    // transmitted + residual == sum of accumulated gradients (plain EF),
    // across multiple rounds.
    for case in 0..60 {
        let mut rng = Rng::new(0xEF + case);
        let n = 16 + rng.below(2000);
        let mut fb = FeedbackMemory::new(n, Correction::Plain, 0.0);
        let mut injected = vec![0.0f64; n];
        let mut transmitted = vec![0.0f64; n];
        for _ in 0..5 {
            let g = rng.normal_vec(n, 1.0);
            for (a, b) in injected.iter_mut().zip(&g) {
                *a += *b as f64;
            }
            fb.accumulate(&g);
            let k = 1 + rng.below(n / 4 + 1);
            let sel = fb.select_and_clear(k);
            for (&i, &v) in sel.indices.iter().zip(&sel.values) {
                transmitted[i as usize] += v as f64;
            }
        }
        for i in 0..n {
            let resid = fb.memory()[i] as f64;
            assert!(
                (transmitted[i] + resid - injected[i]).abs() < 1e-3,
                "case {case} coord {i}"
            );
        }
    }
}

#[test]
fn prop_ring_allreduce_equals_direct_sum() {
    for case in 0..60 {
        let mut rng = Rng::new(0x516 + case);
        let k = 2 + rng.below(9);
        let n = k + rng.below(4000);
        let vecs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(n, 1.0)).collect();
        let mut work = vecs.clone();
        let mut ledger = Ledger::new();
        let got = ring::ring_allreduce_sum(&mut work, &mut ledger, Kind::Dense);
        for j in 0..n {
            let want: f32 = vecs.iter().map(|v| v[j]).sum();
            assert!(
                (got[j] - want).abs() <= 1e-4 * (1.0 + want.abs()),
                "case {case} k={k} n={n} j={j}"
            );
        }
        // Byte cost: 2(K-1)/K * size per node, within chunk-rounding slop.
        let per_node = *ledger.per_node.get(&0).unwrap() as f64;
        let ideal = 2.0 * (k as f64 - 1.0) / k as f64 * (n * 4) as f64;
        assert!(
            (per_node - ideal).abs() <= 8.0 * (k as f64 - 1.0) * 2.0,
            "case {case}: per_node={per_node} ideal={ideal}"
        );
    }
}

#[test]
fn prop_scatter_gather_inverse() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x5CA + case);
        let n = 8 + rng.below(3000);
        let k = 1 + rng.below(n);
        let idx = random_indices(&mut rng, n, k);
        let vals: Vec<f32> = (0..idx.len()).map(|_| rng.normal()).collect();
        let dense = topk::scatter(n, &idx, &vals);
        assert_eq!(topk::gather(&dense, &idx), vals, "case {case}");
    }
}

#[test]
fn prop_mi_bounds() {
    // 0 <= MI <= min(H(a), H(b)) for arbitrary correlated inputs.
    for case in 0..40 {
        let mut rng = Rng::new(0x311 + case);
        let n = 5000 + rng.below(20_000);
        let rho = rng.uniform();
        let a = rng.normal_vec(n, 1.0);
        let b: Vec<f32> = a
            .iter()
            .map(|x| rho * x + (1.0 - rho) * rng.normal())
            .collect();
        let ip = info::info_plane(&a, &b, 32);
        assert!(ip.mi >= 0.0, "case {case}");
        assert!(
            ip.mi <= ip.h_a.min(ip.h_b) + 1e-9,
            "case {case}: mi={} ha={} hb={}",
            ip.mi,
            ip.h_a,
            ip.h_b
        );
    }
}

#[test]
fn prop_sharded_ledger_thread_invariance() {
    // The tentpole determinism contract, over randomized configurations:
    // running the per-node pipeline (EF accumulate -> top-k select ->
    // encode -> shard-record) under any worker-thread count produces a
    // bit-identical merged ledger and bit-identical aggregated means.
    for case in 0..12u64 {
        let mut cfg_rng = Rng::new(0x5AAD + case);
        let nodes = 2 + cfg_rng.below(9);
        let n = 64 + cfg_rng.below(3000);
        let alpha = 0.005 + cfg_rng.uniform() as f64 * 0.1;
        let rounds = 3;

        let run = |threads: usize| {
            let mut rng = Rng::new(0xDA7A + case);
            let mut fbs: Vec<FeedbackMemory> = (0..nodes)
                .map(|_| FeedbackMemory::new(n, Correction::Momentum, 0.9))
                .collect();
            let mut shards = NodeLedger::for_nodes(nodes);
            let mut ledger = Ledger::new();
            ledger.set_phase(2);
            let mut means: Vec<Vec<f32>> = Vec::new();
            for _ in 0..rounds {
                let grads: Vec<Vec<f32>> =
                    (0..nodes).map(|_| rng.normal_vec(n, 1.0)).collect();
                let k_sel = topk::k_of(n, alpha);
                let packets: Vec<(Vec<u32>, Vec<f32>)> = parallel::par_zip_mut(
                    threads,
                    &mut fbs,
                    &mut shards,
                    |node, fb, shard| {
                        fb.accumulate(&grads[node]);
                        let sel = fb.select_and_clear(k_sel);
                        shard.record(Kind::Values, sel.values.len() * 4);
                        shard.record(
                            Kind::Indices,
                            index_coding::encode(&sel.indices, n).unwrap().len(),
                        );
                        (sel.indices, sel.values)
                    },
                );
                let mut mean = vec![0.0f32; n];
                for (idx, vals) in &packets {
                    topk::scatter_add(&mut mean, idx, vals);
                }
                mean.iter_mut().for_each(|m| *m /= nodes as f32);
                means.push(mean);
                ledger.merge_shards(&mut shards);
                ledger.end_iteration();
            }
            (means, ledger)
        };

        let (base_means, base_ledger) = run(1);
        for threads in [2, nodes, 16] {
            let (means, ledger) = run(threads);
            assert_eq!(means, base_means, "case {case} threads={threads}");
            assert_eq!(
                ledger.iter_bytes, base_ledger.iter_bytes,
                "case {case} threads={threads}"
            );
            assert_eq!(ledger.total(), base_ledger.total(), "case {case}");
            assert_eq!(ledger.per_node, base_ledger.per_node, "case {case}");
            assert_eq!(ledger.per_kind, base_ledger.per_kind, "case {case}");
        }
    }
}

// ---------------------------------------------------------------------------
// DEFLATE rewrite (LZ77 + dynamic Huffman): differential + fuzz properties
// ---------------------------------------------------------------------------

/// Payload generator spanning the encoder's regimes: incompressible,
/// tiny-alphabet, high-bit-skewed (varint-continuation-like), and
/// repeated patterns (forces LZ77 matches).
fn random_payload(rng: &mut Rng) -> Vec<u8> {
    let n = rng.below(4000);
    match rng.below(4) {
        0 => (0..n).map(|_| rng.below(256) as u8).collect(),
        1 => (0..n).map(|_| rng.below(8) as u8).collect(),
        2 => (0..n).map(|_| 0x80 | rng.below(64) as u8).collect(),
        _ => {
            let pat: Vec<u8> =
                (0..1 + rng.below(37)).map(|_| rng.below(256) as u8).collect();
            (0..n).map(|i| pat[i % pat.len()]).collect()
        }
    }
}

#[test]
fn prop_deflate_roundtrips_all_levels() {
    for case in 0..120u64 {
        let mut rng = Rng::new(0xDEF1 + case);
        let data = random_payload(&mut rng);
        for level in [0u32, 1, 6, 9] {
            let packed = flate2::compress(&data, flate2::Compression::new(level));
            assert_eq!(
                flate2::decompress(&packed).unwrap(),
                data,
                "case {case} level {level}"
            );
        }
    }
}

#[test]
fn prop_both_decoders_agree_on_fixed_and_stored_streams() {
    // Differential over the decoder pair: whenever a stream contains only
    // stored/fixed blocks — everything the legacy decoder understands —
    // the legacy fixed-only inflate and the new dynamic-capable inflate
    // must produce bit-identical output.
    for case in 0..150u64 {
        let mut rng = Rng::new(0xD1F + case);
        let data = random_payload(&mut rng);
        // Level 0 output is stored-only by construction.
        let stored = flate2::compress(&data, flate2::Compression::new(0));
        let a = flate2::legacy::inflate_fixed_only(&stored).unwrap();
        let b = flate2::decompress(&stored).unwrap();
        assert_eq!(a, b, "case {case}");
        assert_eq!(a, data, "case {case}");
        // Default level: the new decoder always inflates its own output;
        // the legacy decoder must agree whenever the cost race happened
        // to avoid dynamic blocks (it errors on them otherwise).
        let packed = flate2::compress(&data, flate2::Compression::default());
        let b = flate2::decompress(&packed).unwrap();
        assert_eq!(b, data, "case {case}");
        if let Ok(a) = flate2::legacy::inflate_fixed_only(&packed) {
            assert_eq!(a, b, "case {case}: decoders disagree on a fixed/stored stream");
        }
        // The legacy *encoder*'s streams decode identically under both.
        let legacy_packed = flate2::legacy::deflate_fixed_only(&data);
        assert_eq!(flate2::decompress(&legacy_packed).unwrap(), data, "case {case}");
        assert_eq!(
            flate2::legacy::inflate_fixed_only(&legacy_packed).unwrap(),
            data,
            "case {case}"
        );
    }
}

#[test]
fn prop_index_payloads_never_grow_vs_fixed_baseline() {
    // The new encoder considers fixed and stored candidates per block, so
    // it can never lose to the fixed-only baseline; decode must agree.
    for case in 0..40u64 {
        let mut rng = Rng::new(0x1DEA + case);
        let n = 1000 + rng.below(500_000);
        let k = 1 + rng.below((n / 50).max(1));
        let idx = random_indices(&mut rng, n, k);
        let new = index_coding::encode(&idx, n).unwrap();
        let old = index_coding::encode_fixed_baseline(&idx, n).unwrap();
        assert!(
            new.len() <= old.len(),
            "case {case} n={n} k={k}: {} > {}",
            new.len(),
            old.len()
        );
        assert_eq!(index_coding::decode(&new, n).unwrap(), idx, "case {case}");
        assert_eq!(index_coding::decode(&old, n).unwrap(), idx, "case {case}");
    }
}

#[test]
fn prop_inflate_never_panics_on_arbitrary_bytes() {
    // Decode-total fuzz: arbitrary byte strings must yield Ok or Err,
    // never a panic, from both inflate paths.
    for case in 0..CASES * 10 {
        let mut rng = Rng::new(0xF422 + case);
        let n = rng.below(300);
        let blob: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let _ = flate2::decompress(&blob);
        let _ = flate2::legacy::inflate_fixed_only(&blob);
    }
    // Mutated valid streams probe deeper decoder states than pure noise.
    for case in 0..CASES {
        let mut rng = Rng::new(0xF423 + case);
        let data = random_payload(&mut rng);
        let mut packed = flate2::compress(&data, flate2::Compression::default());
        for _ in 0..1 + rng.below(5) {
            if packed.is_empty() {
                break;
            }
            let pos = rng.below(packed.len());
            packed[pos] ^= 1 << rng.below(8);
        }
        let _ = flate2::decompress(&packed);
    }
}

#[test]
fn prop_index_decode_never_panics_on_arbitrary_bytes() {
    // Truncated bitmaps, corrupt counts, non-canonical varints, garbage
    // DEFLATE payloads: decode/decode_ordered must error, not panic.
    for case in 0..CASES * 5 {
        let mut rng = Rng::new(0x1DF + case);
        let n = 1 + rng.below(100_000);
        let len = rng.below(200);
        let mut blob: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // Half the time force a valid mode byte to reach the deep paths
        // (0 = deflate-delta, 1 = bitmap, 2 = golomb).
        if !blob.is_empty() && rng.below(2) == 0 {
            blob[0] = rng.below(3) as u8;
        }
        let _ = index_coding::decode(&blob, n);
        let _ = index_coding::decode_ordered(&blob);
    }
    // Truncations of *valid* payloads (all three modes).
    for case in 0..CASES {
        let mut rng = Rng::new(0x1E0 + case);
        let n = 64 + rng.below(10_000);
        let dense = rng.below(2) == 0;
        let k = if dense { n / 2 } else { 1 + n / 100 };
        let idx = random_indices(&mut rng, n, k);
        let bytes = index_coding::encode(&idx, n).unwrap();
        let cut = rng.below(bytes.len().max(1));
        let _ = index_coding::decode(&bytes[..cut], n);
        let golomb = index_coding::encode_with(&idx, n, IndexCodec::Golomb).unwrap();
        let cut = rng.below(golomb.len().max(1));
        let _ = index_coding::decode(&golomb[..cut], n);
        let ordered = index_coding::encode_ordered(&idx).unwrap();
        let cut = rng.below(ordered.len().max(1));
        let _ = index_coding::decode_ordered(&ordered[..cut]);
    }
}

#[test]
fn prop_golomb_roundtrips_and_survives_hostile_payloads() {
    // MODE_GOLOMB over the whole operating range: dense halves, paper-
    // sparsity sets, singletons, empty — exact roundtrip; then truncated
    // and bit-flipped payloads must error (or decode to *some* valid set
    // when the flip lands in ignored padding), never panic.
    for case in 0..CASES {
        let mut rng = Rng::new(0x60F + case);
        let n = 1 + rng.below(300_000);
        let k = match case % 4 {
            0 => 0,
            1 => 1,
            2 => 1 + rng.below((n / 100).max(1)),
            _ => 1 + rng.below((n / 2).max(1)),
        };
        let idx = random_indices(&mut rng, n, k);
        let bytes = index_coding::encode_with(&idx, n, IndexCodec::Golomb).unwrap();
        assert_eq!(bytes[0], 2, "case {case}: golomb mode byte");
        assert_eq!(
            index_coding::decode(&bytes, n).unwrap(),
            idx,
            "case {case} n={n} k={k}"
        );
        // Truncation: every strict prefix must fail or return a prefix-
        // consistent set — and must not panic.
        let cut = rng.below(bytes.len());
        let _ = index_coding::decode(&bytes[..cut], n);
        // Mutation: flip 1..4 random bits anywhere in the payload.
        let mut bad = bytes.clone();
        for _ in 0..1 + rng.below(4) {
            let pos = rng.below(bad.len());
            bad[pos] ^= 1 << rng.below(8);
        }
        if let Ok(back) = index_coding::decode(&bad, n) {
            // A surviving decode must still be a sane index set.
            assert!(back.windows(2).all(|w| w[0] < w[1]), "case {case}: unsorted");
            assert!(back.iter().all(|&i| (i as usize) < n), "case {case}: out of range");
        }
    }
}

#[test]
fn prop_auto_picker_emits_the_smallest_candidate() {
    // `Auto`'s wire bytes == min over the three forced codecs, for any
    // index set; and the emitted payload decodes back exactly.
    for case in 0..CASES {
        let mut rng = Rng::new(0xA070 + case);
        let n = 8 + rng.below(500_000);
        let k = match case % 3 {
            0 => rng.below(4),                        // near-empty
            1 => 1 + rng.below((n / 200).max(1)),     // sparse (golomb/deflate regime)
            _ => 1 + rng.below((n / 2).max(1)),       // dense (bitmap regime)
        };
        let idx = random_indices(&mut rng, n, k);
        let auto = index_coding::encode_with(&idx, n, IndexCodec::Auto).unwrap();
        let best = [IndexCodec::Bitmap, IndexCodec::Deflate, IndexCodec::Golomb]
            .iter()
            .map(|&c| index_coding::encode_with(&idx, n, c).unwrap().len())
            .min()
            .unwrap();
        assert_eq!(auto.len(), best, "case {case} n={n} k={k}: auto is not minimal");
        assert_eq!(index_coding::decode(&auto, n).unwrap(), idx, "case {case}");
        // Auto never loses to the legacy hybrid (the fig10/11 rate bar).
        let legacy = index_coding::encode(&idx, n).unwrap();
        assert!(
            auto.len() <= legacy.len(),
            "case {case}: auto {} > legacy {}",
            auto.len(),
            legacy.len()
        );
    }
}

#[test]
fn prop_every_codec_strategy_decodes_with_the_one_decoder() {
    // The decoder is mode-dispatched off the wire byte, so any receiver
    // accepts any sender-side strategy without configuration.
    for case in 0..CASES {
        let mut rng = Rng::new(0xDEC0 + case);
        let n = 8 + rng.below(100_000);
        let k = rng.below((n / 4).max(1));
        let idx = random_indices(&mut rng, n, k);
        for codec in IndexCodec::all() {
            let bytes = index_coding::encode_with(&idx, n, codec).unwrap();
            assert_eq!(
                index_coding::decode(&bytes, n).unwrap(),
                idx,
                "case {case} codec={}",
                codec.name()
            );
        }
    }
}

#[test]
fn prop_scratch_encode_paths_match_allocating_paths() {
    // The zero-allocation arena entry points must be byte-identical to
    // the allocating wrappers for any input (arenas are wall-clock only,
    // never semantics — DESIGN.md §6.11).
    use lgc::compress::Scratch;
    let mut sc = Scratch::new();
    for case in 0..60u64 {
        let mut rng = Rng::new(0x5C1 + case);
        let n = 16 + rng.below(200_000);
        let k = 1 + rng.below((n / 4).max(1));
        let idx = random_indices(&mut rng, n, k);
        let a = index_coding::encode(&idx, n).unwrap();
        let b = index_coding::encode_into(&idx, n, &mut sc.enc).unwrap();
        assert_eq!(a, b, "case {case}");
        let c = index_coding::encode_ordered(&idx).unwrap();
        let d = index_coding::encode_ordered_into(&idx, &mut sc.enc).unwrap();
        assert_eq!(c, d, "case {case}");
        // Selection through the arena matches the allocating top-k.
        let g = rng.normal_vec(1 + rng.below(3000), 1.0);
        let kk = 1 + rng.below(g.len());
        let want = topk::top_k(&g, kk);
        let thr = topk::top_k_into(&g, kk, &mut sc.mags, &mut sc.idx, &mut sc.vals);
        assert_eq!(want.indices, sc.idx, "case {case}");
        assert_eq!(want.values, sc.vals, "case {case}");
        assert_eq!(want.threshold, thr, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// Bucketed pipeline (DESIGN.md §13): ragged partitions never change bits
// ---------------------------------------------------------------------------

/// Random ascending contiguous partition of `[0, n)` into `1..=max_b`
/// ragged ranges — cut points drawn uniformly, so widths vary wildly,
/// width-1 buckets included.
fn random_partition(rng: &mut Rng, n: usize, max_b: usize) -> Vec<std::ops::Range<usize>> {
    let b = 1 + rng.below(max_b.min(n - 1));
    let mut cuts = std::collections::BTreeSet::new();
    while cuts.len() < b - 1 {
        cuts.insert(1 + rng.below(n - 1));
    }
    let mut edges = vec![0usize];
    edges.extend(cuts);
    edges.push(n);
    edges.windows(2).map(|w| w[0]..w[1]).collect()
}

#[test]
fn prop_ragged_buckets_bit_identical_for_ef_family() {
    // The sparse-EF strategies (sparse_gd = plain EF, dgc = momentum-
    // corrected EF) under any 1..=32 ragged bucket partition: selection,
    // values, and residual feedback memory must all be bit-identical to
    // the monolithic path, round after round (DESIGN.md §13.2).
    use lgc::compress::Scratch;
    for case in 0..CASES {
        let mut rng = Rng::new(0xB0C4E7 + case);
        let n = 64 + rng.below(4000);
        let ranges = random_partition(&mut rng, n, 32);
        for correction in [Correction::Plain, Correction::Momentum] {
            let mut mono = FeedbackMemory::new(n, correction, 0.9);
            let mut buck = FeedbackMemory::new(n, correction, 0.9);
            let (mut sc_m, mut sc_b) = (Scratch::new(), Scratch::new());
            let mut grad_rng = Rng::new(0x6AAD + case);
            for round in 0..4 {
                let g = grad_rng.normal_vec(n, 1.0);
                mono.accumulate(&g);
                buck.accumulate(&g);
                let k = 1 + rng.below(n / 4 + 1);
                mono.select_and_clear_into(k, &mut sc_m);
                buck.select_and_clear_bucketed_into(k, &ranges, &mut sc_b);
                assert_eq!(sc_m.idx, sc_b.idx, "case {case} round {round}");
                assert_eq!(sc_m.vals, sc_b.vals, "case {case} round {round}");
                assert_eq!(mono.memory(), buck.memory(), "case {case} round {round}");
                // The splits must tile the selection along the partition.
                assert_eq!(sc_b.splits.len(), ranges.len() + 1, "case {case}");
                assert_eq!(sc_b.splits[0], 0, "case {case}");
                assert_eq!(*sc_b.splits.last().unwrap(), sc_b.idx.len(), "case {case}");
                for (b, r) in ranges.iter().enumerate() {
                    for &i in &sc_b.idx[sc_b.splits[b]..sc_b.splits[b + 1]] {
                        assert!(r.contains(&(i as usize)), "case {case} bucket {b} idx {i}");
                    }
                }
            }
        }
    }
}

#[test]
fn prop_threshold_splits_and_bucket_packets_remerge() {
    // The hard-threshold strategy ships whatever AIMD selected, cut into
    // buckets by `splits_of`; each bucket's indices travel bucket-local,
    // coded over the range width (the wire's GradientBucket framing).
    // Decoding every bucket, re-globalizing, and concatenating must
    // reproduce the monolithic packet bit-for-bit.
    use lgc::coordinator::bucket::BucketPlan;
    for case in 0..CASES {
        let mut rng = Rng::new(0x5B11 + case);
        let n = 64 + rng.below(20_000);
        let max_layers = 8 + rng.below(56);
        let layers = random_partition(&mut rng, n, max_layers);
        let plan = BucketPlan::from_layers(n, &layers, 1 + rng.below(32));
        let k = 1 + rng.below(n / 4 + 1);
        let idx = random_indices(&mut rng, n, k);
        let vals: Vec<f32> = (0..idx.len()).map(|_| rng.normal()).collect();
        let mut splits = Vec::new();
        plan.splits_of(&idx, &mut splits);
        assert_eq!(splits.len(), plan.len() + 1, "case {case}");
        assert_eq!(splits[0], 0, "case {case}");
        assert_eq!(*splits.last().unwrap(), idx.len(), "case {case}");
        let (mut got_idx, mut got_vals) = (Vec::new(), Vec::new());
        for (b, r) in plan.ranges().iter().enumerate() {
            let (lo, hi) = (splits[b], splits[b + 1]);
            let width = r.end - r.start;
            let local: Vec<u32> = idx[lo..hi].iter().map(|&i| i - r.start as u32).collect();
            assert!(
                local.iter().all(|&i| (i as usize) < width),
                "case {case} bucket {b}: local index out of range"
            );
            let coded = index_coding::encode(&local, width).unwrap();
            let back = index_coding::decode(&coded, width).unwrap();
            got_idx.extend(back.iter().map(|&i| i + r.start as u32));
            got_vals.extend_from_slice(&vals[lo..hi]);
        }
        assert_eq!(got_idx, idx, "case {case}");
        assert_eq!(got_vals, vals, "case {case}");
    }
}

#[test]
fn prop_dense_bucket_slices_reassemble_exactly() {
    // The dense baseline streams each bucket as a raw slice; slotting the
    // slices back by range must reproduce the original gradient bitwise,
    // so the per-node mean (and everything downstream) cannot differ.
    for case in 0..CASES {
        let mut rng = Rng::new(0xDE2E + case);
        let n = 32 + rng.below(4000);
        let ranges = random_partition(&mut rng, n, 32);
        let g = rng.normal_vec(n, 1.0);
        let mut back = vec![0.0f32; n];
        for r in &ranges {
            back[r.clone()].copy_from_slice(&g[r.clone()]);
        }
        assert_eq!(back, g, "case {case}");
    }
}

// ---------------------------------------------------------------------------
// Observability (DESIGN.md §15): checkpoint and trace serializers
// ---------------------------------------------------------------------------

#[test]
fn prop_ledger_checkpoint_roundtrips_after_shard_merges() {
    // Ledger::to_bytes/from_bytes over randomized histories: direct
    // records, one-off payloads, and sharded per-node records merged in,
    // across random phase switches and iteration boundaries.  The
    // restored ledger must compare equal (PartialEq covers every map and
    // the per-iteration series).
    use lgc::util::ser::Reader;
    const KINDS: [Kind; 5] =
        [Kind::Dense, Kind::Values, Kind::Indices, Kind::Latent, Kind::AeWeights];
    for case in 0..CASES {
        let mut rng = Rng::new(0x13D6E2 + case);
        let nodes = 1 + rng.below(9);
        let mut ledger = Ledger::new();
        let rounds = 1 + rng.below(12);
        for _ in 0..rounds {
            ledger.set_phase(1 + rng.below(3) as u8);
            // Direct records on the coordinator path.
            for _ in 0..rng.below(6) {
                let kind = KINDS[rng.below(KINDS.len())];
                let node = rng.below(nodes);
                if rng.below(8) == 0 {
                    ledger.record_oneoff(node, kind, rng.below(100_000));
                } else {
                    ledger.record(node, kind, rng.below(100_000));
                }
            }
            // Sharded records merged like the parallel exchange does.
            let mut shards = NodeLedger::for_nodes(nodes);
            for shard in shards.iter_mut() {
                for _ in 0..rng.below(4) {
                    let kind = KINDS[rng.below(KINDS.len())];
                    if rng.below(8) == 0 {
                        shard.record_oneoff(kind, rng.below(100_000));
                    } else {
                        shard.record(kind, rng.below(100_000));
                    }
                }
            }
            ledger.merge_shards(&mut shards);
            // Snapshots happen at iteration boundaries (cur_iter == 0).
            ledger.end_iteration();
        }
        let bytes = ledger.to_bytes();
        let back = Ledger::from_bytes(&mut Reader::new(&bytes))
            .unwrap_or_else(|e| panic!("case {case}: from_bytes failed: {e:#}"));
        assert_eq!(back, ledger, "case {case} nodes={nodes} rounds={rounds}");
        // Serialization is a pure function of the ledger state.
        assert_eq!(back.to_bytes(), bytes, "case {case}: re-serialize differs");
    }
}

/// Hostile label generator: quotes, backslashes, every C0 control char,
/// DEL, multi-byte UTF-8 (including astral-plane and bidi controls),
/// NUL, and long runs — everything a part-file line or Chrome trace
/// string field could choke on.
fn hostile_label(rng: &mut Rng) -> String {
    const POOL: &[&str] = &[
        "\"", "\\", "\\\"", "\n", "\r", "\t", "\u{0}", "\u{1}", "\u{8}",
        "\u{b}", "\u{c}", "\u{1f}", "\u{7f}", "ü", "漢", "🦀", "\u{202e}",
        "\u{feff}", "}", "{", "[", "]", ",", ":", "grad", " ", "é\u{301}",
    ];
    let n = rng.below(40);
    let mut s = String::new();
    for _ in 0..n {
        s.push_str(POOL[rng.below(POOL.len())]);
    }
    s
}

#[test]
fn prop_trace_serializers_never_panic_and_part_lines_roundtrip() {
    use lgc::obs::trace::{self, SpanEvent};
    for case in 0..CASES {
        let mut rng = Rng::new(0x7AACE + case);
        let n = rng.below(60);
        let events: Vec<SpanEvent> = (0..n)
            .map(|_| SpanEvent {
                lane: if rng.below(4) == 0 { trace::COORD_LANE } else { rng.below(16) },
                stage: hostile_label(&mut rng),
                // Keep numeric fields inside f64's exact-integer range —
                // the JSON transport is f64, and real timestamps
                // (microseconds since the epoch) are far below 2^53.
                iter: rng.below(1 << 40) as u64,
                bucket: if rng.below(3) == 0 { -1 } else { rng.below(256) as i64 },
                ts_us: rng.below(1 << 50) as u64,
                dur_us: rng.below(1 << 40) as u64,
            })
            .collect();
        // Part-file lines: one JSON object per event, exact roundtrip.
        let lines = trace::part_lines(&events);
        let parsed: Vec<SpanEvent> = lines
            .lines()
            .map(|l| {
                trace::parse_part_line(l)
                    .unwrap_or_else(|e| panic!("case {case}: parse failed: {e:#}\n{l}"))
            })
            .collect();
        assert_eq!(parsed, events, "case {case}");
        // Chrome trace JSON: must serialize without panicking and with
        // every control character escaped (raw C0 bytes inside a string
        // field would make the file unloadable).
        let json = trace::chrome_trace_json(&events);
        assert!(
            json.chars().all(|c| c >= ' '),
            "case {case}: unescaped control character in trace JSON"
        );
    }
    // Arbitrary garbage into the part-line parser: Err, never a panic.
    for case in 0..CASES {
        let mut rng = Rng::new(0x7AACF + case);
        let blob = hostile_label(&mut rng);
        let _ = lgc::obs::trace::parse_part_line(&blob);
    }
}

#[test]
fn prop_quantizer_error_bounded_by_bucket_norm() {
    use lgc::compress::quantize;
    for case in 0..60 {
        let mut rng = Rng::new(0x4A + case);
        let n = 64 + rng.below(4000);
        let levels = 1 + rng.below(255) as u32;
        let bucket = 16 + rng.below(512);
        let g = rng.normal_vec(n, 1.0);
        let p = quantize::qsgd(&g, levels, bucket, &mut rng);
        for (chunk_i, chunk) in g.chunks(bucket).enumerate() {
            let norm = chunk.iter().map(|x| x * x).sum::<f32>().sqrt();
            for (j, &x) in chunk.iter().enumerate() {
                let q = p.dequant[chunk_i * bucket + j];
                assert!(
                    (q - x).abs() <= norm / levels as f32 + 1e-5,
                    "case {case}: |{q} - {x}| > {}",
                    norm / levels as f32
                );
            }
        }
    }
}
