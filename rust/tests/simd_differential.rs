//! Scalar <-> SIMD differential suite (DESIGN.md §16.1).
//!
//! Every vectorized kernel in the encode hot path ships with a scalar
//! twin, and the pair must be *bit-identical* — same selected indices,
//! same f32 bit patterns, same bytes out — because training curves,
//! ledgers, and the sim-vs-wire identity contract all flow through them.
//! Each test here drives a kernel through its public entry point under
//! forced-scalar and auto dispatch over adversarial shapes (length 0, 1,
//! non-multiples of the 8-lane width) and adversarial values (NaN, ±inf,
//! ±0, denormals, all-equal-to-threshold ties), comparing outputs at the
//! bit level; the final test runs whole native training sessions both
//! ways and diffs every deterministic artifact, checkpoint bytes
//! included.
//!
//! Dispatch is process-global, so every test serializes on one mutex and
//! restores auto dispatch before releasing it.

use lgc::compress::index_coding::IndexCodec;
use lgc::compress::{f16, quantize, simd, topk};
use lgc::config::{Method, TrainConfig};
use lgc::coordinator;
use lgc::runtime::Engine;
use lgc::util::rng::Rng;

const CASES: u64 = 220;

/// Serialize dispatch flips across the concurrently-run tests in this
/// binary; a poisoned lock just means another test failed, not that the
/// dispatch state is corrupt.
fn dispatch_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` twice — scalar twins pinned, then auto dispatch — and restore
/// auto before returning `(scalar, auto)`.
fn both<R>(mut f: impl FnMut() -> R) -> (R, R) {
    simd::force_scalar(true);
    let s = f();
    simd::force_scalar(false);
    let a = f();
    (s, a)
}

/// Adversarial f32 pool: specials, signed zeros, f32 and f16 denormals,
/// threshold-magnitude ties get injected separately.
const SPECIALS: [f32; 12] = [
    f32::NAN,
    f32::INFINITY,
    f32::NEG_INFINITY,
    0.0,
    -0.0,
    1e-40,
    -1e-40,
    f32::MIN_POSITIVE,
    -f32::MIN_POSITIVE,
    6.1e-5,  // just below the f16 normal boundary
    65520.0, // the f16 overflow tie
    f32::MAX,
];

/// Lengths straddling the 8-lane width: empty, single, tail-only, exact
/// multiples, multiples ± 1, and a large odd size.
const LENS: [usize; 12] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 64, 255, 1021];

fn adversarial_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut g = rng.normal_vec(len, 1.0);
    for _ in 0..len / 3 {
        let at = rng.below(len.max(1));
        g[at] = SPECIALS[rng.below(SPECIALS.len())];
    }
    g
}

// ---------------------------------------------------------------------------
// Top-k threshold scan
// ---------------------------------------------------------------------------

#[test]
fn topk_selection_is_bit_identical_scalar_vs_simd() {
    let _g = dispatch_lock();
    for case in 0..CASES {
        let mut rng = Rng::new(0x70D1F + case);
        let len = LENS[case as usize % LENS.len()];
        let mut g = adversarial_vec(&mut rng, len);
        // Force magnitude ties so the tie-fill pass has to disambiguate
        // against the vectorized strict pass.
        for _ in 0..len / 4 {
            let (a, b) = (rng.below(len.max(1)), rng.below(len.max(1)));
            if len > 0 {
                g[a] = g[b].abs();
            }
        }
        let k = rng.below(len + 2); // includes 0 and > len
        let (s, a) = both(|| topk::top_k(&g, k));
        assert_eq!(s.indices, a.indices, "case {case} len={len} k={k}");
        let sv: Vec<u32> = s.values.iter().map(|v| v.to_bits()).collect();
        let av: Vec<u32> = a.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(sv, av, "case {case}: value bits drifted");
        assert_eq!(s.threshold.to_bits(), a.threshold.to_bits(), "case {case}");
    }
    // All-equal-to-threshold: every coordinate ties, the strict pass
    // selects nothing, and both paths must fill identically.
    for len in [1usize, 7, 8, 9, 33] {
        let g = vec![1.0f32; len];
        for k in [1, len / 2 + 1, len] {
            let (s, a) = both(|| topk::top_k(&g, k));
            assert_eq!(s.indices, a.indices, "ties len={len} k={k}");
            assert_eq!(s.indices.len(), k.min(len));
        }
    }
}

#[test]
fn bucketed_topk_is_bit_identical_scalar_vs_simd() {
    let _g = dispatch_lock();
    for case in 0..CASES {
        let mut rng = Rng::new(0xB0D1F + case);
        let len = 16 + rng.below(2000);
        let g = adversarial_vec(&mut rng, len);
        let k = 1 + rng.below(len / 2 + 1);
        // Random ascending contiguous partition.
        let nb = 1 + rng.below(16);
        let mut cuts = std::collections::BTreeSet::new();
        while cuts.len() < nb.min(len - 1) {
            cuts.insert(1 + rng.below(len - 1));
        }
        let mut edges = vec![0usize];
        edges.extend(&cuts);
        edges.push(len);
        let ranges: Vec<std::ops::Range<usize>> =
            edges.windows(2).map(|w| w[0]..w[1]).collect();
        let run = || {
            let (mut mags, mut idx, mut vals, mut splits) =
                (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            let thr =
                topk::top_k_bucketed_into(&g, k, &ranges, &mut mags, &mut idx, &mut vals, &mut splits);
            let vbits: Vec<u32> = vals.iter().map(|v| v.to_bits()).collect();
            (thr.to_bits(), idx, vbits, splits)
        };
        let (s, a) = both(run);
        assert_eq!(s, a, "case {case} len={len} k={k} buckets={}", ranges.len());
    }
}

// ---------------------------------------------------------------------------
// QSGD stochastic quantization
// ---------------------------------------------------------------------------

#[test]
fn qsgd_is_bit_identical_scalar_vs_simd() {
    let _g = dispatch_lock();
    for case in 0..CASES {
        let mut rng = Rng::new(0x45D1F + case);
        let len = LENS[case as usize % LENS.len()];
        let g = adversarial_vec(&mut rng, len);
        let levels = 1 + rng.below(255) as u32;
        let bucket = 1 + rng.below(64);
        // Both paths must consume the RNG stream identically, so each
        // gets a fresh generator with the same seed.
        let run = || {
            let mut qrng = Rng::new(0x0123 + case);
            let p = quantize::qsgd(&g, levels, bucket, &mut qrng);
            let bits: Vec<u32> = p.dequant.iter().map(|v| v.to_bits()).collect();
            (p.bytes, bits)
        };
        let (s, a) = both(run);
        assert_eq!(s.0, a.0, "case {case}: packet bytes drifted");
        assert_eq!(s.1, a.1, "case {case} len={len} levels={levels} bucket={bucket}");
    }
}

// ---------------------------------------------------------------------------
// f32 <-> f16 wire round-trip
// ---------------------------------------------------------------------------

#[test]
fn f16_roundtrip_is_bit_identical_scalar_vs_simd() {
    let _g = dispatch_lock();
    for case in 0..CASES {
        let mut rng = Rng::new(0xF16D1F + case);
        let len = LENS[case as usize % LENS.len()];
        // Raw bit-pattern sampling reaches every f32 class (denormals,
        // NaN payloads, both signs) far more often than normal draws.
        let vals: Vec<f32> = (0..len)
            .map(|_| {
                if rng.below(3) == 0 {
                    SPECIALS[rng.below(SPECIALS.len())]
                } else {
                    f32::from_bits(rng.below(u32::MAX as usize + 1) as u32)
                }
            })
            .collect();
        let run = || {
            let (deq, bytes) = f16::quantize_f16(&vals);
            let bits: Vec<u32> = deq.iter().map(|v| v.to_bits()).collect();
            (bytes, bits)
        };
        let (s, a) = both(run);
        assert_eq!(s, a, "case {case} len={len}");
    }
    // Exhaustive over every f16-representable value (and its neighbours'
    // roundtrip targets): all 65536 bit patterns decoded to f32, then
    // round-tripped by both paths in one 65536-lane sweep.
    let all: Vec<f32> = (0..=u16::MAX).map(f16::f16_bits_to_f32).collect();
    let run = || {
        let mut v = all.clone();
        f16::roundtrip_in_place(&mut v);
        v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>()
    };
    let (s, a) = both(run);
    assert_eq!(s, a, "exhaustive f16 sweep drifted");
}

// ---------------------------------------------------------------------------
// DEFLATE LZ77 match loop (vendored flate2)
// ---------------------------------------------------------------------------

#[test]
fn deflate_output_is_byte_identical_scalar_vs_simd() {
    let _g = dispatch_lock();
    for case in 0..CASES {
        let mut rng = Rng::new(0xDEF51 + case);
        let n = rng.below(6000);
        // Corpora spanning the match-finder's regimes: pure noise (no
        // matches), small alphabets (many short matches), long periodic
        // repeats (matches crossing the 32-byte SIMD stride), and a
        // duplicated random block (maximal matches with a controlled
        // mismatch tail).
        let data: Vec<u8> = match case % 4 {
            0 => (0..n).map(|_| rng.below(256) as u8).collect(),
            1 => (0..n).map(|_| rng.below(4) as u8).collect(),
            2 => {
                let pat: Vec<u8> =
                    (0..1 + rng.below(67)).map(|_| rng.below(256) as u8).collect();
                (0..n).map(|i| pat[i % pat.len()]).collect()
            }
            _ => {
                let half: Vec<u8> = (0..n / 2).map(|_| rng.below(256) as u8).collect();
                let mut d = half.clone();
                d.extend(&half);
                d
            }
        };
        for level in [1u32, 6, 9] {
            let run = || flate2::compress(&data, flate2::Compression::new(level));
            let (s, a) = both(run);
            assert_eq!(s, a, "case {case} level={level}: compressed bytes drifted");
            assert_eq!(flate2::decompress(&s).unwrap(), data, "case {case}");
        }
    }
}

// ---------------------------------------------------------------------------
// Environment override
// ---------------------------------------------------------------------------

#[test]
fn lgc_force_scalar_env_var_pins_the_scalar_twins() {
    let _g = dispatch_lock();
    // `force_scalar(false)` re-detects, and detection honours the env
    // var — so setting it must keep dispatch scalar even after release.
    std::env::set_var("LGC_FORCE_SCALAR", "1");
    simd::force_scalar(false);
    assert!(!simd::using_avx2(), "env override ignored by re-detection");
    std::env::remove_var("LGC_FORCE_SCALAR");
    simd::force_scalar(false); // restore hardware auto-detection
}

// ---------------------------------------------------------------------------
// End-to-end: whole native training runs, scalar vs auto
// ---------------------------------------------------------------------------

fn tiny_cfg(method: Method, codec: IndexCodec) -> TrainConfig {
    TrainConfig {
        model: "convnet_mini".into(),
        method,
        nodes: 2,
        steps: 12,
        warmup_iters: 4,
        ae_train_iters: 4,
        eval_every: 4,
        eval_batches: 2,
        ae_gate: f32::INFINITY,
        index_codec: codec,
        ..Default::default()
    }
}

/// Every deterministic training artifact, flattened to exact bits.
type Fingerprint = (Vec<(usize, u32, u32)>, Vec<(usize, u32, u32)>, String, Vec<u64>);

fn fingerprint(r: &coordinator::TrainResult) -> Fingerprint {
    let curve: Vec<(usize, u32, u32)> = r
        .curve
        .iter()
        .map(|p| (p.iter, p.train_loss.to_bits(), p.train_acc.to_bits()))
        .collect();
    let evals: Vec<(usize, u32, u32)> =
        r.evals.iter().map(|(i, l, a)| (*i, l.to_bits(), a.to_bits())).collect();
    (curve, evals, format!("{:?}", r.ledger), r.ledger.iter_bytes.clone())
}

/// The ISSUE acceptance bar: `LGC_FORCE_SCALAR=1` and auto dispatch
/// produce bit-identical curves, evals, ledgers, network traces, and
/// final checkpoint bytes — for a sparse-EF method under all four index
/// codecs (fp16 on, driving the f16 kernel), for QSGD (driving the
/// stochastic-round kernel), and for learned LGC (AE + innovation path).
#[test]
fn native_training_is_bit_identical_scalar_vs_simd() {
    let _g = dispatch_lock();
    let e = Engine::native().expect("native engine always constructs");
    let tmp = std::env::temp_dir();
    let pid = std::process::id();
    let mut configs: Vec<(String, TrainConfig)> = IndexCodec::all()
        .into_iter()
        .map(|codec| {
            let mut cfg = tiny_cfg(Method::SparseGd, codec);
            cfg.fp16_values = true;
            (format!("sparse_gd/{}", codec.name()), cfg)
        })
        .collect();
    configs.push(("qsgd".into(), tiny_cfg(Method::Qsgd, IndexCodec::Deflate)));
    configs.push(("lgc_ps/auto".into(), tiny_cfg(Method::LgcPs, IndexCodec::Auto)));
    for (tag, cfg) in configs {
        let safe_tag = tag.replace('/', "_");
        let run = |suffix: &str| {
            let path = tmp.join(format!("lgc_simd_diff_{pid}_{safe_tag}_{suffix}"));
            let mut cfg = cfg.clone();
            cfg.checkpoint = Some(path.to_string_lossy().into_owned());
            let r = coordinator::train(&e, cfg).unwrap();
            let ckpt = std::fs::read(&path).unwrap();
            std::fs::remove_file(&path).ok();
            (fingerprint(&r), format!("{:?}", r.net), ckpt)
        };
        simd::force_scalar(true);
        let scalar = run("scalar");
        simd::force_scalar(false);
        let auto = run("auto");
        assert_eq!(scalar.0, auto.0, "{tag}: curve/evals/ledger drifted");
        assert_eq!(scalar.1, auto.1, "{tag}: network trace drifted");
        assert_eq!(scalar.2, auto.2, "{tag}: checkpoint bytes drifted");
    }
}
