//! Telemetry contract (DESIGN.md §15): the observability flags only
//! *observe* —
//!
//! * A run with `--trace-out`, `--log-json`, and `--metrics-addr` set
//!   produces bit-identical curves, evals, ledgers, AE traces, and net
//!   reports to the same config with telemetry off.
//! * The emitted Chrome/Perfetto trace covers every pipeline stage, for
//!   every node lane, for every iteration (the `grad` span is the
//!   per-iteration heartbeat of each node).
//! * The JSONL run log carries the manifest, one record per iteration,
//!   every fault event, and the end-of-run summary — each line valid
//!   JSON.
//!
//! The span recorder is process-global, so everything trace-related
//! lives in ONE test; the fault-log test uses only `--log-json`.

use std::collections::{BTreeMap, BTreeSet};

use lgc::config::{Method, OnFault, TrainConfig};
use lgc::coordinator::{self, TrainResult};
use lgc::runtime::Engine;
use lgc::util::json::Json;

fn engine() -> Engine {
    Engine::native().expect("native engine always constructs")
}

/// Small three-phase run that reaches the compressed phase engaged
/// (`ae_gate = +inf` latches readiness once the loss window fills), so
/// the AE stages all appear in the trace.
fn cfg(model: &str, method: Method, nodes: usize) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        method,
        nodes,
        steps: 24,
        warmup_iters: 6,
        ae_train_iters: 8,
        eval_every: 6,
        eval_batches: 2,
        ae_gate: f32::INFINITY,
        ..Default::default()
    }
}

fn tmp_path(tag: &str) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("lgc-telemetry-{}-{tag}", std::process::id()));
    p.to_string_lossy().into_owned()
}

fn assert_results_identical(plain: &TrainResult, obs: &TrainResult) {
    assert_eq!(plain.curve.len(), obs.curve.len(), "curve lengths");
    for (a, b) in plain.curve.iter().zip(&obs.curve) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "loss at iter {}", a.iter);
        assert_eq!(a.train_acc.to_bits(), b.train_acc.to_bits(), "acc at iter {}", a.iter);
    }
    assert_eq!(plain.evals.len(), obs.evals.len(), "eval counts");
    for ((i1, l1, a1), (i2, l2, a2)) in plain.evals.iter().zip(&obs.evals) {
        assert_eq!(i1, i2);
        assert_eq!(l1.to_bits(), l2.to_bits(), "eval loss at iter {i1}");
        assert_eq!(a1.to_bits(), a2.to_bits(), "eval acc at iter {i1}");
    }
    assert_eq!(plain.final_eval.0.to_bits(), obs.final_eval.0.to_bits(), "final eval loss");
    assert_eq!(plain.final_eval.1.to_bits(), obs.final_eval.1.to_bits(), "final eval acc");
    assert_eq!(plain.phase_iters, obs.phase_iters, "phase iteration counts");
    assert_eq!(plain.ledger, obs.ledger, "byte ledgers");
    assert_eq!(plain.net, obs.net, "net fabric reports");
    assert_eq!(plain.ae_losses.len(), obs.ae_losses.len(), "AE loss trace lengths");
    for (i, ((r1, s1), (r2, s2))) in plain.ae_losses.iter().zip(&obs.ae_losses).enumerate() {
        assert_eq!(r1.to_bits(), r2.to_bits(), "AE rec loss {i}");
        assert_eq!(s1.to_bits(), s2.to_bits(), "AE sim loss {i}");
    }
}

#[test]
fn telemetry_run_bit_identical_and_trace_covers_pipeline() {
    let e = engine();
    let nodes = 4;
    let steps = 24;
    let plain = coordinator::train(&e, cfg("mlp_mini", Method::LgcRar, nodes))
        .expect("plain run");

    let trace_path = tmp_path("rar.trace.json");
    let jsonl_path = tmp_path("rar.jsonl");
    let mut c = cfg("mlp_mini", Method::LgcRar, nodes);
    c.trace_out = Some(trace_path.clone());
    c.log_json = Some(jsonl_path.clone());
    // Ephemeral port: proves install + bind + scrape path is live
    // without fixture ports colliding across CI shards.
    c.metrics_addr = Some("127.0.0.1:0".into());
    let obs = coordinator::train(&e, c).expect("telemetry run");

    // Contract 1: telemetry never feeds back into the math.
    assert_results_identical(&plain, &obs);

    // Contract 2: the trace is one valid JSON document covering every
    // stage of the engaged LGC-RAR pipeline, every node lane, and every
    // iteration.
    let text = std::fs::read_to_string(&trace_path).expect("trace written");
    let root = Json::parse(&text).expect("trace parses");
    let events = root
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    let mut stages: BTreeSet<String> = BTreeSet::new();
    // pid -> iterations that recorded a `grad` span (pid 0 is the
    // coordinator, pid N+1 is node N).
    let mut grad_iters: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    for ev in events {
        if ev.get("ph").and_then(Json::as_str) == Some("M") {
            continue; // process-name metadata
        }
        let name = ev.get("name").and_then(Json::as_str).unwrap_or("").to_string();
        let pid = ev.get("pid").and_then(Json::as_usize).expect("event pid");
        let iter = ev.get("args").and_then(|a| a.get("iter")).and_then(Json::as_usize);
        if name == "grad" {
            grad_iters.entry(pid).or_default().insert(iter.expect("grad iter tag"));
        }
        stages.insert(name);
    }
    for stage in [
        "grad", "ef", "topk", "ae_encode", "ae_decode", "ae_train",
        "index_code", "deflate", "exchange", "update",
    ] {
        assert!(stages.contains(stage), "trace missing stage {stage:?}; got {stages:?}");
    }
    for node in 0..nodes {
        let iters = grad_iters
            .get(&(node + 1))
            .unwrap_or_else(|| panic!("no grad spans for node {node}"));
        assert_eq!(
            iters.len(),
            steps,
            "node {node}: grad spans cover {} of {steps} iterations",
            iters.len()
        );
    }
    // Exchange/update run on the coordinator lane (pid 0) in sim runs.
    assert!(
        events.iter().any(|e| e.get("pid").and_then(Json::as_usize) == Some(0)),
        "no coordinator-lane events"
    );

    // Contract 3: the JSONL log is line-delimited valid JSON with the
    // manifest first, one record per iteration, and the run_end summary.
    let log = std::fs::read_to_string(&jsonl_path).expect("jsonl written");
    let recs: Vec<Json> = log
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).expect("every JSONL line parses"))
        .collect();
    assert_eq!(recs[0].str_of("event"), "run_start");
    assert_eq!(recs[0].str_of("method"), "lgc_rar");
    assert!(recs[0].get("cfg_fingerprint").is_some(), "manifest has cfg fingerprint");
    let iters: Vec<usize> = recs
        .iter()
        .filter(|r| r.str_of("event") == "iteration")
        .map(|r| r.usize_of("iter"))
        .collect();
    assert_eq!(iters, (0..steps).collect::<Vec<_>>(), "one record per iteration");
    for r in recs.iter().filter(|r| r.str_of("event") == "iteration") {
        for key in ["phase", "train_loss", "bytes_total", "compression_ratio", "exchange_s"] {
            assert!(r.get(key).is_some(), "iteration record missing {key:?}");
        }
    }
    assert_eq!(recs.last().unwrap().str_of("event"), "run_end");

    let _ = std::fs::remove_file(&trace_path);
    let _ = std::fs::remove_file(&jsonl_path);
}

#[test]
fn jsonl_captures_every_fault_event() {
    let e = engine();
    let jsonl_path = tmp_path("faults.jsonl");
    let mut c = cfg("mlp_mini", Method::SparseGd, 4);
    c.log_json = Some(jsonl_path.clone());
    c.faults = Some("iter=8:stall=2:50ms;iter=10:kill=1".into());
    c.on_fault = OnFault::Continue;
    let r = coordinator::train(&e, c).expect("faulty run completes under continue");
    assert!(!r.fault_events.is_empty(), "run recorded fault events");

    let log = std::fs::read_to_string(&jsonl_path).expect("jsonl written");
    let faults: Vec<Json> = log
        .lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| Json::parse(l).expect("line parses"))
        .filter(|r| r.str_of("event") == "fault")
        .collect();
    // Every event in TrainResult::fault_events has a JSONL record with
    // the same (iter, kind) — the log is the complete fault history.
    assert_eq!(faults.len(), r.fault_events.len(), "fault record count");
    for (rec, ev) in faults.iter().zip(&r.fault_events) {
        assert_eq!(rec.usize_of("iter"), ev.iter, "fault iter");
        assert_eq!(rec.str_of("kind"), ev.kind, "fault kind");
    }
    let _ = std::fs::remove_file(&jsonl_path);
}
