//! Integration tests over the full stack: PJRT runtime + coordinator +
//! compression strategies, against the real AOT artifacts.
//!
//! Gating: a clean checkout has neither `artifacts/` (built by
//! `make artifacts` with the JAX toolchain) nor a real PJRT backend (the
//! offline build links the vendored xla stub).  Every test in this file
//! therefore acquires the engine through [`engine`], which requests the
//! PJRT backend explicitly, yields `None` in that environment, and the
//! test records itself as skipped — loudly, on stderr — instead of
//! failing the tier-1 suite.  With artifacts and a real `xla` crate
//! present the whole file runs against live HLOs.
//!
//! The same end-to-end coverage runs unconditionally on the native CPU
//! backend in `tests/native_e2e.rs` — no artifacts, no PJRT, zero skips
//! — so the full pipeline is exercised from a clean checkout; this file
//! is what PJRT *adds* on top (AOT HLO parity).
//!
//! The PJRT client is process-global state; tests share one Engine via
//! OnceLock.  `Engine` is `Sync` (mutexed executable cache + internally
//! synchronized CPU client), so the shared `Mutex<Engine>` is sound
//! without any unsafe impls.

use std::sync::{Mutex, MutexGuard, OnceLock};

use lgc::config::{Method, SparsifySchedule, TrainConfig};
use lgc::coordinator::{self, scheduler::Phase};
use lgc::runtime::{BackendKind, Engine, Tensor};

/// Shared PJRT engine, or `None` when artifacts / PJRT are unavailable.
fn engine() -> Option<MutexGuard<'static, Engine>> {
    static ENGINE: OnceLock<Option<Mutex<Engine>>> = OnceLock::new();
    ENGINE
        .get_or_init(|| match Engine::open(BackendKind::Pjrt) {
            Ok(e) => Some(Mutex::new(e)),
            Err(err) => {
                eprintln!(
                    "integration suite: PJRT engine unavailable, tests will skip \
                     (run `make artifacts` with a PJRT build to enable; the \
                     native-backend suite in native_e2e.rs covers this path \
                     without artifacts): {err:#}"
                );
                None
            }
        })
        .as_ref()
        // A failed test must not cascade into unrelated ones: the Engine
        // carries no cross-test mutable state worth invalidating.
        .map(|m| m.lock().unwrap_or_else(|poisoned| poisoned.into_inner()))
}

macro_rules! require_engine {
    () => {
        match engine() {
            Some(e) => e,
            None => {
                eprintln!("skipped: no artifacts/PJRT in this environment");
                return;
            }
        }
    };
}

fn tiny_cfg(model: &str, method: Method, nodes: usize) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        method,
        nodes,
        steps: 12,
        warmup_iters: 4,
        ae_train_iters: 4,
        eval_every: 0,
        eval_batches: 2,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// Runtime-level
// ---------------------------------------------------------------------------

#[test]
fn manifest_covers_all_models() {
    let e = require_engine!();
    for m in ["convnet5", "resnet_mini", "resnet_mini_deep", "segnet_mini",
              "transformer_mini"] {
        assert!(e.manifest.models.contains_key(m), "{m}");
    }
}

#[test]
fn grad_step_executes_and_returns_finite_loss() {
    let e = require_engine!();
    let meta = e.manifest.model("convnet5").clone();
    let model = lgc::model::Model::new(&meta, 1);
    let data = lgc::data::for_model(&meta, 2);
    let batch = data.batch(0, 0);
    let (loss, acc, grads) = model.grad_step(&e, &batch).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
    assert_eq!(grads.len(), meta.params.len());
    for (g, shape) in grads.iter().zip(&meta.params) {
        assert_eq!(&g.dims, shape);
    }
}

#[test]
fn grad_step_deterministic_across_calls() {
    let e = require_engine!();
    let meta = e.manifest.model("convnet5").clone();
    let model = lgc::model::Model::new(&meta, 1);
    let data = lgc::data::for_model(&meta, 2);
    let batch = data.batch(0, 0);
    let (l1, _, g1) = model.grad_step(&e, &batch).unwrap();
    let (l2, _, g2) = model.grad_step(&e, &batch).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(g1[0].as_f32(), g2[0].as_f32());
}

#[test]
fn sparsify_hlo_matches_rust_semantics() {
    // The AOT'd Pallas sparsify kernel and the rust ref must agree.
    let e = require_engine!();
    let meta = e.manifest.model("convnet5").clone();
    let n = meta.n_mid;
    let mut rng = lgc::util::rng::Rng::new(3);
    let g = rng.normal_vec(n, 1.0);
    let acc = rng.normal_vec(n, 0.5);
    let thr = 0.8f32;
    let out = e
        .run(
            &meta.sparsify,
            &[
                Tensor::f32(vec![n], g.clone()),
                Tensor::f32(vec![n], acc.clone()),
                Tensor::f32(vec![1], vec![thr]),
            ],
        )
        .unwrap();
    let (gsp, acc2) = (out[0].as_f32(), out[1].as_f32());
    for i in 0..n {
        let u = g[i] + acc[i];
        if u.abs() >= thr {
            assert_eq!(gsp[i], u);
            assert_eq!(acc2[i], 0.0);
        } else {
            assert_eq!(gsp[i], 0.0);
            assert_eq!(acc2[i], u);
        }
    }
}

#[test]
fn executable_rejects_bad_shapes() {
    let e = require_engine!();
    let meta = e.manifest.model("convnet5").clone();
    let err = e.run(&meta.sparsify, &[Tensor::zeros(vec![3])]);
    assert!(err.is_err());
}

// ---------------------------------------------------------------------------
// Autoencoder round trips
// ---------------------------------------------------------------------------

#[test]
fn ae_encode_decode_roundtrip_shapes() {
    use lgc::compress::autoencoder::{AeCompressor, Pattern};
    let e = require_engine!();
    let mu = e.manifest.model("convnet5").mu;
    let ae = AeCompressor::new(&e, mu, 2, Pattern::RingAllreduce, 7).unwrap();
    let mut rng = lgc::util::rng::Rng::new(8);
    let g = rng.normal_vec(mu, 0.01);
    let (latent, scale) = ae.encode(&e, &g).unwrap();
    assert_eq!(latent.len(), mu / 4); // 4 ch x mu/16 (the paper's rate math)
    let rec = ae.decode_rar(&e, &latent, scale).unwrap();
    assert_eq!(rec.len(), mu);
    assert!(rec.iter().all(|x| x.is_finite()));
}

#[test]
fn ae_online_training_reduces_reconstruction_loss() {
    use lgc::compress::autoencoder::{AeCompressor, Pattern};
    let e = require_engine!();
    let mu = e.manifest.model("convnet5").mu;
    let mut ae = AeCompressor::new(&e, mu, 2, Pattern::RingAllreduce, 7).unwrap();
    let mut rng = lgc::util::rng::Rng::new(9);
    // A fixed pair of correlated "gradients".
    let base = rng.normal_vec(mu, 0.1);
    let grads: Vec<Vec<f32>> = (0..2)
        .map(|_| base.iter().map(|x| x + 0.02 * rng.normal()).collect())
        .collect();
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..30 {
        let (rec, _) = ae.train_step(&e, &grads, None, 0, 1e-3, 1.0, 0.0).unwrap();
        first = first.or(Some(rec));
        last = rec;
    }
    assert!(last < first.unwrap(), "{last} !< {first:?}");
}

#[test]
fn ae_ps_decoder_uses_innovation_channel() {
    use lgc::compress::autoencoder::{AeCompressor, Pattern};
    let e = require_engine!();
    let mu = e.manifest.model("convnet5").mu;
    let ae = AeCompressor::new(&e, mu, 2, Pattern::ParamServer, 7).unwrap();
    let mut rng = lgc::util::rng::Rng::new(10);
    let g = rng.normal_vec(mu, 0.01);
    let (latent, scale) = ae.encode(&e, &g).unwrap();
    let zero_innov = vec![0.0f32; mu];
    let big_innov: Vec<f32> = (0..mu).map(|i| if i % 7 == 0 { 1.0 } else { 0.0 }).collect();
    let r0 = ae.decode_ps(&e, 0, &latent, &zero_innov, scale).unwrap();
    let r1 = ae.decode_ps(&e, 0, &latent, &big_innov, scale).unwrap();
    let diff: f32 = r0.iter().zip(&r1).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 0.0);
    // Different per-node decoders give different reconstructions.
    let r_node1 = ae.decode_ps(&e, 1, &latent, &zero_innov, scale).unwrap();
    let diff01: f32 = r0.iter().zip(&r_node1).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff01 > 0.0);
}

// ---------------------------------------------------------------------------
// Full training loops, one per method
// ---------------------------------------------------------------------------

fn run_method(method: Method) -> Option<coordinator::TrainResult> {
    let e = engine()?;
    Some(coordinator::train(&e, tiny_cfg("convnet5", method, 2)).unwrap())
}

#[test]
fn every_method_trains_without_error_and_accounts_bytes() {
    for m in Method::all() {
        let Some(r) = run_method(m) else {
            eprintln!("skipped: no artifacts/PJRT in this environment");
            return;
        };
        assert_eq!(r.curve.len(), 12, "{}", m.name());
        assert!(r.final_eval.0.is_finite());
        assert!(r.ledger.total() > 0, "{} sent nothing", m.name());
        assert!(
            r.curve.iter().all(|p| p.train_loss.is_finite()),
            "{} diverged",
            m.name()
        );
    }
}

#[test]
fn sparse_methods_send_less_than_baseline() {
    let Some(base) = run_method(Method::Baseline) else {
        eprintln!("skipped: no artifacts/PJRT in this environment");
        return;
    };
    let base = base.ledger.total();
    for m in [Method::SparseGd, Method::Dgc, Method::ScaleCom, Method::Qsgd] {
        let r = run_method(m).unwrap();
        assert!(
            r.ledger.total() < base,
            "{}: {} !< {}",
            m.name(),
            r.ledger.total(),
            base
        );
    }
}

#[test]
fn lgc_compresses_harder_than_dgc_at_steady_state() {
    let Some(dgc) = run_method(Method::Dgc) else {
        eprintln!("skipped: no artifacts/PJRT in this environment");
        return;
    };
    // Force the readiness gate open: the 12-step config cannot train the
    // AE to the production gate, and this test checks *rates*, not
    // reconstruction quality.
    let run_gated = |m: Method| {
        let e = engine().unwrap();
        let mut cfg = tiny_cfg("convnet5", m, 2);
        cfg.ae_gate = f32::INFINITY;
        coordinator::train(&e, cfg).unwrap()
    };
    let ps = run_gated(Method::LgcPs);
    let rar = run_gated(Method::LgcRar);
    // Steady-state (phase 3) rate must beat DGC's for both LGC instances
    // (Table IV/VI's headline ordering).
    assert!(
        ps.compression_ratio() > dgc.compression_ratio(),
        "ps {} !> dgc {}",
        ps.compression_ratio(),
        dgc.compression_ratio()
    );
    assert!(
        rar.compression_ratio() > dgc.compression_ratio(),
        "rar {} !> dgc {}",
        rar.compression_ratio(),
        dgc.compression_ratio()
    );
}

#[test]
fn training_is_deterministic_given_seed() {
    let Some(a) = run_method(Method::LgcPs) else {
        eprintln!("skipped: no artifacts/PJRT in this environment");
        return;
    };
    let b = run_method(Method::LgcPs).unwrap();
    assert_eq!(a.final_eval, b.final_eval);
    assert_eq!(a.ledger.total(), b.ledger.total());
    assert_eq!(a.ledger.iter_bytes, b.ledger.iter_bytes);
    let la: Vec<f32> = a.curve.iter().map(|p| p.train_loss).collect();
    let lb: Vec<f32> = b.curve.iter().map(|p| p.train_loss).collect();
    assert_eq!(la, lb);
}

#[test]
fn training_is_thread_count_invariant() {
    // The tentpole's acceptance bar: ledger totals (and the whole loss
    // curve) are bit-identical between 1-thread and N-thread runs of the
    // same seed, for both a baseline and an LGC method.
    let run_with = |method: Method, threads: usize| {
        let e = engine().unwrap();
        let mut cfg = tiny_cfg("convnet5", method, 4);
        cfg.threads = threads;
        coordinator::train(&e, cfg).unwrap()
    };
    if engine().is_none() {
        eprintln!("skipped: no artifacts/PJRT in this environment");
        return;
    }
    for method in [Method::Dgc, Method::LgcPs] {
        let seq = run_with(method, 1);
        for threads in [2, 4] {
            let par = run_with(method, threads);
            assert_eq!(
                seq.ledger.iter_bytes,
                par.ledger.iter_bytes,
                "{} threads={threads}: per-iteration bytes drifted",
                method.name()
            );
            assert_eq!(seq.ledger.total(), par.ledger.total(), "{}", method.name());
            let ls: Vec<f32> = seq.curve.iter().map(|p| p.train_loss).collect();
            let lp: Vec<f32> = par.curve.iter().map(|p| p.train_loss).collect();
            assert_eq!(ls, lp, "{} threads={threads}: loss curve drifted", method.name());
        }
    }
}

#[test]
fn phases_progress_dense_topk_compressed() {
    let cfg = tiny_cfg("convnet5", Method::LgcPs, 2);
    // The schedule itself is engine-independent.
    assert_eq!(
        coordinator::scheduler::phase_and_alpha(&cfg, 0).0,
        Phase::Dense
    );
    assert_eq!(
        coordinator::scheduler::phase_and_alpha(&cfg, 5).0,
        Phase::TopK
    );
    assert_eq!(
        coordinator::scheduler::phase_and_alpha(&cfg, 9).0,
        Phase::Compressed
    );
    let e = require_engine!();
    let r = coordinator::train(&e, cfg.clone()).unwrap();
    assert_eq!(r.phase_iters, [4, 4, 4]);
    // AE trains during phase 2 (inner steps per iteration) and keeps
    // training through any gated compressed iterations (readiness gate).
    assert!(r.ae_losses.len() >= 4 * cfg.ae_inner_steps);
}

#[test]
fn lgc_rar_counts_one_time_weight_broadcast() {
    let Some(r) = run_method(Method::LgcRar) else {
        eprintln!("skipped: no artifacts/PJRT in this environment");
        return;
    };
    let ae_bytes = r
        .ledger
        .per_kind
        .get(&lgc::metrics::Kind::AeWeights)
        .copied()
        .unwrap_or(0);
    assert!(ae_bytes > 0, "RAR must count the one-time AE weight broadcast");
}

#[test]
fn schedule_ablation_changes_phase_structure() {
    let e = require_engine!();
    let mut cfg = tiny_cfg("convnet5", Method::LgcPs, 2);
    cfg.schedule = SparsifySchedule::Fixed;
    let r = coordinator::train(&e, cfg).unwrap();
    assert_eq!(r.phase_iters[0], 0, "fixed schedule has no dense phase");
}

#[test]
fn segmentation_model_trains() {
    let e = require_engine!();
    let r = coordinator::train(&e, tiny_cfg("segnet_mini", Method::LgcPs, 2)).unwrap();
    assert!(r.final_eval.1 > 0.0);
}

#[test]
fn transformer_trains_with_rar() {
    let e = require_engine!();
    let r = coordinator::train(&e, tiny_cfg("transformer_mini", Method::LgcRar, 4)).unwrap();
    assert!(r.final_eval.0.is_finite());
}
