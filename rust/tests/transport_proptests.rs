//! Property tests on the wire layer: the frame codec and the message
//! grammar must never panic on hostile input — truncation, corrupt
//! length prefixes, unknown type bytes, interleaved partial reads — and
//! must roundtrip every well-formed message byte-exactly.
//!
//! The offline crate set has no proptest, so this uses the in-tree
//! deterministic RNG for randomized case generation with fixed seeds
//! (every failure prints the case seed; re-running with it is exact).

use lgc::config::{Method, OnFault, SparsifySchedule, TrainConfig, TransportKind};
use lgc::transport::{
    frame, BucketUp, Frame, FrameDecoder, LastUp, MidUp, Msg, MAX_FRAME, PROTO_VERSION,
};
use lgc::util::rng::Rng;

const CASES: u64 = 200;

fn random_bytes(rng: &mut Rng, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(256) as u8).collect()
}

/// Random f32 payload from raw bit patterns — NaNs, infinities, -0.0 and
/// subnormals included, since the wire carries raw IEEE bits.
fn random_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| f32::from_bits(rng.next_u64() as u32)).collect()
}

/// Random-length (0..64) raw-bits f32 vector.
fn vecf(rng: &mut Rng) -> Vec<f32> {
    let n = rng.below(64);
    random_f32s(rng, n)
}

/// Random-length (0..max) byte vector.
fn vecb(rng: &mut Rng, max: usize) -> Vec<u8> {
    let n = rng.below(max);
    random_bytes(rng, n)
}

// ---------------------------------------------------------------------------
// Frame codec
// ---------------------------------------------------------------------------

#[test]
fn prop_frames_roundtrip_under_random_chunked_feeds() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xF2A3E + case);
        let frames: Vec<Frame> = (0..1 + rng.below(8))
            .map(|_| {
                let n = rng.below(4096);
                Frame { kind: rng.below(256) as u8, payload: random_bytes(&mut rng, n) }
            })
            .collect();
        let mut wire = Vec::new();
        for f in &frames {
            frame::encode_into(f.kind, &f.payload, &mut wire).unwrap();
        }

        // Feed the byte stream in random-sized chunks, popping eagerly.
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        let mut off = 0;
        while off < wire.len() {
            let n = (1 + rng.below(777)).min(wire.len() - off);
            dec.feed(&wire[off..off + n]);
            off += n;
            while let Some(f) = dec.pop().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, frames, "case {case}");
        assert_eq!(dec.pending(), 0, "case {case}: leftover bytes after all frames popped");
    }
}

#[test]
fn prop_truncated_streams_wait_and_never_panic() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x7256 + case);
        let n = 1 + rng.below(512);
        let payload = random_bytes(&mut rng, n);
        let mut wire = Vec::new();
        frame::encode_into(7, &payload, &mut wire).unwrap();
        // Every strict prefix is an incomplete frame: pop must report
        // "not yet" (Ok(None)), never a frame and never a panic.
        let cut = rng.below(wire.len());
        let mut dec = FrameDecoder::new();
        dec.feed(&wire[..cut]);
        assert!(dec.pop().unwrap().is_none(), "case {case}: frame from a {cut}-byte prefix");
        // Completing the stream later yields the frame intact.
        dec.feed(&wire[cut..]);
        let f = dec.pop().unwrap().expect("completed frame");
        assert_eq!(f.payload, payload, "case {case}");
    }
}

#[test]
fn prop_corrupt_length_prefixes_error_cleanly() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xC0221 + case);
        let mut wire = Vec::new();
        frame::encode_into(3, &random_bytes(&mut rng, 32), &mut wire).unwrap();
        // Zero-length and over-MAX_FRAME prefixes are both invalid: a
        // frame's length counts the type byte, so it is always >= 1.
        let bad: u32 = if case % 2 == 0 {
            0
        } else {
            MAX_FRAME + 1 + rng.below(1 << 20) as u32
        };
        wire[..4].copy_from_slice(&bad.to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.feed(&wire);
        assert!(dec.pop().is_err(), "case {case}: accepted length prefix {bad}");
    }
}

#[test]
fn prop_garbage_streams_never_panic() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x6A2BA6E + case);
        let mut dec = FrameDecoder::new();
        let n = rng.below(2048);
        let garbage = random_bytes(&mut rng, n);
        dec.feed(&garbage);
        // Drain until the decoder errors or runs dry; anything but a
        // panic or an infinite loop is acceptable on garbage.
        for _ in 0..garbage.len() + 1 {
            match dec.pop() {
                Ok(Some(_)) => continue,
                Ok(None) | Err(_) => break,
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Message grammar
// ---------------------------------------------------------------------------

fn random_mid(rng: &mut Rng) -> MidUp {
    match rng.below(6) {
        0 => MidUp::Dense(vecf(rng)),
        1 => MidUp::Sparse { coded_idx: vecb(rng, 64), vals: vecf(rng) },
        2 => MidUp::Vv(vecf(rng)),
        3 => MidUp::Innovation {
            coded_idx: vecb(rng, 64),
            vals: vecf(rng),
            scale: f32::from_bits(rng.next_u64() as u32),
        },
        4 => MidUp::Buckets(1 + rng.next_u64() as u32 % 32),
        _ => MidUp::None,
    }
}

fn random_msg(rng: &mut Rng) -> Msg {
    match rng.below(16) {
        0 => Msg::Join {
            proto: rng.next_u64() as u16,
            session: rng.next_u64(),
            pid: rng.next_u64(),
        },
        1 => Msg::JoinAck {
            node: rng.next_u64() as u32,
            nodes: rng.next_u64() as u32,
            platform: format!("plat-{}", rng.below(100)),
            cfg: random_cfg(rng),
        },
        2 => Msg::IterPlan {
            iter: rng.next_u64() as u32,
            engaged: rng.below(2) == 0,
            weights_follow: rng.below(2) == 0,
        },
        3 => Msg::Support { iter: rng.next_u64() as u32, coded: vecb(rng, 256) },
        4 => Msg::SupportBcast { iter: rng.next_u64() as u32, coded: vecb(rng, 256) },
        5 => Msg::Gradient {
            iter: rng.next_u64() as u32,
            loss: f32::from_bits(rng.next_u64() as u32),
            acc: f32::from_bits(rng.next_u64() as u32),
            first: vecf(rng),
            mid: random_mid(rng),
            last: if rng.below(2) == 0 {
                LastUp::Dense(vecf(rng))
            } else {
                LastUp::Sparse { coded_idx: vecb(rng, 64), vals: vecf(rng) }
            },
            ctrl_mid: if rng.below(2) == 0 {
                Some(vecf(rng))
            } else {
                None
            },
        },
        6 => Msg::Latent {
            iter: rng.next_u64() as u32,
            latent: vecf(rng),
            scale: f32::from_bits(rng.next_u64() as u32),
        },
        7 => Msg::SyncInfo {
            iter: rng.next_u64() as u32,
            first: vecf(rng),
            mid: vecf(rng),
            last: vecf(rng),
        },
        8 => Msg::Model { iter: rng.next_u64() as u32, payload: vecb(rng, 256) },
        9 => Msg::Heartbeat,
        10 => Msg::Shutdown { reason: format!("reason {}", rng.below(1000)) },
        11 => Msg::GradientBucket {
            iter: rng.next_u64() as u32,
            bucket: rng.next_u64() as u32,
            up: if rng.below(2) == 0 {
                BucketUp::Dense(vecf(rng))
            } else {
                BucketUp::Sparse { coded_idx: vecb(rng, 64), vals: vecf(rng) }
            },
        },
        12 => Msg::Rejoin {
            proto: rng.next_u64() as u16,
            session: rng.next_u64(),
            node: rng.next_u64() as u32,
            token: rng.next_u64(),
        },
        13 => Msg::RejoinAck {
            node: rng.next_u64() as u32,
            nodes: rng.next_u64() as u32,
            platform: format!("plat-{}", rng.below(100)),
            cfg: random_cfg(rng),
            iter: rng.next_u64() as u32,
            model: vecb(rng, 256),
            state: vecb(rng, 256),
            encoder: if rng.below(2) == 0 {
                Some(vecb(rng, 256))
            } else {
                None
            },
        },
        14 => Msg::StateSync { iter: rng.next_u64() as u32, blob: vecb(rng, 256) },
        _ => Msg::Error { msg: format!("error {}", rng.below(1000)) },
    }
}

fn random_cfg(rng: &mut Rng) -> TrainConfig {
    let methods = Method::all();
    TrainConfig {
        model: format!("model_{}", rng.below(50)),
        method: methods[rng.below(methods.len())],
        nodes: rng.below(64),
        steps: rng.below(100_000),
        lr: rng.uniform(),
        momentum: rng.uniform(),
        alpha: rng.uniform() as f64,
        warmup_iters: rng.below(1000),
        ae_train_iters: rng.below(1000),
        seed: rng.next_u64(),
        fp16_values: rng.below(2) == 0,
        verbose: rng.below(2) == 0,
        schedule: match rng.below(3) {
            0 => SparsifySchedule::Warmup,
            1 => SparsifySchedule::Fixed,
            _ => SparsifySchedule::Exponential,
        },
        straggler_spec: (0..rng.below(4))
            .map(|_| (rng.below(8), rng.uniform() as f64 * 4.0))
            .collect(),
        buckets: 1 + rng.below(32),
        bucket_bytes: rng.below(1 << 20),
        overlap: rng.below(2) == 0,
        heartbeat_ms: rng.next_u64() >> 8,
        miss_budget: rng.next_u64() as u32,
        on_fault: match rng.below(3) {
            0 => OnFault::Fail,
            1 => OnFault::Continue,
            _ => OnFault::WaitRejoin,
        },
        ..Default::default()
    }
}

#[test]
fn prop_every_message_roundtrips_byte_exactly() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x536 + case);
        let msg = random_msg(&mut rng);
        let (kind, payload) = msg.encode();
        let back = Msg::decode(kind, &payload).unwrap_or_else(|e| {
            panic!("case {case}: decode of {} failed: {e}", msg.name());
        });
        // Compare re-encoded bytes, not values: raw-bit f32 transport
        // means NaN payloads roundtrip even though NaN != NaN.
        let (kind2, payload2) = back.encode();
        assert_eq!((kind, &payload), (kind2, &payload2), "case {case}: {}", msg.name());
    }
}

#[test]
fn prop_cfg_blob_roundtrips_through_join_ack() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xCF6 + case);
        let mut cfg = random_cfg(&mut rng);
        cfg.transport = TransportKind::Tcp;
        cfg.checkpoint = Some("never-forwarded.ckpt".into());
        cfg.faults = Some("iter=1:crash".into());
        cfg.resume = Some("never-forwarded.ckpt".into());
        cfg.ckpt_every = 1 + rng.below(100);
        cfg.heartbeat_ms = rng.next_u64() >> 8;
        cfg.miss_budget = rng.next_u64() as u32;
        let msg =
            Msg::JoinAck { node: 1, nodes: 4, platform: "native".into(), cfg: cfg.clone() };
        let (kind, payload) = msg.encode();
        let Msg::JoinAck { cfg: back, .. } = Msg::decode(kind, &payload).unwrap() else {
            panic!("case {case}: wrong variant");
        };
        // The decoder forces Sim and drops checkpoint/faults/resume so a
        // worker can never recursively self-spawn, re-inject the plan, or
        // write over the coordinator's files; everything else (the
        // heartbeat/on-fault fields included) must survive exactly.
        cfg.transport = TransportKind::Sim;
        cfg.checkpoint = None;
        cfg.faults = None;
        cfg.resume = None;
        cfg.ckpt_every = 0;
        assert_eq!(back, cfg, "case {case}");
    }
}

#[test]
fn prop_unknown_message_type_bytes_error_cleanly() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x1214 + case);
        // Valid kinds are 1..=16; 0 and 17..=255 must be clean errors.
        let kind = if case % 2 == 0 {
            0
        } else {
            17 + rng.below(239) as u8
        };
        let n = rng.below(128);
        let payload = random_bytes(&mut rng, n);
        assert!(Msg::decode(kind, &payload).is_err(), "case {case}: accepted kind {kind}");
    }
}

#[test]
fn prop_truncated_payloads_error_and_never_panic() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x7214CA7E + case);
        let (kind, payload) = random_msg(&mut rng).encode();
        if payload.is_empty() {
            continue; // Heartbeat: no strict prefix exists.
        }
        let cut = rng.below(payload.len());
        // A strict prefix can never decode: every field is length- or
        // count-prefixed and the grammar rejects short *and* trailing
        // bytes, so truncation is always a clean error.
        assert!(Msg::decode(kind, &payload[..cut]).is_err(), "case {case}: kind {kind} cut {cut}");
    }
}

#[test]
fn prop_mutated_payloads_never_panic() {
    for case in 0..CASES {
        let mut rng = Rng::new(0xB17F11 + case);
        let (kind, mut payload) = random_msg(&mut rng).encode();
        if payload.is_empty() {
            continue;
        }
        // Flip a handful of bytes anywhere (length prefixes included):
        // decode may succeed or error, but must never panic or OOM.
        for _ in 0..1 + rng.below(4) {
            let at = rng.below(payload.len());
            payload[at] = rng.below(256) as u8;
        }
        let _ = Msg::decode(kind, &payload);
    }
}

#[test]
fn prop_interleaved_partial_reads_preserve_message_order() {
    for case in 0..CASES {
        let mut rng = Rng::new(0x1272 + case);
        let msgs: Vec<Msg> = (0..2 + rng.below(6)).map(|_| random_msg(&mut rng)).collect();
        let mut wire = Vec::new();
        for m in &msgs {
            let (kind, payload) = m.encode();
            frame::encode_into(kind, &payload, &mut wire).unwrap();
        }
        // One-byte drip feed: the decoder must reassemble every frame
        // and the grammar must yield the same messages in order.
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        for b in &wire {
            dec.feed(std::slice::from_ref(b));
            while let Some(f) = dec.pop().unwrap() {
                got.push(Msg::decode(f.kind, &f.payload).unwrap());
            }
        }
        assert_eq!(got.len(), msgs.len(), "case {case}");
        for (g, m) in got.iter().zip(&msgs) {
            assert_eq!(g.encode(), m.encode(), "case {case}");
        }
    }
}

#[test]
fn proto_version_is_pinned() {
    // The join handshake rejects other versions; this test pins the
    // constant so bumping it is a conscious, reviewed change.  v2 added
    // bucketed streaming: kind 13 (GradientBucket), the MidUp::Buckets
    // closing tag, and the buckets/bucket-bytes/overlap cfg fields.
    // v3 added elastic fault tolerance: the Join pid, kinds 14..=16
    // (Rejoin / RejoinAck / StateSync), and the heartbeat-ms /
    // miss-budget / on-fault cfg fields.
    assert_eq!(PROTO_VERSION, 3);
}
