//! End-to-end contract of the real wire transport (DESIGN.md §12):
//!
//! * **Bit-identity** — `--transport tcp` with K real worker *processes*
//!   on loopback produces byte-identical ledgers, loss curves, eval
//!   traces, AE losses, net reports, and checkpoint files to the
//!   single-process simulator with the same config, for Baseline,
//!   SparseGd, LgcPs, and LgcRar (TCP and Unix-domain sockets).
//! * **Fault injection** — killing a worker mid-run surfaces as a
//!   descriptive coordinator error within the configured timeout (never
//!   a hang); extra joiners are refused with "session full" while the
//!   run is live; workers retry with backoff when the coordinator is
//!   slow to bind.
//!
//! Worker processes are spawned from this package's own `lgc` binary
//! (`CARGO_BIN_EXE_lgc`), on the native backend, so the whole suite runs
//! from a clean checkout with no artifacts.

use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use lgc::config::{Method, TrainConfig};
use lgc::coordinator::{self, remote, TrainResult};
use lgc::runtime::Engine;
use lgc::transport::{BucketUp, Conn, Msg, PROTO_VERSION};

const LGC_BIN: &str = env!("CARGO_BIN_EXE_lgc");

fn engine() -> Engine {
    Engine::native().expect("native engine always constructs")
}

/// A small three-phase run that reaches the compressed phase *engaged*:
/// `ae_gate = +inf` latches readiness as soon as the 8-loss window
/// fills, which 8 phase-2 iterations guarantee.
fn cfg(model: &str, method: Method, nodes: usize) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        method,
        nodes,
        steps: 24,
        warmup_iters: 6,
        ae_train_iters: 8,
        eval_every: 6,
        eval_batches: 2,
        ae_gate: f32::INFINITY,
        ..Default::default()
    }
}

fn tmp_path(tag: &str) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("lgc-e2e-{}-{tag}", std::process::id()));
    p.to_string_lossy().into_owned()
}

/// Run the same config through the simulator and through K real worker
/// processes, and assert every observable output is bit-identical.
fn assert_tcp_matches_sim(model: &str, method: Method, nodes: usize, listen: &str, session: u64) {
    assert_tcp_matches_sim_with(model, method, nodes, listen, session, |_| {});
}

/// [`assert_tcp_matches_sim`] with a config tweak applied to both runs
/// (bucketing flags, thread counts, ...).
fn assert_tcp_matches_sim_with(
    model: &str,
    method: Method,
    nodes: usize,
    listen: &str,
    session: u64,
    tweak: impl Fn(&mut TrainConfig),
) {
    let e = engine();
    let tag = format!("{}-{}", method.name(), session);
    let ckpt_sim = tmp_path(&format!("{tag}-sim.ckpt"));
    let ckpt_tcp = tmp_path(&format!("{tag}-tcp.ckpt"));

    let mut cfg_sim = cfg(model, method, nodes);
    cfg_sim.checkpoint = Some(ckpt_sim.clone());
    tweak(&mut cfg_sim);
    let sim = coordinator::train(&e, cfg_sim).expect("sim run");

    let mut cfg_tcp = cfg(model, method, nodes);
    cfg_tcp.checkpoint = Some(ckpt_tcp.clone());
    tweak(&mut cfg_tcp);
    let mut opts = remote::RemoteOpts::local(session);
    opts.listen = listen.into();
    opts.worker_bin = Some(LGC_BIN.into());
    let tcp = remote::train_with_opts(&e, cfg_tcp, &opts).expect("tcp run");

    assert_bit_identical(&sim, &tcp);
    let sim_bytes = std::fs::read(&ckpt_sim).expect("sim checkpoint written");
    let tcp_bytes = std::fs::read(&ckpt_tcp).expect("tcp checkpoint written");
    assert_eq!(sim_bytes, tcp_bytes, "{tag}: checkpoint files differ");
    let _ = std::fs::remove_file(&ckpt_sim);
    let _ = std::fs::remove_file(&ckpt_tcp);
}

fn assert_bit_identical(sim: &TrainResult, tcp: &TrainResult) {
    assert_eq!(sim.curve.len(), tcp.curve.len(), "curve lengths");
    for (a, b) in sim.curve.iter().zip(&tcp.curve) {
        assert_eq!(a.iter, b.iter);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "loss at iter {}", a.iter);
        assert_eq!(a.train_acc.to_bits(), b.train_acc.to_bits(), "acc at iter {}", a.iter);
    }
    assert_eq!(sim.evals.len(), tcp.evals.len(), "eval counts");
    for ((i1, l1, a1), (i2, l2, a2)) in sim.evals.iter().zip(&tcp.evals) {
        assert_eq!(i1, i2);
        assert_eq!(l1.to_bits(), l2.to_bits(), "eval loss at iter {i1}");
        assert_eq!(a1.to_bits(), a2.to_bits(), "eval acc at iter {i1}");
    }
    assert_eq!(sim.final_eval.0.to_bits(), tcp.final_eval.0.to_bits(), "final eval loss");
    assert_eq!(sim.final_eval.1.to_bits(), tcp.final_eval.1.to_bits(), "final eval acc");
    assert_eq!(sim.phase_iters, tcp.phase_iters, "phase iteration counts");
    assert_eq!(sim.ledger, tcp.ledger, "byte ledgers");
    assert_eq!(sim.net, tcp.net, "net fabric reports");
    assert_eq!(sim.ae_losses.len(), tcp.ae_losses.len(), "AE loss trace lengths");
    for (i, ((r1, s1), (r2, s2))) in sim.ae_losses.iter().zip(&tcp.ae_losses).enumerate() {
        assert_eq!(r1.to_bits(), r2.to_bits(), "AE rec loss {i}");
        assert_eq!(s1.to_bits(), s2.to_bits(), "AE sim loss {i}");
    }
    assert_eq!(sim.dense_bytes_per_node, tcp.dense_bytes_per_node);
}

// ---------------------------------------------------------------------------
// Bit-identity, 4 worker processes on loopback
// ---------------------------------------------------------------------------

#[test]
fn tcp_baseline_bit_identical_to_sim() {
    assert_tcp_matches_sim("convnet_mini", Method::Baseline, 4, "127.0.0.1:0", 0xE2E1);
}

#[test]
fn tcp_sparse_gd_bit_identical_to_sim() {
    assert_tcp_matches_sim("mlp_mini", Method::SparseGd, 4, "127.0.0.1:0", 0xE2E2);
}

#[test]
fn tcp_lgc_ps_bit_identical_to_sim() {
    assert_tcp_matches_sim("convnet_mini", Method::LgcPs, 4, "127.0.0.1:0", 0xE2E3);
}

#[test]
fn tcp_lgc_rar_bit_identical_to_sim() {
    assert_tcp_matches_sim("mlp_mini", Method::LgcRar, 4, "127.0.0.1:0", 0xE2E4);
}

#[test]
fn uds_run_bit_identical_to_sim() {
    // Same code path over a Unix-domain socket address.
    let sock = tmp_path("uds.sock");
    let _ = std::fs::remove_file(&sock);
    assert_tcp_matches_sim("mlp_mini", Method::LgcPs, 2, &format!("unix:{sock}"), 0xE2E5);
}

/// DESIGN.md §13.4: with `--buckets 8 --no-overlap` the wire carries the
/// legacy whole-group frames and must stay bit-identical to the sim —
/// which in turn is bit-identical to the unbucketed run (native_e2e).
#[test]
fn tcp_bucketed_no_overlap_bit_identical_to_sim() {
    assert_tcp_matches_sim_with("mlp_mini", Method::SparseGd, 4, "127.0.0.1:0", 0xE2E7, |c| {
        c.buckets = 8;
        c.overlap = false;
    });
}

/// Overlapped mode streams one `GradientBucket` frame per bucket; the
/// coordinator's replay mirrors the sim's per-bucket accounting exactly,
/// so even here every observable — curves, ledgers, bucket-tagged net
/// trace, checkpoint bytes — matches the simulator bit-for-bit.
#[test]
fn tcp_overlapped_buckets_bit_identical_to_sim() {
    assert_tcp_matches_sim_with("convnet_mini", Method::Baseline, 4, "127.0.0.1:0", 0xE2E8, |c| {
        c.buckets = 8;
    });
    assert_tcp_matches_sim_with("mlp_mini", Method::Dgc, 2, "127.0.0.1:0", 0xE2E9, |c| {
        c.buckets = 4;
    });
}

/// `--index-codec auto` prices every sparse upload per layer on the
/// worker side; the coordinator-side sim replay must pick the same codec
/// from the same bytes, so ledgers, curves, and checkpoints stay
/// bit-identical across the wire (DESIGN.md §16.2).  Golomb forced
/// everywhere is the other interesting wire shape (a codec the legacy
/// decoder never produced).
#[test]
fn tcp_index_codec_auto_and_golomb_bit_identical_to_sim() {
    use lgc::compress::index_coding::IndexCodec;
    assert_tcp_matches_sim_with("convnet_mini", Method::LgcPs, 4, "127.0.0.1:0", 0xE2EA, |c| {
        c.index_codec = IndexCodec::Auto;
    });
    assert_tcp_matches_sim_with("mlp_mini", Method::SparseGd, 2, "127.0.0.1:0", 0xE2EB, |c| {
        c.index_codec = IndexCodec::Golomb;
        c.fp16_values = true;
    });
}

#[test]
fn unsupported_methods_error_loudly() {
    let e = engine();
    for m in [Method::ScaleCom, Method::Qsgd] {
        let mut opts = remote::RemoteOpts::local(0xE2E6);
        opts.worker_bin = Some(LGC_BIN.into());
        let err = remote::train_with_opts(&e, cfg("mlp_mini", m, 2), &opts)
            .expect_err("gated method must not run");
        let msg = format!("{err:#}");
        assert!(msg.contains("--transport tcp does not support"), "got: {msg}");
        assert!(msg.contains("--transport sim"), "got: {msg}");
    }
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

fn spawn_external_worker(addr: &str, session: u64) -> Child {
    Command::new(LGC_BIN)
        .arg("worker")
        .arg("--connect")
        .arg(addr)
        .arg("--session")
        .arg(session.to_string())
        .arg("--retries")
        .arg("80")
        .arg("--backoff-ms")
        .arg("25")
        .arg("--net-timeout-ms")
        .arg("60000")
        .env("LGC_BACKEND", "native")
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn external worker")
}

fn join_within<T>(h: std::thread::JoinHandle<T>, secs: u64, what: &str) -> T {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !h.is_finished() {
        assert!(Instant::now() < deadline, "{what}: coordinator hung past the deadline");
        std::thread::sleep(Duration::from_millis(50));
    }
    h.join().expect("coordinator thread panicked")
}

/// Killing one worker mid-run must produce a descriptive coordinator
/// error within the configured net timeout — never a hang.  While the
/// run is live, a late joiner must be refused with "session full".
#[test]
fn killed_worker_errors_within_timeout_and_late_joins_are_refused() {
    let sock = tmp_path("kill.sock");
    let _ = std::fs::remove_file(&sock);
    let addr = format!("unix:{sock}");
    let session = 0xFA11u64;
    let nodes = 4;

    let coord_addr = addr.clone();
    let coord = std::thread::spawn(move || {
        let e = engine();
        // Far more steps than will ever run: the kill must end the run.
        let mut c = cfg("mlp_mini", Method::Baseline, nodes);
        c.steps = 1_000_000;
        c.eval_every = 0;
        let mut opts = remote::RemoteOpts::local(session);
        opts.listen = coord_addr;
        opts.spawn_workers = false;
        opts.net_timeout = Duration::from_secs(10);
        remote::train_with_opts(&e, c, &opts)
    });

    let mut workers: Vec<Child> =
        (0..nodes).map(|_| spawn_external_worker(&addr, session)).collect();
    // Let the session form fully (all K joins) and the training loop
    // spin for a moment; a probe that lands during the join phase would
    // consume a node slot instead of hitting the rejector.
    std::thread::sleep(Duration::from_secs(5));

    // Probe: a fifth joiner on a live session is refused, descriptively.
    let mut probe = Conn::connect(&addr).expect("probe connect");
    probe.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    probe.send(&Msg::Join { proto: PROTO_VERSION, session, pid: 0 }).unwrap();
    let refusal = probe.recv().expect_err("late join must be refused").to_string();
    assert!(refusal.contains("session full"), "got: {refusal}");

    // Kill one worker mid-iteration.
    workers[1].kill().expect("kill worker");
    let _ = workers[1].wait();

    let err = join_within(coord, 60, "kill test").expect_err("run must fail after the kill");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("disconnected") || msg.contains("timed out"),
        "error must name the fault, got: {msg}"
    );
    for w in &mut workers {
        let _ = w.kill();
        let _ = w.wait();
    }
}

/// A frame claiming a bucket id outside the session's plan must be
/// answered with a descriptive `Error` frame and fail the run cleanly —
/// never an index panic or a hang (the ISSUE-7 wire-validation bar).
#[test]
fn out_of_plan_bucket_id_is_refused_with_a_descriptive_error() {
    let sock = tmp_path("badbucket.sock");
    let _ = std::fs::remove_file(&sock);
    let addr = format!("unix:{sock}");
    let session = 0xBADBu64;
    let nodes = 2;

    let coord_addr = addr.clone();
    let coord = std::thread::spawn(move || {
        let e = engine();
        let mut c = cfg("mlp_mini", Method::SparseGd, nodes);
        c.buckets = 4;
        c.steps = 1_000_000; // the rejection must end the run, not step count
        c.eval_every = 0;
        let mut opts = remote::RemoteOpts::local(session);
        opts.listen = coord_addr;
        opts.spawn_workers = false;
        opts.net_timeout = Duration::from_secs(10);
        remote::train_with_opts(&e, c, &opts)
    });

    // One honest worker process; the other node is this hand-rolled
    // client, which joins properly and then lies about its bucket id.
    let mut honest = spawn_external_worker(&addr, session);
    let deadline = Instant::now() + Duration::from_secs(20);
    let mut conn = loop {
        match Conn::connect(&addr) {
            Ok(c) => break c,
            Err(e) if Instant::now() > deadline => panic!("connect: {e}"),
            Err(_) => std::thread::sleep(Duration::from_millis(50)),
        }
    };
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    conn.send(&Msg::Join { proto: PROTO_VERSION, session, pid: 0 }).unwrap();
    let iter = loop {
        match conn.recv().expect("handshake before the hostile frame") {
            Msg::IterPlan { iter, .. } => break iter,
            _ => continue, // JoinAck, weight broadcasts, ...
        }
    };
    conn.send(&Msg::GradientBucket {
        iter,
        bucket: 999,
        up: BucketUp::Sparse { coded_idx: Vec::new(), vals: Vec::new() },
    })
    .unwrap();

    let refusal =
        conn.recv().expect_err("out-of-plan bucket id must be refused").to_string();
    assert!(refusal.contains("out of plan bounds"), "got: {refusal}");
    let err = join_within(coord, 60, "bad bucket").expect_err("run must fail after rejection");
    let msg = format!("{err:#}");
    assert!(msg.contains("out of plan bounds"), "coordinator error must name it, got: {msg}");
    let _ = honest.kill();
    let _ = honest.wait();
}

// ---------------------------------------------------------------------------
// Elastic fault tolerance (DESIGN.md §14)
// ---------------------------------------------------------------------------

/// Run one self-spawned tcp session with fault-tolerance knobs applied.
fn run_tcp(mut cfg: TrainConfig, session: u64) -> Result<TrainResult, anyhow::Error> {
    let e = engine();
    cfg.transport = lgc::config::TransportKind::Tcp;
    let mut opts = remote::RemoteOpts::local(session);
    opts.worker_bin = Some(LGC_BIN.into());
    remote::train_with_opts(&e, cfg, &opts)
}

/// `--on-fault continue`: killing one of 4 workers mid-run must not end
/// the run.  The survivor continuation is *bit-identical* to the
/// simulator executing the same fault plan (masked aggregation on both
/// sides), the kill is logged, and the final loss stays within tolerance
/// of the fault-free run (ISSUE-8 acceptance bar).
#[test]
fn continue_kill_survives_and_matches_faulted_sim() {
    let session = 0xFA57u64;
    let mut c = cfg("mlp_mini", Method::SparseGd, 4);
    c.on_fault = lgc::config::OnFault::Continue;
    c.faults = Some("iter=8:kill=2".into());
    c.heartbeat_ms = 100; // exercise the pump + heartbeat-skip path too
    c.eval_every = 0;

    let e = engine();
    let sim = coordinator::train(&e, c.clone()).expect("faulted sim run");
    assert_eq!(sim.fault_events.len(), 1, "sim records the kill");
    let tcp = run_tcp(c.clone(), session).expect("faulted tcp run survives the kill");
    assert_bit_identical(&sim, &tcp);
    assert_eq!(tcp.fault_events.len(), 1, "tcp records the kill");
    let ev = &tcp.fault_events[0];
    assert_eq!((ev.iter, ev.node, ev.kind.as_str()), (8, Some(2), "kill"));
    assert!(ev.detail.contains("3 survivors"), "got: {}", ev.detail);

    // Tolerance vs the fault-free twin: still converging, close by.
    let mut free_cfg = c;
    free_cfg.faults = None;
    let free = coordinator::train(&e, free_cfg).expect("fault-free run");
    let (first, faulted, fault_free) = (
        tcp.curve.first().unwrap().train_loss,
        tcp.final_train_loss(),
        free.final_train_loss(),
    );
    assert!(faulted.is_finite() && faulted < first, "faulted run must still improve");
    assert!(
        (faulted - fault_free).abs() < 1.0,
        "faulted final loss {faulted} vs fault-free {fault_free}"
    );
}

/// `--on-fault wait-rejoin`: a worker killed by the plan is respawned,
/// re-admitted through the token handshake, and resynced bit-exactly —
/// the whole run (ledger byte counts included, from the rejoin iteration
/// onward and everywhere else) matches the fault-free sim run.  Kills in
/// the dense phase and in the engaged compressed phase (where the
/// RejoinAck must also carry the AE encoder) are both exercised.
#[test]
fn wait_rejoin_is_bit_identical_to_fault_free() {
    let session = 0x12E1u64;
    let base = cfg("convnet_mini", Method::LgcPs, 4);

    let e = engine();
    let free = coordinator::train(&e, base.clone()).expect("fault-free sim run");

    let mut c = base;
    c.on_fault = lgc::config::OnFault::WaitRejoin;
    c.faults = Some("iter=2:kill=1;iter=20:kill=1".into());
    let tcp = run_tcp(c, session).expect("wait-rejoin tcp run");
    assert_bit_identical(&free, &tcp);
    let kinds: Vec<&str> = tcp.fault_events.iter().map(|ev| ev.kind.as_str()).collect();
    assert_eq!(kinds, ["kill", "rejoin", "kill", "rejoin"], "events: {:?}", tcp.fault_events);
    assert!(
        tcp.fault_events[3].detail.contains("AE encoder"),
        "the engaged-phase rejoin must resync the encoder, got: {}",
        tcp.fault_events[3].detail
    );
}

/// A `--faults`-driven chaos run mixing every process-level fault:
/// stall (SIGSTOP window, priced), corrupt-frame (the armed frame kills
/// the worker's decoder; `continue` absorbs the death), and a planned
/// kill.  Two of four workers survive and the run completes, improving.
#[test]
fn chaos_plan_with_stall_corrupt_and_kill_completes() {
    let session = 0xC405u64;
    let mut c = cfg("mlp_mini", Method::Baseline, 4);
    c.on_fault = lgc::config::OnFault::Continue;
    c.faults = Some("iter=6:stall=1:50ms;iter=10:corrupt-frame=3;iter=14:kill=2".into());
    c.heartbeat_ms = 100;
    c.eval_every = 0;
    let r = run_tcp(c, session).expect("chaos run completes on the survivors");
    let kinds: Vec<&str> = r.fault_events.iter().map(|ev| ev.kind.as_str()).collect();
    assert_eq!(
        kinds,
        ["stall", "corrupt-frame", "death", "kill"],
        "events: {:?}",
        r.fault_events
    );
    let first = r.curve.first().unwrap().train_loss;
    let last = r.final_train_loss();
    assert!(last.is_finite() && last < first, "chaos run must still improve: {first} -> {last}");
}

/// `--faults` kill/stall entries are refused when the workers are not
/// this coordinator's own children (`lgc serve`) — it cannot signal them.
#[test]
fn process_faults_require_self_spawned_workers() {
    let e = engine();
    let mut c = cfg("mlp_mini", Method::Baseline, 2);
    c.faults = Some("iter=1:kill=0".into());
    c.on_fault = lgc::config::OnFault::Continue;
    let mut opts = remote::RemoteOpts::local(0x5E12);
    opts.spawn_workers = false;
    let err = remote::train_with_opts(&e, c, &opts).expect_err("serve + kill faults");
    let msg = format!("{err:#}");
    assert!(msg.contains("self-spawned workers"), "got: {msg}");
}

/// Workers launched before the coordinator binds must connect anyway:
/// `connect_with_retry` backs off exponentially until the listener
/// appears, and the run then completes normally.
#[test]
fn workers_retry_until_coordinator_binds() {
    let sock = tmp_path("retry.sock");
    let _ = std::fs::remove_file(&sock);
    let addr = format!("unix:{sock}");
    let session = 0xB0FFu64;
    let nodes = 2;

    let mut workers: Vec<Child> =
        (0..nodes).map(|_| spawn_external_worker(&addr, session)).collect();
    // Make the workers wait: the coordinator is deliberately late.
    std::thread::sleep(Duration::from_millis(500));

    let e = engine();
    let mut c = cfg("mlp_mini", Method::Baseline, nodes);
    c.steps = 6;
    c.eval_every = 0;
    let mut opts = remote::RemoteOpts::local(session);
    opts.listen = addr;
    opts.spawn_workers = false;
    let r = remote::train_with_opts(&e, c, &opts).expect("late-bound run completes");
    assert_eq!(r.curve.len(), 6);

    // The shutdown broadcast lets the workers exit on their own.
    let deadline = Instant::now() + Duration::from_secs(20);
    for w in &mut workers {
        loop {
            match w.try_wait().expect("try_wait") {
                Some(status) => {
                    assert!(status.success(), "worker exited with {status}");
                    break;
                }
                None if Instant::now() > deadline => {
                    let _ = w.kill();
                    panic!("worker did not exit after shutdown broadcast");
                }
                None => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }
}
