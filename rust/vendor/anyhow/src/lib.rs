//! Offline stand-in for the `anyhow` crate (vendored; DESIGN.md §7).
//!
//! The build environment has no network access and no crates.io mirror, so
//! this implements exactly the subset the `lgc` workspace uses with the
//! same API shape: [`Error`], [`Result`], the [`Context`] trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.  Swapping in the real crate is
//! a one-line change in `Cargo.toml`; no call site would notice.

use std::error::Error as StdError;
use std::fmt;

/// A dynamically-typed error with a human-readable context chain.
///
/// Like the real `anyhow::Error`, this deliberately does NOT implement
/// `std::error::Error` itself, which is what allows the blanket
/// `From<E: std::error::Error>` conversion to exist.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Attach a higher-level context message, pushing `self` down the
    /// cause chain.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(Chained(self))) }
    }

    /// Iterate the cause chain, outermost first (the top message is not
    /// itself an element; this mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: self.source.as_deref().map(shrink_dyn) }
    }
}

/// Drop the auto-trait bounds from a cause reference (plain coercion).
fn shrink_dyn(e: &(dyn StdError + Send + Sync + 'static)) -> &(dyn StdError + 'static) {
    e
}

/// Internal adapter so an [`Error`] can sit inside a `dyn std::error::Error`
/// cause chain.
struct Chained(Error);

impl fmt::Display for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)
    }
}

impl fmt::Debug for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.0)
    }
}

impl StdError for Chained {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.0.source.as_deref().map(shrink_dyn)
    }
}

/// Iterator over an error's cause chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for cause in self.chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<String> = self.chain().map(|c| c.to_string()).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn from_std_error_and_context() {
        let e: Error = io_err().into();
        assert_eq!(e.to_string(), "missing");
        let e = e.context("opening manifest");
        assert_eq!(e.to_string(), "opening manifest");
        assert_eq!(format!("{e:#}"), "opening manifest: missing");
        assert_eq!(e.chain().count(), 1);
    }

    #[test]
    fn result_and_option_context() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = Context::context(r, "ctx").unwrap_err();
        assert_eq!(e.to_string(), "ctx");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
