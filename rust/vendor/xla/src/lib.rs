//! Offline API-compatible stub of the `xla` (xla_extension) bindings
//! (vendored; DESIGN.md §7).
//!
//! The build environment ships neither the xla_extension shared library
//! nor a crates.io mirror, so this crate provides the exact API surface
//! `lgc::runtime` compiles against:
//!
//! * [`Literal`] is fully functional host-side (shape + untyped bytes +
//!   tuples) — the `Tensor` marshaling layer and its tests work for real.
//! * [`PjRtClient`] constructs, but [`PjRtClient::compile`] and
//!   [`PjRtLoadedExecutable::execute`] return a clear "PJRT backend
//!   unavailable" error.  Everything engine-driven (HLO grad steps, AE
//!   encode/decode) therefore fails fast at the call site with an
//!   actionable message, while the pure-Rust 95% of the framework —
//!   compression, ledgers, ring protocol, schedulers, parallel runtime —
//!   builds and tests offline.
//!
//! When a real PJRT toolchain is present, point `Cargo.toml` at the real
//! `xla` crate (pinned 0.5.1 wiring per /opt/xla-example/load_hlo); no
//! call site changes.
//!
//! All types here are plain host data (no raw pointers), so they are
//! `Send + Sync` — which is what lets the coordinator's parallel node
//! runtime share one `Engine` across worker threads.

use std::fmt;
use std::path::Path;

/// Stub error type (the real crate's `xla::Error` equivalent).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const NO_PJRT: &str = "PJRT backend unavailable: this build uses the offline xla stub \
                       (vendor/xla). Install xla_extension and point Cargo.toml at the \
                       real `xla` crate to execute HLO modules.";

/// XLA element types (subset + padding variants so `match` arms on
/// concrete types keep a reachable wildcard).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    /// Bytes per element (0 for sub-byte/predicate types in this stub).
    pub fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Array shape: dims + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Rust native types that can view a literal's payload.
pub trait NativeType: Sized + Copy {
    const TY: ElementType;
    fn from_le_bytes(b: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le_bytes(b: &[u8]) -> f32 {
        f32::from_le_bytes(b.try_into().expect("4-byte chunk"))
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le_bytes(b: &[u8]) -> i32 {
        i32::from_le_bytes(b.try_into().expect("4-byte chunk"))
    }
}

/// Host-side literal: either an array (shape + untyped little-endian
/// bytes) or a tuple of literals.  Fully functional in the stub.
#[derive(Debug, Clone)]
pub enum Literal {
    Array { ty: ElementType, dims: Vec<i64>, data: Vec<u8> },
    Tuple(Vec<Literal>),
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        let want = n * ty.byte_size();
        if data.len() != want {
            return Err(Error::new(format!(
                "literal payload size mismatch: {} bytes for {dims:?} x {ty:?} (want {want})",
                data.len()
            )));
        }
        Ok(Literal::Array {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal::Tuple(elems)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => Ok(ArrayShape { dims: dims.clone(), ty: *ty }),
            Literal::Tuple(_) => Err(Error::new("tuple literal has no array shape")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::TY {
                    return Err(Error::new(format!(
                        "element type mismatch: literal is {ty:?}, requested {:?}",
                        T::TY
                    )));
                }
                let sz = ty.byte_size();
                Ok(data.chunks_exact(sz).map(T::from_le_bytes).collect())
            }
            Literal::Tuple(_) => Err(Error::new("cannot view a tuple literal as a vector")),
        }
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(elems) => Ok(elems.clone()),
            Literal::Array { .. } => Err(Error::new("literal is not a tuple")),
        }
    }
}

/// Parsed HLO module (the stub stores the text verbatim; parsing happens
/// in the real backend).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::new(format!("reading {:?}: {e}", path.as_ref())))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper (carried through to `compile`).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// PJRT client stub: constructs (so manifest-less tooling can report the
/// platform), but cannot compile.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (offline: no PJRT)".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(NO_PJRT))
    }
}

/// Loaded-executable stub.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(NO_PJRT))
    }
}

/// Device-buffer stub.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(NO_PJRT))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes)
            .unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_size_validation() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 7])
                .is_err()
        );
    }

    #[test]
    fn tuple_literals() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0])
            .unwrap();
        let t = Literal::tuple(vec![a.clone()]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(t.array_shape().is_err());
        assert!(a.to_tuple().is_err());
    }

    #[test]
    fn client_constructs_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = c.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("PJRT backend unavailable"), "{err}");
    }

    #[test]
    fn stub_types_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<PjRtClient>();
        assert_send_sync::<PjRtLoadedExecutable>();
        assert_send_sync::<Literal>();
    }
}
