//! Offline stand-in for the `flate2` crate (vendored; DESIGN.md §7).
//!
//! Implements the subset the `lgc` workspace uses — raw-DEFLATE encode /
//! decode (`write::DeflateEncoder`, `read::DeflateDecoder`) and [`Crc`] —
//! with no C dependency and no crates.io access.
//!
//! The encoder is a real RFC 1951 compressor: hash-chain LZ77 match
//! finding (3-byte hash, chain depth driven by [`Compression`] level),
//! length/distance symbol emission, and per-block selection among stored,
//! fixed-Huffman, and dynamic-Huffman coding (code-length coding per
//! §3.2.7, length-limited Huffman construction via the zlib-style
//! Kraft-excess adjustment).  The decoder inflates arbitrary conforming
//! streams — stored, fixed, and dynamic blocks, LZ77 references across
//! block boundaries — using canonical count/symbol tables.
//!
//! [`DeflateScratch`] + [`compress_into`] give the hot path a
//! zero-allocation entry point: all hash chains, token buffers, and
//! code-construction state live in the reusable scratch (DESIGN.md §6.11).
//!
//! The previous fixed/stored-only codec is preserved verbatim in
//! [`legacy`]: it is the bench baseline for the encode hot path and the
//! reference decoder for the differential tests (every fixed/stored
//! stream must inflate bit-identically under both decoders).

use std::io;

/// Compression level knob (0 = stored only, 1 = fastest search,
/// 9 = deepest hash chains; the per-block stored/fixed/dynamic choice is
/// always size-driven).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(u32);

impl Compression {
    pub const fn new(level: u32) -> Compression {
        Compression(level)
    }

    pub const fn level(self) -> u32 {
        self.0
    }

    /// (max hash-chain probes, early-exit match length) per level.
    fn search_params(self) -> (usize, usize) {
        match self.0 {
            0 => (0, 0),
            1 => (4, 8),
            2 => (8, 16),
            3 => (16, 32),
            4 => (32, 64),
            5 => (64, 96),
            6 => (128, 128),
            7 => (256, 196),
            8 => (1024, 258),
            _ => (4096, 258),
        }
    }
}

impl Default for Compression {
    fn default() -> Compression {
        Compression(6)
    }
}

// ---------------------------------------------------------------------------
// Shared constants (RFC 1951 §3.2.5)
// ---------------------------------------------------------------------------

const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 258;
const WINDOW: usize = 32_768;
/// Tokens per emitted block: bounds per-block code-table staleness while
/// amortizing the ~50-byte dynamic header.
const TOKENS_PER_BLOCK: usize = 1 << 15;

const LEN_BASE: [u32; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u32; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u32; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u32; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];
/// Order in which code-length-code lengths are transmitted (§3.2.7).
const CLCL_ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

/// match length - 3 -> length symbol - 257.
const fn build_len_to_sym() -> [u8; 256] {
    let mut t = [0u8; 256];
    let mut s = 0;
    while s < 29 {
        let mut off = 0;
        while off < (1usize << LEN_EXTRA[s]) {
            let idx = LEN_BASE[s] as usize - 3 + off;
            if idx < 256 {
                t[idx] = s as u8;
            }
            off += 1;
        }
        s += 1;
    }
    // len 258 is symbol 285 (not the tail of 284's extra-bit range).
    t[255] = 28;
    t
}
static LEN_TO_SYM: [u8; 256] = build_len_to_sym();

/// Distance (1..=32768) -> distance symbol (0..30).
#[inline]
fn dist_sym(d: u32) -> usize {
    let e = d - 1;
    if e < 4 {
        e as usize
    } else {
        let l = 31 - e.leading_zeros();
        (2 * l + ((e >> (l - 1)) & 1)) as usize
    }
}

/// Reverse the low `n` bits of `code` (canonical codes are MSB-first;
/// the bit writer is LSB-first).
#[inline]
fn rev_bits(code: u32, n: u8) -> u16 {
    let mut r = 0u32;
    let mut i = 0;
    while i < n {
        r |= ((code >> i) & 1) << (n - 1 - i);
        i += 1;
    }
    r as u16
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("deflate: {msg}"))
}

// ---------------------------------------------------------------------------
// Bit-level I/O (DEFLATE packs fields LSB-first; Huffman codes MSB-first)
// ---------------------------------------------------------------------------

struct BitWriter<'a> {
    out: &'a mut Vec<u8>,
    bit_buf: u64,
    bit_count: u32,
}

impl<'a> BitWriter<'a> {
    fn new(out: &'a mut Vec<u8>) -> BitWriter<'a> {
        BitWriter { out, bit_buf: 0, bit_count: 0 }
    }

    /// Write `n` (0..=16) bits of `value`, least-significant bit first.
    #[inline]
    fn write_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 16 && value >> n == 0 || n == 0);
        self.bit_buf |= (value as u64) << self.bit_count;
        self.bit_count += n;
        while self.bit_count >= 8 {
            self.out.push(self.bit_buf as u8);
            self.bit_buf >>= 8;
            self.bit_count -= 8;
        }
    }

    /// Pad with zero bits to the next byte boundary.
    fn align_byte(&mut self) {
        if self.bit_count > 0 {
            self.write_bits(0, 8 - self.bit_count);
        }
    }

    fn finish(mut self) {
        if self.bit_count > 0 {
            self.out.push(self.bit_buf as u8);
            self.bit_buf = 0;
            self.bit_count = 0;
        }
    }
}

struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    bit_buf: u32,
    bit_count: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> BitReader<'a> {
        BitReader { data, pos: 0, bit_buf: 0, bit_count: 0 }
    }

    fn read_bits(&mut self, n: u32) -> io::Result<u32> {
        debug_assert!(n <= 16);
        while self.bit_count < n {
            let b = *self.data.get(self.pos).ok_or_else(|| bad("unexpected end of stream"))?;
            self.pos += 1;
            self.bit_buf |= (b as u32) << self.bit_count;
            self.bit_count += 8;
        }
        let v = self.bit_buf & ((1u32 << n) - 1);
        self.bit_buf >>= n;
        self.bit_count -= n;
        Ok(v)
    }

    /// Discard bits up to the next byte boundary (stored-block headers).
    fn align_byte(&mut self) {
        let drop = self.bit_count % 8;
        self.bit_buf >>= drop;
        self.bit_count -= drop;
    }
}

// ---------------------------------------------------------------------------
// Length-limited Huffman construction (encoder side)
// ---------------------------------------------------------------------------

/// Largest alphabet we build codes for (literal/length).
const MAX_SYMS: usize = 286;

/// Optimal Huffman code lengths for `freqs`, limited to `max_len` bits.
///
/// Two-queue O(n log n) Huffman on the sorted leaves, then depths beyond
/// `max_len` are clamped and the integer Kraft excess is paid back by
/// moving leaves down one level at a time (each move frees exactly one
/// `max_len` slot), yielding a complete tree: sum(2^-len) == 1 whenever
/// >= 2 symbols are coded.  Callers needing a *decodable-by-anyone*
/// (complete) tree with < 2 used symbols go through
/// [`build_lengths_complete`].
fn build_lengths(freqs: &[u32], max_len: usize, lengths: &mut [u8]) {
    debug_assert!(freqs.len() <= MAX_SYMS && freqs.len() == lengths.len());
    lengths[..].fill(0);
    // Weights carried as u64: merged-node sums can exceed u32 for
    // adversarial frequency inputs (the tests feed Fibonacci weights).
    let mut leaves = [(0u64, 0u16); MAX_SYMS];
    let mut used = 0usize;
    for (s, &f) in freqs.iter().enumerate() {
        if f > 0 {
            leaves[used] = (f as u64, s as u16);
            used += 1;
        }
    }
    if used == 0 {
        return;
    }
    if used == 1 {
        lengths[leaves[0].1 as usize] = 1;
        return;
    }
    leaves[..used].sort_unstable();

    // Two-queue merge: q1 = sorted leaves (id = symbol), q2 = internal
    // nodes in creation (= non-decreasing weight) order, ids from MAX_SYMS.
    let mut q2 = [(0u64, 0u16); MAX_SYMS];
    let mut parent = [0u16; 2 * MAX_SYMS];
    let (mut i1, mut h2, mut t2) = (0usize, 0usize, 0usize);
    let mut next_id = MAX_SYMS as u16;
    while (used - i1) + (t2 - h2) > 1 {
        let take = |i1: &mut usize, h2: &mut usize| -> (u64, u16) {
            if *i1 < used && (*h2 >= t2 || leaves[*i1].0 <= q2[*h2].0) {
                *i1 += 1;
                leaves[*i1 - 1]
            } else {
                *h2 += 1;
                q2[*h2 - 1]
            }
        };
        let a = take(&mut i1, &mut h2);
        let b = take(&mut i1, &mut h2);
        parent[a.1 as usize] = next_id;
        parent[b.1 as usize] = next_id;
        q2[t2] = (a.0 + b.0, next_id);
        t2 += 1;
        next_id += 1;
    }
    let root = next_id - 1;

    // Depth histogram, clamped into max_len.
    let mut bl_count = [0i64; 17];
    for &(_, sym) in &leaves[..used] {
        let mut d = 0usize;
        let mut id = sym;
        while id != root {
            id = parent[id as usize];
            d += 1;
        }
        bl_count[d.min(max_len)] += 1;
    }
    // Kraft excess in units of 2^-max_len; every leaf moved from depth b
    // to b+1 frees one max_len slot, reducing the excess by exactly 1.
    let mut excess: i64 = -(1i64 << max_len);
    for (l, &c) in bl_count.iter().enumerate().take(max_len + 1) {
        excess += c << (max_len - l);
    }
    while excess > 0 {
        let mut bits = max_len - 1;
        while bl_count[bits] == 0 {
            bits -= 1;
        }
        bl_count[bits] -= 1;
        bl_count[bits + 1] += 2;
        bl_count[max_len] -= 1;
        excess -= 1;
    }
    // Reassign: most frequent symbols take the shortest lengths
    // (descending-frequency order = the ascending sort, reversed).
    let mut i = 0usize;
    for len in 1..=max_len {
        for _ in 0..bl_count[len] {
            lengths[leaves[used - 1 - i].1 as usize] = len as u8;
            i += 1;
        }
    }
    debug_assert_eq!(i, used);
}

/// [`build_lengths`], forcing at least two coded symbols so the emitted
/// tree is complete (strict inflaters reject incomplete trees; the extra
/// never-used code costs one header bit).
fn build_lengths_complete(freqs: &[u32], max_len: usize, lengths: &mut [u8]) {
    let used = freqs.iter().filter(|&&f| f > 0).count();
    if used >= 2 {
        build_lengths(freqs, max_len, lengths);
        return;
    }
    lengths[..].fill(0);
    match freqs.iter().position(|&f| f > 0) {
        None => {
            lengths[0] = 1;
            lengths[1] = 1;
        }
        Some(s) => {
            lengths[s] = 1;
            lengths[if s == 0 { 1 } else { 0 }] = 1;
        }
    }
}

/// RFC 1951 canonical codes from lengths, stored bit-reversed for the
/// LSB-first writer.
fn canonical_codes(lengths: &[u8], codes: &mut [u16]) {
    let mut bl_count = [0u32; 16];
    for &l in lengths {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = [0u32; 16];
    let mut code = 0u32;
    for l in 1..16 {
        code = (code + bl_count[l - 1]) << 1;
        next_code[l] = code;
    }
    for (s, &l) in lengths.iter().enumerate() {
        if l > 0 {
            codes[s] = rev_bits(next_code[l as usize], l);
            next_code[l as usize] += 1;
        }
    }
}

/// Fixed-Huffman code lengths (§3.2.6).
fn fixed_lit_lengths() -> [u8; 288] {
    let mut l = [8u8; 288];
    for x in l.iter_mut().take(256).skip(144) {
        *x = 9;
    }
    for x in l.iter_mut().take(280).skip(256) {
        *x = 7;
    }
    l
}

#[inline]
fn fixed_lit_len(sym: usize) -> u64 {
    match sym {
        0..=143 => 8,
        144..=255 => 9,
        256..=279 => 7,
        _ => 8,
    }
}

// ---------------------------------------------------------------------------
// LZ77 tokenization (hash chains) + reusable scratch state
// ---------------------------------------------------------------------------

/// Per-block code-construction state, reused across blocks and calls.
struct CodeGen {
    lit_freq: [u32; 286],
    dist_freq: [u32; 30],
    cl_freq: [u32; 19],
    lit_len: [u8; 286],
    dist_len: [u8; 30],
    cl_len: [u8; 19],
    lit_code: [u16; 286],
    dist_code: [u16; 30],
    cl_code: [u16; 19],
    /// RLE of the transmitted length arrays: (symbol, extra value, extra bits).
    rle: Vec<(u8, u8, u8)>,
}

impl CodeGen {
    fn new() -> CodeGen {
        CodeGen {
            lit_freq: [0; 286],
            dist_freq: [0; 30],
            cl_freq: [0; 19],
            lit_len: [0; 286],
            dist_len: [0; 30],
            cl_len: [0; 19],
            lit_code: [0; 286],
            dist_code: [0; 30],
            cl_code: [0; 19],
            rle: Vec::new(),
        }
    }
}

/// Reusable compressor state: with a long-lived scratch, [`compress_into`]
/// performs no heap allocation in the steady state (hash heads/chains,
/// token buffer, and code-gen state all live here and are recycled).
pub struct DeflateScratch {
    head: Vec<i32>,
    prev: Vec<i32>,
    /// Packed tokens: bit 31 set => match, bits 16..24 = len-3,
    /// bits 0..16 = dist-1; else literal byte in bits 0..8.
    tokens: Vec<u32>,
    cg: CodeGen,
}

impl DeflateScratch {
    pub fn new() -> DeflateScratch {
        DeflateScratch {
            head: Vec::new(),
            prev: Vec::new(),
            tokens: Vec::new(),
            cg: CodeGen::new(),
        }
    }
}

impl Default for DeflateScratch {
    fn default() -> DeflateScratch {
        DeflateScratch::new()
    }
}

const TOKEN_MATCH: u32 = 1 << 31;

/// Minimum-length matches beyond this distance are dropped (zlib's
/// TOO_FAR heuristic): a far 3-byte match can cost more bits than its
/// literals, and rejecting them is what guarantees a tokenized block
/// never codes larger under fixed Huffman than the literal-only stream.
const TOO_FAR: usize = 4096;

// ---------------------------------------------------------------------------
// Match-length extension: the LZ77 inner loop, SIMD-dispatched
// ---------------------------------------------------------------------------

const SIMD_UNDECIDED: u8 = 0;
const SIMD_SCALAR: u8 = 1;
const SIMD_AVX2: u8 = 2;

/// Cached dispatch for [`match_len`].  This crate is vendored below the
/// `lgc` workspace and cannot see its dispatch atomic, so it keeps its
/// own, driven by the same inputs: `LGC_FORCE_SCALAR=1`, AVX2 detection,
/// and [`set_force_scalar`] (which `lgc::compress::simd::force_scalar`
/// forwards to).
static SIMD_DISPATCH: std::sync::atomic::AtomicU8 =
    std::sync::atomic::AtomicU8::new(SIMD_UNDECIDED);

fn simd_detect() -> u8 {
    if std::env::var_os("LGC_FORCE_SCALAR").is_some_and(|v| v == "1") {
        return SIMD_SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return SIMD_AVX2;
    }
    SIMD_SCALAR
}

fn simd_active() -> bool {
    use std::sync::atomic::Ordering;
    match SIMD_DISPATCH.load(Ordering::Relaxed) {
        SIMD_UNDECIDED => {
            let d = simd_detect();
            SIMD_DISPATCH.store(d, Ordering::Relaxed);
            d == SIMD_AVX2
        }
        d => d == SIMD_AVX2,
    }
}

/// Pin (`true`) or re-detect (`false`) the scalar match loop at runtime;
/// the environment override survives release.
pub fn set_force_scalar(force: bool) {
    let d = if force { SIMD_SCALAR } else { simd_detect() };
    SIMD_DISPATCH.store(d, std::sync::atomic::Ordering::Relaxed);
}

/// Length of the common prefix of `data[a..]` and `data[b..]`, capped at
/// `max_l`.  Caller guarantees `a < b` and `b + max_l <= data.len()`.
///
/// Both variants test exact byte equality, so they return identical
/// lengths for every input (DESIGN.md §16.1).
fn match_len(data: &[u8], a: usize, b: usize, max_l: usize) -> usize {
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: AVX2 presence was runtime-checked by `simd_active`.
        return unsafe { match_len_avx2(data, a, b, max_l) };
    }
    match_len_scalar(data, a, b, max_l)
}

fn match_len_scalar(data: &[u8], a: usize, b: usize, max_l: usize) -> usize {
    let mut l = 0usize;
    while l < max_l && data[a + l] == data[b + l] {
        l += 1;
    }
    l
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn match_len_avx2(data: &[u8], a: usize, b: usize, max_l: usize) -> usize {
    use std::arch::x86_64::*;
    let mut l = 0usize;
    // 32-byte blocks while fully inside the cap: loads stay in bounds
    // because a + l + 32 <= b + max_l <= data.len().
    while l + 32 <= max_l {
        // SAFETY: bounds argument above; unaligned loads.
        let (x, y) = unsafe {
            (
                _mm256_loadu_si256(data.as_ptr().add(a + l) as *const __m256i),
                _mm256_loadu_si256(data.as_ptr().add(b + l) as *const __m256i),
            )
        };
        let eq = _mm256_movemask_epi8(_mm256_cmpeq_epi8(x, y)) as u32;
        if eq != u32::MAX {
            return l + (!eq).trailing_zeros() as usize;
        }
        l += 32;
    }
    while l < max_l && data[a + l] == data[b + l] {
        l += 1;
    }
    l
}

/// Greedy hash-chain LZ77 over `data` into `s.tokens`.
fn tokenize(data: &[u8], max_chain: usize, nice_len: usize, s: &mut DeflateScratch) {
    let n = data.len();
    // Size the hash table to the input (8..15 bits): small payloads avoid
    // paying a 32K-entry table reset per call.
    let hash_bits = (usize::BITS - n.leading_zeros()).clamp(8, 15);
    let hash_shift = 32 - hash_bits;
    s.head.clear();
    s.head.resize(1usize << hash_bits, -1);
    if s.prev.len() < n {
        s.prev.resize(n, 0); // stale entries are fine: written before read
    }
    s.tokens.clear();

    let hash3 = |p: usize| -> usize {
        let h = ((data[p] as u32) << 16) ^ ((data[p + 1] as u32) << 8) ^ (data[p + 2] as u32);
        (h.wrapping_mul(0x9E37_79B1) >> hash_shift) as usize
    };

    let mut i = 0usize;
    while i < n {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= n && max_chain > 0 {
            let h = hash3(i);
            let mut j = s.head[h] as isize;
            let limit = i as isize - WINDOW as isize;
            let max_l = (n - i).min(MAX_MATCH);
            let mut chain = max_chain;
            while j >= 0 && j >= limit && chain > 0 && best_len < max_l {
                chain -= 1;
                let ju = j as usize;
                // Quick reject on the byte that would extend the best
                // match (safe: best_len < max_l <= n - i).
                if best_len > 0 && data[ju + best_len] != data[i + best_len] {
                    j = s.prev[ju] as isize;
                    continue;
                }
                let l = match_len(data, ju, i, max_l);
                if l > best_len {
                    best_len = l;
                    best_dist = i - ju;
                    if l >= nice_len {
                        break;
                    }
                }
                j = s.prev[ju] as isize;
            }
            if best_len == MIN_MATCH && best_dist > TOO_FAR {
                best_len = 0;
            }
        }
        if best_len >= MIN_MATCH {
            s.tokens.push(
                TOKEN_MATCH | (((best_len - MIN_MATCH) as u32) << 16) | (best_dist as u32 - 1),
            );
            for p in i..i + best_len {
                if p + MIN_MATCH <= n {
                    let h = hash3(p);
                    s.prev[p] = s.head[h];
                    s.head[h] = p as i32;
                }
            }
            i += best_len;
        } else {
            s.tokens.push(data[i] as u32);
            if i + MIN_MATCH <= n {
                let h = hash3(i);
                s.prev[i] = s.head[h];
                s.head[h] = i as i32;
            }
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Block emission: stored / fixed / dynamic, whichever is smallest
// ---------------------------------------------------------------------------

/// RLE a transmitted code-length array (lit lengths ++ dist lengths) into
/// §3.2.7 symbols: 16 = repeat previous 3-6, 17 = 3-10 zeros,
/// 18 = 11-138 zeros.
fn rle_lengths(lengths: &[u8], out: &mut Vec<(u8, u8, u8)>) {
    out.clear();
    let n = lengths.len();
    let mut i = 0usize;
    while i < n {
        let v = lengths[i];
        let mut run = 1usize;
        while i + run < n && lengths[i + run] == v {
            run += 1;
        }
        if v == 0 {
            let mut r = run;
            while r >= 11 {
                let rep = r.min(138);
                out.push((18, (rep - 11) as u8, 7));
                r -= rep;
            }
            if r >= 3 {
                out.push((17, (r - 3) as u8, 3));
                r = 0;
            }
            out.resize(out.len() + r, (0, 0, 0));
        } else {
            out.push((v, 0, 0));
            let mut r = run - 1;
            while r >= 3 {
                let rep = r.min(6);
                out.push((16, (rep - 3) as u8, 2));
                r -= rep;
            }
            out.resize(out.len() + r, (v, 0, 0));
        }
        i += run;
    }
}

fn emit_stored(w: &mut BitWriter, data: &[u8], start: usize, end: usize, last: bool) {
    let mut s = start;
    loop {
        let e = (s + 65_535).min(end);
        let final_chunk = last && e == end;
        w.write_bits(u32::from(final_chunk), 1);
        w.write_bits(0, 2);
        w.align_byte();
        let len = (e - s) as u16;
        w.out.extend_from_slice(&len.to_le_bytes());
        w.out.extend_from_slice(&(!len).to_le_bytes());
        w.out.extend_from_slice(&data[s..e]);
        s = e;
        if s >= end {
            return;
        }
    }
}

/// Histogram a token run, build its dynamic code, compare the three block
/// encodings, emit the cheapest.  `start..end` is the input byte range the
/// tokens cover (needed for the stored fallback).
fn emit_block(
    w: &mut BitWriter,
    toks: &[u32],
    data: &[u8],
    start: usize,
    end: usize,
    last: bool,
    cg: &mut CodeGen,
) {
    cg.lit_freq.fill(0);
    cg.dist_freq.fill(0);
    let mut len_extra_bits = 0u64;
    let mut dist_extra_bits = 0u64;
    let mut match_count = 0u64;
    for &t in toks {
        if t & TOKEN_MATCH == 0 {
            cg.lit_freq[t as usize] += 1;
        } else {
            let ls = LEN_TO_SYM[((t >> 16) & 0xFF) as usize] as usize;
            cg.lit_freq[257 + ls] += 1;
            len_extra_bits += LEN_EXTRA[ls] as u64;
            let ds = dist_sym((t & 0xFFFF) + 1);
            cg.dist_freq[ds] += 1;
            dist_extra_bits += DIST_EXTRA[ds] as u64;
            match_count += 1;
        }
    }
    cg.lit_freq[256] += 1; // end-of-block

    build_lengths_complete(&cg.lit_freq, 15, &mut cg.lit_len);
    build_lengths_complete(&cg.dist_freq, 15, &mut cg.dist_len);

    let mut hlit = 286usize;
    while hlit > 257 && cg.lit_len[hlit - 1] == 0 {
        hlit -= 1;
    }
    let mut hdist = 30usize;
    while hdist > 1 && cg.dist_len[hdist - 1] == 0 {
        hdist -= 1;
    }

    // The repeat codes may legally run across the lit/dist boundary, so
    // RLE the concatenation in one pass.
    let mut concat = [0u8; 316];
    concat[..hlit].copy_from_slice(&cg.lit_len[..hlit]);
    concat[hlit..hlit + hdist].copy_from_slice(&cg.dist_len[..hdist]);
    rle_lengths(&concat[..hlit + hdist], &mut cg.rle);

    cg.cl_freq.fill(0);
    for &(sym, _, _) in &cg.rle {
        cg.cl_freq[sym as usize] += 1;
    }
    build_lengths_complete(&cg.cl_freq, 7, &mut cg.cl_len);
    let mut hclen = 19usize;
    while hclen > 4 && cg.cl_len[CLCL_ORDER[hclen - 1]] == 0 {
        hclen -= 1;
    }

    // --- size of each candidate encoding, in bits ------------------------
    let mut dyn_bits = 3 + 5 + 5 + 4 + hclen as u64 * 3;
    for &(sym, _, eb) in &cg.rle {
        dyn_bits += cg.cl_len[sym as usize] as u64 + eb as u64;
    }
    let mut fixed_bits = 3 + len_extra_bits + dist_extra_bits;
    for s in 0..286 {
        if cg.lit_freq[s] > 0 {
            dyn_bits += cg.lit_freq[s] as u64 * cg.lit_len[s] as u64;
            fixed_bits += cg.lit_freq[s] as u64 * fixed_lit_len(s);
        }
    }
    dyn_bits += len_extra_bits + dist_extra_bits;
    for s in 0..30 {
        if cg.dist_freq[s] > 0 {
            dyn_bits += cg.dist_freq[s] as u64 * cg.dist_len[s] as u64;
        }
    }
    fixed_bits += 5 * match_count;

    let nbytes = (end - start) as u64;
    let nchunks = nbytes.div_ceil(65_535).max(1);
    // Upper bound: worst-case byte-alignment padding per chunk header.
    let stored_bits = nchunks * 40 + 8 * nbytes;

    if stored_bits < dyn_bits && stored_bits < fixed_bits {
        emit_stored(w, data, start, end, last);
        return;
    }
    if fixed_bits <= dyn_bits {
        let fl = fixed_lit_lengths();
        cg.lit_len[..286].copy_from_slice(&fl[..286]);
        cg.dist_len.fill(5);
        // Canonical codes of the fixed lengths need the full 288-symbol
        // alphabet (codes for 286..287 shift the 280.. range).
        let mut full_codes = [0u16; 288];
        canonical_codes(&fl, &mut full_codes);
        cg.lit_code.copy_from_slice(&full_codes[..286]);
        let dl = [5u8; 32];
        let mut dcodes = [0u16; 32];
        canonical_codes(&dl, &mut dcodes);
        cg.dist_code.copy_from_slice(&dcodes[..30]);
        w.write_bits(u32::from(last), 1);
        w.write_bits(1, 2);
    } else {
        w.write_bits(u32::from(last), 1);
        w.write_bits(2, 2);
        w.write_bits((hlit - 257) as u32, 5);
        w.write_bits((hdist - 1) as u32, 5);
        w.write_bits((hclen - 4) as u32, 4);
        canonical_codes(&cg.cl_len, &mut cg.cl_code);
        for &ord in CLCL_ORDER.iter().take(hclen) {
            w.write_bits(cg.cl_len[ord] as u32, 3);
        }
        for &(sym, ev, eb) in &cg.rle {
            w.write_bits(cg.cl_code[sym as usize] as u32, cg.cl_len[sym as usize] as u32);
            if eb > 0 {
                w.write_bits(ev as u32, eb as u32);
            }
        }
        canonical_codes(&cg.lit_len, &mut cg.lit_code);
        canonical_codes(&cg.dist_len, &mut cg.dist_code);
    }

    for &t in toks {
        if t & TOKEN_MATCH == 0 {
            let b = t as usize;
            w.write_bits(cg.lit_code[b] as u32, cg.lit_len[b] as u32);
        } else {
            let ls = LEN_TO_SYM[((t >> 16) & 0xFF) as usize] as usize;
            let sym = 257 + ls;
            w.write_bits(cg.lit_code[sym] as u32, cg.lit_len[sym] as u32);
            let len = ((t >> 16) & 0xFF) + MIN_MATCH as u32;
            if LEN_EXTRA[ls] > 0 {
                w.write_bits(len - LEN_BASE[ls], LEN_EXTRA[ls]);
            }
            let dist = (t & 0xFFFF) + 1;
            let ds = dist_sym(dist);
            w.write_bits(cg.dist_code[ds] as u32, cg.dist_len[ds] as u32);
            if DIST_EXTRA[ds] > 0 {
                w.write_bits(dist - DIST_BASE[ds], DIST_EXTRA[ds]);
            }
        }
    }
    w.write_bits(cg.lit_code[256] as u32, cg.lit_len[256] as u32);
}

/// Raw-DEFLATE compress `data` into `out` (appended), reusing `scratch`.
/// Allocation-free in the steady state once the scratch buffers have
/// grown to the workload's high-water mark.
pub fn compress_into(
    data: &[u8],
    level: Compression,
    scratch: &mut DeflateScratch,
    out: &mut Vec<u8>,
) {
    let mut w = BitWriter::new(out);
    if data.is_empty() {
        // Fixed block holding only end-of-block: 10 bits total.
        w.write_bits(1, 1);
        w.write_bits(1, 2);
        w.write_bits(0, 7); // EOB (symbol 256) is the all-zero 7-bit code
        w.finish();
        return;
    }
    if level.level() == 0 {
        emit_stored(&mut w, data, 0, data.len(), true);
        w.finish();
        return;
    }
    let (max_chain, nice_len) = level.search_params();
    tokenize(data, max_chain, nice_len, scratch);
    let ntoks = scratch.tokens.len();
    let mut i = 0usize;
    let mut pos = 0usize;
    while i < ntoks {
        let j = (i + TOKENS_PER_BLOCK).min(ntoks);
        let mut span = 0usize;
        for &t in &scratch.tokens[i..j] {
            span += if t & TOKEN_MATCH == 0 {
                1
            } else {
                ((t >> 16) & 0xFF) as usize + MIN_MATCH
            };
        }
        emit_block(
            &mut w,
            &scratch.tokens[i..j],
            data,
            pos,
            pos + span,
            j == ntoks,
            &mut scratch.cg,
        );
        pos += span;
        i = j;
    }
    w.finish();
}

/// One-shot compress (allocating convenience wrapper).
pub fn compress(data: &[u8], level: Compression) -> Vec<u8> {
    let mut scratch = DeflateScratch::new();
    let mut out = Vec::new();
    compress_into(data, level, &mut scratch, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Decoder: canonical Huffman tables, stored + fixed + dynamic blocks
// ---------------------------------------------------------------------------

/// Canonical Huffman decoding table: per-length symbol counts plus the
/// symbols sorted by (length, symbol).
struct Huff {
    count: [u16; 16],
    symbol: [u16; 288],
}

impl Huff {
    fn build(lengths: &[u8]) -> io::Result<Huff> {
        let mut count = [0u16; 16];
        for &l in lengths {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        let mut left = 1i32;
        for &c in count.iter().skip(1) {
            left <<= 1;
            left -= c as i32;
            if left < 0 {
                return Err(bad("over-subscribed huffman code"));
            }
        }
        let mut offs = [0usize; 16];
        for l in 1..15 {
            offs[l + 1] = offs[l] + count[l] as usize;
        }
        let mut symbol = [0u16; 288];
        for (s, &l) in lengths.iter().enumerate() {
            if l > 0 {
                symbol[offs[l as usize]] = s as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huff { count, symbol })
    }

    /// Decode one symbol, reading the MSB-first code bit by bit.
    fn decode(&self, r: &mut BitReader) -> io::Result<u16> {
        let mut code = 0i32;
        let mut first = 0i32;
        let mut index = 0i32;
        for len in 1..16 {
            code |= r.read_bits(1)? as i32;
            let count = self.count[len] as i32;
            if code - first < count {
                return Ok(self.symbol[(index + code - first) as usize]);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        Err(bad("invalid huffman code"))
    }
}

fn fixed_decoders() -> (Huff, Huff) {
    let lit = Huff::build(&fixed_lit_lengths()).expect("fixed lit table");
    let dist = Huff::build(&[5u8; 30]).expect("fixed dist table");
    (lit, dist)
}

/// Decode the compressed body of one fixed/dynamic block into `out`,
/// erroring once the output would exceed `limit`.
fn inflate_block(
    r: &mut BitReader,
    out: &mut Vec<u8>,
    lit: &Huff,
    dist: &Huff,
    limit: usize,
) -> io::Result<()> {
    loop {
        let sym = lit.decode(r)? as usize;
        match sym {
            0..=255 => {
                if out.len() >= limit {
                    return Err(bad("output exceeds size limit"));
                }
                out.push(sym as u8);
            }
            256 => return Ok(()),
            257..=285 => {
                let i = sym - 257;
                let len = (LEN_BASE[i] + r.read_bits(LEN_EXTRA[i])?) as usize;
                let ds = dist.decode(r)? as usize;
                if ds >= 30 {
                    return Err(bad("invalid distance symbol"));
                }
                let d = (DIST_BASE[ds] + r.read_bits(DIST_EXTRA[ds])?) as usize;
                if d == 0 || d > out.len() {
                    return Err(bad("distance beyond window"));
                }
                if out.len() + len > limit {
                    return Err(bad("output exceeds size limit"));
                }
                let start = out.len() - d;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
            _ => return Err(bad("invalid literal/length symbol")),
        }
    }
}

fn inflate(data: &[u8], limit: usize) -> io::Result<Vec<u8>> {
    let mut r = BitReader::new(data);
    let mut out = Vec::new();
    loop {
        let bfinal = r.read_bits(1)?;
        match r.read_bits(2)? {
            0 => {
                r.align_byte();
                let len = r.read_bits(16)?;
                let nlen = r.read_bits(16)?;
                if len ^ nlen != 0xFFFF {
                    return Err(bad("stored-block LEN/NLEN mismatch"));
                }
                if out.len() + len as usize > limit {
                    return Err(bad("output exceeds size limit"));
                }
                out.reserve(len as usize);
                for _ in 0..len {
                    out.push(r.read_bits(8)? as u8);
                }
            }
            1 => {
                let (lit, dist) = fixed_decoders();
                inflate_block(&mut r, &mut out, &lit, &dist, limit)?;
            }
            2 => {
                let hlit = r.read_bits(5)? as usize + 257;
                let hdist = r.read_bits(5)? as usize + 1;
                let hclen = r.read_bits(4)? as usize + 4;
                if hlit > 286 || hdist > 30 {
                    return Err(bad("bad HLIT/HDIST"));
                }
                let mut cl_lengths = [0u8; 19];
                for &ord in CLCL_ORDER.iter().take(hclen) {
                    cl_lengths[ord] = r.read_bits(3)? as u8;
                }
                let cl = Huff::build(&cl_lengths)?;
                let total = hlit + hdist;
                let mut lengths = [0u8; 316];
                let mut cnt = 0usize;
                while cnt < total {
                    let sym = cl.decode(&mut r)?;
                    match sym {
                        0..=15 => {
                            lengths[cnt] = sym as u8;
                            cnt += 1;
                        }
                        16 => {
                            if cnt == 0 {
                                return Err(bad("length repeat with no previous length"));
                            }
                            let rep = 3 + r.read_bits(2)? as usize;
                            if cnt + rep > total {
                                return Err(bad("too many code lengths"));
                            }
                            let v = lengths[cnt - 1];
                            for _ in 0..rep {
                                lengths[cnt] = v;
                                cnt += 1;
                            }
                        }
                        17 | 18 => {
                            let rep = if sym == 17 {
                                3 + r.read_bits(3)? as usize
                            } else {
                                11 + r.read_bits(7)? as usize
                            };
                            if cnt + rep > total {
                                return Err(bad("too many code lengths"));
                            }
                            cnt += rep; // lengths[] is zero-initialized
                        }
                        _ => return Err(bad("invalid code-length symbol")),
                    }
                }
                let lit = Huff::build(&lengths[..hlit])?;
                let dist = Huff::build(&lengths[hlit..total])?;
                inflate_block(&mut r, &mut out, &lit, &dist, limit)?;
            }
            _ => return Err(bad("reserved block type")),
        }
        if bfinal == 1 {
            return Ok(out);
        }
    }
}

/// Inflate a raw-DEFLATE stream (one-shot convenience wrapper).
pub fn decompress(data: &[u8]) -> io::Result<Vec<u8>> {
    inflate(data, usize::MAX)
}

/// Inflate with an output-size cap: errors (instead of allocating
/// unboundedly) if the stream would expand past `max_out` bytes.  For
/// untrusted payloads whose plaintext size has a known bound — DEFLATE
/// expands up to ~1032x, so a tiny crafted input can otherwise demand
/// gigabytes.
pub fn decompress_limited(data: &[u8], max_out: usize) -> io::Result<Vec<u8>> {
    inflate(data, max_out)
}

// ---------------------------------------------------------------------------
// Legacy fixed/stored-only codec (the pre-LZ77 implementation, verbatim).
//
// Kept as (a) the bench baseline the hot-path speedup is measured against
// and (b) the reference decoder for the differential tests: any stream of
// stored/fixed blocks must inflate bit-identically here and in the new
// decoder.  Not used on any production path.
// ---------------------------------------------------------------------------

pub mod legacy {
    use super::{bad, BitReader, DIST_BASE, DIST_EXTRA, LEN_BASE, LEN_EXTRA};
    use std::io;

    pub(crate) struct BitWriter {
        out: Vec<u8>,
        bit_buf: u32,
        bit_count: u32,
    }

    impl BitWriter {
        pub(crate) fn new() -> BitWriter {
            BitWriter { out: Vec::new(), bit_buf: 0, bit_count: 0 }
        }

        pub(crate) fn write_bits(&mut self, value: u32, n: u32) {
            debug_assert!((1..=16).contains(&n) && (value >> n) == 0);
            self.bit_buf |= value << self.bit_count;
            self.bit_count += n;
            while self.bit_count >= 8 {
                self.out.push((self.bit_buf & 0xff) as u8);
                self.bit_buf >>= 8;
                self.bit_count -= 8;
            }
        }

        /// Write a Huffman code, reversing to MSB-first bit order.
        pub(crate) fn write_huffman(&mut self, code: u32, len: u32) {
            let mut rev = 0u32;
            for i in 0..len {
                rev |= ((code >> i) & 1) << (len - 1 - i);
            }
            self.write_bits(rev, len);
        }

        pub(crate) fn finish(mut self) -> Vec<u8> {
            if self.bit_count > 0 {
                self.out.push((self.bit_buf & 0xff) as u8);
            }
            self.out
        }
    }

    /// (code, length) of literal/length symbol `sym` in the fixed tree.
    pub(crate) fn fixed_lit_code(sym: u32) -> (u32, u32) {
        match sym {
            0..=143 => (0x30 + sym, 8),
            144..=255 => (0x190 + (sym - 144), 9),
            256..=279 => (sym - 256, 7),
            _ => (0xC0 + (sym - 280), 8),
        }
    }

    fn stored_size(n: usize) -> usize {
        if n == 0 {
            return 5;
        }
        n.div_ceil(65_535) * 5 + n
    }

    fn fixed_size(data: &[u8]) -> usize {
        let mut bits = 3usize + 7;
        for &b in data {
            bits += if b < 144 { 8 } else { 9 };
        }
        bits.div_ceil(8)
    }

    fn encode_stored(data: &[u8]) -> Vec<u8> {
        let mut out = Vec::with_capacity(stored_size(data.len()));
        let mut chunks: Vec<&[u8]> = data.chunks(65_535).collect();
        if chunks.is_empty() {
            chunks.push(&[]);
        }
        let last = chunks.len() - 1;
        for (i, chunk) in chunks.iter().enumerate() {
            out.push(u8::from(i == last));
            let len = chunk.len() as u16;
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&(!len).to_le_bytes());
            out.extend_from_slice(chunk);
        }
        out
    }

    fn encode_fixed(data: &[u8]) -> Vec<u8> {
        let mut w = BitWriter::new();
        w.write_bits(1, 1);
        w.write_bits(1, 2);
        for &b in data {
            let (code, len) = fixed_lit_code(b as u32);
            w.write_huffman(code, len);
        }
        let (code, len) = fixed_lit_code(256);
        w.write_huffman(code, len);
        w.finish()
    }

    /// The old encoder: the smaller of a stored and a fixed-Huffman
    /// literal-only encoding (no LZ77, no dynamic blocks).
    pub fn deflate_fixed_only(data: &[u8]) -> Vec<u8> {
        if fixed_size(data) <= stored_size(data.len()) {
            encode_fixed(data)
        } else {
            encode_stored(data)
        }
    }

    fn read_huffman_bits(r: &mut BitReader, n: u32) -> io::Result<u32> {
        let mut code = 0u32;
        for _ in 0..n {
            code = (code << 1) | r.read_bits(1)?;
        }
        Ok(code)
    }

    fn read_fixed_symbol(r: &mut BitReader) -> io::Result<u32> {
        let mut code = read_huffman_bits(r, 7)?;
        if code <= 0b001_0111 {
            return Ok(256 + code);
        }
        code = (code << 1) | r.read_bits(1)?;
        if (0x30..=0xBF).contains(&code) {
            return Ok(code - 0x30);
        }
        if (0xC0..=0xC7).contains(&code) {
            return Ok(280 + (code - 0xC0));
        }
        code = (code << 1) | r.read_bits(1)?;
        if (0x190..=0x1FF).contains(&code) {
            return Ok(144 + (code - 0x190));
        }
        Err(bad("invalid fixed-Huffman code"))
    }

    /// The old decoder: stored + fixed blocks only; dynamic rejected.
    pub fn inflate_fixed_only(data: &[u8]) -> io::Result<Vec<u8>> {
        let mut r = BitReader::new(data);
        let mut out = Vec::new();
        loop {
            let bfinal = r.read_bits(1)?;
            match r.read_bits(2)? {
                0 => {
                    r.align_byte();
                    let len = r.read_bits(16)?;
                    let nlen = r.read_bits(16)?;
                    if len ^ nlen != 0xFFFF {
                        return Err(bad("stored-block LEN/NLEN mismatch"));
                    }
                    out.reserve(len as usize);
                    for _ in 0..len {
                        out.push(r.read_bits(8)? as u8);
                    }
                }
                1 => loop {
                    let sym = read_fixed_symbol(&mut r)?;
                    match sym {
                        0..=255 => out.push(sym as u8),
                        256 => break,
                        257..=285 => {
                            let i = (sym - 257) as usize;
                            let len = (LEN_BASE[i] + r.read_bits(LEN_EXTRA[i])?) as usize;
                            let dcode = read_huffman_bits(&mut r, 5)? as usize;
                            if dcode >= DIST_BASE.len() {
                                return Err(bad("invalid distance code"));
                            }
                            let dist =
                                (DIST_BASE[dcode] + r.read_bits(DIST_EXTRA[dcode])?) as usize;
                            if dist == 0 || dist > out.len() {
                                return Err(bad("distance beyond window"));
                            }
                            let start = out.len() - dist;
                            for k in 0..len {
                                let b = out[start + k];
                                out.push(b);
                            }
                        }
                        _ => return Err(bad("invalid literal/length symbol")),
                    }
                },
                2 => return Err(bad("dynamic-Huffman blocks unsupported in legacy inflate")),
                _ => return Err(bad("reserved block type")),
            }
            if bfinal == 1 {
                return Ok(out);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Public reader/writer wrappers (the `flate2` API surface we use)
// ---------------------------------------------------------------------------

pub mod write {
    use std::io::{self, Write};

    use crate::Compression;

    /// Buffers everything written, emits one raw-DEFLATE stream on
    /// [`DeflateEncoder::finish`].
    pub struct DeflateEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
        level: Compression,
    }

    impl<W: Write> DeflateEncoder<W> {
        pub fn new(inner: W, level: Compression) -> DeflateEncoder<W> {
            DeflateEncoder { inner, buf: Vec::new(), level }
        }

        pub fn finish(mut self) -> io::Result<W> {
            let packed = crate::compress(&self.buf, self.level);
            self.inner.write_all(&packed)?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for DeflateEncoder<W> {
        fn write(&mut self, data: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(data);
            Ok(data.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use std::io::{self, Read};

    /// Reads the whole compressed stream on first use, inflates, then
    /// serves plain bytes.
    pub struct DeflateDecoder<R: Read> {
        inner: Option<R>,
        out: Vec<u8>,
        pos: usize,
    }

    impl<R: Read> DeflateDecoder<R> {
        pub fn new(inner: R) -> DeflateDecoder<R> {
            DeflateDecoder { inner: Some(inner), out: Vec::new(), pos: 0 }
        }

        fn fill(&mut self) -> io::Result<()> {
            if let Some(mut r) = self.inner.take() {
                let mut raw = Vec::new();
                r.read_to_end(&mut raw)?;
                self.out = crate::inflate(&raw, usize::MAX)?;
            }
            Ok(())
        }
    }

    impl<R: Read> Read for DeflateDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.fill()?;
            let n = (self.out.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.out[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE, reflected) — `flate2::Crc` surface
// ---------------------------------------------------------------------------

pub struct Crc {
    state: u32,
    amount: u32,
}

impl Crc {
    pub fn new() -> Crc {
        Crc { state: 0xFFFF_FFFF, amount: 0 }
    }

    pub fn update(&mut self, data: &[u8]) {
        for &b in data {
            self.state ^= b as u32;
            for _ in 0..8 {
                let mask = 0u32.wrapping_sub(self.state & 1);
                self.state = (self.state >> 1) ^ (0xEDB8_8320 & mask);
            }
        }
        self.amount = self.amount.wrapping_add(data.len() as u32);
    }

    pub fn sum(&self) -> u32 {
        !self.state
    }

    pub fn amount(&self) -> u32 {
        self.amount
    }

    pub fn reset(&mut self) {
        *self = Crc::new();
    }
}

impl Default for Crc {
    fn default() -> Crc {
        Crc::new()
    }
}

#[cfg(test)]
mod tests {
    use std::io::{Read, Write};

    use super::*;

    /// Deterministic xorshift-ish byte stream for test corpora.
    struct TestRng(u64);

    impl TestRng {
        fn byte(&mut self) -> u8 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1);
            (self.0 >> 56) as u8
        }

        fn below(&mut self, n: usize) -> usize {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((self.0 >> 33) as usize) % n
        }
    }

    fn roundtrip_at(data: &[u8], level: u32) {
        let packed = compress(data, Compression::new(level));
        let back = decompress(&packed).unwrap();
        assert_eq!(back, data, "len {} level {level}", data.len());
    }

    fn roundtrip(data: &[u8]) {
        for level in [0, 1, 6, 9] {
            roundtrip_at(data, level);
        }
        // The streaming wrappers agree with the one-shot entry points.
        let mut enc = write::DeflateEncoder::new(Vec::new(), Compression::default());
        enc.write_all(data).unwrap();
        let packed = enc.finish().unwrap();
        let mut back = Vec::new();
        read::DeflateDecoder::new(&packed[..]).read_to_end(&mut back).unwrap();
        assert_eq!(back, data, "wrapper len {}", data.len());
    }

    #[test]
    fn roundtrip_empty_and_small() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"hello, deflate");
        roundtrip(b"abcabcabcabc");
    }

    #[test]
    fn roundtrip_all_byte_values() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        roundtrip(&data);
    }

    #[test]
    fn match_len_twins_agree_and_forced_scalar_output_is_identical() {
        // Direct kernel differential: mismatch positions swept across the
        // 32-byte block boundaries, with caps below/at/above one block.
        let mut rng = TestRng(0xC0FFEE);
        let base: Vec<u8> = (0..512).map(|_| rng.byte()).collect();
        let mut data = base.clone();
        data.extend_from_slice(&base);
        for mis in [0usize, 1, 31, 32, 33, 63, 64, 65, 255, 256, 511] {
            let saved = data[512 + mis];
            data[512 + mis] = saved.wrapping_add(1);
            for max_l in [0usize, 1, 31, 32, 33, 64, 65, 258, 512] {
                let want = match_len_scalar(&data, 0, 512, max_l);
                assert_eq!(want, mis.min(max_l), "scalar twin sanity");
                let got = match_len(&data, 0, 512, max_l);
                assert_eq!(got, want, "mis={mis} max_l={max_l}");
            }
            data[512 + mis] = saved;
        }
        // End-to-end: the emitted stream must be byte-identical with the
        // match loop pinned scalar (dispatch is a wall-clock knob only).
        let corpora: Vec<Vec<u8>> = vec![
            (0..50_000).map(|_| rng.byte()).collect(),
            b"abcabcabcabc".repeat(2000),
            vec![0u8; 10_000],
        ];
        for data in &corpora {
            for level in [1u32, 6, 9] {
                set_force_scalar(true);
                let scalar = compress(data, Compression::new(level));
                set_force_scalar(false);
                let auto = compress(data, Compression::new(level));
                assert_eq!(scalar, auto, "len {} level {level}", data.len());
                assert_eq!(decompress(&scalar).unwrap(), *data);
            }
        }
        set_force_scalar(false);
    }

    #[test]
    fn roundtrip_multi_block_stored() {
        // Uniform-random bytes keep the stored path competitive; > 65535
        // forces multiple chunks.
        let mut rng = TestRng(0x12345678);
        let data: Vec<u8> = (0..200_000).map(|_| rng.byte()).collect();
        roundtrip(&data);
    }

    #[test]
    fn roundtrip_structured() {
        // Repeated text exercises LZ77 matches + dynamic blocks.
        let data: Vec<u8> =
            b"the quick brown fox jumps over the lazy dog. ".repeat(200);
        roundtrip(&data);
        // Small alphabet forces a heavily skewed dynamic tree.
        let mut rng = TestRng(7);
        let data: Vec<u8> = (0..5000).map(|_| b"abcd"[rng.below(4)]).collect();
        roundtrip(&data);
        // Long runs spanning block-token boundaries.
        let mut data = Vec::new();
        let mut rng = TestRng(9);
        while data.len() < 150_000 {
            let b = rng.byte();
            let run = 1 + rng.below(60);
            data.resize(data.len() + run, b);
        }
        roundtrip(&data);
    }

    #[test]
    fn compresses_repetitive_payloads() {
        let data = vec![3u8; 10_000];
        let packed = compress(&data, Compression::default());
        // LZ77 + dynamic coding must crush a constant run far below the
        // fixed-only baseline.
        let baseline = legacy::deflate_fixed_only(&data);
        assert!(packed.len() < 100, "{} bytes for 10k constant run", packed.len());
        assert!(packed.len() < baseline.len());
        assert_eq!(decompress(&packed).unwrap(), data);
    }

    #[test]
    fn dynamic_beats_fixed_on_skewed_varints() {
        // Varint-delta-like payload (the index-coding workload): bytes
        // with the high bit split ~30/70 and small second-byte values.
        let mut rng = TestRng(0xA5);
        let data: Vec<u8> = (0..8192)
            .map(|i| {
                if i % 3 == 0 {
                    0x80 | (rng.below(128) as u8)
                } else {
                    rng.below(40) as u8
                }
            })
            .collect();
        let new = compress(&data, Compression::default());
        let old = legacy::deflate_fixed_only(&data);
        assert!(new.len() < old.len(), "dynamic {} !< fixed {}", new.len(), old.len());
        assert_eq!(decompress(&new).unwrap(), data);
    }

    #[test]
    fn legacy_and_new_inflate_agree_on_fixed_streams() {
        // Differential: every fixed/stored stream the legacy encoder emits
        // must inflate bit-identically under both decoders.
        let mut rng = TestRng(0x5EED);
        for case in 0..50 {
            let n = rng.below(3000);
            let data: Vec<u8> = match case % 3 {
                0 => (0..n).map(|_| rng.byte()).collect(),
                1 => (0..n).map(|_| rng.below(16) as u8).collect(),
                _ => (0..n).map(|_| 0x80 | (rng.below(64) as u8)).collect(),
            };
            let packed = legacy::deflate_fixed_only(&data);
            let a = legacy::inflate_fixed_only(&packed).unwrap();
            let b = decompress(&packed).unwrap();
            assert_eq!(a, data, "case {case}");
            assert_eq!(b, data, "case {case}");
        }
    }

    #[test]
    fn inflate_decodes_external_dynamic_stream() {
        // Raw-DEFLATE stream produced by zlib (level 9, windowBits -15):
        // one dynamic-Huffman block with LZ77 matches.  Conformance anchor
        // for the dynamic decode path against a stream we did not emit.
        let msg: Vec<u8> =
            b"Learned Gradient Compression entropy-codes the transferred \
              indices with DEFLATE; "
                .repeat(4);
        let vector: [u8; 82] = [
            0xE5, 0x8C, 0xB1, 0x0D, 0x80, 0x30, 0x0C, 0xC0, 0x5E, 0xC9, 0x03, 0x5C, 0xC0, 0x84,
            0xA0, 0xB0, 0x74, 0xE4, 0x81, 0xAA, 0x09, 0x6A, 0x06, 0x92, 0x2A, 0x89, 0x84, 0xF8,
            0x9E, 0xFE, 0xC1, 0x68, 0x4B, 0x76, 0xA6, 0x62, 0x42, 0x08, 0x87, 0x15, 0x64, 0x92,
            0x80, 0x55, 0xEF, 0x6E, 0xE4, 0xCE, 0x2A, 0x30, 0xD8, 0xB4, 0xBF, 0x53, 0x55, 0x24,
            0x87, 0x68, 0x04, 0x61, 0x45, 0xFC, 0x22, 0xB3, 0x91, 0xB0, 0x20, 0xD7, 0xE1, 0x1F,
            0x8E, 0x06, 0x5B, 0xDA, 0xF3, 0x72, 0xA6, 0x19, 0xF2, 0xFF, 0x86, 0x1F,
        ];
        assert_eq!((vector[0] >> 1) & 3, 2, "vector must start with a dynamic block");
        assert_eq!(decompress(&vector).unwrap(), msg);
        // The legacy decoder must reject it (that was the old limitation).
        assert!(legacy::inflate_fixed_only(&vector).is_err());
    }

    #[test]
    fn new_inflate_decodes_legacy_output_and_vice_versa() {
        let mut rng = TestRng(44);
        let data: Vec<u8> = (0..2048).map(|_| rng.below(32) as u8).collect();
        // old encoder -> new decoder
        assert_eq!(decompress(&legacy::deflate_fixed_only(&data)).unwrap(), data);
        // new encoder at level 0 (stored) -> old decoder
        let stored = compress(&data, Compression::new(0));
        assert_eq!(legacy::inflate_fixed_only(&stored).unwrap(), data);
    }

    #[test]
    fn scratch_reuse_is_stable() {
        // Same scratch across many different payloads: identical output to
        // a fresh-scratch run (stale hash-chain state must never leak).
        let mut rng = TestRng(0xCAFE);
        let mut scratch = DeflateScratch::new();
        for _ in 0..30 {
            let n = rng.below(5000);
            let data: Vec<u8> = (0..n).map(|_| rng.below(50) as u8).collect();
            let mut out_reused = Vec::new();
            compress_into(&data, Compression::default(), &mut scratch, &mut out_reused);
            let out_fresh = compress(&data, Compression::default());
            assert_eq!(out_reused, out_fresh);
            assert_eq!(decompress(&out_reused).unwrap(), data);
        }
    }

    #[test]
    fn inflate_never_panics_on_garbage() {
        let mut rng = TestRng(0xF422);
        for _ in 0..2000 {
            let n = rng.below(200);
            let blob: Vec<u8> = (0..n).map(|_| rng.byte()).collect();
            let _ = decompress(&blob); // Ok or Err, never panic
            let _ = legacy::inflate_fixed_only(&blob);
        }
    }

    #[test]
    fn inflate_handles_lz77_matches() {
        // Hand-built fixed-Huffman block: "abc" + <len 6, dist 3> + EOB
        // => "abcabcabc"; decodable by both decoders.
        let mut w = legacy::BitWriter::new();
        w.write_bits(1, 1);
        w.write_bits(1, 2);
        for &b in b"abc" {
            let (c, l) = legacy::fixed_lit_code(b as u32);
            w.write_huffman(c, l);
        }
        let (c, l) = legacy::fixed_lit_code(260); // length symbol 260 = base 6
        w.write_huffman(c, l);
        w.write_huffman(2, 5); // distance code 2 = dist 3
        let (c, l) = legacy::fixed_lit_code(256);
        w.write_huffman(c, l);
        let packed = w.finish();
        assert_eq!(legacy::inflate_fixed_only(&packed).unwrap(), b"abcabcabc");
        assert_eq!(decompress(&packed).unwrap(), b"abcabcabc");
    }

    #[test]
    fn huffman_lengths_are_complete_and_bounded() {
        // Kraft equality + max-length bound over adversarial frequency
        // sets (Fibonacci weights force the overflow-adjustment path).
        let mut rng = TestRng(3);
        for trial in 0..500 {
            let n = 2 + rng.below(60);
            let mut freqs = vec![0u32; n];
            match trial % 3 {
                0 => {
                    for f in freqs.iter_mut() {
                        *f = rng.below(1000) as u32;
                    }
                }
                1 => {
                    for f in freqs.iter_mut() {
                        *f = 1u32 << rng.below(30);
                    }
                }
                _ => {
                    let (mut a, mut b) = (1u64, 1u64);
                    for f in freqs.iter_mut() {
                        *f = a.min(u32::MAX as u64) as u32;
                        let c = a + b;
                        a = b;
                        b = c;
                    }
                }
            }
            if freqs.iter().filter(|&&f| f > 0).count() < 2 {
                continue;
            }
            for max_len in [7usize, 15] {
                let mut lengths = vec![0u8; n];
                build_lengths(&freqs, max_len, &mut lengths);
                let mut kraft = 0f64;
                for (s, &l) in lengths.iter().enumerate() {
                    assert!((l as usize) <= max_len, "trial {trial}");
                    if freqs[s] > 0 {
                        assert!(l > 0, "trial {trial}: used symbol got no code");
                        kraft += (2f64).powi(-(l as i32));
                    } else {
                        assert_eq!(l, 0, "trial {trial}");
                    }
                }
                assert!((kraft - 1.0).abs() < 1e-12, "trial {trial}: kraft {kraft}");
            }
        }
    }

    #[test]
    fn decompress_limited_caps_expansion() {
        let data = vec![7u8; 100_000];
        let packed = compress(&data, Compression::default());
        assert!(packed.len() < 1000, "run should crush");
        // Under the cap: decodes fully.
        assert_eq!(decompress_limited(&packed, 100_000).unwrap(), data);
        // Over the cap: errors instead of allocating the expansion.
        assert!(decompress_limited(&packed, 50_000).is_err());
        assert!(decompress_limited(&packed, 0).is_err());
        // Stored streams respect the cap too.
        let stored = compress(&data[..1000], Compression::new(0));
        assert!(decompress_limited(&stored, 999).is_err());
        assert_eq!(decompress_limited(&stored, 1000).unwrap(), &data[..1000]);
    }

    #[test]
    fn crc32_known_vector() {
        let mut crc = Crc::new();
        crc.update(b"123456789");
        assert_eq!(crc.sum(), 0xCBF4_3926);
        assert_eq!(crc.amount(), 9);
    }

    #[test]
    fn truncated_stream_errors() {
        let mut enc = write::DeflateEncoder::new(Vec::new(), Compression::default());
        enc.write_all(&[7u8; 500]).unwrap();
        let packed = enc.finish().unwrap();
        let mut out = Vec::new();
        assert!(read::DeflateDecoder::new(&packed[..packed.len() / 2])
            .read_to_end(&mut out)
            .is_err());
    }
}
