//! Offline stand-in for the `flate2` crate (vendored; DESIGN.md §7).
//!
//! Implements the subset the `lgc` workspace uses — raw-DEFLATE encode /
//! decode (`write::DeflateEncoder`, `read::DeflateDecoder`) and [`Crc`] —
//! with no C dependency and no crates.io access.
//!
//! The encoder emits RFC 1951-conformant streams built from stored and
//! fixed-Huffman blocks, choosing whichever is smaller for the payload.
//! The decoder inflates stored and fixed-Huffman blocks, including LZ77
//! length/distance pairs, so any conformant fixed/stored stream decodes;
//! dynamic-Huffman blocks are rejected (this pair only ever decodes its
//! own output inside the workspace).  Swapping in the real crate is a
//! one-line `Cargo.toml` change; the byte-accounting tests only assume
//! round-tripping plus "sparse index payloads beat raw u32", both of
//! which hold for fixed-Huffman coding of delta varints.

use std::io;

/// Compression level knob (accepted for API compatibility; the block-type
/// choice here is size-driven, not level-driven).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(u32);

impl Compression {
    pub const fn new(level: u32) -> Compression {
        Compression(level)
    }

    pub const fn level(self) -> u32 {
        self.0
    }
}

impl Default for Compression {
    fn default() -> Compression {
        Compression(6)
    }
}

// ---------------------------------------------------------------------------
// Bit-level I/O (DEFLATE packs fields LSB-first; Huffman codes MSB-first)
// ---------------------------------------------------------------------------

struct BitWriter {
    out: Vec<u8>,
    bit_buf: u32,
    bit_count: u32,
}

impl BitWriter {
    fn new() -> BitWriter {
        BitWriter { out: Vec::new(), bit_buf: 0, bit_count: 0 }
    }

    /// Write `n` (1..=16) bits of `value`, least-significant bit first.
    fn write_bits(&mut self, value: u32, n: u32) {
        debug_assert!((1..=16).contains(&n) && (value >> n) == 0);
        self.bit_buf |= value << self.bit_count;
        self.bit_count += n;
        while self.bit_count >= 8 {
            self.out.push((self.bit_buf & 0xff) as u8);
            self.bit_buf >>= 8;
            self.bit_count -= 8;
        }
    }

    /// Write a Huffman code: codes are defined most-significant-bit first.
    fn write_huffman(&mut self, code: u32, len: u32) {
        let mut rev = 0u32;
        for i in 0..len {
            rev |= ((code >> i) & 1) << (len - 1 - i);
        }
        self.write_bits(rev, len);
    }

    fn finish(mut self) -> Vec<u8> {
        if self.bit_count > 0 {
            self.out.push((self.bit_buf & 0xff) as u8);
        }
        self.out
    }
}

struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    bit_buf: u32,
    bit_count: u32,
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("deflate: {msg}"))
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> BitReader<'a> {
        BitReader { data, pos: 0, bit_buf: 0, bit_count: 0 }
    }

    fn read_bits(&mut self, n: u32) -> io::Result<u32> {
        debug_assert!(n <= 16);
        while self.bit_count < n {
            let b = *self.data.get(self.pos).ok_or_else(|| bad("unexpected end of stream"))?;
            self.pos += 1;
            self.bit_buf |= (b as u32) << self.bit_count;
            self.bit_count += 8;
        }
        let v = self.bit_buf & ((1u32 << n) - 1);
        self.bit_buf >>= n;
        self.bit_count -= n;
        Ok(v)
    }

    /// Read a Huffman-ordered (MSB-first) code of `n` bits.
    fn read_huffman_bits(&mut self, n: u32) -> io::Result<u32> {
        let mut code = 0u32;
        for _ in 0..n {
            code = (code << 1) | self.read_bits(1)?;
        }
        Ok(code)
    }

    /// Discard bits up to the next byte boundary (stored-block headers).
    fn align_byte(&mut self) {
        let drop = self.bit_count % 8;
        self.bit_buf >>= drop;
        self.bit_count -= drop;
    }
}

// ---------------------------------------------------------------------------
// Fixed-Huffman tables (RFC 1951 §3.2.6)
// ---------------------------------------------------------------------------

/// (code, length) of literal/length symbol `sym` in the fixed tree.
fn fixed_lit_code(sym: u32) -> (u32, u32) {
    match sym {
        0..=143 => (0x30 + sym, 8),
        144..=255 => (0x190 + (sym - 144), 9),
        256..=279 => (sym - 256, 7),
        _ => (0xC0 + (sym - 280), 8),
    }
}

const LEN_BASE: [u32; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u32; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u32; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u32; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];

// ---------------------------------------------------------------------------
// Encoder
// ---------------------------------------------------------------------------

fn stored_size(n: usize) -> usize {
    // Per stored block: 1 header byte (3 bits + pad) + 4 bytes LEN/NLEN.
    if n == 0 {
        return 5;
    }
    n.div_ceil(65_535) * 5 + n
}

fn fixed_size(data: &[u8]) -> usize {
    let mut bits = 3usize + 7; // block header + end-of-block code
    for &b in data {
        bits += if b < 144 { 8 } else { 9 };
    }
    bits.div_ceil(8)
}

fn encode_stored(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(stored_size(data.len()));
    let mut chunks: Vec<&[u8]> = data.chunks(65_535).collect();
    if chunks.is_empty() {
        chunks.push(&[]);
    }
    let last = chunks.len() - 1;
    for (i, chunk) in chunks.iter().enumerate() {
        // BFINAL in bit 0, BTYPE=00, then padding to the byte boundary.
        out.push(u8::from(i == last));
        let len = chunk.len() as u16;
        out.extend_from_slice(&len.to_le_bytes());
        out.extend_from_slice(&(!len).to_le_bytes());
        out.extend_from_slice(chunk);
    }
    out
}

fn encode_fixed(data: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.write_bits(1, 1); // BFINAL
    w.write_bits(1, 2); // BTYPE = 01 (fixed Huffman)
    for &b in data {
        let (code, len) = fixed_lit_code(b as u32);
        w.write_huffman(code, len);
    }
    let (code, len) = fixed_lit_code(256);
    w.write_huffman(code, len);
    w.finish()
}

/// Raw-DEFLATE compress: pick the smaller of a stored and a fixed-Huffman
/// encoding (both conformant; no LZ77 search — callers in this workspace
/// pre-compact with delta+varint coding, where match search buys little).
fn deflate(data: &[u8]) -> Vec<u8> {
    if fixed_size(data) <= stored_size(data.len()) {
        encode_fixed(data)
    } else {
        encode_stored(data)
    }
}

// ---------------------------------------------------------------------------
// Decoder
// ---------------------------------------------------------------------------

fn read_fixed_symbol(r: &mut BitReader) -> io::Result<u32> {
    let mut code = r.read_huffman_bits(7)?;
    if code <= 0b001_0111 {
        return Ok(256 + code);
    }
    code = (code << 1) | r.read_bits(1)?;
    if (0x30..=0xBF).contains(&code) {
        return Ok(code - 0x30);
    }
    if (0xC0..=0xC7).contains(&code) {
        return Ok(280 + (code - 0xC0));
    }
    code = (code << 1) | r.read_bits(1)?;
    if (0x190..=0x1FF).contains(&code) {
        return Ok(144 + (code - 0x190));
    }
    Err(bad("invalid fixed-Huffman code"))
}

fn inflate(data: &[u8]) -> io::Result<Vec<u8>> {
    let mut r = BitReader::new(data);
    let mut out = Vec::new();
    loop {
        let bfinal = r.read_bits(1)?;
        match r.read_bits(2)? {
            0 => {
                r.align_byte();
                let len = r.read_bits(16)?;
                let nlen = r.read_bits(16)?;
                if len ^ nlen != 0xFFFF {
                    return Err(bad("stored-block LEN/NLEN mismatch"));
                }
                out.reserve(len as usize);
                for _ in 0..len {
                    out.push(r.read_bits(8)? as u8);
                }
            }
            1 => loop {
                let sym = read_fixed_symbol(&mut r)?;
                match sym {
                    0..=255 => out.push(sym as u8),
                    256 => break,
                    257..=285 => {
                        let i = (sym - 257) as usize;
                        let len = (LEN_BASE[i] + r.read_bits(LEN_EXTRA[i])?) as usize;
                        let dcode = r.read_huffman_bits(5)? as usize;
                        if dcode >= DIST_BASE.len() {
                            return Err(bad("invalid distance code"));
                        }
                        let dist = (DIST_BASE[dcode] + r.read_bits(DIST_EXTRA[dcode])?) as usize;
                        if dist == 0 || dist > out.len() {
                            return Err(bad("distance beyond window"));
                        }
                        let start = out.len() - dist;
                        for k in 0..len {
                            let b = out[start + k];
                            out.push(b);
                        }
                    }
                    _ => return Err(bad("invalid literal/length symbol")),
                }
            },
            2 => return Err(bad("dynamic-Huffman blocks unsupported in offline inflate")),
            _ => return Err(bad("reserved block type")),
        }
        if bfinal == 1 {
            return Ok(out);
        }
    }
}

// ---------------------------------------------------------------------------
// Public reader/writer wrappers (the `flate2` API surface we use)
// ---------------------------------------------------------------------------

pub mod write {
    use std::io::{self, Write};

    use crate::Compression;

    /// Buffers everything written, emits one raw-DEFLATE stream on
    /// [`DeflateEncoder::finish`].
    pub struct DeflateEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> DeflateEncoder<W> {
        pub fn new(inner: W, _level: Compression) -> DeflateEncoder<W> {
            DeflateEncoder { inner, buf: Vec::new() }
        }

        pub fn finish(mut self) -> io::Result<W> {
            let packed = crate::deflate(&self.buf);
            self.inner.write_all(&packed)?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for DeflateEncoder<W> {
        fn write(&mut self, data: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(data);
            Ok(data.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use std::io::{self, Read};

    /// Reads the whole compressed stream on first use, inflates, then
    /// serves plain bytes.
    pub struct DeflateDecoder<R: Read> {
        inner: Option<R>,
        out: Vec<u8>,
        pos: usize,
    }

    impl<R: Read> DeflateDecoder<R> {
        pub fn new(inner: R) -> DeflateDecoder<R> {
            DeflateDecoder { inner: Some(inner), out: Vec::new(), pos: 0 }
        }

        fn fill(&mut self) -> io::Result<()> {
            if let Some(mut r) = self.inner.take() {
                let mut raw = Vec::new();
                r.read_to_end(&mut raw)?;
                self.out = crate::inflate(&raw)?;
            }
            Ok(())
        }
    }

    impl<R: Read> Read for DeflateDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.fill()?;
            let n = (self.out.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.out[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE, reflected) — `flate2::Crc` surface
// ---------------------------------------------------------------------------

pub struct Crc {
    state: u32,
    amount: u32,
}

impl Crc {
    pub fn new() -> Crc {
        Crc { state: 0xFFFF_FFFF, amount: 0 }
    }

    pub fn update(&mut self, data: &[u8]) {
        for &b in data {
            self.state ^= b as u32;
            for _ in 0..8 {
                let mask = 0u32.wrapping_sub(self.state & 1);
                self.state = (self.state >> 1) ^ (0xEDB8_8320 & mask);
            }
        }
        self.amount = self.amount.wrapping_add(data.len() as u32);
    }

    pub fn sum(&self) -> u32 {
        !self.state
    }

    pub fn amount(&self) -> u32 {
        self.amount
    }

    pub fn reset(&mut self) {
        *self = Crc::new();
    }
}

impl Default for Crc {
    fn default() -> Crc {
        Crc::new()
    }
}

#[cfg(test)]
mod tests {
    use std::io::{Read, Write};

    use super::*;

    fn roundtrip(data: &[u8]) {
        let mut enc = write::DeflateEncoder::new(Vec::new(), Compression::default());
        enc.write_all(data).unwrap();
        let packed = enc.finish().unwrap();
        let mut back = Vec::new();
        read::DeflateDecoder::new(&packed[..]).read_to_end(&mut back).unwrap();
        assert_eq!(back, data, "len {}", data.len());
    }

    #[test]
    fn roundtrip_empty_and_small() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"hello, deflate");
    }

    #[test]
    fn roundtrip_all_byte_values() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        roundtrip(&data);
    }

    #[test]
    fn roundtrip_multi_block_stored() {
        // Uniform-random bytes force the stored path; > 65535 forces
        // multiple blocks.
        let mut state = 0x12345678u64;
        let data: Vec<u8> = (0..200_000)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                (state >> 56) as u8
            })
            .collect();
        roundtrip(&data);
    }

    #[test]
    fn small_bytes_compress() {
        // Delta-varint-like payloads (small byte values) must shrink below
        // raw size: that is the property the index-coding tests rely on.
        let data = vec![3u8; 10_000];
        let mut enc = write::DeflateEncoder::new(Vec::new(), Compression::default());
        enc.write_all(&data).unwrap();
        let packed = enc.finish().unwrap();
        assert!(packed.len() < data.len(), "{} !< {}", packed.len(), data.len());
    }

    #[test]
    fn inflate_handles_lz77_matches() {
        // Hand-built fixed-Huffman block: "abc" + <len 6, dist 3> + EOB
        // => "abcabcabc".
        let mut w = BitWriter::new();
        w.write_bits(1, 1);
        w.write_bits(1, 2);
        for &b in b"abc" {
            let (c, l) = fixed_lit_code(b as u32);
            w.write_huffman(c, l);
        }
        let (c, l) = fixed_lit_code(260); // length symbol 260 = base 6
        w.write_huffman(c, l);
        w.write_huffman(2, 5); // distance code 2 = dist 3
        let (c, l) = fixed_lit_code(256);
        w.write_huffman(c, l);
        let packed = w.finish();
        assert_eq!(inflate(&packed).unwrap(), b"abcabcabc");
    }

    #[test]
    fn crc32_known_vector() {
        let mut crc = Crc::new();
        crc.update(b"123456789");
        assert_eq!(crc.sum(), 0xCBF4_3926);
        assert_eq!(crc.amount(), 9);
    }

    #[test]
    fn truncated_stream_errors() {
        let mut enc = write::DeflateEncoder::new(Vec::new(), Compression::default());
        enc.write_all(&[7u8; 500]).unwrap();
        let packed = enc.finish().unwrap();
        let mut out = Vec::new();
        assert!(read::DeflateDecoder::new(&packed[..packed.len() / 2])
            .read_to_end(&mut out)
            .is_err());
    }
}
