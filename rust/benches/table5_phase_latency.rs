//! Bench: regenerate Table V — per-phase iteration duration for the two
//! LGC instances (paper: seconds/iter on 8 GPU-simulated nodes; here:
//! ms/iter on the CPU-PJRT testbed; the *relative* phase ordering is the
//! reproduced claim: compressed < full < top-k for PS, and RAR phases
//! uniformly cheaper than PS phases).

use lgc::exp;
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let steps = exp::default_steps();
    let t = exp::table5(&engine, steps)?;
    let [ps, rar] = t;
    println!(
        "\nshape check: PS top-k ({:.1} ms) is the most expensive PS phase: {}",
        ps[1],
        ps[1] >= ps[0] && ps[1] >= ps[2]
    );
    println!(
        "shape check: RAR compressed ({:.1} ms) <= PS compressed ({:.1} ms): {}",
        rar[2],
        ps[2],
        rar[2] <= ps[2] * 1.25
    );
    Ok(())
}
