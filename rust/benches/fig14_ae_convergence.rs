//! Bench: regenerate Fig 14 — autoencoder reconstruction-loss convergence
//! during online training, with the similarity-loss (lambda_2) ablation.
//!
//! Reproduced claims: (a) the AE converges within the phase-2 window for
//! both patterns; (b) lambda_2 = 0.5 reconstructs better than lambda_2 = 0.

use lgc::exp;
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let steps = exp::default_steps();
    exp::fig14_ae(&engine, steps)?;
    Ok(())
}
