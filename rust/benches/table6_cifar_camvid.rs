//! Bench: regenerate Table VI — accuracy / info size / compression ratio
//! for three workloads x five methods (paper: ResNet50+ResNet101 on
//! Cifar10, PSPNet on CamVid; scaled per DESIGN.md §2).

use lgc::exp;
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let steps = exp::default_steps();
    exp::table6(&engine, steps)?;
    Ok(())
}
