//! Bench: hot-path microbenchmarks (the §Perf iteration targets).
//!
//! Times each building block of the steady-state (phase 3) iteration in
//! isolation so the optimization loop (EXPERIMENTS.md §Perf) can see where
//! per-iteration time goes:
//!   top-k select | index coding | sparsify scalar | ring allreduce |
//!   per-node pipeline K=8 sequential vs parallel | — and, when AOT
//!   artifacts + a PJRT backend are present — grad_step HLO, AE
//!   encode/decode, sparsify HLO, full phase-3 LGC iteration.
//!
//! The pure-CPU sections run everywhere (no artifacts needed); the
//! headline row is the K=8 node-pipeline comparison, which measures the
//! wall-clock win of the parallel node runtime (`coordinator::parallel`)
//! over the sequential per-node loop on the same work.

use lgc::compress::{index_coding, topk, Correction, FeedbackMemory};
use lgc::config::{Method, TrainConfig};
use lgc::coordinator::{parallel, ring};
use lgc::metrics::{Kind, Ledger, NodeLedger};
use lgc::runtime::{Engine, Tensor};
use lgc::util::bench::{time, time_budget, Stats, Table};
use lgc::util::rng::Rng;

fn fmt(s: &Stats) -> (String, String) {
    (format!("{:.3} ms", s.mean_ms()), format!("{:.3} ms", s.p95_ns / 1e6))
}

/// The K=8 per-node simulation pipeline: EF accumulate -> top-k select ->
/// index encode, per node, under `threads` workers.  Returns per-node
/// coded byte counts (kept observable so nothing is optimized away).
fn node_pipeline(
    threads: usize,
    fbs: &mut [FeedbackMemory],
    shards: &mut [NodeLedger],
    grads: &[Vec<f32>],
    k_sel: usize,
    n: usize,
) -> Vec<usize> {
    parallel::par_zip_mut(threads, fbs, shards, |node, fb, shard| {
        fb.accumulate(&grads[node]);
        let sel = fb.select_and_clear(k_sel);
        let coded = index_coding::encode(&sel.indices, n).unwrap().len();
        shard.record(Kind::Values, sel.values.len() * 4);
        shard.record(Kind::Indices, coded);
        coded
    })
}

fn pure_sections(t: &mut Table, n_mid: usize, mu: usize) {
    let mut rng = Rng::new(1);

    // top-k selection over the mid group.
    let g = rng.normal_vec(n_mid, 1.0);
    let s = time_budget(1_000, || {
        std::hint::black_box(topk::top_k(&g, mu));
    });
    let (a, b) = fmt(&s);
    t.row(&["top-k select".into(), a, b, format!("n={n_mid} k={mu}")]);

    // Index coding.
    let sel = topk::top_k(&g, mu);
    let s = time_budget(500, || {
        std::hint::black_box(index_coding::encode(&sel.indices, n_mid).unwrap());
    });
    let coded = index_coding::encode(&sel.indices, n_mid).unwrap().len();
    let (a, b) = fmt(&s);
    t.row(&["index encode (DEFLATE)".into(), a, b,
            format!("{} idx -> {} B", sel.indices.len(), coded)]);

    // Rust scalar sparsify reference (the Pallas kernel's contract).
    let acc = rng.normal_vec(n_mid, 0.5);
    let s = time_budget(500, || {
        let mut o1 = vec![0.0f32; n_mid];
        let mut o2 = vec![0.0f32; n_mid];
        for i in 0..n_mid {
            let u = g[i] + acc[i];
            if u.abs() >= 0.8 {
                o1[i] = u;
            } else {
                o2[i] = u;
            }
        }
        std::hint::black_box((o1, o2));
    });
    let (a, b) = fmt(&s);
    t.row(&["sparsify rust scalar".into(), a, b, "reference".into()]);

    // Ring allreduce on latent vectors (K = 8).
    let latents: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(mu / 4, 1.0)).collect();
    let s = time_budget(500, || {
        let mut work = latents.clone();
        let mut ledger = Ledger::new();
        std::hint::black_box(ring::ring_allreduce_sum(&mut work, &mut ledger, Kind::Latent));
    });
    let (a, b) = fmt(&s);
    t.row(&["ring allreduce latents K=8".into(), a, b, format!("len={}", mu / 4)]);
}

/// Sequential vs parallel per-node simulation at K=8 — the tentpole's
/// acceptance measurement.  Returns (seq_ms, par_ms).
fn node_loop_comparison(t: &mut Table, n: usize) -> (f64, f64) {
    const K: usize = 8;
    let mut rng = Rng::new(7);
    let k_sel = topk::k_of(n, 0.01);
    let grads: Vec<Vec<f32>> = (0..K).map(|_| rng.normal_vec(n, 1.0)).collect();

    let run = |threads: usize| -> Stats {
        let mut fbs: Vec<FeedbackMemory> = (0..K)
            .map(|_| FeedbackMemory::new(n, Correction::Momentum, 0.9))
            .collect();
        let mut shards = NodeLedger::for_nodes(K);
        let mut ledger = Ledger::new();
        time(2, 12, || {
            let coded =
                node_pipeline(threads, &mut fbs, &mut shards, &grads, k_sel, n);
            ledger.merge_shards(&mut shards);
            ledger.end_iteration();
            std::hint::black_box(coded);
        })
    };

    let seq = run(1);
    let par = run(0); // 0 = one worker per core
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let speedup = seq.mean_ms() / par.mean_ms();
    let (a, b) = fmt(&seq);
    t.row(&["node pipeline K=8 sequential".into(), a, b,
            format!("n={n} k={k_sel} x8 nodes")]);
    let (a, b) = fmt(&par);
    t.row(&["node pipeline K=8 parallel".into(), a, b,
            format!("{cores} cores -> {speedup:.2}x speedup")]);
    println!(
        "node-pipeline K=8: sequential {:.3} ms/iter, parallel {:.3} ms/iter \
         ({speedup:.2}x on {cores} cores)",
        seq.mean_ms(),
        par.mean_ms()
    );
    if cores >= 4 && speedup < 2.0 {
        eprintln!(
            "WARNING: expected >=2x parallel speedup at K=8 on a {cores}-core host, \
             measured {speedup:.2}x"
        );
    }
    (seq.mean_ms(), par.mean_ms())
}

fn engine_sections(engine: &Engine, t: &mut Table, model: &str) -> anyhow::Result<()> {
    use lgc::compress::autoencoder::{AeCompressor, Pattern};

    let meta = engine.manifest.model(model).clone();
    let mu = meta.mu;
    let n_mid = meta.n_mid;
    let mut rng = Rng::new(1);

    // grad_step HLO (the dominant compute).
    let m = lgc::model::Model::new(&meta, 7);
    let data = lgc::data::for_model(&meta, 8);
    let batch = data.batch(0, 0);
    m.grad_step(engine, &batch)?; // compile
    let s = time_budget(2_000, || {
        m.grad_step(engine, &batch).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&[format!("{model}_grad_step"), a, b, format!("n={}", meta.n_params)]);

    // AE encode / decode.
    let ae = AeCompressor::new(engine, mu, 2, Pattern::RingAllreduce, 3)?;
    let vals = rng.normal_vec(mu, 0.01);
    let (lat, sc) = ae.encode(engine, &vals)?;
    let s = time(3, 50, || {
        ae.encode(engine, &vals).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["AE encode (L1 conv1d)".into(), a, b,
            format!("mu={mu} (paper GPU: 0.007-0.01 ms)")]);
    let s = time(3, 50, || {
        ae.decode_rar(engine, &lat, sc).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["AE decode (L1 deconv1d)".into(), a, b,
            format!("mu={mu} (paper GPU: ~1 ms)")]);

    // Fused sparsify HLO (Pallas).
    let g = rng.normal_vec(n_mid, 1.0);
    let acc = rng.normal_vec(n_mid, 0.5);
    let gt = Tensor::f32(vec![n_mid], g);
    let at = Tensor::f32(vec![n_mid], acc);
    let tt = Tensor::f32(vec![1], vec![0.8]);
    engine.run(&meta.sparsify, &[gt.clone(), at.clone(), tt.clone()])?;
    let s = time(3, 50, || {
        engine.run(&meta.sparsify, &[gt.clone(), at.clone(), tt.clone()]).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["sparsify HLO (Pallas)".into(), a, b, format!("n={n_mid}")]);

    // Full steady-state iteration (phase 3 only) — and the end-to-end
    // view of the parallel node runtime: identical config at 1 thread vs
    // one-per-core.
    for (label, threads) in [("1 thread", 1usize), ("per-core", 0)] {
        let cfg = TrainConfig {
            model: model.to_string(),
            method: Method::LgcPs,
            nodes: 8,
            steps: 14,
            warmup_iters: 2,
            ae_train_iters: 2,
            eval_every: 0,
            threads,
            ..Default::default()
        };
        let r = lgc::coordinator::train(engine, cfg)?;
        t.row(&[
            format!("full LGC-PS phase-3 iter K=8 ({label})"),
            format!("{:.3} ms", r.phase_time[2].as_secs_f64() * 1e3 / r.phase_iters[2] as f64),
            "-".into(),
            format!("{} iters", r.phase_iters[2]),
        ]);
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("LGC_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let engine = Engine::open_default().ok();

    // Workload sizes come from the manifest when available; otherwise use
    // resnet_mini-scale defaults so the pure-CPU rows still measure the
    // real operating point.
    let (n_mid, mu) = match &engine {
        Some(e) => {
            let meta = e.manifest.model(&model);
            (meta.n_mid, meta.mu)
        }
        None => (262_144, 4_096),
    };

    let mut t = Table::new(&["hot-path op", "mean", "p95", "notes"]);
    pure_sections(&mut t, n_mid, mu);
    node_loop_comparison(&mut t, 200_000);

    match &engine {
        Some(e) => engine_sections(e, &mut t, &model)?,
        None => println!(
            "(skipping PJRT sections: artifacts/backend unavailable — pure-CPU \
             rows above cover the coordinator hot path)"
        ),
    }

    println!("\n=== hot-path microbenchmarks ({model}) ===");
    t.print();
    t.write_csv("results/hotpath.csv")?;
    println!("-> results/hotpath.csv");
    Ok(())
}
