//! Bench: hot-path microbenchmarks (the §Perf iteration targets).
//!
//! Times each building block of the steady-state (phase 3) iteration in
//! isolation so the optimization loop (EXPERIMENTS.md §Perf) can see where
//! per-iteration time goes:
//!   grad_step HLO | top-k select | index coding | AE encode | AE decode |
//!   sparsify HLO | ring allreduce | full phase-3 LGC iteration

use lgc::compress::autoencoder::{AeCompressor, Pattern};
use lgc::compress::{index_coding, topk};
use lgc::config::{Method, TrainConfig};
use lgc::coordinator::ring;
use lgc::metrics::{Kind, Ledger};
use lgc::runtime::{Engine, Tensor};
use lgc::util::bench::{time, time_budget, Table};
use lgc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let model = std::env::var("LGC_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let meta = engine.manifest.model(&model).clone();
    let mu = meta.mu;
    let n_mid = meta.n_mid;
    let mut rng = Rng::new(1);
    let mut t = Table::new(&["hot-path op", "mean", "p95", "notes"]);
    let fmt = |s: &lgc::util::bench::Stats| {
        (format!("{:.3} ms", s.mean_ms()), format!("{:.3} ms", s.p95_ns / 1e6))
    };

    // grad_step HLO (the dominant compute).
    let m = lgc::model::Model::new(&meta, 7);
    let data = lgc::data::for_model(&meta, 8);
    let batch = data.batch(0, 0);
    m.grad_step(&engine, &batch)?; // compile
    let s = time_budget(2_000, || {
        m.grad_step(&engine, &batch).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&[format!("{model}_grad_step"), a, b, format!("n={}", meta.n_params)]);

    // top-k selection over the mid group.
    let g = rng.normal_vec(n_mid, 1.0);
    let s = time_budget(1_000, || {
        std::hint::black_box(topk::top_k(&g, mu));
    });
    let (a, b) = fmt(&s);
    t.row(&["top-k select".into(), a, b, format!("n={n_mid} k={mu}")]);

    // Index coding.
    let sel = topk::top_k(&g, mu);
    let s = time_budget(500, || {
        std::hint::black_box(index_coding::encode(&sel.indices, n_mid).unwrap());
    });
    let coded = index_coding::encode(&sel.indices, n_mid)?.len();
    let (a, b) = fmt(&s);
    t.row(&["index encode (DEFLATE)".into(), a, b,
            format!("{} idx -> {} B", sel.indices.len(), coded)]);

    // AE encode / decode.
    let ae = AeCompressor::new(&engine, mu, 2, Pattern::RingAllreduce, 3)?;
    let vals = rng.normal_vec(mu, 0.01);
    let (lat, sc) = ae.encode(&engine, &vals)?;
    let s = time(3, 50, || {
        ae.encode(&engine, &vals).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["AE encode (L1 conv1d)".into(), a, b,
            format!("mu={mu} (paper GPU: 0.007-0.01 ms)")]);
    let s = time(3, 50, || {
        ae.decode_rar(&engine, &lat, sc).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["AE decode (L1 deconv1d)".into(), a, b,
            format!("mu={mu} (paper GPU: ~1 ms)")]);

    // Fused sparsify HLO (Pallas) vs rust scalar reference.
    let acc = rng.normal_vec(n_mid, 0.5);
    let gt = Tensor::f32(vec![n_mid], g.clone());
    let at = Tensor::f32(vec![n_mid], acc.clone());
    let tt = Tensor::f32(vec![1], vec![0.8]);
    engine.run(&meta.sparsify, &[gt.clone(), at.clone(), tt.clone()])?;
    let s = time(3, 50, || {
        engine.run(&meta.sparsify, &[gt.clone(), at.clone(), tt.clone()]).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["sparsify HLO (Pallas)".into(), a, b, format!("n={n_mid}")]);
    let s = time_budget(500, || {
        let mut o1 = vec![0.0f32; n_mid];
        let mut o2 = vec![0.0f32; n_mid];
        for i in 0..n_mid {
            let u = g[i] + acc[i];
            if u.abs() >= 0.8 {
                o1[i] = u;
            } else {
                o2[i] = u;
            }
        }
        std::hint::black_box((o1, o2));
    });
    let (a, b) = fmt(&s);
    t.row(&["sparsify rust scalar".into(), a, b, "reference".into()]);

    // Ring allreduce on latent vectors (K = 8).
    let latents: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(mu / 4, 1.0)).collect();
    let s = time_budget(500, || {
        let mut work = latents.clone();
        let mut ledger = Ledger::new();
        std::hint::black_box(ring::ring_allreduce_sum(&mut work, &mut ledger, Kind::Latent));
    });
    let (a, b) = fmt(&s);
    t.row(&["ring allreduce latents K=8".into(), a, b, format!("len={}", mu / 4)]);

    // Full steady-state iteration (phase 3 only, measured via a run whose
    // phases are all compressed after a minimal warmup).
    let cfg = TrainConfig {
        model: model.clone(),
        method: Method::LgcPs,
        nodes: 2,
        steps: 14,
        warmup_iters: 2,
        ae_train_iters: 2,
        eval_every: 0,
        ..Default::default()
    };
    let r = lgc::coordinator::train(&engine, cfg)?;
    t.row(&[
        "full LGC-PS phase-3 iter (K=2)".into(),
        format!("{:.3} ms", r.phase_time[2].as_secs_f64() * 1e3 / r.phase_iters[2] as f64),
        "-".into(),
        format!("{} iters", r.phase_iters[2]),
    ]);

    println!("\n=== hot-path microbenchmarks ({model}) ===");
    t.print();
    t.write_csv("results/hotpath.csv")?;
    println!("-> results/hotpath.csv");
    Ok(())
}
