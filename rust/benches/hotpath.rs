//! Bench: hot-path microbenchmarks (the §Perf iteration targets).
//!
//! Times each building block of the steady-state (phase 3) iteration in
//! isolation so the optimization loop (EXPERIMENTS.md §Perf) can see where
//! per-iteration time goes:
//!   top-k select | index coding (fixed-only baseline vs LZ77+dynamic) |
//!   scalar-vs-SIMD kernel twins (DESIGN.md §16.1) | Golomb vs DEFLATE
//!   index rate + the auto-picker contract (§16.2) |
//!   sparsify scalar | ring allreduce | per-node pipeline K=8 sequential
//!   vs parallel | bucketed per-bucket encode + modeled overlap-on/off
//!   iteration at 50 Mbit/s (DESIGN.md §13) |
//!   — and, when AOT artifacts + a PJRT backend are present
//!   — grad_step HLO, AE encode/decode, sparsify HLO, full phase-3 LGC
//!   iteration.
//!
//! Besides the human-readable table (+ results/hotpath.csv), every run
//! emits machine-readable `BENCH_hotpath.json` at the repo root — median
//! ns/op and payload bytes per bench — so the bench trajectory is tracked
//! PR-over-PR.  `LGC_BENCH_SMOKE=1` shrinks the timing budgets for CI.
//!
//! The index-encode rows measure the tentpole: the PR-2-era
//! fixed-Huffman-only encoder (`index_coding::encode_fixed_baseline`,
//! fresh allocations) against the rewritten zero-allocation
//! LZ77+dynamic-Huffman path (`index_coding::encode_into` with a
//! persistent `Scratch`), over a corpus of operating points.

use std::collections::BTreeMap;

use lgc::compress::{index_coding, topk, Correction, FeedbackMemory, Scratch};
use lgc::config::{Method, TrainConfig};
use lgc::coordinator::{parallel, ring};
use lgc::metrics::{Kind, Ledger, NodeLedger};
use lgc::runtime::{Engine, Tensor};
use lgc::util::bench::{time, time_budget, Stats, Table};
use lgc::util::json::Json;
use lgc::util::rng::Rng;

/// One JSON entry: a named timing (and optionally a payload size).
struct JsonEntry {
    name: String,
    stats: Stats,
    bytes: Option<usize>,
}

struct JsonOut {
    smoke: bool,
    entries: Vec<JsonEntry>,
    /// (speedup_median, baseline_bytes_median, new_bytes_median)
    index_encode: Option<(f64, usize, usize)>,
    /// (avx2_active, per-kernel (name, scalar_median_ns, simd_median_ns))
    simd: Option<(bool, Vec<(String, f64, f64)>)>,
    /// (encode_speedup_vs_deflate, golomb/deflate/auto bytes medians)
    index_golomb: Option<(f64, usize, usize, usize)>,
}

impl JsonOut {
    fn push(&mut self, name: &str, stats: &Stats, bytes: Option<usize>) {
        self.entries.push(JsonEntry { name: name.into(), stats: stats.clone(), bytes });
    }

    fn write(&self, path: &str) -> std::io::Result<()> {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("hotpath".into()));
        root.insert("smoke".to_string(), Json::Bool(self.smoke));
        if let Some((speedup, old_b, new_b)) = self.index_encode {
            let mut ie = BTreeMap::new();
            ie.insert("speedup_median".to_string(), Json::Num(speedup));
            ie.insert("baseline_bytes_median".to_string(), Json::Num(old_b as f64));
            ie.insert("new_bytes_median".to_string(), Json::Num(new_b as f64));
            root.insert("index_encode".to_string(), Json::Obj(ie));
        }
        if let Some((avx2, kernels)) = &self.simd {
            let mut sd = BTreeMap::new();
            sd.insert("avx2".to_string(), Json::Bool(*avx2));
            let mut ks = BTreeMap::new();
            for (name, scalar_ns, simd_ns) in kernels {
                let mut k = BTreeMap::new();
                k.insert("scalar_median_ns".to_string(), Json::Num(*scalar_ns));
                k.insert("simd_median_ns".to_string(), Json::Num(*simd_ns));
                k.insert("ratio".to_string(), Json::Num(simd_ns / scalar_ns));
                ks.insert(name.clone(), Json::Obj(k));
            }
            sd.insert("kernels".to_string(), Json::Obj(ks));
            root.insert("simd".to_string(), Json::Obj(sd));
        }
        if let Some((speedup, gb, db, ab)) = self.index_golomb {
            let mut ig = BTreeMap::new();
            ig.insert("encode_speedup_vs_deflate_median".to_string(), Json::Num(speedup));
            ig.insert("golomb_bytes_median".to_string(), Json::Num(gb as f64));
            ig.insert("deflate_bytes_median".to_string(), Json::Num(db as f64));
            ig.insert("auto_bytes_median".to_string(), Json::Num(ab as f64));
            root.insert("index_golomb".to_string(), Json::Obj(ig));
        }
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(e.name.clone()));
                m.insert("median_ns".to_string(), Json::Num(e.stats.p50_ns));
                m.insert("mean_ns".to_string(), Json::Num(e.stats.mean_ns));
                m.insert("p95_ns".to_string(), Json::Num(e.stats.p95_ns));
                let bytes = match e.bytes {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                };
                m.insert("bytes".to_string(), bytes);
                Json::Obj(m)
            })
            .collect();
        root.insert("entries".to_string(), Json::Arr(entries));
        std::fs::write(path, format!("{}\n", Json::Obj(root)))
    }
}

fn fmt(s: &Stats) -> (String, String) {
    (format!("{:.3} ms", s.mean_ms()), format!("{:.3} ms", s.p95_ns / 1e6))
}

/// Timing budget (ms), shrunk under LGC_BENCH_SMOKE.
fn budget(smoke: bool, ms: u64) -> u64 {
    if smoke {
        (ms / 20).max(5)
    } else {
        ms
    }
}

/// Random sorted unique index set over [0, n) — the index-coding corpus
/// generator (same shape as the proptests').
fn random_indices(rng: &mut Rng, n: usize, k: usize) -> Vec<u32> {
    let mut set = std::collections::BTreeSet::new();
    while set.len() < k.min(n) {
        set.insert(rng.below(n) as u32);
    }
    set.into_iter().collect()
}

/// The K=8 per-node simulation pipeline: EF accumulate -> top-k select ->
/// index encode, per node, under `threads` workers, each node borrowing
/// its own scratch arena.  Returns per-node coded byte counts (kept
/// observable so nothing is optimized away).
fn node_pipeline(
    threads: usize,
    fbs: &mut [FeedbackMemory],
    shards: &mut [NodeLedger],
    arenas: &mut [Scratch],
    grads: &[Vec<f32>],
    k_sel: usize,
    n: usize,
) -> Vec<usize> {
    parallel::par_zip3_mut(threads, fbs, shards, arenas, |node, fb, shard, sc| {
        fb.accumulate(&grads[node]);
        fb.select_and_clear_into(k_sel, sc);
        let coded = index_coding::encode_into(&sc.idx, n, &mut sc.enc).unwrap().len();
        shard.record(Kind::Values, sc.vals.len() * 4);
        shard.record(Kind::Indices, coded);
        coded
    })
}

/// The tentpole's acceptance measurement: fixed-Huffman-only baseline vs
/// the LZ77+dynamic zero-allocation encoder, over the operating-point
/// corpus.  Returns (median speedup, median baseline bytes, median new
/// bytes).
fn index_encode_comparison(t: &mut Table, json: &mut JsonOut, smoke: bool) -> (f64, usize, usize) {
    let corpus: [(usize, usize); 4] =
        [(262_144, 4_096), (1_000_000, 1_000), (200_000, 2_000), (65_536, 8_192)];
    let mut speedups = Vec::new();
    let mut old_bytes = Vec::new();
    let mut new_bytes = Vec::new();
    let mut scratch = Scratch::new();
    for (ci, &(n, k)) in corpus.iter().enumerate() {
        let mut rng = Rng::new(0x1DE + ci as u64);
        let idx = random_indices(&mut rng, n, k);

        let s_old = time_budget(budget(smoke, 400), || {
            std::hint::black_box(index_coding::encode_fixed_baseline(&idx, n).unwrap());
        });
        let b_old = index_coding::encode_fixed_baseline(&idx, n).unwrap().len();

        let s_new = time_budget(budget(smoke, 400), || {
            std::hint::black_box(
                index_coding::encode_into(&idx, n, &mut scratch.enc).unwrap().len(),
            );
        });
        let b_new = index_coding::encode_into(&idx, n, &mut scratch.enc).unwrap().len();

        let speedup = s_old.p50_ns / s_new.p50_ns;
        speedups.push(speedup);
        old_bytes.push(b_old);
        new_bytes.push(b_new);

        let (a, b) = fmt(&s_old);
        t.row(&[
            format!("index encode fixed-only n={n} k={k}"),
            a,
            b,
            format!("{b_old} B (baseline)"),
        ]);
        let (a, b) = fmt(&s_new);
        t.row(&[
            format!("index encode LZ77+dyn  n={n} k={k}"),
            a,
            b,
            format!("{b_new} B, {speedup:.2}x vs baseline"),
        ]);
        json.push(&format!("index_encode_baseline_n{n}_k{k}"), &s_old, Some(b_old));
        json.push(&format!("index_encode_new_n{n}_k{k}"), &s_new, Some(b_new));
    }
    let median = |v: &mut Vec<f64>| -> f64 {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let med_speedup = median(&mut speedups);
    old_bytes.sort_unstable();
    new_bytes.sort_unstable();
    let med_old = old_bytes[old_bytes.len() / 2];
    let med_new = new_bytes[new_bytes.len() / 2];
    println!(
        "index-encode: median speedup {med_speedup:.2}x, median bytes {med_old} -> {med_new} \
         ({:.1}% smaller)",
        100.0 * (1.0 - med_new as f64 / med_old as f64)
    );
    if !smoke && med_speedup < 2.0 {
        eprintln!("WARNING: index-encode median speedup {med_speedup:.2}x < 2x target");
    }
    if !smoke && med_new >= med_old {
        eprintln!("WARNING: new index payloads not smaller ({med_new} >= {med_old})");
    }
    (med_speedup, med_old, med_new)
}

/// One scalar-vs-auto timing pair for a SIMD-twinned kernel: the same
/// closure timed under forced-scalar dispatch and under auto dispatch
/// (AVX2 where the host has it).  Pushes both rows to the table and the
/// `(name, scalar_median_ns, simd_median_ns)` triple for the JSON `simd`
/// section.
fn simd_pair<F: FnMut()>(
    t: &mut Table,
    kernels: &mut Vec<(String, f64, f64)>,
    smoke: bool,
    name: &str,
    ms: u64,
    mut f: F,
) {
    use lgc::compress::simd;
    simd::force_scalar(true);
    let s = time_budget(budget(smoke, ms), &mut f);
    simd::force_scalar(false);
    let a = time_budget(budget(smoke, ms), &mut f);
    let ratio = a.p50_ns / s.p50_ns;
    let (m, p) = fmt(&s);
    t.row(&[format!("{name} scalar"), m, p, "forced-scalar twin".into()]);
    let (m, p) = fmt(&a);
    t.row(&[format!("{name} auto"), m, p, format!("{ratio:.2}x vs scalar")]);
    kernels.push((name.to_string(), s.p50_ns, a.p50_ns));
}

/// SIMD twins (DESIGN.md §16.1): each vectorized kernel timed through its
/// public entry point under forced-scalar and auto dispatch.  On AVX2
/// hosts CI asserts the auto medians stay at or below scalar; elsewhere
/// both columns time the same scalar twin and the ratio just tracks
/// measurement noise.
fn simd_section(t: &mut Table, json: &mut JsonOut, smoke: bool) {
    use lgc::compress::{f16, quantize, simd};

    let avx2 = simd::using_avx2();
    let mut rng = Rng::new(0x51D);
    let n = 262_144usize;
    let g = rng.normal_vec(n, 1.0);
    let deflate_data: Vec<u8> = {
        let half: Vec<u8> = (0..32_768).map(|_| rng.below(256) as u8).collect();
        let mut d = half.clone();
        d.extend(&half);
        d
    };

    let mut kernels = Vec::new();
    simd_pair(t, &mut kernels, smoke, "simd topk_scan", 400, || {
        std::hint::black_box(topk::top_k(&g, 4_096));
    });
    let mut qrng = Rng::new(0x51D2);
    simd_pair(t, &mut kernels, smoke, "simd qsgd", 400, || {
        std::hint::black_box(quantize::qsgd(&g, 16, 512, &mut qrng));
    });
    // f16 values stabilize after the first roundtrip, so reusing one
    // buffer times the identical workload every iteration.
    let mut buf = rng.normal_vec(n, 0.01);
    f16::roundtrip_in_place(&mut buf);
    simd_pair(t, &mut kernels, smoke, "simd f16_roundtrip", 400, || {
        f16::roundtrip_in_place(&mut buf);
        std::hint::black_box(buf.len());
    });
    simd_pair(t, &mut kernels, smoke, "simd deflate", 400, || {
        std::hint::black_box(flate2::compress(&deflate_data, flate2::Compression::new(6)));
    });
    simd::force_scalar(false); // leave auto dispatch for the later sections

    println!(
        "simd: avx2 {}; auto-vs-scalar medians {}",
        if avx2 { "active" } else { "inactive (both columns run the scalar twin)" },
        kernels
            .iter()
            .map(|(k, s, a)| format!("{k} {:.2}x", a / s))
            .collect::<Vec<_>>()
            .join(", ")
    );
    json.simd = Some((avx2, kernels));
}

/// The rate push (DESIGN.md §16.2): Golomb/Rice gap coding vs the legacy
/// DEFLATE hybrid over the operating-point corpus, plus the auto-picker's
/// contract — its payload is exactly the smallest candidate at every
/// point.
fn index_golomb_section(t: &mut Table, json: &mut JsonOut, smoke: bool) {
    use lgc::compress::index_coding::IndexCodec;

    let corpus: [(usize, usize); 4] =
        [(262_144, 4_096), (1_000_000, 1_000), (200_000, 2_000), (65_536, 8_192)];
    let mut scratch = Scratch::new();
    let (mut speedups, mut g_bytes, mut d_bytes, mut a_bytes) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    for (ci, &(n, k)) in corpus.iter().enumerate() {
        let mut rng = Rng::new(0x601 + ci as u64);
        let idx = random_indices(&mut rng, n, k);

        let s_deflate = time_budget(budget(smoke, 300), || {
            std::hint::black_box(
                index_coding::encode_with_into(&idx, n, IndexCodec::Deflate, &mut scratch.enc)
                    .unwrap()
                    .len(),
            );
        });
        let s_golomb = time_budget(budget(smoke, 300), || {
            std::hint::black_box(
                index_coding::encode_with_into(&idx, n, IndexCodec::Golomb, &mut scratch.enc)
                    .unwrap()
                    .len(),
            );
        });
        let b_d = index_coding::encode_with(&idx, n, IndexCodec::Deflate).unwrap().len();
        let b_g = index_coding::encode_with(&idx, n, IndexCodec::Golomb).unwrap().len();
        let b_bm = index_coding::encode_with(&idx, n, IndexCodec::Bitmap).unwrap().len();
        let b_a = index_coding::encode_with(&idx, n, IndexCodec::Auto).unwrap().len();
        assert_eq!(b_a, b_d.min(b_g).min(b_bm), "auto must ship the smallest candidate");

        let speedup = s_deflate.p50_ns / s_golomb.p50_ns;
        speedups.push(speedup);
        g_bytes.push(b_g);
        d_bytes.push(b_d);
        a_bytes.push(b_a);
        let (a, b) = fmt(&s_golomb);
        t.row(&[
            format!("index encode golomb n={n} k={k}"),
            a,
            b,
            format!("{b_g} B vs deflate {b_d} B (auto {b_a} B), {speedup:.2}x encode"),
        ]);
        json.push(&format!("index_golomb_n{n}_k{k}"), &s_golomb, Some(b_g));
    }
    let median_f = |v: &mut Vec<f64>| -> f64 {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let median_u = |v: &mut Vec<usize>| -> usize {
        v.sort_unstable();
        v[v.len() / 2]
    };
    let med_speedup = median_f(&mut speedups);
    let med_g = median_u(&mut g_bytes);
    let med_d = median_u(&mut d_bytes);
    let med_a = median_u(&mut a_bytes);
    println!(
        "index-golomb: median bytes deflate {med_d} -> golomb {med_g} (auto {med_a}), \
         encode {med_speedup:.2}x vs deflate"
    );
    json.index_golomb = Some((med_speedup, med_g, med_d, med_a));
}

/// Telemetry cost on the encode hot path (DESIGN.md §15.1): the same
/// corpus point timed with span recording off (today's default — the
/// spans compile to one relaxed load each) and with a live recorder
/// installed.  CI asserts the on/off median ratio stays under 1.05;
/// since compiled-in-but-disabled is strictly cheaper than enabled,
/// that bounds the disabled overhead too.
fn telemetry_overhead(t: &mut Table, json: &mut JsonOut, smoke: bool) {
    use lgc::obs::trace;
    let (n, k) = (262_144usize, 4_096usize);
    let mut rng = Rng::new(0x0B5);
    let idx = random_indices(&mut rng, n, k);
    let mut scratch = Scratch::new();

    let s_off = time_budget(budget(smoke, 400), || {
        std::hint::black_box(index_coding::encode_into(&idx, n, &mut scratch.enc).unwrap().len());
    });

    trace::install(1);
    let s_on = {
        let _lane = trace::lane_scope(0);
        time_budget(budget(smoke, 400), || {
            std::hint::black_box(
                index_coding::encode_into(&idx, n, &mut scratch.enc).unwrap().len(),
            );
        })
    };
    let recorded = trace::uninstall().len();
    assert!(recorded > 0, "recorder installed but no spans captured");

    let ratio = s_on.p50_ns / s_off.p50_ns;
    let (a, b) = fmt(&s_off);
    t.row(&["index encode, tracing off".into(), a, b, format!("n={n} k={k}")]);
    let (a, b) = fmt(&s_on);
    t.row(&[
        "index encode, tracing ON".into(),
        a,
        b,
        format!("{recorded} spans recorded, {ratio:.3}x vs off"),
    ]);
    json.push("index_encode_telemetry_off", &s_off, None);
    json.push("index_encode_telemetry_on", &s_on, None);
    println!("telemetry overhead on encode: {ratio:.3}x (tracing on vs off)");
    if !smoke && ratio > 1.05 {
        eprintln!("WARNING: telemetry-on encode median {ratio:.3}x > 1.05x budget");
    }
}

fn pure_sections(t: &mut Table, json: &mut JsonOut, n_mid: usize, mu: usize, smoke: bool) {
    let mut rng = Rng::new(1);

    // top-k selection over the mid group.
    let g = rng.normal_vec(n_mid, 1.0);
    let s = time_budget(budget(smoke, 1_000), || {
        std::hint::black_box(topk::top_k(&g, mu));
    });
    let (a, b) = fmt(&s);
    t.row(&["top-k select".into(), a, b, format!("n={n_mid} k={mu}")]);
    json.push("topk_select", &s, None);

    // top-k selection through a reused arena (the hot-path variant).
    let mut sc = Scratch::new();
    let s = time_budget(budget(smoke, 1_000), || {
        topk::top_k_into(&g, mu, &mut sc.mags, &mut sc.idx, &mut sc.vals);
        std::hint::black_box(sc.idx.len());
    });
    let (a, b) = fmt(&s);
    t.row(&["top-k select (arena)".into(), a, b, format!("n={n_mid} k={mu}")]);
    json.push("topk_select_arena", &s, None);

    // Rust scalar sparsify reference (the Pallas kernel's contract).
    let acc = rng.normal_vec(n_mid, 0.5);
    let s = time_budget(budget(smoke, 500), || {
        let mut o1 = vec![0.0f32; n_mid];
        let mut o2 = vec![0.0f32; n_mid];
        for i in 0..n_mid {
            let u = g[i] + acc[i];
            if u.abs() >= 0.8 {
                o1[i] = u;
            } else {
                o2[i] = u;
            }
        }
        std::hint::black_box((o1, o2));
    });
    let (a, b) = fmt(&s);
    t.row(&["sparsify rust scalar".into(), a, b, "reference".into()]);
    json.push("sparsify_scalar", &s, None);

    // Ring allreduce on latent vectors (K = 8).
    let latents: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(mu / 4, 1.0)).collect();
    let s = time_budget(budget(smoke, 500), || {
        let mut work = latents.clone();
        let mut ledger = Ledger::new();
        std::hint::black_box(ring::ring_allreduce_sum(&mut work, &mut ledger, Kind::Latent));
    });
    let (a, b) = fmt(&s);
    t.row(&["ring allreduce latents K=8".into(), a, b, format!("len={}", mu / 4)]);
    json.push("ring_allreduce_latents_k8", &s, None);
}

/// Sequential vs parallel per-node simulation at K=8.
/// Returns (seq_ms, par_ms).
fn node_loop_comparison(t: &mut Table, json: &mut JsonOut, n: usize, smoke: bool) -> (f64, f64) {
    const K: usize = 8;
    let mut rng = Rng::new(7);
    let k_sel = topk::k_of(n, 0.01);
    let grads: Vec<Vec<f32>> = (0..K).map(|_| rng.normal_vec(n, 1.0)).collect();

    let iters = if smoke { 4 } else { 12 };
    let run = |threads: usize| -> Stats {
        let mut fbs: Vec<FeedbackMemory> = (0..K)
            .map(|_| FeedbackMemory::new(n, Correction::Momentum, 0.9))
            .collect();
        let mut shards = NodeLedger::for_nodes(K);
        let mut arenas = Scratch::for_nodes(K);
        let mut ledger = Ledger::new();
        time(2, iters, || {
            let coded =
                node_pipeline(threads, &mut fbs, &mut shards, &mut arenas, &grads, k_sel, n);
            ledger.merge_shards(&mut shards);
            ledger.end_iteration();
            std::hint::black_box(coded);
        })
    };

    let seq = run(1);
    let par = run(0); // 0 = one worker per core
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let speedup = seq.mean_ms() / par.mean_ms();
    let (a, b) = fmt(&seq);
    t.row(&["node pipeline K=8 sequential".into(), a, b,
            format!("n={n} k={k_sel} x8 nodes")]);
    let (a, b) = fmt(&par);
    t.row(&["node pipeline K=8 parallel".into(), a, b,
            format!("{cores} cores -> {speedup:.2}x speedup")]);
    json.push("node_pipeline_k8_sequential", &seq, None);
    json.push("node_pipeline_k8_parallel", &par, None);
    println!(
        "node-pipeline K=8: sequential {:.3} ms/iter, parallel {:.3} ms/iter \
         ({speedup:.2}x on {cores} cores)",
        seq.mean_ms(),
        par.mean_ms()
    );
    if !smoke && cores >= 4 && speedup < 2.0 {
        eprintln!(
            "WARNING: expected >=2x parallel speedup at K=8 on a {cores}-core host, \
             measured {speedup:.2}x"
        );
    }
    (seq.mean_ms(), par.mean_ms())
}

/// Pipelined execution (DESIGN.md §13): per-bucket encode latency under
/// an 8-bucket plan, plus the modeled steady-state iteration time at
/// 50 Mbit/s with overlap on vs off.  The encode rows are measured; the
/// modeled rows are synthetic single-sample stats derived from those
/// measurements and the recorded per-bucket byte counts, priced by the
/// same fabric arithmetic the coordinator uses — so the JSON trajectory
/// tracks both the per-bucket hot path and the schedule it buys.
fn pipelined_section(t: &mut Table, json: &mut JsonOut, smoke: bool) {
    use lgc::coordinator::bucket::BucketPlan;
    use lgc::net::{Fabric, LinkModel, NetSim};

    const N: usize = 200_000;
    const K: usize = 8; // nodes
    const BUCKETS: usize = 8;
    let k_sel = topk::k_of(N, 0.01);
    let plan = BucketPlan::from_layers(N, &[], BUCKETS);
    let mut rng = Rng::new(0x13);
    let grad = rng.normal_vec(N, 1.0);

    // One steady-state selection: the bucketed path (identical global
    // threshold, plus per-bucket splits) feeds every row below.
    let mut fb = FeedbackMemory::new(N, Correction::Momentum, 0.9);
    let mut sc = Scratch::new();
    fb.accumulate(&grad);
    fb.select_and_clear_bucketed_into(k_sel, plan.ranges(), &mut sc);
    let idx = sc.idx.clone();
    let splits = sc.splits.clone();

    // Whole-group index encode — the `--no-overlap` packet (one global
    // stream) as the reference point.
    let s_mono = time_budget(budget(smoke, 400), || {
        std::hint::black_box(index_coding::encode_into(&idx, N, &mut sc.enc).unwrap().len());
    });
    let (a, b) = fmt(&s_mono);
    t.row(&["bucket encode monolithic".into(), a, b, format!("n={N} k={k_sel}")]);
    json.push("pipelined_encode_monolithic", &s_mono, None);

    // Per-bucket encode latency — the overlap packets: bucket-local
    // indices coded over the bucket width (DESIGN.md §13.4).
    let mut local: Vec<u32> = Vec::new();
    let mut per_bucket_s = Vec::with_capacity(plan.len());
    let mut per_bucket_bytes: Vec<u64> = Vec::with_capacity(plan.len());
    for (bkt, r) in plan.ranges().iter().enumerate() {
        let ids = &idx[splits[bkt]..splits[bkt + 1]];
        let width = r.len().max(1);
        let s = time_budget(budget(smoke, 150), || {
            local.clear();
            local.extend(ids.iter().map(|&i| i - r.start as u32));
            std::hint::black_box(
                index_coding::encode_into(&local, width, &mut sc.enc).unwrap().len(),
            );
        });
        local.clear();
        local.extend(ids.iter().map(|&i| i - r.start as u32));
        let coded = index_coding::encode_into(&local, width, &mut sc.enc).unwrap().len();
        per_bucket_bytes.push((coded + ids.len() * 4) as u64);
        per_bucket_s.push(s.p50_ns / 1e9);
        json.push(&format!("pipelined_encode_bucket{bkt}"), &s, Some(coded));
    }
    let sum_ms: f64 = per_bucket_s.iter().sum::<f64>() * 1e3;
    t.row(&[
        format!("bucket encode x{BUCKETS} (sum)"),
        format!("{sum_ms:.3} ms"),
        "-".into(),
        format!("per-bucket packets, k={k_sel} total"),
    ]);

    // Modeled steady-state iteration at 50 Mbit/s, K=8: the per-bucket
    // fan-in + bucket-tagged fan-out schedule the coordinator records,
    // priced sequentially (`--no-overlap`) and pipelined.  Per-bucket
    // compute is the measured encode latency above.
    let fabric = Fabric::new(LinkModel::from_mbits(50.0, 50e-6), vec![1.0; K]);
    let mut sim = NetSim::new(fabric.clone(), K);
    for (bkt, &bytes) in per_bucket_bytes.iter().enumerate() {
        for node in 0..K {
            sim.send(node, bytes);
        }
        sim.fanout_bucketed(bkt, bytes * K as u64);
    }
    sim.end_iteration();
    let report = sim.into_report();
    let total_compute: f64 = per_bucket_s.iter().sum();
    let barrier_s = total_compute + report.iter_comm_s_under(&fabric)[0];
    let piped_s = report.pipelined_iter_s_under(&fabric, &per_bucket_s)[0];
    let model_stats = |secs: f64| Stats {
        iters: 1,
        mean_ns: secs * 1e9,
        p50_ns: secs * 1e9,
        p95_ns: secs * 1e9,
        min_ns: secs * 1e9,
    };
    t.row(&[
        "modeled iter 50 Mbit/s overlap off".into(),
        format!("{:.3} ms", barrier_s * 1e3),
        "-".into(),
        format!("K={K}, {BUCKETS} buckets, compute = encode"),
    ]);
    t.row(&[
        "modeled iter 50 Mbit/s overlap on".into(),
        format!("{:.3} ms", piped_s * 1e3),
        "-".into(),
        format!("{:.2}x vs barrier", barrier_s / piped_s),
    ]);
    json.push("pipelined_iter_50mbit_overlap_off", &model_stats(barrier_s), None);
    json.push("pipelined_iter_50mbit_overlap_on", &model_stats(piped_s), None);
    println!(
        "pipelined: {BUCKETS}-bucket modeled iteration at 50 Mbit/s {:.3} ms -> {:.3} ms \
         ({:.2}x) with overlap",
        barrier_s * 1e3,
        piped_s * 1e3,
        barrier_s / piped_s
    );
    if piped_s > barrier_s + 1e-12 {
        eprintln!("WARNING: pipelined modeled iteration above the barrier price");
    }
}

/// Native-backend AE encode/decode latency (always available: the native
/// engine needs no artifacts).  Tracked in BENCH_hotpath.json so the
/// learned-compressor hot path has a PR-over-PR latency trajectory even
/// on machines without a PJRT toolchain.
fn native_ae_section(t: &mut Table, json: &mut JsonOut, smoke: bool) -> anyhow::Result<()> {
    use lgc::compress::autoencoder::{AeCompressor, Pattern};

    let engine = Engine::native()?;
    let meta = engine.manifest.resolve_model("convnet_mini").clone();
    let mu = meta.mu;
    let mut rng = Rng::new(21);
    let vals = rng.normal_vec(mu, 0.01);
    let iters = if smoke { 10 } else { 50 };

    let rar = AeCompressor::new(&engine, mu, 2, Pattern::RingAllreduce, 3)?;
    let (lat, sc) = rar.encode(&engine, &vals)?;
    let s = time(3, iters, || {
        rar.encode(&engine, &vals).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["native AE encode".into(), a, b, format!("mu={mu}, pure-rust kernels")]);
    json.push("native_ae_encode", &s, None);

    let s = time(3, iters, || {
        rar.decode_rar(&engine, &lat, sc).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["native AE decode RAR".into(), a, b, format!("mu={mu}")]);
    json.push("native_ae_decode_rar", &s, None);

    let ps = AeCompressor::new(&engine, mu, 2, Pattern::ParamServer, 3)?;
    let innov = vec![0.0f32; mu];
    let s = time(3, iters, || {
        ps.decode_ps(&engine, 0, &lat, &innov, sc).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["native AE decode PS".into(), a, b, format!("mu={mu}, innovation channel")]);
    json.push("native_ae_decode_ps", &s, None);
    Ok(())
}

fn engine_sections(
    engine: &Engine,
    t: &mut Table,
    json: &mut JsonOut,
    model: &str,
) -> anyhow::Result<()> {
    use lgc::compress::autoencoder::{AeCompressor, Pattern};

    let meta = engine.manifest.resolve_model(model).clone();
    let mu = meta.mu;
    let n_mid = meta.n_mid;
    let mut rng = Rng::new(1);

    // grad_step HLO (the dominant compute).
    let m = lgc::model::Model::new(&meta, 7);
    let data = lgc::data::for_model(&meta, 8);
    let batch = data.batch(0, 0);
    m.grad_step(engine, &batch)?; // compile
    let s = time_budget(2_000, || {
        m.grad_step(engine, &batch).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&[format!("{model}_grad_step"), a, b, format!("n={}", meta.n_params)]);
    json.push(&format!("{model}_grad_step"), &s, None);

    // AE encode / decode.
    let ae = AeCompressor::new(engine, mu, 2, Pattern::RingAllreduce, 3)?;
    let vals = rng.normal_vec(mu, 0.01);
    let (lat, sc) = ae.encode(engine, &vals)?;
    let s = time(3, 50, || {
        ae.encode(engine, &vals).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["AE encode (L1 conv1d)".into(), a, b,
            format!("mu={mu} (paper GPU: 0.007-0.01 ms)")]);
    json.push("ae_encode", &s, None);
    let s = time(3, 50, || {
        ae.decode_rar(engine, &lat, sc).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["AE decode (L1 deconv1d)".into(), a, b,
            format!("mu={mu} (paper GPU: ~1 ms)")]);
    json.push("ae_decode", &s, None);

    // Fused sparsify HLO (Pallas).
    let g = rng.normal_vec(n_mid, 1.0);
    let acc = rng.normal_vec(n_mid, 0.5);
    let gt = Tensor::f32(vec![n_mid], g);
    let at = Tensor::f32(vec![n_mid], acc);
    let tt = Tensor::f32(vec![1], vec![0.8]);
    engine.run(&meta.sparsify, &[gt.clone(), at.clone(), tt.clone()])?;
    let s = time(3, 50, || {
        engine.run(&meta.sparsify, &[gt.clone(), at.clone(), tt.clone()]).unwrap();
    });
    let (a, b) = fmt(&s);
    t.row(&["sparsify HLO (Pallas)".into(), a, b, format!("n={n_mid}")]);
    json.push("sparsify_hlo", &s, None);

    // Full steady-state iteration (phase 3 only) — and the end-to-end
    // view of the parallel node runtime: identical config at 1 thread vs
    // one-per-core.
    for (label, threads) in [("1 thread", 1usize), ("per-core", 0)] {
        let cfg = TrainConfig {
            model: model.to_string(),
            method: Method::LgcPs,
            nodes: 8,
            steps: 14,
            warmup_iters: 2,
            ae_train_iters: 2,
            eval_every: 0,
            threads,
            ..Default::default()
        };
        let r = lgc::coordinator::train(engine, cfg)?;
        t.row(&[
            format!("full LGC-PS phase-3 iter K=8 ({label})"),
            format!("{:.3} ms", r.phase_time[2].as_secs_f64() * 1e3 / r.phase_iters[2] as f64),
            "-".into(),
            format!("{} iters", r.phase_iters[2]),
        ]);
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("LGC_MODEL").unwrap_or_else(|_| "resnet_mini".into());
    let smoke = std::env::var("LGC_BENCH_SMOKE").is_ok();
    let engine = Engine::open_default().ok();

    // Workload sizes come from the manifest when it carries the requested
    // model; otherwise (native manifest or no engine) keep resnet_mini-
    // scale defaults so the pure-CPU rows measure the same operating
    // point PR-over-PR.
    let (n_mid, mu) = match &engine {
        Some(e) if e.manifest.models.contains_key(&model) => {
            let meta = e.manifest.model(&model);
            (meta.n_mid, meta.mu)
        }
        _ => (262_144, 4_096),
    };

    let mut json =
        JsonOut { smoke, entries: Vec::new(), index_encode: None, simd: None, index_golomb: None };
    let mut t = Table::new(&["hot-path op", "mean", "p95", "notes"]);
    pure_sections(&mut t, &mut json, n_mid, mu, smoke);
    json.index_encode = Some(index_encode_comparison(&mut t, &mut json, smoke));
    simd_section(&mut t, &mut json, smoke);
    index_golomb_section(&mut t, &mut json, smoke);
    telemetry_overhead(&mut t, &mut json, smoke);
    node_loop_comparison(&mut t, &mut json, 200_000, smoke);
    pipelined_section(&mut t, &mut json, smoke);
    native_ae_section(&mut t, &mut json, smoke)?;

    // PJRT-only sections: their JSON keys (ae_encode, sparsify_hlo, ...)
    // are the HLO-latency trajectory and must never silently record
    // native-kernel numbers (the native rows above have their own keys).
    let is_native = |e: &Engine| {
        e.manifest
            .fingerprint
            .starts_with(lgc::runtime::manifest::NATIVE_FINGERPRINT_PREFIX)
    };
    match &engine {
        Some(e) if !is_native(e) => engine_sections(e, &mut t, &mut json, &model)?,
        _ => println!(
            "(skipping PJRT sections: no artifacts/PJRT backend — native AE \
             rows above cover the learned-compressor hot path)"
        ),
    }

    println!("\n=== hot-path microbenchmarks ({model}) ===");
    t.print();
    t.write_csv("results/hotpath.csv")?;
    println!("-> results/hotpath.csv");
    let json_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    json.write(json_path)?;
    println!("-> {json_path}");
    Ok(())
}
