//! Bench: regenerate Figs 10 & 11 — learning curves of every method on the
//! classification (Fig 10) and segmentation (Fig 11) workloads.
//!
//! Reproduced claim: LGC/DGC curves track the baseline; Sparse GD lags.

use lgc::exp;
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let steps = exp::default_steps();
    let r10 = exp::learning_curves(&engine, "resnet_mini", 2, steps, "results/fig10.csv")?;
    let r11 = exp::learning_curves(&engine, "segnet_mini", 2, steps, "results/fig11.csv")?;
    for (rows, tag) in [(&r10, "fig10"), (&r11, "fig11")] {
        let base = rows.iter().find(|r| r.method == lgc::config::Method::Baseline).unwrap();
        let lgc_ps = rows.iter().find(|r| r.method == lgc::config::Method::LgcPs).unwrap();
        println!(
            "shape check [{tag}]: LGC-PS final loss {:.4} within 0.5 of baseline {:.4}: {}",
            lgc_ps.result.final_train_loss(),
            base.result.final_train_loss(),
            (lgc_ps.result.final_train_loss() - base.result.final_train_loss()).abs() < 0.5
        );
    }
    Ok(())
}
