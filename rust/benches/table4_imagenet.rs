//! Bench: regenerate Table IV — top-1 accuracy vs compression ratio vs
//! total transferred information, 8 nodes (paper: ResNet50/ImageNet;
//! scaled: resnet_mini/synth-cifar, DESIGN.md §2).
//!
//!   cargo bench --bench table4_imagenet        (LGC_STEPS to resize)
//!
//! Expected shape (paper Table IV): every compressed method's steady rate
//! is orders of magnitude under baseline; LGC-PS compresses hardest,
//! LGC-RAR and DGC next, ScaleCom/SparseGD behind; accuracy within noise
//! of baseline for all EF-corrected methods.

use lgc::exp;
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let steps = exp::default_steps();
    let rows = exp::table4(&engine, steps)?;

    // Paper-shape assertions (who wins, roughly by what factor).
    let get = |m: lgc::config::Method| {
        rows.iter().find(|r| r.method == m).unwrap()
    };
    use lgc::config::Method::*;
    let ps = get(LgcPs).ratio;
    let rar = get(LgcRar).ratio;
    let dgc = get(Dgc).ratio;
    let sc = get(ScaleCom).ratio;
    println!("\nshape check: LGC-PS {ps:.0}x > DGC {dgc:.0}x: {}", ps > dgc);
    println!("shape check: LGC-RAR {rar:.0}x > ScaleCom {sc:.0}x: {}", rar > sc);
    Ok(())
}
