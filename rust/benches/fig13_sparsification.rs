//! Bench: regenerate Fig 13 — sparsification-strategy ablation (fixed vs
//! exponential vs warmup) on ConvNet5 and ResNet-mini.
//!
//! Reproduced claim: warmup (LGC's choice) reaches lower loss faster than
//! fixed-from-start and exponential-ramp sparsification.

use lgc::exp;
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let steps = exp::default_steps();
    exp::fig13(&engine, steps)?;
    Ok(())
}
