//! Bench: regenerate Figs 3 & 4 — per-layer MI/entropy between two nodes'
//! gradients over training (paper: ResNet50/Cifar10 + PSPNet/CamVid;
//! scaled: resnet_mini + segnet_mini).
//!
//! Reproduced claims: (a) MI is a large fraction of H at every layer
//! ("~80% of the information content is common"); (b) MI tracks H across
//! iterations; (c) residual-sum layers carry visibly more information.

use lgc::exp::info_plane::{fig3_fig4, per_layer_means};
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let steps: usize = std::env::var("LGC_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40)
        .min(60);
    for model in ["resnet_mini", "segnet_mini"] {
        let rows = fig3_fig4(&engine, model, steps, 256)?;
        let means = per_layer_means(&rows);
        let ratio: f64 = means.iter().map(|(_, h, mi)| mi / h.max(1e-9)).sum::<f64>()
            / means.len() as f64;
        println!("shape check [{model}]: mean per-layer MI/H = {ratio:.2} (paper ~0.8): {}",
                 ratio > 0.5);
    }
    Ok(())
}
