//! Bench: regenerate Fig 12 — gradient MI/entropy at larger node counts
//! (paper: VGG11 @ 16 nodes on Food101, ConvNet5 @ 22 nodes on
//! TinyImageNet; scaled: convnet5 @ 16 and @ 22 on synth-cifar).
//!
//! Reproduced claim: the §III correlation persists at scale — the MI
//! between two arbitrary nodes' gradients stays a large fraction of H.

use lgc::exp::info_plane::{info_plane_run, per_layer_means};
use lgc::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::open_default()?;
    let steps: usize = std::env::var("LGC_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(25)
        .min(40);
    for (model, nodes, pair) in [
        ("vgg11_mini", 16usize, (3usize, 11usize)),
        ("convnet5", 22, (8usize, 10usize)),
    ] {
        let rows = info_plane_run(
            &engine,
            model,
            nodes,
            steps,
            pair,
            256,
            0.05,
            &format!("results/fig12_k{nodes}.csv"),
        )?;
        let means = per_layer_means(&rows);
        let (h, mi): (Vec<f64>, Vec<f64>) = means.iter().map(|(_, h, m)| (*h, *m)).unzip();
        let hm = h.iter().sum::<f64>() / h.len() as f64;
        let mm = mi.iter().sum::<f64>() / mi.len() as f64;
        println!(
            "K={nodes} pair={pair:?}: mean H {hm:.3}, mean MI {mm:.3}, MI/H {:.2} (>0.5: {})",
            mm / hm,
            mm / hm > 0.5
        );
    }
    Ok(())
}
