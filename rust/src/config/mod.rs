//! Experiment configuration + presets.
//!
//! Every run of the framework — CLI `lgc train`, the `lgc exp` experiment
//! drivers, the benches, and the examples — is described by a
//! [`TrainConfig`].  Presets encode the paper's per-experiment settings
//! scaled to this testbed (DESIGN.md §5).

use crate::net::{model::parse_bandwidth_mbits, Fabric, LinkModel};
use crate::util::cli::Args;

/// Which gradient-compression method runs the mid-group exchange.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Uncompressed synchronous SGD.
    Baseline,
    /// Top-k sparsification with plain error feedback (Sparse GD [19]).
    SparseGd,
    /// Deep Gradient Compression [20]: momentum-corrected EF + exponential
    /// sparsity warmup.
    Dgc,
    /// ScaleCom [25]: CLT-k leader-driven index selection.
    ScaleCom,
    /// QSGD [22] stochastic quantization.
    Qsgd,
    /// Hard-threshold sparsification (Aji & Heafield [29]).
    Threshold,
    /// LGC, parameter-server instance (§V-B1).
    LgcPs,
    /// LGC, ring-allreduce instance (§V-B2).
    LgcRar,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::Baseline => "baseline",
            Method::SparseGd => "sparse_gd",
            Method::Dgc => "dgc",
            Method::ScaleCom => "scalecom",
            Method::Qsgd => "qsgd",
            Method::Threshold => "threshold",
            Method::LgcPs => "lgc_ps",
            Method::LgcRar => "lgc_rar",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "baseline" => Method::Baseline,
            "sparse_gd" | "sparsegd" => Method::SparseGd,
            "dgc" => Method::Dgc,
            "scalecom" => Method::ScaleCom,
            "qsgd" => Method::Qsgd,
            "threshold" => Method::Threshold,
            "lgc_ps" | "lgc-ps" => Method::LgcPs,
            "lgc_rar" | "lgc-rar" => Method::LgcRar,
            _ => return None,
        })
    }

    pub fn all() -> [Method; 8] {
        [
            Method::Baseline,
            Method::SparseGd,
            Method::Dgc,
            Method::ScaleCom,
            Method::Qsgd,
            Method::Threshold,
            Method::LgcPs,
            Method::LgcRar,
        ]
    }
}

/// Which exchange backend carries the bytes (DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Single-process simulated exchange — the bit-exactness reference.
    #[default]
    Sim,
    /// Real multi-process transport over TCP or Unix-domain sockets
    /// (`transport/` module): one OS process per node, typed frames.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        Some(match s {
            "sim" => TransportKind::Sim,
            "tcp" | "uds" | "socket" => TransportKind::Tcp,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Sim => "sim",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// What the coordinator does when a worker dies mid-run (DESIGN.md §14).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OnFault {
    /// Fail-stop (default): abort the whole job with a descriptive error —
    /// the PR-6 behavior, unchanged.
    #[default]
    Fail,
    /// Remove the dead worker and renormalize aggregation over the K'
    /// survivors.  The dead node's error-feedback residual is dropped;
    /// survivors' state is untouched.  Only methods whose exchange is
    /// leaderless support this (see `coordinator::faults`).
    Continue,
    /// Hold the iteration and re-admit the worker via the session-token
    /// rejoin handshake: the coordinator resyncs iteration index, model
    /// replica, AE encoder weights, and the worker's EF memory snapshot.
    WaitRejoin,
}

impl OnFault {
    pub fn parse(s: &str) -> Option<OnFault> {
        Some(match s {
            "fail" => OnFault::Fail,
            "continue" => OnFault::Continue,
            "wait-rejoin" | "wait_rejoin" | "rejoin" => OnFault::WaitRejoin,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            OnFault::Fail => "fail",
            OnFault::Continue => "continue",
            OnFault::WaitRejoin => "wait-rejoin",
        }
    }
}

/// Sparsification schedule ablation (paper §VI-F, Fig. 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparsifySchedule {
    /// LGC's choice: dense updates for `warmup_iters`, then fixed alpha.
    Warmup,
    /// Fixed alpha from iteration 0 ([19], [22], [25]).
    Fixed,
    /// DGC's exponential ramp: alpha_it from 25% down to alpha.
    Exponential,
}

impl SparsifySchedule {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "warmup" => Self::Warmup,
            "fixed" => Self::Fixed,
            "exponential" | "exp" => Self::Exponential,
            _ => return None,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub model: String,
    pub method: Method,
    pub nodes: usize,
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// Top-k sparsity for mid/last groups (paper: 0.001 = 0.1%).
    pub alpha: f64,
    /// Innovation selection within g~ (Algorithm 1: top 10% of g~).
    pub innovation_frac: f64,
    /// Phase 1 length (dense updates).
    pub warmup_iters: usize,
    /// Phase 2 length (top-k updates + AE online training).
    pub ae_train_iters: usize,
    pub ae_lr: f32,
    /// AE SGD steps per phase-2 iteration (compute-only; recovers the
    /// paper's 200-300-step AE budget inside the scaled phase-2 window).
    pub ae_inner_steps: usize,
    /// Similarity-loss weight lambda_2 (PS autoencoder, eq. 7).
    pub lambda2: f32,
    pub schedule: SparsifySchedule,
    /// Evaluate on held-out batches every this many iterations.
    pub eval_every: usize,
    pub eval_batches: usize,
    pub seed: u64,
    /// QSGD quantization levels.
    pub qsgd_levels: u32,
    /// Transmit sparse value payloads as f16 (rate ablation).
    pub fp16_values: bool,
    /// Index-coding strategy for sparse support sets (`--index-codec`,
    /// DESIGN.md §16.2): `deflate` is the legacy hybrid coder, `auto`
    /// prices bitmap/deflate/Golomb per layer and emits the smallest.
    /// Shipped to TCP workers (the encoder side) through the config blob.
    pub index_codec: crate::compress::index_coding::IndexCodec,
    /// AE readiness gate: compressed updates engage once the online rec
    /// loss (unit-RMS MSE, 8-step mean) falls below this. Set high to
    /// force-engage (tests), low to never engage.
    pub ae_gate: f32,
    /// Worker threads for the per-node simulation stages (0 = one per
    /// available core).  Thread count changes wall-clock only: curves and
    /// ledgers are bit-identical across values (DESIGN.md §6.5).
    pub threads: usize,
    /// Modeled link bandwidth in megabits/s for the network fabric
    /// (DESIGN.md §11; the paper's Fig. 14 sweeps this axis).
    pub bandwidth_mbits: f64,
    /// Modeled per-message base latency in seconds.
    pub latency_s: f64,
    /// Per-node straggler multipliers as `(node, multiplier)` overrides;
    /// unlisted nodes are nominal (1.0).  Entries naming nodes beyond
    /// `nodes` are ignored.
    pub straggler_spec: Vec<(usize, f64)>,
    pub verbose: bool,
    /// Bucketed pipeline (DESIGN.md §13): split the mid group into ~this
    /// many contiguous buckets cut at layer boundaries.  1 = the legacy
    /// monolithic exchange.  Only the dense baseline and the sparse-EF
    /// family bucket; other methods keep a single-bucket plan.
    pub buckets: usize,
    /// Alternative bucket policy: target dense bucket size in bytes
    /// (0 = off; wins over `buckets` when set).
    pub bucket_bytes: usize,
    /// Overlap the exchange of bucket *i* with the encode of bucket
    /// *i+1* (default).  `--no-overlap` serializes encode-then-exchange,
    /// which is bit-identical — curves, ledgers, net traces — to the
    /// unbucketed path for any bucket count.
    pub overlap: bool,
    /// Exchange backend: simulated (default) or real sockets.  The sim
    /// path is the bit-exactness reference; `Tcp` must reproduce its
    /// ledgers and curves byte-for-byte (tests/tcp_e2e.rs).
    pub transport: TransportKind,
    /// Save the final model checkpoint here (both transports), so runs
    /// can be compared byte-for-byte across backends.
    pub checkpoint: Option<String>,
    /// Worker→coordinator heartbeat period in milliseconds (0 = off, the
    /// legacy behavior: liveness rests on per-read socket deadlines only).
    pub heartbeat_ms: u64,
    /// How many consecutive missed heartbeat periods the coordinator
    /// tolerates before declaring a worker dead.
    pub miss_budget: u32,
    /// Fault policy: what happens when a worker dies (DESIGN.md §14).
    pub on_fault: OnFault,
    /// Deterministic fault-injection plan, e.g.
    /// `"iter=40:kill=2;iter=60:stall=1:500ms;iter=80:corrupt-frame=3"`
    /// (parsed by `coordinator::faults::FaultPlan`).
    pub faults: Option<String>,
    /// Resume a sim run from a v2 training-state checkpoint written by
    /// `--ckpt-every`; the resumed run is bit-identical to an
    /// uninterrupted one.
    pub resume: Option<String>,
    /// Write a full training-state snapshot to `checkpoint` every N
    /// iterations (0 = final model checkpoint only).
    pub ckpt_every: usize,
    /// Write a Chrome/Perfetto `trace_event` JSON of pipeline spans here
    /// (DESIGN.md §15.2).  Shipped to TCP workers through the config
    /// blob so every process records; `None` (default) keeps spans inert.
    pub trace_out: Option<String>,
    /// Write the structured JSONL run log here (DESIGN.md §15.3).
    /// Coordinator-local: never shipped to workers.
    pub log_json: Option<String>,
    /// Serve Prometheus text-format scrapes from the coordinator at this
    /// address (DESIGN.md §15.5).  Coordinator-local.
    pub metrics_addr: Option<String>,
    /// Stderr diagnostic level (`--log-level`); Info preserves the
    /// historical output byte-for-byte.  Shipped to TCP workers.
    pub log_level: crate::obs::log::Level,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "convnet5".into(),
            method: Method::LgcPs,
            nodes: 4,
            steps: 500,
            lr: 0.1,
            momentum: 0.9,
            weight_decay: 1e-4,
            alpha: 1e-3,
            innovation_frac: 0.1,
            warmup_iters: 50,
            ae_train_iters: 75,
            // 1e-2 (vs the paper's 1e-3): our losses are means, not sums
            // (python/compile/autoencoder.py), which rescales the step.
            ae_lr: 1e-2,
            ae_inner_steps: 4,
            lambda2: 0.5,
            schedule: SparsifySchedule::Warmup,
            eval_every: 25,
            eval_batches: 4,
            seed: 42,
            qsgd_levels: 15,
            fp16_values: false,
            index_codec: crate::compress::index_coding::IndexCodec::Deflate,
            ae_gate: 0.55,
            threads: 0,
            bandwidth_mbits: 1000.0,
            latency_s: 50e-6,
            straggler_spec: Vec::new(),
            verbose: false,
            buckets: 1,
            bucket_bytes: 0,
            overlap: true,
            transport: TransportKind::Sim,
            checkpoint: None,
            heartbeat_ms: 0,
            miss_budget: 3,
            on_fault: OnFault::Fail,
            faults: None,
            resume: None,
            ckpt_every: 0,
            trace_out: None,
            log_json: None,
            metrics_addr: None,
            log_level: crate::obs::log::Level::Info,
        }
    }
}

/// Parse a `--straggler` spec: either a bare multiplier applied to node 0
/// (`"2.5"`) or comma-separated `node:multiplier` pairs (`"0:2,3:1.5"`).
pub fn parse_straggler_spec(s: &str) -> Option<Vec<(usize, f64)>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (node, mult) = match part.split_once(':') {
            Some((n, m)) => {
                (n.trim().parse::<usize>().ok()?, m.trim().parse::<f64>().ok()?)
            }
            None => (0usize, part.parse::<f64>().ok()?),
        };
        if !mult.is_finite() || mult <= 0.0 {
            return None;
        }
        out.push((node, mult));
    }
    Some(out)
}

impl TrainConfig {
    /// Paper default phases (200 dense / 200-300 AE) scale with run length:
    /// short runs use proportional phases so phase 3 still covers ~the
    /// paper's 85% of iterations.
    pub fn scaled_phases(mut self) -> Self {
        self.warmup_iters = (self.steps / 10).max(10);
        self.ae_train_iters = (self.steps * 3 / 20).max(15);
        self
    }

    /// Materialize the simulated network fabric for this run: the
    /// configured link plus a per-node straggler vector (DESIGN.md §11).
    pub fn fabric(&self) -> Fabric {
        let mut mults = vec![1.0f64; self.nodes];
        for &(node, m) in &self.straggler_spec {
            if node < self.nodes {
                mults[node] = m;
            }
        }
        Fabric::new(LinkModel::from_mbits(self.bandwidth_mbits, self.latency_s), mults)
    }

    pub fn from_args(a: &Args) -> TrainConfig {
        let mut c = TrainConfig::default();
        c.model = a.str("model", &c.model);
        if let Some(m) = a.opt_str("method") {
            c.method = Method::parse(&m).unwrap_or_else(|| panic!("bad --method {m:?}"));
        }
        c.nodes = a.usize("nodes", c.nodes);
        c.steps = a.usize("steps", c.steps);
        c.lr = a.f32("lr", c.lr);
        c.momentum = a.f32("momentum", c.momentum);
        c.alpha = a.f32("alpha", c.alpha as f32) as f64;
        c.warmup_iters = a.usize("warmup", c.warmup_iters);
        c.ae_train_iters = a.usize("ae-train", c.ae_train_iters);
        c.ae_lr = a.f32("ae-lr", c.ae_lr);
        c.lambda2 = a.f32("lambda2", c.lambda2);
        if let Some(s) = a.opt_str("schedule") {
            c.schedule =
                SparsifySchedule::parse(&s).unwrap_or_else(|| panic!("bad --schedule {s:?}"));
        }
        c.eval_every = a.usize("eval-every", c.eval_every);
        c.seed = a.u64("seed", c.seed);
        c.fp16_values = a.has("fp16");
        if let Some(s) = a.opt_str("index-codec") {
            c.index_codec = crate::compress::index_coding::IndexCodec::parse(&s)
                .unwrap_or_else(|| panic!("bad --index-codec {s:?} (auto|bitmap|deflate|golomb)"));
        }
        c.threads = a.usize("threads", c.threads);
        if let Some(b) = a.opt_str("bandwidth") {
            c.bandwidth_mbits = parse_bandwidth_mbits(&b)
                .unwrap_or_else(|| panic!("bad --bandwidth {b:?} (e.g. 1gbps, 50mbps, 250)"));
        }
        c.latency_s = a.f32("latency-us", (c.latency_s * 1e6) as f32) as f64 * 1e-6;
        if let Some(s) = a.opt_str("straggler") {
            c.straggler_spec = parse_straggler_spec(&s)
                .unwrap_or_else(|| panic!("bad --straggler {s:?} (e.g. 2.5 or 0:2,3:1.5)"));
        }
        c.verbose = a.has("verbose");
        c.buckets = a.usize("buckets", c.buckets);
        c.bucket_bytes = a.usize("bucket-bytes", c.bucket_bytes);
        if a.has("no-overlap") {
            c.overlap = false;
        }
        if let Some(t) = a.opt_str("transport") {
            c.transport = TransportKind::parse(&t)
                .unwrap_or_else(|| panic!("bad --transport {t:?} (sim|tcp)"));
        }
        c.checkpoint = a.opt_str("checkpoint");
        c.heartbeat_ms = a.u64("heartbeat-ms", c.heartbeat_ms);
        c.miss_budget = a.usize("miss-budget", c.miss_budget as usize) as u32;
        if let Some(p) = a.opt_str("on-fault") {
            c.on_fault = OnFault::parse(&p)
                .unwrap_or_else(|| panic!("bad --on-fault {p:?} (fail|continue|wait-rejoin)"));
        }
        c.faults = a.opt_str("faults");
        c.resume = a.opt_str("resume");
        c.ckpt_every = a.usize("ckpt-every", c.ckpt_every);
        c.trace_out = a.opt_str("trace-out");
        c.log_json = a.opt_str("log-json");
        c.metrics_addr = a.opt_str("metrics-addr");
        if let Some(l) = a.opt_str("log-level") {
            c.log_level = crate::obs::log::Level::parse(&l)
                .unwrap_or_else(|e| panic!("bad --log-level: {e}"));
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn scaled_phases_cover_paper_fractions() {
        let c = TrainConfig { steps: 1000, ..Default::default() }.scaled_phases();
        assert_eq!(c.warmup_iters, 100);
        assert_eq!(c.ae_train_iters, 150);
        // phase 3 = 75% of training, in the paper's 83-89% ballpark.
        assert!(c.steps - c.warmup_iters - c.ae_train_iters >= c.steps * 3 / 4);
    }

    #[test]
    fn straggler_spec_parsing() {
        assert_eq!(parse_straggler_spec("2.5"), Some(vec![(0, 2.5)]));
        assert_eq!(
            parse_straggler_spec("0:2,3:1.5"),
            Some(vec![(0, 2.0), (3, 1.5)])
        );
        assert_eq!(parse_straggler_spec(""), Some(vec![]));
        assert_eq!(parse_straggler_spec("0:-1"), None);
        assert_eq!(parse_straggler_spec("a:b"), None);
    }

    #[test]
    fn fabric_materializes_stragglers_per_node() {
        let c = TrainConfig {
            nodes: 4,
            bandwidth_mbits: 100.0,
            latency_s: 1e-4,
            straggler_spec: vec![(1, 2.0), (9, 7.0)], // node 9 out of range
            ..Default::default()
        };
        let f = c.fabric();
        assert_eq!(f.stragglers, vec![1.0, 2.0, 1.0, 1.0]);
        assert!((f.link.mbits() - 100.0).abs() < 1e-9);
        assert_eq!(f.link.latency_s, 1e-4);
    }

    #[test]
    fn from_args_overrides() {
        let a = Args::parse(
            ["--model", "resnet_mini", "--method", "dgc", "--steps", "7"]
                .iter()
                .map(|s| s.to_string()),
            &["model", "method", "steps"],
            &[],
        )
        .unwrap();
        let c = TrainConfig::from_args(&a);
        assert_eq!(c.model, "resnet_mini");
        assert_eq!(c.method, Method::Dgc);
        assert_eq!(c.steps, 7);
    }

    #[test]
    fn fault_flags_parse() {
        let c = TrainConfig::default();
        assert_eq!(c.heartbeat_ms, 0);
        assert_eq!(c.miss_budget, 3);
        assert_eq!(c.on_fault, OnFault::Fail);
        assert_eq!(c.faults, None);
        assert_eq!(c.resume, None);
        assert_eq!(c.ckpt_every, 0);
        let a = Args::parse(
            [
                "--heartbeat-ms",
                "200",
                "--miss-budget",
                "5",
                "--on-fault",
                "wait-rejoin",
                "--faults",
                "iter=4:kill=1",
                "--ckpt-every",
                "8",
            ]
            .iter()
            .map(|s| s.to_string()),
            &["heartbeat-ms", "miss-budget", "on-fault", "faults", "ckpt-every"],
            &[],
        )
        .unwrap();
        let c = TrainConfig::from_args(&a);
        assert_eq!(c.heartbeat_ms, 200);
        assert_eq!(c.miss_budget, 5);
        assert_eq!(c.on_fault, OnFault::WaitRejoin);
        assert_eq!(c.faults.as_deref(), Some("iter=4:kill=1"));
        assert_eq!(c.ckpt_every, 8);
        for (s, want) in [
            ("fail", OnFault::Fail),
            ("continue", OnFault::Continue),
            ("wait_rejoin", OnFault::WaitRejoin),
        ] {
            assert_eq!(OnFault::parse(s), Some(want));
            assert_eq!(OnFault::parse(want.name()), Some(want));
        }
        assert_eq!(OnFault::parse("retry"), None);
    }

    #[test]
    fn index_codec_flag_parses() {
        use crate::compress::index_coding::IndexCodec;
        // Default stays the legacy hybrid coder (bit-identity with
        // pre-codec runs).
        assert_eq!(TrainConfig::default().index_codec, IndexCodec::Deflate);
        for codec in IndexCodec::all() {
            let a = Args::parse(
                ["--index-codec", codec.name()].iter().map(|s| s.to_string()),
                &["index-codec"],
                &[],
            )
            .unwrap();
            assert_eq!(TrainConfig::from_args(&a).index_codec, codec);
            assert_eq!(IndexCodec::parse(codec.name()), Some(codec));
        }
        assert_eq!(IndexCodec::parse("zstd"), None);
    }

    #[test]
    fn telemetry_flags_parse() {
        let c = TrainConfig::default();
        assert_eq!(c.trace_out, None);
        assert_eq!(c.log_json, None);
        assert_eq!(c.metrics_addr, None);
        assert_eq!(c.log_level, crate::obs::log::Level::Info);
        let a = Args::parse(
            [
                "--trace-out",
                "run.trace.json",
                "--log-json",
                "run.jsonl",
                "--metrics-addr",
                "127.0.0.1:9464",
                "--log-level",
                "debug",
            ]
            .iter()
            .map(|s| s.to_string()),
            &["trace-out", "log-json", "metrics-addr", "log-level"],
            &[],
        )
        .unwrap();
        let c = TrainConfig::from_args(&a);
        assert_eq!(c.trace_out.as_deref(), Some("run.trace.json"));
        assert_eq!(c.log_json.as_deref(), Some("run.jsonl"));
        assert_eq!(c.metrics_addr.as_deref(), Some("127.0.0.1:9464"));
        assert_eq!(c.log_level, crate::obs::log::Level::Debug);
    }

    #[test]
    fn bucket_flags_parse() {
        let c = TrainConfig::default();
        assert_eq!((c.buckets, c.bucket_bytes, c.overlap), (1, 0, true));
        let a = Args::parse(
            ["--buckets", "8", "--bucket-bytes", "4096", "--no-overlap"]
                .iter()
                .map(|s| s.to_string()),
            &["buckets", "bucket-bytes"],
            &["no-overlap"],
        )
        .unwrap();
        let c = TrainConfig::from_args(&a);
        assert_eq!(c.buckets, 8);
        assert_eq!(c.bucket_bytes, 4096);
        assert!(!c.overlap);
    }
}
