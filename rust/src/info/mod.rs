//! Histogram entropy / mutual-information estimators (paper §III).
//!
//! The paper quantizes gradient pairs and estimates marginal entropy
//! H(g2), conditional entropy H(g2|g1), and MI I(g1; g2) from histograms.
//! The paper states a "2^32-level" quantizer, which is degenerate for
//! ~10^4-10^6 samples (every bin holds <= 1 sample, H -> log N, MI -> H);
//! we use 2^6-2^12 bins (sweepable) over a symmetric range clipped at a
//! high percentile — the regime where the estimates stabilize
//! (DESIGN.md §10, deviation 3).

/// Marginal + joint histogram statistics of a gradient pair.
#[derive(Debug, Clone)]
pub struct InfoPlane {
    /// H(a) in bits.
    pub h_a: f64,
    /// H(b) in bits.
    pub h_b: f64,
    /// H(a, b) in bits.
    pub h_ab: f64,
    /// I(a; b) = H(a) + H(b) - H(a,b), clamped at >= 0.
    pub mi: f64,
}

impl InfoPlane {
    /// H(b | a) = H(a,b) - H(a).
    pub fn cond_b_given_a(&self) -> f64 {
        (self.h_ab - self.h_a).max(0.0)
    }
}

fn entropy(counts: &[u32], total: f64) -> f64 {
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / total;
            -p * p.log2()
        })
        .sum()
}

/// Symmetric clip range covering ~99.5% of both vectors' mass.
fn clip_range(a: &[f32], b: &[f32]) -> f32 {
    let mut mags: Vec<f32> = a.iter().chain(b).map(|x| x.abs()).collect();
    let idx = ((mags.len() as f64) * 0.995) as usize;
    let idx = idx.min(mags.len() - 1);
    let (_, v, _) = mags.select_nth_unstable_by(idx, |x, y| x.partial_cmp(y).unwrap());
    let r = *v;
    if r > 0.0 { r } else { 1e-8 }
}

/// Estimate the information plane of two equal-length gradient vectors
/// with a `bins` x `bins` joint histogram.
pub fn info_plane(a: &[f32], b: &[f32], bins: usize) -> InfoPlane {
    assert_eq!(a.len(), b.len());
    assert!(bins >= 2 && !a.is_empty());
    let r = clip_range(a, b);
    let quant = |x: f32| -> usize {
        let t = ((x + r) / (2.0 * r)).clamp(0.0, 1.0);
        ((t * bins as f32) as usize).min(bins - 1)
    };
    let mut ha = vec![0u32; bins];
    let mut hb = vec![0u32; bins];
    let mut hab = vec![0u32; bins * bins];
    for (&x, &y) in a.iter().zip(b) {
        let (i, j) = (quant(x), quant(y));
        ha[i] += 1;
        hb[j] += 1;
        hab[i * bins + j] += 1;
    }
    let n = a.len() as f64;
    let h_a = entropy(&ha, n);
    let h_b = entropy(&hb, n);
    let h_ab = entropy(&hab, n);
    InfoPlane { h_a, h_b, h_ab, mi: (h_a + h_b - h_ab).max(0.0) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_vectors_mi_equals_entropy() {
        let mut rng = Rng::new(1);
        let a = rng.normal_vec(50_000, 1.0);
        let ip = info_plane(&a, &a, 64);
        assert!((ip.mi - ip.h_b).abs() < 0.02, "mi={} h={}", ip.mi, ip.h_b);
        assert!(ip.cond_b_given_a() < 0.02);
    }

    #[test]
    fn independent_vectors_mi_near_zero() {
        let mut rng = Rng::new(2);
        let a = rng.normal_vec(100_000, 1.0);
        let b = rng.normal_vec(100_000, 1.0);
        let ip = info_plane(&a, &b, 32);
        // finite-sample bias ~ (bins-1)^2 / (2 N ln 2) ~ 0.007 bits
        assert!(ip.mi < 0.05, "mi={}", ip.mi);
        assert!(ip.h_a > 3.0); // gaussian over 32 bins carries real entropy
    }

    #[test]
    fn correlated_vectors_match_analytic_gaussian_mi() {
        // b = a + sigma*noise, both ~N(0,1):
        // I(a;b) = 0.5 * log2(1 + 1/sigma^2) bits exactly.
        let mut rng = Rng::new(3);
        let a = rng.normal_vec(200_000, 1.0);
        let sigma = 0.3f32;
        let b: Vec<f32> = a.iter().map(|x| x + sigma * rng.normal()).collect();
        let ip = info_plane(&a, &b, 64);
        let analytic = 0.5 * (1.0 + 1.0 / (sigma as f64).powi(2)).log2(); // ~1.80
        assert!(
            (ip.mi - analytic).abs() < 0.25,
            "mi={} analytic={analytic}", ip.mi
        );
        assert!(ip.mi < ip.h_b); // lossy channel: MI strictly below H
    }

    #[test]
    fn mi_symmetric() {
        let mut rng = Rng::new(4);
        let a = rng.normal_vec(20_000, 1.0);
        let b: Vec<f32> = a.iter().map(|x| 0.5 * x + 0.5 * rng.normal()).collect();
        let ab = info_plane(&a, &b, 32).mi;
        let ba = info_plane(&b, &a, 32).mi;
        assert!((ab - ba).abs() < 1e-9);
    }

    #[test]
    fn constant_vector_zero_entropy() {
        let a = vec![0.0f32; 1000];
        let mut rng = Rng::new(5);
        let b = rng.normal_vec(1000, 1.0);
        let ip = info_plane(&a, &b, 16);
        assert!(ip.h_a < 1e-9);
        assert!(ip.mi < 1e-9);
    }

    #[test]
    fn bins_sweep_is_stable_for_correlated_data() {
        // The MI/H ratio (the paper's "~80%" claim) should be roughly
        // bin-count independent in the stable regime.
        let mut rng = Rng::new(6);
        let a = rng.normal_vec(200_000, 1.0);
        let b: Vec<f32> = a.iter().map(|x| x + 0.2 * rng.normal()).collect();
        let r1 = {
            let ip = info_plane(&a, &b, 64);
            ip.mi / ip.h_b
        };
        let r2 = {
            let ip = info_plane(&a, &b, 256);
            ip.mi / ip.h_b
        };
        assert!((r1 - r2).abs() < 0.15, "{r1} vs {r2}");
    }
}
