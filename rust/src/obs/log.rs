//! Leveled diagnostic logging (DESIGN.md §15.4).
//!
//! A process-wide level gate over the `eprintln!`-style progress and
//! diagnostic lines the coordinator, workers, and transport emit.  The
//! default level is [`Level::Info`], which preserves the exact output
//! the repo has always produced (CI greps the `FAULT iter=...` and
//! `measured wall (tcp)` lines verbatim); `--log-level quiet` silences
//! everything, `--log-level debug` adds the chatty per-iteration
//! diagnostics that used to hide behind ad-hoc env vars.
//!
//! Call sites use the [`log_info!`](crate::log_info) /
//! [`log_debug!`](crate::log_debug) macros, which expand to a single
//! relaxed atomic load before any formatting happens — a disabled line
//! costs one branch and allocates nothing.

use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::{bail, Result};

/// Diagnostic verbosity, ordered: `Quiet < Info < Debug`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// No progress or diagnostic output at all.
    Quiet,
    /// The default: today's progress, fault, and summary lines.
    Info,
    /// Info plus per-iteration internals (e.g. AE reconstruction error).
    Debug,
}

impl Level {
    /// Parse a `--log-level` value.
    pub fn parse(s: &str) -> Result<Level> {
        Ok(match s {
            "quiet" => Level::Quiet,
            "info" => Level::Info,
            "debug" => Level::Debug,
            other => bail!("unknown log level {other:?} (expected quiet, info, or debug)"),
        })
    }

    /// The CLI name this level parses from.
    pub fn name(self) -> &'static str {
        match self {
            Level::Quiet => "quiet",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Quiet,
            2 => Level::Debug,
            _ => Level::Info,
        }
    }
}

/// The process-wide level.  Info by default so a build without any
/// telemetry flags is byte-for-byte today's output.
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the process-wide log level (parsed from `--log-level`; workers
/// inherit it through the config blob at join).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current process-wide log level.
pub fn level() -> Level {
    Level::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Would a message at `at` print right now?  One relaxed load — the
/// macros call this before doing any formatting work.
pub fn enabled(at: Level) -> bool {
    at as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Print to stderr when the process log level admits [`Level::Info`].
/// Formatting is skipped entirely when gated off.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            eprintln!($($arg)*);
        }
    };
}

/// Print to stderr when the process log level admits [`Level::Debug`].
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            eprintln!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_roundtrips() {
        for l in [Level::Quiet, Level::Info, Level::Debug] {
            assert_eq!(Level::parse(l.name()).unwrap(), l);
        }
        assert!(Level::parse("verbose").is_err());
    }

    #[test]
    fn level_order_gates_messages() {
        assert!(Level::Quiet < Level::Info && Level::Info < Level::Debug);
        // The global default admits info but not debug.
        assert!(enabled(Level::Info));
    }
}
