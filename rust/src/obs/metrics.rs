//! Live coordinator metrics: a Prometheus text-format endpoint
//! (DESIGN.md §15.5).
//!
//! `lgc serve --metrics-addr HOST:PORT` (and `lgc train --transport
//! tcp --metrics-addr ...`) answers `GET /metrics` scrapes from a tiny
//! single-threaded HTTP responder on the coordinator.  The registry is
//! a fixed set of atomics the training loop bumps — no locking on the
//! hot path, no allocation after startup — rendered on demand in the
//! Prometheus exposition format (version 0.0.4).
//!
//! Exposed series:
//! * `lgc_iterations_total` — completed training iterations;
//! * `lgc_node_bytes_up_total{node}` — post-compression uplink bytes
//!   per worker (ledger-accounted, so identical to the sim's);
//! * `lgc_heartbeat_age_seconds{node}` — seconds since the node last
//!   made progress;
//! * `lgc_stalls_total`, `lgc_deaths_total`, `lgc_rejoins_total`,
//!   `lgc_decode_errors_total` — fault/liveness counters;
//! * `lgc_stage_seconds{stage}` — per-stage latency histograms (grad /
//!   exchange / update) with fixed log2 buckets from 1 µs to ~67 s.

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

/// Histogram bucket count: upper bounds 2^0 .. 2^24 microseconds plus
/// the implicit `+Inf` bucket.
const HIST_BUCKETS: usize = 25;

/// The stages timed into `lgc_stage_seconds`.
const STAGES: [&str; 3] = ["grad", "exchange", "update"];

/// One log2-bucketed latency histogram (microsecond samples).
struct Histogram {
    /// `counts[i]` counts samples with `value_us <= 2^i`; the last
    /// slot is `+Inf`.
    counts: [AtomicU64; HIST_BUCKETS],
    sum_us: AtomicU64,
    total: AtomicU64,
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            total: AtomicU64::new(0),
        }
    }

    fn observe_us(&self, us: u64) {
        let slot = if us == 0 {
            0
        } else {
            ((64 - us.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        };
        self.counts[slot].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    fn render(&self, name: &str, stage: &str, out: &mut String) {
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate().take(HIST_BUCKETS - 1) {
            cum += c.load(Ordering::Relaxed);
            let le = (1u64 << i) as f64 / 1e6;
            out.push_str(&format!("{name}_bucket{{stage=\"{stage}\",le=\"{le}\"}} {cum}\n"));
        }
        cum += self.counts[HIST_BUCKETS - 1].load(Ordering::Relaxed);
        out.push_str(&format!("{name}_bucket{{stage=\"{stage}\",le=\"+Inf\"}} {cum}\n"));
        let sum = self.sum_us.load(Ordering::Relaxed) as f64 / 1e6;
        out.push_str(&format!("{name}_sum{{stage=\"{stage}\"}} {sum}\n"));
        out.push_str(&format!(
            "{name}_count{{stage=\"{stage}\"}} {}\n",
            self.total.load(Ordering::Relaxed)
        ));
    }
}

/// The coordinator's metric registry — a fixed set of atomics sized at
/// install time for the run's node count.
pub struct Registry {
    epoch: Instant,
    iterations: AtomicU64,
    bytes_up: Vec<AtomicU64>,
    /// Microseconds-since-epoch of each node's last observed progress.
    last_progress_us: Vec<AtomicU64>,
    stalls: AtomicU64,
    deaths: AtomicU64,
    rejoins: AtomicU64,
    decode_errors: AtomicU64,
    stage_hist: [Histogram; 3],
}

impl Registry {
    fn new(nodes: usize) -> Registry {
        Registry {
            epoch: Instant::now(),
            iterations: AtomicU64::new(0),
            bytes_up: (0..nodes).map(|_| AtomicU64::new(0)).collect(),
            last_progress_us: (0..nodes).map(|_| AtomicU64::new(0)).collect(),
            stalls: AtomicU64::new(0),
            deaths: AtomicU64::new(0),
            rejoins: AtomicU64::new(0),
            decode_errors: AtomicU64::new(0),
            stage_hist: [Histogram::new(), Histogram::new(), Histogram::new()],
        }
    }

    /// Render the whole registry in Prometheus text exposition format.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("# HELP lgc_iterations_total Completed training iterations.\n");
        out.push_str("# TYPE lgc_iterations_total counter\n");
        out.push_str(&format!(
            "lgc_iterations_total {}\n",
            self.iterations.load(Ordering::Relaxed)
        ));
        out.push_str("# HELP lgc_node_bytes_up_total Ledger-accounted uplink bytes per node.\n");
        out.push_str("# TYPE lgc_node_bytes_up_total counter\n");
        for (n, b) in self.bytes_up.iter().enumerate() {
            out.push_str(&format!(
                "lgc_node_bytes_up_total{{node=\"{n}\"}} {}\n",
                b.load(Ordering::Relaxed)
            ));
        }
        out.push_str("# HELP lgc_heartbeat_age_seconds Seconds since the node last progressed.\n");
        out.push_str("# TYPE lgc_heartbeat_age_seconds gauge\n");
        let now_us = self.epoch.elapsed().as_micros() as u64;
        for (n, t) in self.last_progress_us.iter().enumerate() {
            let age = now_us.saturating_sub(t.load(Ordering::Relaxed)) as f64 / 1e6;
            out.push_str(&format!("lgc_heartbeat_age_seconds{{node=\"{n}\"}} {age}\n"));
        }
        for (name, help, v) in [
            ("lgc_stalls_total", "Planned stall faults executed.", &self.stalls),
            ("lgc_deaths_total", "Workers removed from aggregation.", &self.deaths),
            ("lgc_rejoins_total", "Workers re-admitted via rejoin.", &self.rejoins),
            ("lgc_decode_errors_total", "Frame decode/receive errors.", &self.decode_errors),
        ] {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {}\n", v.load(Ordering::Relaxed)));
        }
        out.push_str("# HELP lgc_stage_seconds Per-stage wall-clock latency.\n");
        out.push_str("# TYPE lgc_stage_seconds histogram\n");
        for (stage, h) in STAGES.iter().zip(&self.stage_hist) {
            h.render("lgc_stage_seconds", stage, &mut out);
        }
        out
    }
}

fn registry_slot() -> &'static Mutex<Option<Arc<Registry>>> {
    static SLOT: OnceLock<Mutex<Option<Arc<Registry>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

/// Install a fresh registry for a run with `nodes` workers and return
/// it.  The bump helpers below are no-ops until this is called.
pub fn install(nodes: usize) -> Arc<Registry> {
    let reg = Arc::new(Registry::new(nodes));
    *registry_slot().lock().unwrap() = Some(reg.clone());
    reg
}

/// The live registry, if one is installed.
pub fn current() -> Option<Arc<Registry>> {
    registry_slot().lock().unwrap().clone()
}

fn with<F: FnOnce(&Registry)>(f: F) {
    if let Some(r) = current() {
        f(&r);
    }
}

/// Count one completed iteration.
pub fn inc_iterations() {
    with(|r| {
        r.iterations.fetch_add(1, Ordering::Relaxed);
    });
}

/// Add ledger-accounted uplink bytes for `node`.
pub fn add_bytes_up(node: usize, bytes: u64) {
    with(|r| {
        if let Some(b) = r.bytes_up.get(node) {
            b.fetch_add(bytes, Ordering::Relaxed);
        }
    });
}

/// Refresh `node`'s last-progress clock (heartbeat age gauge).
pub fn mark_progress(node: usize) {
    with(|r| {
        if let Some(t) = r.last_progress_us.get(node) {
            t.store(r.epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
        }
    });
}

/// Count one planned stall fault.
pub fn inc_stalls() {
    with(|r| {
        r.stalls.fetch_add(1, Ordering::Relaxed);
    });
}

/// Count one worker death (removal from aggregation).
pub fn inc_deaths() {
    with(|r| {
        r.deaths.fetch_add(1, Ordering::Relaxed);
    });
}

/// Count one successful rejoin.
pub fn inc_rejoins() {
    with(|r| {
        r.rejoins.fetch_add(1, Ordering::Relaxed);
    });
}

/// Count one frame decode/receive error.
pub fn inc_decode_errors() {
    with(|r| {
        r.decode_errors.fetch_add(1, Ordering::Relaxed);
    });
}

/// Observe a per-stage duration (`stage` ∈ grad / exchange / update).
pub fn observe_stage(stage: &str, dur: std::time::Duration) {
    with(|r| {
        if let Some(i) = STAGES.iter().position(|s| *s == stage) {
            r.stage_hist[i].observe_us(dur.as_micros() as u64);
        }
    });
}

/// Handle to the scrape responder thread; the bound address is
/// available for tests and logs.  The thread is detached and serves
/// until process exit.
pub struct MetricsServer {
    addr: String,
}

impl MetricsServer {
    /// The address the responder actually bound (port resolved).
    pub fn addr(&self) -> &str {
        &self.addr
    }
}

/// Bind `addr` (e.g. `127.0.0.1:9464`; port 0 picks an ephemeral one)
/// and serve Prometheus scrapes of the installed registry from a
/// detached thread.  One request per connection, any path answered.
pub fn serve(addr: &str) -> Result<MetricsServer> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding --metrics-addr {addr:?}"))?;
    let bound = listener.local_addr().context("resolving metrics listener address")?;
    std::thread::Builder::new()
        .name("lgc-metrics".to_string())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut conn) = conn else { continue };
                let _ = conn.set_read_timeout(Some(std::time::Duration::from_secs(2)));
                // Drain the request line + headers (best effort; we
                // answer every path identically).
                let mut buf = [0u8; 1024];
                let mut seen = Vec::new();
                while !seen.windows(4).any(|w| w == b"\r\n\r\n") {
                    match conn.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            seen.extend_from_slice(&buf[..n]);
                            if seen.len() > 16 * 1024 {
                                break;
                            }
                        }
                    }
                }
                let body = match current() {
                    Some(r) => r.render(),
                    None => String::from("# no registry installed\n"),
                };
                let resp = format!(
                    "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; \
                     charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = conn.write_all(resp.as_bytes());
            }
        })
        .context("spawning metrics responder thread")?;
    Ok(MetricsServer { addr: bound.to_string() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_text_is_well_formed() {
        let reg = Registry::new(2);
        reg.iterations.fetch_add(3, Ordering::Relaxed);
        reg.bytes_up[1].fetch_add(1024, Ordering::Relaxed);
        reg.stage_hist[0].observe_us(100);
        reg.stage_hist[0].observe_us(1_000_000);
        let text = reg.render();
        assert!(text.contains("lgc_iterations_total 3"));
        assert!(text.contains("lgc_node_bytes_up_total{node=\"1\"} 1024"));
        assert!(text.contains("le=\"+Inf\"} 2"));
        assert!(text.contains("lgc_stage_seconds_count{stage=\"grad\"} 2"));
        // Every non-comment line is `name{labels} value` or `name value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, value) = line.rsplit_once(' ').expect("metric line has a value");
            value.parse::<f64>().expect("metric value parses");
        }
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let h = Histogram::new();
        for us in [0, 1, 2, 3, 1 << 20, u64::MAX] {
            h.observe_us(us);
        }
        let mut out = String::new();
        h.render("x", "s", &mut out);
        let infs: Vec<&str> = out.lines().filter(|l| l.contains("+Inf")).collect();
        assert_eq!(infs.len(), 1);
        assert!(infs[0].ends_with(" 6"));
    }

    #[test]
    fn scrape_roundtrip_over_tcp() {
        install(1);
        inc_iterations();
        let srv = serve("127.0.0.1:0").unwrap();
        let mut conn = std::net::TcpStream::connect(srv.addr()).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"));
        assert!(resp.contains("lgc_iterations_total"));
    }
}
