//! Observability: spans, traces, structured logs, live metrics
//! (DESIGN.md §15).
//!
//! A zero-dependency telemetry subsystem threaded through the training
//! stack, with a hard contract the test suite enforces in both
//! directions:
//!
//! * **telemetry off ⇒ nothing changes** — every [`trace::span`] call
//!   compiled into the pipeline is a single relaxed atomic load when no
//!   recorder is installed (no clock read, no allocation), and results
//!   are bit-identical to a build that never heard of telemetry;
//! * **telemetry on ⇒ only observation is added** — spans, JSONL
//!   records, and metric bumps never feed back into training math, so
//!   curves, ledgers, and checkpoints stay byte-identical with every
//!   flag enabled.
//!
//! The four front-ends:
//! * [`trace`] — RAII pipeline spans recorded into per-node lanes,
//!   merged deterministically and written as Chrome/Perfetto
//!   `trace_event` JSON (`--trace-out`);
//! * [`jsonl`] — the structured run log (`--log-json`): manifest,
//!   per-iteration records, fault/liveness events;
//! * [`metrics`] — the coordinator's Prometheus text-format scrape
//!   endpoint (`--metrics-addr`);
//! * [`log`] — leveled stderr diagnostics (`--log-level`), default
//!   byte-identical to the historical output.

pub mod jsonl;
pub mod log;
pub mod metrics;
pub mod trace;
