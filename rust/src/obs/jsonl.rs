//! Structured JSONL run log (DESIGN.md §15.3).
//!
//! `--log-json PATH` writes one JSON object per line: a `run_start`
//! manifest (config fingerprint, git describe, backend, run shape),
//! one `iteration` record per training step (loss, bytes by
//! [`crate::metrics::Kind`], per-stage wall-clock), one `fault` record
//! per fault/liveness event (the structured twin of the `FAULT ...`
//! stderr lines), and a closing `run_end` summary.  `exp` drivers and
//! CI consume this instead of scraping stdout.
//!
//! Records are flushed line-by-line so a crashed run still leaves a
//! readable prefix; every line parses with [`crate::util::json::Json`].

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::process::Command;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// An open JSONL run log.  Dropping it flushes; [`RunLog::finish`]
/// flushes with an explicit error path.
pub struct RunLog {
    w: BufWriter<File>,
    path: String,
}

/// Convenience: a `(key, value)` list turned into a JSON object.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl RunLog {
    /// Create (truncate) the log at `path`.
    pub fn create(path: &str) -> Result<RunLog> {
        let f = File::create(path).with_context(|| format!("creating run log {path:?}"))?;
        Ok(RunLog { w: BufWriter::new(f), path: path.to_string() })
    }

    /// Append one record: `fields` plus an `event` tag, as a single
    /// JSON line, flushed immediately.
    pub fn record(&mut self, event: &str, fields: Vec<(&str, Json)>) -> Result<()> {
        let mut m: BTreeMap<String, Json> =
            fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        m.insert("event".to_string(), Json::Str(event.to_string()));
        writeln!(self.w, "{}", Json::Obj(m))
            .and_then(|()| self.w.flush())
            .with_context(|| format!("writing run log {:?}", self.path))
    }

    /// Flush and close, surfacing any buffered I/O error loudly.
    pub fn finish(mut self) -> Result<()> {
        self.w.flush().with_context(|| format!("flushing run log {:?}", self.path))
    }
}

/// `git describe --always --dirty` of the working tree, or `"unknown"`
/// outside a git checkout — recorded in the run manifest so a results
/// file can always be traced back to the code that produced it.
pub fn git_describe() -> String {
    Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_parse_line_by_line() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("lgc_runlog_test_{}.jsonl", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        let mut log = RunLog::create(&path_s).unwrap();
        log.record(
            "run_start",
            vec![
                ("method", Json::Str("lgc_ps".into())),
                ("nodes", Json::Num(4.0)),
                ("note", Json::Str("quotes \" and \n newlines".into())),
            ],
        )
        .unwrap();
        log.record("iteration", vec![("iter", Json::Num(0.0)), ("loss", Json::Num(2.5))])
            .unwrap();
        log.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.str_of("event"), "run_start");
        assert_eq!(first.usize_of("nodes"), 4);
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.str_of("event"), "iteration");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn git_describe_never_panics() {
        let d = git_describe();
        assert!(!d.is_empty());
    }
}
