//! Pipeline span recording and Chrome/Perfetto trace emission
//! (DESIGN.md §15.1–§15.2).
//!
//! A process-wide recorder holds one append-only event lane per node
//! plus one for the coordinator role.  Each lane is written only by the
//! thread currently executing that node's pipeline slice (the
//! per-node closures in the sim, the single worker thread in a TCP
//! worker process), so lanes never contend, and the final merge walks
//! lanes in ascending node order — the same determinism argument as
//! [`crate::metrics::NodeLedger`] shard merging: output bytes depend
//! only on what each node did, never on thread scheduling.
//!
//! The off state is the common one and is engineered to cost nothing:
//! [`span`] does a single relaxed atomic load and returns an inert
//! guard — no clock read, no allocation, no TLS write — so telemetry
//! compiled in but disabled cannot perturb the hot path (the bench
//! smoke job asserts this stays under 5%).
//!
//! Timestamps are absolute microseconds since the Unix epoch (one
//! `SystemTime` anchor at install, then monotonic offsets), so events
//! recorded in different OS processes — TCP worker part files — land on
//! one comparable axis when the coordinator merges them.

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Instant, SystemTime};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// The lane id used for work executed in the coordinator role (central
/// aggregation, AE training, model update) rather than on behalf of a
/// specific node.
pub const COORD_LANE: usize = usize::MAX;

/// Events a lane holds before further records are counted as dropped
/// instead of growing without bound (a long run at debug span density
/// stays a few tens of MB).
const LANE_CAP: usize = 1 << 18;

/// One pipeline stage a span can cover.  `name()` strings are the
/// Perfetto event names and the JSONL `stage` values — stable API.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Local gradient computation (forward + backward).
    Grad,
    /// Error-feedback accumulation into the residual memory.
    Ef,
    /// Top-k / threshold selection (including bucketed selection).
    TopK,
    /// Autoencoder encode of a value-vector.
    AeEncode,
    /// Autoencoder decode of a (reduced) latent.
    AeDecode,
    /// One online AE training step on received value-vectors.
    AeTrain,
    /// Index coding of a selected support (delta + DEFLATE framing).
    IndexCode,
    /// The DEFLATE compression call inside index coding.
    Deflate,
    /// QSGD quantization of a gradient.
    Quantize,
    /// The exchange step: aggregation, replay, sync broadcast.
    Exchange,
    /// Applying the aggregated update to the model replica.
    Update,
    /// Held-out evaluation.
    Eval,
}

impl Stage {
    /// Stable lower-snake name used in traces and logs.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Grad => "grad",
            Stage::Ef => "ef",
            Stage::TopK => "topk",
            Stage::AeEncode => "ae_encode",
            Stage::AeDecode => "ae_decode",
            Stage::AeTrain => "ae_train",
            Stage::IndexCode => "index_code",
            Stage::Deflate => "deflate",
            Stage::Quantize => "quantize",
            Stage::Exchange => "exchange",
            Stage::Update => "update",
            Stage::Eval => "eval",
        }
    }

    /// Every stage, in display order (metrics and coverage checks).
    pub fn all() -> &'static [Stage] {
        &[
            Stage::Grad,
            Stage::Ef,
            Stage::TopK,
            Stage::AeEncode,
            Stage::AeDecode,
            Stage::AeTrain,
            Stage::IndexCode,
            Stage::Deflate,
            Stage::Quantize,
            Stage::Exchange,
            Stage::Update,
            Stage::Eval,
        ]
    }
}

/// One recorded span (or instant event, when `dur_us == 0` and the
/// label is set): the unit the Perfetto writer and the JSONL part files
/// serialize.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanEvent {
    /// Node lane ([`COORD_LANE`] for coordinator-role work).
    pub lane: usize,
    /// Stage name ([`Stage::name`] for spans; free-form for events).
    pub stage: String,
    /// Iteration the span belongs to.
    pub iter: u64,
    /// Bucket id within the iteration, or `-1` when not bucketed.
    pub bucket: i64,
    /// Start time, microseconds since the Unix epoch.
    pub ts_us: u64,
    /// Duration in microseconds (0 for instant events).
    pub dur_us: u64,
}

struct Recorder {
    nodes: usize,
    origin: Instant,
    origin_unix_us: u64,
    /// One lane per node plus the coordinator lane at index `nodes`.
    lanes: Vec<Mutex<Vec<SpanEvent>>>,
    dropped: AtomicU64,
}

impl Recorder {
    fn now_us(&self) -> u64 {
        self.origin_unix_us + self.origin.elapsed().as_micros() as u64
    }

    fn lane_index(&self, lane: usize) -> Option<usize> {
        if lane == COORD_LANE {
            Some(self.nodes)
        } else if lane < self.nodes {
            Some(lane)
        } else {
            None
        }
    }

    fn push(&self, ev: SpanEvent) {
        match self.lane_index(ev.lane) {
            Some(i) => {
                let mut lane = self.lanes[i].lock().unwrap();
                if lane.len() < LANE_CAP {
                    lane.push(ev);
                } else {
                    self.dropped.fetch_add(1, Ordering::Relaxed);
                }
            }
            None => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Fast-path gate: spans are inert unless this is set by [`install`].
static ENABLED: AtomicBool = AtomicBool::new(false);
/// The iteration tag spans record; stored once per iteration from the
/// (single-threaded) top of the training loop.
static CUR_ITER: AtomicU64 = AtomicU64::new(0);

fn recorder_slot() -> &'static Mutex<Option<Arc<Recorder>>> {
    static SLOT: OnceLock<Mutex<Option<Arc<Recorder>>>> = OnceLock::new();
    SLOT.get_or_init(|| Mutex::new(None))
}

fn current_recorder() -> Option<Arc<Recorder>> {
    recorder_slot().lock().unwrap().clone()
}

thread_local! {
    static LANE: Cell<usize> = const { Cell::new(COORD_LANE) };
}

/// Is span recording active in this process?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start recording with `nodes` node lanes (plus the coordinator
/// lane).  Replaces any previous recorder; its events are discarded.
pub fn install(nodes: usize) {
    let origin_unix_us = SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0);
    let rec = Recorder {
        nodes,
        origin: Instant::now(),
        origin_unix_us,
        lanes: (0..=nodes).map(|_| Mutex::new(Vec::new())).collect(),
        dropped: AtomicU64::new(0),
    };
    *recorder_slot().lock().unwrap() = Some(Arc::new(rec));
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording and return everything recorded so far, merged
/// deterministically (ascending node lane, coordinator lane last; each
/// lane in record order).
pub fn uninstall() -> Vec<SpanEvent> {
    ENABLED.store(false, Ordering::Relaxed);
    let rec = recorder_slot().lock().unwrap().take();
    match rec {
        Some(r) => r.lanes.iter().flat_map(|l| l.lock().unwrap().clone()).collect(),
        None => Vec::new(),
    }
}

/// Tag subsequent spans with iteration `it`.  Called from the single-
/// threaded top of the training loop; a relaxed store the per-node
/// threads read when they open spans.
pub fn set_iter(it: usize) {
    if enabled() {
        CUR_ITER.store(it as u64, Ordering::Relaxed);
    }
}

/// Scope guard that routes this thread's spans to `lane` (a node id)
/// until dropped, restoring the previous lane on exit.  A no-op when
/// recording is off.
pub struct LaneGuard {
    prev: Option<usize>,
}

/// Route this thread's spans to node `lane` for the guard's lifetime.
pub fn lane_scope(lane: usize) -> LaneGuard {
    if !enabled() {
        return LaneGuard { prev: None };
    }
    let prev = LANE.with(|l| l.replace(lane));
    LaneGuard { prev: Some(prev) }
}

impl Drop for LaneGuard {
    fn drop(&mut self) {
        if let Some(prev) = self.prev {
            LANE.with(|l| l.set(prev));
        }
    }
}

/// RAII span: records `[open, drop)` into the current thread's lane.
/// Inert (no clock read, no allocation) when recording is off.
pub struct SpanGuard {
    open: Option<(usize, Stage, i64, Instant)>,
}

/// Open a span for `stage` on the current lane.
#[inline]
pub fn span(stage: Stage) -> SpanGuard {
    span_inner(stage, -1)
}

/// Open a bucket-tagged span for `stage` on the current lane.
#[inline]
pub fn span_bucket(stage: Stage, bucket: usize) -> SpanGuard {
    span_inner(stage, bucket as i64)
}

#[inline]
fn span_inner(stage: Stage, bucket: i64) -> SpanGuard {
    if !enabled() {
        return SpanGuard { open: None };
    }
    let lane = LANE.with(|l| l.get());
    SpanGuard { open: Some((lane, stage, bucket, Instant::now())) }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some((lane, stage, bucket, start)) = self.open.take() else {
            return;
        };
        let Some(rec) = current_recorder() else { return };
        let dur_us = start.elapsed().as_micros() as u64;
        let end_us = rec.now_us();
        rec.push(SpanEvent {
            lane,
            stage: stage.name().to_string(),
            iter: CUR_ITER.load(Ordering::Relaxed),
            bucket,
            ts_us: end_us.saturating_sub(dur_us),
            dur_us,
        });
    }
}

/// Record an instant event with a free-form label (fault and liveness
/// markers).  The label passes through the JSON string escaper, so any
/// UTF-8 is safe (tests/proptests.rs feeds it hostile input).
pub fn event(label: &str) {
    if !enabled() {
        return;
    }
    let Some(rec) = current_recorder() else { return };
    let ev = SpanEvent {
        lane: LANE.with(|l| l.get()),
        stage: label.to_string(),
        iter: CUR_ITER.load(Ordering::Relaxed),
        bucket: -1,
        ts_us: rec.now_us(),
        dur_us: 0,
    };
    rec.push(ev);
}

/// Snapshot of everything recorded so far without stopping the
/// recorder, in the same deterministic merge order as [`uninstall`].
pub fn snapshot() -> Vec<SpanEvent> {
    match current_recorder() {
        Some(r) => r.lanes.iter().flat_map(|l| l.lock().unwrap().clone()).collect(),
        None => Vec::new(),
    }
}

fn pid_of(lane: usize) -> u64 {
    if lane == COORD_LANE {
        0
    } else {
        lane as u64 + 1
    }
}

fn lane_name(lane: usize) -> String {
    if lane == COORD_LANE {
        "coordinator".to_string()
    } else {
        format!("node {lane}")
    }
}

/// Serialize events as Chrome/Perfetto `trace_event` JSON (the
/// `{"traceEvents": [...]}` object format `ui.perfetto.dev` loads).
/// Every string field goes through [`crate::util::json::Json`]'s
/// escaping serializer, so arbitrary labels cannot corrupt the output.
pub fn chrome_trace_json(events: &[SpanEvent]) -> String {
    let mut out: Vec<Json> = Vec::with_capacity(events.len() + 8);
    // Process-name metadata, one per lane present (ascending pid).
    let mut lanes: Vec<usize> = events.iter().map(|e| e.lane).collect();
    lanes.sort_by_key(|&l| pid_of(l));
    lanes.dedup();
    for lane in lanes {
        let mut args = BTreeMap::new();
        args.insert("name".to_string(), Json::Str(lane_name(lane)));
        let mut m = BTreeMap::new();
        m.insert("ph".to_string(), Json::Str("M".to_string()));
        m.insert("name".to_string(), Json::Str("process_name".to_string()));
        m.insert("pid".to_string(), Json::Num(pid_of(lane) as f64));
        m.insert("tid".to_string(), Json::Num(0.0));
        m.insert("args".to_string(), Json::Obj(args));
        out.push(Json::Obj(m));
    }
    for e in events {
        let mut args = BTreeMap::new();
        args.insert("iter".to_string(), Json::Num(e.iter as f64));
        if e.bucket >= 0 {
            args.insert("bucket".to_string(), Json::Num(e.bucket as f64));
        }
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(e.stage.clone()));
        m.insert("cat".to_string(), Json::Str("lgc".to_string()));
        m.insert(
            "ph".to_string(),
            Json::Str(if e.dur_us > 0 { "X" } else { "i" }.to_string()),
        );
        if e.dur_us > 0 {
            m.insert("dur".to_string(), Json::Num(e.dur_us as f64));
        } else {
            // Perfetto instant events need an explicit scope.
            m.insert("s".to_string(), Json::Str("p".to_string()));
        }
        m.insert("ts".to_string(), Json::Num(e.ts_us as f64));
        m.insert("pid".to_string(), Json::Num(pid_of(e.lane) as f64));
        m.insert("tid".to_string(), Json::Num(0.0));
        m.insert("args".to_string(), Json::Obj(args));
        out.push(Json::Obj(m));
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(out));
    Json::Obj(root).to_string()
}

fn event_to_json(e: &SpanEvent) -> Json {
    let mut m = BTreeMap::new();
    let lane = if e.lane == COORD_LANE { -1.0 } else { e.lane as f64 };
    m.insert("lane".to_string(), Json::Num(lane));
    m.insert("stage".to_string(), Json::Str(e.stage.clone()));
    m.insert("iter".to_string(), Json::Num(e.iter as f64));
    m.insert("bucket".to_string(), Json::Num(e.bucket as f64));
    m.insert("ts".to_string(), Json::Num(e.ts_us as f64));
    m.insert("dur".to_string(), Json::Num(e.dur_us as f64));
    Json::Obj(m)
}

fn num_of(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("trace event field {key:?} missing or not a number"))
}

fn event_from_json(j: &Json) -> Result<SpanEvent> {
    let lane_raw = num_of(j, "lane")?;
    let lane = if lane_raw < 0.0 {
        COORD_LANE
    } else {
        lane_raw as usize
    };
    Ok(SpanEvent {
        lane,
        stage: j
            .get("stage")
            .and_then(Json::as_str)
            .context("trace event field \"stage\" missing or not a string")?
            .to_string(),
        iter: num_of(j, "iter")? as u64,
        bucket: num_of(j, "bucket")? as i64,
        ts_us: num_of(j, "ts")? as u64,
        dur_us: num_of(j, "dur")? as u64,
    })
}

/// Serialize events as one JSON object per line — the worker part-file
/// format ([`part_path`]).
pub fn part_lines(events: &[SpanEvent]) -> String {
    let mut s = String::new();
    for e in events {
        s.push_str(&event_to_json(e).to_string());
        s.push('\n');
    }
    s
}

/// Parse one part-file line back into a [`SpanEvent`].
pub fn parse_part_line(line: &str) -> Result<SpanEvent> {
    event_from_json(&Json::parse(line)?)
}

/// The part-file path a TCP worker process writes its lane to:
/// `{trace_out}.node{N}.part`, merged (and removed) by the coordinator
/// when it writes the final trace.
pub fn part_path(trace_out: &str, node: usize) -> String {
    format!("{trace_out}.node{node}.part")
}

/// Worker-side flush: write everything this process recorded to its
/// part file (the coordinator merges part files after workers exit).
pub fn write_part(trace_out: &str, node: usize) -> Result<()> {
    let events = snapshot();
    let path = part_path(trace_out, node);
    std::fs::write(&path, part_lines(&events))
        .with_context(|| format!("writing trace part file {path:?}"))
}

/// Coordinator-side final write: merge this process's events with any
/// worker part files (`{path}.node{N}.part`, removed after reading) and
/// emit the Chrome/Perfetto JSON at `path`.  Missing part files are
/// fine — sim runs have none, and a killed worker may never have
/// flushed.
pub fn write_merged(path: &str, nodes: usize) -> Result<()> {
    let mut parts: Vec<SpanEvent> = Vec::new();
    for node in 0..nodes {
        let p = part_path(path, node);
        let Ok(text) = std::fs::read_to_string(&p) else {
            continue;
        };
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            parts.push(
                parse_part_line(line)
                    .with_context(|| format!("parsing trace part file {p:?}"))?,
            );
        }
        let _ = std::fs::remove_file(&p);
    }
    let own = snapshot();
    // Deterministic merge, the NodeLedger argument: ascending node lane
    // first (worker parts, then own per-node lanes from sim runs), the
    // coordinator lane last; ties keep record order (sort is stable).
    let mut all: Vec<SpanEvent> = parts.into_iter().chain(own).collect();
    all.sort_by_key(|e| (pid_of(e.lane) == 0, pid_of(e.lane)));
    std::fs::write(path, chrome_trace_json(&all))
        .with_context(|| format!("writing trace {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn part_line_roundtrips_hostile_labels() {
        let ev = SpanEvent {
            lane: COORD_LANE,
            stage: "weird \"label\"\nwith\tcontrol\u{1}chars and ünïcode".to_string(),
            iter: 7,
            bucket: 3,
            ts_us: 123_456,
            dur_us: 42,
        };
        let line = part_lines(std::slice::from_ref(&ev));
        let back = parse_part_line(line.trim_end()).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn chrome_trace_is_valid_json_with_all_events() {
        let events = vec![
            SpanEvent {
                lane: 0,
                stage: "grad".into(),
                iter: 0,
                bucket: -1,
                ts_us: 10,
                dur_us: 5,
            },
            SpanEvent {
                lane: COORD_LANE,
                stage: "exchange".into(),
                iter: 0,
                bucket: 2,
                ts_us: 16,
                dur_us: 0,
            },
        ];
        let json = chrome_trace_json(&events);
        let parsed = Json::parse(&json).unwrap();
        let arr = parsed.req("traceEvents").as_arr().unwrap();
        // 2 process-name metadata records + 2 events.
        assert_eq!(arr.len(), 4);
    }

    #[test]
    fn spans_are_inert_when_disabled() {
        // Never installed in this test: the guard must be a no-op.
        let g = span(Stage::Grad);
        assert!(g.open.is_none());
        drop(g);
        let g = lane_scope(3);
        assert!(g.prev.is_none() || enabled());
    }
}
