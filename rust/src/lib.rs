//! # LGC — Learned Gradient Compression for Distributed Deep Learning
//!
//! Rust + JAX + Pallas reproduction of Abrahamyan et al., 2021 (cs.LG).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — the distributed-training coordinator: simulated
//!   multi-node topology, parameter-server + ring-allreduce protocols,
//!   three-phase scheduler, gradient compression strategies (LGC + the
//!   paper's comparators), byte-accounted rate ledger.
//! * **L2 (python/compile, build time only)** — JAX models and the LGC
//!   autoencoders, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot-spot (1-D conv encoder/decoder, fused sparsify).
//!
//! Execution is backend-pluggable (DESIGN.md §7.3): the AOT'd HLO
//! modules run through PJRT when artifacts are present, and a pure-Rust
//! native CPU backend (`runtime/native`) implements the same module
//! contracts from a clean checkout — `Engine::open_default()` picks
//! automatically, so the quickstart below always works:
//!
//! ```no_run
//! use lgc::{config::TrainConfig, coordinator, runtime::Engine};
//! let engine = Engine::open_default().unwrap();
//! let cfg = TrainConfig { steps: 100, ..Default::default() }.scaled_phases();
//! let result = coordinator::train(&engine, cfg).unwrap();
//! println!("compression ratio: {:.0}x", result.compression_ratio());
//! ```
//!
//! Module map (L3):
//! * [`coordinator`] — the training loop, exchange protocols, per-node
//!   parallel runtime;
//! * [`compress`] — top-k selection, error feedback, index coding, f16,
//!   the learned autoencoder front-end, per-node scratch arenas;
//! * [`baselines`] — the paper's comparator methods behind one
//!   [`baselines::MidStrategy`] trait;
//! * [`metrics`] — the measured byte ledger every table derives from;
//! * [`net`] — the simulated network fabric that turns measured bytes
//!   into modeled wall-clock time (DESIGN.md §11);
//! * [`transport`] — the real multi-process wire transport (TCP /
//!   Unix-domain sockets): length-prefixed frames, typed messages, the
//!   coordinator's join handshake (DESIGN.md §12);
//! * [`exp`] — one driver per paper table/figure, each emitting
//!   `results/*.csv`;
//! * [`obs`] — observability: pipeline spans + Perfetto traces, the
//!   JSONL run log, the Prometheus metrics endpoint, leveled logging
//!   (DESIGN.md §15);
//! * [`runtime`] — backend dispatch (PJRT or native CPU), manifest,
//!   tensors;
//! * [`config`], [`data`], [`model`], [`info`], [`util`] — run
//!   configuration, synthetic datasets, the parameter store, the
//!   information-plane estimator, and support code.

pub mod baselines;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod info;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod transport;
pub mod util;
