//! Training-state checkpointing (framework feature; not in the paper).
//!
//! Two binary formats share the magic and the trailing CRC32 (so truncated
//! files fail loudly):
//!
//! v1 — model tensors (unchanged on-disk bytes since PR 4):
//!   magic "LGCK" | u32 1 | u32 n_tensors |
//!   per tensor: u32 rank | u64 dims[rank] | u8 dtype | payload bytes
//!
//! v2 — named state blobs, the full-training-state container behind
//! `--ckpt-every` / `--resume` (DESIGN.md §14):
//!   magic "LGCK" | u32 2 | u32 n_blobs |
//!   per blob: str name | bytes payload      (util::ser framing)
//!
//! All writes are atomic — temp file in the same directory, fsync, rename —
//! so a crash mid-save leaves the previous checkpoint intact instead of a
//! truncated file that only fails at resume time.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use flate2::Crc;

use crate::runtime::{Data, Tensor};
use crate::util::ser;

const MAGIC: &[u8; 4] = b"LGCK";
const VERSION: u32 = 1;
const BLOB_VERSION: u32 = 2;

/// The exact v1 file bytes for a tensor list (magic through CRC trailer).
/// Kept as a pure function so tests can byte-compare checkpoints across
/// transports without touching the filesystem path logic.
pub fn encode_tensors(tensors: &[Tensor]) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend(MAGIC);
    buf.extend(VERSION.to_le_bytes());
    buf.extend((tensors.len() as u32).to_le_bytes());
    for t in tensors {
        buf.extend((t.dims.len() as u32).to_le_bytes());
        for &d in &t.dims {
            buf.extend((d as u64).to_le_bytes());
        }
        match &t.data {
            Data::F32(v) => {
                buf.push(0u8);
                for x in v {
                    buf.extend(x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                buf.push(1u8);
                for x in v {
                    buf.extend(x.to_le_bytes());
                }
            }
        }
    }
    seal(buf)
}

/// Append the CRC32 trailer.
fn seal(mut buf: Vec<u8>) -> Vec<u8> {
    let mut crc = Crc::new();
    crc.update(&buf);
    buf.extend(crc.sum().to_le_bytes());
    buf
}

fn temp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(format!(".tmp-{}", std::process::id()));
    path.with_file_name(name)
}

/// Crash-safe file replacement: write to a temp file *in the same
/// directory* (rename across filesystems is not atomic), fsync, then
/// rename over the destination.  A crash at any point leaves either the
/// old file or the new one — never a truncated hybrid.
pub fn atomic_write(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = temp_path(path);
    let res = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {tmp:?}"))?;
        f.write_all(bytes)?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
        Ok(())
    })();
    if res.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    res
}

/// Fault-injection twin of [`atomic_write`]: writes only the first
/// `limit` bytes to the temp file and then fails *before the rename*,
/// simulating a crash mid-save.  The destination file is never touched —
/// the partial-write test proves the old checkpoint survives and still
/// loads.
pub fn atomic_write_with_limit(
    path: impl AsRef<Path>,
    bytes: &[u8],
    limit: usize,
) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = temp_path(path);
    let mut f = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
    f.write_all(&bytes[..limit.min(bytes.len())])?;
    f.sync_all()?;
    drop(f);
    bail!("injected crash after {} of {} bytes (temp {tmp:?})", limit.min(bytes.len()), bytes.len());
}

pub fn save(path: impl AsRef<Path>, tensors: &[Tensor]) -> Result<()> {
    atomic_write(path, &encode_tensors(tensors))
}

/// Verify the CRC trailer + magic of in-memory checkpoint bytes and
/// return (version, body after the 8-byte header).  Shared by the file
/// loaders and the wire-carried model-state blobs.
pub fn verify_bytes(buf: &[u8]) -> Result<(u32, &[u8])> {
    if buf.len() < 16 {
        bail!("checkpoint too short");
    }
    let (body, tail) = buf.split_at(buf.len() - 4);
    let want_crc = u32::from_le_bytes(tail.try_into()?);
    let mut crc = Crc::new();
    crc.update(body);
    if crc.sum() != want_crc {
        bail!("checkpoint CRC mismatch (truncated or corrupted)");
    }
    if &body[..4] != MAGIC {
        bail!("not an LGC checkpoint");
    }
    let version = u32::from_le_bytes(body[4..8].try_into()?);
    Ok((version, &body[8..]))
}

/// Read a checkpoint file and [`verify_bytes`] it.
fn read_verified(path: &Path) -> Result<(u32, Vec<u8>)> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {path:?}"))?
        .read_to_end(&mut buf)?;
    let (version, body) = verify_bytes(&buf)?;
    Ok((version, body.to_vec()))
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    let (version, body) = read_verified(path.as_ref())?;
    if version != VERSION {
        bail!(
            "unsupported checkpoint version {version} (model checkpoints are v1; \
             v2 files hold full training state — resume them with --resume)"
        );
    }
    decode_tensors(&body)
}

/// Parse the v1 tensor section (everything after magic+version, before the
/// CRC trailer).
pub fn decode_tensors(body: &[u8]) -> Result<Vec<Tensor>> {
    let mut pos = 0usize;
    let mut take = |n: usize| -> Result<&[u8]> {
        if pos + n > body.len() {
            bail!("checkpoint truncated");
        }
        let s = &body[pos..pos + n];
        pos += n;
        Ok(s)
    };
    let count = u32::from_le_bytes(take(4)?.try_into()?) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let rank = u32::from_le_bytes(take(4)?.try_into()?) as usize;
        if rank > 16 {
            bail!("implausible tensor rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(u64::from_le_bytes(take(8)?.try_into()?) as usize);
        }
        let n: usize = dims.iter().product();
        let dtype = take(1)?[0];
        match dtype {
            0 => {
                let raw = take(n * 4)?;
                let v = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                out.push(Tensor::f32(dims, v));
            }
            1 => {
                let raw = take(n * 4)?;
                let v = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                out.push(Tensor::i32(dims, v));
            }
            other => bail!("unknown dtype tag {other}"),
        }
    }
    Ok(out)
}

/// Encode the v2 named-blob container (magic through CRC trailer).
pub fn encode_blobs(blobs: &[(&str, Vec<u8>)]) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend(MAGIC);
    buf.extend(BLOB_VERSION.to_le_bytes());
    buf.extend((blobs.len() as u32).to_le_bytes());
    for (name, payload) in blobs {
        ser::put_str(&mut buf, name);
        ser::put_bytes(&mut buf, payload);
    }
    seal(buf)
}

/// Atomically write a v2 training-state checkpoint.
pub fn save_blobs(path: impl AsRef<Path>, blobs: &[(&str, Vec<u8>)]) -> Result<()> {
    atomic_write(path, &encode_blobs(blobs))
}

/// Load a v2 training-state checkpoint as (name, payload) pairs.
pub fn load_blobs(path: impl AsRef<Path>) -> Result<Vec<(String, Vec<u8>)>> {
    let (version, body) = read_verified(path.as_ref())?;
    if version != BLOB_VERSION {
        bail!(
            "unsupported checkpoint version {version} (training-state checkpoints are v2; \
             this looks like a model-only v1 file)"
        );
    }
    let mut r = ser::Reader::new(&body);
    let count = r.u32()? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name = r.string()?;
        let payload = r.bytes()?;
        out.push((name, payload));
    }
    r.finish()?;
    Ok(out)
}

/// Find a named blob in a loaded v2 container.
pub fn blob<'a>(blobs: &'a [(String, Vec<u8>)], name: &str) -> Result<&'a [u8]> {
    blobs
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, b)| b.as_slice())
        .ok_or_else(|| anyhow::anyhow!("checkpoint is missing the {name:?} state blob"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("lgc_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip_mixed_tensors() {
        let tensors = vec![
            Tensor::f32(vec![2, 3], vec![1., -2., 3.5, 0., 5., 6.]),
            Tensor::i32(vec![4], vec![-7, 0, 1, 2]),
            Tensor::scalar_f32(42.0),
        ];
        let p = tmp("roundtrip");
        save(&p, &tensors).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, tensors);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_corruption() {
        let tensors = vec![Tensor::f32(vec![8], vec![1.0; 8])];
        let p = tmp("corrupt");
        save(&p, &tensors).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_truncation() {
        let tensors = vec![Tensor::f32(vec![100], vec![0.5; 100])];
        let p = tmp("trunc");
        save(&p, &tensors).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage");
        std::fs::write(&p, b"this is not a checkpoint at all").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_tensor_list() {
        let p = tmp("empty");
        save(&p, &[]).unwrap();
        assert_eq!(load(&p).unwrap(), vec![]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn save_leaves_no_temp_files_and_same_bytes_as_encode() {
        let tensors = vec![Tensor::f32(vec![3], vec![1.0, 2.0, 3.0])];
        let p = tmp("atomic");
        save(&p, &tensors).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), encode_tensors(&tensors));
        assert!(!temp_path(&p).exists(), "temp file must be renamed away");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn partial_write_injection_preserves_old_checkpoint() {
        let old = vec![Tensor::f32(vec![4], vec![9.0; 4])];
        let new = vec![Tensor::f32(vec![256], vec![1.0; 256])];
        let p = tmp("partial");
        save(&p, &old).unwrap();
        let old_bytes = std::fs::read(&p).unwrap();
        // Crash mid-save at every interesting cut point: the destination
        // is untouched and still loads.
        let new_bytes = encode_tensors(&new);
        for cut in [0, 1, 7, new_bytes.len() / 2, new_bytes.len() - 1] {
            assert!(atomic_write_with_limit(&p, &new_bytes, cut).is_err());
            assert_eq!(std::fs::read(&p).unwrap(), old_bytes);
            assert_eq!(load(&p).unwrap(), old);
        }
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(temp_path(&p)).ok();
    }

    #[test]
    fn blob_container_roundtrip() {
        let p = tmp("blobs");
        let blobs: Vec<(&str, Vec<u8>)> =
            vec![("model", vec![1, 2, 3]), ("rng", vec![]), ("ledger", vec![0xFF; 100])];
        save_blobs(&p, &blobs).unwrap();
        let back = load_blobs(&p).unwrap();
        assert_eq!(back.len(), 3);
        for ((n0, b0), (n1, b1)) in blobs.iter().zip(&back) {
            assert_eq!(n0, n1);
            assert_eq!(b0, b1);
        }
        assert_eq!(blob(&back, "rng").unwrap(), &[] as &[u8]);
        assert!(blob(&back, "nope").is_err());
        // Version confusion fails loudly in both directions.
        assert!(load(&p).is_err());
        let p1 = tmp("blobs_v1");
        save(&p1, &[]).unwrap();
        assert!(load_blobs(&p1).is_err());
        std::fs::remove_file(&p).ok();
        std::fs::remove_file(&p1).ok();
    }

    #[test]
    fn blob_container_detects_corruption() {
        let p = tmp("blobs_corrupt");
        save_blobs(&p, &[("state", vec![7; 64])]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_blobs(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
