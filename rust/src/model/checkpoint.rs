//! Training-state checkpointing (framework feature; not in the paper).
//!
//! Binary format, versioned, self-describing:
//!   magic "LGCK" | u32 version | u32 n_tensors |
//!   per tensor: u32 rank | u64 dims[rank] | u8 dtype | payload bytes
//! plus a trailing CRC32 so truncated files fail loudly.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};
use flate2::Crc;

use crate::runtime::{Data, Tensor};

const MAGIC: &[u8; 4] = b"LGCK";
const VERSION: u32 = 1;

pub fn save(path: impl AsRef<Path>, tensors: &[Tensor]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend(MAGIC);
    buf.extend(VERSION.to_le_bytes());
    buf.extend((tensors.len() as u32).to_le_bytes());
    for t in tensors {
        buf.extend((t.dims.len() as u32).to_le_bytes());
        for &d in &t.dims {
            buf.extend((d as u64).to_le_bytes());
        }
        match &t.data {
            Data::F32(v) => {
                buf.push(0u8);
                for x in v {
                    buf.extend(x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                buf.push(1u8);
                for x in v {
                    buf.extend(x.to_le_bytes());
                }
            }
        }
    }
    let mut crc = Crc::new();
    crc.update(&buf);
    buf.extend(crc.sum().to_le_bytes());
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(&buf)?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<Tensor>> {
    let mut buf = Vec::new();
    std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?
        .read_to_end(&mut buf)?;
    if buf.len() < 16 {
        bail!("checkpoint too short");
    }
    let (body, tail) = buf.split_at(buf.len() - 4);
    let want_crc = u32::from_le_bytes(tail.try_into()?);
    let mut crc = Crc::new();
    crc.update(body);
    if crc.sum() != want_crc {
        bail!("checkpoint CRC mismatch (truncated or corrupted)");
    }
    let mut pos = 0usize;
    let mut take = |n: usize| -> Result<&[u8]> {
        if pos + n > body.len() {
            bail!("checkpoint truncated");
        }
        let s = &body[pos..pos + n];
        pos += n;
        Ok(s)
    };
    if take(4)? != MAGIC {
        bail!("not an LGC checkpoint");
    }
    let version = u32::from_le_bytes(take(4)?.try_into()?);
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let count = u32::from_le_bytes(take(4)?.try_into()?) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let rank = u32::from_le_bytes(take(4)?.try_into()?) as usize;
        if rank > 16 {
            bail!("implausible tensor rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(u64::from_le_bytes(take(8)?.try_into()?) as usize);
        }
        let n: usize = dims.iter().product();
        let dtype = take(1)?[0];
        match dtype {
            0 => {
                let raw = take(n * 4)?;
                let v = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                out.push(Tensor::f32(dims, v));
            }
            1 => {
                let raw = take(n * 4)?;
                let v = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                out.push(Tensor::i32(dims, v));
            }
            other => bail!("unknown dtype tag {other}"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("lgc_ckpt_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip_mixed_tensors() {
        let tensors = vec![
            Tensor::f32(vec![2, 3], vec![1., -2., 3.5, 0., 5., 6.]),
            Tensor::i32(vec![4], vec![-7, 0, 1, 2]),
            Tensor::scalar_f32(42.0),
        ];
        let p = tmp("roundtrip");
        save(&p, &tensors).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back, tensors);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_corruption() {
        let tensors = vec![Tensor::f32(vec![8], vec![1.0; 8])];
        let p = tmp("corrupt");
        save(&p, &tensors).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn detects_truncation() {
        let tensors = vec![Tensor::f32(vec![100], vec![0.5; 100])];
        let p = tmp("trunc");
        save(&p, &tensors).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("garbage");
        std::fs::write(&p, b"this is not a checkpoint at all").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_tensor_list() {
        let p = tmp("empty");
        save(&p, &[]).unwrap();
        assert_eq!(load(&p).unwrap(), vec![]);
        std::fs::remove_file(&p).ok();
    }
}
