//! Host-side model state: parameter replay, gradient flattening, SGD.
//!
//! Synchronous data-parallel SGD keeps all replicas bit-identical, so the
//! coordinator stores ONE copy of the parameters; per-node state lives in
//! the compression strategies (error-feedback memories).
//!
//! Parameter init replays the same He-normal rule aot.py's python models
//! use (weights: N(0, sqrt(2/fan_in)), fan_in = prod(shape[1:]); rank-1
//! tensors: zeros), from the manifest shapes — no weight files needed.

pub mod checkpoint;

use anyhow::Result;

use crate::data::Batch;
use crate::runtime::{Engine, ModelMeta, Tensor};
use crate::util::rng::Rng;

/// The three parameter groups of §VI-A's layer rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Group {
    /// First layer: always updated with original dense gradients.
    First,
    /// Middle layers: top-k + autoencoder compression.
    Mid,
    /// Last layer: top-k only, no autoencoder.
    Last,
}

pub struct Model {
    pub meta: ModelMeta,
    pub params: Vec<Tensor>,
    /// SGD momentum buffer (same layout as the flattened full gradient).
    velocity: Vec<f32>,
    pub momentum: f32,
    pub weight_decay: f32,
}

impl Model {
    pub fn new(meta: &ModelMeta, seed: u64) -> Model {
        let mut rng = Rng::new(seed);
        let params = meta
            .params
            .iter()
            .map(|shape| {
                let n: usize = shape.iter().product();
                if shape.len() > 1 {
                    let fan_in: usize = shape[1..].iter().product();
                    let std = (2.0f32 / fan_in as f32).sqrt();
                    Tensor::f32(shape.clone(), rng.normal_vec(n, std))
                } else {
                    Tensor::zeros(shape.clone())
                }
            })
            .collect();
        Model {
            meta: meta.clone(),
            params,
            velocity: vec![0.0; meta.n_params],
            momentum: 0.0,
            weight_decay: 0.0,
        }
    }

    pub fn group_idx(&self, g: Group) -> &[usize] {
        match g {
            Group::First => &self.meta.first_param_idx,
            Group::Mid => &self.meta.mid_param_idx,
            Group::Last => &self.meta.last_param_idx,
        }
    }

    /// Scalar length of a parameter group.
    pub fn group_len(&self, g: Group) -> usize {
        self.meta.group_len(self.group_idx(g))
    }

    /// Run one grad_step on `batch`; returns (loss, acc, per-param grads).
    pub fn grad_step(&self, engine: &Engine, batch: &Batch) -> Result<(f32, f32, Vec<Tensor>)> {
        let mut inputs = self.params.clone();
        inputs.push(batch.x.clone());
        inputs.push(batch.y.clone());
        let mut out = engine.run(&self.meta.grad_step, &inputs)?;
        let grads = out.split_off(2);
        Ok((out[0].scalar(), out[1].scalar(), grads))
    }

    pub fn evaluate(&self, engine: &Engine, batch: &Batch) -> Result<(f32, f32)> {
        let mut inputs = self.params.clone();
        inputs.push(batch.x.clone());
        inputs.push(batch.y.clone());
        let out = engine.run(&self.meta.evaluate, &inputs)?;
        Ok((out[0].scalar(), out[1].scalar()))
    }

    /// Flatten a parameter group of a per-param gradient list into one
    /// contiguous vector (the coordinator's working representation).
    pub fn flatten_group(&self, grads: &[Tensor], g: Group) -> Vec<f32> {
        let idx = self.group_idx(g);
        let mut out = Vec::with_capacity(self.group_len(g));
        for &i in idx {
            out.extend_from_slice(grads[i].as_f32());
        }
        out
    }

    /// Per-layer slices of the *mid* group flat vector: (layer, range).
    /// Used by the info-plane analysis, which is per-layer (§III).
    pub fn layer_slices(&self, g: Group) -> Vec<(usize, std::ops::Range<usize>)> {
        let mut out = Vec::new();
        let mut off = 0usize;
        let mut cur: Option<(usize, usize)> = None; // (layer, start)
        for &i in self.group_idx(g) {
            let layer = self.meta.layer_of_param[i];
            let len = self.meta.param_len(i);
            match cur {
                Some((l, start)) if l == layer => {
                    cur = Some((l, start));
                }
                Some((l, start)) => {
                    out.push((l, start..off));
                    cur = Some((layer, off));
                }
                None => cur = Some((layer, off)),
            }
            off += len;
        }
        if let Some((l, start)) = cur {
            out.push((l, start..off));
        }
        out
    }

    /// Persist parameters + optimizer state to a checkpoint file.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut tensors = self.params.clone();
        tensors.push(Tensor::f32(vec![self.velocity.len()], self.velocity.clone()));
        checkpoint::save(path, &tensors)
    }

    /// Restore parameters + optimizer state from a checkpoint file.
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let mut tensors = checkpoint::load(path)?;
        anyhow::ensure!(
            tensors.len() == self.params.len() + 1,
            "checkpoint tensor count mismatch: got {}, want {}",
            tensors.len(),
            self.params.len() + 1
        );
        let vel = tensors.pop().unwrap();
        anyhow::ensure!(vel.len() == self.velocity.len(), "velocity length mismatch");
        for (t, shape) in tensors.iter().zip(&self.meta.params) {
            anyhow::ensure!(&t.dims == shape, "param shape mismatch: {:?} vs {:?}",
                            t.dims, shape);
        }
        self.velocity = vel.as_f32().to_vec();
        self.params = tensors;
        Ok(())
    }

    /// Parameters + optimizer state as in-memory v1 checkpoint bytes —
    /// the replica payload the rejoin handshake and the v2 resume
    /// container both carry (same encoding as [`Model::save_checkpoint`],
    /// minus the file).
    pub fn state_bytes(&self) -> Vec<u8> {
        let mut tensors = self.params.clone();
        tensors.push(Tensor::f32(vec![self.velocity.len()], self.velocity.clone()));
        checkpoint::encode_tensors(&tensors)
    }

    /// Restore parameters + optimizer state from [`Model::state_bytes`].
    pub fn load_state_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        let (version, body) = checkpoint::verify_bytes(bytes)?;
        anyhow::ensure!(version == 1, "model state blob has version {version}, want 1");
        let mut tensors = checkpoint::decode_tensors(body)?;
        anyhow::ensure!(
            tensors.len() == self.params.len() + 1,
            "model state tensor count mismatch: got {}, want {}",
            tensors.len(),
            self.params.len() + 1
        );
        let vel = tensors.pop().unwrap();
        anyhow::ensure!(vel.len() == self.velocity.len(), "velocity length mismatch");
        for (t, shape) in tensors.iter().zip(&self.meta.params) {
            anyhow::ensure!(&t.dims == shape, "param shape mismatch: {:?} vs {:?}",
                            t.dims, shape);
        }
        self.velocity = vel.as_f32().to_vec();
        self.params = tensors;
        Ok(())
    }

    /// SGD update from group-flattened aggregated gradients.
    ///
    /// `lr` is the step size; momentum/weight decay per the model config.
    /// The flat layout must match `flatten_group` ordering.
    pub fn apply_update(&mut self, updates: &[(Group, Vec<f32>)], lr: f32) {
        // Assemble the full-length flat gradient.
        let mut full = vec![0.0f32; self.meta.n_params];
        // Precompute param offsets in full-flat order (param index order).
        let mut offsets = Vec::with_capacity(self.meta.params.len());
        let mut off = 0;
        for i in 0..self.meta.params.len() {
            offsets.push(off);
            off += self.meta.param_len(i);
        }
        for (g, flat) in updates {
            let idx = self.group_idx(*g).to_vec();
            let mut pos = 0usize;
            for &i in &idx {
                let len = self.meta.param_len(i);
                full[offsets[i]..offsets[i] + len]
                    .copy_from_slice(&flat[pos..pos + len]);
                pos += len;
            }
            debug_assert_eq!(pos, flat.len());
        }
        // Momentum + weight decay, then the parameter step.
        let wd = self.weight_decay;
        let m = self.momentum;
        let mut pi = 0usize;
        for (i, p) in self.params.iter_mut().enumerate() {
            let base = offsets[i];
            let data = p.as_f32_mut();
            for (j, w) in data.iter_mut().enumerate() {
                let mut g = full[base + j] + wd * *w;
                if m > 0.0 {
                    let v = &mut self.velocity[base + j];
                    *v = m * *v + g;
                    g = *v;
                }
                *w -= lr * g;
            }
            pi += data.len();
        }
        debug_assert_eq!(pi, self.meta.n_params);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "m".into(),
            params: vec![vec![2, 3], vec![3], vec![4], vec![2, 2]],
            layer_of_param: vec![0, 0, 1, 2],
            n_params: 6 + 3 + 4 + 4,
            n_mid: 4,
            mu: 16,
            first_param_idx: vec![0, 1],
            mid_param_idx: vec![2],
            last_param_idx: vec![3],
            batch: 1,
            input_shape: vec![1],
            input_dtype: "f32".into(),
            num_classes: 2,
            grad_step: "g".into(),
            evaluate: "e".into(),
            sparsify: "s".into(),
        }
    }

    #[test]
    fn init_replays_he_rule() {
        let m = Model::new(&meta(), 1);
        assert_eq!(m.params[0].dims, vec![2, 3]);
        assert!(m.params[0].as_f32().iter().any(|&x| x != 0.0));
        assert!(m.params[1].as_f32().iter().all(|&x| x == 0.0)); // bias
    }

    #[test]
    fn group_flatten_lengths() {
        let m = Model::new(&meta(), 1);
        assert_eq!(m.group_len(Group::First), 9);
        assert_eq!(m.group_len(Group::Mid), 4);
        assert_eq!(m.group_len(Group::Last), 4);
    }

    #[test]
    fn apply_update_touches_only_given_groups() {
        let mut m = Model::new(&meta(), 1);
        let before_first = m.params[0].as_f32().to_vec();
        let before_mid = m.params[2].as_f32().to_vec();
        m.apply_update(&[(Group::Mid, vec![1.0; 4])], 0.1);
        assert_eq!(m.params[0].as_f32(), &before_first[..]);
        for (a, b) in m.params[2].as_f32().iter().zip(&before_mid) {
            assert!((a - (b - 0.1)).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_accumulates() {
        let mut m = Model::new(&meta(), 1);
        m.momentum = 0.9;
        let w0 = m.params[2].as_f32()[0];
        m.apply_update(&[(Group::Mid, vec![1.0; 4])], 0.1);
        m.apply_update(&[(Group::Mid, vec![1.0; 4])], 0.1);
        // First step: -0.1; second: v=1.9 -> -0.19; total -0.29.
        assert!((m.params[2].as_f32()[0] - (w0 - 0.29)).abs() < 1e-5);
    }

    #[test]
    fn state_bytes_roundtrip_exact() {
        let mut a = Model::new(&meta(), 7);
        a.momentum = 0.9;
        a.apply_update(&[(Group::Mid, vec![1.0; 4])], 0.1);
        let blob = a.state_bytes();
        let mut b = Model::new(&meta(), 8); // different init
        b.momentum = 0.9;
        b.load_state_bytes(&blob).unwrap();
        for (pa, pb) in a.params.iter().zip(&b.params) {
            assert_eq!(pa, pb);
        }
        assert_eq!(a.velocity, b.velocity);
        // Corruption is caught by the CRC.
        let mut bad = blob.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        assert!(b.load_state_bytes(&bad).is_err());
    }

    #[test]
    fn layer_slices_group_contiguous() {
        let m = Model::new(&meta(), 1);
        let s = m.layer_slices(Group::First);
        assert_eq!(s, vec![(0usize, 0..9)]);
    }
}
