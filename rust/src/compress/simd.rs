//! Runtime-dispatched SIMD kernels for the encode hot path (DESIGN.md
//! §16.1).
//!
//! Every kernel here is a *pair*: a scalar twin (the reference semantics,
//! always compiled, the only path on non-x86_64) and an AVX2 variant
//! selected at runtime via `is_x86_feature_detected!`.  The pairs are
//! bit-identical by construction — same selected indices, same f32 bit
//! patterns, same bytes out — because ledgers, training curves, and the
//! sim-vs-wire identity contract all flow through them; the differential
//! suite (`tests/simd_differential.rs`) locks this down per kernel and
//! end-to-end.  `LGC_FORCE_SCALAR=1` (or [`force_scalar`]) pins the
//! scalar twins at runtime, which is how CI runs the whole tier-1 suite
//! on the fallback path.
//!
//! The dispatch decision is cached in one atomic: the hot loops pay a
//! single relaxed load, never a `cpuid`.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::rng::Rng;

const UNDECIDED: u8 = 0;
const SCALAR: u8 = 1;
const AVX2: u8 = 2;

static DISPATCH: AtomicU8 = AtomicU8::new(UNDECIDED);

/// Detect the dispatch state: AVX2 when the CPU has it and
/// `LGC_FORCE_SCALAR=1` is not set; scalar otherwise (and always on
/// non-x86_64 targets).
fn detect() -> u8 {
    if std::env::var_os("LGC_FORCE_SCALAR").is_some_and(|v| v == "1") {
        return SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return AVX2;
    }
    SCALAR
}

/// True when the AVX2 kernels are active (cached after the first call).
pub fn using_avx2() -> bool {
    match DISPATCH.load(Ordering::Relaxed) {
        UNDECIDED => {
            let d = detect();
            DISPATCH.store(d, Ordering::Relaxed);
            d == AVX2
        }
        d => d == AVX2,
    }
}

/// Pin (`true`) or release (`false`) the scalar twins at runtime — the
/// in-process equivalent of `LGC_FORCE_SCALAR=1`, used by the
/// differential tests and benches to run both paths in one binary.
/// Releasing re-detects, so the environment override still wins.
/// Also switches the vendored `flate2`'s own match-loop dispatch, which
/// cannot see this crate.
pub fn force_scalar(force: bool) {
    let d = if force { SCALAR } else { detect() };
    DISPATCH.store(d, Ordering::Relaxed);
    flate2::set_force_scalar(force);
}

// ---------------------------------------------------------------------------
// Top-k threshold scan
// ---------------------------------------------------------------------------

/// Append `base + i` for every `g[i]` with `|g[i]| > threshold`, in
/// ascending order (the strict pass of the top-k selection).
///
/// Bit-identity: AVX2 `|x|` is the same sign-bit clear as `f32::abs`,
/// and `_CMP_GT_OQ` is IEEE ordered-greater — false for NaN on either
/// side, exactly like the scalar `>` — so both variants select the same
/// indices for every input including NaN/±inf/±0/denormals.
pub(crate) fn scan_above(g: &[f32], base: u32, threshold: f32, out: &mut Vec<u32>) {
    #[cfg(target_arch = "x86_64")]
    if using_avx2() {
        // SAFETY: AVX2 presence was runtime-checked by `using_avx2`.
        unsafe { scan_above_avx2(g, base, threshold, out) };
        return;
    }
    scan_above_scalar(g, base, threshold, out);
}

fn scan_above_scalar(g: &[f32], base: u32, threshold: f32, out: &mut Vec<u32>) {
    for (i, &v) in g.iter().enumerate() {
        if v.abs() > threshold {
            out.push(base + i as u32);
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scan_above_avx2(g: &[f32], base: u32, threshold: f32, out: &mut Vec<u32>) {
    use std::arch::x86_64::*;
    let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
    let thr = _mm256_set1_ps(threshold);
    let mut j = 0usize;
    while j + 8 <= g.len() {
        // SAFETY: j + 8 <= g.len(), unaligned load.
        let v = unsafe { _mm256_loadu_ps(g.as_ptr().add(j)) };
        let gt = _mm256_cmp_ps::<_CMP_GT_OQ>(_mm256_and_ps(v, abs_mask), thr);
        let mut m = _mm256_movemask_ps(gt) as u32;
        while m != 0 {
            out.push(base + (j + m.trailing_zeros() as usize) as u32);
            m &= m - 1;
        }
        j += 8;
    }
    for (i, &v) in g[j..].iter().enumerate() {
        if v.abs() > threshold {
            out.push(base + (j + i) as u32);
        }
    }
}

// ---------------------------------------------------------------------------
// QSGD stochastic quantization (elementwise stage; the norm reduction is
// order-sensitive and stays scalar in the caller)
// ---------------------------------------------------------------------------

/// Quantize one non-zero-norm bucket: for each `chunk[i]`, draw one
/// uniform (in index order — the RNG stream is part of the contract) and
/// write the dequantized value into `out[i]`.
///
/// Bit-identity: the AVX2 variant batches 8 *scalar* RNG draws in index
/// order, evaluates `|x|/norm*levels`, `floor`, `u < r - low` and the
/// final `((sign*norm)*level)/levels` with the exact scalar operation
/// order (IEEE ops round identically lane-wise), selects `low + 1.0` vs
/// `low` by blend (not arithmetic, preserving `-0.0` and NaN payloads),
/// and reproduces `f32::signum` — ±1.0 by sign-bit transfer, canonical
/// NaN for NaN input — so every output bit matches the scalar twin.
pub(crate) fn qsgd_elems(chunk: &[f32], norm: f32, levels: f32, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(chunk.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    if using_avx2() {
        // SAFETY: AVX2 presence was runtime-checked by `using_avx2`.
        unsafe { qsgd_elems_avx2(chunk, norm, levels, rng, out) };
        return;
    }
    qsgd_elems_scalar(chunk, norm, levels, rng, out);
}

fn qsgd_elems_scalar(chunk: &[f32], norm: f32, levels: f32, rng: &mut Rng, out: &mut [f32]) {
    for (i, &x) in chunk.iter().enumerate() {
        let r = x.abs() / norm * levels;
        let low = r.floor();
        // Stochastic rounding: E[level] = r (unbiasedness, QSGD lemma 3.1)
        let level = if rng.uniform() < r - low { low + 1.0 } else { low };
        out[i] = x.signum() * norm * level / levels;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn qsgd_elems_avx2(chunk: &[f32], norm: f32, levels: f32, rng: &mut Rng, out: &mut [f32]) {
    use std::arch::x86_64::*;
    let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
    let sign_mask = _mm256_set1_ps(-0.0);
    let vnorm = _mm256_set1_ps(norm);
    let vlev = _mm256_set1_ps(levels);
    let one = _mm256_set1_ps(1.0);
    let canon_nan = _mm256_set1_ps(f32::NAN);
    let mut j = 0usize;
    while j + 8 <= chunk.len() {
        // The scalar twin draws one uniform per element in index order;
        // batch the same 8 draws before touching the lanes.
        let mut u = [0.0f32; 8];
        for slot in &mut u {
            *slot = rng.uniform();
        }
        // SAFETY: j + 8 <= chunk.len() == out.len(), unaligned load/store.
        let x = unsafe { _mm256_loadu_ps(chunk.as_ptr().add(j)) };
        let r = _mm256_mul_ps(_mm256_div_ps(_mm256_and_ps(x, abs_mask), vnorm), vlev);
        let low = _mm256_floor_ps(r);
        let bump = _mm256_cmp_ps::<_CMP_LT_OQ>(
            // SAFETY: `u` is 8 contiguous f32s.
            unsafe { _mm256_loadu_ps(u.as_ptr()) },
            _mm256_sub_ps(r, low),
        );
        let level = _mm256_blendv_ps(low, _mm256_add_ps(low, one), bump);
        let sgn = _mm256_or_ps(_mm256_and_ps(x, sign_mask), one);
        let sgn = _mm256_blendv_ps(sgn, canon_nan, _mm256_cmp_ps::<_CMP_UNORD_Q>(x, x));
        let d = _mm256_div_ps(_mm256_mul_ps(_mm256_mul_ps(sgn, vnorm), level), vlev);
        // SAFETY: as above.
        unsafe { _mm256_storeu_ps(out.as_mut_ptr().add(j), d) };
        j += 8;
    }
    qsgd_elems_scalar(&chunk[j..], norm, levels, rng, &mut out[j..]);
}

// ---------------------------------------------------------------------------
// f32 <-> f16 wire round-trip
// ---------------------------------------------------------------------------

/// Replace every value by its f16 wire round-trip (what the receiver
/// applies under `--fp16`), element-wise.
///
/// Bit-identity: the AVX2 variant does NOT use F16C (`vcvtps2ph` emits a
/// different NaN payload than our scalar converter) — it emulates the
/// exact integer algorithm of [`super::f16::f32_to_f16_bits`] /
/// [`super::f16::f16_bits_to_f32`] with AVX2 integer ops (variable
/// shifts, compares, blends), whose every step is bit-deterministic.
/// The one float step per direction — the subnormal `frac * 2^-24`
/// scale — is exact in both paths (int-to-float of a value < 2^11 and a
/// power-of-two multiply round identically).
pub(crate) fn f16_roundtrip_in_place(values: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if using_avx2() {
        // SAFETY: AVX2 presence was runtime-checked by `using_avx2`.
        unsafe { f16_roundtrip_avx2(values) };
        return;
    }
    for v in values.iter_mut() {
        *v = super::f16::f16_bits_to_f32(super::f16::f32_to_f16_bits(*v));
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn f16_roundtrip_avx2(values: &mut [f32]) {
    use std::arch::x86_64::*;

    #[inline]
    fn splat(v: i32) -> __m256i {
        // SAFETY: no preconditions.
        unsafe { _mm256_set1_epi32(v) }
    }

    let mut j = 0usize;
    while j + 8 <= values.len() {
        // SAFETY: j + 8 <= values.len(), unaligned load.
        let x = unsafe { _mm256_loadu_ps(values.as_ptr().add(j)) };
        let bits = _mm256_castps_si256(x);

        // ---- f32 -> f16 (f32_to_f16_bits, lane-parallel) ----
        let sign16 = _mm256_and_si256(_mm256_srli_epi32::<16>(bits), splat(0x8000));
        let exp = _mm256_and_si256(_mm256_srli_epi32::<23>(bits), splat(0xff));
        let frac = _mm256_and_si256(bits, splat(0x007f_ffff));
        let one = splat(1);

        // Normal path: exp16 = exp - 127 + 15, RNE on the low 13 bits;
        // the mantissa carry bumps the exponent via the plain add.
        let mant_n = _mm256_srli_epi32::<13>(frac);
        let rem_n = _mm256_and_si256(frac, splat(0x1fff));
        let odd_n = _mm256_cmpeq_epi32(_mm256_and_si256(mant_n, one), one);
        let rnd_n = _mm256_or_si256(
            _mm256_cmpgt_epi32(rem_n, splat(0x1000)),
            _mm256_and_si256(_mm256_cmpeq_epi32(rem_n, splat(0x1000)), odd_n),
        );
        let mant_n = _mm256_add_epi32(mant_n, _mm256_and_si256(rnd_n, one));
        let out_normal = _mm256_add_epi32(
            _mm256_slli_epi32::<10>(_mm256_sub_epi32(exp, splat(112))),
            mant_n,
        );

        // Subnormal path: shift = -1 - unbiased = 126 - exp (14..=24 when
        // this branch is selected; other lanes produce garbage that the
        // blend below discards).
        let shift = _mm256_sub_epi32(splat(126), exp);
        let mant32 = _mm256_or_si256(splat(0x0080_0000), frac);
        let mant_s = _mm256_srlv_epi32(mant32, shift);
        let rem_s = _mm256_and_si256(
            mant32,
            _mm256_sub_epi32(_mm256_sllv_epi32(one, shift), one),
        );
        let half = _mm256_sllv_epi32(one, _mm256_sub_epi32(shift, one));
        let odd_s = _mm256_cmpeq_epi32(_mm256_and_si256(mant_s, one), one);
        let rnd_s = _mm256_or_si256(
            _mm256_cmpgt_epi32(rem_s, half),
            _mm256_and_si256(_mm256_cmpeq_epi32(rem_s, half), odd_s),
        );
        let out_sub = _mm256_add_epi32(mant_s, _mm256_and_si256(rnd_s, one));

        // Inf/NaN: 0x7c00 plus the fixed 0x0200 quiet payload for NaN.
        let frac_nz = {
            let z = _mm256_cmpeq_epi32(frac, _mm256_setzero_si256());
            _mm256_xor_si256(z, splat(-1))
        };
        let out_special =
            _mm256_or_si256(splat(0x7c00), _mm256_and_si256(frac_nz, splat(0x0200)));

        // Select by exponent class, mirroring the scalar branch ladder:
        // exp == 255 -> special; exp > 142 -> inf; exp >= 113 -> normal;
        // exp >= 102 -> subnormal; else -> signed zero.
        let is_specl = _mm256_cmpeq_epi32(exp, splat(0xff));
        let is_inf = _mm256_cmpgt_epi32(exp, splat(142));
        let is_norm = _mm256_cmpgt_epi32(exp, splat(112));
        let is_sub = _mm256_cmpgt_epi32(exp, splat(101));
        let mut h = _mm256_and_si256(is_sub, out_sub);
        h = _mm256_blendv_epi8(h, out_normal, is_norm);
        h = _mm256_blendv_epi8(h, splat(0x7c00), is_inf);
        h = _mm256_blendv_epi8(h, out_special, is_specl);
        let h = _mm256_or_si256(sign16, h);

        // ---- f16 -> f32 (f16_bits_to_f32, lane-parallel) ----
        let sign32 = _mm256_slli_epi32::<16>(_mm256_and_si256(h, splat(0x8000)));
        let e16 = _mm256_and_si256(_mm256_srli_epi32::<10>(h), splat(0x1f));
        let f16 = _mm256_and_si256(h, splat(0x3ff));

        // exp == 0: frac * 2^-24 exactly (cvt of an int < 2^11 is exact,
        // power-of-two scaling is exact); the scalar negates the
        // magnitude, which for these non-NaN values is the sign-bit OR.
        let sub_f = _mm256_mul_ps(_mm256_cvtepi32_ps(f16), _mm256_set1_ps(5.960_464_5e-8));
        let back_sub = _mm256_castps_si256(sub_f);
        // Normal: rebias and shift the fraction up.
        let back_norm = _mm256_or_si256(
            _mm256_slli_epi32::<23>(_mm256_add_epi32(e16, splat(112))),
            _mm256_slli_epi32::<13>(f16),
        );
        // exp == 31: inf, or the canonical quiet NaN the scalar returns
        // (f32::NAN, sign applied by the trailing negation).
        let f16_nz = {
            let z = _mm256_cmpeq_epi32(f16, _mm256_setzero_si256());
            _mm256_xor_si256(z, splat(-1))
        };
        let back_spec = _mm256_blendv_epi8(splat(0x7f80_0000), splat(0x7fc0_0000), f16_nz);

        let e_is_zero = _mm256_cmpeq_epi32(e16, _mm256_setzero_si256());
        let e_is_max = _mm256_cmpeq_epi32(e16, splat(0x1f));
        let mut back = back_norm;
        back = _mm256_blendv_epi8(back, back_sub, e_is_zero);
        back = _mm256_blendv_epi8(back, back_spec, e_is_max);
        let back = _mm256_or_si256(back, sign32);

        // SAFETY: as above, unaligned store.
        unsafe { _mm256_storeu_ps(values.as_mut_ptr().add(j), _mm256_castsi256_ps(back)) };
        j += 8;
    }
    for v in values[j..].iter_mut() {
        *v = super::f16::f16_bits_to_f32(super::f16::f32_to_f16_bits(*v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialize dispatch-flipping tests (the unit tests in this module
    /// and the integration differential suite each guard their own
    /// binary; within one binary the harness runs tests concurrently).
    pub(crate) fn dispatch_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn force_scalar_pins_and_releases() {
        let _g = dispatch_lock();
        force_scalar(true);
        assert!(!using_avx2());
        force_scalar(false);
        // Either outcome is legal (hardware/env dependent); the call must
        // simply re-detect without panicking.
        let _ = using_avx2();
        force_scalar(true);
        assert!(!using_avx2());
        force_scalar(false);
    }

    #[test]
    fn scan_above_pairs_agree_on_adversarial_values() {
        let _g = dispatch_lock();
        let mut rng = crate::util::rng::Rng::new(0x51D);
        let specials = [
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            0.0,
            -0.0,
            1e-40,
            -1e-40,
            f32::MIN_POSITIVE,
        ];
        for len in [0usize, 1, 7, 8, 9, 16, 31, 32, 33, 257] {
            let mut g: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            for _ in 0..len / 3 {
                let at = rng.below(len.max(1));
                g[at] = specials[rng.below(specials.len())];
            }
            for thr in [0.5f32, 0.0, -0.0, f32::NAN, f32::INFINITY] {
                let (mut a, mut b) = (Vec::new(), Vec::new());
                force_scalar(true);
                scan_above(&g, 3, thr, &mut a);
                force_scalar(false);
                scan_above(&g, 3, thr, &mut b);
                assert_eq!(a, b, "len={len} thr={thr}");
            }
        }
        force_scalar(false);
    }
}
