//! Index coding for sparse-gradient payloads (paper §V-A: "the transferred
//! indices are entropy encoded — using the DEFLATE compression method —
//! and their rate is taken into account in the total rate calculation").
//!
//! Pipeline: sorted u32 indices -> delta encoding -> LEB128 varints ->
//! DEFLATE (LZ77 + dynamic Huffman since the vendored-`flate2` rewrite;
//! previously fixed-Huffman literals only).  A raw-bitmap fallback is
//! chosen automatically when denser selections would make it cheaper; the
//! 1-byte header records the mode.  Every byte that leaves a node flows
//! through [`encode`] / [`encode_into`], so ledger totals are measured,
//! never modeled.
//!
//! Beyond the historical hybrid, the codec family is selectable per run
//! ([`IndexCodec`], `--index-codec`): `golomb` Rice-codes the sorted index
//! gaps with the parameter derived from the measured mean gap (DGC / Lin
//! et al. budget indices this way; Sattler et al. show Golomb gap coding
//! is rate-optimal for top-k index streams), and `auto` encodes all three
//! candidates into scratch and emits the smallest (DESIGN.md §16.2).
//!
//! Hot-path variants ([`encode_into`], [`encode_with_into`],
//! [`encode_ordered_into`]) borrow an [`EncScratch`] arena and allocate
//! nothing in the steady state (DESIGN.md §6.11); the allocating wrappers
//! delegate to them, so both paths are byte-identical by construction.

use anyhow::{bail, Result};
use flate2::Compression;

use super::scratch::EncScratch;
use crate::obs::trace;

const MODE_DEFLATE_DELTA: u8 = 0;
const MODE_BITMAP: u8 = 1;
const MODE_GOLOMB: u8 = 2;

/// Per-layer index-codec strategy (`--index-codec`, DESIGN.md §16.2).
///
/// `Deflate` is the historical default: delta + varint + DEFLATE with the
/// built-in bitmap escape for dense selections — byte-identical to every
/// release before the codec family existed.  `Bitmap` and `Golomb` force
/// their single mode; `Auto` encodes all three candidates into scratch
/// and emits the smallest wire payload (ties break toward the lowest
/// mode byte: deflate 0, bitmap 1, golomb 2), so its payloads are \<= the
/// default's at every operating point by construction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum IndexCodec {
    /// Smallest of the three candidate encodings, per payload.
    Auto,
    /// Raw `n`-bit occupancy bitmap, always.
    Bitmap,
    /// Delta + varint + DEFLATE with bitmap escape (the historical codec).
    #[default]
    Deflate,
    /// Rice/Golomb coding of the sorted index gaps, always.
    Golomb,
}

impl IndexCodec {
    /// CLI name (`--index-codec` value).
    pub fn name(self) -> &'static str {
        match self {
            IndexCodec::Auto => "auto",
            IndexCodec::Bitmap => "bitmap",
            IndexCodec::Deflate => "deflate",
            IndexCodec::Golomb => "golomb",
        }
    }

    /// Parse a CLI name; `None` for unknown strategies.
    pub fn parse(s: &str) -> Option<IndexCodec> {
        match s {
            "auto" => Some(IndexCodec::Auto),
            "bitmap" => Some(IndexCodec::Bitmap),
            "deflate" => Some(IndexCodec::Deflate),
            "golomb" => Some(IndexCodec::Golomb),
            _ => None,
        }
    }

    /// Every strategy, for exhaustive tests and help text.
    pub fn all() -> [IndexCodec; 4] {
        [IndexCodec::Auto, IndexCodec::Bitmap, IndexCodec::Deflate, IndexCodec::Golomb]
    }
}

/// Reject unsorted/out-of-universe inputs (shared by every encoder).
fn validate(indices: &[u32], n: usize) -> Result<()> {
    debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices must be sorted unique");
    if let Some(&last) = indices.last() {
        if last as usize >= n {
            bail!("index {last} out of universe {n}");
        }
    }
    Ok(())
}

/// Build the delta+varint+DEFLATE candidate (`MODE_DEFLATE_DELTA` framing)
/// into `s.payload`; returns its full wire length.
fn deflate_candidate(indices: &[u32], s: &mut EncScratch) -> usize {
    s.varints.clear();
    let mut prev = 0u32;
    for (i, &idx) in indices.iter().enumerate() {
        let delta = if i == 0 { idx } else { idx - prev - 1 };
        write_varint(&mut s.varints, delta);
        prev = idx;
    }
    s.payload.clear();
    s.payload.push(MODE_DEFLATE_DELTA);
    s.payload.extend((indices.len() as u32).to_le_bytes());
    {
        let _sp = trace::span(trace::Stage::Deflate);
        flate2::compress_into(&s.varints, Compression::default(), &mut s.deflate, &mut s.payload);
    }
    s.payload.len()
}

/// Build the `MODE_BITMAP` framing into `out` (replacing its contents).
fn bitmap_into(indices: &[u32], n: usize, out: &mut Vec<u8>) {
    let bitmap_len = n.div_ceil(8);
    out.clear();
    out.resize(1 + bitmap_len, 0);
    out[0] = MODE_BITMAP;
    for &i in indices {
        out[1 + (i as usize) / 8] |= 1 << (i % 8);
    }
}

/// Encode a sorted index set over a universe of size `n`, reusing the
/// arena's buffers; the returned slice borrows `s.payload`.
pub fn encode_into<'a>(indices: &[u32], n: usize, s: &'a mut EncScratch) -> Result<&'a [u8]> {
    // One span per payload (and one nested around the DEFLATE call): a
    // single relaxed load when tracing is off, so the hot path the bench
    // smoke job guards stays untouched.
    let _sp = trace::span(trace::Stage::IndexCode);
    validate(indices, n)?;
    // Candidate A: delta + varint + deflate.
    let deflated_len = deflate_candidate(indices, s) - 5;

    // Candidate B: raw bitmap (wins for dense selections).  Compare full
    // wire sizes: deflate mode carries a 5-byte header, bitmap 1 byte.
    // (The old encoder compared the bodies only and could pick a payload
    // up to 4 bytes larger; `encode_fixed_baseline` preserves that rule.)
    let bitmap_len = n.div_ceil(8);
    if deflated_len + 4 <= bitmap_len {
        return Ok(&s.payload);
    }
    bitmap_into(indices, n, &mut s.payload);
    Ok(&s.payload)
}

/// Encode under an explicit [`IndexCodec`] strategy, reusing the arena's
/// buffers.  `Deflate` is exactly [`encode_into`]; the returned slice
/// borrows either `s.payload` or the arena's Golomb candidate buffer.
pub fn encode_with_into<'a>(
    indices: &[u32],
    n: usize,
    codec: IndexCodec,
    s: &'a mut EncScratch,
) -> Result<&'a [u8]> {
    match codec {
        IndexCodec::Deflate => encode_into(indices, n, s),
        IndexCodec::Bitmap => {
            let _sp = trace::span(trace::Stage::IndexCode);
            validate(indices, n)?;
            bitmap_into(indices, n, &mut s.payload);
            Ok(&s.payload)
        }
        IndexCodec::Golomb => {
            let _sp = trace::span(trace::Stage::IndexCode);
            validate(indices, n)?;
            golomb_into(indices, &mut s.golomb);
            Ok(&s.golomb)
        }
        IndexCodec::Auto => {
            let _sp = trace::span(trace::Stage::IndexCode);
            validate(indices, n)?;
            // All three candidates priced on full wire length; ties break
            // toward the lowest mode byte (deflate < bitmap < golomb), so
            // the pick is a pure function of the index set and `n`.
            let deflate_wire = deflate_candidate(indices, s);
            golomb_into(indices, &mut s.golomb);
            let golomb_wire = s.golomb.len();
            let bitmap_wire = 1 + n.div_ceil(8);
            if deflate_wire <= bitmap_wire && deflate_wire <= golomb_wire {
                Ok(&s.payload)
            } else if bitmap_wire <= golomb_wire {
                bitmap_into(indices, n, &mut s.payload);
                Ok(&s.payload)
            } else {
                Ok(&s.golomb)
            }
        }
    }
}

/// Allocating wrapper around [`encode_with_into`].
pub fn encode_with(indices: &[u32], n: usize, codec: IndexCodec) -> Result<Vec<u8>> {
    let mut s = EncScratch::new();
    encode_with_into(indices, n, codec, &mut s).map(|b| b.to_vec())
}

/// Encode a sorted index set over a universe of size `n` (allocating
/// wrapper around [`encode_into`]).
///
/// ```
/// use lgc::compress::index_coding::{decode, encode};
/// let idx: Vec<u32> = (0..800).step_by(8).collect(); // 100 sorted indices
/// let wire = encode(&idx, 100_000).unwrap();
/// assert!(wire.len() < idx.len() * 4); // beats raw u32 transmission
/// assert_eq!(decode(&wire, 100_000).unwrap(), idx); // lossless roundtrip
/// ```
pub fn encode(indices: &[u32], n: usize) -> Result<Vec<u8>> {
    let mut s = EncScratch::new();
    encode_into(indices, n, &mut s).map(|b| b.to_vec())
}

/// Rice parameter from the measured mean gap (DESIGN.md §16.2): `k =
/// floor(log2(mean_gap))`, the deterministic integer form of the
/// Golomb-parameter rule in Sattler et al.'s sparse binary compression
/// (SNIPPETS.md `__golomb_idx_size` picks `M ~ mean/phi` from the
/// sparsity rate; a power-of-two `M = 2^k` in `[mean/2, mean]` is within
/// one bit/symbol of that optimum and needs no floating point, so the
/// wire bytes are a pure function of the index set).
fn golomb_k(indices: &[u32]) -> u8 {
    let c = indices.len() as u64;
    if c == 0 {
        return 0;
    }
    // Sum of the coded gaps telescopes: gap_0 = idx_0, gap_i =
    // idx_i - idx_{i-1} - 1, so sum = last - (c - 1).
    let mean = (*indices.last().unwrap() as u64 + 1 - c) / c;
    if mean <= 1 {
        0
    } else {
        (63 - mean.leading_zeros()) as u8 // <= 31: mean <= u32::MAX
    }
}

/// LSB-first bit appender over a byte vector (Golomb bitstream).
struct BitWriter<'a> {
    out: &'a mut Vec<u8>,
    cur: u8,
    filled: u8,
}

impl<'a> BitWriter<'a> {
    fn new(out: &'a mut Vec<u8>) -> Self {
        BitWriter { out, cur: 0, filled: 0 }
    }

    fn bit(&mut self, b: bool) {
        if b {
            self.cur |= 1 << self.filled;
        }
        self.filled += 1;
        if self.filled == 8 {
            self.out.push(self.cur);
            self.cur = 0;
            self.filled = 0;
        }
    }

    fn bits(&mut self, v: u32, k: u8) {
        for j in 0..k {
            self.bit(v >> j & 1 != 0);
        }
    }

    fn finish(self) {
        if self.filled > 0 {
            self.out.push(self.cur); // zero-padded final byte
        }
    }
}

/// LSB-first bit cursor over an untrusted byte slice; every read is
/// bounds-checked so truncated payloads `bail!` instead of panicking.
struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize, // in bits
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    fn bit(&mut self) -> Result<bool> {
        if self.pos >= self.bytes.len() * 8 {
            bail!("truncated golomb bitstream");
        }
        let b = self.bytes[self.pos / 8] >> (self.pos % 8) & 1;
        self.pos += 1;
        Ok(b != 0)
    }

    fn bits(&mut self, k: u8) -> Result<u32> {
        let mut v = 0u32;
        for j in 0..k {
            if self.bit()? {
                v |= 1 << j;
            }
        }
        Ok(v)
    }

    /// Bytes touched so far (partial final byte included).
    fn consumed_bytes(&self) -> usize {
        self.pos.div_ceil(8)
    }
}

/// Build the `MODE_GOLOMB` framing into `out` (replacing its contents):
/// `[2][count u32 LE][k u8][bitstream]` where each sorted-gap is coded as
/// `gap >> k` one-bits, a zero terminator, then the `k` low bits of the
/// gap, all packed LSB-first.  Gap convention matches the deflate mode:
/// first gap is the index itself, then `idx - prev - 1`.
fn golomb_into(indices: &[u32], out: &mut Vec<u8>) {
    out.clear();
    out.push(MODE_GOLOMB);
    out.extend((indices.len() as u32).to_le_bytes());
    let k = golomb_k(indices);
    out.push(k);
    let mut bw = BitWriter::new(out);
    let mut prev = 0u32;
    for (i, &idx) in indices.iter().enumerate() {
        let gap = if i == 0 { idx } else { idx - prev - 1 };
        for _ in 0..gap >> k {
            bw.bit(true);
        }
        bw.bit(false);
        bw.bits(gap, k);
        prev = idx;
    }
    bw.finish();
}

/// The PR-2-era encoder: identical delta+varint+bitmap framing, but the
/// DEFLATE stage is the legacy fixed-Huffman/stored-only compressor with
/// per-call allocations.  Kept as the bench baseline the hot-path speedup
/// is measured against, and for the differential tests; never used on a
/// production path.
pub fn encode_fixed_baseline(indices: &[u32], n: usize) -> Result<Vec<u8>> {
    if let Some(&last) = indices.last() {
        if last as usize >= n {
            bail!("index {last} out of universe {n}");
        }
    }
    let mut varints = Vec::with_capacity(indices.len() * 2);
    let mut prev = 0u32;
    for (i, &idx) in indices.iter().enumerate() {
        let delta = if i == 0 { idx } else { idx - prev - 1 };
        write_varint(&mut varints, delta);
        prev = idx;
    }
    let deflated = flate2::legacy::deflate_fixed_only(&varints);
    let bitmap_len = n.div_ceil(8);
    if deflated.len() <= bitmap_len {
        let mut out = Vec::with_capacity(deflated.len() + 5);
        out.push(MODE_DEFLATE_DELTA);
        out.extend((indices.len() as u32).to_le_bytes());
        out.extend(deflated);
        Ok(out)
    } else {
        let mut out = vec![0u8; 1 + bitmap_len];
        out[0] = MODE_BITMAP;
        for &i in indices {
            out[1 + (i as usize) / 8] |= 1 << (i % 8);
        }
        Ok(out)
    }
}

/// Decode back to the sorted index list.
///
/// Total on untrusted input: truncated headers, truncated bitmaps,
/// inconsistent counts, and non-canonical varints all `bail!` instead of
/// panicking (the out-of-bounds bitmap read and the varint overflow were
/// real bugs; see the regression tests).
pub fn decode(bytes: &[u8], n: usize) -> Result<Vec<u32>> {
    match bytes.first() {
        Some(&MODE_DEFLATE_DELTA) => {
            if bytes.len() < 5 {
                bail!("truncated index payload: {} bytes < 5-byte header", bytes.len());
            }
            let count = u32::from_le_bytes(bytes[1..5].try_into()?) as usize;
            // A valid payload holds at most 5 varint bytes per index and
            // indices < n, so cap the inflation there — an adversarial
            // stream cannot demand unbounded memory (DEFLATE expands up
            // to ~1032x).
            let max_out = n.saturating_mul(5).saturating_add(16);
            let inflated = flate2::decompress_limited(&bytes[5..], max_out)?;
            // Each index costs at least one varint byte, so a count beyond
            // the inflated size is corrupt — reject before reserving.
            if count > inflated.len() {
                bail!("index count {count} exceeds payload ({} bytes)", inflated.len());
            }
            let mut out = Vec::with_capacity(count);
            let mut pos = 0usize;
            let mut prev = 0u32;
            for i in 0..count {
                let (delta, used) = read_varint(&inflated[pos..])?;
                pos += used;
                let idx = if i == 0 {
                    delta
                } else {
                    match prev.checked_add(delta).and_then(|v| v.checked_add(1)) {
                        Some(v) => v,
                        None => bail!("index delta overflows u32"),
                    }
                };
                // Enforce the output contract (sorted indices < n): a
                // corrupt payload must not hand out-of-universe indices
                // to unchecked scatter/gather consumers.
                if idx as usize >= n {
                    bail!("decoded index {idx} out of universe {n}");
                }
                out.push(idx);
                prev = idx;
            }
            Ok(out)
        }
        Some(&MODE_BITMAP) => {
            let need = 1 + n.div_ceil(8);
            if bytes.len() < need {
                bail!("truncated bitmap payload: {} bytes < {need}", bytes.len());
            }
            let mut out = Vec::new();
            for i in 0..n {
                if bytes[1 + i / 8] & (1 << (i % 8)) != 0 {
                    out.push(i as u32);
                }
            }
            Ok(out)
        }
        Some(&MODE_GOLOMB) => {
            if bytes.len() < 6 {
                bail!("truncated golomb payload: {} bytes < 6-byte header", bytes.len());
            }
            let count = u32::from_le_bytes(bytes[1..5].try_into()?) as usize;
            let k = bytes[5];
            if k > 31 {
                bail!("golomb parameter k={k} out of range (max 31)");
            }
            // Indices are unique in [0, n), so more than n of them is
            // corrupt; each symbol also costs at least k+1 bits, so a
            // count beyond the bit budget is rejected before decoding.
            if count > n {
                bail!("golomb index count {count} exceeds universe {n}");
            }
            let body = &bytes[6..];
            if (count as u64) * (k as u64 + 1) > body.len() as u64 * 8 {
                bail!(
                    "golomb payload too short: {count} symbols need more than {} bits",
                    body.len() * 8
                );
            }
            let mut br = BitReader::new(body);
            let mut out = Vec::with_capacity(count);
            let mut prev = 0u32;
            for i in 0..count {
                let mut q = 0u32;
                while br.bit()? {
                    q += 1;
                    // The unary run is self-bounding (every one-bit comes
                    // from the payload), but a quotient whose gap cannot
                    // fit a u32 is corrupt — reject before it overflows.
                    if (q as u64) << k > u32::MAX as u64 {
                        bail!("golomb quotient overflows u32 (k={k})");
                    }
                }
                let gap = (q << k) | br.bits(k)?;
                let idx = if i == 0 {
                    gap
                } else {
                    match prev.checked_add(gap).and_then(|v| v.checked_add(1)) {
                        Some(v) => v,
                        None => bail!("golomb index gap overflows u32"),
                    }
                };
                if idx as usize >= n {
                    bail!("decoded index {idx} out of universe {n}");
                }
                out.push(idx);
                prev = idx;
            }
            // Padding bits in the final byte are ignored, but whole bytes
            // past the last symbol mean the count and stream disagree.
            if br.consumed_bytes() < body.len() {
                bail!(
                    "golomb payload has {} trailing bytes past the last symbol",
                    body.len() - br.consumed_bytes()
                );
            }
            Ok(out)
        }
        Some(&mode) => bail!("unknown index-coding mode byte {mode:#04x} (known: 0..=2)"),
        None => bail!("empty index payload"),
    }
}

/// Encode an index list whose ORDER is significant (LGC phase 3: the
/// leader broadcasts its support in signed-descending-value order, which
/// is what makes the value-vectors smooth enough for the conv
/// autoencoder — DESIGN.md §6.6).  Delta coding would destroy the order,
/// so this DEFLATEs the raw LE-u32 stream; still counted byte-exactly.
/// The returned slice borrows `s.payload`.
pub fn encode_ordered_into<'a>(indices: &[u32], s: &'a mut EncScratch) -> Result<&'a [u8]> {
    let _sp = trace::span(trace::Stage::IndexCode);
    s.varints.clear();
    s.varints.extend((indices.len() as u32).to_le_bytes());
    for &i in indices {
        s.varints.extend(i.to_le_bytes());
    }
    s.payload.clear();
    {
        let _sp = trace::span(trace::Stage::Deflate);
        flate2::compress_into(&s.varints, Compression::default(), &mut s.deflate, &mut s.payload);
    }
    Ok(&s.payload)
}

/// Allocating wrapper around [`encode_ordered_into`].
pub fn encode_ordered(indices: &[u32]) -> Result<Vec<u8>> {
    let mut s = EncScratch::new();
    encode_ordered_into(indices, &mut s).map(|b| b.to_vec())
}

/// Upper bound on an inflated ordered-index payload (16M indices —
/// orders of magnitude above any support size this codebase transmits);
/// keeps adversarial streams from demanding unbounded memory.
const MAX_ORDERED_BYTES: usize = 64 << 20;

/// Decode an order-significant index list.
pub fn decode_ordered(bytes: &[u8]) -> Result<Vec<u32>> {
    let raw = flate2::decompress_limited(bytes, MAX_ORDERED_BYTES)?;
    if raw.len() < 4 {
        bail!("truncated ordered index payload");
    }
    let count = u32::from_le_bytes(raw[0..4].try_into()?) as usize;
    if raw.len() != 4 + 4 * count {
        bail!("ordered index payload length mismatch");
    }
    Ok((0..count)
        .map(|i| u32::from_le_bytes(raw[4 + 4 * i..8 + 4 * i].try_into().unwrap()))
        .collect())
}

fn write_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(b: &[u8]) -> Result<(u32, usize)> {
    let mut v = 0u32;
    for (i, &byte) in b.iter().enumerate().take(5) {
        // A u32 uses at most 4 bits of the 5th byte; anything above (or a
        // continuation bit there) is a non-canonical encoding whose high
        // bits would silently vanish — reject instead of mis-decoding.
        if i == 4 && byte > 0x0F {
            bail!("varint overflow: byte 5 is {byte:#04x}");
        }
        v |= ((byte & 0x7f) as u32) << (7 * i);
        if byte & 0x80 == 0 {
            return Ok((v, i + 1));
        }
    }
    bail!("truncated varint")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(indices: &[u32], n: usize) {
        let bytes = encode(indices, n).unwrap();
        assert_eq!(decode(&bytes, n).unwrap(), indices);
    }

    #[test]
    fn roundtrip_empty_and_single() {
        roundtrip(&[], 100);
        roundtrip(&[0], 100);
        roundtrip(&[99], 100);
    }

    #[test]
    fn roundtrip_random_sparse() {
        let mut rng = Rng::new(11);
        for n in [100usize, 10_000, 1_000_000] {
            let k = (n / 1000).max(2);
            let mut idx: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut idx);
            let mut sel: Vec<u32> = idx[..k].to_vec();
            sel.sort_unstable();
            roundtrip(&sel, n);
        }
    }

    #[test]
    fn dense_never_worse_than_bitmap() {
        // Contiguous dense runs delta-code to all zeros, which DEFLATE
        // crushes below the bitmap; either way the chosen mode must not
        // exceed bitmap size by more than the 5-byte header.
        let n = 1024usize;
        let all: Vec<u32> = (0..n as u32).collect();
        let bytes = encode(&all, n).unwrap();
        assert!(bytes.len() <= 1 + n / 8 + 5, "len={}", bytes.len());
        roundtrip(&all, n);
        // An adversarial random half-dense set round-trips through
        // whichever mode wins.
        let mut rng = Rng::new(77);
        let sel: Vec<u32> = (0..n as u32).filter(|_| rng.uniform() < 0.5).collect();
        roundtrip(&sel, n);
    }

    #[test]
    fn sparse_beats_raw_u32() {
        // 0.1% sparsity over 1M: coded indices must be well under 4 B each.
        let mut rng = Rng::new(5);
        let n = 1_000_000usize;
        let mut sel: Vec<u32> = (0..1000).map(|_| rng.below(n) as u32).collect();
        sel.sort_unstable();
        sel.dedup();
        let bytes = encode(&sel, n).unwrap();
        assert!(
            bytes.len() < sel.len() * 3,
            "coded {} bytes for {} indices",
            bytes.len(),
            sel.len()
        );
    }

    #[test]
    fn new_encoder_never_beaten_by_fixed_baseline() {
        // The dynamic-Huffman encoder considers fixed and stored blocks
        // too, so it can never lose to the old fixed-only path by more
        // than the block-choice tie; at the paper's operating points it
        // must win outright.
        let mut rng = Rng::new(21);
        let mut strictly_smaller = 0;
        let cases = [(262_144usize, 4096usize), (1_000_000, 1000), (200_000, 2000)];
        for &(n, k) in &cases {
            let mut set = std::collections::BTreeSet::new();
            while set.len() < k {
                set.insert(rng.below(n) as u32);
            }
            let sel: Vec<u32> = set.into_iter().collect();
            let new = encode(&sel, n).unwrap();
            let old = encode_fixed_baseline(&sel, n).unwrap();
            assert!(new.len() <= old.len(), "n={n} k={k}: {} > {}", new.len(), old.len());
            if new.len() < old.len() {
                strictly_smaller += 1;
            }
            assert_eq!(decode(&new, n).unwrap(), sel);
            assert_eq!(decode(&old, n).unwrap(), sel, "baseline framing must still decode");
        }
        assert_eq!(strictly_smaller, cases.len(), "dynamic coding should win every case");
    }

    #[test]
    fn rejects_out_of_universe() {
        assert!(encode(&[100], 100).is_err());
        assert!(encode_fixed_baseline(&[100], 100).is_err());
    }

    #[test]
    fn ordered_roundtrip_preserves_order() {
        let idx = vec![5u32, 1, 999, 3, 3_000_000];
        let bytes = encode_ordered(&idx).unwrap();
        assert_eq!(decode_ordered(&bytes).unwrap(), idx);
        assert!(encode_ordered(&[]).is_ok());
        assert_eq!(decode_ordered(&encode_ordered(&[]).unwrap()).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn varint_boundaries() {
        let mut buf = Vec::new();
        for v in [0u32, 127, 128, 16383, 16384, u32::MAX] {
            buf.clear();
            write_varint(&mut buf, v);
            assert_eq!(read_varint(&buf).unwrap(), (v, buf.len()));
        }
    }

    #[test]
    fn varint_rejects_overflow_bits() {
        // u32::MAX is the canonical ceiling: [FF FF FF FF 0F].
        assert_eq!(
            read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x0F]).unwrap(),
            (u32::MAX, 5)
        );
        // One bit past the top of u32 must be rejected, not discarded.
        assert!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x1F]).is_err());
        assert!(read_varint(&[0x80, 0x80, 0x80, 0x80, 0x7F]).is_err());
        // A continuation bit in the 5th byte can never be valid either.
        assert!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0xFF]).is_err());
        // Truncated streams still error.
        assert!(read_varint(&[]).is_err());
        assert!(read_varint(&[0x80]).is_err());
    }

    #[test]
    fn truncated_bitmap_errors_instead_of_panicking() {
        // Regression: a MODE_BITMAP payload shorter than the universe's
        // bitmap used to index out of bounds.  Craft the bitmap wire
        // format directly (the LZ77 encoder now crushes most dense
        // selections below bitmap size, so the mode is rarely chosen).
        let n = 1024usize;
        let sel: Vec<u32> = (0..n as u32).step_by(2).collect();
        let mut bytes = vec![0u8; 1 + n.div_ceil(8)];
        bytes[0] = 1;
        for &i in &sel {
            bytes[1 + (i as usize) / 8] |= 1 << (i % 8);
        }
        assert_eq!(decode(&bytes, n).unwrap(), sel, "crafted bitmap must decode");
        for cut in [1usize, 2, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode(&bytes[..cut], n).is_err(), "cut={cut}");
        }
        // Bitmap header alone, arbitrary n.
        assert!(decode(&[1u8], 64).is_err());
        assert!(decode(&[1u8, 0xFF], 64).is_err());
    }

    #[test]
    fn truncated_delta_header_errors() {
        // MODE_DEFLATE_DELTA with fewer than 5 header bytes.
        for len in 1..5 {
            let bytes = vec![0u8; len];
            assert!(decode(&bytes, 100).is_err(), "len={len}");
        }
        // Absurd count over a tiny payload is rejected before allocating.
        let mut bytes = vec![0u8];
        bytes.extend(u32::MAX.to_le_bytes());
        bytes.extend(flate2::compress(&[0u8; 4], flate2::Compression::default()));
        assert!(decode(&bytes, 100).is_err());
    }

    #[test]
    fn scratch_and_allocating_paths_agree() {
        let mut rng = Rng::new(0x1DC);
        let mut sc = crate::compress::scratch::EncScratch::new();
        for _ in 0..30 {
            let n = 128 + rng.below(100_000);
            let k = 1 + rng.below((n / 8).max(1));
            let mut set = std::collections::BTreeSet::new();
            while set.len() < k.min(n) {
                set.insert(rng.below(n) as u32);
            }
            let sel: Vec<u32> = set.into_iter().collect();
            let a = encode(&sel, n).unwrap();
            let b = encode_into(&sel, n, &mut sc).unwrap();
            assert_eq!(a, b);
            let c = encode_ordered(&sel).unwrap();
            let d = encode_ordered_into(&sel, &mut sc).unwrap();
            assert_eq!(c, d);
            for codec in IndexCodec::all() {
                let e = encode_with(&sel, n, codec).unwrap();
                let f = encode_with_into(&sel, n, codec, &mut sc).unwrap();
                assert_eq!(e, f, "codec {}", codec.name());
            }
        }
    }

    /// Random sorted index set: `k` draws over `[0, n)`, deduplicated.
    fn random_set(rng: &mut Rng, n: usize, k: usize) -> Vec<u32> {
        let mut set = std::collections::BTreeSet::new();
        while set.len() < k.min(n) {
            set.insert(rng.below(n) as u32);
        }
        set.into_iter().collect()
    }

    #[test]
    fn golomb_roundtrips_across_gap_distributions() {
        // Dense, single-index, u32::MAX, empty — the adversarial shapes —
        // plus random sparsities.
        let huge = u32::MAX as usize + 1;
        let cases: Vec<(Vec<u32>, usize)> = vec![
            (vec![], 100),
            (vec![0], 1),
            (vec![0], 100),
            (vec![99], 100),
            (vec![u32::MAX], huge),
            (vec![0, u32::MAX], huge),
            (vec![u32::MAX - 1, u32::MAX], huge),
            ((0..1024u32).collect(), 1024),
            ((0..1024u32).step_by(2).collect(), 1024),
        ];
        for (sel, n) in cases {
            let wire = encode_with(&sel, n, IndexCodec::Golomb).unwrap();
            assert_eq!(wire[0], MODE_GOLOMB);
            assert_eq!(decode(&wire, n).unwrap(), sel, "n={n} k={}", sel.len());
        }
        let mut rng = Rng::new(0x601);
        for &(n, k) in &[(1usize, 1usize), (64, 64), (10_000, 10), (262_144, 4096), (1 << 20, 1)] {
            let sel = random_set(&mut rng, n, k);
            let wire = encode_with(&sel, n, IndexCodec::Golomb).unwrap();
            assert_eq!(decode(&wire, n).unwrap(), sel, "n={n} k={k}");
        }
    }

    #[test]
    fn golomb_rate_matches_estimator() {
        // Exact transliteration of the size estimator (SNIPPETS.md
        // `__golomb_idx_size`, adapted to the integer parameter rule of
        // DESIGN.md §16.2): 6 header bytes + ceil(sum(gap >> k) + count
        // * (k + 1) bits / 8).  The encoder must hit it exactly.
        let mut rng = Rng::new(0x602);
        for &(n, k) in &[(262_144usize, 4096usize), (1_000_000, 1000), (65_536, 8192), (512, 500)]
        {
            let sel = random_set(&mut rng, n, k);
            let c = sel.len() as u64;
            let mean = (*sel.last().unwrap() as u64 + 1 - c) / c;
            let kk = if mean <= 1 { 0 } else { 63 - mean.leading_zeros() as u64 };
            let mut bits = 0u64;
            let mut prev = 0u32;
            for (i, &idx) in sel.iter().enumerate() {
                let gap = if i == 0 { idx } else { idx - prev - 1 } as u64;
                bits += (gap >> kk) + 1 + kk;
                prev = idx;
            }
            let expect = 6 + bits.div_ceil(8) as usize;
            let wire = encode_with(&sel, n, IndexCodec::Golomb).unwrap();
            assert_eq!(wire.len(), expect, "n={n} k={k}");
        }
    }

    #[test]
    fn golomb_beats_deflate_at_paper_sparsities() {
        // The rate-push claim at the fig10/11 operating points: Golomb
        // gaps beat delta+varint+DEFLATE for uniform sparse supports, so
        // `auto` has a real third candidate to pick.
        let mut rng = Rng::new(0x603);
        for &(n, k) in &[(262_144usize, 4096usize), (1_000_000, 1000), (200_000, 2000)] {
            let sel = random_set(&mut rng, n, k);
            let g = encode_with(&sel, n, IndexCodec::Golomb).unwrap();
            let d = encode_with(&sel, n, IndexCodec::Deflate).unwrap();
            assert!(g.len() < d.len(), "n={n} k={k}: golomb {} >= deflate {}", g.len(), d.len());
        }
    }

    #[test]
    fn auto_picks_the_minimum_candidate() {
        let mut rng = Rng::new(0x604);
        for _ in 0..40 {
            let n = 64 + rng.below(200_000);
            let k = 1 + rng.below(n.min(9000));
            let sel = random_set(&mut rng, n, k);
            let auto = encode_with(&sel, n, IndexCodec::Auto).unwrap();
            let forced: Vec<usize> = [IndexCodec::Bitmap, IndexCodec::Deflate, IndexCodec::Golomb]
                .into_iter()
                .map(|c| encode_with(&sel, n, c).unwrap().len())
                .collect();
            let min = *forced.iter().min().unwrap();
            assert_eq!(auto.len(), min, "n={n} k={} forced={forced:?}", sel.len());
            assert_eq!(decode(&auto, n).unwrap(), sel);
        }
        // Empty selection: every candidate is tiny, auto still decodes.
        let auto = encode_with(&[], 64, IndexCodec::Auto).unwrap();
        assert_eq!(decode(&auto, 64).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn deflate_strategy_is_the_legacy_encoder_byte_for_byte() {
        // The default strategy must keep every historical payload
        // identical — ledger totals and sim-vs-wire identity depend on it.
        let mut rng = Rng::new(0x605);
        for _ in 0..20 {
            let n = 64 + rng.below(100_000);
            let sel = random_set(&mut rng, n, 1 + rng.below(n.min(5000)));
            assert_eq!(
                encode_with(&sel, n, IndexCodec::Deflate).unwrap(),
                encode(&sel, n).unwrap()
            );
        }
    }

    #[test]
    fn every_codec_roundtrips_through_the_one_decoder() {
        // The decoder dispatches on the wire mode byte alone, so it must
        // accept all modes regardless of the sender's picker strategy.
        let mut rng = Rng::new(0x606);
        for codec in IndexCodec::all() {
            for &(n, k) in &[(1usize, 1usize), (100, 7), (4096, 4096), (65_536, 700)] {
                let sel = random_set(&mut rng, n, k);
                let wire = encode_with(&sel, n, codec).unwrap();
                assert_eq!(decode(&wire, n).unwrap(), sel, "codec {} n={n}", codec.name());
            }
            assert!(encode_with(&[100], 100, codec).is_err(), "out-of-universe must fail");
        }
    }

    #[test]
    fn unknown_mode_bytes_bail_descriptively() {
        // Reserved/unknown mode bytes (3..=255 now that 2 is Golomb) must
        // error — never panic — whatever follows them.
        for mode in 3u8..=255 {
            for tail in [0usize, 1, 5, 64] {
                let mut bytes = vec![mode];
                bytes.extend(std::iter::repeat_n(0xA5u8, tail));
                let err = decode(&bytes, 1024).unwrap_err().to_string();
                assert!(err.contains("unknown index-coding mode"), "mode {mode}: {err}");
            }
        }
        assert!(decode(&[], 1024).unwrap_err().to_string().contains("empty"));
    }

    #[test]
    fn corrupt_golomb_payloads_error_instead_of_panicking() {
        let sel: Vec<u32> = (0..4096u32).step_by(3).collect();
        let n = 65_536usize;
        let wire = encode_with(&sel, n, IndexCodec::Golomb).unwrap();
        // Truncations at every prefix class.
        for cut in [1usize, 2, 5, 6, 7, wire.len() / 2, wire.len() - 1] {
            assert!(decode(&wire[..cut], n).is_err(), "cut={cut}");
        }
        // Out-of-range parameter.
        let mut bad = wire.clone();
        bad[5] = 32;
        assert!(decode(&bad, n).unwrap_err().to_string().contains("out of range"));
        // Count beyond the universe.
        let mut bad = wire.clone();
        bad[1..5].copy_from_slice(&(n as u32 + 1).to_le_bytes());
        assert!(decode(&bad, n).unwrap_err().to_string().contains("exceeds universe"));
        // Count beyond the bit budget.
        let mut bad = wire.clone();
        bad[1..5].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&bad, usize::MAX).unwrap_err().to_string().contains("too short"));
        // Trailing bytes past the last symbol.
        let mut bad = wire.clone();
        bad.extend([0u8; 3]);
        assert!(decode(&bad, n).unwrap_err().to_string().contains("trailing"));
        // A decoded index walking past the universe bound.
        assert!(decode(&wire, sel.len()).is_err(), "shrunken universe must reject");
        // All-ones bitstream: unbounded unary run must hit the quotient
        // guard (or the truncation guard), not loop into an overflow.
        let mut bad = vec![MODE_GOLOMB];
        bad.extend(1u32.to_le_bytes());
        bad.push(0);
        bad.extend([0xFFu8; 64]);
        assert!(decode(&bad, 1 << 20).is_err());
    }
}
