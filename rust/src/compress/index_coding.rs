//! Index coding for sparse-gradient payloads (paper §V-A: "the transferred
//! indices are entropy encoded — using the DEFLATE compression method —
//! and their rate is taken into account in the total rate calculation").
//!
//! Pipeline: sorted u32 indices -> delta encoding -> LEB128 varints ->
//! DEFLATE (LZ77 + dynamic Huffman since the vendored-`flate2` rewrite;
//! previously fixed-Huffman literals only).  A raw-bitmap fallback is
//! chosen automatically when denser selections would make it cheaper; the
//! 1-byte header records the mode.  Every byte that leaves a node flows
//! through [`encode`] / [`encode_into`], so ledger totals are measured,
//! never modeled.
//!
//! Hot-path variants ([`encode_into`], [`encode_ordered_into`]) borrow an
//! [`EncScratch`] arena and allocate nothing in the steady state
//! (DESIGN.md §6.11); the allocating wrappers delegate to them, so both
//! paths are byte-identical by construction.

use anyhow::{bail, Result};
use flate2::Compression;

use super::scratch::EncScratch;
use crate::obs::trace;

const MODE_DEFLATE_DELTA: u8 = 0;
const MODE_BITMAP: u8 = 1;

/// Encode a sorted index set over a universe of size `n`, reusing the
/// arena's buffers; the returned slice borrows `s.payload`.
pub fn encode_into<'a>(indices: &[u32], n: usize, s: &'a mut EncScratch) -> Result<&'a [u8]> {
    // One span per payload (and one nested around the DEFLATE call): a
    // single relaxed load when tracing is off, so the hot path the bench
    // smoke job guards stays untouched.
    let _sp = trace::span(trace::Stage::IndexCode);
    debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices must be sorted unique");
    if let Some(&last) = indices.last() {
        if last as usize >= n {
            bail!("index {last} out of universe {n}");
        }
    }
    // Candidate A: delta + varint + deflate.
    s.varints.clear();
    let mut prev = 0u32;
    for (i, &idx) in indices.iter().enumerate() {
        let delta = if i == 0 { idx } else { idx - prev - 1 };
        write_varint(&mut s.varints, delta);
        prev = idx;
    }
    s.payload.clear();
    s.payload.push(MODE_DEFLATE_DELTA);
    s.payload.extend((indices.len() as u32).to_le_bytes());
    {
        let _sp = trace::span(trace::Stage::Deflate);
        flate2::compress_into(&s.varints, Compression::default(), &mut s.deflate, &mut s.payload);
    }
    let deflated_len = s.payload.len() - 5;

    // Candidate B: raw bitmap (wins for dense selections).  Compare full
    // wire sizes: deflate mode carries a 5-byte header, bitmap 1 byte.
    // (The old encoder compared the bodies only and could pick a payload
    // up to 4 bytes larger; `encode_fixed_baseline` preserves that rule.)
    let bitmap_len = n.div_ceil(8);
    if deflated_len + 4 <= bitmap_len {
        return Ok(&s.payload);
    }
    s.payload.clear();
    s.payload.resize(1 + bitmap_len, 0);
    s.payload[0] = MODE_BITMAP;
    for &i in indices {
        s.payload[1 + (i as usize) / 8] |= 1 << (i % 8);
    }
    Ok(&s.payload)
}

/// Encode a sorted index set over a universe of size `n` (allocating
/// wrapper around [`encode_into`]).
///
/// ```
/// use lgc::compress::index_coding::{decode, encode};
/// let idx: Vec<u32> = (0..800).step_by(8).collect(); // 100 sorted indices
/// let wire = encode(&idx, 100_000).unwrap();
/// assert!(wire.len() < idx.len() * 4); // beats raw u32 transmission
/// assert_eq!(decode(&wire, 100_000).unwrap(), idx); // lossless roundtrip
/// ```
pub fn encode(indices: &[u32], n: usize) -> Result<Vec<u8>> {
    let mut s = EncScratch::new();
    encode_into(indices, n, &mut s).map(|b| b.to_vec())
}

/// The PR-2-era encoder: identical delta+varint+bitmap framing, but the
/// DEFLATE stage is the legacy fixed-Huffman/stored-only compressor with
/// per-call allocations.  Kept as the bench baseline the hot-path speedup
/// is measured against, and for the differential tests; never used on a
/// production path.
pub fn encode_fixed_baseline(indices: &[u32], n: usize) -> Result<Vec<u8>> {
    if let Some(&last) = indices.last() {
        if last as usize >= n {
            bail!("index {last} out of universe {n}");
        }
    }
    let mut varints = Vec::with_capacity(indices.len() * 2);
    let mut prev = 0u32;
    for (i, &idx) in indices.iter().enumerate() {
        let delta = if i == 0 { idx } else { idx - prev - 1 };
        write_varint(&mut varints, delta);
        prev = idx;
    }
    let deflated = flate2::legacy::deflate_fixed_only(&varints);
    let bitmap_len = n.div_ceil(8);
    if deflated.len() <= bitmap_len {
        let mut out = Vec::with_capacity(deflated.len() + 5);
        out.push(MODE_DEFLATE_DELTA);
        out.extend((indices.len() as u32).to_le_bytes());
        out.extend(deflated);
        Ok(out)
    } else {
        let mut out = vec![0u8; 1 + bitmap_len];
        out[0] = MODE_BITMAP;
        for &i in indices {
            out[1 + (i as usize) / 8] |= 1 << (i % 8);
        }
        Ok(out)
    }
}

/// Decode back to the sorted index list.
///
/// Total on untrusted input: truncated headers, truncated bitmaps,
/// inconsistent counts, and non-canonical varints all `bail!` instead of
/// panicking (the out-of-bounds bitmap read and the varint overflow were
/// real bugs; see the regression tests).
pub fn decode(bytes: &[u8], n: usize) -> Result<Vec<u32>> {
    match bytes.first() {
        Some(&MODE_DEFLATE_DELTA) => {
            if bytes.len() < 5 {
                bail!("truncated index payload: {} bytes < 5-byte header", bytes.len());
            }
            let count = u32::from_le_bytes(bytes[1..5].try_into()?) as usize;
            // A valid payload holds at most 5 varint bytes per index and
            // indices < n, so cap the inflation there — an adversarial
            // stream cannot demand unbounded memory (DEFLATE expands up
            // to ~1032x).
            let max_out = n.saturating_mul(5).saturating_add(16);
            let inflated = flate2::decompress_limited(&bytes[5..], max_out)?;
            // Each index costs at least one varint byte, so a count beyond
            // the inflated size is corrupt — reject before reserving.
            if count > inflated.len() {
                bail!("index count {count} exceeds payload ({} bytes)", inflated.len());
            }
            let mut out = Vec::with_capacity(count);
            let mut pos = 0usize;
            let mut prev = 0u32;
            for i in 0..count {
                let (delta, used) = read_varint(&inflated[pos..])?;
                pos += used;
                let idx = if i == 0 {
                    delta
                } else {
                    match prev.checked_add(delta).and_then(|v| v.checked_add(1)) {
                        Some(v) => v,
                        None => bail!("index delta overflows u32"),
                    }
                };
                // Enforce the output contract (sorted indices < n): a
                // corrupt payload must not hand out-of-universe indices
                // to unchecked scatter/gather consumers.
                if idx as usize >= n {
                    bail!("decoded index {idx} out of universe {n}");
                }
                out.push(idx);
                prev = idx;
            }
            Ok(out)
        }
        Some(&MODE_BITMAP) => {
            let need = 1 + n.div_ceil(8);
            if bytes.len() < need {
                bail!("truncated bitmap payload: {} bytes < {need}", bytes.len());
            }
            let mut out = Vec::new();
            for i in 0..n {
                if bytes[1 + i / 8] & (1 << (i % 8)) != 0 {
                    out.push(i as u32);
                }
            }
            Ok(out)
        }
        _ => bail!("bad index-coding header"),
    }
}

/// Encode an index list whose ORDER is significant (LGC phase 3: the
/// leader broadcasts its support in signed-descending-value order, which
/// is what makes the value-vectors smooth enough for the conv
/// autoencoder — DESIGN.md §6.6).  Delta coding would destroy the order,
/// so this DEFLATEs the raw LE-u32 stream; still counted byte-exactly.
/// The returned slice borrows `s.payload`.
pub fn encode_ordered_into<'a>(indices: &[u32], s: &'a mut EncScratch) -> Result<&'a [u8]> {
    let _sp = trace::span(trace::Stage::IndexCode);
    s.varints.clear();
    s.varints.extend((indices.len() as u32).to_le_bytes());
    for &i in indices {
        s.varints.extend(i.to_le_bytes());
    }
    s.payload.clear();
    {
        let _sp = trace::span(trace::Stage::Deflate);
        flate2::compress_into(&s.varints, Compression::default(), &mut s.deflate, &mut s.payload);
    }
    Ok(&s.payload)
}

/// Allocating wrapper around [`encode_ordered_into`].
pub fn encode_ordered(indices: &[u32]) -> Result<Vec<u8>> {
    let mut s = EncScratch::new();
    encode_ordered_into(indices, &mut s).map(|b| b.to_vec())
}

/// Upper bound on an inflated ordered-index payload (16M indices —
/// orders of magnitude above any support size this codebase transmits);
/// keeps adversarial streams from demanding unbounded memory.
const MAX_ORDERED_BYTES: usize = 64 << 20;

/// Decode an order-significant index list.
pub fn decode_ordered(bytes: &[u8]) -> Result<Vec<u32>> {
    let raw = flate2::decompress_limited(bytes, MAX_ORDERED_BYTES)?;
    if raw.len() < 4 {
        bail!("truncated ordered index payload");
    }
    let count = u32::from_le_bytes(raw[0..4].try_into()?) as usize;
    if raw.len() != 4 + 4 * count {
        bail!("ordered index payload length mismatch");
    }
    Ok((0..count)
        .map(|i| u32::from_le_bytes(raw[4 + 4 * i..8 + 4 * i].try_into().unwrap()))
        .collect())
}

fn write_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(b: &[u8]) -> Result<(u32, usize)> {
    let mut v = 0u32;
    for (i, &byte) in b.iter().enumerate().take(5) {
        // A u32 uses at most 4 bits of the 5th byte; anything above (or a
        // continuation bit there) is a non-canonical encoding whose high
        // bits would silently vanish — reject instead of mis-decoding.
        if i == 4 && byte > 0x0F {
            bail!("varint overflow: byte 5 is {byte:#04x}");
        }
        v |= ((byte & 0x7f) as u32) << (7 * i);
        if byte & 0x80 == 0 {
            return Ok((v, i + 1));
        }
    }
    bail!("truncated varint")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(indices: &[u32], n: usize) {
        let bytes = encode(indices, n).unwrap();
        assert_eq!(decode(&bytes, n).unwrap(), indices);
    }

    #[test]
    fn roundtrip_empty_and_single() {
        roundtrip(&[], 100);
        roundtrip(&[0], 100);
        roundtrip(&[99], 100);
    }

    #[test]
    fn roundtrip_random_sparse() {
        let mut rng = Rng::new(11);
        for n in [100usize, 10_000, 1_000_000] {
            let k = (n / 1000).max(2);
            let mut idx: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut idx);
            let mut sel: Vec<u32> = idx[..k].to_vec();
            sel.sort_unstable();
            roundtrip(&sel, n);
        }
    }

    #[test]
    fn dense_never_worse_than_bitmap() {
        // Contiguous dense runs delta-code to all zeros, which DEFLATE
        // crushes below the bitmap; either way the chosen mode must not
        // exceed bitmap size by more than the 5-byte header.
        let n = 1024usize;
        let all: Vec<u32> = (0..n as u32).collect();
        let bytes = encode(&all, n).unwrap();
        assert!(bytes.len() <= 1 + n / 8 + 5, "len={}", bytes.len());
        roundtrip(&all, n);
        // An adversarial random half-dense set round-trips through
        // whichever mode wins.
        let mut rng = Rng::new(77);
        let sel: Vec<u32> = (0..n as u32).filter(|_| rng.uniform() < 0.5).collect();
        roundtrip(&sel, n);
    }

    #[test]
    fn sparse_beats_raw_u32() {
        // 0.1% sparsity over 1M: coded indices must be well under 4 B each.
        let mut rng = Rng::new(5);
        let n = 1_000_000usize;
        let mut sel: Vec<u32> = (0..1000).map(|_| rng.below(n) as u32).collect();
        sel.sort_unstable();
        sel.dedup();
        let bytes = encode(&sel, n).unwrap();
        assert!(
            bytes.len() < sel.len() * 3,
            "coded {} bytes for {} indices",
            bytes.len(),
            sel.len()
        );
    }

    #[test]
    fn new_encoder_never_beaten_by_fixed_baseline() {
        // The dynamic-Huffman encoder considers fixed and stored blocks
        // too, so it can never lose to the old fixed-only path by more
        // than the block-choice tie; at the paper's operating points it
        // must win outright.
        let mut rng = Rng::new(21);
        let mut strictly_smaller = 0;
        let cases = [(262_144usize, 4096usize), (1_000_000, 1000), (200_000, 2000)];
        for &(n, k) in &cases {
            let mut set = std::collections::BTreeSet::new();
            while set.len() < k {
                set.insert(rng.below(n) as u32);
            }
            let sel: Vec<u32> = set.into_iter().collect();
            let new = encode(&sel, n).unwrap();
            let old = encode_fixed_baseline(&sel, n).unwrap();
            assert!(new.len() <= old.len(), "n={n} k={k}: {} > {}", new.len(), old.len());
            if new.len() < old.len() {
                strictly_smaller += 1;
            }
            assert_eq!(decode(&new, n).unwrap(), sel);
            assert_eq!(decode(&old, n).unwrap(), sel, "baseline framing must still decode");
        }
        assert_eq!(strictly_smaller, cases.len(), "dynamic coding should win every case");
    }

    #[test]
    fn rejects_out_of_universe() {
        assert!(encode(&[100], 100).is_err());
        assert!(encode_fixed_baseline(&[100], 100).is_err());
    }

    #[test]
    fn ordered_roundtrip_preserves_order() {
        let idx = vec![5u32, 1, 999, 3, 3_000_000];
        let bytes = encode_ordered(&idx).unwrap();
        assert_eq!(decode_ordered(&bytes).unwrap(), idx);
        assert!(encode_ordered(&[]).is_ok());
        assert_eq!(decode_ordered(&encode_ordered(&[]).unwrap()).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn varint_boundaries() {
        let mut buf = Vec::new();
        for v in [0u32, 127, 128, 16383, 16384, u32::MAX] {
            buf.clear();
            write_varint(&mut buf, v);
            assert_eq!(read_varint(&buf).unwrap(), (v, buf.len()));
        }
    }

    #[test]
    fn varint_rejects_overflow_bits() {
        // u32::MAX is the canonical ceiling: [FF FF FF FF 0F].
        assert_eq!(
            read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x0F]).unwrap(),
            (u32::MAX, 5)
        );
        // One bit past the top of u32 must be rejected, not discarded.
        assert!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0x1F]).is_err());
        assert!(read_varint(&[0x80, 0x80, 0x80, 0x80, 0x7F]).is_err());
        // A continuation bit in the 5th byte can never be valid either.
        assert!(read_varint(&[0xFF, 0xFF, 0xFF, 0xFF, 0xFF]).is_err());
        // Truncated streams still error.
        assert!(read_varint(&[]).is_err());
        assert!(read_varint(&[0x80]).is_err());
    }

    #[test]
    fn truncated_bitmap_errors_instead_of_panicking() {
        // Regression: a MODE_BITMAP payload shorter than the universe's
        // bitmap used to index out of bounds.  Craft the bitmap wire
        // format directly (the LZ77 encoder now crushes most dense
        // selections below bitmap size, so the mode is rarely chosen).
        let n = 1024usize;
        let sel: Vec<u32> = (0..n as u32).step_by(2).collect();
        let mut bytes = vec![0u8; 1 + n.div_ceil(8)];
        bytes[0] = 1;
        for &i in &sel {
            bytes[1 + (i as usize) / 8] |= 1 << (i % 8);
        }
        assert_eq!(decode(&bytes, n).unwrap(), sel, "crafted bitmap must decode");
        for cut in [1usize, 2, bytes.len() / 2, bytes.len() - 1] {
            assert!(decode(&bytes[..cut], n).is_err(), "cut={cut}");
        }
        // Bitmap header alone, arbitrary n.
        assert!(decode(&[1u8], 64).is_err());
        assert!(decode(&[1u8, 0xFF], 64).is_err());
    }

    #[test]
    fn truncated_delta_header_errors() {
        // MODE_DEFLATE_DELTA with fewer than 5 header bytes.
        for len in 1..5 {
            let bytes = vec![0u8; len];
            assert!(decode(&bytes, 100).is_err(), "len={len}");
        }
        // Absurd count over a tiny payload is rejected before allocating.
        let mut bytes = vec![0u8];
        bytes.extend(u32::MAX.to_le_bytes());
        bytes.extend(flate2::compress(&[0u8; 4], flate2::Compression::default()));
        assert!(decode(&bytes, 100).is_err());
    }

    #[test]
    fn scratch_and_allocating_paths_agree() {
        let mut rng = Rng::new(0x1DC);
        let mut sc = crate::compress::scratch::EncScratch::new();
        for _ in 0..30 {
            let n = 128 + rng.below(100_000);
            let k = 1 + rng.below((n / 8).max(1));
            let mut set = std::collections::BTreeSet::new();
            while set.len() < k.min(n) {
                set.insert(rng.below(n) as u32);
            }
            let sel: Vec<u32> = set.into_iter().collect();
            let a = encode(&sel, n).unwrap();
            let b = encode_into(&sel, n, &mut sc).unwrap();
            assert_eq!(a, b);
            let c = encode_ordered(&sel).unwrap();
            let d = encode_ordered_into(&sel, &mut sc).unwrap();
            assert_eq!(c, d);
        }
    }
}
