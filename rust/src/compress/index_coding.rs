//! Index coding for sparse-gradient payloads (paper §V-A: "the transferred
//! indices are entropy encoded — using the DEFLATE compression method —
//! and their rate is taken into account in the total rate calculation").
//!
//! Pipeline: sorted u32 indices -> delta encoding -> LEB128 varints ->
//! DEFLATE.  A raw-bitmap fallback is chosen automatically when denser
//! selections would make it cheaper; the 1-byte header records the mode.
//! Every byte that leaves a node flows through [`encode`], so ledger totals
//! are measured, never modeled.

use std::io::{Read, Write};

use anyhow::{bail, Result};
use flate2::read::DeflateDecoder;
use flate2::write::DeflateEncoder;
use flate2::Compression;

const MODE_DEFLATE_DELTA: u8 = 0;
const MODE_BITMAP: u8 = 1;

/// Encode a sorted index set over a universe of size `n`.
pub fn encode(indices: &[u32], n: usize) -> Result<Vec<u8>> {
    debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices must be sorted unique");
    if let Some(&last) = indices.last() {
        if last as usize >= n {
            bail!("index {last} out of universe {n}");
        }
    }
    // Candidate A: delta + varint + deflate.
    let mut varints = Vec::with_capacity(indices.len() * 2);
    let mut prev = 0u32;
    for (i, &idx) in indices.iter().enumerate() {
        let delta = if i == 0 { idx } else { idx - prev - 1 };
        write_varint(&mut varints, delta);
        prev = idx;
    }
    let mut enc = DeflateEncoder::new(Vec::new(), Compression::default());
    enc.write_all(&varints)?;
    let deflated = enc.finish()?;

    // Candidate B: raw bitmap (wins for dense selections).
    let bitmap_len = n.div_ceil(8);

    if deflated.len() <= bitmap_len {
        let mut out = Vec::with_capacity(deflated.len() + 5);
        out.push(MODE_DEFLATE_DELTA);
        out.extend((indices.len() as u32).to_le_bytes());
        out.extend(deflated);
        Ok(out)
    } else {
        let mut out = vec![0u8; 1 + bitmap_len];
        out[0] = MODE_BITMAP;
        for &i in indices {
            out[1 + (i as usize) / 8] |= 1 << (i % 8);
        }
        Ok(out)
    }
}

/// Decode back to the sorted index list.
pub fn decode(bytes: &[u8], n: usize) -> Result<Vec<u32>> {
    match bytes.first() {
        Some(&MODE_DEFLATE_DELTA) => {
            let count = u32::from_le_bytes(bytes[1..5].try_into()?) as usize;
            let mut inflated = Vec::new();
            DeflateDecoder::new(&bytes[5..]).read_to_end(&mut inflated)?;
            let mut out = Vec::with_capacity(count);
            let mut pos = 0usize;
            let mut prev = 0u32;
            for i in 0..count {
                let (delta, used) = read_varint(&inflated[pos..])?;
                pos += used;
                let idx = if i == 0 { delta } else { prev + delta + 1 };
                out.push(idx);
                prev = idx;
            }
            Ok(out)
        }
        Some(&MODE_BITMAP) => {
            let mut out = Vec::new();
            for i in 0..n {
                if bytes[1 + i / 8] & (1 << (i % 8)) != 0 {
                    out.push(i as u32);
                }
            }
            Ok(out)
        }
        _ => bail!("bad index-coding header"),
    }
}

/// Encode an index list whose ORDER is significant (LGC phase 3: the
/// leader broadcasts its support in signed-descending-value order, which
/// is what makes the value-vectors smooth enough for the conv
/// autoencoder — DESIGN.md §6.6).  Delta coding would destroy the order,
/// so this DEFLATEs the raw LE-u32 stream; still counted byte-exactly.
pub fn encode_ordered(indices: &[u32]) -> Result<Vec<u8>> {
    let mut raw = Vec::with_capacity(indices.len() * 4 + 4);
    raw.extend((indices.len() as u32).to_le_bytes());
    for &i in indices {
        raw.extend(i.to_le_bytes());
    }
    let mut enc = DeflateEncoder::new(Vec::new(), Compression::default());
    enc.write_all(&raw)?;
    Ok(enc.finish()?)
}

/// Decode an order-significant index list.
pub fn decode_ordered(bytes: &[u8]) -> Result<Vec<u32>> {
    let mut raw = Vec::new();
    DeflateDecoder::new(bytes).read_to_end(&mut raw)?;
    if raw.len() < 4 {
        bail!("truncated ordered index payload");
    }
    let count = u32::from_le_bytes(raw[0..4].try_into()?) as usize;
    if raw.len() != 4 + 4 * count {
        bail!("ordered index payload length mismatch");
    }
    Ok((0..count)
        .map(|i| u32::from_le_bytes(raw[4 + 4 * i..8 + 4 * i].try_into().unwrap()))
        .collect())
}

fn write_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(b: &[u8]) -> Result<(u32, usize)> {
    let mut v = 0u32;
    for (i, &byte) in b.iter().enumerate().take(5) {
        v |= ((byte & 0x7f) as u32) << (7 * i);
        if byte & 0x80 == 0 {
            return Ok((v, i + 1));
        }
    }
    bail!("truncated varint")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(indices: &[u32], n: usize) {
        let bytes = encode(indices, n).unwrap();
        assert_eq!(decode(&bytes, n).unwrap(), indices);
    }

    #[test]
    fn roundtrip_empty_and_single() {
        roundtrip(&[], 100);
        roundtrip(&[0], 100);
        roundtrip(&[99], 100);
    }

    #[test]
    fn roundtrip_random_sparse() {
        let mut rng = Rng::new(11);
        for n in [100usize, 10_000, 1_000_000] {
            let k = (n / 1000).max(2);
            let mut idx: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut idx);
            let mut sel: Vec<u32> = idx[..k].to_vec();
            sel.sort_unstable();
            roundtrip(&sel, n);
        }
    }

    #[test]
    fn dense_never_worse_than_bitmap() {
        // Contiguous dense runs delta-code to all zeros, which DEFLATE
        // crushes below the bitmap; either way the chosen mode must not
        // exceed bitmap size by more than the 5-byte header.
        let n = 1024usize;
        let all: Vec<u32> = (0..n as u32).collect();
        let bytes = encode(&all, n).unwrap();
        assert!(bytes.len() <= 1 + n / 8 + 5, "len={}", bytes.len());
        roundtrip(&all, n);
        // An adversarial random half-dense set round-trips through
        // whichever mode wins.
        let mut rng = Rng::new(77);
        let sel: Vec<u32> = (0..n as u32).filter(|_| rng.uniform() < 0.5).collect();
        roundtrip(&sel, n);
    }

    #[test]
    fn sparse_beats_raw_u32() {
        // 0.1% sparsity over 1M: coded indices must be well under 4 B each.
        let mut rng = Rng::new(5);
        let n = 1_000_000usize;
        let mut sel: Vec<u32> = (0..1000).map(|_| rng.below(n) as u32).collect();
        sel.sort_unstable();
        sel.dedup();
        let bytes = encode(&sel, n).unwrap();
        assert!(
            bytes.len() < sel.len() * 3,
            "coded {} bytes for {} indices",
            bytes.len(),
            sel.len()
        );
    }

    #[test]
    fn rejects_out_of_universe() {
        assert!(encode(&[100], 100).is_err());
    }

    #[test]
    fn ordered_roundtrip_preserves_order() {
        let idx = vec![5u32, 1, 999, 3, 3_000_000];
        let bytes = encode_ordered(&idx).unwrap();
        assert_eq!(decode_ordered(&bytes).unwrap(), idx);
        assert!(encode_ordered(&[]).is_ok());
        assert_eq!(decode_ordered(&encode_ordered(&[]).unwrap()).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn varint_boundaries() {
        let mut buf = Vec::new();
        for v in [0u32, 127, 128, 16383, 16384, u32::MAX] {
            buf.clear();
            write_varint(&mut buf, v);
            assert_eq!(read_varint(&buf).unwrap(), (v, buf.len()));
        }
    }
}
