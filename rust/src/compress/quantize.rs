//! Gradient quantizers for the quantization baselines (paper §II-B).
//!
//! * [`qsgd`]: QSGD [22] — per-bucket L2-norm scaling with `s` stochastic
//!   levels; payload = norm (f32) + sign+level per coordinate.
//! * [`ternary`]: TernGrad-style {-1, 0, +1} * scale quantization.
//!
//! Both return (packet, dequantized) so callers can byte-account the packet
//! and apply the dequantized gradient.

use crate::util::rng::Rng;

/// QSGD with `levels` quantization levels and `bucket` coordinates per
/// scaling group. Payload size: 4 bytes per bucket (norm) + ceil(bits)/8
/// per coordinate where bits = 1 (sign) + ceil(log2(levels+1)).
pub struct QsgdPacket {
    pub bytes: usize,
    pub dequant: Vec<f32>,
}

pub fn qsgd(g: &[f32], levels: u32, bucket: usize, rng: &mut Rng) -> QsgdPacket {
    assert!(levels >= 1 && bucket >= 1);
    let mut dequant = vec![0.0f32; g.len()];
    let bits_per_coord = 1 + (32 - (levels as u32).leading_zeros()) as usize;
    let mut bytes = 0usize;
    for (bi, chunk) in g.chunks(bucket).enumerate() {
        let norm = chunk.iter().map(|x| x * x).sum::<f32>().sqrt();
        bytes += 4; // the bucket norm
        if norm == 0.0 {
            continue;
        }
        for (i, &x) in chunk.iter().enumerate() {
            let r = x.abs() / norm * levels as f32;
            let low = r.floor();
            // Stochastic rounding: E[level] = r (unbiasedness, QSGD lemma 3.1)
            let level = if rng.uniform() < r - low { low + 1.0 } else { low };
            dequant[bi * bucket + i] = x.signum() * norm * level / levels as f32;
        }
        bytes += (chunk.len() * bits_per_coord).div_ceil(8);
    }
    QsgdPacket { bytes, dequant }
}

/// TernGrad-style ternarization: scale = max |g|, coords in {-1, 0, 1}
/// chosen stochastically so E[q] = g.  Payload: 4 + 2 bits/coord.
pub fn ternary(g: &[f32], rng: &mut Rng) -> QsgdPacket {
    let scale = g.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    let mut dequant = vec![0.0f32; g.len()];
    if scale > 0.0 {
        for (i, &x) in g.iter().enumerate() {
            let p = x.abs() / scale;
            if rng.uniform() < p {
                dequant[i] = x.signum() * scale;
            }
        }
    }
    QsgdPacket { bytes: 4 + (g.len() * 2).div_ceil(8), dequant }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qsgd_is_unbiased() {
        let mut rng = Rng::new(17);
        let g = vec![0.5f32, -0.25, 0.1, 0.0];
        let trials = 20_000;
        let mut mean = vec![0.0f64; g.len()];
        for _ in 0..trials {
            let p = qsgd(&g, 4, g.len(), &mut rng);
            for (m, d) in mean.iter_mut().zip(&p.dequant) {
                *m += *d as f64;
            }
        }
        for (m, x) in mean.iter().zip(&g) {
            assert!(
                (m / trials as f64 - *x as f64).abs() < 0.01,
                "E[q]={} vs {}", m / trials as f64, x
            );
        }
    }

    #[test]
    fn qsgd_zero_vector() {
        let mut rng = Rng::new(1);
        let p = qsgd(&[0.0; 64], 8, 32, &mut rng);
        assert!(p.dequant.iter().all(|&x| x == 0.0));
        assert_eq!(p.bytes, 8); // two bucket norms only
    }

    #[test]
    fn qsgd_packet_smaller_than_f32() {
        let mut rng = Rng::new(2);
        let g = rng.normal_vec(10_000, 1.0);
        let p = qsgd(&g, 15, 512, &mut rng);
        assert!(p.bytes < g.len() * 4 / 4, "bytes={}", p.bytes); // >4x smaller
    }

    #[test]
    fn ternary_levels() {
        let mut rng = Rng::new(3);
        let g = vec![1.0f32, -0.5, 0.0];
        let p = ternary(&g, &mut rng);
        for (d, _x) in p.dequant.iter().zip(&g) {
            assert!(*d == 0.0 || d.abs() == 1.0);
        }
        assert_eq!(p.dequant[2], 0.0);
    }

    #[test]
    fn ternary_unbiased() {
        let mut rng = Rng::new(4);
        let g = vec![0.3f32, -0.7];
        let trials = 30_000;
        let mut mean = [0.0f64; 2];
        for _ in 0..trials {
            let p = ternary(&g, &mut rng);
            mean[0] += p.dequant[0] as f64;
            mean[1] += p.dequant[1] as f64;
        }
        assert!((mean[0] / trials as f64 - 0.3).abs() < 0.02);
        assert!((mean[1] / trials as f64 + 0.7).abs() < 0.02);
    }
}
