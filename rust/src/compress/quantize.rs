//! Gradient quantizers for the quantization baselines (paper §II-B).
//!
//! * [`qsgd`]: QSGD [22] — per-bucket L2-norm scaling with `s` stochastic
//!   levels; payload = norm (f32) + sign+level per coordinate.
//! * [`ternary`]: TernGrad-style {-1, 0, +1} * scale quantization.
//!
//! Both return (packet, dequantized) so callers can byte-account the packet
//! and apply the dequantized gradient.

use crate::util::rng::Rng;

/// QSGD with `levels` quantization levels and `bucket` coordinates per
/// scaling group. Payload size: 4 bytes per bucket (norm) + ceil(bits)/8
/// per coordinate, where bits come from [`bits_per_coord`].
pub struct QsgdPacket {
    pub bytes: usize,
    pub dequant: Vec<f32>,
}

/// Fixed-width bits needed per transmitted coordinate.
///
/// A coordinate's quantized state is a signed level in
/// `{-levels, .., -1, 0, +1, .., +levels}` — `2*levels + 1` reachable
/// states (stochastic rounding reaches the extremes: `level = levels`
/// occurs when `|x| = norm`), so the exact fixed-width cost is
/// `ceil(log2(2*levels + 1))` bits.  `1 + bit_length(levels)` equals that
/// quantity for every `levels >= 1`, including powers of two:
/// `1 + floor(log2 s) + 1 = ceil(log2(2s + 1))` because `2s + 1` always
/// lands strictly between `2^(floor(log2 s)+1)` and `2^(floor(log2 s)+2)`.
/// (Audited against exact state enumeration in
/// `tests::bits_per_coord_matches_exact_enumeration`; an earlier review
/// suspected a +1 overcount at power-of-two `levels` — the enumeration
/// shows sign+magnitude fixed-width coding is already minimal there, e.g.
/// `levels = 2` has 5 states and genuinely needs 3 bits.)
pub fn bits_per_coord(levels: u32) -> usize {
    debug_assert!(levels >= 1);
    1 + (32 - levels.leading_zeros()) as usize
}

/// [`qsgd`] into a caller-owned dequant buffer (cleared and re-zeroed
/// first); returns the packet bytes.  The hot path borrows the buffer
/// from a per-node arena (DESIGN.md §6.11); draws from `rng` are
/// identical to [`qsgd`]'s, so both paths quantize bit-identically.
pub fn qsgd_into(
    g: &[f32],
    levels: u32,
    bucket: usize,
    rng: &mut Rng,
    dequant: &mut Vec<f32>,
) -> usize {
    assert!(levels >= 1 && bucket >= 1);
    dequant.clear();
    dequant.resize(g.len(), 0.0);
    let bits_per_coord = bits_per_coord(levels);
    let mut bytes = 0usize;
    for (bi, chunk) in g.chunks(bucket).enumerate() {
        let norm = chunk.iter().map(|x| x * x).sum::<f32>().sqrt();
        bytes += 4; // the bucket norm
        if norm == 0.0 {
            continue;
        }
        // Elementwise stage (stochastic round + dequant) is vectorized
        // with a bit-identical scalar twin; the norm reduction above is
        // order-sensitive and stays scalar (DESIGN.md §16.1).
        let out = &mut dequant[bi * bucket..][..chunk.len()];
        super::simd::qsgd_elems(chunk, norm, levels as f32, rng, out);
        bytes += (chunk.len() * bits_per_coord).div_ceil(8);
    }
    bytes
}

/// Allocating wrapper around [`qsgd_into`].
pub fn qsgd(g: &[f32], levels: u32, bucket: usize, rng: &mut Rng) -> QsgdPacket {
    let mut dequant = Vec::new();
    let bytes = qsgd_into(g, levels, bucket, rng, &mut dequant);
    QsgdPacket { bytes, dequant }
}

/// TernGrad-style ternarization: scale = max |g|, coords in {-1, 0, 1}
/// chosen stochastically so E[q] = g.  Payload: 4 + 2 bits/coord.
pub fn ternary(g: &[f32], rng: &mut Rng) -> QsgdPacket {
    let scale = g.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    let mut dequant = vec![0.0f32; g.len()];
    if scale > 0.0 {
        for (i, &x) in g.iter().enumerate() {
            let p = x.abs() / scale;
            if rng.uniform() < p {
                dequant[i] = x.signum() * scale;
            }
        }
    }
    QsgdPacket { bytes: 4 + (g.len() * 2).div_ceil(8), dequant }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qsgd_is_unbiased() {
        let mut rng = Rng::new(17);
        let g = vec![0.5f32, -0.25, 0.1, 0.0];
        let trials = 20_000;
        let mut mean = vec![0.0f64; g.len()];
        for _ in 0..trials {
            let p = qsgd(&g, 4, g.len(), &mut rng);
            for (m, d) in mean.iter_mut().zip(&p.dequant) {
                *m += *d as f64;
            }
        }
        for (m, x) in mean.iter().zip(&g) {
            assert!(
                (m / trials as f64 - *x as f64).abs() < 0.01,
                "E[q]={} vs {}", m / trials as f64, x
            );
        }
    }

    #[test]
    fn bits_per_coord_matches_exact_enumeration() {
        // (a) Analytically: bits_per_coord must equal
        //     ceil(log2(#reachable states)) with #states = 2*levels + 1.
        for levels in 1u32..=300 {
            let states = 2 * levels as u64 + 1;
            let exact = (64 - (states - 1).leading_zeros() as usize).max(1);
            assert_eq!(
                bits_per_coord(levels),
                exact,
                "levels={levels}: formula disagrees with exact enumeration \
                 ({} states)",
                states
            );
        }
        // Spot-check the cases a rate audit worries about (powers of two).
        assert_eq!(bits_per_coord(1), 2); // {-1, 0, +1}
        assert_eq!(bits_per_coord(2), 3); // 5 states: 3 bits ARE minimal
        assert_eq!(bits_per_coord(4), 4); // 9 states
        assert_eq!(bits_per_coord(8), 5); // 17 states
        assert_eq!(bits_per_coord(15), 5); // 31 states (the default config)

        // (b) Empirically: enumerate the states the quantizer actually
        //     emits for small `levels` and confirm the state count.
        let mut rng = Rng::new(0xA0D17);
        for levels in [1u32, 2, 3, 4] {
            let mut seen = std::collections::BTreeSet::new();
            for _ in 0..2000 {
                let g: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
                let p = qsgd(&g, levels, 8, &mut rng);
                let norm = g.iter().map(|x| x * x).sum::<f32>().sqrt();
                for d in p.dequant {
                    // Recover the signed level: d = sign * norm * l / levels.
                    let l = (d / norm * levels as f32).round() as i64;
                    seen.insert(l);
                }
            }
            // Extremes need |x| == norm, which Gaussian draws never hit;
            // drive them explicitly with a single-coordinate bucket.
            let p = qsgd(&[1.0], levels, 1, &mut rng);
            seen.insert((p.dequant[0] * levels as f32).round() as i64);
            let p = qsgd(&[-1.0], levels, 1, &mut rng);
            seen.insert((p.dequant[0] * levels as f32).round() as i64);
            assert!(seen.contains(&(levels as i64)));
            assert!(seen.contains(&-(levels as i64)));
            assert!(seen.contains(&0));
            let states = seen.len() as u64;
            assert!(
                states <= 2 * levels as u64 + 1,
                "levels={levels}: {states} states observed"
            );
            // The budget bits_per_coord pays for is exactly enough (and,
            // at the observed extremes, necessary) for these states.
            assert!(1u64 << bits_per_coord(levels) >= states);
        }
    }

    #[test]
    fn qsgd_zero_vector() {
        let mut rng = Rng::new(1);
        let p = qsgd(&[0.0; 64], 8, 32, &mut rng);
        assert!(p.dequant.iter().all(|&x| x == 0.0));
        assert_eq!(p.bytes, 8); // two bucket norms only
    }

    #[test]
    fn qsgd_packet_smaller_than_f32() {
        let mut rng = Rng::new(2);
        let g = rng.normal_vec(10_000, 1.0);
        let p = qsgd(&g, 15, 512, &mut rng);
        assert!(p.bytes < g.len() * 4 / 4, "bytes={}", p.bytes); // >4x smaller
    }

    #[test]
    fn ternary_levels() {
        let mut rng = Rng::new(3);
        let g = vec![1.0f32, -0.5, 0.0];
        let p = ternary(&g, &mut rng);
        for (d, _x) in p.dequant.iter().zip(&g) {
            assert!(*d == 0.0 || d.abs() == 1.0);
        }
        assert_eq!(p.dequant[2], 0.0);
    }

    #[test]
    fn ternary_unbiased() {
        let mut rng = Rng::new(4);
        let g = vec![0.3f32, -0.7];
        let trials = 30_000;
        let mut mean = [0.0f64; 2];
        for _ in 0..trials {
            let p = ternary(&g, &mut rng);
            mean[0] += p.dequant[0] as f64;
            mean[1] += p.dequant[1] as f64;
        }
        assert!((mean[0] / trials as f64 - 0.3).abs() < 0.02);
        assert!((mean[1] / trials as f64 + 0.7).abs() < 0.02);
    }
}
