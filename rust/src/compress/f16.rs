//! IEEE-754 binary16 conversion for half-precision value payloads.
//!
//! The paper transmits f32 values; several follow-ups halve the value
//! payload with f16. The framework exposes this as a rate option
//! (`TrainConfig::value_bytes` = 4 | 2); conversions here are exact
//! round-to-nearest-even, implemented locally (no `half` crate in the
//! offline set).

/// f32 -> f16 bit pattern (round-to-nearest-even, IEEE 754).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN
        let frac16 = if frac != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | frac16;
    }
    // Re-bias: f32 exp-127, f16 exp-15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal f16.
        let exp16 = (unbiased + 15) as u32;
        let mut mant = frac >> 13;
        // Round to nearest even on the truncated 13 bits.
        let rem = frac & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (mant & 1) == 1) {
            mant += 1;
        }
        let out = (exp16 << 10) + mant; // mantissa carry bumps exponent
        return sign | out as u16;
    }
    if unbiased >= -25 {
        // Subnormal f16: mantissa = RNE(|x| / 2^-24)
        //              = RNE((2^23 + frac) >> (-1 - unbiased)).
        let shift = (-1 - unbiased) as u32; // 14 ..= 24
        let mant32 = 0x0080_0000 | frac;
        let mut mant = mant32 >> shift;
        let rem = mant32 & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && (mant & 1) == 1) {
            mant += 1; // may carry into the smallest normal (0x0400): fine
        }
        return sign | mant as u16;
    }
    sign // underflow -> signed zero
}

/// f16 bit pattern -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let neg = h & 0x8000 != 0;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let mag = match (exp, frac) {
        (0, f) => f as f32 * 2.0f32.powi(-24), // zero / subnormal (exact in f32)
        (0x1f, 0) => f32::INFINITY,
        (0x1f, _) => f32::NAN,
        (e, f) => f32::from_bits(((e + 127 - 15) << 23) | (f << 13)),
    };
    if neg {
        -mag
    } else {
        mag
    }
}

/// Replace every element by its f16 wire round-trip, in place — the
/// vectorized bulk path (bit-identical scalar twin; DESIGN.md §16.1).
pub fn roundtrip_in_place(values: &mut [f32]) {
    super::simd::f16_roundtrip_in_place(values);
}

/// Round-trip a whole vector through f16 (the wire representation), and
/// report the payload size.
pub fn quantize_f16(values: &[f32]) -> (Vec<f32>, usize) {
    let mut deq = values.to_vec();
    roundtrip_in_place(&mut deq);
    (deq, values.len() * 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(x: f32) -> f32 {
        f16_bits_to_f32(f32_to_f16_bits(x))
    }

    #[test]
    fn exact_values_roundtrip() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0,
                  1.5, 0.25, 1024.0] {
            assert_eq!(roundtrip(x), x, "{x}");
        }
    }

    #[test]
    fn special_values() {
        assert_eq!(roundtrip(f32::INFINITY), f32::INFINITY);
        assert_eq!(roundtrip(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(roundtrip(f32::NAN).is_nan());
        assert_eq!(roundtrip(1e9), f32::INFINITY); // overflow
        assert_eq!(roundtrip(1e-10), 0.0); // underflow
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        let mut rng = crate::util::rng::Rng::new(20);
        for _ in 0..10_000 {
            let x = rng.normal() * 10.0;
            if x.abs() < 6.2e-5 {
                continue; // subnormal range has absolute, not relative bounds
            }
            let r = roundtrip(x);
            let rel = ((r - x) / x).abs();
            assert!(rel <= 1.0 / 1024.0, "x={x} r={r} rel={rel}");
        }
    }

    #[test]
    fn subnormals_roundtrip() {
        // Smallest positive f16 subnormal = 2^-24.
        let tiny = 2.0f32.powi(-24);
        assert_eq!(roundtrip(tiny), tiny);
        assert_eq!(roundtrip(2.0f32.powi(-14)), 2.0f32.powi(-14)); // smallest normal
        let sub = 3.0 * 2.0f32.powi(-24);
        assert_eq!(roundtrip(sub), sub);
    }

    #[test]
    fn quantize_vec_size() {
        let (deq, bytes) = quantize_f16(&[1.0, 2.0, 3.0]);
        assert_eq!(bytes, 6);
        assert_eq!(deq, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn monotone_on_samples() {
        // f16 quantization must preserve ordering of representable gaps.
        let mut prev = f16_bits_to_f32(0x0001);
        for bits in 2..0x7c00u16 {
            let v = f16_bits_to_f32(bits);
            assert!(v > prev, "bits={bits:#x} {v} !> {prev}");
            prev = v;
        }
    }
}
