//! The learned compressor: rust-side wrapper over the LGC autoencoder HLOs.
//!
//! Holds the autoencoder parameters host-side (He-init replayed from the
//! manifest shapes), and drives four AOT'd entry points:
//!
//!   encode      ae_enc_{mu}           g~ (1,mu)            -> latent
//!   decode RAR  ae_dec_rar_{mu}       latent               -> g_rec
//!   decode PS   ae_dec_ps_{mu}        latent + innovation  -> g_rec
//!   train       ae_train_{ps|rar}_{mu}_k{K}  (online, phase 2)
//!
//! Rates: a transmitted latent is `mu/4` f32s (4 channels x mu/16) plus a
//! 4-byte RMS scale — [`AeCompressor::latent_bytes`] is what the ledger
//! charges.
//!
//! Normalization: gradient value-vectors have tiny, drifting RMS (~1e-2
//! early, decaying over training); the autoencoder is trained and run on
//! unit-RMS inputs, with the scale transmitted alongside each payload and
//! re-applied after decoding.  This is standard practice in learned
//! compression and is what makes the few-hundred-step online training
//! regime of §V-B stable (DESIGN.md §6).

use anyhow::Result;

use crate::runtime::{Engine, Tensor};
use crate::util::rng::Rng;

/// Which §V-B communication pattern an autoencoder instance serves
/// (the two differ in decoder layout and training entry point).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    ParamServer,
    RingAllreduce,
}

/// The learned gradient compressor: host-side parameter store +
/// dispatcher for the per-(mu, K) AE modules (encode, pattern-specific
/// decode, online train step).
pub struct AeCompressor {
    pub mu: usize,
    pub k_nodes: usize,
    pub pattern: Pattern,
    enc_params: Vec<Tensor>,
    /// RAR: one decoder. PS: K stacked decoders (leading K axis per array).
    dec_params: Vec<Tensor>,
    enc_name: String,
    dec_name: String,
    train_name: String,
    latent_dims: Vec<usize>,
    /// Train-step losses observed so far (Fig. 14 traces).
    pub train_losses: Vec<(f32, f32)>,
}

/// RMS of a vector, clamped away from zero.
pub fn rms(v: &[f32]) -> f32 {
    let ms = v.iter().map(|x| x * x).sum::<f32>() / v.len().max(1) as f32;
    ms.sqrt().max(1e-8)
}

fn he_init_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    if shape.len() > 1 {
        let fan_in: usize = shape[1..].iter().product();
        let std = (2.0f32 / fan_in as f32).sqrt();
        Tensor::f32(shape.to_vec(), rng.normal_vec(n, std))
    } else {
        Tensor::zeros(shape.to_vec())
    }
}

impl AeCompressor {
    /// He-initialize a compressor for `mu`-length value-vectors and
    /// `k_nodes` nodes; fails cleanly when the manifest lacks the
    /// (mu, K) module family.
    pub fn new(
        engine: &Engine,
        mu: usize,
        k_nodes: usize,
        pattern: Pattern,
        seed: u64,
    ) -> Result<AeCompressor> {
        let ae = &engine.manifest.ae;
        let var = engine.manifest.ae_variant(mu);
        let mut rng = Rng::new(seed);
        let enc_params: Vec<Tensor> = ae
            .enc_shapes
            .iter()
            .map(|s| he_init_tensor(s, &mut rng))
            .collect();
        let (dec_params, dec_name, train_name) = match pattern {
            Pattern::RingAllreduce => {
                let dp = ae
                    .dec_shapes_rar
                    .iter()
                    .map(|s| he_init_tensor(s, &mut rng))
                    .collect();
                (
                    dp,
                    var.dec_rar.clone(),
                    var.train_rar
                        .get(&k_nodes)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "no RAR AE train variant for mu={mu}, K={k_nodes} \
                                 (supported K: {:?})",
                                var.train_rar.keys().collect::<Vec<_>>()
                            )
                        })?
                        .clone(),
                )
            }
            Pattern::ParamServer => {
                // K stacked decoders, each He-initialized independently.
                let dp = ae
                    .dec_shapes_ps
                    .iter()
                    .map(|s| {
                        let mut dims = vec![k_nodes];
                        dims.extend_from_slice(s);
                        let per: usize = s.iter().product();
                        let mut data = Vec::with_capacity(per * k_nodes);
                        for _ in 0..k_nodes {
                            data.extend(he_init_tensor(s, &mut rng).as_f32());
                        }
                        Tensor::f32(dims, data)
                    })
                    .collect();
                (
                    dp,
                    var.dec_ps.clone(),
                    var.train_ps
                        .get(&k_nodes)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "no PS AE train variant for mu={mu}, K={k_nodes} \
                                 (supported K: {:?})",
                                var.train_ps.keys().collect::<Vec<_>>()
                            )
                        })?
                        .clone(),
                )
            }
        };
        Ok(AeCompressor {
            mu,
            k_nodes,
            pattern,
            enc_params,
            dec_params,
            enc_name: var.enc.clone(),
            dec_name,
            train_name,
            latent_dims: vec![ae.latent_ch, mu / ae.down],
            train_losses: Vec::new(),
        })
    }

    /// Latent payload size on the wire (f32).
    pub fn latent_len(&self) -> usize {
        self.latent_dims.iter().product()
    }

    /// Wire bytes of one latent payload: latent f32s + the RMS scale.
    pub fn latent_bytes(&self) -> usize {
        self.latent_len() * 4 + 4
    }

    /// Total autoencoder parameter bytes (the one-time RAR weight
    /// broadcast, paper §V-B2).
    pub fn param_bytes(&self) -> usize {
        let e: usize = self.enc_params.iter().map(|t| t.len() * 4).sum();
        let d: usize = self.dec_params.iter().map(|t| t.len() * 4).sum();
        e + d
    }

    /// E_c(g~ / rms): compress a mu-length sparsified-gradient vector.
    /// Returns (latent, scale); the scale travels with the payload.
    pub fn encode(&self, engine: &Engine, g: &[f32]) -> Result<(Vec<f32>, f32)> {
        assert_eq!(g.len(), self.mu);
        let s = rms(g);
        let normed: Vec<f32> = g.iter().map(|x| x / s).collect();
        let mut inputs = self.enc_params.clone();
        inputs.push(Tensor::f32(vec![1, self.mu], normed));
        let out = engine.run(&self.enc_name, &inputs)?;
        Ok((out.into_iter().next().unwrap().as_f32().to_vec(), s))
    }

    /// RAR decode: D_c(latent_avg) * scale -> aggregated mu-length gradient.
    pub fn decode_rar(&self, engine: &Engine, latent: &[f32], scale: f32) -> Result<Vec<f32>> {
        assert_eq!(self.pattern, Pattern::RingAllreduce);
        let mut inputs = self.dec_params.clone();
        inputs.push(Tensor::f32(self.latent_dims.clone(), latent.to_vec()));
        let out = engine.run(&self.dec_name, &inputs)?;
        Ok(out.into_iter().next().unwrap().as_f32().iter().map(|x| x * scale).collect())
    }

    /// PS decode with node-k's decoder D_c^k and dense innovation vector
    /// (raw scale; normalized inside by the node's transmitted `scale`).
    pub fn decode_ps(
        &self,
        engine: &Engine,
        node: usize,
        latent: &[f32],
        innovation: &[f32],
        scale: f32,
    ) -> Result<Vec<f32>> {
        assert_eq!(self.pattern, Pattern::ParamServer);
        assert!(node < self.k_nodes);
        let mut inputs: Vec<Tensor> = self
            .dec_params
            .iter()
            .map(|stacked| {
                // Slice row `node` out of the K-leading stacked tensor.
                let per = stacked.len() / self.k_nodes;
                let dims = stacked.dims[1..].to_vec();
                Tensor::f32(dims, stacked.as_f32()[node * per..(node + 1) * per].to_vec())
            })
            .collect();
        inputs.push(Tensor::f32(self.latent_dims.clone(), latent.to_vec()));
        inputs.push(Tensor::f32(
            vec![1, self.mu],
            innovation.iter().map(|x| x / scale).collect(),
        ));
        let out = engine.run(&self.dec_name, &inputs)?;
        Ok(out.into_iter().next().unwrap().as_f32().iter().map(|x| x * scale).collect())
    }

    /// Serialize the encoder parameters as raw little-endian f32 bits,
    /// tensor by tensor in declaration order.  Workers only ever run
    /// `encode`, so shipping the encoder alone suffices — and raw bits
    /// keep the transferred copy bit-identical to the coordinator's
    /// (tests/tcp_e2e.rs depends on this).
    pub fn export_encoder(&self) -> Vec<u8> {
        let n: usize = self.enc_params.iter().map(|t| t.len() * 4).sum();
        let mut out = Vec::with_capacity(n);
        for t in &self.enc_params {
            for &x in t.as_f32() {
                out.extend_from_slice(&x.to_bits().to_le_bytes());
            }
        }
        out
    }

    /// Replace the encoder parameters from an [`AeCompressor::export_encoder`]
    /// payload; shapes stay local, only values cross the wire.
    pub fn import_encoder(&mut self, bytes: &[u8]) -> Result<()> {
        let want: usize = self.enc_params.iter().map(|t| t.len() * 4).sum();
        anyhow::ensure!(
            bytes.len() == want,
            "encoder payload is {} bytes, expected {want}",
            bytes.len()
        );
        let mut off = 0;
        for t in &mut self.enc_params {
            let dims = t.dims.clone();
            let vals: Vec<f32> = bytes[off..off + t.len() * 4]
                .chunks_exact(4)
                .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                .collect();
            off += t.len() * 4;
            *t = Tensor::f32(dims, vals);
        }
        Ok(())
    }

    /// Serialize the *full* compressor state — encoder + decoder
    /// parameters (raw LE f32 bits, declaration order, same discipline as
    /// [`AeCompressor::export_encoder`]) plus the loss trace — for
    /// crash-safe resume (DESIGN.md §14).
    pub fn export_state(&self) -> Vec<u8> {
        use crate::util::ser;
        let mut out = Vec::new();
        for group in [&self.enc_params, &self.dec_params] {
            ser::put_u32(&mut out, group.len() as u32);
            for t in group {
                let flat: &[f32] = t.as_f32();
                ser::put_f32s(&mut out, flat);
            }
        }
        ser::put_u64(&mut out, self.train_losses.len() as u64);
        for &(r, s) in &self.train_losses {
            ser::put_f32(&mut out, r);
            ser::put_f32(&mut out, s);
        }
        out
    }

    /// Restore from [`AeCompressor::export_state`] bytes; shapes stay
    /// local (He-init replay), only values are replaced.
    pub fn import_state(&mut self, r: &mut crate::util::ser::Reader) -> Result<()> {
        for group in [&mut self.enc_params, &mut self.dec_params] {
            let n = r.u32()? as usize;
            anyhow::ensure!(
                n == group.len(),
                "AE state blob has {n} tensors, expected {}",
                group.len()
            );
            for t in group.iter_mut() {
                let vals = r.f32s()?;
                anyhow::ensure!(
                    vals.len() == t.len(),
                    "AE tensor size mismatch: blob {} vs local {}",
                    vals.len(),
                    t.len()
                );
                *t = Tensor::f32(t.dims.clone(), vals);
            }
        }
        let n_losses = r.count(8)?;
        let mut losses = Vec::with_capacity(n_losses);
        for _ in 0..n_losses {
            losses.push((r.f32()?, r.f32()?));
        }
        self.train_losses = losses;
        Ok(())
    }

    /// One online SGD step on the autoencoder (phase 2), on unit-RMS
    /// normalized inputs (each row by its own scale; PS innovations by
    /// the matching row's scale, mirroring the inference path).
    ///
    /// RAR: `innovations` is ignored. PS: `ridx` picks the common node.
    /// Returns (rec_loss, sim_loss) — sim_loss is 0 for RAR.
    ///
    /// Rows are taken generically (`Vec<f32>`, `&[f32]`, ...) so the
    /// coordinator can pass value-vectors borrowed straight out of its
    /// per-node arenas without re-collecting them (DESIGN.md §6.11).
    pub fn train_step<R: AsRef<[f32]>>(
        &mut self,
        engine: &Engine,
        grads: &[R],
        innovations: Option<&[R]>,
        ridx: usize,
        lr: f32,
        lam1: f32,
        lam2: f32,
    ) -> Result<(f32, f32)> {
        assert_eq!(grads.len(), self.k_nodes);
        let scales: Vec<f32> = grads.iter().map(|g| rms(g.as_ref())).collect();
        let stack = |rows: &[R], scales: &[f32]| {
            let mut data = Vec::with_capacity(self.k_nodes * self.mu);
            for (r, &s) in rows.iter().zip(scales) {
                let r = r.as_ref();
                assert_eq!(r.len(), self.mu);
                data.extend(r.iter().map(|x| x / s));
            }
            Tensor::f32(vec![self.k_nodes, self.mu], data)
        };
        let mut inputs: Vec<Tensor> = self.enc_params.clone();
        inputs.extend(self.dec_params.clone());
        inputs.push(stack(grads, &scales));
        let (rec, sim) = match self.pattern {
            Pattern::RingAllreduce => {
                inputs.push(Tensor::scalar_f32(lr));
                let out = engine.run(&self.train_name, &inputs)?;
                let ne = self.enc_params.len();
                let nd = self.dec_params.len();
                self.enc_params = out[..ne].to_vec();
                self.dec_params = out[ne..ne + nd].to_vec();
                (out[ne + nd].scalar(), 0.0)
            }
            Pattern::ParamServer => {
                inputs.push(stack(
                    innovations.expect("PS training needs innovations"),
                    &scales,
                ));
                inputs.push(Tensor::scalar_i32(ridx as i32));
                inputs.push(Tensor::scalar_f32(lr));
                inputs.push(Tensor::scalar_f32(lam1));
                inputs.push(Tensor::scalar_f32(lam2));
                let out = engine.run(&self.train_name, &inputs)?;
                let ne = self.enc_params.len();
                let nd = self.dec_params.len();
                self.enc_params = out[..ne].to_vec();
                self.dec_params = out[ne..ne + nd].to_vec();
                (out[ne + nd].scalar(), out[ne + nd + 1].scalar())
            }
        };
        self.train_losses.push((rec, sim));
        Ok((rec, sim))
    }
}
