//! Gradient compression substrates (paper §IV-V).
//!
//! * [`topk`]         — exact top-k magnitude selection (Algorithm 1)
//! * [`feedback`]     — error-feedback memory w/ momentum correction
//! * [`index_coding`] — DEFLATE index entropy coding (§V-A)
//! * [`scratch`]      — per-worker arenas for the zero-allocation hot
//!   path (DESIGN.md §6.11)
//! * [`quantize`]     — QSGD / ternary baselines (§II-B)
//! * [`autoencoder`]  — the learned compressor: wraps the AOT'd LGC
//!   autoencoder HLOs (encode / decode / online train)
//! * [`simd`]         — runtime-dispatched AVX2 kernels with bit-identical
//!   scalar twins for the encode hot path (DESIGN.md §16)

pub mod autoencoder;
pub mod f16;
pub mod feedback;
pub mod index_coding;
pub mod quantize;
pub mod scratch;
pub mod simd;
pub mod topk;

pub use autoencoder::AeCompressor;
pub use feedback::{Correction, FeedbackMemory};
pub use scratch::Scratch;
pub use topk::TopK;
