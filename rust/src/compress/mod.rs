//! Gradient compression substrates (paper §IV-V).
//!
//! * [`topk`]         — exact top-k magnitude selection (Algorithm 1)
//! * [`feedback`]     — error-feedback memory w/ momentum correction
//! * [`index_coding`] — DEFLATE index entropy coding (§V-A)
//! * [`quantize`]     — QSGD / ternary baselines (§II-B)
//! * [`autoencoder`]  — the learned compressor: wraps the AOT'd LGC
//!   autoencoder HLOs (encode / decode / online train)

pub mod autoencoder;
pub mod f16;
pub mod feedback;
pub mod index_coding;
pub mod quantize;
pub mod topk;

pub use autoencoder::AeCompressor;
pub use feedback::{Correction, FeedbackMemory};
pub use topk::TopK;
