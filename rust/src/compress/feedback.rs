//! Error-feedback memory with momentum correction (paper §V-A / DGC [20]).
//!
//! Each node keeps, per parameter group, the residual of everything it did
//! not transmit.  Two variants (Table III):
//!
//! * plain accumulation (Sparse GD [19]):        acc += g; send top-k(acc)
//! * momentum correction (DGC [20] / LGC):       u = m*u + g; v += u;
//!                                               send top-k(v)
//!
//! Both subtract the transmitted coordinates from the memory after
//! selection, which is exactly Algorithm 1's `g_acc <- g_acc + (!mask) * g`
//! formulation rearranged.

use super::scratch::Scratch;
use super::topk::{self, TopK};

/// How the memory folds fresh gradients in (Table III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Correction {
    /// acc += g (Sparse GD)
    Plain,
    /// Momentum-corrected accumulation (DGC §3.2, LGC §V-A)
    Momentum,
}

/// One node's error-feedback memory: the accumulated residual of
/// everything selection has not yet transmitted.
#[derive(Debug, Clone)]
pub struct FeedbackMemory {
    correction: Correction,
    momentum: f32,
    /// Momentum buffer u (only used under `Correction::Momentum`).
    u: Vec<f32>,
    /// Accumulated (velocity) buffer v — the memory that feeds selection.
    v: Vec<f32>,
}

impl FeedbackMemory {
    /// Zeroed memory over `n` coordinates.
    pub fn new(n: usize, correction: Correction, momentum: f32) -> Self {
        FeedbackMemory { correction, momentum, u: vec![0.0; n], v: vec![0.0; n] }
    }

    /// Number of coordinates the memory tracks.
    pub fn len(&self) -> usize {
        self.v.len()
    }

    /// Whether the memory tracks zero coordinates (empty group).
    pub fn is_empty(&self) -> bool {
        self.v.is_empty()
    }

    /// Fold a fresh gradient into the memory; the result (`self.v`) is the
    /// vector selection should run on.
    pub fn accumulate(&mut self, grad: &[f32]) {
        assert_eq!(grad.len(), self.v.len());
        match self.correction {
            Correction::Plain => {
                for (v, g) in self.v.iter_mut().zip(grad) {
                    *v += g;
                }
            }
            Correction::Momentum => {
                for ((u, v), g) in self.u.iter_mut().zip(&mut self.v).zip(grad) {
                    *u = self.momentum * *u + g;
                    *v += *u;
                }
            }
        }
    }

    /// Current memory state (selection input).
    pub fn memory(&self) -> &[f32] {
        &self.v
    }

    /// Select top-k of the memory, clear the transmitted coordinates
    /// (and their momentum, per DGC's momentum masking), return the packet.
    pub fn select_and_clear(&mut self, k: usize) -> TopK {
        let sel = topk::top_k(&self.v, k);
        self.clear_at(&sel.indices);
        sel
    }

    /// [`FeedbackMemory::select_and_clear`] into the arena's selection
    /// buffers (`sc.idx` / `sc.vals`), allocation-free in steady state.
    pub fn select_and_clear_into(&mut self, k: usize, sc: &mut Scratch) {
        topk::top_k_into(&self.v, k, &mut sc.mags, &mut sc.idx, &mut sc.vals);
        self.clear_at(&sc.idx);
    }

    /// [`FeedbackMemory::select_and_clear_into`] over a bucket partition
    /// (DESIGN.md §13.2): same global threshold, same selection, same
    /// memory clears — bit-identical to the monolithic path — but
    /// additionally fills `sc.splits` with per-bucket offsets so each
    /// bucket's packet can be encoded (and shipped) independently.
    pub fn select_and_clear_bucketed_into(
        &mut self,
        k: usize,
        ranges: &[std::ops::Range<usize>],
        sc: &mut Scratch,
    ) {
        topk::top_k_bucketed_into(
            &self.v,
            k,
            ranges,
            &mut sc.mags,
            &mut sc.idx,
            &mut sc.vals,
            &mut sc.splits,
        );
        self.clear_at(&sc.idx);
    }

    /// Clear given coordinates after transmitting them (CLT-k path: the
    /// index set came from the leader, not from our own top-k).
    pub fn take_at(&mut self, indices: &[u32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.take_at_into(indices, &mut out);
        out
    }

    /// [`FeedbackMemory::take_at`] into a caller-owned buffer (cleared
    /// first).
    pub fn take_at_into(&mut self, indices: &[u32], out: &mut Vec<f32>) {
        topk::gather_into(&self.v, indices, out);
        self.clear_at(indices);
    }

    fn clear_at(&mut self, indices: &[u32]) {
        for &i in indices {
            self.v[i as usize] = 0.0;
            if self.correction == Correction::Momentum {
                self.u[i as usize] = 0.0;
            }
        }
    }

    /// Scatter-add a correction back into the memory (error feedback on a
    /// *biased, shared* compressor output: after an aggregate update
    /// `rec` replaced the ideal per-node contribution `vals_k`, each node
    /// re-accumulates e_k = vals_k - rec at the transmitted coordinates;
    /// mean_k(e_k) = ideal - applied, so the averaged update recovers the
    /// compressor error on later iterations — Stich et al. [40] extended
    /// to the shared-reconstruction setting, DESIGN.md §6.10).
    pub fn add_at(&mut self, indices: &[u32], deltas: &[f32]) {
        for (&i, &d) in indices.iter().zip(deltas) {
            self.v[i as usize] += d;
        }
    }

    /// L2 norm of the residual (used by tests / diagnostics).
    pub fn residual_norm(&self) -> f32 {
        self.v.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Snapshot the memory (u, v) for resume/rejoin (DESIGN.md §14).  The
    /// correction mode and momentum coefficient are config-derived and
    /// therefore not part of the blob.
    pub fn write_state(&self, out: &mut Vec<u8>) {
        crate::util::ser::put_f32s(out, &self.u);
        crate::util::ser::put_f32s(out, &self.v);
    }

    /// Restore (u, v) from [`FeedbackMemory::write_state`] bytes into a
    /// memory already sized for its group.
    pub fn read_state(&mut self, r: &mut crate::util::ser::Reader) -> anyhow::Result<()> {
        let u = r.f32s()?;
        let v = r.f32s()?;
        anyhow::ensure!(
            u.len() == self.u.len() && v.len() == self.v.len(),
            "EF state size mismatch: blob ({}, {}) vs memory ({}, {})",
            u.len(),
            v.len(),
            self.u.len(),
            self.v.len()
        );
        self.u = u;
        self.v = v;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_accumulates_and_clears() {
        let mut fb = FeedbackMemory::new(4, Correction::Plain, 0.0);
        fb.accumulate(&[1.0, -3.0, 0.5, 0.0]);
        let sel = fb.select_and_clear(1);
        assert_eq!(sel.indices, vec![1]);
        assert_eq!(sel.values, vec![-3.0]);
        // Untransmitted residual remains.
        assert_eq!(fb.memory(), &[1.0, 0.0, 0.5, 0.0]);
        fb.accumulate(&[0.0; 4]);
        let sel2 = fb.select_and_clear(1);
        assert_eq!(sel2.indices, vec![0]); // residual eventually drains
    }

    #[test]
    fn momentum_correction_amplifies_repeated_signal() {
        let mut fb = FeedbackMemory::new(2, Correction::Momentum, 0.9);
        for _ in 0..5 {
            fb.accumulate(&[1.0, 0.0]);
        }
        // With momentum, v[0] > 5 (sum of partial geometric series).
        assert!(fb.memory()[0] > 5.0);
        assert_eq!(fb.memory()[1], 0.0);
    }

    #[test]
    fn momentum_cleared_on_transmit() {
        let mut fb = FeedbackMemory::new(2, Correction::Momentum, 0.9);
        fb.accumulate(&[1.0, 0.1]);
        fb.select_and_clear(1);
        fb.accumulate(&[0.0, 0.0]);
        // u[0] was masked out: no phantom momentum re-appears.
        assert_eq!(fb.memory()[0], 0.0);
    }

    #[test]
    fn take_at_uses_external_indices() {
        let mut fb = FeedbackMemory::new(3, Correction::Plain, 0.0);
        fb.accumulate(&[1.0, 2.0, 3.0]);
        let vals = fb.take_at(&[0, 2]);
        assert_eq!(vals, vec![1.0, 3.0]);
        assert_eq!(fb.memory(), &[0.0, 2.0, 0.0]);
    }

    #[test]
    fn scratch_select_matches_allocating_select() {
        let mut rng = crate::util::rng::Rng::new(17);
        let g = rng.normal_vec(500, 1.0);
        let mut a = FeedbackMemory::new(500, Correction::Momentum, 0.9);
        let mut b = a.clone();
        let mut sc = Scratch::new();
        for k in [1usize, 7, 50] {
            a.accumulate(&g);
            b.accumulate(&g);
            let sel = a.select_and_clear(k);
            b.select_and_clear_into(k, &mut sc);
            assert_eq!(sel.indices, sc.idx);
            assert_eq!(sel.values, sc.vals);
            assert_eq!(a.memory(), b.memory());
        }
    }

    #[test]
    fn bucketed_select_matches_monolithic_select() {
        let mut rng = crate::util::rng::Rng::new(29);
        let n = 700;
        let ranges = vec![0..100, 100..101, 101..450, 450..700];
        let mut a = FeedbackMemory::new(n, Correction::Momentum, 0.9);
        let mut b = a.clone();
        let (mut sa, mut sb) = (Scratch::new(), Scratch::new());
        for k in [1usize, 13, 200] {
            let g = rng.normal_vec(n, 1.0);
            a.accumulate(&g);
            b.accumulate(&g);
            a.select_and_clear_into(k, &mut sa);
            b.select_and_clear_bucketed_into(k, &ranges, &mut sb);
            assert_eq!(sa.idx, sb.idx);
            assert_eq!(sa.vals, sb.vals);
            assert_eq!(a.memory(), b.memory());
            assert_eq!(sb.splits.len(), ranges.len() + 1);
            assert_eq!(*sb.splits.last().unwrap(), sb.idx.len());
        }
    }

    #[test]
    fn state_roundtrip_is_exact_and_size_checked() {
        let mut rng = crate::util::rng::Rng::new(41);
        let mut a = FeedbackMemory::new(64, Correction::Momentum, 0.9);
        a.accumulate(&rng.normal_vec(64, 1.0));
        a.select_and_clear(5);
        a.accumulate(&rng.normal_vec(64, 1.0));
        let mut blob = Vec::new();
        a.write_state(&mut blob);
        let mut b = FeedbackMemory::new(64, Correction::Momentum, 0.9);
        let mut r = crate::util::ser::Reader::new(&blob);
        b.read_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(a.memory(), b.memory());
        assert_eq!(a.u, b.u);
        // A blob for the wrong group size is rejected.
        let mut c = FeedbackMemory::new(63, Correction::Momentum, 0.9);
        let mut r = crate::util::ser::Reader::new(&blob);
        assert!(c.read_state(&mut r).is_err());
    }

    #[test]
    fn nothing_lost_split_invariant() {
        // transmitted + residual == accumulated input (plain EF)
        let mut rng = crate::util::rng::Rng::new(3);
        let g = rng.normal_vec(100, 1.0);
        let mut fb = FeedbackMemory::new(100, Correction::Plain, 0.0);
        fb.accumulate(&g);
        let sel = fb.select_and_clear(10);
        let mut recon = fb.memory().to_vec();
        super::topk::scatter_add(&mut recon, &sel.indices, &sel.values);
        for (a, b) in recon.iter().zip(&g) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
