//! Per-worker scratch arenas for the compression hot path (DESIGN.md
//! §6.11).
//!
//! Every per-node, per-iteration stage — magnitude selection, gather at
//! the shared support, innovation scatter, varint/DEFLATE index coding —
//! needs working buffers sized by the gradient group.  Allocating them
//! per call is the dominant steady-state allocator traffic, so each
//! simulated node owns one [`Scratch`] (created once, next to its ledger
//! shard) and every stage borrows from it.  After the first iteration the
//! buffers sit at the workload's high-water mark and the steady state
//! allocates nothing.
//!
//! Determinism (§6.5): arenas hold no state that outlives a call — every
//! user clears or overwrites before reading — and each node always uses
//! its own arena, so they are a wall-clock knob, never a semantics knob.
//! The proptests pin this down by comparing scratch-path outputs against
//! the allocating reference paths bit-for-bit.

/// Reusable buffers for one worker/node.
///
/// The selection fields (`idx`, `vals`) double as the *output* of a
/// node-local stage: the barrier that follows reads them directly (e.g.
/// scatter-mean over all nodes), which is what removes the per-packet
/// allocations of the old pipeline.
pub struct Scratch {
    /// |g| magnitude buffer for threshold selection (gradient-group size).
    pub mags: Vec<f32>,
    /// Selected indices of the last selection stage (ascending).
    pub idx: Vec<u32>,
    /// Values at `idx` (same order), or the last gathered value-vector.
    pub vals: Vec<f32>,
    /// Cumulative per-bucket offsets into `idx`/`vals` after a bucketed
    /// selection (`plan.len() + 1` entries, leading 0): bucket `b` owns
    /// `idx[splits[b]..splits[b + 1]]`.  See DESIGN.md §13.
    pub splits: Vec<usize>,
    /// Bucket-local index staging for per-bucket index coding (global
    /// index minus the bucket range's start).
    pub idx_local: Vec<u32>,
    /// Index-codec state: varint staging, payload output, DEFLATE state.
    pub enc: EncScratch,
}

/// Encoder-side buffers of [`crate::compress::index_coding`]: the staged
/// varint bytes, the final wire payload, and the vendored-`flate2`
/// compressor state (hash chains, token buffer, code-gen tables).
pub struct EncScratch {
    pub(crate) varints: Vec<u8>,
    pub(crate) payload: Vec<u8>,
    /// Golomb candidate staging for the `golomb`/`auto` strategies; kept
    /// apart from `payload` so the auto-picker can price both candidates
    /// before committing (DESIGN.md §16.2).
    pub(crate) golomb: Vec<u8>,
    pub(crate) deflate: flate2::DeflateScratch,
}

impl EncScratch {
    /// Empty codec state; buffers grow to the workload's high-water mark.
    pub fn new() -> EncScratch {
        EncScratch {
            varints: Vec::new(),
            payload: Vec::new(),
            golomb: Vec::new(),
            deflate: flate2::DeflateScratch::new(),
        }
    }
}

impl Default for EncScratch {
    fn default() -> EncScratch {
        EncScratch::new()
    }
}

impl Scratch {
    /// Empty arena; buffers grow on first use and are then reused.
    pub fn new() -> Scratch {
        Scratch {
            mags: Vec::new(),
            idx: Vec::new(),
            vals: Vec::new(),
            splits: Vec::new(),
            idx_local: Vec::new(),
            enc: EncScratch::new(),
        }
    }

    /// One arena per simulated node (mirrors `NodeLedger::for_nodes`).
    pub fn for_nodes(nodes: usize) -> Vec<Scratch> {
        (0..nodes).map(|_| Scratch::new()).collect()
    }
}

impl Default for Scratch {
    fn default() -> Scratch {
        Scratch::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{index_coding, topk};

    #[test]
    fn arenas_are_pure_scratch() {
        // Using one arena across unrelated payloads must give the same
        // results as fresh arenas: no state leaks between calls.
        let mut rng = crate::util::rng::Rng::new(9);
        let mut sc = Scratch::new();
        for _ in 0..20 {
            let n = 64 + rng.below(4000);
            let g = rng.normal_vec(n, 1.0);
            let k = 1 + rng.below(n / 2 + 1);
            let want = topk::top_k(&g, k);
            topk::top_k_into(&g, k, &mut sc.mags, &mut sc.idx, &mut sc.vals);
            assert_eq!(sc.idx, want.indices);
            assert_eq!(sc.vals, want.values);
            let want_bytes = index_coding::encode(&sc.idx, n).unwrap();
            let got = index_coding::encode_into(&sc.idx, n, &mut sc.enc).unwrap();
            assert_eq!(got, &want_bytes[..]);
        }
    }

    #[test]
    fn for_nodes_builds_one_arena_each() {
        assert_eq!(Scratch::for_nodes(5).len(), 5);
        assert!(Scratch::for_nodes(0).is_empty());
    }
}
