//! Exact top-k magnitude selection (paper §V-A, Algorithm 1).
//!
//! `threshold = min(top alpha% of |g|)`, realised with an O(n) partial
//! selection (`select_nth_unstable`) on a scratch copy of magnitudes —
//! this is the L3 hot-path version; the fused Pallas `sparsify` kernel
//! consumes the threshold it produces (see python/compile/kernels/).

/// Result of a top-k selection over a dense vector.
#[derive(Debug, Clone, Default)]
pub struct TopK {
    /// Ascending indices of the selected entries.
    pub indices: Vec<u32>,
    /// Values at those indices (same order).
    pub values: Vec<f32>,
    /// The magnitude threshold actually used.
    pub threshold: f32,
}

/// Number of elements a sparsity fraction keeps: at least 1 for non-empty
/// inputs, and 0 for empty ones.  (A model whose mid or last group is
/// empty — e.g. a bias-free single-layer head — must yield an empty
/// selection, not a `clamp(1, 0)` panic.)
pub fn k_of(n: usize, fraction: f64) -> usize {
    if n == 0 {
        return 0;
    }
    ((n as f64 * fraction).ceil() as usize).clamp(1, n)
}

/// Magnitude threshold that keeps ~k elements of `g` (O(n)), staging
/// magnitudes in the caller's reusable buffer (DESIGN.md §6.11: the
/// n-sized magnitude copy is the selection stage's only large
/// allocation, so the hot path borrows it from a per-node arena).
pub fn threshold_for_k_in(g: &[f32], k: usize, mags: &mut Vec<f32>) -> f32 {
    if k == 0 || g.is_empty() {
        return f32::INFINITY;
    }
    let k = k.min(g.len());
    mags.clear();
    mags.extend(g.iter().map(|x| x.abs()));
    let idx = g.len() - k;
    let (_, thr, _) = mags.select_nth_unstable_by(idx, f32::total_cmp);
    *thr
}

/// Magnitude threshold that keeps ~k elements of `g` (O(n)).
/// Degenerate selections (`k == 0` or an empty `g`) yield `f32::INFINITY`
/// so that no coordinate passes the threshold.
pub fn threshold_for_k(g: &[f32], k: usize) -> f32 {
    threshold_for_k_in(g, k, &mut Vec::new())
}

/// [`top_k`] into caller-owned buffers (cleared first); returns the
/// threshold.  Selection semantics are identical to [`top_k`] — the
/// proptests compare the two paths bit-for-bit.
pub fn top_k_into(
    g: &[f32],
    k: usize,
    mags: &mut Vec<f32>,
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
) -> f32 {
    indices.clear();
    values.clear();
    if k == 0 || g.is_empty() {
        return f32::INFINITY;
    }
    let k = k.min(g.len());
    let threshold = threshold_for_k_in(g, k, mags);
    // Strict pass: vectorized, bit-identical to the scalar scan
    // (DESIGN.md §16.1).  The tie pass below terminates early and stays
    // scalar.
    super::simd::scan_above(g, 0, threshold, indices);
    // Fill the remainder with threshold-magnitude ties (index order).
    if indices.len() < k {
        for (i, &v) in g.iter().enumerate() {
            if v.abs() == threshold {
                indices.push(i as u32);
                if indices.len() == k {
                    break;
                }
            }
        }
    }
    indices.sort_unstable();
    indices.truncate(k);
    values.extend(indices.iter().map(|&i| g[i as usize]));
    threshold
}

/// [`top_k_into`] over an explicit bucket partition of `g` (DESIGN.md
/// §13.2): the *global* threshold is computed first (node-local, O(n)),
/// then each contiguous range is scanned independently in ascending
/// order.  For any ascending, contiguous partition of `0..g.len()` the
/// selected index set, its order, and the gathered values are
/// **bit-identical** to the monolithic [`top_k_into`] — the strict pass
/// visits indices in exactly `0..n` order either way, fewer than `k`
/// coordinates can be strictly above the k-th magnitude, and the shared
/// tie budget fills in the same ascending order.  This is what makes the
/// bucketed pipeline's `--no-overlap` mode reproduce the legacy path
/// exactly.
///
/// Additionally fills `splits` with cumulative per-bucket offsets
/// (`ranges.len() + 1` entries, leading 0): bucket `b`'s selection is
/// `indices[splits[b]..splits[b + 1]]`.
pub fn top_k_bucketed_into(
    g: &[f32],
    k: usize,
    ranges: &[std::ops::Range<usize>],
    mags: &mut Vec<f32>,
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
    splits: &mut Vec<usize>,
) -> f32 {
    indices.clear();
    values.clear();
    splits.clear();
    if k == 0 || g.is_empty() {
        splits.resize(ranges.len() + 1, 0);
        return f32::INFINITY;
    }
    let k = k.min(g.len());
    let threshold = threshold_for_k_in(g, k, mags);
    for r in ranges {
        super::simd::scan_above(&g[r.clone()], r.start as u32, threshold, indices);
    }
    // Shared tie budget, filled across buckets in ascending index order —
    // exactly the monolithic tie pass restricted to the same walk.
    if indices.len() < k {
        'fill: for r in ranges {
            for i in r.clone() {
                if g[i].abs() == threshold {
                    indices.push(i as u32);
                    if indices.len() == k {
                        break 'fill;
                    }
                }
            }
        }
    }
    indices.sort_unstable();
    indices.truncate(k);
    values.extend(indices.iter().map(|&i| g[i as usize]));
    splits.push(0);
    let mut pos = 0usize;
    for r in ranges {
        while pos < indices.len() && (indices[pos] as usize) < r.end {
            pos += 1;
        }
        splits.push(pos);
    }
    threshold
}

/// Select the k largest-magnitude entries. Ties at the threshold are
/// resolved by index order, and the result is always *exactly*
/// `min(k, g.len())` entries (the paper's rate accounting assumes a fixed
/// payload size); degenerate inputs return an empty selection.
///
/// ```
/// use lgc::compress::topk::top_k;
/// let t = top_k(&[0.1, -5.0, 0.2, 3.0, -0.3], 2);
/// assert_eq!(t.indices, vec![1, 3]); // ascending indices...
/// assert_eq!(t.values, vec![-5.0, 3.0]); // ...values in index order
/// assert!(t.threshold >= 0.3 && t.threshold <= 3.0);
/// ```
pub fn top_k(g: &[f32], k: usize) -> TopK {
    let mut indices = Vec::new();
    let mut values = Vec::new();
    let threshold = top_k_into(g, k, &mut Vec::new(), &mut indices, &mut values);
    TopK { indices, values, threshold }
}

/// Gather values of `g` at `indices` into a caller-owned buffer
/// (cleared first).
pub fn gather_into(g: &[f32], indices: &[u32], out: &mut Vec<f32>) {
    out.clear();
    out.extend(indices.iter().map(|&i| g[i as usize]));
}

/// Gather values of `g` at `indices` (ScaleCom's CLT-k: follow the leader's
/// index set).
pub fn gather(g: &[f32], indices: &[u32]) -> Vec<f32> {
    let mut out = Vec::new();
    gather_into(g, indices, &mut out);
    out
}

/// Scatter (indices, values) into a caller-owned dense buffer, resized to
/// `n` and zeroed first.
pub fn scatter_into(out: &mut Vec<f32>, n: usize, indices: &[u32], values: &[f32]) {
    out.clear();
    out.resize(n, 0.0);
    for (&i, &v) in indices.iter().zip(values) {
        out[i as usize] = v;
    }
}

/// Scatter (indices, values) into a dense zero vector of length n.
pub fn scatter(n: usize, indices: &[u32], values: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    scatter_into(&mut out, n, indices, values);
    out
}

/// Scatter-add into an existing dense vector.
pub fn scatter_add(dst: &mut [f32], indices: &[u32], values: &[f32]) {
    for (&i, &v) in indices.iter().zip(values) {
        dst[i as usize] += v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_exactly_k_largest() {
        let g = vec![0.1, -5.0, 0.2, 3.0, -0.3, 0.05];
        let t = top_k(&g, 2);
        assert_eq!(t.indices, vec![1, 3]);
        assert_eq!(t.values, vec![-5.0, 3.0]);
    }

    #[test]
    fn handles_ties_deterministically() {
        let g = vec![1.0; 10];
        let t = top_k(&g, 3);
        assert_eq!(t.indices, vec![0, 1, 2]);
        assert_eq!(t.values.len(), 3);
    }

    #[test]
    fn k_of_clamps() {
        assert_eq!(k_of(1000, 0.001), 1);
        assert_eq!(k_of(1_000_000, 0.001), 1000);
        assert_eq!(k_of(5, 1e-9), 1);
        assert_eq!(k_of(5, 2.0), 5);
    }

    #[test]
    fn threshold_matches_sorted_definition() {
        let mut rng = crate::util::rng::Rng::new(1);
        let g = rng.normal_vec(997, 1.0);
        let k = 50;
        let thr = threshold_for_k(&g, k);
        let mut mags: Vec<f32> = g.iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert_eq!(thr, mags[k - 1]);
    }

    #[test]
    fn scatter_roundtrip() {
        let g = vec![0.0, 2.0, 0.0, -1.0];
        let t = top_k(&g, 2);
        assert_eq!(scatter(4, &t.indices, &t.values), g);
    }

    #[test]
    fn gather_follows_leader_indices() {
        let g = vec![10., 20., 30., 40.];
        assert_eq!(gather(&g, &[3, 0]), vec![40., 10.]);
    }

    #[test]
    fn top_k_full_vector() {
        let g = vec![1.0, -2.0];
        let t = top_k(&g, 2);
        assert_eq!(t.indices, vec![0, 1]);
    }

    /// Random ragged partitions of `0..n`, ascending and contiguous.
    fn random_partition(
        rng: &mut crate::util::rng::Rng,
        n: usize,
        buckets: usize,
    ) -> Vec<std::ops::Range<usize>> {
        let b = buckets.min(n).max(1);
        let mut cuts = vec![0usize, n];
        while cuts.len() < b + 1 {
            let c = 1 + rng.below(n - 1);
            if !cuts.contains(&c) {
                cuts.push(c);
            }
        }
        cuts.sort_unstable();
        cuts.windows(2).map(|w| w[0]..w[1]).collect()
    }

    #[test]
    fn bucketed_selection_is_bit_identical_to_monolithic() {
        let mut rng = crate::util::rng::Rng::new(23);
        let mut mags = Vec::new();
        let (mut idx, mut vals) = (Vec::new(), Vec::new());
        let (mut bidx, mut bvals, mut splits) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..40 {
            let n = 64 + rng.below(2000);
            let mut g = rng.normal_vec(n, 1.0);
            // Force magnitude ties so the shared tie budget is exercised.
            for _ in 0..10 {
                let (a, b) = (rng.below(n), rng.below(n));
                g[a] = g[b].abs();
            }
            let k = 1 + rng.below(n / 2 + 1);
            let nb = 1 + rng.below(32);
            let ranges = random_partition(&mut rng, n, nb);
            let thr = top_k_into(&g, k, &mut mags, &mut idx, &mut vals);
            let bthr =
                top_k_bucketed_into(&g, k, &ranges, &mut mags, &mut bidx, &mut bvals, &mut splits);
            assert_eq!(thr.to_bits(), bthr.to_bits());
            assert_eq!(idx, bidx);
            assert_eq!(vals, bvals);
            // splits tile the selection and respect bucket bounds.
            assert_eq!(splits.len(), ranges.len() + 1);
            assert_eq!(*splits.last().unwrap(), bidx.len());
            for (b, r) in ranges.iter().enumerate() {
                for &i in &bidx[splits[b]..splits[b + 1]] {
                    assert!(r.contains(&(i as usize)));
                }
            }
        }
    }

    #[test]
    fn bucketed_selection_degenerate_inputs() {
        let (mut mags, mut idx, mut vals, mut splits) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        let thr = top_k_bucketed_into(&[], 3, &[0..0], &mut mags, &mut idx, &mut vals, &mut splits);
        assert_eq!(thr, f32::INFINITY);
        assert!(idx.is_empty());
        assert_eq!(splits, vec![0, 0]);
        let thr = top_k_bucketed_into(
            &[1.0, -2.0],
            0,
            &[0..1, 1..2],
            &mut mags,
            &mut idx,
            &mut vals,
            &mut splits,
        );
        assert_eq!(thr, f32::INFINITY);
        assert_eq!(splits, vec![0, 0, 0]);
    }
}

#[cfg(test)]
mod debug_tests {
    use super::*;

    #[test]
    fn top_k_on_zero_memory() {
        let g = vec![0.0f32; 100];
        let t = top_k(&g, 5);
        assert_eq!(t.indices.len(), 5, "{t:?}");
    }

    #[test]
    fn empty_gradient_group_regression() {
        // k_of(0, f) used to panic (`.clamp(1, 0)` has min > max); an
        // empty parameter group must flow through the whole selection
        // pipeline as an empty — not panicking — selection.
        assert_eq!(k_of(0, 0.001), 0);
        assert_eq!(k_of(0, 1.0), 0);

        let t = top_k(&[], 3);
        assert!(t.indices.is_empty() && t.values.is_empty(), "{t:?}");

        let t = top_k(&[1.0, -2.0], 0);
        assert!(t.indices.is_empty(), "{t:?}");

        assert_eq!(threshold_for_k(&[], 0), f32::INFINITY);
        assert_eq!(threshold_for_k(&[1.0], 0), f32::INFINITY);

        // k beyond the vector length clamps instead of asserting.
        let t = top_k(&[3.0, -1.0], 9);
        assert_eq!(t.indices, vec![0, 1]);

        // Scatter/gather on the empty selection round-trip.
        assert_eq!(scatter(0, &[], &[]), Vec::<f32>::new());
        assert_eq!(gather(&[], &[]), Vec::<f32>::new());
    }

    #[test]
    fn empty_group_through_feedback_memory() {
        use crate::compress::{Correction, FeedbackMemory};
        let mut fb = FeedbackMemory::new(0, Correction::Momentum, 0.9);
        fb.accumulate(&[]);
        let sel = fb.select_and_clear(k_of(0, 0.01));
        assert!(sel.indices.is_empty());
        assert!(fb.take_at(&[]).is_empty());
    }
}
