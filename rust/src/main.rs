//! `lgc` — CLI launcher for the LGC distributed-training framework.
//!
//! Subcommands:
//!   train       run one distributed-training configuration
//!   exp         regenerate a paper table/figure (--id table4|table5|...)
//!   info-plane  §III MI/entropy analysis
//!   latency     AE encode/decode latency measurement
//!   profile     per-HLO-module call profile of a short run
//!   list        show manifest contents
//!
//! Examples:
//!   lgc train --model resnet_mini --method lgc_ps --nodes 4 --steps 300
//!   lgc exp --id table6 --steps 280
//!   lgc info-plane --model resnet_mini --steps 40

use anyhow::{bail, Result};

use lgc::config::TrainConfig;
use lgc::exp::{self, speedup::LinkModel};
use lgc::runtime::{BackendKind, Engine};
use lgc::util::cli::Args;

const FLAGS: &[&str] = &[
    "model", "method", "nodes", "steps", "lr", "momentum", "alpha", "warmup",
    "ae-train", "ae-lr", "lambda2", "schedule", "eval-every", "seed",
    "threads", "verbose", "id", "bins", "pair", "bandwidth-mbps", "artifacts",
    "backend", "assert-improves",
];

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), FLAGS)
        .map_err(|e| anyhow::anyhow!("{e}\nrun `lgc help` for usage"))?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    if sub == "help" {
        print_help();
        return Ok(());
    }
    if let Some(dir) = args.opt_str("artifacts") {
        std::env::set_var("LGC_ARTIFACTS", dir);
    }
    // --backend beats $LGC_BACKEND beats auto.  An explicit --artifacts
    // with no --backend is explicit PJRT intent: a bad path must error
    // (as it always did), never silently fall back to the native
    // backend.  The native backend itself needs no artifacts directory.
    let engine = match args.opt_str("backend") {
        Some(s) => {
            let kind = BackendKind::parse(&s)
                .ok_or_else(|| anyhow::anyhow!("bad --backend {s:?} (auto|pjrt|native)"))?;
            Engine::open(kind)?
        }
        None if args.has("artifacts") => Engine::open(BackendKind::Pjrt)?,
        None => Engine::open_default()?,
    };
    eprintln!(
        "lgc: platform={} models={:?}",
        engine.platform(),
        engine.manifest.models.keys().collect::<Vec<_>>()
    );

    match sub.as_str() {
        "train" => {
            let mut cfg = TrainConfig::from_args(&args);
            if !args.has("warmup") && !args.has("ae-train") {
                cfg = cfg.scaled_phases();
            }
            let r = lgc::coordinator::train(&engine, cfg)?;
            let first_loss = r.curve.first().map(|p| p.train_loss).unwrap_or(f32::NAN);
            let final_loss = r.final_train_loss();
            println!("train loss: {first_loss:.4} -> {final_loss:.4}");
            println!("final eval: loss {:.4}, acc {:.4}", r.final_eval.0, r.final_eval.1);
            println!(
                "steady info size: {:.6} MB/iter/node, compression ratio {:.0}x",
                r.info_size_mb(),
                r.compression_ratio()
            );
            println!("{}", r.ledger.summary());
            if args.has("assert-improves") {
                // CI gate: the run must end with a finite, improved loss.
                if !final_loss.is_finite() || !(final_loss < first_loss) {
                    bail!("--assert-improves: train loss {first_loss} -> {final_loss}");
                }
            }
        }
        "exp" => {
            let id = args.str("id", "all");
            let steps = args.usize("steps", exp::default_steps());
            run_exp(&engine, &id, steps, &args)?;
        }
        "info-plane" => {
            let model = args.str("model", "resnet_mini");
            let steps = args.usize("steps", 40);
            let bins = args.usize("bins", 256);
            exp::info_plane::fig3_fig4(&engine, &model, steps, bins)?;
        }
        "latency" => {
            let model = args.str("model", "resnet_mini");
            let mu = engine.manifest.resolve_model(&model).mu;
            let (e, d, dp) = exp::speedup::ae_latency(&engine, mu, 2)?;
            println!("mu={mu}: encode {e:.3} ms, decode RAR {d:.3} ms, decode PS {dp:.3} ms");
        }
        "profile" => {
            let mut cfg = TrainConfig::from_args(&args);
            cfg.steps = args.usize("steps", 60);
            cfg = cfg.scaled_phases();
            let r = lgc::coordinator::train(&engine, cfg)?;
            println!(
                "coordinator wall: grad {:.1} ms, exchange {:.1} ms, update {:.1} ms",
                r.time_grad.as_secs_f64() * 1e3,
                r.time_exchange.as_secs_f64() * 1e3,
                r.time_update.as_secs_f64() * 1e3
            );
            println!("{:<28} {:>8} {:>12} {:>10}", "module", "calls", "total ms", "ms/call");
            for (name, n, d) in engine.profile() {
                println!(
                    "{:<28} {:>8} {:>12.1} {:>10.3}",
                    name,
                    n,
                    d.as_secs_f64() * 1e3,
                    d.as_secs_f64() * 1e3 / n as f64
                );
            }
        }
        "list" => {
            println!("alpha = {}", engine.manifest.alpha);
            for (name, m) in &engine.manifest.models {
                println!(
                    "model {name}: n={} layers={} mu={} batch={}",
                    m.n_params,
                    m.n_layers(),
                    m.mu,
                    m.batch
                );
            }
            for (mu, v) in &engine.manifest.ae.variants {
                println!(
                    "ae mu={mu}: train K(rar)={:?} K(ps)={:?}",
                    v.train_rar.keys().collect::<Vec<_>>(),
                    v.train_ps.keys().collect::<Vec<_>>()
                );
            }
            println!("{} modules", engine.manifest.modules.len());
        }
        other => bail!("unknown subcommand {other:?}; run `lgc help`"),
    }
    Ok(())
}

fn run_exp(engine: &Engine, id: &str, steps: usize, args: &Args) -> Result<()> {
    match id {
        "table4" => {
            exp::table4(engine, steps)?;
        }
        "table5" => {
            exp::table5(engine, steps)?;
        }
        "table6" => {
            exp::table6(engine, steps)?;
        }
        "fig3" | "fig4" => {
            let bins = args.usize("bins", 256);
            exp::info_plane::fig3_fig4(engine, "resnet_mini", steps.min(60), bins)?;
            exp::info_plane::fig3_fig4(engine, "segnet_mini", steps.min(60), bins)?;
        }
        "fig10" => {
            exp::learning_curves(engine, "resnet_mini", 2, steps, "results/fig10.csv")?;
        }
        "fig11" => {
            exp::learning_curves(engine, "segnet_mini", 2, steps, "results/fig11.csv")?;
        }
        "fig12" => {
            let bins = args.usize("bins", 256);
            println!("=== Fig 12 (scaled): info plane at scale ===");
            // VGG11@16 nodes; ConvNet5@22 nodes (paper SS VI-E).
            for (model, nodes, pair) in [
                ("vgg11_mini", 16usize, (3usize, 11usize)),
                ("convnet5", 22, (8usize, 10usize)),
            ] {
                let rows = exp::info_plane::info_plane_run(
                    engine,
                    model,
                    nodes,
                    steps.min(30),
                    pair,
                    bins,
                    0.05,
                    &format!("results/fig12_k{nodes}.csv"),
                )?;
                let means = exp::info_plane::per_layer_means(&rows);
                let (h, mi): (Vec<f64>, Vec<f64>) =
                    means.iter().map(|(_, h, m)| (*h, *m)).unzip();
                println!(
                    "K={nodes} pair={pair:?}: mean H {:.3} bits, mean MI {:.3} bits, MI/H {:.2}",
                    h.iter().sum::<f64>() / h.len() as f64,
                    mi.iter().sum::<f64>() / mi.len() as f64,
                    mi.iter().sum::<f64>() / h.iter().sum::<f64>()
                );
            }
        }
        "fig13" => {
            exp::fig13(engine, steps)?;
        }
        "fig14" => {
            exp::fig14(engine, steps)?;
        }
        "ablation" => {
            exp::ablation::run_all(engine, steps)?;
        }
        "speedup" => {
            let mbps = args.f32("bandwidth-mbps", 125.0) as f64;
            let link = LinkModel {
                bandwidth_bytes_per_s: mbps * 1e6,
                latency_s: 50e-6,
            };
            exp::speedup_table(engine, "resnet_mini", 4, steps, link)?;
        }
        "all" => {
            for id in [
                "fig3", "table4", "table5", "table6", "fig10", "fig11", "fig12",
                "fig13", "fig14", "speedup",
            ] {
                run_exp(engine, id, steps, args)?;
            }
        }
        other => bail!("unknown experiment id {other:?}"),
    }
    Ok(())
}

fn print_help() {
    println!(
        r#"lgc — Learned Gradient Compression (distributed training framework)

USAGE:
  lgc <subcommand> [--flag value]...

SUBCOMMANDS:
  train        --model M --method baseline|sparse_gd|dgc|scalecom|qsgd|lgc_ps|lgc_rar
               --nodes K --steps N [--lr F --alpha F --schedule warmup|fixed|exp
               --warmup N --ae-train N --lambda2 F --seed S --verbose
               --threads T (0 = one per core; results are identical for any T)
               --assert-improves (exit nonzero unless train loss decreased)]
  exp          --id table4|table5|table6|fig3|fig10|fig11|fig12|fig13|fig14|speedup|all
               [--steps N]
  info-plane   --model M [--steps N --bins B]
  latency      --model M
  profile      --model M --method X [--steps N]
  list

BACKENDS (--backend, or $LGC_BACKEND):
  auto    (default) PJRT when an artifacts dir with manifest.json exists,
          native otherwise
  pjrt    AOT HLO artifacts via the PJRT CPU client; needs `make artifacts`
          and a real xla toolchain (--artifacts DIR or $LGC_ARTIFACTS;
          errors out with instructions when unavailable)
  native  pure-Rust CPU kernels + synthesized manifest; needs no artifacts
          (--artifacts is ignored); models: convnet_mini, mlp_mini (other
          model names substitute the reference workload)

MODELS (pjrt): convnet5, resnet_mini, resnet_mini_deep, segnet_mini,
transformer_mini.  Artifacts are read from $LGC_ARTIFACTS or ./artifacts
(run `make artifacts`)."#
    );
}
