//! `lgc` — CLI launcher for the LGC distributed-training framework.
//!
//! Subcommands:
//!   train       run one distributed-training configuration
//!   serve       coordinator for externally-launched workers (DESIGN.md §12)
//!   worker      one node of a multi-process run; connects to a coordinator
//!   exp         regenerate a paper table/figure (`lgc exp fig14` or --id)
//!   info-plane  §III MI/entropy analysis
//!   latency     AE encode/decode latency measurement
//!   profile     per-HLO-module call profile of a short run
//!   list        show manifest contents
//!
//! Examples:
//!   lgc train --model resnet_mini --method lgc_ps --nodes 4 --steps 300
//!   lgc train --method lgc_rar --nodes 4 --steps 120 --transport tcp
//!   lgc exp fig14 --backend native
//!   lgc exp --id table6 --steps 280
//!   lgc info-plane --model resnet_mini --steps 40

use std::time::Duration;

use anyhow::{bail, Result};

use lgc::config::{TrainConfig, TransportKind};
use lgc::coordinator::{remote, worker};
use lgc::exp::{self, speedup::LinkModel, Fig14Opts};
use lgc::net::{model::parse_bandwidth_mbits, Topology};
use lgc::runtime::{BackendKind, Engine};
use lgc::util::cli::Args;

/// Valued flags (`--flag value`).
const FLAGS: &[&str] = &[
    "model", "method", "nodes", "steps", "lr", "momentum", "alpha", "warmup",
    "ae-train", "ae-lr", "lambda2", "schedule", "eval-every", "seed",
    "threads", "id", "bins", "pair", "bandwidth-mbps", "artifacts",
    "backend", "bandwidth", "latency-us", "straggler", "topology",
    "transport", "listen", "connect", "session", "net-timeout-ms",
    "join-timeout-ms", "retries", "backoff-ms", "checkpoint",
    "buckets", "bucket-bytes", "index-codec",
    "heartbeat-ms", "miss-budget", "on-fault", "faults", "resume",
    "ckpt-every", "rejoin-node",
    "trace-out", "log-json", "metrics-addr", "log-level",
];

/// Boolean switches (never consume the next token).
const SWITCHES: &[&str] = &["verbose", "assert-improves", "fp16", "no-overlap"];

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), FLAGS, SWITCHES)
        .map_err(|e| anyhow::anyhow!("{e}\nrun `lgc help` for usage"))?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    if sub == "help" {
        print_help();
        return Ok(());
    }
    // Positionals are only meaningful for `exp <id>`; anywhere else a
    // bare token is a mistake (e.g. `lgc train lgc_rar` missing
    // `--method`) and must fail loudly, as unknown flags do.
    let max_positionals = usize::from(sub == "exp");
    if let Some(extra) = args.positional(max_positionals) {
        bail!("unexpected argument {extra:?} for `{sub}`; run `lgc help` for usage");
    }
    if let Some(dir) = args.opt_str("artifacts") {
        std::env::set_var("LGC_ARTIFACTS", dir);
    }
    // --backend beats $LGC_BACKEND beats auto.  An explicit --artifacts
    // with no --backend is explicit PJRT intent: a bad path must error
    // (as it always did), never silently fall back to the native
    // backend.  The native backend itself needs no artifacts directory.
    let engine = match args.opt_str("backend") {
        Some(s) => {
            let kind = BackendKind::parse(&s)
                .ok_or_else(|| anyhow::anyhow!("bad --backend {s:?} (auto|pjrt|native)"))?;
            Engine::open(kind)?
        }
        None if args.has("artifacts") => Engine::open(BackendKind::Pjrt)?,
        None => Engine::open_default()?,
    };
    eprintln!(
        "lgc: platform={} models={:?}",
        engine.platform(),
        engine.manifest.models.keys().collect::<Vec<_>>()
    );

    match sub.as_str() {
        "train" => {
            let mut cfg = TrainConfig::from_args(&args);
            if !args.has("warmup") && !args.has("ae-train") {
                cfg = cfg.scaled_phases();
            }
            let tcp = cfg.transport == TransportKind::Tcp;
            let iters = cfg.steps.max(1) as f64;
            let r = if tcp {
                // The opts-carrying TCP path bypasses `coordinator::train`,
                // so it owns the telemetry lifecycle itself (the metrics
                // server must outlive the run; the trace merge happens
                // after the workers' part files are flushed).
                let _metrics = lgc::coordinator::telemetry_install(&cfg)?;
                let result = remote::train_with_opts(&engine, cfg.clone(), &remote_opts(&args));
                lgc::coordinator::telemetry_finish(&cfg, result.is_ok())?;
                result?
            } else {
                lgc::coordinator::train(&engine, cfg)?
            };
            let first_loss = r.curve.first().map(|p| p.train_loss).unwrap_or(f32::NAN);
            let final_loss = r.final_train_loss();
            println!("train loss: {first_loss:.4} -> {final_loss:.4}");
            println!("final eval: loss {:.4}, acc {:.4}", r.final_eval.0, r.final_eval.1);
            println!(
                "steady info size: {:.6} MB/iter/node, compression ratio {:.0}x",
                r.info_size_mb(),
                r.compression_ratio()
            );
            let link = r.net.fabric.link;
            let per_node_note = if r.net.fabric.has_stragglers() {
                let rounded: Vec<f64> = r
                    .net
                    .per_node_s_at(link)
                    .iter()
                    .map(|s| (s * 1e3).round() / 1e3)
                    .collect();
                format!(", per-node link s: {rounded:?}")
            } else {
                String::new()
            };
            println!(
                "modeled comm ({:.0} Mbit/s, {:.0} us): {:.3} ms/iter steady{}",
                link.mbits(),
                link.latency_s * 1e6,
                r.steady_comm_s_at(link, 50) * 1e3,
                per_node_note
            );
            if tcp {
                // Measured wall-clock vs the fabric's model (CI uploads
                // this line as the tcp-loopback artifact).
                println!(
                    "measured wall (tcp): grad+wire {:.3} ms/iter, exchange {:.3} ms/iter, \
                     modeled comm {:.3} ms/iter",
                    r.time_grad.as_secs_f64() * 1e3 / iters,
                    r.time_exchange.as_secs_f64() * 1e3 / iters,
                    r.steady_comm_s_at(link, 50) * 1e3
                );
            }
            print_fault_events(&r);
            println!("{}", r.ledger.summary());
            if args.has("assert-improves") {
                // CI gate: the run must end with a finite, improved loss.
                if !final_loss.is_finite() || !(final_loss < first_loss) {
                    bail!("--assert-improves: train loss {first_loss} -> {final_loss}");
                }
            }
        }
        "serve" => {
            // Coordinator only: bind, wait for externally-launched
            // `lgc worker` processes, run the session.
            let mut cfg = TrainConfig::from_args(&args);
            if !args.has("warmup") && !args.has("ae-train") {
                cfg = cfg.scaled_phases();
            }
            cfg.transport = TransportKind::Tcp;
            let mut opts = remote_opts(&args);
            opts.spawn_workers = false;
            let _metrics = lgc::coordinator::telemetry_install(&cfg)?;
            let result = remote::train_with_opts(&engine, cfg.clone(), &opts);
            lgc::coordinator::telemetry_finish(&cfg, result.is_ok())?;
            let r = result?;
            println!("final eval: loss {:.4}, acc {:.4}", r.final_eval.0, r.final_eval.1);
            print_fault_events(&r);
            println!("{}", r.ledger.summary());
        }
        "worker" => {
            let connect = args.opt_str("connect").ok_or_else(|| {
                anyhow::anyhow!("`lgc worker` needs --connect <host:port|unix:/path>")
            })?;
            let mut opts = worker::WorkerOpts { connect, ..Default::default() };
            opts.session = args.u64("session", opts.session);
            opts.retries = args.usize("retries", opts.retries);
            opts.backoff_ms = args.u64("backoff-ms", opts.backoff_ms);
            opts.net_timeout = Duration::from_millis(
                args.u64("net-timeout-ms", opts.net_timeout.as_millis() as u64),
            );
            if args.has("rejoin-node") {
                opts.rejoin_node = Some(args.u64("rejoin-node", 0) as u32);
            }
            worker::run(&engine, &opts)?;
        }
        "exp" => {
            // `lgc exp fig14` and `lgc exp --id fig14` are equivalent.
            if let Some(t) = args.opt_str("transport") {
                let kind = TransportKind::parse(&t)
                    .ok_or_else(|| anyhow::anyhow!("bad --transport {t:?} (sim|tcp)"))?;
                exp::set_transport(kind);
            }
            if let Some(c) = args.opt_str("index-codec") {
                let codec = lgc::compress::index_coding::IndexCodec::parse(&c).ok_or_else(
                    || anyhow::anyhow!("bad --index-codec {c:?} (auto|bitmap|deflate|golomb)"),
                )?;
                exp::set_index_codec(codec);
            }
            let id = args
                .positional(0)
                .map(str::to_string)
                .unwrap_or_else(|| args.str("id", "all"));
            let steps = args.usize("steps", exp::default_steps());
            run_exp(&engine, &id, steps, &args)?;
        }
        "info-plane" => {
            let model = args.str("model", "resnet_mini");
            let steps = args.usize("steps", 40);
            let bins = args.usize("bins", 256);
            exp::info_plane::fig3_fig4(&engine, &model, steps, bins)?;
        }
        "latency" => {
            let model = args.str("model", "resnet_mini");
            let mu = engine.manifest.resolve_model(&model).mu;
            let (e, d, dp) = exp::speedup::ae_latency(&engine, mu, 2)?;
            println!("mu={mu}: encode {e:.3} ms, decode RAR {d:.3} ms, decode PS {dp:.3} ms");
        }
        "profile" => {
            let mut cfg = TrainConfig::from_args(&args);
            cfg.steps = args.usize("steps", 60);
            cfg = cfg.scaled_phases();
            let r = lgc::coordinator::train(&engine, cfg)?;
            println!(
                "coordinator wall: grad {:.1} ms, exchange {:.1} ms, update {:.1} ms",
                r.time_grad.as_secs_f64() * 1e3,
                r.time_exchange.as_secs_f64() * 1e3,
                r.time_update.as_secs_f64() * 1e3
            );
            println!("{:<28} {:>8} {:>12} {:>10}", "module", "calls", "total ms", "ms/call");
            for (name, n, d) in engine.profile() {
                println!(
                    "{:<28} {:>8} {:>12.1} {:>10.3}",
                    name,
                    n,
                    d.as_secs_f64() * 1e3,
                    d.as_secs_f64() * 1e3 / n as f64
                );
            }
        }
        "list" => {
            println!("alpha = {}", engine.manifest.alpha);
            for (name, m) in &engine.manifest.models {
                println!(
                    "model {name}: n={} layers={} mu={} batch={}",
                    m.n_params,
                    m.n_layers(),
                    m.mu,
                    m.batch
                );
            }
            for (mu, v) in &engine.manifest.ae.variants {
                println!(
                    "ae mu={mu}: train K(rar)={:?} K(ps)={:?}",
                    v.train_rar.keys().collect::<Vec<_>>(),
                    v.train_ps.keys().collect::<Vec<_>>()
                );
            }
            println!("{} modules", engine.manifest.modules.len());
        }
        other => bail!("unknown subcommand {other:?}; run `lgc help`"),
    }
    Ok(())
}

/// The fault-event log (each line also streamed to stderr as it fired) —
/// CI's chaos job uploads these lines as its artifact.
fn print_fault_events(r: &lgc::coordinator::TrainResult) {
    if r.fault_events.is_empty() {
        return;
    }
    println!("fault events ({}):", r.fault_events.len());
    for ev in &r.fault_events {
        println!("  {}", ev.log_line());
    }
}

/// Coordinator-side transport knobs from the command line (`train
/// --transport tcp` and `serve`).
fn remote_opts(args: &Args) -> remote::RemoteOpts {
    let mut o = remote::RemoteOpts::local(args.u64("session", remote::default_session()));
    o.listen = args.str("listen", &o.listen);
    o.join_timeout = Duration::from_millis(args.u64("join-timeout-ms", 60_000));
    o.net_timeout = Duration::from_millis(args.u64("net-timeout-ms", 30_000));
    o
}

fn run_exp(engine: &Engine, id: &str, steps: usize, args: &Args) -> Result<()> {
    match id {
        "table4" => {
            exp::table4(engine, steps)?;
        }
        "table5" => {
            exp::table5(engine, steps)?;
        }
        "table6" => {
            exp::table6(engine, steps)?;
        }
        "fig3" | "fig4" => {
            let bins = args.usize("bins", 256);
            exp::info_plane::fig3_fig4(engine, "resnet_mini", steps.min(60), bins)?;
            exp::info_plane::fig3_fig4(engine, "segnet_mini", steps.min(60), bins)?;
        }
        "fig10" => {
            exp::learning_curves(engine, "resnet_mini", 2, steps, "results/fig10.csv")?;
        }
        "fig11" => {
            exp::learning_curves(engine, "segnet_mini", 2, steps, "results/fig11.csv")?;
        }
        "fig12" => {
            let bins = args.usize("bins", 256);
            println!("=== Fig 12 (scaled): info plane at scale ===");
            // VGG11@16 nodes; ConvNet5@22 nodes (paper SS VI-E).
            for (model, nodes, pair) in [
                ("vgg11_mini", 16usize, (3usize, 11usize)),
                ("convnet5", 22, (8usize, 10usize)),
            ] {
                let rows = exp::info_plane::info_plane_run(
                    engine,
                    model,
                    nodes,
                    steps.min(30),
                    pair,
                    bins,
                    0.05,
                    &format!("results/fig12_k{nodes}.csv"),
                )?;
                let means = exp::info_plane::per_layer_means(&rows);
                let (h, mi): (Vec<f64>, Vec<f64>) =
                    means.iter().map(|(_, h, m)| (*h, *m)).unzip();
                println!(
                    "K={nodes} pair={pair:?}: mean H {:.3} bits, mean MI {:.3} bits, MI/H {:.2}",
                    h.iter().sum::<f64>() / h.len() as f64,
                    mi.iter().sum::<f64>() / mi.len() as f64,
                    mi.iter().sum::<f64>() / h.iter().sum::<f64>()
                );
            }
        }
        "fig13" => {
            exp::fig13(engine, steps)?;
        }
        "fig14" => {
            let mut opts = Fig14Opts {
                model: args.str("model", "resnet_mini"),
                nodes: args.usize("nodes", 4),
                steps,
                threads: args.usize("threads", 0),
                ..Default::default()
            };
            opts.latency_s =
                args.f32("latency-us", (opts.latency_s * 1e6) as f32) as f64 * 1e-6;
            if let Some(b) = args.opt_str("bandwidth") {
                // An explicit --bandwidth narrows the sweep to one point.
                let mbits = parse_bandwidth_mbits(&b)
                    .ok_or_else(|| anyhow::anyhow!("bad --bandwidth {b:?}"))?;
                opts.bandwidths_mbits = vec![mbits];
            }
            if let Some(t) = args.opt_str("topology") {
                opts.topology = Some(
                    Topology::parse(&t)
                        .ok_or_else(|| anyhow::anyhow!("bad --topology {t:?} (ps|ring)"))?,
                );
            }
            if let Some(s) = args.opt_str("straggler") {
                opts.straggler_spec = lgc::config::parse_straggler_spec(&s)
                    .ok_or_else(|| anyhow::anyhow!("bad --straggler {s:?}"))?;
            }
            exp::fig14_sweep(engine, &opts)?;
        }
        "fig14-ae" => {
            exp::fig14_ae(engine, steps)?;
        }
        "validate-net" => {
            // Measured (tcp loopback) vs modeled (fabric) per phase;
            // keep the default step budget tcp-sized.
            let method = match args.opt_str("method") {
                Some(s) => lgc::config::Method::parse(&s)
                    .ok_or_else(|| anyhow::anyhow!("bad --method {s:?}"))?,
                None => lgc::config::Method::LgcRar,
            };
            let model = args.str("model", "resnet_mini");
            let nodes = args.usize("nodes", 4);
            let steps = if args.has("steps") { steps } else { steps.min(60) };
            exp::validate_net::validate_net(engine, &model, method, nodes, steps)?;
        }
        "ablation" => {
            exp::ablation::run_all(engine, steps)?;
        }
        "speedup" => {
            let link = if let Some(b) = args.opt_str("bandwidth") {
                let mbits = parse_bandwidth_mbits(&b)
                    .ok_or_else(|| anyhow::anyhow!("bad --bandwidth {b:?}"))?;
                LinkModel::from_mbits(
                    mbits,
                    args.f32("latency-us", 50.0) as f64 * 1e-6,
                )
            } else {
                // Legacy flag: megaBYTES per second.
                let mbps = args.f32("bandwidth-mbps", 125.0) as f64;
                LinkModel {
                    bandwidth_bytes_per_s: mbps * 1e6,
                    latency_s: args.f32("latency-us", 50.0) as f64 * 1e-6,
                }
            };
            exp::speedup_table(engine, "resnet_mini", 4, steps, link)?;
        }
        "all" => {
            for id in [
                "fig3", "table4", "table5", "table6", "fig10", "fig11", "fig12",
                "fig13", "fig14", "fig14-ae", "speedup",
            ] {
                run_exp(engine, id, steps, args)?;
            }
        }
        other => bail!("unknown experiment id {other:?}"),
    }
    Ok(())
}

fn print_help() {
    println!(
        r#"lgc — Learned Gradient Compression (distributed training framework)

USAGE:
  lgc <subcommand> [--flag value]...

SUBCOMMANDS:
  train        --model M --method baseline|sparse_gd|dgc|scalecom|qsgd|lgc_ps|lgc_rar
               --nodes K --steps N [--lr F --alpha F --schedule warmup|fixed|exp
               --warmup N --ae-train N --lambda2 F --seed S --verbose
               --fp16 (transmit sparse value payloads as f16)
               --index-codec auto|bitmap|deflate|golomb (sparse index wire
               codec; deflate = legacy hybrid default, auto prices all
               three per layer and ships the smallest; DESIGN.md §16.2)
               --threads T (0 = one per core; results are identical for any T)
               --assert-improves (exit nonzero unless train loss decreased)]
  serve        coordinator for externally-launched workers; same training
               flags as train, plus --listen ADDR --session ID
               [--join-timeout-ms N --net-timeout-ms N]
  worker       one node of a multi-process run: --connect HOST:PORT|unix:/path
               [--session ID --retries N --backoff-ms N --net-timeout-ms N
               --rejoin-node N (re-enter a live elastic run as node N)]
  exp          <id> or --id ID, one of table4|table5|table6|fig3|fig10|fig11|
               fig12|fig13|fig14|fig14-ae|speedup|ablation|validate-net|all
               [--steps N --index-codec auto|bitmap|deflate|golomb]
               fig14 = modeled speedup-vs-bandwidth sweep (results/
               fig14_speedup.csv + overlap-adjusted fig14_overlap.csv);
               fig14-ae = AE convergence traces;
               validate-net = same config under sim and tcp, per-phase
               modeled-vs-measured table (results/net_validation.csv)
  info-plane   --model M [--steps N --bins B]
  latency      --model M
  profile      --model M --method X [--steps N]
  list

TRANSPORT (train, serve, exp; DESIGN.md §12):
  --transport sim|tcp  sim (default) = single-process simulated exchange;
                       tcp = one OS process per node over TCP/UDS, spawned
                       from this binary, bit-identical results to sim
  --listen ADDR        coordinator bind: host:port (0 = ephemeral) or
                       unix:/path.sock (default 127.0.0.1:0)
  --session ID         session id workers must present (default pid-derived)
  --net-timeout-ms N   per-receive deadline; a dead peer errors out within
                       this bound instead of hanging (default 30000)
  --checkpoint PATH    save the final model replica to PATH (any transport)

FAULT TOLERANCE (train, serve; DESIGN.md §14):
  --heartbeat-ms N     worker->coordinator heartbeat period (0 = off); with
                       heartbeats on, a silent worker is declared dead after
                       the miss budget instead of the full net timeout
  --miss-budget N      consecutive missed heartbeat periods tolerated
                       (default 3)
  --on-fault POLICY    fail (default) = any worker death aborts the run;
                       continue = drop the dead worker and renormalize
                       aggregation over the survivors (its EF residual is
                       lost; methods with shared coordinator state refuse);
                       wait-rejoin = respawn the worker and resync it via a
                       token-checked rejoin handshake, bit-identically
  --faults SPEC        deterministic fault plan, e.g.
                       "iter=40:kill=2;iter=60:stall=1:500ms;
                        iter=80:corrupt-frame=3;iter=90:crash"
                       (executed by sim and tcp backends alike)
  --ckpt-every N       write an atomic training checkpoint every N
                       iterations to --checkpoint PATH (sim transport)
  --resume PATH        resume a sim run from a training checkpoint; the
                       resumed run is bit-identical to an uninterrupted one

PIPELINED EXECUTION (train, serve, worker; DESIGN.md §13):
  --buckets N        partition the mid-group gradient into N layer-aligned
                     buckets (default 1 = monolithic); selection and values
                     stay bit-identical to the unbucketed run
  --bucket-bytes B   size-targeted alternative: cut buckets of <= B dense
                     bytes each (wins over --buckets when set)
  --no-overlap       keep the legacy barrier schedule: encode everything,
                     then exchange everything.  Default (overlap on) streams
                     bucket i's exchange while bucket i+1 encodes; training
                     curves and final model state are identical either way

OBSERVABILITY (train, serve; DESIGN.md §15):
  --trace-out PATH     write a Chrome/Perfetto trace of every pipeline
                       stage (grad, EF, top-k, AE encode/decode, index
                       coding, DEFLATE, exchange, update) per node and
                       iteration; load at ui.perfetto.dev.  TCP workers
                       inherit the flag and flush PATH.nodeN.part files
                       the coordinator merges
  --log-json PATH      structured JSONL run log: run manifest (config
                       fingerprint, git describe, backend), one record
                       per iteration (loss, bytes by kind, compression
                       ratio, stage durations), every fault event
  --metrics-addr ADDR  serve live Prometheus text-format metrics on ADDR
                       while training (iterations, per-worker bytes,
                       heartbeat age, stalls/deaths/rejoins, decode
                       errors, per-stage latency histograms)
  --log-level L        quiet|info|debug (default info preserves today's
                       stderr output byte for byte; workers inherit the
                       level through the config blob)
  Telemetry off = zero overhead; on, the training math is unchanged
  (curves, ledgers, checkpoints stay bit-identical — tests enforce it).

NETWORK FABRIC (train, exp fig14, exp speedup; DESIGN.md §11):
  --bandwidth B      modeled link bandwidth: 1gbps, 50mbps, or Mbit/s number
                     (default 1gbps; exp fig14 sweeps 1000..50 Mbit/s unless set)
  --latency-us L     per-message base latency in microseconds (default 50)
  --straggler S      per-node slowdown: a bare multiplier for node 0 ("2.5")
                     or node:mult pairs ("0:2,3:1.5")
  --topology ps|ring restrict exp fig14's LGC curves to one pattern
  (--bandwidth-mbps is the legacy exp-speedup flag, in megaBYTES/s)

BACKENDS (--backend, or $LGC_BACKEND):
  auto    (default) PJRT when an artifacts dir with manifest.json exists,
          native otherwise
  pjrt    AOT HLO artifacts via the PJRT CPU client; needs `make artifacts`
          and a real xla toolchain (--artifacts DIR or $LGC_ARTIFACTS;
          errors out with instructions when unavailable)
  native  pure-Rust CPU kernels + synthesized manifest; needs no artifacts
          (--artifacts is ignored); models: convnet_mini, mlp_mini (other
          model names substitute the reference workload)

MODELS (pjrt): convnet5, resnet_mini, resnet_mini_deep, segnet_mini,
transformer_mini.  Artifacts are read from $LGC_ARTIFACTS or ./artifacts
(run `make artifacts`).

ENVIRONMENT:
  LGC_FORCE_SCALAR=1  disable the runtime-dispatched AVX2 encode kernels
                      and run their scalar twins instead; every output is
                      bit-identical either way (DESIGN.md §16.1)"#
    );
}
