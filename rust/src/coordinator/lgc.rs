//! LGC — the paper's contribution, both communication-pattern instances.
//!
//! Shared structure (Algorithm 1 / 2):
//!   phase 1 (dense):      plain dense exchange
//!   phase 2 (top-k):      per-node top-mu EF selection transmitted like
//!                         DGC, while the autoencoder trains online on the
//!                         observed value-vectors
//!   phase 3 (compressed): top-mu value-vectors flow *through* the learned
//!                         compressor
//!
//! Support-set protocol clarification (DESIGN.md §6.6): in phase 3 the
//! per-iteration leader's top-mu index set defines every node's selection
//! (ScaleCom's CLT-k rule, which §V-A prescribes for ring-allreduce; we
//! apply it to the PS pattern's phase 3 too so the master can scatter
//! reconstructions without per-node index uploads — this is what makes the
//! paper's "innovation-only" rate for non-leader workers realizable).
//!
//! * PS (§V-B1): leader uploads latent + coded indices (+ its innovation);
//!   every other worker uploads only its innovation (top 10% of its
//!   value-vector). The master decodes per-node with decoder D_c^k and the
//!   node's innovation, averages, scatters.
//! * RAR (§V-B2): every node encodes its value-vector; the *latents* are
//!   ring-allreduced; every node decodes the averaged latent. The AE
//!   weights are broadcast once when phase 3 begins (rate counted).
//!
//! Execution model (DESIGN.md §6.5, §6.11): each simulated node owns one
//! `NodeState` — its EF memory, its value-vector and innovation
//! buffers, and its scratch arena — so the node-local stages (EF
//! accumulation, gather-at-support, innovation selection, per-node
//! encode/decode) fan out over `coordinator::parallel` with zero
//! steady-state allocation; the leader broadcast, latent ring-allreduce,
//! and every mean reduction are sequential barriers reducing in node
//! order, so thread count never changes a result bit.

use anyhow::Result;

use crate::baselines::{check_node_count, dense_mean_accounted, ExchangeCtx, MidStrategy};
use crate::compress::autoencoder::{rms, AeCompressor, Pattern};
use crate::compress::index_coding::IndexCodec;
use crate::compress::{index_coding, topk, Correction, FeedbackMemory, Scratch};
use crate::coordinator::parallel;
use crate::coordinator::ring;
use crate::coordinator::scheduler::Phase;
use crate::metrics::Kind;
use crate::obs::trace;
use crate::util::ser::{self, Reader};

/// Knobs shared by both LGC instances (subset of [`crate::config::TrainConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct LgcParams {
    pub momentum: f32,
    pub innovation_frac: f64,
    pub ae_lr: f32,
    pub lambda2: f32,
    pub ae_inner_steps: usize,
    pub ae_gate: f32,
    pub seed: u64,
}

/// Stability guard for the compressed phase.  Error feedback makes the
/// EF memories grow whenever the reconstruction drains them slower than
/// momentum-corrected gradients flow in, so any bound tied to the memory
/// norm grows with it and cannot prevent divergence.  The correct trust
/// region is the *fresh gradient* scale: the applied update may never
/// exceed `CLIP_MULT x || mean of this iteration\'s raw mid gradients ||`.
/// Clipped mass is not lost — the EF correction re-accumulates it.
const CLIP_MULT: f32 = 2.0;

pub(crate) fn clip_to_gradient_scale(rec: &mut [f32], grads: &[Vec<f32>]) {
    // Non-finite outputs zero out entirely (EF retransmits the mass).
    if rec.iter().any(|x| !x.is_finite()) {
        rec.iter_mut().for_each(|x| *x = 0.0);
        return;
    }
    let n = grads[0].len();
    let k = grads.len() as f32;
    let mut norm2 = 0.0f64;
    for j in 0..n {
        let m: f32 = grads.iter().map(|g| g[j]).sum::<f32>() / k;
        norm2 += (m as f64) * (m as f64);
    }
    let target = (norm2.sqrt() as f32) * CLIP_MULT;
    let rec_norm = rec.iter().map(|x| x * x).sum::<f32>().sqrt();
    if rec_norm > target && rec_norm > 0.0 {
        let scale = target / rec_norm;
        rec.iter_mut().for_each(|x| *x *= scale);
    }
}

/// All per-node state of an LGC instance, bundled so one worker thread
/// owns the whole row (DESIGN.md §6.5/§6.11): the EF memory, the
/// value-vector gathered at the shared support, the dense innovation
/// vector, and the scratch arena every node-local stage borrows from.
struct NodeState {
    fb: FeedbackMemory,
    /// Value-vector gathered at the shared support (mu-length).
    vv: Vec<f32>,
    /// Dense innovation vector (mu-length; PS pattern).
    inn: Vec<f32>,
    scratch: Scratch,
}

/// Innovation component of a value-vector: top `frac` of |values| kept at
/// their positions, zeros elsewhere (Algorithm 1's mask_inv), written
/// into the node's `dense` buffer.  Returns the wire bytes (values +
/// coded indices).  Free function (not a method) so the parallel
/// per-node closures can call it while node rows are mutably split
/// across workers.
pub(crate) fn innovation_into(
    values: &[f32],
    frac: f64,
    codec: IndexCodec,
    dense: &mut Vec<f32>,
    sc: &mut Scratch,
) -> Result<usize> {
    let k_inn = topk::k_of(values.len(), frac);
    topk::top_k_into(values, k_inn, &mut sc.mags, &mut sc.idx, &mut sc.vals);
    topk::scatter_into(dense, values.len(), &sc.idx, &sc.vals);
    let coded = index_coding::encode_with_into(&sc.idx, values.len(), codec, &mut sc.enc)?.len();
    Ok(sc.vals.len() * 4 + coded)
}

/// State shared by both LGC instances: per-node rows, the autoencoder,
/// the leader's broadcast support, and the phase-3 readiness gate.
pub struct LgcCommon {
    nodes: Vec<NodeState>,
    pub ae: AeCompressor,
    /// The shared support of the current iteration, in the leader's
    /// signed-descending-value order.  Persistent buffer: refilled by
    /// [`LgcCommon::leader_support_inner`] each iteration, borrowed by
    /// every node-local stage after it.
    support: Vec<u32>,
    mu: usize,
    innovation_frac: f64,
    ae_lr: f32,
    lambda2: f32,
    ae_inner_steps: usize,
    ae_gate: f32,
    /// Sticky readiness gate: compressed updates engage only after the
    /// online reconstruction loss (unit-RMS MSE) clears AE_READY_GATE.
    /// An under-trained decoder emits noise at gradient scale; applying
    /// it as the update stalls or diverges training (the paper trains
    /// "until it can be used", §V-B — the gate operationalizes that).
    ae_ready: bool,
}

/// Rec-loss averaging window for the readiness gate.
pub(crate) const AE_GATE_WINDOW: usize = 8;

/// Whether nodes re-accumulate the shared-reconstruction error into their
/// EF memories.  Algorithm 1/2 discard it (only non-selected coordinates
/// accumulate); with the gradient-scale clip that is also the stabler
/// configuration — EF-on-rec keeps ~all selected mass in the memory
/// (drainage << inflow), ballooning it without improving updates.
/// Kept as a switch for the ablation (LGC_EF_ON_REC=1).
pub(crate) fn ef_on_rec() -> bool {
    std::env::var("LGC_EF_ON_REC").is_ok()
}

/// Per-iteration reconstruction diagnostics: on at `--log-level debug`,
/// or under the legacy `LGC_DEBUG` env var (which keeps working at any
/// level, so existing invocations are unchanged).
fn dbg_rec() -> bool {
    crate::obs::log::enabled(crate::obs::log::Level::Debug) || std::env::var("LGC_DEBUG").is_ok()
}

impl LgcCommon {
    fn new(nodes: usize, n: usize, mu: usize, p: &LgcParams, ae: AeCompressor) -> Self {
        LgcCommon {
            nodes: (0..nodes)
                .map(|_| NodeState {
                    fb: FeedbackMemory::new(n, Correction::Momentum, p.momentum),
                    vv: Vec::new(),
                    inn: Vec::new(),
                    scratch: Scratch::new(),
                })
                .collect(),
            ae,
            support: Vec::new(),
            mu,
            innovation_frac: p.innovation_frac,
            ae_lr: p.ae_lr,
            lambda2: p.lambda2,
            ae_inner_steps: p.ae_inner_steps.max(1),
            ae_gate: p.ae_gate,
            ae_ready: false,
        }
    }

    /// Serialize the cross-iteration state shared by both LGC instances
    /// (crash-safe resume, DESIGN.md §14): per-node EF memories, the
    /// latched readiness gate, and the autoencoder (weights + the online
    /// loss history the gate averages over).  The support and the
    /// per-node value/innovation buffers are refilled every iteration
    /// and are not serialized.
    fn save_state(&self, out: &mut Vec<u8>) {
        ser::put_u64(out, self.nodes.len() as u64);
        for st in &self.nodes {
            st.fb.write_state(out);
        }
        ser::put_u8(out, self.ae_ready as u8);
        out.extend_from_slice(&self.ae.export_state());
    }

    fn load_state(&mut self, r: &mut Reader) -> Result<()> {
        check_node_count(r, self.nodes.len(), "lgc")?;
        for st in &mut self.nodes {
            st.fb.read_state(r)?;
        }
        self.ae_ready = match r.u8()? {
            0 => false,
            1 => true,
            other => anyhow::bail!("bad ae_ready tag {other}"),
        };
        self.ae.import_state(r)?;
        Ok(())
    }

    /// Check (and latch) autoencoder readiness.
    fn check_ae_ready(&mut self) -> bool {
        if self.ae_ready {
            return true;
        }
        let losses = &self.ae.train_losses;
        if losses.len() >= AE_GATE_WINDOW {
            let tail = &losses[losses.len() - AE_GATE_WINDOW..];
            let mean = tail.iter().map(|(r, _)| r).sum::<f32>() / AE_GATE_WINDOW as f32;
            if mean < self.ae_gate {
                self.ae_ready = true;
            }
        }
        self.ae_ready
    }

    /// Phase-2 step shared by both patterns: leader-support top-mu
    /// selection, transmitted values (+ the leader's ordered index
    /// broadcast), exact-value updates, AE online training.
    ///
    /// The selection uses the same leader-signed-order protocol as phase 3
    /// (see leader_support_inner) so the autoencoder trains on exactly the
    /// distribution it will compress — training it on per-node index-order
    /// vectors and deploying it on leader-ordered ones is a train/serve
    /// skew that cancels the learned gains.
    fn topk_phase(
        &mut self,
        ctx: &mut ExchangeCtx,
        grads: &[Vec<f32>],
        ps: bool,
    ) -> Result<Vec<f32>> {
        let n = grads[0].len();
        let nodes = grads.len();
        let leader = if ps { 0 } else { ctx.iter % nodes };
        self.leader_support_inner(ctx, grads, leader)?;
        // Node-local stage: gather each node's EF memory at the shared
        // support into the node's value-vector buffer, byte-accounting
        // per shard.  In the RAR pattern the per-iteration trainer node
        // additionally gathers every other node's value-vector (paper
        // Fig. 7) — those uplinks ride along.
        let trainer = ctx.iter % nodes;
        let mu = self.mu;
        parallel::par_zip_mut(
            ctx.threads,
            &mut self.nodes,
            &mut *ctx.shards,
            |node, st, shard| {
                st.fb.take_at_into(&self.support, &mut st.vv);
                shard.record(Kind::Values, st.vv.len() * 4);
                if !ps && node != trainer {
                    shard.record(Kind::Values, mu * 4);
                }
            },
        );
        // Barrier: exact-value mean in node order.
        let mut mean = vec![0.0f32; n];
        for st in &self.nodes {
            topk::scatter_add(&mut mean, &self.support, &st.vv);
        }
        mean.iter_mut().for_each(|m| *m /= nodes as f32);

        // Result redistribution: PS scatters from the server (server-side
        // traffic, fabric time only like every fan-out); RAR's
        // per-iteration trainer node unicasts the mu aggregated values to
        // its K-1 peers (paper Fig. 7) — the trainer is a *worker*, so
        // those bytes are uplink: ledger-recorded on the barrier path
        // (§6.5) in lockstep with the fabric broadcast.
        if ps {
            ctx.net.fanout((self.mu * 4) as u64);
        } else if nodes > 1 {
            ctx.ledger.record(trainer, Kind::Values, (nodes - 1) * self.mu * 4);
            ctx.net.broadcast(trainer, (self.mu * 4) as u64);
        }

        // Online AE training on the just-observed value-vectors.  The data
        // already sits where the trainer runs (master for PS, the gathered
        // trainer node for RAR), so the inner steps add compute, not bytes
        // — they recover the paper's 200-300-iteration AE training budget
        // within our scaled phase-2 window.
        if ps {
            let frac = self.innovation_frac;
            let codec = ctx.codec;
            parallel::collect_node_results(parallel::par_map_mut(
                ctx.threads,
                &mut self.nodes,
                |_node, st| -> Result<()> {
                    innovation_into(&st.vv, frac, codec, &mut st.inn, &mut st.scratch)?;
                    Ok(())
                },
            ))?;
            let rows: Vec<&[f32]> = self.nodes.iter().map(|st| st.vv.as_slice()).collect();
            let inns: Vec<&[f32]> = self.nodes.iter().map(|st| st.inn.as_slice()).collect();
            let _sp = trace::span(trace::Stage::AeTrain);
            for _ in 0..self.ae_inner_steps {
                let ridx = ctx.rng.below(nodes);
                self.ae.train_step(
                    ctx.engine,
                    &rows,
                    Some(&inns),
                    ridx,
                    self.ae_lr,
                    1.0,
                    self.lambda2,
                )?;
            }
        } else {
            let rows: Vec<&[f32]> = self.nodes.iter().map(|st| st.vv.as_slice()).collect();
            let _sp = trace::span(trace::Stage::AeTrain);
            for _ in 0..self.ae_inner_steps {
                self.ae.train_step(ctx.engine, &rows, None, 0, self.ae_lr, 1.0, 0.0)?;
            }
        }
        Ok(mean)
    }

    /// Leader-driven shared support for phase 3, refilled into
    /// `self.support`.
    ///
    /// PS uses a fixed leader (the worker hosting the trained encoder,
    /// §V-B1: "the weights of the learned encoder are transferred to one
    /// of the worker nodes"); RAR rotates it per iteration (§V-A).
    /// The support is broadcast in the leader's *signed-descending-value*
    /// order, so every node's gathered value-vector is a near-monotone
    /// curve (large positive -> large negative).  That smoothness is what
    /// the 1-D conv autoencoder exploits; with index-order vectors the
    /// input is position-iid heavy-tailed noise and no 4:1 learned coder
    /// can reconstruct it (rate-distortion, DESIGN.md §6.6).  The order-
    /// significant index payload is DEFLATE'd raw (encode_ordered) and
    /// byte-counted as such.
    ///
    /// EF accumulation (node-local) fans out; the leader's selection and
    /// its broadcast are the barrier and land on the global ledger.  The
    /// selection's magnitude pass and the payload encode borrow the
    /// leader's arena (§6.11).
    fn leader_support_inner(
        &mut self,
        ctx: &mut ExchangeCtx,
        grads: &[Vec<f32>],
        leader: usize,
    ) -> Result<()> {
        parallel::par_map_mut(ctx.threads, &mut self.nodes, |node, st| {
            let _lane = trace::lane_scope(node);
            let _sp = trace::span(trace::Stage::Ef);
            st.fb.accumulate(&grads[node]);
        });
        let mu = self.mu;
        let support = &mut self.support;
        let st = &mut self.nodes[leader];
        let sp_sel = trace::span(trace::Stage::TopK);
        topk::top_k_into(st.fb.memory(), mu, &mut st.scratch.mags, support, &mut st.scratch.vals);
        debug_assert_eq!(support.len(), mu);
        let mem = st.fb.memory();
        support.sort_by(|&a, &b| {
            mem[b as usize]
                .partial_cmp(&mem[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        drop(sp_sel);
        let coded = index_coding::encode_ordered_into(support, &mut st.scratch.enc)?.len();
        ctx.ledger.record(leader, Kind::Indices, coded);
        // The leader's ordered-support broadcast is its own fabric round.
        ctx.net.send(leader, coded as u64);
        ctx.net.barrier();
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Parameter-server instance
// ---------------------------------------------------------------------------

/// LGC over the parameter-server pattern (§V-B1, Algorithm 1).
pub struct LgcPs {
    c: LgcCommon,
}

impl LgcPs {
    /// Build the PS instance over `n` mid-group coordinates with a
    /// mu-length learned compressor.
    pub fn new(
        engine: &crate::runtime::Engine,
        nodes: usize,
        n: usize,
        mu: usize,
        p: LgcParams,
    ) -> Result<Self> {
        let ae = AeCompressor::new(engine, mu, nodes, Pattern::ParamServer, p.seed)?;
        Ok(LgcPs { c: LgcCommon::new(nodes, n, mu, &p, ae) })
    }

    /// The learned compressor (losses, latent sizing) for inspection.
    pub fn ae(&self) -> &AeCompressor {
        &self.c.ae
    }
}

impl MidStrategy for LgcPs {
    fn name(&self) -> &'static str {
        "lgc_ps"
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx, grads: &[Vec<f32>]) -> Result<Vec<f32>> {
        // Leaderful method: `--on-fault continue` is rejected at config
        // validation (use wait-rejoin), so the mask is all-true here.
        debug_assert!(ctx.alive.iter().all(|&a| a), "lgc_ps does not support dead nodes");
        match ctx.phase {
            Phase::Dense => {
                let mean = dense_mean_accounted(grads, &mut *ctx.shards);
                ctx.net.fanout((mean.len() * 4) as u64);
                Ok(mean)
            }
            Phase::TopK => self.c.topk_phase(ctx, grads, true),
            Phase::Compressed if !self.c.check_ae_ready() => {
                // AE not converged yet: stay on exact top-k updates and
                // keep training it (bytes counted by the top-k path).
                self.c.topk_phase(ctx, grads, true)
            }
            Phase::Compressed => {
                let n = grads[0].len();
                let nodes = grads.len();
                // Fixed leader: worker 0 hosts the trained encoder.
                let leader = 0usize;
                self.c.leader_support_inner(ctx, grads, leader)?;

                // Node-local stage: gather at the shared support, select
                // the innovation into the node's buffers, byte-account
                // (innovation + 4 B scale).  Returns each node's RMS
                // scale s_k.
                let frac = self.c.innovation_frac;
                let codec = ctx.codec;
                let s_ks = parallel::collect_node_results(parallel::par_zip_mut(
                    ctx.threads,
                    &mut self.c.nodes,
                    &mut *ctx.shards,
                    |_node, st, shard| -> Result<f32> {
                        st.fb.take_at_into(&self.c.support, &mut st.vv);
                        let bytes =
                            innovation_into(&st.vv, frac, codec, &mut st.inn, &mut st.scratch)?;
                        shard.record(Kind::Values, bytes + 4);
                        Ok(rms(&st.vv))
                    },
                ))?;

                // Leader uploads the compressed common representation
                // (latent + RMS scale).  Recorded on the leader's shard
                // so it joins the iteration's fan-in round on the fabric,
                // overlapping with the other nodes' innovation uplinks.
                let (latent, _s0) = {
                    let _lane = trace::lane_scope(leader);
                    let _sp = trace::span(trace::Stage::AeEncode);
                    self.c.ae.encode(ctx.engine, &self.c.nodes[leader].vv)?
                };
                ctx.shards[leader].record(Kind::Latent, self.c.ae.latent_bytes());

                // Master decodes per node with decoder D_c^k and the
                // node's innovation (eqs. 12-13); decodes fan out, the
                // average reduces in node order.
                let ae = &self.c.ae;
                let engine = ctx.engine;
                let node_rows = &self.c.nodes;
                let recs = parallel::collect_node_results(parallel::par_map_indexed(
                    ctx.threads,
                    nodes,
                    |node| -> Result<Vec<f32>> {
                        let _lane = trace::lane_scope(node);
                        let _sp = trace::span(trace::Stage::AeDecode);
                        ae.decode_ps(engine, node, &latent, &node_rows[node].inn, s_ks[node])
                    },
                ))?;
                let mut mean_vals = vec![0.0f32; self.c.mu];
                for rec in &recs {
                    for (m, x) in mean_vals.iter_mut().zip(rec) {
                        *m += x;
                    }
                }
                mean_vals.iter_mut().for_each(|m| *m /= nodes as f32);
                clip_to_gradient_scale(&mut mean_vals, grads);
                // Optional error feedback on the shared reconstruction
                // (see ef_on_rec; default off, per Algorithm 1).
                if ef_on_rec() {
                    let mean_ref = &mean_vals;
                    parallel::par_map_mut(ctx.threads, &mut self.c.nodes, |_node, st| {
                        let e: Vec<f32> =
                            st.vv.iter().zip(mean_ref).map(|(v, r)| v - r).collect();
                        st.fb.add_at(&self.c.support, &e);
                    });
                }
                // Fan-out: the master scatters the mu averaged
                // reconstruction values (support already broadcast).
                ctx.net.fanout((self.c.mu * 4) as u64);
                if dbg_rec() {
                    let mut true_mean = vec![0.0f32; self.c.mu];
                    for st in &self.c.nodes {
                        for (t, x) in true_mean.iter_mut().zip(&st.vv) {
                            *t += x / nodes as f32;
                        }
                    }
                    let err: f32 = mean_vals.iter().zip(&true_mean)
                        .map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
                    let nrm: f32 = true_mean.iter().map(|x| x * x).sum::<f32>().sqrt();
                    eprintln!("DBG ps rec rel_err={:.3} ||true||={:.4}", err / nrm.max(1e-9), nrm);
                }
                Ok(topk::scatter(n, &self.c.support, &mean_vals))
            }
        }
    }

    fn ae_losses(&self) -> &[(f32, f32)] {
        &self.c.ae.train_losses
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        self.c.save_state(out);
    }

    fn load_state(&mut self, r: &mut Reader) -> Result<()> {
        self.c.load_state(r)
    }
}

// ---------------------------------------------------------------------------
// Ring-allreduce instance
// ---------------------------------------------------------------------------

/// LGC over the ring-allreduce pattern (§V-B2, Algorithm 2).
pub struct LgcRar {
    c: LgcCommon,
    /// Reused per-node working copies for the dense-phase ring allreduce
    /// (replaces the per-iteration `grads.to_vec()`; §6.11).
    ring_work: Vec<Vec<f32>>,
    /// AE weights are broadcast once when phase 3 begins (§V-B2).
    weights_broadcast: bool,
}

impl LgcRar {
    /// Build the RAR instance over `n` mid-group coordinates with a
    /// mu-length learned compressor.
    pub fn new(
        engine: &crate::runtime::Engine,
        nodes: usize,
        n: usize,
        mu: usize,
        p: LgcParams,
    ) -> Result<Self> {
        let ae = AeCompressor::new(engine, mu, nodes, Pattern::RingAllreduce, p.seed)?;
        Ok(LgcRar {
            c: LgcCommon::new(nodes, n, mu, &p, ae),
            ring_work: Vec::new(),
            weights_broadcast: false,
        })
    }

    /// The learned compressor (losses, latent sizing) for inspection.
    pub fn ae(&self) -> &AeCompressor {
        &self.c.ae
    }
}

impl MidStrategy for LgcRar {
    fn name(&self) -> &'static str {
        "lgc_rar"
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx, grads: &[Vec<f32>]) -> Result<Vec<f32>> {
        // Leaderful method: `--on-fault continue` is rejected at config
        // validation (use wait-rejoin), so the mask is all-true here.
        debug_assert!(ctx.alive.iter().all(|&a| a), "lgc_rar does not support dead nodes");
        // The dense-phase working copies are only live during warmup;
        // release the K gradient-sized buffers once the phase moves on.
        if ctx.phase != Phase::Dense && !self.ring_work.is_empty() {
            self.ring_work = Vec::new();
        }
        match ctx.phase {
            Phase::Dense => {
                // Dense ring-allreduce of raw gradients, staged in the
                // persistent working copies.
                self.ring_work.resize(grads.len(), Vec::new());
                for (w, g) in self.ring_work.iter_mut().zip(grads) {
                    w.clear();
                    w.extend_from_slice(g);
                }
                Ok(ring::ring_allreduce_mean_timed(
                    &mut self.ring_work,
                    ctx.ledger,
                    Kind::Dense,
                    Some(&mut *ctx.net),
                ))
            }
            Phase::TopK => self.c.topk_phase(ctx, grads, false),
            Phase::Compressed if !self.c.check_ae_ready() => {
                self.c.topk_phase(ctx, grads, false)
            }
            Phase::Compressed => {
                let n = grads[0].len();
                let nodes = grads.len();
                if !self.weights_broadcast {
                    // One-time AE weight broadcast from the trainer node
                    // (counted in totals; excluded from per-iter rates).
                    // On the fabric it serializes K-1 unicasts on the
                    // trainer's link — a real, if one-off, time cost.
                    ctx.ledger.record_oneoff(
                        ctx.iter % nodes,
                        Kind::AeWeights,
                        self.c.ae.param_bytes() * (nodes - 1),
                    );
                    ctx.net.broadcast_oneoff(ctx.iter % nodes, self.c.ae.param_bytes() as u64);
                    self.weights_broadcast = true;
                }
                self.c.leader_support_inner(ctx, grads, ctx.iter % nodes)?;
                // Node-local stage: gather at the support into the node's
                // value-vector buffer + encode each node's value-vector on
                // its worker.  (The 4-byte scale rides inside
                // latent_bytes; the ring traffic below is measured per
                // transmission.)
                let ae = &self.c.ae;
                let engine = ctx.engine;
                let encoded = parallel::collect_node_results(parallel::par_zip_mut(
                    ctx.threads,
                    &mut self.c.nodes,
                    &mut *ctx.shards,
                    |node, st, _shard| -> Result<(Vec<f32>, f32)> {
                        let _lane = trace::lane_scope(node);
                        st.fb.take_at_into(&self.c.support, &mut st.vv);
                        let _sp = trace::span(trace::Stage::AeEncode);
                        ae.encode(engine, &st.vv)
                    },
                ))?;
                let mut latents = Vec::with_capacity(nodes);
                let mut scales = Vec::with_capacity(nodes);
                for (lat, s) in encoded {
                    latents.push(lat);
                    scales.push(s);
                }
                // Barrier: ring-allreduce the latents (eq. 19), one
                // fabric round per chunked step.
                let latent_avg = ring::ring_allreduce_mean_timed(
                    &mut latents,
                    ctx.ledger,
                    Kind::Latent,
                    Some(&mut *ctx.net),
                );
                let scale_avg = scales.iter().sum::<f32>() / nodes as f32;
                // Every node decodes the same averaged latent; compute is
                // replicated, the result identical — one decode suffices.
                let mut rec = {
                    let _sp = trace::span(trace::Stage::AeDecode);
                    self.c.ae.decode_rar(ctx.engine, &latent_avg, scale_avg)?
                };
                clip_to_gradient_scale(&mut rec, grads);
                // Optional error feedback on the shared reconstruction
                // (see ef_on_rec; default off, per Algorithm 2).
                if ef_on_rec() {
                    let rec_ref = &rec;
                    parallel::par_map_mut(ctx.threads, &mut self.c.nodes, |_node, st| {
                        let e: Vec<f32> =
                            st.vv.iter().zip(rec_ref).map(|(v, r)| v - r).collect();
                        st.fb.add_at(&self.c.support, &e);
                    });
                }
                if dbg_rec() {
                    let nrm = |v: &[f32]| v.iter().map(|x| x * x).sum::<f32>().sqrt();
                    let vbar: f32 =
                        self.c.nodes.iter().map(|st| nrm(&st.vv)).sum::<f32>() / nodes as f32;
                    eprintln!(
                        "DBG rar it={} ||rec||={:.3} ||v||~{:.3} scale_avg={:.4} mem0={:.3}",
                        ctx.iter, nrm(&rec), vbar, scale_avg,
                        nrm(self.c.nodes[0].fb.memory())
                    );
                }
                Ok(topk::scatter(n, &self.c.support, &rec))
            }
        }
    }

    fn ae_losses(&self) -> &[(f32, f32)] {
        &self.c.ae.train_losses
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        self.c.save_state(out);
        // The one-time phase-3 weight broadcast must not re-fire (and
        // re-bill) after a resume.
        ser::put_u8(out, self.weights_broadcast as u8);
    }

    fn load_state(&mut self, r: &mut Reader) -> Result<()> {
        self.c.load_state(r)?;
        self.weights_broadcast = match r.u8()? {
            0 => false,
            1 => true,
            other => anyhow::bail!("bad weights_broadcast tag {other}"),
        };
        Ok(())
    }
}
