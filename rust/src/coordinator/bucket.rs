//! Bucket plans for the pipelined execution path (DESIGN.md §13).
//!
//! A [`BucketPlan`] partitions a flat gradient group into contiguous,
//! ascending ranges ("buckets") derived from the manifest's layer
//! boundaries.  Buckets are the unit of the overlap pipeline: bucket *i*
//! encodes independently of bucket *i+1* (the per-node selection shares
//! one global top-k threshold, so the bucketed selection is bit-identical
//! to the monolithic one for *any* partition — see
//! [`crate::compress::topk::top_k_bucketed_into`]), and in `--overlap`
//! mode the exchange of bucket *i* runs while bucket *i+1* is still
//! encoding ([`crate::coordinator::scheduler::bucket_task_graph`]).
//!
//! Policy (`TrainConfig`):
//!
//! * `--buckets N`      — split the mid group into ~N buckets, cutting at
//!   the layer boundary nearest each ideal cut when one is close enough,
//!   else mid-layer (large layers are split rather than inflating a
//!   bucket to several times the target size);
//! * `--bucket-bytes B` — derive N from the group's dense byte size;
//! * neither            — one bucket, the legacy monolithic path.
//!
//! The plan is a pure function of `(group length, layer boundaries,
//! config)`, so the simulator, the TCP coordinator, and every worker
//! process derive the *same* plan independently — nothing about it is
//! ever negotiated on the wire beyond the config blob.

use std::ops::Range;

use crate::config::{Method, TrainConfig};

/// Methods whose mid-group exchange supports bucketed execution: the
/// dense baseline and the sparse-EF family, whose selections decompose
/// exactly across contiguous ranges.  ScaleCom's leader support,
/// QSGD's bucket-quantized stream, and LGC's AE latents are monolithic
/// payloads, so those methods always run a single-bucket plan
/// (DESIGN.md §13.4).
pub fn method_bucketable(m: Method) -> bool {
    matches!(
        m,
        Method::Baseline | Method::SparseGd | Method::Dgc | Method::Threshold
    )
}

/// A contiguous, ascending partition of `0..n` into buckets.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketPlan {
    ranges: Vec<Range<usize>>,
}

impl BucketPlan {
    /// The legacy plan: one bucket covering the whole group.
    pub fn single(n: usize) -> BucketPlan {
        BucketPlan { ranges: vec![0..n] }
    }

    /// Partition `0..n` into ~`buckets` ranges, snapping each ideal cut
    /// (`i * n / buckets`) to the nearest layer boundary when one lies
    /// within half a bucket of it.  `layers` are the group's contiguous
    /// per-layer ranges ([`crate::model::Model::layer_slices`]); passing
    /// an empty slice degrades to an even split.  Deterministic integer
    /// arithmetic only.
    pub fn from_layers(n: usize, layers: &[Range<usize>], buckets: usize) -> BucketPlan {
        if buckets <= 1 || n <= 1 {
            return BucketPlan::single(n);
        }
        let b = buckets.min(n);
        let target = n / b;
        let bounds: Vec<usize> =
            layers.iter().map(|r| r.end).filter(|&e| e > 0 && e < n).collect();
        let mut cuts = Vec::with_capacity(b + 1);
        cuts.push(0usize);
        for i in 1..b {
            let ideal = i * n / b;
            let diff = |e: usize| if e > ideal { e - ideal } else { ideal - e };
            let cut = bounds
                .iter()
                .copied()
                .min_by_key(|&e| diff(e))
                .filter(|&e| diff(e) * 2 <= target)
                .unwrap_or(ideal);
            if cut > *cuts.last().unwrap() && cut < n {
                cuts.push(cut);
            }
        }
        cuts.push(n);
        BucketPlan { ranges: cuts.windows(2).map(|w| w[0]..w[1]).collect() }
    }

    /// The configured plan for a group of `n` coordinates with the given
    /// layer boundaries: `--bucket-bytes` wins over `--buckets`; both
    /// default to the single-bucket legacy plan.
    pub fn for_group(n: usize, layers: &[Range<usize>], cfg: &TrainConfig) -> BucketPlan {
        let buckets = if cfg.bucket_bytes > 0 {
            ((n * 4 + cfg.bucket_bytes - 1) / cfg.bucket_bytes).max(1)
        } else {
            cfg.buckets.max(1)
        };
        BucketPlan::from_layers(n, layers, buckets)
    }

    /// Number of buckets.
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    /// True for the legacy single-bucket plan.
    pub fn is_single(&self) -> bool {
        self.ranges.len() <= 1
    }

    /// Never true — a plan always holds at least one (possibly empty)
    /// range.
    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    /// Total coordinates covered (`n`).
    pub fn total(&self) -> usize {
        self.ranges.last().map(|r| r.end).unwrap_or(0)
    }

    /// All bucket ranges, ascending and contiguous.
    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }

    /// Range of bucket `b` (panics if out of plan — wire-facing callers
    /// must go through [`BucketPlan::check_bucket`] first).
    pub fn range(&self, b: usize) -> Range<usize> {
        self.ranges[b].clone()
    }

    /// Wire-facing bounds check: a descriptive error instead of an index
    /// panic for an out-of-plan bucket id.
    pub fn check_bucket(&self, b: usize) -> anyhow::Result<Range<usize>> {
        self.ranges.get(b).cloned().ok_or_else(|| {
            anyhow::anyhow!(
                "bucket id {b} out of plan bounds (plan has {} buckets over {} coords)",
                self.ranges.len(),
                self.total()
            )
        })
    }

    /// Split an ascending global index list into per-bucket segments:
    /// fills `splits` with cumulative offsets (`len() + 1` entries,
    /// leading 0), so bucket `b`'s entries are `idx[splits[b]..splits[b+1]]`.
    pub fn splits_of(&self, idx: &[u32], splits: &mut Vec<usize>) {
        splits.clear();
        splits.push(0);
        let mut pos = 0usize;
        for r in &self.ranges {
            while pos < idx.len() && (idx[pos] as usize) < r.end {
                pos += 1;
            }
            splits.push(pos);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiles(plan: &BucketPlan, n: usize) {
        let rs = plan.ranges();
        assert_eq!(rs.first().unwrap().start, 0);
        assert_eq!(rs.last().unwrap().end, n);
        for w in rs.windows(2) {
            assert_eq!(w[0].end, w[1].start, "{rs:?}");
        }
    }

    #[test]
    fn single_covers_everything() {
        let p = BucketPlan::single(10);
        assert!(p.is_single());
        assert_eq!(p.total(), 10);
        tiles(&p, 10);
    }

    #[test]
    fn even_split_without_layers() {
        let p = BucketPlan::from_layers(100, &[], 4);
        assert_eq!(p.len(), 4);
        tiles(&p, 100);
        assert_eq!(p.ranges(), &[0..25, 25..50, 50..75, 75..100]);
    }

    #[test]
    fn cuts_snap_to_nearby_layer_boundaries() {
        // Layers end at 24, 52, 75; ideal cuts 25/50/75 all snap.
        let layers = vec![0..24, 24..52, 52..75, 75..100];
        let p = BucketPlan::from_layers(100, &layers, 4);
        assert_eq!(p.ranges(), &[0..24, 24..52, 52..75, 75..100]);
    }

    #[test]
    fn oversized_layer_is_split_mid_layer() {
        // One huge layer: no boundary near the ideal cuts, so they stay
        // at the even positions instead of collapsing buckets.
        let layers = vec![0..97, 97..100];
        let p = BucketPlan::from_layers(100, &layers, 4);
        assert_eq!(p.len(), 4);
        tiles(&p, 100);
        assert_eq!(p.ranges()[0], 0..25);
    }

    #[test]
    fn buckets_clamp_to_len_and_degenerate_inputs() {
        assert_eq!(BucketPlan::from_layers(3, &[], 8).len(), 3);
        assert!(BucketPlan::from_layers(0, &[], 8).is_single());
        assert!(BucketPlan::from_layers(50, &[], 1).is_single());
        assert!(BucketPlan::from_layers(1, &[], 5).is_single());
    }

    #[test]
    fn bucket_bytes_policy_derives_count() {
        let cfg = TrainConfig { bucket_bytes: 100, ..Default::default() };
        // 100 coords * 4 B = 400 B => 4 buckets of <= 100 B.
        let p = BucketPlan::for_group(100, &[], &cfg);
        assert_eq!(p.len(), 4);
        let cfg = TrainConfig { buckets: 5, ..Default::default() };
        assert_eq!(BucketPlan::for_group(100, &[], &cfg).len(), 5);
        let cfg = TrainConfig::default();
        assert!(BucketPlan::for_group(100, &[], &cfg).is_single());
    }

    #[test]
    fn check_bucket_rejects_out_of_plan_ids() {
        let p = BucketPlan::from_layers(10, &[], 2);
        assert!(p.check_bucket(1).is_ok());
        let err = p.check_bucket(7).unwrap_err().to_string();
        assert!(err.contains("bucket id 7"), "{err}");
    }

    #[test]
    fn splits_partition_ascending_indices() {
        let p = BucketPlan::from_layers(10, &[], 3); // 0..3, 3..6, 6..10
        let mut splits = Vec::new();
        p.splits_of(&[0, 2, 5, 6, 9], &mut splits);
        assert_eq!(splits, vec![0, 2, 3, 5]);
        p.splits_of(&[], &mut splits);
        assert_eq!(splits, vec![0, 0, 0, 0]);
        p.splits_of(&[7, 8], &mut splits);
        assert_eq!(splits, vec![0, 0, 0, 2]);
    }

    #[test]
    fn bucketable_methods_are_the_sparse_ef_family_plus_dense() {
        assert!(method_bucketable(Method::Baseline));
        assert!(method_bucketable(Method::SparseGd));
        assert!(method_bucketable(Method::Dgc));
        assert!(method_bucketable(Method::Threshold));
        assert!(!method_bucketable(Method::ScaleCom));
        assert!(!method_bucketable(Method::Qsgd));
        assert!(!method_bucketable(Method::LgcPs));
        assert!(!method_bucketable(Method::LgcRar));
    }
}
