//! Parallel node runtime: fan per-node work out over scoped threads.
//!
//! The coordinator simulates K synchronous data-parallel nodes.  All
//! *node-local* work of an iteration — grad-shard compute, error-feedback
//! updates, top-k selection, payload encoding — is independent across
//! nodes by construction, so it fans out here; the *exchange* steps (PS
//! gather, ring reduce-scatter/allgather, leader broadcasts) remain
//! sequential barriers in the caller (DESIGN.md §6.5).  This module holds
//! no per-iteration ordering of its own: which encode/exchange runs when
//! is owned solely by [`crate::coordinator::scheduler::bucket_task_graph`]
//! and [`crate::coordinator::scheduler::close_iteration`] (DESIGN.md §13).
//!
//! Determinism contract: every helper returns results indexed by node,
//! each node's closure sees only that node's `&mut` state (enforced by
//! the borrow checker via slice splitting), and callers reduce the
//! returned per-node values in node order.  Thread count therefore
//! affects wall-clock only — never a single output bit.  This is what
//! makes "ledger totals bit-identical between 1-thread and N-thread
//! runs" a structural property rather than a hope.
//!
//! Implementation: `std::thread::scope` + contiguous chunking (no rayon
//! in the offline crate set).  K is small (2..64), so one spawn per chunk
//! per iteration is noise next to a grad step.

use std::num::NonZeroUsize;

/// Resolve a requested thread count: 0 = one per available core, always
/// clamped to `[1, tasks]`.
pub fn effective_threads(requested: usize, tasks: usize) -> usize {
    let hw = std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1);
    let t = if requested == 0 { hw } else { requested };
    t.clamp(1, tasks.max(1))
}

/// Run `f(node)` for `node in 0..tasks` across `threads` workers and
/// return the results in node order.
pub fn par_map_indexed<R, F>(threads: usize, tasks: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let t = effective_threads(threads, tasks);
    if t <= 1 || tasks <= 1 {
        return (0..tasks).map(f).collect();
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(tasks);
    out.resize_with(tasks, || None);
    let chunk = tasks.div_ceil(t);
    std::thread::scope(|scope| {
        let f = &f;
        let mut slots: &mut [Option<R>] = &mut out;
        let mut base = 0usize;
        while !slots.is_empty() {
            let len = chunk.min(slots.len());
            let (head, tail) = std::mem::take(&mut slots).split_at_mut(len);
            slots = tail;
            let start = base;
            base += len;
            scope.spawn(move || {
                for (j, slot) in head.iter_mut().enumerate() {
                    *slot = Some(f(start + j));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker filled every slot")).collect()
}

/// Run `f(node, &mut a[node])` for every element of `a` across `threads`
/// workers; results in node order.  Each worker owns a disjoint chunk of
/// `a`, so the closure is lock-free on the per-node state.
///
/// Delegates to [`par_zip3_mut`] with zero-sized dummy lanes (a `Vec<()>`
/// never allocates), so the chunk/split/spawn machinery exists once.
pub fn par_map_mut<A, R, F>(threads: usize, a: &mut [A], f: F) -> Vec<R>
where
    A: Send,
    R: Send,
    F: Fn(usize, &mut A) -> R + Sync,
{
    let mut dummy_b = vec![(); a.len()];
    let mut dummy_c = vec![(); a.len()];
    par_zip3_mut(threads, a, &mut dummy_b, &mut dummy_c, |i, x, _, _| f(i, x))
}

/// Run `f(node, &mut a[node], &mut b[node])` across `threads` workers;
/// results in node order.  `a` and `b` must be the same length — the
/// typical pairing is (per-node feedback memory, per-node ledger shard).
pub fn par_zip_mut<A, B, R, F>(threads: usize, a: &mut [A], b: &mut [B], f: F) -> Vec<R>
where
    A: Send,
    B: Send,
    R: Send,
    F: Fn(usize, &mut A, &mut B) -> R + Sync,
{
    assert_eq!(a.len(), b.len(), "par_zip_mut: slice lengths differ");
    let mut dummy_c = vec![(); a.len()];
    par_zip3_mut(threads, a, b, &mut dummy_c, |i, x, y, _| f(i, x, y))
}

/// Run `f(node, &mut a[node], &mut b[node], &mut c[node])` across
/// `threads` workers; results in node order.  All three slices must be
/// the same length — the typical triple is (per-node feedback memory,
/// per-node ledger shard, per-node scratch arena; DESIGN.md §6.11).
pub fn par_zip3_mut<A, B, C, R, F>(
    threads: usize,
    a: &mut [A],
    b: &mut [B],
    c: &mut [C],
    f: F,
) -> Vec<R>
where
    A: Send,
    B: Send,
    C: Send,
    R: Send,
    F: Fn(usize, &mut A, &mut B, &mut C) -> R + Sync,
{
    assert_eq!(a.len(), b.len(), "par_zip3_mut: slice lengths differ");
    assert_eq!(a.len(), c.len(), "par_zip3_mut: slice lengths differ");
    let tasks = a.len();
    let t = effective_threads(threads, tasks);
    if t <= 1 || tasks <= 1 {
        let mut out = Vec::with_capacity(tasks);
        for (i, ((x, y), z)) in a.iter_mut().zip(b.iter_mut()).zip(c.iter_mut()).enumerate() {
            out.push(f(i, x, y, z));
        }
        return out;
    }
    let mut out: Vec<Option<R>> = Vec::with_capacity(tasks);
    out.resize_with(tasks, || None);
    let chunk = tasks.div_ceil(t);
    std::thread::scope(|scope| {
        let f = &f;
        let mut a_rest: &mut [A] = a;
        let mut b_rest: &mut [B] = b;
        let mut c_rest: &mut [C] = c;
        let mut slots: &mut [Option<R>] = &mut out;
        let mut base = 0usize;
        while !a_rest.is_empty() {
            let len = chunk.min(a_rest.len());
            let (ahead, atail) = std::mem::take(&mut a_rest).split_at_mut(len);
            let (bhead, btail) = std::mem::take(&mut b_rest).split_at_mut(len);
            let (chead, ctail) = std::mem::take(&mut c_rest).split_at_mut(len);
            let (shead, stail) = std::mem::take(&mut slots).split_at_mut(len);
            a_rest = atail;
            b_rest = btail;
            c_rest = ctail;
            slots = stail;
            let start = base;
            base += len;
            scope.spawn(move || {
                for (j, (((x, y), z), slot)) in ahead
                    .iter_mut()
                    .zip(bhead.iter_mut())
                    .zip(chead.iter_mut())
                    .zip(shead.iter_mut())
                    .enumerate()
                {
                    *slot = Some(f(start + j, x, y, z));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker filled every slot")).collect()
}

/// Collect a vector of per-node fallible results into `Result<Vec<_>>`,
/// surfacing the lowest-node error (deterministic regardless of which
/// thread failed first).
pub fn collect_node_results<T>(results: Vec<anyhow::Result<T>>) -> anyhow::Result<Vec<T>> {
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexed_results_in_order() {
        for threads in [1, 2, 3, 8] {
            let got = par_map_indexed(threads, 17, |i| i * i);
            let want: Vec<usize> = (0..17).map(|i| i * i).collect();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn map_mut_touches_every_element_once() {
        for threads in [1, 2, 5] {
            let mut v = vec![0u64; 23];
            let r = par_map_mut(threads, &mut v, |i, x| {
                *x += 1;
                i as u64
            });
            assert!(v.iter().all(|&x| x == 1), "threads={threads}");
            assert_eq!(r, (0..23).map(|i| i as u64).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zip_mut_pairs_by_index() {
        for threads in [1, 4] {
            let mut a: Vec<usize> = (0..11).collect();
            let mut b = vec![0usize; 11];
            let r = par_zip_mut(threads, &mut a, &mut b, |i, x, y| {
                *y = *x * 2;
                assert_eq!(*x, i);
                *y
            });
            assert_eq!(r, (0..11).map(|i| i * 2).collect::<Vec<_>>());
            assert_eq!(b, (0..11).map(|i| i * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn zip3_mut_pairs_by_index() {
        for threads in [1, 3, 8] {
            let mut a: Vec<usize> = (0..13).collect();
            let mut b = vec![0usize; 13];
            let mut c = vec![100usize; 13];
            let r = par_zip3_mut(threads, &mut a, &mut b, &mut c, |i, x, y, z| {
                assert_eq!(*x, i);
                *y = *x * 3;
                *z += i;
                *y
            });
            assert_eq!(r, (0..13).map(|i| i * 3).collect::<Vec<_>>());
            assert_eq!(b, (0..13).map(|i| i * 3).collect::<Vec<_>>());
            assert_eq!(c, (0..13).map(|i| 100 + i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn thread_count_never_changes_results() {
        // The determinism contract, at the helper level: any thread count
        // produces bitwise-identical outputs.
        let baseline = par_map_indexed(1, 64, |i| {
            let mut rng = crate::util::rng::Rng::new(i as u64);
            rng.normal_vec(50, 1.0)
        });
        for threads in [2, 3, 7, 16] {
            let got = par_map_indexed(threads, 64, |i| {
                let mut rng = crate::util::rng::Rng::new(i as u64);
                rng.normal_vec(50, 1.0)
            });
            assert_eq!(got, baseline, "threads={threads}");
        }
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(4, 2), 2);
        assert_eq!(effective_threads(1, 100), 1);
        assert!(effective_threads(0, 100) >= 1);
        assert_eq!(effective_threads(3, 0), 1);
    }

    #[test]
    fn empty_and_single_inputs() {
        assert!(par_map_indexed(4, 0, |i| i).is_empty());
        let mut one = vec![7u32];
        let r = par_map_mut(4, &mut one, |_, x| {
            *x += 1;
            *x
        });
        assert_eq!(r, vec![8]);
    }
}
