//! Ring-allreduce protocol (paper §II-A, Fig. 2) — an actual chunked
//! implementation, not a cost formula.
//!
//! K nodes each hold a vector; the vector is split into K chunks. K-1
//! reduce-scatter steps (each node sends one chunk to its successor, which
//! accumulates) leave node i holding the fully-reduced chunk (i+1) mod K;
//! K-1 allgather steps circulate the reduced chunks.  Every transmission
//! is byte-accounted against the sending node, so the well-known
//! 2(K-1)/K * size bound is *measured* by the tests rather than assumed.

use crate::metrics::{Kind, Ledger};
use crate::net::NetSim;

/// Chunk boundaries: near-equal split of `n` into `k` chunks.
fn chunks(n: usize, k: usize) -> Vec<std::ops::Range<usize>> {
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut off = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push(off..off + len);
        off += len;
    }
    out
}

/// In-place ring allreduce (sum) over `vectors` (one per node).
/// Returns the reduced sum (identical at every node afterwards).
pub fn ring_allreduce_sum(
    vectors: &mut [Vec<f32>],
    ledger: &mut Ledger,
    kind: Kind,
) -> Vec<f32> {
    ring_allreduce_sum_timed(vectors, ledger, kind, None)
}

/// [`ring_allreduce_sum`] that additionally emits one network round per
/// chunked step into `net` — the `2 * (K - 1)` step structure the fabric
/// prices (DESIGN.md §11).  Callers must close any pending sends with a
/// barrier first, so the ring steps are rounds of their own.
pub fn ring_allreduce_sum_timed(
    vectors: &mut [Vec<f32>],
    ledger: &mut Ledger,
    kind: Kind,
    mut net: Option<&mut NetSim>,
) -> Vec<f32> {
    let k = vectors.len();
    assert!(k >= 1);
    let n = vectors[0].len();
    assert!(vectors.iter().all(|v| v.len() == n));
    if k == 1 {
        return vectors[0].clone();
    }
    let ch = chunks(n, k);

    // Reduce-scatter: at step s, node i sends chunk (i - s) mod k.
    for s in 0..k - 1 {
        // Snapshot the outgoing chunks first (simultaneous exchange).
        let outgoing: Vec<(usize, Vec<f32>)> = (0..k)
            .map(|i| {
                let c = (i + k - s) % k;
                (c, vectors[i][ch[c].clone()].to_vec())
            })
            .collect();
        for (i, (c, data)) in outgoing.into_iter().enumerate() {
            let dst = (i + 1) % k;
            ledger.record(i, kind, data.len() * 4);
            // Empty chunks (k > n) are never transmitted: no latency term.
            match net.as_deref_mut() {
                Some(net) if !data.is_empty() => net.send(i, (data.len() * 4) as u64),
                _ => {}
            }
            let slot = &mut vectors[dst][ch[c].clone()];
            for (d, v) in slot.iter_mut().zip(&data) {
                *d += v;
            }
        }
        if let Some(net) = net.as_deref_mut() {
            net.barrier();
        }
    }
    // After reduce-scatter, node i holds the full sum of chunk (i+1) mod k.
    // Allgather: circulate the reduced chunks.
    for s in 0..k - 1 {
        let outgoing: Vec<(usize, Vec<f32>)> = (0..k)
            .map(|i| {
                let c = (i + 1 + k - s) % k;
                (c, vectors[i][ch[c].clone()].to_vec())
            })
            .collect();
        for (i, (c, data)) in outgoing.into_iter().enumerate() {
            let dst = (i + 1) % k;
            ledger.record(i, kind, data.len() * 4);
            // Empty chunks (k > n) are never transmitted: no latency term.
            match net.as_deref_mut() {
                Some(net) if !data.is_empty() => net.send(i, (data.len() * 4) as u64),
                _ => {}
            }
            vectors[dst][ch[c].clone()].copy_from_slice(&data);
        }
        if let Some(net) = net.as_deref_mut() {
            net.barrier();
        }
    }
    vectors[0].clone()
}

/// Ring allreduce returning the *mean* (the aggregation every method wants).
pub fn ring_allreduce_mean(
    vectors: &mut [Vec<f32>],
    ledger: &mut Ledger,
    kind: Kind,
) -> Vec<f32> {
    ring_allreduce_mean_timed(vectors, ledger, kind, None)
}

/// [`ring_allreduce_mean`] with the per-step network rounds of
/// [`ring_allreduce_sum_timed`].
pub fn ring_allreduce_mean_timed(
    vectors: &mut [Vec<f32>],
    ledger: &mut Ledger,
    kind: Kind,
    net: Option<&mut NetSim>,
) -> Vec<f32> {
    let k = vectors.len() as f32;
    let mut sum = ring_allreduce_sum_timed(vectors, ledger, kind, net);
    for v in &mut sum {
        *v /= k;
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn allreduce_matches_direct_sum() {
        let mut rng = Rng::new(1);
        for k in [1usize, 2, 3, 4, 8] {
            for n in [1usize, 5, 16, 103] {
                if n < k {
                    continue;
                }
                let vecs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(n, 1.0)).collect();
                let want: Vec<f32> = (0..n)
                    .map(|j| vecs.iter().map(|v| v[j]).sum::<f32>())
                    .collect();
                let mut work = vecs.clone();
                let mut ledger = Ledger::new();
                let got = ring_allreduce_sum(&mut work, &mut ledger, crate::metrics::Kind::Dense);
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "k={k} n={n}");
                }
                // Every node converged to the same vector.
                for v in &work {
                    for (a, b) in v.iter().zip(&got) {
                        assert!((a - b).abs() < 1e-5);
                    }
                }
            }
        }
    }

    #[test]
    fn bytes_match_2k_minus_1_over_k_bound() {
        let k = 4;
        let n = 1000;
        let mut rng = Rng::new(2);
        let mut vecs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(n, 1.0)).collect();
        let mut ledger = Ledger::new();
        ring_allreduce_sum(&mut vecs, &mut ledger, crate::metrics::Kind::Dense);
        let per_node = ledger.per_node[&0] as f64;
        let expected = 2.0 * (k as f64 - 1.0) / k as f64 * (n * 4) as f64;
        assert!(
            (per_node - expected).abs() / expected < 0.02,
            "per_node={per_node} expected={expected}"
        );
    }

    #[test]
    fn single_node_sends_nothing() {
        let mut vecs = vec![vec![1.0f32, 2.0]];
        let mut ledger = Ledger::new();
        let out = ring_allreduce_sum(&mut vecs, &mut ledger, crate::metrics::Kind::Dense);
        assert_eq!(out, vec![1.0, 2.0]);
        assert_eq!(ledger.total(), 0);
    }

    #[test]
    fn mean_divides_by_k() {
        let mut vecs = vec![vec![2.0f32; 8], vec![4.0f32; 8]];
        let mut ledger = Ledger::new();
        let out = ring_allreduce_mean(&mut vecs, &mut ledger, crate::metrics::Kind::Dense);
        assert!(out.iter().all(|&x| (x - 3.0).abs() < 1e-6));
    }

    #[test]
    fn timed_ring_trace_matches_closed_form_oracle() {
        use crate::net::topology::ring_allreduce_s;
        use crate::net::{Fabric, LinkModel, NetSim};
        let link = LinkModel::from_mbits(80.0, 1e-4); // 10 MB/s
        for k in [2usize, 3, 4, 8] {
            for n in [1000usize, 1001, 4096] {
                let mut vecs: Vec<Vec<f32>> = (0..k).map(|_| vec![1.0; n]).collect();
                let mut ledger = Ledger::new();
                let mut net = NetSim::new(Fabric::new(link, Vec::new()), k);
                ring_allreduce_sum_timed(
                    &mut vecs,
                    &mut ledger,
                    Kind::Dense,
                    Some(&mut net),
                );
                net.end_iteration();
                let report = net.into_report();
                // 2(K-1) rounds, one per chunked step.
                assert_eq!(report.trace[0].len(), 2 * (k - 1), "k={k} n={n}");
                let got = report.iter_comm_s()[0];
                // Element-level oracle: every step is paced by the
                // largest chunk, ceil(n/k) f32 elements.
                let chunk_bytes = (n.div_ceil(k) * 4) as u64;
                let want = 2.0 * (k - 1) as f64 * link.transfer_s(1, chunk_bytes);
                assert!(
                    (got - want).abs() < 1e-12 * want.max(1.0),
                    "k={k} n={n}: {got} vs {want}"
                );
                // For k | n the byte-level closed form agrees exactly.
                if n % k == 0 {
                    let cf = ring_allreduce_s(&link, (n * 4) as u64, k);
                    assert!((got - cf).abs() < 1e-12 * cf.max(1.0), "k={k} n={n}");
                }
                // The trace carries exactly the ledger's measured bytes.
                assert_eq!(report.total_bytes(), ledger.total());
            }
        }
    }

    #[test]
    fn timed_ring_straggler_paces_every_step() {
        use crate::net::{Fabric, LinkModel, NetSim};
        let link = LinkModel::from_mbits(80.0, 0.0);
        let k = 4;
        let n = 1000; // 4 | 1000: uniform 250-element (1000-byte) chunks
        let run = |mult: f64| {
            let mut vecs: Vec<Vec<f32>> = (0..k).map(|_| vec![1.0; n]).collect();
            let mut ledger = Ledger::new();
            let stragglers = vec![1.0, 1.0, mult, 1.0];
            let mut net = NetSim::new(Fabric::new(link, stragglers), k);
            ring_allreduce_sum_timed(&mut vecs, &mut ledger, Kind::Dense, Some(&mut net));
            net.end_iteration();
            net.into_report().iter_comm_s()[0]
        };
        // Every one of the 2(K-1) steps includes the straggler's link, so
        // total time scales exactly with the multiplier.
        let base = run(1.0);
        let slow = run(3.0);
        assert!((slow - 3.0 * base).abs() < 1e-12, "{slow} vs 3x{base}");
    }

    #[test]
    fn chunks_partition() {
        let ch = chunks(10, 3);
        assert_eq!(ch, vec![0..4, 4..7, 7..10]);
        let ch = chunks(3, 8); // more nodes than elements: empty chunks ok
        assert_eq!(ch.iter().map(|r| r.len()).sum::<usize>(), 3);
    }
}
