//! Three-phase training schedule (paper §V-B) + sparsification-strategy
//! ablation (§VI-F, Fig. 13).
//!
//! Phase 1 (dense):      weights update with original gradients (eq. 14)
//! Phase 2 (top-k):      top-k updates while the autoencoder trains (eq. 15)
//! Phase 3 (compressed): updates with autoencoder reconstructions (eq. 16)
//!
//! The ablation schedules reproduce Fig. 13's comparison:
//! * Warmup      — LGC's choice: dense first, then fixed alpha
//! * Fixed       — fixed alpha from iteration 0 (Sparse GD / QSGD / ScaleCom)
//! * Exponential — DGC's ramp: keep-fraction decays 25% -> alpha over the
//!                 ramp window, then stays at alpha
//!
//! This module is also the single owner of **per-iteration ordering**
//! (DESIGN.md §13): [`bucket_task_graph`] fixes the encode/exchange
//! interleaving every execution path follows — the in-process trainer,
//! the sim strategies, and the TCP coordinator's replay — and
//! [`close_iteration`] is the one close-out sequence (shard fan-in round,
//! ledger merge, iteration boundaries) that both the sim trainer and
//! `remote.rs` run, so the two paths cannot drift apart.

use crate::config::{SparsifySchedule, TrainConfig};
use crate::metrics::{Ledger, NodeLedger};
use crate::net::NetSim;

/// One node-side unit of the per-iteration pipeline over bucket `usize`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepTask {
    /// Select/encode bucket *b*'s packet (EF accumulate happened before
    /// the graph starts; selection shares one global threshold, so encode
    /// order never changes the selection — DESIGN.md §13.2).
    Encode(usize),
    /// Exchange bucket *b*'s packets (fan-in + aggregate fan-out).
    Exchange(usize),
}

/// The per-iteration task graph over `buckets` buckets, linearized in
/// dependency order (DESIGN.md §13.1).
///
/// * `overlap == false`: all encodes, then all exchanges — the legacy
///   barrier schedule, bit-identical to the unbucketed path.
/// * `overlap == true`: the exchange of bucket *i* is issued directly
///   after the encode of bucket *i + 1*, i.e. it overlaps that encode in
///   the priced schedule ([`crate::net::NetReport::pipelined_iter_s_under`])
///   and on the wire (workers stream bucket *i* while selecting
///   *i + 1*).
///
/// ```
/// use lgc::coordinator::scheduler::{bucket_task_graph, StepTask::*};
/// assert_eq!(bucket_task_graph(2, false), vec![Encode(0), Encode(1), Exchange(0), Exchange(1)]);
/// assert_eq!(bucket_task_graph(3, true), vec![Encode(0), Encode(1), Exchange(0), Encode(2), Exchange(1), Exchange(2)]);
/// ```
pub fn bucket_task_graph(buckets: usize, overlap: bool) -> Vec<StepTask> {
    let b = buckets.max(1);
    let mut tasks = Vec::with_capacity(2 * b);
    if overlap {
        tasks.push(StepTask::Encode(0));
        for i in 1..b {
            tasks.push(StepTask::Encode(i));
            tasks.push(StepTask::Exchange(i - 1));
        }
        tasks.push(StepTask::Exchange(b - 1));
    } else {
        for i in 0..b {
            tasks.push(StepTask::Encode(i));
        }
        for i in 0..b {
            tasks.push(StepTask::Exchange(i));
        }
    }
    tasks
}

/// Close one training iteration — the single owner of the close-out
/// sequence shared by the sim trainer and the TCP coordinator's replay:
/// flush one-off shard traffic as its own setup round, feed the
/// recurring per-node shard payloads into the iteration's fan-in round,
/// then advance the network trace and the byte ledger in lockstep.
/// Merging walks shards in ascending node order (§6.5), which is what
/// keeps ledgers and traces bit-identical for any `--threads`.
pub fn close_iteration(ledger: &mut Ledger, shards: &mut [NodeLedger], net: &mut NetSim) {
    for shard in shards.iter() {
        let (msgs, bytes) = shard.pending_oneoff();
        if msgs > 0 {
            net.send_many(shard.node(), msgs, bytes);
        }
    }
    net.barrier_oneoff();
    for shard in shards.iter() {
        let (msgs, bytes) = shard.pending_recurring();
        if msgs > 0 {
            net.send_many(shard.node(), msgs, bytes);
        }
    }
    net.end_iteration();
    ledger.merge_shards(shards);
    ledger.end_iteration();
}

/// The three training phases of §V-B (eqs. 14-16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Dense,
    TopK,
    Compressed,
}

impl Phase {
    /// Zero-based phase index (ledger phases are `index() + 1`).
    pub fn index(self) -> usize {
        match self {
            Phase::Dense => 0,
            Phase::TopK => 1,
            Phase::Compressed => 2,
        }
    }

    /// Lower-case phase name for logs and CSV cells.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Dense => "dense",
            Phase::TopK => "topk",
            Phase::Compressed => "compressed",
        }
    }
}

/// DGC's exponential keep-fraction ramp: 0.25 -> alpha over `ramp` iters.
pub fn exponential_alpha(it: usize, ramp: usize, alpha: f64) -> f64 {
    if it >= ramp || ramp == 0 {
        return alpha;
    }
    let t = (it + 1) as f64 / ramp as f64;
    0.25 * (alpha / 0.25_f64).powf(t)
}

/// The LGC phase + keep-fraction for iteration `it`.
pub fn phase_and_alpha(cfg: &TrainConfig, it: usize) -> (Phase, f64) {
    match cfg.schedule {
        SparsifySchedule::Warmup => {
            if it < cfg.warmup_iters {
                (Phase::Dense, 1.0)
            } else if it < cfg.warmup_iters + cfg.ae_train_iters {
                (Phase::TopK, cfg.alpha)
            } else {
                (Phase::Compressed, cfg.alpha)
            }
        }
        SparsifySchedule::Fixed => {
            if it < cfg.ae_train_iters {
                (Phase::TopK, cfg.alpha)
            } else {
                (Phase::Compressed, cfg.alpha)
            }
        }
        SparsifySchedule::Exponential => {
            let ramp = cfg.warmup_iters + cfg.ae_train_iters;
            if it < ramp {
                (Phase::TopK, exponential_alpha(it, ramp, cfg.alpha))
            } else {
                (Phase::Compressed, cfg.alpha)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn cfg(schedule: SparsifySchedule) -> TrainConfig {
        TrainConfig {
            warmup_iters: 10,
            ae_train_iters: 20,
            alpha: 1e-3,
            schedule,
            ..Default::default()
        }
    }

    #[test]
    fn warmup_schedule_phases() {
        let c = cfg(SparsifySchedule::Warmup);
        assert_eq!(phase_and_alpha(&c, 0), (Phase::Dense, 1.0));
        assert_eq!(phase_and_alpha(&c, 9), (Phase::Dense, 1.0));
        assert_eq!(phase_and_alpha(&c, 10), (Phase::TopK, 1e-3));
        assert_eq!(phase_and_alpha(&c, 29), (Phase::TopK, 1e-3));
        assert_eq!(phase_and_alpha(&c, 30), (Phase::Compressed, 1e-3));
    }

    #[test]
    fn fixed_schedule_sparsifies_immediately() {
        let c = cfg(SparsifySchedule::Fixed);
        let (p, a) = phase_and_alpha(&c, 0);
        assert_eq!(p, Phase::TopK);
        assert_eq!(a, 1e-3);
        assert_eq!(phase_and_alpha(&c, 20).0, Phase::Compressed);
    }

    #[test]
    fn exponential_ramp_monotone_decreasing() {
        let c = cfg(SparsifySchedule::Exponential);
        let mut prev = 1.0;
        for it in 0..30 {
            let (p, a) = phase_and_alpha(&c, it);
            assert_eq!(p, Phase::TopK);
            assert!(a <= prev + 1e-12, "alpha must ramp down");
            assert!(a >= 1e-3 && a <= 0.25);
            prev = a;
        }
        assert_eq!(phase_and_alpha(&c, 30), (Phase::Compressed, 1e-3));
    }

    #[test]
    fn exponential_alpha_endpoints() {
        assert!((exponential_alpha(99, 100, 1e-3) - 1e-3).abs() < 1e-9);
        assert!(exponential_alpha(0, 100, 1e-3) < 0.25);
        assert_eq!(exponential_alpha(5, 0, 1e-3), 1e-3);
    }
}
