//! Three-phase training schedule (paper §V-B) + sparsification-strategy
//! ablation (§VI-F, Fig. 13).
//!
//! Phase 1 (dense):      weights update with original gradients (eq. 14)
//! Phase 2 (top-k):      top-k updates while the autoencoder trains (eq. 15)
//! Phase 3 (compressed): updates with autoencoder reconstructions (eq. 16)
//!
//! The ablation schedules reproduce Fig. 13's comparison:
//! * Warmup      — LGC's choice: dense first, then fixed alpha
//! * Fixed       — fixed alpha from iteration 0 (Sparse GD / QSGD / ScaleCom)
//! * Exponential — DGC's ramp: keep-fraction decays 25% -> alpha over the
//!                 ramp window, then stays at alpha

use crate::config::{SparsifySchedule, TrainConfig};

/// The three training phases of §V-B (eqs. 14-16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Dense,
    TopK,
    Compressed,
}

impl Phase {
    /// Zero-based phase index (ledger phases are `index() + 1`).
    pub fn index(self) -> usize {
        match self {
            Phase::Dense => 0,
            Phase::TopK => 1,
            Phase::Compressed => 2,
        }
    }

    /// Lower-case phase name for logs and CSV cells.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Dense => "dense",
            Phase::TopK => "topk",
            Phase::Compressed => "compressed",
        }
    }
}

/// DGC's exponential keep-fraction ramp: 0.25 -> alpha over `ramp` iters.
pub fn exponential_alpha(it: usize, ramp: usize, alpha: f64) -> f64 {
    if it >= ramp || ramp == 0 {
        return alpha;
    }
    let t = (it + 1) as f64 / ramp as f64;
    0.25 * (alpha / 0.25_f64).powf(t)
}

/// The LGC phase + keep-fraction for iteration `it`.
pub fn phase_and_alpha(cfg: &TrainConfig, it: usize) -> (Phase, f64) {
    match cfg.schedule {
        SparsifySchedule::Warmup => {
            if it < cfg.warmup_iters {
                (Phase::Dense, 1.0)
            } else if it < cfg.warmup_iters + cfg.ae_train_iters {
                (Phase::TopK, cfg.alpha)
            } else {
                (Phase::Compressed, cfg.alpha)
            }
        }
        SparsifySchedule::Fixed => {
            if it < cfg.ae_train_iters {
                (Phase::TopK, cfg.alpha)
            } else {
                (Phase::Compressed, cfg.alpha)
            }
        }
        SparsifySchedule::Exponential => {
            let ramp = cfg.warmup_iters + cfg.ae_train_iters;
            if it < ramp {
                (Phase::TopK, exponential_alpha(it, ramp, cfg.alpha))
            } else {
                (Phase::Compressed, cfg.alpha)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;

    fn cfg(schedule: SparsifySchedule) -> TrainConfig {
        TrainConfig {
            warmup_iters: 10,
            ae_train_iters: 20,
            alpha: 1e-3,
            schedule,
            ..Default::default()
        }
    }

    #[test]
    fn warmup_schedule_phases() {
        let c = cfg(SparsifySchedule::Warmup);
        assert_eq!(phase_and_alpha(&c, 0), (Phase::Dense, 1.0));
        assert_eq!(phase_and_alpha(&c, 9), (Phase::Dense, 1.0));
        assert_eq!(phase_and_alpha(&c, 10), (Phase::TopK, 1e-3));
        assert_eq!(phase_and_alpha(&c, 29), (Phase::TopK, 1e-3));
        assert_eq!(phase_and_alpha(&c, 30), (Phase::Compressed, 1e-3));
    }

    #[test]
    fn fixed_schedule_sparsifies_immediately() {
        let c = cfg(SparsifySchedule::Fixed);
        let (p, a) = phase_and_alpha(&c, 0);
        assert_eq!(p, Phase::TopK);
        assert_eq!(a, 1e-3);
        assert_eq!(phase_and_alpha(&c, 20).0, Phase::Compressed);
    }

    #[test]
    fn exponential_ramp_monotone_decreasing() {
        let c = cfg(SparsifySchedule::Exponential);
        let mut prev = 1.0;
        for it in 0..30 {
            let (p, a) = phase_and_alpha(&c, it);
            assert_eq!(p, Phase::TopK);
            assert!(a <= prev + 1e-12, "alpha must ramp down");
            assert!(a >= 1e-3 && a <= 0.25);
            prev = a;
        }
        assert_eq!(phase_and_alpha(&c, 30), (Phase::Compressed, 1e-3));
    }

    #[test]
    fn exponential_alpha_endpoints() {
        assert!((exponential_alpha(99, 100, 1e-3) - 1e-3).abs() < 1e-9);
        assert!(exponential_alpha(0, 100, 1e-3) < 0.25);
        assert_eq!(exponential_alpha(5, 0, 1e-3), 1e-3);
    }
}
