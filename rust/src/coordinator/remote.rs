//! Coordinator side of the real multi-process transport (DESIGN.md §12).
//!
//! `train_tcp` runs the same three-phase training loop as the in-process
//! [`crate::coordinator::Trainer`], but every node's local pipeline (EF →
//! top-k → AE/index-coding) executes in its own `lgc worker` process and
//! the payloads arrive over TCP or Unix-domain sockets.  The coordinator
//! keeps its own model replica (for eval, curves, checkpoints), performs
//! all aggregation and AE training/decoding centrally, and — crucially —
//! replays the simulator's ledger/fabric call sequence verbatim against
//! the *received* payload sizes, so `Ledger`, `NetReport`, loss curves,
//! and checkpoints are bit-identical to a sim run of the same config
//! (tests/tcp_e2e.rs asserts this for every supported method).
//!
//! Accounting order is decoupled from wire arrival order: each iteration
//! first receives everything (support, gradients, latents), then replays
//! the sim's exact record/send/barrier sequence, so socket scheduling
//! can never perturb the ledger.
//!
//! Fault semantics: every receive is deadline-bounded by the configured
//! net timeout.  A worker that dies mid-iteration surfaces as a
//! descriptive "disconnected"/"timed out" error naming the node and
//! iteration — never a hang — after which the remaining workers get a
//! best-effort [`Msg::Shutdown`] and self-spawned children are killed.
//!
//! Wall-clock bookkeeping: worker compute and wire time are
//! indistinguishable from the coordinator's seat, so `time_grad` covers
//! plan-send → all-payloads-received (compute + wire) and
//! `time_exchange` covers the central replay (decode, AE work, sync
//! broadcast).  `lgc train --transport tcp` prints the measured per-
//! iteration wall-clock next to the fabric's modeled time so the two can
//! be compared (CI uploads that artifact).

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::baselines::{dense_mean_masked, fanout_rounds, live_count};
use crate::compress::autoencoder::{AeCompressor, Pattern};
use crate::compress::{index_coding, topk, Scratch};
use crate::config::{Method, OnFault, TrainConfig};
use crate::coordinator::bucket::{method_bucketable, BucketPlan};
use crate::coordinator::faults::{self, FaultAction, FaultEvent, FaultPlan, LivenessMonitor};
use crate::coordinator::lgc::{clip_to_gradient_scale, ef_on_rec, innovation_into, AE_GATE_WINDOW};
use crate::coordinator::scheduler::{self, phase_and_alpha, Phase};
use crate::coordinator::{lr_at, ring, CurvePoint, TrainResult};
use crate::data::{self, Dataset};
use crate::metrics::{Kind, Ledger, NodeLedger};
use crate::model::{Group, Model};
use crate::net::NetSim;
use crate::obs::{jsonl, trace};
use crate::runtime::{Engine, ModelMeta};
use crate::transport::{
    accept_rejoin, accept_workers, BucketUp, Conn, LastUp, Listener, MidUp, Msg, RejectorGuard,
};
use crate::util::rng::Rng;

/// Methods the wire transport supports (the others error loudly; see
/// [`gate_method`]).
pub const TCP_METHODS: &[Method] = &[
    Method::Baseline,
    Method::SparseGd,
    Method::Dgc,
    Method::Threshold,
    Method::LgcPs,
    Method::LgcRar,
];

/// Coordinator-side knobs for one multi-process run.
#[derive(Debug, Clone)]
pub struct RemoteOpts {
    /// Bind address: `host:port` (port 0 = ephemeral) or `unix:/path`.
    pub listen: String,
    /// Session id; joins offering a different id are rejected.
    pub session: u64,
    /// Deadline for all K workers to join.
    pub join_timeout: Duration,
    /// Per-receive deadline during training — a dead worker surfaces as
    /// an error within this bound, never a hang.
    pub net_timeout: Duration,
    /// Self-spawn K `lgc worker` child processes (the `--transport tcp`
    /// path).  `lgc serve` sets this false and waits for external
    /// workers.
    pub spawn_workers: bool,
    /// Binary to spawn workers from (default: this executable).
    pub worker_bin: Option<PathBuf>,
}

impl RemoteOpts {
    /// Defaults for a self-contained loopback run.
    pub fn local(session: u64) -> RemoteOpts {
        RemoteOpts {
            listen: "127.0.0.1:0".into(),
            session,
            join_timeout: Duration::from_secs(60),
            net_timeout: Duration::from_secs(30),
            spawn_workers: true,
            worker_bin: None,
        }
    }
}

/// A session id that differs across concurrent runs on one host (the
/// handshake rejects joins carrying another run's id).
pub fn default_session() -> u64 {
    ((std::process::id() as u64) << 16) | 0xC0DE
}

/// Fail fast on configs the wire transport cannot reproduce
/// bit-identically (satellite 4: loud errors, not silent fallbacks).
pub fn gate_method(cfg: &TrainConfig) -> Result<()> {
    match cfg.method {
        Method::ScaleCom | Method::Qsgd => bail!(
            "--transport tcp does not support method {} (supported: baseline, sparse_gd, \
             dgc, threshold, lgc_ps, lgc_rar); rerun with --transport sim",
            cfg.method.name()
        ),
        Method::LgcPs | Method::LgcRar if ef_on_rec() => bail!(
            "--transport tcp does not support LGC_EF_ON_REC=1 (the shared reconstruction \
             would have to be re-broadcast into every worker's EF memory); rerun with \
             --transport sim"
        ),
        _ => Ok(()),
    }
}

/// Entry point for `cfg.transport == Tcp`: bind loopback, self-spawn K
/// worker processes from this executable, run the session.
pub fn train_tcp(engine: &Engine, cfg: TrainConfig) -> Result<TrainResult> {
    train_with_opts(engine, cfg, &RemoteOpts::local(default_session()))
}

/// Full-control entry point (also the `lgc serve` implementation with
/// `spawn_workers: false`).
pub fn train_with_opts(
    engine: &Engine,
    mut cfg: TrainConfig,
    opts: &RemoteOpts,
) -> Result<TrainResult> {
    gate_method(&cfg)?;
    faults::validate_fault_config(&cfg)?;
    ensure!(cfg.nodes >= 1, "--transport tcp needs at least one worker node");
    // Resolve the model up front so every worker receives the resolved
    // name and builds the identical replica.
    let meta = engine.manifest.resolve_model(&cfg.model).clone();
    cfg.model = meta.name.clone();

    let listener = Listener::bind(&opts.listen)
        .with_context(|| format!("binding coordinator listener on {:?}", opts.listen))?;
    let addr = listener.local_addr()?;
    crate::log_info!(
        "lgc: coordinator listening on {addr} (session {:#x}, {} workers)",
        opts.session,
        cfg.nodes
    );

    // The deterministic fault plan fires from the coordinator's loop;
    // kill/stall faults signal real OS processes, so they need the
    // workers to be this coordinator's own children.
    let fault_plan = match &cfg.faults {
        Some(spec) => FaultPlan::parse(spec, cfg.nodes)?,
        None => FaultPlan::default(),
    };
    if fault_plan.targets_processes() && !opts.spawn_workers {
        bail!(
            "--faults kill/stall need self-spawned workers (lgc train --transport tcp); \
             lgc serve workers are processes this coordinator cannot signal"
        );
    }

    let mut children = ChildGuard::default();
    if opts.spawn_workers {
        for _ in 0..cfg.nodes {
            children.spawn(engine, &addr, opts, None)?;
        }
    }

    let (mut conns, pids): (Vec<Conn>, Vec<u64>) = accept_workers(
        &listener,
        cfg.nodes,
        opts.session,
        &engine.platform(),
        &cfg,
        opts.join_timeout,
    )?
    .into_iter()
    .unzip();
    for conn in &mut conns {
        apply_timeouts(conn, &cfg, opts.net_timeout)?;
    }
    // Late connections (double joins, strays) get a descriptive "session
    // full" refusal for the rest of the run — except under wait-rejoin,
    // where the listener must stay available for the token-checked
    // re-admission handshake (strays then simply queue unanswered).
    let (kept_listener, _rejector) = if cfg.on_fault == OnFault::WaitRejoin {
        (Some(listener), None)
    } else {
        (None, Some(RejectorGuard::spawn(listener, cfg.nodes)?))
    };

    let mut co = Coordinator::new(
        engine,
        cfg,
        meta,
        conns,
        pids,
        children,
        kept_listener,
        addr,
        opts.clone(),
        fault_plan,
    )?;
    let result = co.run();
    match &result {
        Ok(_) => co.broadcast_best_effort(&Msg::Shutdown { reason: "training complete".into() }),
        Err(e) => co.broadcast_best_effort(&Msg::Shutdown {
            reason: format!("coordinator error: {e:#}"),
        }),
    }
    if result.is_ok() {
        co.children.reap(Duration::from_secs(10));
    }
    // On error, ChildGuard::drop kills any still-running children.
    result
}

/// Socket deadlines for one worker connection.  Without heartbeats the
/// per-read deadline is the configured net timeout (the legacy shape).
/// With heartbeats on, a live worker emits a frame at least every
/// `heartbeat_ms`, so death is declared after `miss_budget` silent
/// periods — much faster than the net timeout — while the *progress*
/// deadline (heartbeats excluded, [`Conn::set_progress_timeout`]) keeps
/// the net timeout as the bound on a wedged-but-heartbeating peer.
fn apply_timeouts(conn: &mut Conn, cfg: &TrainConfig, net_timeout: Duration) -> Result<()> {
    if cfg.heartbeat_ms > 0 {
        let budget = cfg.heartbeat_ms.saturating_mul(cfg.miss_budget.max(1) as u64);
        conn.set_read_timeout(Some(Duration::from_millis(budget.max(50))))?;
        conn.set_progress_timeout(Some(net_timeout))?;
    } else {
        conn.set_read_timeout(Some(net_timeout))?;
    }
    Ok(())
}

/// Kills still-running spawned workers on drop (error paths); `reap`
/// waits for clean exits first.
#[derive(Default)]
struct ChildGuard {
    children: Vec<Child>,
}

impl ChildGuard {
    /// Spawn one worker process; `rejoin` makes it re-enter a live
    /// elastic run as that node via the token handshake instead of a
    /// fresh join.  Returns the OS pid (the handle for planned kills).
    fn spawn(
        &mut self,
        engine: &Engine,
        addr: &str,
        opts: &RemoteOpts,
        rejoin: Option<u32>,
    ) -> Result<u64> {
        let bin = match &opts.worker_bin {
            Some(p) => p.clone(),
            None => std::env::current_exe().context("locating this executable to spawn workers")?,
        };
        // The worker must open the same backend kind or the join-time
        // platform check refuses it.
        let backend = if engine.platform().contains("native") {
            "native"
        } else {
            "pjrt"
        };
        let mut cmd = Command::new(&bin);
        cmd.arg("worker")
            .arg("--connect")
            .arg(addr)
            .arg("--session")
            .arg(opts.session.to_string())
            .arg("--retries")
            .arg("40")
            .arg("--backoff-ms")
            .arg("50")
            .arg("--net-timeout-ms")
            .arg((opts.net_timeout.as_millis() as u64 * 4).to_string());
        if let Some(node) = rejoin {
            cmd.arg("--rejoin-node").arg(node.to_string());
        }
        let child = cmd
            .env("LGC_BACKEND", backend)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning worker process from {bin:?}"))?;
        let pid = child.id() as u64;
        self.children.push(child);
        Ok(pid)
    }

    /// SIGKILL the spawned child with OS pid `pid` (planned kill faults)
    /// and reap it.  Errors if no such child exists — externally launched
    /// workers (`lgc serve`) cannot be kill-faulted.
    fn kill_pid(&mut self, pid: u64) -> Result<()> {
        let Some(i) = self.children.iter().position(|c| c.id() as u64 == pid) else {
            bail!("no spawned worker child with pid {pid} to kill (externally launched?)")
        };
        let mut c = self.children.remove(i);
        let _ = c.kill();
        let _ = c.wait();
        Ok(())
    }

    /// Give cleanly-shut-down workers time to exit before the kill-on-
    /// drop backstop.
    fn reap(&mut self, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            self.children.retain_mut(|c| !matches!(c.try_wait(), Ok(Some(_))));
            if self.children.is_empty() {
                return;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Send `sig` (e.g. "-STOP" / "-CONT") to an OS process via kill(1) —
/// the stall fault's freeze/thaw mechanism.  std exposes no signal API,
/// and the only platform this targets is the POSIX one the rest of the
/// transport already assumes.
fn signal_pid(pid: u64, sig: &str) -> Result<()> {
    let status = Command::new("kill")
        .arg(sig)
        .arg(pid.to_string())
        .status()
        .with_context(|| format!("running kill {sig} {pid}"))?;
    ensure!(status.success(), "kill {sig} {pid} exited with {status}");
    Ok(())
}

/// Coordinator-side LGC mirror: the full autoencoder (training + both
/// decoders), the sticky readiness gate, and the one-shot encoder
/// transfer bookkeeping.
struct LgcMirror {
    ae: AeCompressor,
    ps: bool,
    /// Sticky readiness latch — mirrors `LgcCommon::check_ae_ready`.
    ready: bool,
    /// Encoder weights shipped to the worker(s) (one-shot; the AE is
    /// frozen once engaged, so the transfer stays exact).
    enc_shipped: bool,
    /// RAR's one-time AE-weight broadcast recorded on the ledger.
    oneoff_recorded: bool,
    /// Per-node innovation buffers + scratch arenas for the AE-training
    /// mirror (scratch is stateless between calls, so central recompute
    /// is bit-identical to the sim's per-node arenas).
    inns: Vec<Vec<f32>>,
    scratches: Vec<Scratch>,
}

/// One received per-node uplink.
struct Up {
    loss: f32,
    acc: f32,
    first: Vec<f32>,
    mid: MidUp,
    last: LastUp,
    ctrl_mid: Option<Vec<f32>>,
    /// GradientBucket frames streamed ahead of the closing Gradient
    /// (overlap pipeline); bucket ids validated + deduped at receive.
    buckets: Vec<(u32, BucketUp)>,
}

impl Up {
    /// What a dead node contributes under `--on-fault continue`: empty
    /// placeholders every masked replay path skips — the wire twin of the
    /// sim's empty per-node closure results (DESIGN.md §14).
    fn placeholder() -> Up {
        Up {
            loss: 0.0,
            acc: 0.0,
            first: Vec::new(),
            mid: MidUp::None,
            last: LastUp::Dense(Vec::new()),
            ctrl_mid: None,
            buckets: Vec::new(),
        }
    }
}

/// The multi-process training session: K worker connections plus the
/// coordinator's replica of everything the sim's `Trainer` owns
/// centrally.
struct Coordinator<'e> {
    engine: &'e Engine,
    cfg: TrainConfig,
    meta: ModelMeta,
    conns: Vec<Conn>,
    /// OS pid per node (from the Join handshake; updated on rejoin) —
    /// the handle planned kill/stall faults act through.
    pids: Vec<u64>,
    /// Self-spawned worker processes (empty for `lgc serve`).
    children: ChildGuard,
    /// Retained under `--on-fault wait-rejoin` so the rejoin handshake
    /// can re-admit a respawned worker; `None` otherwise (a
    /// [`RejectorGuard`] owns the listener then).
    listener: Option<Listener>,
    /// The bound address workers (re)connect to.
    addr: String,
    ropts: RemoteOpts,
    /// Liveness mask under `--on-fault continue`; all-true otherwise.
    alive: Vec<bool>,
    liveness: LivenessMonitor,
    fault_plan: FaultPlan,
    fault_events: Vec<FaultEvent>,
    /// Latest per-node strategy-state blob ([`Msg::StateSync`]), kept
    /// only under wait-rejoin: the resurrection payload for a node killed
    /// before its next sync.
    worker_states: Vec<Vec<u8>>,
    model: Model,
    dataset: Box<dyn Dataset>,
    rng: Rng,
    lgc: Option<LgcMirror>,
    n_mid: usize,
    n_last: usize,
    /// Mid-group bucket plan — same (cfg, layer-slice) derivation as the
    /// workers' and the sim Trainer's, so all three agree frame-for-frame.
    plan: BucketPlan,
    /// Effective overlap: configured on *and* the plan actually splits.
    overlap: bool,
    /// Structured run log (--log-json, DESIGN.md §15.3); `None` when
    /// the flag is unset.
    run_log: Option<jsonl::RunLog>,
}

impl<'e> Coordinator<'e> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        engine: &'e Engine,
        cfg: TrainConfig,
        meta: ModelMeta,
        conns: Vec<Conn>,
        pids: Vec<u64>,
        children: ChildGuard,
        listener: Option<Listener>,
        addr: String,
        ropts: RemoteOpts,
        fault_plan: FaultPlan,
    ) -> Result<Self> {
        let mut model = Model::new(&meta, cfg.seed);
        model.momentum = match cfg.method {
            Method::Baseline | Method::Qsgd => cfg.momentum,
            _ => 0.0,
        };
        model.weight_decay = cfg.weight_decay;
        let dataset = data::for_model(&meta, cfg.seed ^ 0xDA7A);
        let n_mid = meta.group_len(&meta.mid_param_idx);
        let n_last = meta.group_len(&meta.last_param_idx);
        let lgc = match cfg.method {
            Method::LgcPs | Method::LgcRar => {
                let ps = matches!(cfg.method, Method::LgcPs);
                let pattern = if ps {
                    Pattern::ParamServer
                } else {
                    Pattern::RingAllreduce
                };
                let ae = AeCompressor::new(engine, meta.mu, cfg.nodes, pattern, cfg.seed ^ 0xAE)?;
                Some(LgcMirror {
                    ae,
                    ps,
                    ready: false,
                    enc_shipped: false,
                    oneoff_recorded: false,
                    inns: vec![Vec::new(); cfg.nodes],
                    scratches: Scratch::for_nodes(cfg.nodes),
                })
            }
            _ => None,
        };
        let rng = Rng::new(cfg.seed ^ 0x7124);
        let plan = if method_bucketable(cfg.method) {
            let layers: Vec<std::ops::Range<usize>> =
                model.layer_slices(Group::Mid).into_iter().map(|(_, r)| r).collect();
            BucketPlan::for_group(n_mid, &layers, &cfg)
        } else {
            BucketPlan::single(n_mid)
        };
        let overlap = cfg.overlap && !plan.is_single();
        let alive = vec![true; cfg.nodes];
        let liveness = LivenessMonitor::new(cfg.nodes, cfg.heartbeat_ms, cfg.miss_budget);
        let worker_states = vec![Vec::new(); cfg.nodes];
        let mut run_log = match &cfg.log_json {
            Some(p) => Some(jsonl::RunLog::create(p)?),
            None => None,
        };
        if let Some(log) = &mut run_log {
            use crate::util::json::Json;
            log.record(
                "run_start",
                vec![
                    ("method", Json::Str(cfg.method.name().to_string())),
                    ("model", Json::Str(cfg.model.clone())),
                    ("nodes", Json::Num(cfg.nodes as f64)),
                    ("steps", Json::Num(cfg.steps as f64)),
                    ("transport", Json::Str("tcp".to_string())),
                    ("backend", Json::Str(engine.platform())),
                    ("git", Json::Str(jsonl::git_describe())),
                    ("seed", Json::Num(cfg.seed as f64)),
                ],
            )?;
        }
        Ok(Coordinator {
            engine,
            cfg,
            meta,
            conns,
            pids,
            children,
            listener,
            addr,
            ropts,
            alive,
            liveness,
            fault_plan,
            fault_events: Vec::new(),
            worker_states,
            model,
            dataset,
            rng,
            lgc,
            n_mid,
            n_last,
            plan,
            overlap,
            run_log,
        })
    }

    /// Fan one fault event out to every telemetry sink (stderr line,
    /// JSONL record, trace marker, Prometheus counter) and record it for
    /// the [`TrainResult`] artifact CI uploads.
    fn push_event(&mut self, ev: FaultEvent) -> Result<()> {
        ev.observe(&mut self.run_log)?;
        self.fault_events.push(ev);
        Ok(())
    }

    /// Deadline-bounded receive from one worker with liveness
    /// bookkeeping: progress refreshes the node's clock; a timeout or
    /// disconnect error carries the monitor's budget-aware description.
    fn recv_from(&mut self, node: usize, what: &str) -> Result<Msg> {
        match self.conns[node].expect(what) {
            Ok(m) => {
                self.liveness.observe(node);
                crate::obs::metrics::mark_progress(node);
                Ok(m)
            }
            Err(e) => Err(e.context(self.liveness.describe(node))),
        }
    }

    fn broadcast_best_effort(&mut self, msg: &Msg) {
        for conn in &mut self.conns {
            let _ = conn.send(msg);
        }
    }

    /// Mirror of `LgcCommon::check_ae_ready`, evaluated before each
    /// iteration's work (exactly where the sim's match guard runs).
    fn engaged(&mut self, phase: Phase) -> bool {
        let ae_gate = self.cfg.ae_gate;
        let Some(l) = &mut self.lgc else { return false };
        if phase != Phase::Compressed {
            return false;
        }
        if l.ready {
            return true;
        }
        let losses = &l.ae.train_losses;
        if losses.len() >= AE_GATE_WINDOW {
            let tail = &losses[losses.len() - AE_GATE_WINDOW..];
            let mean = tail.iter().map(|(r, _)| r).sum::<f32>() / AE_GATE_WINDOW as f32;
            if mean < ae_gate {
                l.ready = true;
            }
        }
        l.ready
    }

    /// Send every worker its iteration plan; at the engagement
    /// transition, ship the trained encoder (PS: worker 0 only, §V-B1;
    /// RAR: all workers — the matching byte accounting happens in the
    /// replay, mirroring the sim's oneoff).
    fn send_plans(&mut self, it: usize, engaged: bool) -> Result<()> {
        let (ship, ps, payload) = match &self.lgc {
            Some(l) if engaged && !l.enc_shipped => (true, l.ps, l.ae.export_encoder()),
            Some(l) => (false, l.ps, Vec::new()),
            None => (false, false, Vec::new()),
        };
        for (node, conn) in self.conns.iter_mut().enumerate() {
            if !self.alive[node] {
                continue;
            }
            let follows = ship && (!ps || node == 0);
            conn.send(&Msg::IterPlan { iter: it as u32, engaged, weights_follow: follows })
                .with_context(|| format!("sending iter {it} plan to node {node}"))?;
            if follows {
                conn.send(&Msg::Model { iter: it as u32, payload: payload.clone() })
                    .with_context(|| format!("shipping AE encoder to node {node}"))?;
            }
        }
        if ship {
            if let Some(l) = &mut self.lgc {
                l.enc_shipped = true;
            }
        }
        Ok(())
    }

    /// Receive the leader's support upload and relay it to every worker
    /// (the leader included — one uniform decode path on the workers).
    fn relay_support(&mut self, it: usize, leader: usize) -> Result<Vec<u8>> {
        let coded = match self
            .recv_from(leader, "Support")
            .with_context(|| format!("node {leader} (support leader) at iter {it}"))?
        {
            Msg::Support { iter, coded } => {
                ensure!(
                    iter as usize == it,
                    "protocol desync: Support for iter {iter}, expected {it}"
                );
                coded
            }
            other => bail!("expected Support from node {leader}, got {}", other.name()),
        };
        for (node, conn) in self.conns.iter_mut().enumerate() {
            conn.send(&Msg::SupportBcast { iter: it as u32, coded: coded.clone() })
                .with_context(|| format!("broadcasting support to node {node} at iter {it}"))?;
        }
        Ok(coded)
    }

    /// Receive each node's gradient uplink, in node order.  Overlapped
    /// runs stream [`Msg::GradientBucket`] frames first; bucket ids are
    /// validated against the plan *here* — an out-of-plan or duplicate id
    /// gets a descriptive [`Msg::Error`] frame back, never an index panic
    /// downstream in the replay.
    fn recv_gradients(&mut self, it: usize) -> Result<Vec<Up>> {
        let mut ups = Vec::with_capacity(self.conns.len());
        for node in 0..self.conns.len() {
            if !self.alive[node] {
                ups.push(Up::placeholder());
                continue;
            }
            let mut buckets: Vec<(u32, BucketUp)> = Vec::new();
            let mut died = false;
            loop {
                let msg = match self.recv_from(node, "Gradient") {
                    Ok(m) => m,
                    Err(e) if self.cfg.on_fault == OnFault::Continue => {
                        // Organic mid-iteration death (disconnect, decode
                        // kill from a corrupted frame, liveness timeout):
                        // drop the node and keep training on the
                        // survivors, exactly like a planned kill.
                        self.mark_dead(it, node, &e)?;
                        died = true;
                        break;
                    }
                    Err(e) => {
                        return Err(e.context(format!("node {node} at iter {it}")));
                    }
                };
                match msg {
                    Msg::GradientBucket { iter, bucket, up } => {
                        ensure!(
                            iter as usize == it,
                            "protocol desync: GradientBucket from node {node} for iter {iter}, \
                             expected {it}"
                        );
                        if let Err(e) = self.plan.check_bucket(bucket as usize) {
                            let msg = format!("node {node} at iter {it}: {e}");
                            return Err(reject(&mut self.conns[node], msg));
                        }
                        if buckets.iter().any(|(b, _)| *b == bucket) {
                            let msg =
                                format!("node {node} at iter {it}: duplicate bucket id {bucket}");
                            return Err(reject(&mut self.conns[node], msg));
                        }
                        buckets.push((bucket, up));
                    }
                    Msg::Gradient { iter, loss, acc, first, mid, last, ctrl_mid } => {
                        ensure!(
                            iter as usize == it,
                            "protocol desync: Gradient from node {node} for iter {iter}, \
                             expected {it}"
                        );
                        ensure!(
                            first.len() == self.meta.group_len(&self.meta.first_param_idx),
                            "node {node} sent a first-group gradient of wrong length"
                        );
                        ups.push(Up { loss, acc, first, mid, last, ctrl_mid, buckets });
                        break;
                    }
                    other => bail!("expected Gradient from node {node}, got {}", other.name()),
                }
            }
            if died {
                ups.push(Up::placeholder());
            }
        }
        Ok(ups)
    }

    /// Remove a node that died without a plan entry (`--on-fault
    /// continue` only): flip its liveness bit, log the event, keep going
    /// on the survivors.
    fn mark_dead(&mut self, it: usize, node: usize, err: &anyhow::Error) -> Result<()> {
        self.alive[node] = false;
        let survivors = live_count(&self.alive);
        ensure!(survivors > 0, "no live nodes left at iteration {it}");
        self.push_event(FaultEvent {
            iter: it,
            node: Some(node),
            kind: "death".into(),
            detail: format!(
                "removed from aggregation; {survivors} survivors; the node's EF residual \
                 is dropped ({err:#})"
            ),
        })?;
        Ok(())
    }

    /// Read the end-of-iteration [`Msg::StateSync`] from every live
    /// worker (wait-rejoin only; `None` = the initial pre-loop sync,
    /// tagged `u32::MAX`).  Per-connection FIFO ordering makes this a
    /// plain synchronous read: the sync always precedes the next
    /// iteration's uploads.
    fn recv_state_syncs(&mut self, it: Option<usize>) -> Result<()> {
        let want = it.map(|i| i as u32).unwrap_or(u32::MAX);
        for node in 0..self.cfg.nodes {
            if !self.alive[node] {
                continue;
            }
            match self.recv_from(node, "StateSync")? {
                Msg::StateSync { iter, blob } => {
                    ensure!(
                        iter == want,
                        "protocol desync: StateSync from node {node} for iter {iter}, \
                         expected {want}"
                    );
                    self.worker_states[node] = blob;
                }
                other => bail!("expected StateSync from node {node}, got {}", other.name()),
            }
        }
        Ok(())
    }

    /// Execute one planned fault against the real worker processes
    /// (DESIGN.md §14).  Fabric perturbations that the sim prices
    /// (stalls) are priced identically here, so a faulted TCP run's
    /// modeled-time report still matches its sim twin.
    fn execute_fault(
        &mut self,
        it: usize,
        action: FaultAction,
        net: &mut NetSim,
    ) -> Result<()> {
        match action {
            FaultAction::Kill { node } => match self.cfg.on_fault {
                OnFault::Fail => bail!(
                    "node {node} killed by fault plan at iteration {it} (--on-fault fail); \
                     rerun with --on-fault continue or wait-rejoin to survive it"
                ),
                OnFault::Continue => {
                    if self.alive[node] {
                        self.children.kill_pid(self.pids[node])?;
                        self.alive[node] = false;
                        let survivors = live_count(&self.alive);
                        ensure!(survivors > 0, "no live nodes left at iteration {it}");
                        // Same event detail as the simulator's, so fault
                        // logs compare across backends.
                        self.push_event(FaultEvent {
                            iter: it,
                            node: Some(node),
                            kind: "kill".into(),
                            detail: format!(
                                "removed from aggregation; {survivors} survivors; \
                                 the node's EF residual is dropped"
                            ),
                        })?;
                    }
                }
                OnFault::WaitRejoin => self.kill_and_rejoin(it, node)?,
            },
            FaultAction::Stall { node, ms } => {
                // Freeze the real process for the window, then thaw it —
                // synchronously, so the run's message order is untouched —
                // and price the same modeled stall the sim does.
                signal_pid(self.pids[node], "-STOP")?;
                std::thread::sleep(Duration::from_millis(ms));
                signal_pid(self.pids[node], "-CONT")?;
                net.stall(node, ms as f64 / 1000.0);
                self.push_event(FaultEvent {
                    iter: it,
                    node: Some(node),
                    kind: "stall".into(),
                    detail: format!(
                        "{ms}ms frozen (SIGSTOP/SIGCONT); priced into this iteration's \
                         modeled time"
                    ),
                })?;
            }
            FaultAction::CorruptFrame { node } => {
                // Arm the wire shim: the next frame to this worker goes
                // out with its type byte flipped, so the worker dies on a
                // clean decode error (the sim instead prices a detected
                // retransmit — the asymmetry is documented in DESIGN.md
                // §14).  Recovery is the fault policy's job.
                self.conns[node].corrupt_next();
                self.push_event(FaultEvent {
                    iter: it,
                    node: Some(node),
                    kind: "corrupt-frame".into(),
                    detail: "next frame to the node corrupted in flight; its decode will \
                             fail loudly"
                        .into(),
                })?;
            }
            FaultAction::Crash => {
                bail!("injected crash at iteration {it} (fault plan)");
            }
        }
        Ok(())
    }

    /// The wait-rejoin recovery arc for a planned kill: SIGKILL the
    /// worker, respawn a replacement with `--rejoin-node`, re-admit it
    /// through the token-checked handshake, and resync it from the
    /// coordinator's replica + the node's last StateSync blob (the end of
    /// iteration `it - 1` — planned kills fire at iteration start, so
    /// that is exactly the state the node died with).  Bit-exactness
    /// argument in DESIGN.md §14.3.
    fn kill_and_rejoin(&mut self, it: usize, node: usize) -> Result<()> {
        self.children.kill_pid(self.pids[node])?;
        self.push_event(FaultEvent {
            iter: it,
            node: Some(node),
            kind: "kill".into(),
            detail: "killed; respawning for token-checked rejoin (--on-fault wait-rejoin)"
                .into(),
        })?;
        let ropts = self.ropts.clone();
        self.pids[node] = self.children.spawn(self.engine, &self.addr, &ropts, Some(node as u32))?;
        let ack = Msg::RejoinAck {
            node: node as u32,
            nodes: self.cfg.nodes as u32,
            platform: self.engine.platform(),
            cfg: self.cfg.clone(),
            iter: it as u32,
            model: self.model.state_bytes(),
            state: self.worker_states[node].clone(),
            encoder: match &self.lgc {
                Some(l) if l.enc_shipped => Some(l.ae.export_encoder()),
                _ => None,
            },
        };
        let token = faults::rejoin_token(ropts.session, node);
        let listener = self
            .listener
            .as_ref()
            .expect("wait-rejoin retains the listener for re-admission");
        let mut conn = accept_rejoin(
            listener,
            node as u32,
            ropts.session,
            token,
            &ack,
            ropts.join_timeout,
        )
        .with_context(|| format!("re-admitting node {node} at iteration {it}"))?;
        apply_timeouts(&mut conn, &self.cfg, ropts.net_timeout)?;
        self.conns[node] = conn;
        self.liveness.observe(node);
        self.push_event(FaultEvent {
            iter: it,
            node: Some(node),
            kind: "rejoin".into(),
            detail: format!(
                "re-admitted via session token; resynced to iteration {it} (model replica, \
                 strategy state{})",
                if matches!(&self.lgc, Some(l) if l.enc_shipped) {
                    ", AE encoder"
                } else {
                    ""
                }
            ),
        })?;
        Ok(())
    }

    /// Receive the expected AE latents (engaged iterations only): node 0
    /// for PS, every node for RAR.
    fn recv_latents(&mut self, it: usize) -> Result<Vec<(Vec<f32>, f32)>> {
        let Some(l) = &self.lgc else { return Ok(Vec::new()) };
        let senders: Vec<usize> = if l.ps {
            vec![0]
        } else {
            (0..self.conns.len()).collect()
        };
        let mut out = Vec::with_capacity(senders.len());
        for node in senders {
            match self.conns[node]
                .expect("Latent")
                .with_context(|| format!("node {node} at iter {it}"))?
            {
                Msg::Latent { iter, latent, scale } => {
                    ensure!(
                        iter as usize == it,
                        "protocol desync: Latent from node {node} for iter {iter}, expected {it}"
                    );
                    ensure!(
                        latent.len() == l.ae.latent_len(),
                        "node {node} sent a latent of length {}, expected {}",
                        latent.len(),
                        l.ae.latent_len()
                    );
                    out.push((latent, scale));
                }
                other => bail!("expected Latent from node {node}, got {}", other.name()),
            }
        }
        Ok(out)
    }

    /// The training loop — the sim's `Trainer::run` with the per-node
    /// stages replaced by wire receives and the accounting replayed
    /// verbatim.
    fn run(&mut self) -> Result<TrainResult> {
        let nodes = self.cfg.nodes;
        let steps = self.cfg.steps;
        let mut ledger = Ledger::new();
        let mut shards = NodeLedger::for_nodes(nodes);
        let mut net = NetSim::new(self.cfg.fabric(), nodes);
        let mut curve = Vec::with_capacity(steps);
        let mut evals = Vec::new();
        let mut phase_time = [Duration::ZERO; 3];
        let mut phase_iters = [0usize; 3];
        let mut time_grad = Duration::ZERO;
        let mut time_exchange = Duration::ZERO;
        let mut time_update = Duration::ZERO;
        let mut iter_wall: Vec<(f32, f32)> = Vec::with_capacity(steps);
        // Telemetry deltas (see the sim Trainer's twins): cumulative
        // per-kind bytes for the JSONL breakdown, per-node uplink bytes
        // for the Prometheus counters.
        let mut prev_kind = std::collections::BTreeMap::new();
        let mut prev_node_bytes: Vec<u64> = vec![0; nodes];

        // Elastic runs: every worker ships its initial strategy state
        // before the first plan, so even an iteration-0 kill has a
        // resurrection payload.
        if self.cfg.on_fault == OnFault::WaitRejoin {
            self.recv_state_syncs(None)?;
        }

        for it in 0..steps {
            trace::set_iter(it);
            let (phase, _alpha) = phase_and_alpha(&self.cfg, it);
            // Injected faults fire at the iteration boundary, before any
            // plan goes out — the same point the simulator fires them.
            for action in self.fault_plan.take(it) {
                self.execute_fault(it, action, &mut net)?;
            }
            ledger.set_phase(phase.index() as u8 + 1);
            let t0 = Instant::now();
            let engaged = self.engaged(phase);
            let lgc_support_round = self.lgc.is_some() && phase != Phase::Dense;

            // --- wire exchange: plans out, payloads in -----------------
            let t_grad0 = Instant::now();
            // From the coordinator's seat this window is the workers'
            // compute + wire time — the trace twin of the workers' own
            // in-process `grad` spans (their part files carry those).
            let sp_grad = trace::span(trace::Stage::Grad);
            self.send_plans(it, engaged)?;
            let support_coded = if lgc_support_round {
                let ps = self.lgc.as_ref().map(|l| l.ps).unwrap_or(false);
                let leader = if ps { 0 } else { it % nodes };
                Some(self.relay_support(it, leader)?)
            } else {
                None
            };
            let mut ups = self.recv_gradients(it)?;
            let latents = if engaged {
                self.recv_latents(it)?
            } else {
                Vec::new()
            };
            drop(sp_grad);
            let dt_grad = t_grad0.elapsed();
            time_grad += dt_grad;

            // --- central replay of the sim's exchange ------------------
            let t_ex0 = Instant::now();
            let sp_ex = trace::span(trace::Stage::Exchange);
            // Divergence check in node order, with the sim's exact error.
            let method_name = self.cfg.method.name();
            let lr_cfg = self.cfg.lr;
            let mut loss_sum = 0.0f32;
            let mut acc_sum = 0.0f32;
            for (node, up) in ups.iter().enumerate() {
                if !self.alive[node] {
                    continue;
                }
                anyhow::ensure!(
                    up.loss.is_finite(),
                    "training diverged: non-finite loss at iter {it}, node {node} \
                     (method {method_name}, lr {lr_cfg})"
                );
                loss_sum += up.loss;
                acc_sum += up.acc;
            }

            // First layer: always dense (mean over the live nodes).
            let first_g: Vec<Vec<f32>> =
                ups.iter_mut().map(|u| std::mem::take(&mut u.first)).collect();
            let first_mean = dense_mean_masked(&first_g, &self.alive, &mut shards);
            net.fanout((first_mean.len() * 4) as u64);

            let mid_mean = self.mid_replay(
                it,
                phase,
                engaged,
                &mut ups,
                support_coded.as_deref(),
                latents,
                &mut ledger,
                &mut shards,
                &mut net,
            )?;
            let last_mean = self.last_replay(phase, &mut ups, &mut shards, &mut net)?;

            // --- update: broadcast the means, apply locally ------------
            for (node, conn) in self.conns.iter_mut().enumerate() {
                if !self.alive[node] {
                    continue;
                }
                conn.send(&Msg::SyncInfo {
                    iter: it as u32,
                    first: first_mean.clone(),
                    mid: mid_mean.clone(),
                    last: last_mean.clone(),
                })
                .with_context(|| format!("broadcasting sync to node {node} at iter {it}"))?;
            }
            // Elastic bookkeeping: after applying the sync, each worker
            // ships its end-of-iteration strategy state — the payload a
            // kill at iteration `it + 1` resurrects from.
            if self.cfg.on_fault == OnFault::WaitRejoin {
                self.recv_state_syncs(Some(it))?;
            }
            drop(sp_ex);
            let dt_ex = t_ex0.elapsed();
            time_exchange += dt_ex;
            let t_up0 = Instant::now();
            let sp_up = trace::span(trace::Stage::Update);
            self.model.apply_update(
                &[
                    (Group::First, first_mean),
                    (Group::Mid, mid_mean),
                    (Group::Last, last_mean),
                ],
                lr_at(&self.cfg, it),
            );
            drop(sp_up);
            let dt_up = t_up0.elapsed();
            time_update += dt_up;

            // Fabric + ledger close-out — the scheduler owns the one
            // sequence both transports run (DESIGN.md §13).
            scheduler::close_iteration(&mut ledger, &mut shards, &mut net);

            let dt = t0.elapsed();
            phase_time[phase.index()] += dt;
            phase_iters[phase.index()] += 1;

            let live = live_count(&self.alive) as f32;
            curve.push(CurvePoint {
                iter: it,
                train_loss: loss_sum / live,
                train_acc: acc_sum / live,
            });
            iter_wall.push((dt_grad.as_secs_f32(), dt_ex.as_secs_f32()));

            // Telemetry fan-out — observation only, same as the sim's
            // (DESIGN.md §15 contract).
            if crate::obs::metrics::current().is_some() {
                crate::obs::metrics::inc_iterations();
                crate::obs::metrics::observe_stage("grad", dt_grad);
                crate::obs::metrics::observe_stage("exchange", dt_ex);
                crate::obs::metrics::observe_stage("update", dt_up);
                for (&node, &b) in &ledger.per_node {
                    if let Some(prev) = prev_node_bytes.get_mut(node) {
                        crate::obs::metrics::add_bytes_up(node, b - *prev);
                        *prev = b;
                    }
                }
            }
            if let Some(log) = &mut self.run_log {
                use crate::util::json::Json;
                let mut kinds: Vec<(&str, Json)> = Vec::new();
                for (&k, &v) in &ledger.per_kind {
                    let d = v - prev_kind.get(&k).copied().unwrap_or(0);
                    if d > 0 {
                        kinds.push((k.name(), Json::Num(d as f64)));
                    }
                }
                prev_kind = ledger.per_kind.clone();
                let iter_total = ledger.iter_bytes.last().copied().unwrap_or(0);
                let dense = (self.meta.n_params * 4 * live_count(&self.alive)) as u64;
                log.record(
                    "iteration",
                    vec![
                        ("iter", Json::Num(it as f64)),
                        ("phase", Json::Str(phase.name().to_string())),
                        ("train_loss", Json::Num(f64::from(loss_sum / live))),
                        ("train_acc", Json::Num(f64::from(acc_sum / live))),
                        ("bytes_total", Json::Num(iter_total as f64)),
                        ("bytes_by_kind", jsonl::obj(kinds)),
                        (
                            "compression_ratio",
                            Json::Num(dense as f64 / (iter_total as f64).max(1e-9)),
                        ),
                        ("grad_s", Json::Num(f64::from(dt_grad.as_secs_f32()))),
                        ("exchange_s", Json::Num(f64::from(dt_ex.as_secs_f32()))),
                        ("update_s", Json::Num(f64::from(dt_up.as_secs_f32()))),
                    ],
                )?;
            }

            if self.cfg.eval_every > 0 && (it + 1) % self.cfg.eval_every == 0 {
                let (l, a) = self.evaluate()?;
                evals.push((it, l, a));
                if self.cfg.verbose {
                    crate::log_info!(
                        "[{}/tcp] it {:>5} phase {:<10} train_loss {:.4} eval_loss {:.4} \
                         eval_acc {:.4}",
                        method_name,
                        it,
                        phase.name(),
                        curve.last().unwrap().train_loss,
                        l,
                        a
                    );
                }
            }
        }

        let final_eval = self.evaluate()?;
        if let Some(path) = &self.cfg.checkpoint {
            self.model.save_checkpoint(path)?;
        }
        if let Some(mut log) = self.run_log.take() {
            use crate::util::json::Json;
            log.record(
                "run_end",
                vec![
                    ("final_eval_loss", Json::Num(f64::from(final_eval.0))),
                    ("final_eval_acc", Json::Num(f64::from(final_eval.1))),
                    ("total_bytes", Json::Num(ledger.total() as f64)),
                    ("fault_events", Json::Num(self.fault_events.len() as f64)),
                ],
            )?;
            log.finish()?;
        }
        Ok(TrainResult {
            method: self.cfg.method,
            model: self.cfg.model.clone(),
            nodes,
            steps,
            curve,
            evals,
            ledger,
            phase_time,
            phase_iters,
            ae_losses: self.lgc.as_ref().map(|l| l.ae.train_losses.clone()).unwrap_or_default(),
            final_eval,
            dense_bytes_per_node: (self.meta.n_params * 4) as u64,
            time_grad,
            time_exchange,
            time_update,
            iter_wall,
            net: net.into_report(),
            fault_events: std::mem::take(&mut self.fault_events),
        })
    }

    /// Mid-group replay: per method/phase, mirror the strategy's exact
    /// ledger/fabric sequence against the received payloads and return
    /// the aggregated dense mean.
    #[allow(clippy::too_many_arguments)]
    fn mid_replay(
        &mut self,
        it: usize,
        phase: Phase,
        engaged: bool,
        ups: &mut [Up],
        support_coded: Option<&[u8]>,
        latents: Vec<(Vec<f32>, f32)>,
        ledger: &mut Ledger,
        shards: &mut [NodeLedger],
        net: &mut NetSim,
    ) -> Result<Vec<f32>> {
        let nodes = ups.len();
        let n = self.n_mid;
        match self.cfg.method {
            Method::Baseline => {
                if self.overlap {
                    let mut mids = Vec::with_capacity(nodes);
                    for node in 0..nodes {
                        if !self.alive[node] {
                            mids.push(Vec::new());
                            continue;
                        }
                        mids.push(self.dense_from_buckets(node, &mut ups[node])?);
                    }
                    let mean = dense_mean_masked(&mids, &self.alive, shards);
                    // Per-bucket tagged fan-out rounds — byte-for-byte the
                    // sim Baseline's overlapped pricing.
                    let per_bucket: Vec<u64> = self
                        .plan
                        .ranges()
                        .iter()
                        .map(|r| ((r.end - r.start) * 4) as u64)
                        .collect();
                    fanout_rounds(net, true, self.plan.len(), &[per_bucket]);
                    return Ok(mean);
                }
                let mids = take_dense_mids(ups, &self.alive)?;
                let mean = dense_mean_masked(&mids, &self.alive, shards);
                net.fanout((mean.len() * 4) as u64);
                Ok(mean)
            }
            Method::SparseGd | Method::Dgc | Method::Threshold => {
                let fp16 = self.cfg.fp16_values;
                if self.overlap {
                    return self.sparse_bucket_replay(ups, fp16, shards, net);
                }
                // Mirror of baselines::sparse_ef_exchange / HardThreshold:
                // per-node Values+Indices records, scatter-mean in node
                // order, one fan-out of the concatenated packets.
                let mut mean = vec![0.0f32; n];
                let mut total = 0u64;
                for (node, up) in ups.iter().enumerate() {
                    if !self.alive[node] {
                        continue;
                    }
                    let MidUp::Sparse { coded_idx, vals } = &up.mid else {
                        bail!("node {node} sent {} for a sparse method", up.mid.name())
                    };
                    let idx = index_coding::decode(coded_idx, n)?;
                    ensure!(
                        idx.len() == vals.len(),
                        "node {node}: {} indices vs {} values",
                        idx.len(),
                        vals.len()
                    );
                    let bytes = vals.len() * if fp16 { 2 } else { 4 };
                    shards[node].record(Kind::Values, bytes);
                    shards[node].record(Kind::Indices, coded_idx.len());
                    total += (bytes + coded_idx.len()) as u64;
                    topk::scatter_add(&mut mean, &idx, vals);
                }
                let live = live_count(&self.alive) as f32;
                mean.iter_mut().for_each(|m| *m /= live);
                net.fanout(total);
                Ok(mean)
            }
            Method::LgcPs | Method::LgcRar => {
                let ps = matches!(self.cfg.method, Method::LgcPs);
                if phase == Phase::Dense {
                    let mut mids = take_dense_mids(ups, &self.alive)?;
                    if ps {
                        let mean = dense_mean_masked(&mids, &self.alive, shards);
                        net.fanout((mean.len() * 4) as u64);
                        Ok(mean)
                    } else {
                        Ok(ring::ring_allreduce_mean_timed(
                            &mut mids,
                            ledger,
                            Kind::Dense,
                            Some(net),
                        ))
                    }
                } else if !engaged {
                    self.topk_replay(it, ps, ups, support_coded, ledger, shards, net)
                } else if ps {
                    self.ps_compressed_replay(ups, support_coded, latents, ledger, shards, net)
                } else {
                    self.rar_compressed_replay(it, ups, support_coded, latents, ledger, net)
                }
            }
            Method::ScaleCom | Method::Qsgd => unreachable!("gated in gate_method"),
        }
    }

    /// Reassemble a node's streamed dense bucket frames into the full mid
    /// vector (overlapped Baseline).  Ids were validated and deduped at
    /// receive; completeness and per-bucket lengths are checked here, and
    /// every failure sends the worker a descriptive [`Msg::Error`] frame.
    fn dense_from_buckets(&mut self, node: usize, up: &mut Up) -> Result<Vec<f32>> {
        let b_count = self.plan.len();
        let MidUp::Buckets(nb) = up.mid else {
            bail!("node {node} sent {} on the overlapped dense path", up.mid.name())
        };
        if nb as usize != b_count || up.buckets.len() != b_count {
            let msg = format!(
                "node {node}: bucketed upload announced {nb} buckets, streamed {}, plan has \
                 {b_count}",
                up.buckets.len()
            );
            return Err(reject(&mut self.conns[node], msg));
        }
        let mut full = vec![0.0f32; self.n_mid];
        for (b, bu) in std::mem::take(&mut up.buckets) {
            let range = self.plan.range(b as usize);
            let BucketUp::Dense(v) = bu else {
                let msg =
                    format!("node {node}: bucket {b} carried a sparse payload on a dense path");
                return Err(reject(&mut self.conns[node], msg));
            };
            if v.len() != range.end - range.start {
                let msg = format!(
                    "node {node}: bucket {b} has {} values for a range of {}",
                    v.len(),
                    range.end - range.start
                );
                return Err(reject(&mut self.conns[node], msg));
            }
            full[range].copy_from_slice(&v);
        }
        Ok(full)
    }

    /// Overlapped sparse-EF replay: per node, decode each bucket-local
    /// packet, record per-bucket Values/Indices in bucket order, scatter
    /// into the mean, then price per-bucket tagged fan-out rounds —
    /// exactly `baselines::record_sparse_packet` + `fanout_rounds` in the
    /// sim.  Out-of-plan ranges reject with an [`Msg::Error`] frame.
    fn sparse_bucket_replay(
        &mut self,
        ups: &mut [Up],
        fp16: bool,
        shards: &mut [NodeLedger],
        net: &mut NetSim,
    ) -> Result<Vec<f32>> {
        let nodes = ups.len();
        let b_count = self.plan.len();
        let mut mean = vec![0.0f32; self.n_mid];
        let mut per_node: Vec<Vec<u64>> = Vec::with_capacity(nodes);
        for (node, up) in ups.iter_mut().enumerate() {
            if !self.alive[node] {
                // Same empty packet row the sim's masked exchange emits —
                // `fanout_rounds` tolerates short rows, so pricing matches.
                per_node.push(Vec::new());
                continue;
            }
            let MidUp::Buckets(nb) = up.mid else {
                bail!("node {node} sent {} on the overlapped sparse path", up.mid.name())
            };
            if nb as usize != b_count || up.buckets.len() != b_count {
                let msg = format!(
                    "node {node}: bucketed upload announced {nb} buckets, streamed {}, plan has \
                     {b_count}",
                    up.buckets.len()
                );
                return Err(reject(&mut self.conns[node], msg));
            }
            let mut frames: Vec<Option<BucketUp>> = vec![None; b_count];
            for (b, bu) in std::mem::take(&mut up.buckets) {
                frames[b as usize] = Some(bu);
            }
            let mut bytes_b = Vec::with_capacity(b_count);
            for (b, frame) in frames.into_iter().enumerate() {
                let range = self.plan.range(b);
                let width = range.end - range.start;
                // Valid + deduped ids and an exact count make every slot
                // Some; keep the reject path anyway (no panics on replay).
                let Some(BucketUp::Sparse { coded_idx, vals }) = frame else {
                    let msg = format!(
                        "node {node}: bucket {b} carried a dense payload on a sparse path"
                    );
                    return Err(reject(&mut self.conns[node], msg));
                };
                let idx = match index_coding::decode(&coded_idx, width) {
                    Ok(i) => i,
                    Err(e) => {
                        let msg = format!(
                            "node {node}: bucket {b} indices failed to decode over its range \
                             of {width}: {e:#}"
                        );
                        return Err(reject(&mut self.conns[node], msg));
                    }
                };
                if idx.len() != vals.len() {
                    let msg = format!(
                        "node {node}: bucket {b} has {} indices vs {} values",
                        idx.len(),
                        vals.len()
                    );
                    return Err(reject(&mut self.conns[node], msg));
                }
                let bytes = vals.len() * if fp16 { 2 } else { 4 };
                shards[node].record(Kind::Values, bytes);
                shards[node].record(Kind::Indices, coded_idx.len());
                bytes_b.push((bytes + coded_idx.len()) as u64);
                let global: Vec<u32> =
                    idx.iter().map(|&i| i + range.start as u32).collect();
                topk::scatter_add(&mut mean, &global, &vals);
            }
            per_node.push(bytes_b);
        }
        let live = live_count(&self.alive) as f32;
        mean.iter_mut().for_each(|m| *m /= live);
        fanout_rounds(net, true, b_count, &per_node);
        Ok(mean)
    }

    /// Mirror of the support half of `LgcCommon::leader_support_inner`
    /// (the EF accumulation + selection ran on the workers): account the
    /// leader's ordered-index broadcast and decode the shared support.
    fn support_replay(
        &self,
        leader: usize,
        support_coded: Option<&[u8]>,
        ledger: &mut Ledger,
        net: &mut NetSim,
    ) -> Result<Vec<u32>> {
        let coded = support_coded.context("support round without a support payload")?;
        let support = index_coding::decode_ordered(coded)?;
        ensure!(
            support.len() == self.meta.mu,
            "support has {} indices, expected mu={}",
            support.len(),
            self.meta.mu
        );
        ledger.record(leader, Kind::Indices, coded.len());
        net.send(leader, coded.len() as u64);
        net.barrier();
        Ok(support)
    }

    /// Phase-2 mirror (`LgcCommon::topk_phase`): exact value-vector
    /// accounting + the coordinator-resident AE's online training on the
    /// received vectors (same RNG stream, same inner steps — the loss
    /// trace and the downstream readiness gate stay bit-identical).
    #[allow(clippy::too_many_arguments)]
    fn topk_replay(
        &mut self,
        it: usize,
        ps: bool,
        ups: &mut [Up],
        support_coded: Option<&[u8]>,
        ledger: &mut Ledger,
        shards: &mut [NodeLedger],
        net: &mut NetSim,
    ) -> Result<Vec<f32>> {
        let nodes = ups.len();
        let n = self.n_mid;
        let mu = self.meta.mu;
        let leader = if ps { 0 } else { it % nodes };
        let support = self.support_replay(leader, support_coded, ledger, net)?;
        let trainer = it % nodes;
        let mut vvs: Vec<&[f32]> = Vec::with_capacity(nodes);
        for (node, up) in ups.iter().enumerate() {
            let MidUp::Vv(vv) = &up.mid else {
                bail!("node {node} sent {} in the top-k phase", up.mid.name())
            };
            ensure!(vv.len() == mu, "node {node} value-vector length {} != mu {mu}", vv.len());
            shards[node].record(Kind::Values, vv.len() * 4);
            if !ps && node != trainer {
                shards[node].record(Kind::Values, mu * 4);
            }
            vvs.push(vv);
        }
        let mut mean = vec![0.0f32; n];
        for vv in &vvs {
            topk::scatter_add(&mut mean, &support, vv);
        }
        mean.iter_mut().for_each(|m| *m /= nodes as f32);
        if ps {
            net.fanout((mu * 4) as u64);
        } else if nodes > 1 {
            ledger.record(trainer, Kind::Values, (nodes - 1) * mu * 4);
            net.broadcast(trainer, (mu * 4) as u64);
        }

        // Online AE training on the received value-vectors.
        let l = self.lgc.as_mut().expect("topk_replay only runs for LGC methods");
        let inner = self.cfg.ae_inner_steps.max(1);
        if ps {
            let frac = self.cfg.innovation_frac;
            let codec = self.cfg.index_codec;
            for node in 0..nodes {
                innovation_into(vvs[node], frac, codec, &mut l.inns[node], &mut l.scratches[node])?;
            }
            let inns: Vec<&[f32]> = l.inns.iter().map(|i| i.as_slice()).collect();
            for _ in 0..inner {
                let ridx = self.rng.below(nodes);
                l.ae.train_step(
                    self.engine,
                    &vvs,
                    Some(&inns),
                    ridx,
                    self.cfg.ae_lr,
                    1.0,
                    self.cfg.lambda2,
                )?;
            }
        } else {
            for _ in 0..inner {
                l.ae.train_step(self.engine, &vvs, None, 0, self.cfg.ae_lr, 1.0, 0.0)?;
            }
        }
        Ok(mean)
    }

    /// Phase-3 PS mirror (`LgcPs::exchange`, Compressed arm): innovations
    /// arrive coded from every worker, the latent from the leader; the
    /// master decodes per node, averages, clips, scatters.
    #[allow(clippy::too_many_arguments)]
    fn ps_compressed_replay(
        &mut self,
        ups: &mut [Up],
        support_coded: Option<&[u8]>,
        latents: Vec<(Vec<f32>, f32)>,
        ledger: &mut Ledger,
        shards: &mut [NodeLedger],
        net: &mut NetSim,
    ) -> Result<Vec<f32>> {
        let nodes = ups.len();
        let mu = self.meta.mu;
        let support = self.support_replay(0, support_coded, ledger, net)?;
        let mut s_ks = Vec::with_capacity(nodes);
        let mut inns: Vec<Vec<f32>> = Vec::with_capacity(nodes);
        for (node, up) in ups.iter().enumerate() {
            let MidUp::Innovation { coded_idx, vals, scale } = &up.mid else {
                bail!("node {node} sent {} in the engaged PS phase", up.mid.name())
            };
            let idx = index_coding::decode(coded_idx, mu)?;
            ensure!(
                idx.len() == vals.len(),
                "node {node}: {} innovation indices vs {} values",
                idx.len(),
                vals.len()
            );
            // innovation_into's wire bytes: values + coded indices (+4 B
            // RMS scale recorded by the caller).
            let bytes = vals.len() * 4 + coded_idx.len();
            shards[node].record(Kind::Values, bytes + 4);
            s_ks.push(*scale);
            inns.push(topk::scatter(mu, &idx, vals));
        }
        let l = self.lgc.as_mut().expect("ps replay only runs for LGC methods");
        let (latent, _s0) = latents.into_iter().next().context("leader latent missing")?;
        shards[0].record(Kind::Latent, l.ae.latent_bytes());
        let mut mean_vals = vec![0.0f32; mu];
        for (node, inn) in inns.iter().enumerate() {
            let rec = l.ae.decode_ps(self.engine, node, &latent, inn, s_ks[node])?;
            for (m, x) in mean_vals.iter_mut().zip(&rec) {
                *m += x;
            }
        }
        mean_vals.iter_mut().for_each(|m| *m /= nodes as f32);
        let ctrls = take_ctrl_grads(ups, self.n_mid)?;
        clip_to_gradient_scale(&mut mean_vals, &ctrls);
        net.fanout((mu * 4) as u64);
        Ok(topk::scatter(self.n_mid, &support, &mean_vals))
    }

    /// Phase-3 RAR mirror (`LgcRar::exchange`, Compressed arm): one-time
    /// AE-weight broadcast accounting, latent ring-allreduce on the
    /// received latents, shared decode, clip, scatter.
    fn rar_compressed_replay(
        &mut self,
        it: usize,
        ups: &mut [Up],
        support_coded: Option<&[u8]>,
        latents: Vec<(Vec<f32>, f32)>,
        ledger: &mut Ledger,
        net: &mut NetSim,
    ) -> Result<Vec<f32>> {
        let nodes = ups.len();
        {
            let l = self.lgc.as_mut().expect("rar replay only runs for LGC methods");
            if !l.oneoff_recorded {
                ledger.record_oneoff(it % nodes, Kind::AeWeights, l.ae.param_bytes() * (nodes - 1));
                net.broadcast_oneoff(it % nodes, l.ae.param_bytes() as u64);
                l.oneoff_recorded = true;
            }
        }
        let support = self.support_replay(it % nodes, support_coded, ledger, net)?;
        for (node, up) in ups.iter().enumerate() {
            ensure!(
                matches!(up.mid, MidUp::None),
                "node {node} sent {} in the engaged RAR phase",
                up.mid.name()
            );
        }
        let mut lat_vecs = Vec::with_capacity(nodes);
        let mut scales = Vec::with_capacity(nodes);
        for (lat, s) in latents {
            lat_vecs.push(lat);
            scales.push(s);
        }
        ensure!(lat_vecs.len() == nodes, "expected {nodes} latents, got {}", lat_vecs.len());
        let latent_avg =
            ring::ring_allreduce_mean_timed(&mut lat_vecs, ledger, Kind::Latent, Some(net));
        let scale_avg = scales.iter().sum::<f32>() / nodes as f32;
        let l = self.lgc.as_mut().expect("rar replay only runs for LGC methods");
        let mut rec = l.ae.decode_rar(self.engine, &latent_avg, scale_avg)?;
        let ctrls = take_ctrl_grads(ups, self.n_mid)?;
        clip_to_gradient_scale(&mut rec, &ctrls);
        Ok(topk::scatter(self.n_mid, &support, &rec))
    }

    /// Mirror of `Trainer::last_exchange` against received payloads.
    fn last_replay(
        &mut self,
        phase: Phase,
        ups: &mut [Up],
        shards: &mut [NodeLedger],
        net: &mut NetSim,
    ) -> Result<Vec<f32>> {
        let nodes = ups.len();
        let n = self.n_last;
        let dense = matches!(self.cfg.method, Method::Baseline | Method::Qsgd)
            || phase == Phase::Dense;
        if dense {
            let mut lasts = Vec::with_capacity(nodes);
            for (node, up) in ups.iter_mut().enumerate() {
                if !self.alive[node] {
                    lasts.push(Vec::new());
                    continue;
                }
                let LastUp::Dense(g) = &mut up.last else {
                    bail!("node {node} sent a sparse last-group payload on a dense path")
                };
                ensure!(g.len() == n, "node {node} last-group length {} != {n}", g.len());
                lasts.push(std::mem::take(g));
            }
            let mean = dense_mean_masked(&lasts, &self.alive, shards);
            net.fanout((n * 4) as u64);
            return Ok(mean);
        }
        let mut mean = vec![0.0f32; n];
        let mut total = 0u64;
        for (node, up) in ups.iter().enumerate() {
            if !self.alive[node] {
                continue;
            }
            let LastUp::Sparse { coded_idx, vals } = &up.last else {
                bail!("node {node} sent a dense last-group payload on a sparse path")
            };
            let idx = index_coding::decode(coded_idx, n)?;
            ensure!(
                idx.len() == vals.len(),
                "node {node}: {} last indices vs {} values",
                idx.len(),
                vals.len()
            );
            shards[node].record(Kind::Values, vals.len() * 4);
            shards[node].record(Kind::Indices, coded_idx.len());
            total += (vals.len() * 4 + coded_idx.len()) as u64;
            topk::scatter_add(&mut mean, &idx, vals);
        }
        let live = live_count(&self.alive) as f32;
        mean.iter_mut().for_each(|m| *m /= live);
        net.fanout(total);
        Ok(mean)
    }

    /// Mean loss/acc over the held-out eval batches (coordinator-only;
    /// workers never evaluate).
    fn evaluate(&self) -> Result<(f32, f32)> {
        let mut l = 0.0;
        let mut a = 0.0;
        for i in 0..self.cfg.eval_batches {
            let b = self.dataset.eval_batch(i);
            let (li, ai) = self.model.evaluate(self.engine, &b)?;
            l += li;
            a += ai;
        }
        let n = self.cfg.eval_batches as f32;
        Ok((l / n, a / n))
    }
}

/// Send a descriptive [`Msg::Error`] frame to the offending worker
/// (best-effort) and return the same text as the coordinator-side error —
/// the wire rejection path for malformed bucketed uploads (never a
/// panic).
fn reject(conn: &mut Conn, msg: String) -> anyhow::Error {
    let _ = conn.send(&Msg::Error { msg: msg.clone() });
    anyhow::anyhow!(msg)
}

/// Extract dense mid payloads from every live node (dense phases); dead
/// nodes contribute the empty vector every masked mean skips.
fn take_dense_mids(ups: &mut [Up], alive: &[bool]) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::with_capacity(ups.len());
    for (node, up) in ups.iter_mut().enumerate() {
        if !alive[node] {
            out.push(Vec::new());
            continue;
        }
        let MidUp::Dense(g) = &mut up.mid else {
            bail!("node {node} sent {} on a dense path", up.mid.name())
        };
        out.push(std::mem::take(g));
    }
    Ok(out)
}

/// Extract the raw mid gradients attached for the trust-region clip
/// (engaged LGC iterations only).
fn take_ctrl_grads(ups: &mut [Up], n_mid: usize) -> Result<Vec<Vec<f32>>> {
    let mut out = Vec::with_capacity(ups.len());
    for (node, up) in ups.iter_mut().enumerate() {
        let g = up.ctrl_mid.take().with_context(|| {
            format!("node {node} omitted the raw mid gradient on an engaged iteration")
        })?;
        ensure!(g.len() == n_mid, "node {node} raw mid gradient length {} != {n_mid}", g.len());
        out.push(g);
    }
    Ok(out)
}
