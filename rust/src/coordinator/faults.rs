//! Deterministic fault injection + liveness bookkeeping (DESIGN.md §14).
//!
//! A [`FaultPlan`] is parsed from `--faults
//! "iter=40:kill=2;iter=60:stall=1:500ms;iter=80:corrupt-frame=3"` and
//! executed at iteration boundaries by *both* backends: the simulator
//! perturbs its own loop and the fabric, the TCP coordinator kills or
//! stalls real worker child processes and mangles frames through the
//! [`crate::transport::Conn`] corruption shim.  Because the plan is part
//! of the config and fires on iteration indices (never wall-clock), every
//! recovery path is exercised by reproducible chaos tests instead of
//! hand-timed kills.
//!
//! What happens *after* a fault fires is the [`crate::config::OnFault`]
//! policy's job (fail / continue / wait-rejoin); this module only decides
//! *when and what* breaks, records what broke ([`FaultEvent`]), and keeps
//! the coordinator's per-node liveness clock ([`LivenessMonitor`]).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::{Method, OnFault, TrainConfig};

/// One injected fault, scheduled on an iteration index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultAction {
    /// Kill worker `node` (sim: the node goes silent; tcp: SIGKILL the
    /// child process).
    Kill { node: usize },
    /// Stall worker `node` for `ms` milliseconds (sim: priced into the
    /// fabric's modeled time; tcp: SIGSTOP / sleep / SIGCONT).
    Stall { node: usize, ms: u64 },
    /// Corrupt the next frame received from worker `node` (sim: priced as
    /// a retransmit; tcp: a byte of the next frame payload is flipped
    /// before decoding).
    CorruptFrame { node: usize },
    /// Crash the coordinator itself at the top of the iteration — the
    /// hook the crash-safe-resume tests use to interrupt a run at a
    /// planned point (`--resume` then proves bit-identity).
    Crash,
}

impl FaultAction {
    /// The node a fault targets (None for coordinator crashes).
    pub fn node(&self) -> Option<usize> {
        match self {
            FaultAction::Kill { node }
            | FaultAction::Stall { node, .. }
            | FaultAction::CorruptFrame { node } => Some(*node),
            FaultAction::Crash => None,
        }
    }

    /// Short action name for event logs.
    pub fn name(&self) -> &'static str {
        match self {
            FaultAction::Kill { .. } => "kill",
            FaultAction::Stall { .. } => "stall",
            FaultAction::CorruptFrame { .. } => "corrupt-frame",
            FaultAction::Crash => "crash",
        }
    }
}

/// One entry of a run's fault-event log ([`crate::coordinator::TrainResult`]
/// carries the full list; `lgc train` prints it; CI uploads it).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Iteration the event fired on.
    pub iter: usize,
    /// Affected node (None for coordinator-level events).
    pub node: Option<usize>,
    /// Action name (`kill`, `stall`, `corrupt-frame`, `crash`, plus
    /// recovery outcomes like `removed` or `rejoined`).
    pub kind: String,
    /// Human-readable description of what happened / how it was handled.
    pub detail: String,
}

impl FaultEvent {
    /// One `FAULT ...` log line (the artifact format CI uploads).
    pub fn log_line(&self) -> String {
        match self.node {
            Some(n) => format!("FAULT iter={} node={} {}: {}", self.iter, n, self.kind, self.detail),
            None => format!("FAULT iter={} {}: {}", self.iter, self.kind, self.detail),
        }
    }

    /// Fan the event out to every telemetry sink (DESIGN.md §15): the
    /// leveled stderr line (byte-identical to the historical `FAULT ...`
    /// print at the default level), a structured JSONL record when a run
    /// log is open, a trace instant event, and the matching Prometheus
    /// counter.  Shared by the sim and TCP coordinators so the two
    /// backends report faults identically.
    pub fn observe(&self, run_log: &mut Option<crate::obs::jsonl::RunLog>) -> anyhow::Result<()> {
        crate::log_info!("{}", self.log_line());
        crate::obs::trace::event(&self.log_line());
        match self.kind.as_str() {
            "kill" | "death" => crate::obs::metrics::inc_deaths(),
            "stall" => crate::obs::metrics::inc_stalls(),
            "corrupt-frame" => crate::obs::metrics::inc_decode_errors(),
            "rejoin" => crate::obs::metrics::inc_rejoins(),
            _ => {}
        }
        if let Some(log) = run_log {
            use crate::util::json::Json;
            log.record(
                "fault",
                vec![
                    ("iter", Json::Num(self.iter as f64)),
                    ("node", self.node.map_or(Json::Null, |n| Json::Num(n as f64))),
                    ("kind", Json::Str(self.kind.clone())),
                    ("detail", Json::Str(self.detail.clone())),
                ],
            )?;
        }
        Ok(())
    }
}

/// A parsed, iteration-indexed fault schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// (iteration, action), sorted by iteration (stable: spec order is
    /// preserved within one iteration).
    events: Vec<(usize, FaultAction)>,
}

impl FaultPlan {
    /// Parse a `--faults` spec.  Grammar (`;`-separated segments):
    ///
    /// ```text
    /// segment   := "iter=" N ":" action
    /// action    := "kill=" NODE | "stall=" NODE ":" duration
    ///            | "corrupt-frame=" NODE | "crash"
    /// duration  := N "ms" | N "s"
    /// ```
    ///
    /// Node ids are validated against `nodes`; every malformed input is a
    /// descriptive error, never a panic (fuzzed below).
    pub fn parse(spec: &str, nodes: usize) -> Result<FaultPlan> {
        let mut events: Vec<(usize, FaultAction)> = Vec::new();
        for seg in spec.split(';') {
            let seg = seg.trim();
            if seg.is_empty() {
                continue;
            }
            let mut parts = seg.split(':');
            let iter_part = parts.next().unwrap_or("");
            let iter = match iter_part.strip_prefix("iter=") {
                Some(n) => n
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad iteration {n:?} in --faults segment {seg:?}"))?,
                None => bail!("--faults segment {seg:?} must start with iter=N"),
            };
            let action_part = parts
                .next()
                .ok_or_else(|| anyhow::anyhow!("--faults segment {seg:?} is missing an action"))?
                .trim();
            let parse_node = |raw: &str| -> Result<usize> {
                let node = raw
                    .trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad node id {raw:?} in --faults segment {seg:?}"))?;
                if node >= nodes {
                    bail!(
                        "--faults segment {seg:?} targets node {node}, but the run has only \
                         {nodes} nodes (ids 0..{})",
                        nodes.saturating_sub(1)
                    );
                }
                Ok(node)
            };
            let action = if let Some(raw) = action_part.strip_prefix("kill=") {
                FaultAction::Kill { node: parse_node(raw)? }
            } else if let Some(raw) = action_part.strip_prefix("stall=") {
                let node = parse_node(raw)?;
                let dur = parts
                    .next()
                    .ok_or_else(|| {
                        anyhow::anyhow!("--faults stall in {seg:?} needs a duration (e.g. 500ms)")
                    })?
                    .trim();
                FaultAction::Stall { node, ms: parse_duration_ms(dur, seg)? }
            } else if let Some(raw) = action_part.strip_prefix("corrupt-frame=") {
                FaultAction::CorruptFrame { node: parse_node(raw)? }
            } else if action_part == "crash" {
                FaultAction::Crash
            } else {
                bail!(
                    "unknown --faults action {action_part:?} in segment {seg:?} \
                     (kill=N | stall=N:DUR | corrupt-frame=N | crash)"
                );
            };
            if let Some(extra) = parts.next() {
                bail!("trailing field {extra:?} in --faults segment {seg:?}");
            }
            events.push((iter, action));
        }
        events.sort_by_key(|&(it, _)| it);
        Ok(FaultPlan { events })
    }

    /// Whether any faults are scheduled.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Drain every action scheduled for iteration `it` (in spec order).
    /// Entries scheduled *before* `it` are dropped too — a resumed run
    /// never re-fires faults that belong to the interrupted prefix.
    pub fn take(&mut self, it: usize) -> Vec<FaultAction> {
        let mut fired = Vec::new();
        self.events.retain(|(when, action)| {
            if *when == it {
                fired.push(action.clone());
                false
            } else {
                *when > it
            }
        });
        fired
    }

    /// The nodes any scheduled kill/stall/corrupt targets (used by the
    /// TCP coordinator to validate that it can actually reach the target
    /// processes).
    pub fn targets_processes(&self) -> bool {
        self.events
            .iter()
            .any(|(_, a)| matches!(a, FaultAction::Kill { .. } | FaultAction::Stall { .. }))
    }
}

fn parse_duration_ms(raw: &str, seg: &str) -> Result<u64> {
    let (digits, mult) = if let Some(d) = raw.strip_suffix("ms") {
        (d, 1u64)
    } else if let Some(d) = raw.strip_suffix('s') {
        (d, 1000u64)
    } else {
        bail!("bad duration {raw:?} in --faults segment {seg:?} (expected e.g. 500ms or 2s)");
    };
    let n = digits
        .trim()
        .parse::<u64>()
        .map_err(|_| anyhow::anyhow!("bad duration {raw:?} in --faults segment {seg:?}"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("duration {raw:?} in --faults segment {seg:?} overflows"))
}

/// Reject configurations whose fault policy the selected method cannot
/// honor (loud errors, not silent fallbacks — same contract as
/// [`crate::coordinator::remote::gate_method`]).
pub fn validate_fault_config(cfg: &TrainConfig) -> Result<()> {
    if cfg.on_fault == OnFault::Continue {
        match cfg.method {
            Method::LgcPs | Method::LgcRar | Method::ScaleCom | Method::Qsgd => bail!(
                "--on-fault continue is not supported for method {} (its leader rotation / \
                 per-node quantization streams are indexed by the full node set); use \
                 --on-fault wait-rejoin instead",
                cfg.method.name()
            ),
            _ => {}
        }
    }
    if cfg.faults.is_some() {
        // Parse eagerly so a bad spec fails before any training work.
        FaultPlan::parse(cfg.faults.as_deref().unwrap_or(""), cfg.nodes)?;
    }
    if cfg.ckpt_every > 0 && cfg.checkpoint.is_none() {
        bail!("--ckpt-every needs --checkpoint PATH to write the periodic snapshots to");
    }
    if cfg.resume.is_some() && cfg.transport == crate::config::TransportKind::Tcp {
        bail!("--resume is sim-only for now; rerun with --transport sim");
    }
    Ok(())
}

/// The deterministic re-admission credential for `wait-rejoin`: both
/// sides derive it from (session, node), so a respawned worker needs only
/// `--rejoin-node N` and the session id it already has — and a stray
/// process that knows the session but fakes a node id still has to match
/// the mixed token.
pub fn rejoin_token(session: u64, node: usize) -> u64 {
    // splitmix64 finalizer over the pair.
    let mut z = session ^ (node as u64).wrapping_mul(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Coordinator-side liveness clock: last observed progress per worker,
/// plus the heartbeat parameters that turn "how long ago" into "how many
/// missed beats".  Death is detected by *absence of progress* — the
/// read-deadline on the socket fires — and this monitor turns that into a
/// budget-aware description (DESIGN.md §14's liveness state machine).
#[derive(Debug)]
pub struct LivenessMonitor {
    heartbeat_ms: u64,
    miss_budget: u32,
    last_progress: Vec<Instant>,
}

impl LivenessMonitor {
    pub fn new(nodes: usize, heartbeat_ms: u64, miss_budget: u32) -> LivenessMonitor {
        LivenessMonitor {
            heartbeat_ms,
            miss_budget,
            last_progress: vec![Instant::now(); nodes],
        }
    }

    /// Record that `node` made protocol progress (a real frame arrived or
    /// a send succeeded).
    pub fn observe(&mut self, node: usize) {
        self.last_progress[node] = Instant::now();
    }

    /// Describe `node`'s liveness state for an error message: how stale
    /// it is and how that relates to the configured miss budget.
    pub fn describe(&self, node: usize) -> String {
        let stale = self.last_progress[node].elapsed();
        if self.heartbeat_ms == 0 {
            return format!("node {node} last made progress {:.1}s ago", stale.as_secs_f64());
        }
        let missed = (stale.as_millis() as u64) / self.heartbeat_ms.max(1);
        format!(
            "node {node} last made progress {:.1}s ago (~{missed} heartbeat periods of {}ms; \
             miss budget {})",
            stale.as_secs_f64(),
            self.heartbeat_ms,
            self.miss_budget
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn parses_the_issue_example() {
        let mut p = FaultPlan::parse(
            "iter=40:kill=2;iter=60:stall=1:500ms;iter=80:corrupt-frame=3",
            8,
        )
        .unwrap();
        assert_eq!(p.take(40), vec![FaultAction::Kill { node: 2 }]);
        assert_eq!(p.take(41), vec![]);
        assert_eq!(p.take(60), vec![FaultAction::Stall { node: 1, ms: 500 }]);
        assert_eq!(p.take(80), vec![FaultAction::CorruptFrame { node: 3 }]);
        assert!(p.is_empty());
    }

    #[test]
    fn crash_and_seconds_durations() {
        let mut p = FaultPlan::parse("iter=5:stall=0:2s;iter=5:crash", 2).unwrap();
        assert_eq!(
            p.take(5),
            vec![FaultAction::Stall { node: 0, ms: 2000 }, FaultAction::Crash]
        );
    }

    #[test]
    fn overlapping_iters_fire_in_spec_order() {
        let mut p = FaultPlan::parse("iter=3:kill=1;iter=3:kill=0", 4).unwrap();
        assert_eq!(
            p.take(3),
            vec![FaultAction::Kill { node: 1 }, FaultAction::Kill { node: 0 }]
        );
    }

    #[test]
    fn stale_entries_dropped_on_resume() {
        let mut p = FaultPlan::parse("iter=3:kill=1;iter=9:kill=0", 4).unwrap();
        // A resumed run starting at iteration 5 never re-fires iter 3.
        assert_eq!(p.take(5), vec![]);
        assert_eq!(p.take(9), vec![FaultAction::Kill { node: 0 }]);
    }

    #[test]
    fn out_of_range_node_rejected() {
        let e = FaultPlan::parse("iter=1:kill=4", 4).unwrap_err();
        assert!(e.to_string().contains("node 4"), "{e}");
        assert!(FaultPlan::parse("iter=1:stall=9:1ms", 4).is_err());
        assert!(FaultPlan::parse("iter=1:corrupt-frame=100", 4).is_err());
    }

    #[test]
    fn garbage_specs_are_errors() {
        for bad in [
            "kill=2",
            "iter=x:kill=1",
            "iter=1",
            "iter=1:explode=2",
            "iter=1:stall=1",
            "iter=1:stall=1:fast",
            "iter=1:stall=1:-5ms",
            "iter=1:kill=1:extra",
            "iter=1:stall=1:99999999999999999999ms",
            "iter=1:crash:now",
        ] {
            assert!(FaultPlan::parse(bad, 4).is_err(), "{bad:?} must be rejected");
        }
        // Empty / whitespace / stray separators are fine (empty plan).
        for ok in ["", "  ", ";", "; ;"] {
            assert!(FaultPlan::parse(ok, 4).unwrap().is_empty());
        }
    }

    /// Never-panic fuzz over hostile specs (satellite: the parser is fed
    /// attacker-shaped strings and must always return, Ok or Err).
    #[test]
    fn parser_never_panics_on_hostile_input() {
        let mut rng = Rng::new(0xFA_015);
        let alphabet: Vec<char> =
            "iter=kilstacorup-fmh;:0123456789xms \u{7f}\u{0}=;;".chars().collect();
        for case in 0..500 {
            let len = rng.below(40);
            let s: String =
                (0..len).map(|_| alphabet[rng.below(alphabet.len())]).collect();
            let nodes = 1 + rng.below(9);
            let _ = FaultPlan::parse(&s, nodes); // must not panic
            let _ = case;
        }
        // Structured-but-wrong inputs too.
        for case in 0..200 {
            let s = format!(
                "iter={}:kill={};iter={}:stall={}:{}ms",
                rng.below(1000),
                rng.below(20),
                rng.below(1000),
                rng.below(20),
                rng.below(10_000)
            );
            let _ = FaultPlan::parse(&s, 1 + rng.below(8));
            let _ = case;
        }
    }

    #[test]
    fn rejoin_token_is_deterministic_and_node_specific() {
        let a = rejoin_token(0xE2E1, 2);
        assert_eq!(a, rejoin_token(0xE2E1, 2));
        assert_ne!(a, rejoin_token(0xE2E1, 3));
        assert_ne!(a, rejoin_token(0xE2E2, 2));
    }

    #[test]
    fn validate_rejects_continue_for_leaderful_methods() {
        let mut cfg = TrainConfig { on_fault: OnFault::Continue, ..Default::default() };
        cfg.method = Method::LgcPs;
        assert!(validate_fault_config(&cfg).is_err());
        cfg.method = Method::ScaleCom;
        assert!(validate_fault_config(&cfg).is_err());
        cfg.method = Method::SparseGd;
        assert!(validate_fault_config(&cfg).is_ok());
    }

    #[test]
    fn validate_rejects_ckpt_every_without_path() {
        let cfg = TrainConfig { ckpt_every: 10, ..Default::default() };
        assert!(validate_fault_config(&cfg).is_err());
        let cfg = TrainConfig {
            ckpt_every: 10,
            checkpoint: Some("/tmp/x".into()),
            ..Default::default()
        };
        assert!(validate_fault_config(&cfg).is_ok());
    }
}
