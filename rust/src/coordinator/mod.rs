//! L3 coordinator: the distributed-training loop (paper §V).
//!
//! [`Trainer`] simulates K synchronous data-parallel nodes inside one
//! process: every node is a (data shard, error-feedback memory) pair; the
//! model parameters are stored once because synchronous SGD keeps replicas
//! identical.  All compute (grad steps, eval, autoencoder) executes through
//! the PJRT runtime; all communication flows through byte-accounted
//! exchanges (see [`crate::metrics::Ledger`]).
//!
//! Execution model (DESIGN.md §6.5): within each iteration, the per-node
//! work — grad-shard compute, error-feedback updates, compress/encode —
//! fans out across worker threads via [`parallel`], with each node owning
//! its state (data stream, EF memory, ledger shard).  The exchange steps
//! (PS gather, ring reduce-scatter/allgather, leader broadcasts) are
//! explicit synchronization barriers that always reduce in node order, so
//! curves and ledgers are bit-identical across thread counts.
//!
//! Per-group gradient handling (paper §VI-A):
//!   first layer — always dense (all methods)
//!   mid layers  — the selected [`MidStrategy`] (baselines or LGC)
//!   last layer  — dense for Baseline/QSGD; top-k + EF for sparse methods

pub mod bucket;
pub mod faults;
pub mod lgc;
pub mod parallel;
pub mod remote;
pub mod ring;
pub mod scheduler;
pub mod worker;

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::baselines::{
    dense_mean_masked, live_count, sparse_ef_exchange, Baseline, Dgc, ExchangeCtx,
    HardThreshold, MidStrategy, Qsgd, ScaleCom, SparseGd,
};
use crate::compress::{Correction, FeedbackMemory, Scratch};
use crate::config::{Method, OnFault, TrainConfig, TransportKind};
use crate::data::{self, Dataset};
use crate::metrics::{Ledger, NodeLedger};
use crate::model::{checkpoint, Group, Model};
use crate::net::{LinkModel, NetReport, NetSim};
use crate::obs::{jsonl, trace};
use crate::runtime::Engine;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::ser::{self, Reader};
use bucket::{method_bucketable, BucketPlan};
use faults::{FaultAction, FaultEvent, FaultPlan};
use scheduler::{phase_and_alpha, Phase};

/// Step LR decay mirroring the paper's schedule ("initial learning rate of
/// 0.1 that decays by 10 every 30 epochs" over ~90 epochs, SS VI-B):
/// x1 for the first half, x0.1 to 80%, x0.01 after.  Besides fidelity,
/// this is what keeps EF methods from blowing up logits after the
/// separable synthetic tasks are fully fit.
pub fn lr_at(cfg: &TrainConfig, it: usize) -> f32 {
    if it < cfg.steps / 2 {
        cfg.lr
    } else if it < cfg.steps * 4 / 5 {
        cfg.lr * 0.1
    } else {
        cfg.lr * 0.01
    }
}

/// One recorded training point.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub iter: usize,
    pub train_loss: f32,
    pub train_acc: f32,
}

/// Everything a finished run hands to the experiment drivers: curves,
/// evals, the measured byte ledger, wall-clock breakdowns, AE traces,
/// and the network fabric's modeled-time report.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub method: Method,
    pub model: String,
    pub nodes: usize,
    pub steps: usize,
    pub curve: Vec<CurvePoint>,
    /// (iter, eval_loss, eval_acc) on held-out batches.
    pub evals: Vec<(usize, f32, f32)>,
    pub ledger: Ledger,
    pub phase_time: [Duration; 3],
    pub phase_iters: [usize; 3],
    /// AE (rec, sim) loss trace (empty for baselines) — Fig. 14.
    pub ae_losses: Vec<(f32, f32)>,
    pub final_eval: (f32, f32),
    /// Uncompressed per-node bytes/iteration (the CR denominator).
    pub dense_bytes_per_node: u64,
    /// Wall-clock breakdown: grad-step HLO, mid exchange (incl. AE HLOs),
    /// first/last exchanges + optimizer update, per training phase.
    pub time_grad: Duration,
    pub time_exchange: Duration,
    pub time_update: Duration,
    /// Measured per-iteration wall-clock seconds `(grad_s, exchange_s)`,
    /// recorded by both backends — the measured side `exp validate-net`
    /// joins against the fabric's modeled rounds (DESIGN.md §15.5).
    pub iter_wall: Vec<(f32, f32)>,
    /// The simulated network fabric's recorded trace + pricing — the
    /// per-node modeled time ledger (DESIGN.md §11).
    pub net: NetReport,
    /// Every injected/observed fault this run handled, in execution order
    /// (DESIGN.md §14).  Empty for fault-free runs.
    pub fault_events: Vec<FaultEvent>,
}

impl TrainResult {
    /// Steady-state mean uplink bytes/iteration across all nodes.
    /// The window never reaches back past the start of phase 3 (or the
    /// final phase actually reached), so warmup traffic is excluded.
    pub fn steady_total_bytes_per_iter(&self, window: usize) -> f64 {
        let steady_iters = *self.phase_iters.iter().rev().find(|&&n| n > 0).unwrap_or(&1);
        self.ledger.steady_bytes_per_iter(window.min(steady_iters.max(1)))
    }

    /// Compression ratio vs uncompressed dense training (mean node,
    /// steady state) — the paper's "Ratio" column.
    pub fn compression_ratio(&self) -> f64 {
        let per_node = self.steady_total_bytes_per_iter(50) / self.nodes as f64;
        self.dense_bytes_per_node as f64 / per_node.max(1e-9)
    }

    /// Mean steady-state bytes/iter per node ("Info size" column, MB).
    pub fn info_size_mb(&self) -> f64 {
        self.steady_total_bytes_per_iter(50) / self.nodes as f64 / 1e6
    }

    /// Train loss at the last recorded iteration (NaN for empty runs).
    pub fn final_train_loss(&self) -> f32 {
        self.curve.last().map(|c| c.train_loss).unwrap_or(f32::NAN)
    }

    /// Steady-state modeled communication seconds per iteration under
    /// `link` (same steady-state window rule as
    /// [`TrainResult::steady_total_bytes_per_iter`]; straggler
    /// multipliers stay those the run was recorded with).
    pub fn steady_comm_s_at(&self, link: LinkModel, window: usize) -> f64 {
        self.steady_comm_s_under(&self.net.fabric.with_link(link), window)
    }

    /// [`TrainResult::steady_comm_s_at`] under an arbitrary fabric
    /// (different link and/or straggler multipliers) — scenario sweeps
    /// reprice one recorded run instead of retraining (ablation A5).
    pub fn steady_comm_s_under(&self, fabric: &crate::net::Fabric, window: usize) -> f64 {
        let steady_iters = *self.phase_iters.iter().rev().find(|&&n| n > 0).unwrap_or(&1);
        self.net.steady_comm_s_under(fabric, window.min(steady_iters.max(1)))
    }
}

/// Modeled retransmit window charged to a node whose frame arrives
/// corrupted in the simulated backend (detected by frame CRC,
/// retransmitted once): a fixed, deterministic stall (DESIGN.md §14).
const CORRUPT_RETRANSMIT_S: f64 = 0.05;

/// Configuration fingerprint stored in resume checkpoints: the Debug
/// rendering of the config with every resume-orthogonal field normalized
/// away — the fault/checkpoint plumbing itself plus fields the
/// bit-identity contracts prove irrelevant (thread count, verbosity, and
/// the telemetry knobs, which by the §15 contract never touch the math).
fn cfg_fingerprint(cfg: &TrainConfig) -> String {
    let mut c = cfg.clone();
    c.resume = None;
    c.faults = None;
    c.checkpoint = None;
    c.ckpt_every = 0;
    c.verbose = false;
    c.threads = 0;
    c.trace_out = None;
    c.log_json = None;
    c.metrics_addr = None;
    c.log_level = crate::obs::log::Level::Info;
    format!("{c:?}")
}

/// Build the mid-group strategy for a config.
fn make_strategy(
    engine: &Engine,
    cfg: &TrainConfig,
    n_mid: usize,
    mu: usize,
) -> Result<Box<dyn MidStrategy>> {
    let ramp = cfg.warmup_iters + cfg.ae_train_iters;
    Ok(match cfg.method {
        Method::Baseline => Box::new(Baseline),
        Method::SparseGd => Box::new(SparseGd::new(cfg.nodes, n_mid, cfg.alpha)),
        Method::Dgc => Box::new(Dgc::new(cfg.nodes, n_mid, cfg.alpha, ramp, cfg.momentum)),
        Method::ScaleCom => Box::new(ScaleCom::new(cfg.nodes, n_mid, cfg.alpha, cfg.momentum)),
        Method::Qsgd => {
            Box::new(Qsgd::new(cfg.qsgd_levels, 512, cfg.nodes, cfg.seed ^ 0x45D0))
        }
        Method::Threshold => Box::new(HardThreshold::new(cfg.nodes, n_mid, cfg.alpha)),
        Method::LgcPs => {
            let p = lgc::LgcParams {
                momentum: cfg.momentum,
                innovation_frac: cfg.innovation_frac,
                ae_lr: cfg.ae_lr,
                lambda2: cfg.lambda2,
                ae_inner_steps: cfg.ae_inner_steps,
                ae_gate: cfg.ae_gate,
                seed: cfg.seed ^ 0xAE,
            };
            Box::new(lgc::LgcPs::new(engine, cfg.nodes, n_mid, mu, p)?)
        }
        Method::LgcRar => {
            let p = lgc::LgcParams {
                momentum: cfg.momentum,
                innovation_frac: cfg.innovation_frac,
                ae_lr: cfg.ae_lr,
                lambda2: 0.0,
                ae_inner_steps: cfg.ae_inner_steps,
                ae_gate: cfg.ae_gate,
                seed: cfg.seed ^ 0xAE,
            };
            Box::new(lgc::LgcRar::new(engine, cfg.nodes, n_mid, mu, p)?)
        }
    })
}

/// The assembled training loop for one [`TrainConfig`]: model, data
/// shards, mid-group strategy, per-node EF memories and scratch arenas.
pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub cfg: TrainConfig,
    pub model: Model,
    dataset: Box<dyn Dataset>,
    strategy: Box<dyn MidStrategy>,
    /// Per-node EF memories for the last-layer group (sparse methods).
    last_fbs: Vec<FeedbackMemory>,
    /// Per-node scratch arenas (DESIGN.md §6.11), created once next to
    /// the ledger shards and lent to every exchange stage; buffers reach
    /// their high-water mark in the first iterations and the steady state
    /// allocates nothing on the encode path.
    arenas: Vec<Scratch>,
    /// Mid-group bucket plan (DESIGN.md §13): layer-boundary-derived
    /// contiguous ranges for bucketable methods, single-bucket otherwise.
    plan: BucketPlan,
    /// Last-group plan: always single-bucket (the classifier head is
    /// small; bucketing it would buy nothing and complicate the wire
    /// ledger contract).
    last_plan: BucketPlan,
    /// Effective overlap mode: `cfg.overlap` and a real multi-bucket plan.
    overlap: bool,
    rng: Rng,
    /// Liveness mask (DESIGN.md §14): flipped false by `kill` faults under
    /// `--on-fault continue`; all-true otherwise.
    alive: Vec<bool>,
}

impl<'e> Trainer<'e> {
    /// Resolve the model, build the strategy and all per-node state.
    pub fn new(engine: &'e Engine, mut cfg: TrainConfig) -> Result<Trainer<'e>> {
        // Backend-portable model resolution: missing names fall back to
        // the manifest's reference workload (native backend).
        let meta = engine.manifest.resolve_model(&cfg.model).clone();
        cfg.model = meta.name.clone();
        let mut model = Model::new(&meta, cfg.seed);
        // Momentum lives in the optimizer for Baseline/QSGD, and in the
        // EF memories (momentum correction) for the sparse methods
        // (Table III / DGC §3.2) — not in both.
        model.momentum = match cfg.method {
            Method::Baseline | Method::Qsgd => cfg.momentum,
            _ => 0.0,
        };
        model.weight_decay = cfg.weight_decay;
        let dataset = data::for_model(&meta, cfg.seed ^ 0xDA7A);
        let n_mid = meta.group_len(&meta.mid_param_idx);
        let strategy = make_strategy(engine, &cfg, n_mid, meta.mu)?;
        let n_last = meta.group_len(&meta.last_param_idx);
        let last_correction = match cfg.method {
            Method::SparseGd | Method::Threshold => Correction::Plain,
            _ => Correction::Momentum,
        };
        let last_fbs = (0..cfg.nodes)
            .map(|_| FeedbackMemory::new(n_last, last_correction, cfg.momentum))
            .collect();
        let arenas = Scratch::for_nodes(cfg.nodes);
        // Bucket plan over the mid group's layer boundaries (§13); the
        // same pure derivation runs in the TCP coordinator and in every
        // worker process, so no plan negotiation happens on the wire.
        let plan = if method_bucketable(cfg.method) {
            let layers: Vec<std::ops::Range<usize>> =
                model.layer_slices(Group::Mid).into_iter().map(|(_, r)| r).collect();
            BucketPlan::for_group(n_mid, &layers, &cfg)
        } else {
            BucketPlan::single(n_mid)
        };
        let overlap = cfg.overlap && !plan.is_single();
        let last_plan = BucketPlan::single(n_last);
        let rng = Rng::new(cfg.seed ^ 0x7124);
        let alive = vec![true; cfg.nodes];
        Ok(Trainer {
            engine,
            cfg,
            model,
            dataset,
            strategy,
            last_fbs,
            arenas,
            plan,
            last_plan,
            overlap,
            rng,
            alive,
        })
    }

    /// Last-layer exchange: dense for Baseline/QSGD (and everyone's dense
    /// phase), top-k + EF otherwise (§VI-A: "top-magnitude values ...
    /// without further compression").  The sparse branch routes through
    /// the same [`sparse_ef_exchange`] machinery as SparseGd/Dgc — one
    /// owner of the EF -> select -> encode -> scatter-mean sequence
    /// instead of a duplicated copy here — always on the single-bucket
    /// last-group plan, with value payloads at full precision (the
    /// paper's "without further compression").
    fn last_exchange(
        &mut self,
        phase: Phase,
        grads: &[Vec<f32>],
        shards: &mut [NodeLedger],
        net: &mut NetSim,
    ) -> Result<Vec<f32>> {
        let dense = matches!(self.cfg.method, Method::Baseline | Method::Qsgd)
            || phase == Phase::Dense;
        if dense {
            let mean = dense_mean_masked(grads, &self.alive, shards);
            net.fanout((mean.len() * 4) as u64);
            return Ok(mean);
        }
        sparse_ef_exchange(
            &mut self.last_fbs,
            grads,
            self.cfg.alpha,
            false,
            self.cfg.index_codec,
            shards,
            &mut self.arenas,
            self.cfg.threads,
            &self.last_plan,
            false,
            net,
            &self.alive,
        )
    }

    /// Run the full training loop.
    pub fn run(mut self) -> Result<TrainResult> {
        let meta = self.model.meta.clone();
        let threads = self.cfg.threads;
        let mut ledger = Ledger::new();
        let mut shards = NodeLedger::for_nodes(self.cfg.nodes);
        // The simulated network fabric records this run's event trace
        // alongside the byte ledger (DESIGN.md §11).
        let mut net = NetSim::new(self.cfg.fabric(), self.cfg.nodes);
        let mut curve = Vec::with_capacity(self.cfg.steps);
        let mut evals = Vec::new();
        let mut phase_time = [Duration::ZERO; 3];
        let mut phase_iters = [0usize; 3];
        let mut time_grad = Duration::ZERO;
        let mut time_exchange = Duration::ZERO;
        let mut time_update = Duration::ZERO;
        // Deterministic fault plan + the events it produces (DESIGN.md
        // §14).  Parsed up front so a bad spec fails before any compute.
        let mut fault_plan = match &self.cfg.faults {
            Some(spec) => FaultPlan::parse(spec, self.cfg.nodes)?,
            None => FaultPlan::default(),
        };
        let mut fault_events: Vec<FaultEvent> = Vec::new();
        // Structured run log (--log-json, DESIGN.md §15.3): manifest
        // first, then one record per iteration and per fault event.
        let mut run_log = match &self.cfg.log_json {
            Some(p) => Some(jsonl::RunLog::create(p)?),
            None => None,
        };
        if let Some(log) = &mut run_log {
            log.record(
                "run_start",
                vec![
                    ("method", Json::Str(self.cfg.method.name().to_string())),
                    ("model", Json::Str(self.cfg.model.clone())),
                    ("nodes", Json::Num(self.cfg.nodes as f64)),
                    ("steps", Json::Num(self.cfg.steps as f64)),
                    ("transport", Json::Str("sim".to_string())),
                    ("backend", Json::Str(self.engine.platform())),
                    ("git", Json::Str(jsonl::git_describe())),
                    ("seed", Json::Num(self.cfg.seed as f64)),
                    ("cfg_fingerprint", Json::Str(cfg_fingerprint(&self.cfg))),
                ],
            )?;
        }
        // Measured (grad_s, exchange_s) per iteration — the measured side
        // of `exp validate-net` (DESIGN.md §15.5).
        let mut iter_wall: Vec<(f32, f32)> = Vec::with_capacity(self.cfg.steps);
        // Previous-iteration cumulative per-kind bytes, for the JSONL
        // per-iteration kind breakdown (deltas of a 5-entry map).
        let mut prev_kind = std::collections::BTreeMap::new();
        // Previous cumulative per-node uplink bytes, for the Prometheus
        // per-worker byte counters.
        let mut prev_node_bytes: Vec<u64> = vec![0; self.cfg.nodes];
        // Crash-safe resume: restore every piece of loop state from the
        // blob checkpoint, then continue from the recorded iteration.
        // Contract (tests/native_e2e.rs): a run cut at iteration t and
        // resumed is bit-identical to an uninterrupted run.
        let start_iter = match self.cfg.resume.clone() {
            Some(path) => self.restore_train_state(
                &path,
                &mut phase_iters,
                &mut fault_events,
                &mut curve,
                &mut evals,
                &mut ledger,
                &mut net,
            )?,
            None => 0,
        };

        for it in start_iter..self.cfg.steps {
            trace::set_iter(it);
            let (phase, _alpha) = phase_and_alpha(&self.cfg, it);
            // Injected faults fire at the iteration boundary, before any
            // compute; `FaultPlan::take` also drops entries behind a
            // resumed run so prefix faults never re-fire.
            for action in fault_plan.take(it) {
                self.execute_sim_fault(it, action, &mut net, &mut fault_events, &mut run_log)?;
            }
            ledger.set_phase(phase.index() as u8 + 1);
            let t0 = Instant::now();

            // --- local compute: one grad step per node, fanned out ------
            let t_grad0 = Instant::now();
            let engine = self.engine;
            let model = &self.model;
            let dataset = &*self.dataset;
            let method_name = self.cfg.method.name();
            let lr_cfg = self.cfg.lr;
            let alive = &self.alive;
            type NodeGrads = (f32, f32, Vec<f32>, Vec<f32>, Vec<f32>);
            let per_node = parallel::collect_node_results(parallel::par_map_indexed(
                threads,
                self.cfg.nodes,
                |node| -> Result<NodeGrads> {
                    if !alive[node] {
                        // Dead node under --on-fault continue: no compute,
                        // empty placeholders the masked exchanges skip.
                        return Ok((0.0, 0.0, Vec::new(), Vec::new(), Vec::new()));
                    }
                    let _lane = trace::lane_scope(node);
                    let _sp = trace::span(trace::Stage::Grad);
                    let batch = dataset.batch(node, it);
                    let (loss, acc, grads) = model.grad_step(engine, &batch)?;
                    anyhow::ensure!(
                        loss.is_finite(),
                        "training diverged: non-finite loss at iter {it}, node {node} \
                         (method {method_name}, lr {lr_cfg})"
                    );
                    Ok((
                        loss,
                        acc,
                        model.flatten_group(&grads, Group::First),
                        model.flatten_group(&grads, Group::Mid),
                        model.flatten_group(&grads, Group::Last),
                    ))
                },
            ))?;
            let mut first_g = Vec::with_capacity(self.cfg.nodes);
            let mut mid_g = Vec::with_capacity(self.cfg.nodes);
            let mut last_g = Vec::with_capacity(self.cfg.nodes);
            let mut loss_sum = 0.0f32;
            let mut acc_sum = 0.0f32;
            for (loss, acc, first, mid, last) in per_node {
                loss_sum += loss;
                acc_sum += acc;
                first_g.push(first);
                mid_g.push(mid);
                last_g.push(last);
            }
            let dt_grad = t_grad0.elapsed();
            time_grad += dt_grad;

            // --- exchanges (synchronization barriers) -------------------
            let t_ex0 = Instant::now();
            let sp_ex = trace::span(trace::Stage::Exchange);
            // First layer: always dense (all methods, §VI-A), PS-style
            // scatter of the aggregate on the fabric.
            let first_mean = dense_mean_masked(&first_g, &self.alive, &mut shards);
            net.fanout((first_mean.len() * 4) as u64);

            let mid_mean = {
                let mut ctx = ExchangeCtx {
                    engine: self.engine,
                    ledger: &mut ledger,
                    shards: &mut shards,
                    iter: it,
                    phase,
                    alpha: self.cfg.alpha,
                    fp16: self.cfg.fp16_values,
                    codec: self.cfg.index_codec,
                    rng: &mut self.rng,
                    threads,
                    scratches: &mut self.arenas,
                    net: &mut net,
                    plan: &self.plan,
                    overlap: self.overlap,
                    alive: &self.alive,
                };
                self.strategy.exchange(&mut ctx, &mid_g)?
            };
            let last_mean = self.last_exchange(phase, &last_g, &mut shards, &mut net)?;
            drop(sp_ex);
            let dt_ex = t_ex0.elapsed();
            time_exchange += dt_ex;

            // --- update -------------------------------------------------
            let t_up0 = Instant::now();
            let sp_up = trace::span(trace::Stage::Update);
            self.model.apply_update(
                &[
                    (Group::First, first_mean),
                    (Group::Mid, mid_mean),
                    (Group::Last, last_mean),
                ],
                lr_at(&self.cfg, it),
            );
            drop(sp_up);
            let dt_up = t_up0.elapsed();
            time_update += dt_up;
            // Close the iteration through the scheduler — the single
            // owner of the close-out sequence (fan-in round, shard merge,
            // iteration boundaries) shared with the TCP coordinator.
            scheduler::close_iteration(&mut ledger, &mut shards, &mut net);

            let dt = t0.elapsed();
            phase_time[phase.index()] += dt;
            phase_iters[phase.index()] += 1;

            // Dead nodes contributed 0.0 to the sums; the recorded means
            // average over the survivors (== all nodes when fault-free).
            let live = live_count(&self.alive) as f32;
            curve.push(CurvePoint {
                iter: it,
                train_loss: loss_sum / live,
                train_acc: acc_sum / live,
            });
            iter_wall.push((dt_grad.as_secs_f32(), dt_ex.as_secs_f32()));

            // Telemetry fan-out (all no-ops when nothing is installed;
            // never feeds back into the math — DESIGN.md §15 contract).
            if crate::obs::metrics::current().is_some() {
                crate::obs::metrics::inc_iterations();
                crate::obs::metrics::observe_stage("grad", dt_grad);
                crate::obs::metrics::observe_stage("exchange", dt_ex);
                crate::obs::metrics::observe_stage("update", dt_up);
                for (&node, &b) in &ledger.per_node {
                    if let Some(prev) = prev_node_bytes.get_mut(node) {
                        crate::obs::metrics::add_bytes_up(node, b - *prev);
                        *prev = b;
                    }
                }
                for (node, &is_live) in self.alive.iter().enumerate() {
                    if is_live {
                        crate::obs::metrics::mark_progress(node);
                    }
                }
            }
            if let Some(log) = &mut run_log {
                let mut kinds: Vec<(&str, Json)> = Vec::new();
                for (&k, &v) in &ledger.per_kind {
                    let d = v - prev_kind.get(&k).copied().unwrap_or(0);
                    if d > 0 {
                        kinds.push((k.name(), Json::Num(d as f64)));
                    }
                }
                prev_kind = ledger.per_kind.clone();
                let iter_total = ledger.iter_bytes.last().copied().unwrap_or(0);
                let dense = (meta.n_params * 4 * live_count(&self.alive)) as u64;
                let ratio = dense as f64 / (iter_total as f64).max(1e-9);
                log.record(
                    "iteration",
                    vec![
                        ("iter", Json::Num(it as f64)),
                        ("phase", Json::Str(phase.name().to_string())),
                        ("train_loss", Json::Num(f64::from(loss_sum / live))),
                        ("train_acc", Json::Num(f64::from(acc_sum / live))),
                        ("bytes_total", Json::Num(iter_total as f64)),
                        ("bytes_by_kind", jsonl::obj(kinds)),
                        ("compression_ratio", Json::Num(ratio)),
                        ("grad_s", Json::Num(f64::from(dt_grad.as_secs_f32()))),
                        ("exchange_s", Json::Num(f64::from(dt_ex.as_secs_f32()))),
                        ("update_s", Json::Num(f64::from(dt_up.as_secs_f32()))),
                    ],
                )?;
            }

            if self.cfg.eval_every > 0 && (it + 1) % self.cfg.eval_every == 0 {
                let (l, a) = self.evaluate()?;
                evals.push((it, l, a));
                if self.cfg.verbose {
                    crate::log_info!(
                        "[{}] it {:>5} phase {:<10} train_loss {:.4} eval_loss {:.4} eval_acc {:.4}",
                        self.strategy.name(),
                        it,
                        phase.name(),
                        curve.last().unwrap().train_loss,
                        l,
                        a
                    );
                }
            }

            // Periodic crash-safe snapshot (--ckpt-every): the full
            // training state at this iteration boundary, written
            // atomically (temp + fsync + rename) so a crash mid-write
            // leaves the previous snapshot intact.
            if self.cfg.ckpt_every > 0 && (it + 1) % self.cfg.ckpt_every == 0 {
                let path = self
                    .cfg
                    .checkpoint
                    .clone()
                    .expect("validated: --ckpt-every requires --checkpoint");
                self.save_train_state(
                    &path,
                    it + 1,
                    &phase_iters,
                    &fault_events,
                    &curve,
                    &evals,
                    &ledger,
                    &net,
                )?;
            }
        }

        let final_eval = self.evaluate()?;
        if let Some(path) = &self.cfg.checkpoint {
            self.model.save_checkpoint(path)?;
        }
        if let Some(mut log) = run_log.take() {
            log.record(
                "run_end",
                vec![
                    ("final_eval_loss", Json::Num(f64::from(final_eval.0))),
                    ("final_eval_acc", Json::Num(f64::from(final_eval.1))),
                    ("total_bytes", Json::Num(ledger.total() as f64)),
                    ("fault_events", Json::Num(fault_events.len() as f64)),
                ],
            )?;
            log.finish()?;
        }
        Ok(TrainResult {
            method: self.cfg.method,
            model: self.cfg.model.clone(),
            nodes: self.cfg.nodes,
            steps: self.cfg.steps,
            curve,
            evals,
            ledger,
            phase_time,
            phase_iters,
            ae_losses: self.strategy.ae_losses().to_vec(),
            final_eval,
            dense_bytes_per_node: (meta.n_params * 4) as u64,
            time_grad,
            time_exchange,
            time_update,
            iter_wall,
            net: net.into_report(),
            fault_events,
        })
    }

    /// Execute one planned fault in the simulated backend (DESIGN.md §14).
    fn execute_sim_fault(
        &mut self,
        it: usize,
        action: FaultAction,
        net: &mut NetSim,
        events: &mut Vec<FaultEvent>,
        run_log: &mut Option<jsonl::RunLog>,
    ) -> Result<()> {
        fn push(
            events: &mut Vec<FaultEvent>,
            run_log: &mut Option<jsonl::RunLog>,
            ev: FaultEvent,
        ) -> Result<()> {
            ev.observe(run_log)?;
            events.push(ev);
            Ok(())
        }
        match action {
            FaultAction::Kill { node } => match self.cfg.on_fault {
                OnFault::Fail => anyhow::bail!(
                    "node {node} killed by fault plan at iteration {it} (--on-fault fail); \
                     rerun with --on-fault continue or wait-rejoin to survive it"
                ),
                OnFault::Continue => {
                    if self.alive[node] {
                        self.alive[node] = false;
                        let survivors = live_count(&self.alive);
                        anyhow::ensure!(survivors > 0, "no live nodes left at iteration {it}");
                        push(
                            events,
                            run_log,
                            FaultEvent {
                                iter: it,
                                node: Some(node),
                                kind: "kill".into(),
                                detail: format!(
                                    "removed from aggregation; {survivors} survivors; \
                                     the node's EF residual is dropped"
                                ),
                            },
                        )?;
                    }
                }
                OnFault::WaitRejoin => {
                    // Simulated nodes share the process: state never leaves
                    // it, so a kill+rejoin is a no-op on the math.  Logged
                    // so fault plans behave uniformly across backends.
                    push(
                        events,
                        run_log,
                        FaultEvent {
                            iter: it,
                            node: Some(node),
                            kind: "kill".into(),
                            detail: "wait-rejoin: simulated node re-admitted instantly \
                                     (its state never left the process)"
                                .into(),
                        },
                    )?;
                }
            },
            FaultAction::Stall { node, ms } => {
                net.stall(node, ms as f64 / 1000.0);
                push(
                    events,
                    run_log,
                    FaultEvent {
                        iter: it,
                        node: Some(node),
                        kind: "stall".into(),
                        detail: format!("{ms}ms frozen; priced into this iteration's modeled time"),
                    },
                )?;
            }
            FaultAction::CorruptFrame { node } => {
                net.stall(node, CORRUPT_RETRANSMIT_S);
                push(
                    events,
                    run_log,
                    FaultEvent {
                        iter: it,
                        node: Some(node),
                        kind: "corrupt-frame".into(),
                        detail: format!(
                            "frame CRC failure -> one retransmit window ({:.0}ms) priced",
                            CORRUPT_RETRANSMIT_S * 1000.0
                        ),
                    },
                )?;
            }
            FaultAction::Crash => {
                // The one fault the sim cannot absorb — used by the resume
                // tests to cut a run at an exact iteration boundary.
                anyhow::bail!("injected crash at iteration {it} (fault plan)");
            }
        }
        Ok(())
    }

    /// Write the complete iteration-boundary training state as a v2 blob
    /// checkpoint (crash-safe resume, DESIGN.md §14).  Wall-clock
    /// durations are deliberately excluded: a resumed run reports only
    /// its own elapsed time, while every deterministic output (curve,
    /// evals, ledger, net trace, model, RNG streams, strategy state) is
    /// restored bit-exactly.
    #[allow(clippy::too_many_arguments)]
    fn save_train_state(
        &self,
        path: &str,
        next_iter: usize,
        phase_iters: &[usize; 3],
        fault_events: &[FaultEvent],
        curve: &[CurvePoint],
        evals: &[(usize, f32, f32)],
        ledger: &Ledger,
        net: &NetSim,
    ) -> Result<()> {
        let mut meta = Vec::new();
        ser::put_str(&mut meta, &cfg_fingerprint(&self.cfg));
        ser::put_u64(&mut meta, next_iter as u64);
        for &pi in phase_iters {
            ser::put_u64(&mut meta, pi as u64);
        }
        ser::put_u64(&mut meta, self.alive.len() as u64);
        for &a in &self.alive {
            ser::put_u8(&mut meta, a as u8);
        }
        ser::put_u64(&mut meta, fault_events.len() as u64);
        for ev in fault_events {
            ser::put_u64(&mut meta, ev.iter as u64);
            match ev.node {
                Some(n) => {
                    ser::put_u8(&mut meta, 1);
                    ser::put_u64(&mut meta, n as u64);
                }
                None => ser::put_u8(&mut meta, 0),
            }
            ser::put_str(&mut meta, &ev.kind);
            ser::put_str(&mut meta, &ev.detail);
        }
        let mut rng_b = Vec::new();
        self.rng.save_state(&mut rng_b);
        let mut strat_b = Vec::new();
        self.strategy.save_state(&mut strat_b);
        let mut fbs_b = Vec::new();
        ser::put_u64(&mut fbs_b, self.last_fbs.len() as u64);
        for fb in &self.last_fbs {
            fb.write_state(&mut fbs_b);
        }
        let mut curve_b = Vec::new();
        ser::put_u64(&mut curve_b, curve.len() as u64);
        for p in curve {
            ser::put_u64(&mut curve_b, p.iter as u64);
            ser::put_f32(&mut curve_b, p.train_loss);
            ser::put_f32(&mut curve_b, p.train_acc);
        }
        let mut evals_b = Vec::new();
        ser::put_u64(&mut evals_b, evals.len() as u64);
        for &(i, l, a) in evals {
            ser::put_u64(&mut evals_b, i as u64);
            ser::put_f32(&mut evals_b, l);
            ser::put_f32(&mut evals_b, a);
        }
        let mut net_b = Vec::new();
        net.save_state(&mut net_b);
        checkpoint::save_blobs(
            path,
            &[
                ("meta", meta),
                ("model", self.model.state_bytes()),
                ("rng", rng_b),
                ("strategy", strat_b),
                ("last_fbs", fbs_b),
                ("curve", curve_b),
                ("evals", evals_b),
                ("ledger", ledger.to_bytes()),
                ("net", net_b),
            ],
        )
    }

    /// Inverse of [`Trainer::save_train_state`]: restore everything from a
    /// v2 blob checkpoint and return the iteration to continue from.
    #[allow(clippy::too_many_arguments)]
    fn restore_train_state(
        &mut self,
        path: &str,
        phase_iters: &mut [usize; 3],
        fault_events: &mut Vec<FaultEvent>,
        curve: &mut Vec<CurvePoint>,
        evals: &mut Vec<(usize, f32, f32)>,
        ledger: &mut Ledger,
        net: &mut NetSim,
    ) -> Result<usize> {
        let blobs = checkpoint::load_blobs(path)?;
        let mut r = Reader::new(checkpoint::blob(&blobs, "meta")?);
        let fp = r.string()?;
        let want = cfg_fingerprint(&self.cfg);
        anyhow::ensure!(
            fp == want,
            "resume checkpoint {path:?} was written by a different configuration\n  \
             checkpoint: {fp}\n  this run:   {want}"
        );
        let next_iter = r.u64()? as usize;
        anyhow::ensure!(
            next_iter <= self.cfg.steps,
            "checkpoint is ahead of --steps: next iteration {next_iter} > {}",
            self.cfg.steps
        );
        for pi in phase_iters.iter_mut() {
            *pi = r.u64()? as usize;
        }
        let n = r.count(1)?;
        anyhow::ensure!(n == self.cfg.nodes, "checkpoint has {n} nodes, run has {}", self.cfg.nodes);
        for a in self.alive.iter_mut() {
            *a = match r.u8()? {
                0 => false,
                1 => true,
                other => anyhow::bail!("bad liveness tag {other}"),
            };
        }
        let ne = r.count(25)?;
        for _ in 0..ne {
            let iter = r.u64()? as usize;
            let node = match r.u8()? {
                0 => None,
                1 => Some(r.u64()? as usize),
                other => anyhow::bail!("bad fault-event node tag {other}"),
            };
            let kind = r.string()?;
            let detail = r.string()?;
            fault_events.push(FaultEvent { iter, node, kind, detail });
        }
        r.finish()?;
        self.model.load_state_bytes(checkpoint::blob(&blobs, "model")?)?;
        let mut r = Reader::new(checkpoint::blob(&blobs, "rng")?);
        self.rng = Rng::load_state(&mut r)?;
        r.finish()?;
        let mut r = Reader::new(checkpoint::blob(&blobs, "strategy")?);
        self.strategy.load_state(&mut r)?;
        r.finish()?;
        let mut r = Reader::new(checkpoint::blob(&blobs, "last_fbs")?);
        crate::baselines::check_node_count(&mut r, self.last_fbs.len(), "last_fbs")?;
        for fb in &mut self.last_fbs {
            fb.read_state(&mut r)?;
        }
        r.finish()?;
        let mut r = Reader::new(checkpoint::blob(&blobs, "curve")?);
        let nc = r.count(16)?;
        for _ in 0..nc {
            curve.push(CurvePoint {
                iter: r.u64()? as usize,
                train_loss: r.f32()?,
                train_acc: r.f32()?,
            });
        }
        r.finish()?;
        let mut r = Reader::new(checkpoint::blob(&blobs, "evals")?);
        let nv = r.count(16)?;
        for _ in 0..nv {
            evals.push((r.u64()? as usize, r.f32()?, r.f32()?));
        }
        r.finish()?;
        let mut r = Reader::new(checkpoint::blob(&blobs, "ledger")?);
        *ledger = Ledger::from_bytes(&mut r)?;
        r.finish()?;
        let mut r = Reader::new(checkpoint::blob(&blobs, "net")?);
        net.restore_state(&mut r)?;
        r.finish()?;
        Ok(next_iter)
    }

    /// Mean loss/acc over the held-out eval batches.
    pub fn evaluate(&self) -> Result<(f32, f32)> {
        let mut l = 0.0;
        let mut a = 0.0;
        for i in 0..self.cfg.eval_batches {
            let b = self.dataset.eval_batch(i);
            let (li, ai) = self.model.evaluate(self.engine, &b)?;
            l += li;
            a += ai;
        }
        let n = self.cfg.eval_batches as f32;
        Ok((l / n, a / n))
    }
}

/// Install the process-wide telemetry sinks a coordinator-side run
/// asked for (`--log-level`, `--trace-out` span recording,
/// `--metrics-addr` scrape endpoint), returning the metrics server
/// handle if one was bound.  Shared by [`train`] and the `lgc serve`
/// entry point; every sink stays inert when its flag is unset
/// (DESIGN.md §15).
pub fn telemetry_install(
    cfg: &TrainConfig,
) -> Result<Option<crate::obs::metrics::MetricsServer>> {
    crate::obs::log::set_level(cfg.log_level);
    if cfg.trace_out.is_some() {
        trace::install(cfg.nodes);
    }
    match &cfg.metrics_addr {
        Some(addr) => {
            crate::obs::metrics::install(cfg.nodes);
            let srv = crate::obs::metrics::serve(addr)?;
            crate::log_info!("lgc: metrics endpoint listening on {}", srv.addr());
            Ok(Some(srv))
        }
        None => Ok(None),
    }
}

/// Flush the trace sink after a run: merge worker part files (TCP runs
/// write them at shutdown) with this process's lanes and emit the
/// Chrome/Perfetto JSON at `--trace-out`.  A failed run discards the
/// recorder instead of writing a partial trace.
pub fn telemetry_finish(cfg: &TrainConfig, ok: bool) -> Result<()> {
    if let Some(path) = &cfg.trace_out {
        let write = if ok {
            trace::write_merged(path, cfg.nodes)
        } else {
            Ok(())
        };
        let _ = trace::uninstall();
        write?;
    }
    Ok(())
}

/// Train under the configured transport: the in-process simulator
/// (default), or real worker processes over sockets
/// (`cfg.transport == Tcp`, [`remote::train_tcp`]).  The two backends
/// produce bit-identical results for the supported methods
/// (tests/tcp_e2e.rs) — with or without the telemetry flags, which only
/// observe (DESIGN.md §15).
pub fn train(engine: &Engine, cfg: TrainConfig) -> Result<TrainResult> {
    // Fail fast on inconsistent fault-tolerance flags (bad --faults
    // specs, continue with a leaderful method, --ckpt-every without
    // --checkpoint, --resume over TCP) before spawning anything.
    faults::validate_fault_config(&cfg)?;
    let _metrics = telemetry_install(&cfg)?;
    let result = match cfg.transport {
        TransportKind::Sim => Trainer::new(engine, cfg.clone()).and_then(Trainer::run),
        TransportKind::Tcp => remote::train_tcp(engine, cfg.clone()),
    };
    telemetry_finish(&cfg, result.is_ok())?;
    result
}
