//! L3 coordinator: the distributed-training loop (paper §V).
//!
//! [`Trainer`] simulates K synchronous data-parallel nodes inside one
//! process: every node is a (data shard, error-feedback memory) pair; the
//! model parameters are stored once because synchronous SGD keeps replicas
//! identical.  All compute (grad steps, eval, autoencoder) executes through
//! the PJRT runtime; all communication flows through byte-accounted
//! exchanges (see [`crate::metrics::Ledger`]).
//!
//! Execution model (DESIGN.md §6.5): within each iteration, the per-node
//! work — grad-shard compute, error-feedback updates, compress/encode —
//! fans out across worker threads via [`parallel`], with each node owning
//! its state (data stream, EF memory, ledger shard).  The exchange steps
//! (PS gather, ring reduce-scatter/allgather, leader broadcasts) are
//! explicit synchronization barriers that always reduce in node order, so
//! curves and ledgers are bit-identical across thread counts.
//!
//! Per-group gradient handling (paper §VI-A):
//!   first layer — always dense (all methods)
//!   mid layers  — the selected [`MidStrategy`] (baselines or LGC)
//!   last layer  — dense for Baseline/QSGD; top-k + EF for sparse methods

pub mod bucket;
pub mod lgc;
pub mod parallel;
pub mod remote;
pub mod ring;
pub mod scheduler;
pub mod worker;

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::baselines::{
    dense_mean_accounted, sparse_ef_exchange, Baseline, Dgc, ExchangeCtx, HardThreshold,
    MidStrategy, Qsgd, ScaleCom, SparseGd,
};
use crate::compress::{Correction, FeedbackMemory, Scratch};
use crate::config::{Method, TrainConfig, TransportKind};
use crate::data::{self, Dataset};
use crate::metrics::{Ledger, NodeLedger};
use crate::model::{Group, Model};
use crate::net::{LinkModel, NetReport, NetSim};
use crate::runtime::Engine;
use crate::util::rng::Rng;
use bucket::{method_bucketable, BucketPlan};
use scheduler::{phase_and_alpha, Phase};

/// Step LR decay mirroring the paper's schedule ("initial learning rate of
/// 0.1 that decays by 10 every 30 epochs" over ~90 epochs, SS VI-B):
/// x1 for the first half, x0.1 to 80%, x0.01 after.  Besides fidelity,
/// this is what keeps EF methods from blowing up logits after the
/// separable synthetic tasks are fully fit.
pub fn lr_at(cfg: &TrainConfig, it: usize) -> f32 {
    if it < cfg.steps / 2 {
        cfg.lr
    } else if it < cfg.steps * 4 / 5 {
        cfg.lr * 0.1
    } else {
        cfg.lr * 0.01
    }
}

/// One recorded training point.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub iter: usize,
    pub train_loss: f32,
    pub train_acc: f32,
}

/// Everything a finished run hands to the experiment drivers: curves,
/// evals, the measured byte ledger, wall-clock breakdowns, AE traces,
/// and the network fabric's modeled-time report.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub method: Method,
    pub model: String,
    pub nodes: usize,
    pub steps: usize,
    pub curve: Vec<CurvePoint>,
    /// (iter, eval_loss, eval_acc) on held-out batches.
    pub evals: Vec<(usize, f32, f32)>,
    pub ledger: Ledger,
    pub phase_time: [Duration; 3],
    pub phase_iters: [usize; 3],
    /// AE (rec, sim) loss trace (empty for baselines) — Fig. 14.
    pub ae_losses: Vec<(f32, f32)>,
    pub final_eval: (f32, f32),
    /// Uncompressed per-node bytes/iteration (the CR denominator).
    pub dense_bytes_per_node: u64,
    /// Wall-clock breakdown: grad-step HLO, mid exchange (incl. AE HLOs),
    /// first/last exchanges + optimizer update, per training phase.
    pub time_grad: Duration,
    pub time_exchange: Duration,
    pub time_update: Duration,
    /// The simulated network fabric's recorded trace + pricing — the
    /// per-node modeled time ledger (DESIGN.md §11).
    pub net: NetReport,
}

impl TrainResult {
    /// Steady-state mean uplink bytes/iteration across all nodes.
    /// The window never reaches back past the start of phase 3 (or the
    /// final phase actually reached), so warmup traffic is excluded.
    pub fn steady_total_bytes_per_iter(&self, window: usize) -> f64 {
        let steady_iters = *self.phase_iters.iter().rev().find(|&&n| n > 0).unwrap_or(&1);
        self.ledger.steady_bytes_per_iter(window.min(steady_iters.max(1)))
    }

    /// Compression ratio vs uncompressed dense training (mean node,
    /// steady state) — the paper's "Ratio" column.
    pub fn compression_ratio(&self) -> f64 {
        let per_node = self.steady_total_bytes_per_iter(50) / self.nodes as f64;
        self.dense_bytes_per_node as f64 / per_node.max(1e-9)
    }

    /// Mean steady-state bytes/iter per node ("Info size" column, MB).
    pub fn info_size_mb(&self) -> f64 {
        self.steady_total_bytes_per_iter(50) / self.nodes as f64 / 1e6
    }

    /// Train loss at the last recorded iteration (NaN for empty runs).
    pub fn final_train_loss(&self) -> f32 {
        self.curve.last().map(|c| c.train_loss).unwrap_or(f32::NAN)
    }

    /// Steady-state modeled communication seconds per iteration under
    /// `link` (same steady-state window rule as
    /// [`TrainResult::steady_total_bytes_per_iter`]; straggler
    /// multipliers stay those the run was recorded with).
    pub fn steady_comm_s_at(&self, link: LinkModel, window: usize) -> f64 {
        self.steady_comm_s_under(&self.net.fabric.with_link(link), window)
    }

    /// [`TrainResult::steady_comm_s_at`] under an arbitrary fabric
    /// (different link and/or straggler multipliers) — scenario sweeps
    /// reprice one recorded run instead of retraining (ablation A5).
    pub fn steady_comm_s_under(&self, fabric: &crate::net::Fabric, window: usize) -> f64 {
        let steady_iters = *self.phase_iters.iter().rev().find(|&&n| n > 0).unwrap_or(&1);
        self.net.steady_comm_s_under(fabric, window.min(steady_iters.max(1)))
    }
}

/// Build the mid-group strategy for a config.
fn make_strategy(
    engine: &Engine,
    cfg: &TrainConfig,
    n_mid: usize,
    mu: usize,
) -> Result<Box<dyn MidStrategy>> {
    let ramp = cfg.warmup_iters + cfg.ae_train_iters;
    Ok(match cfg.method {
        Method::Baseline => Box::new(Baseline),
        Method::SparseGd => Box::new(SparseGd::new(cfg.nodes, n_mid, cfg.alpha)),
        Method::Dgc => Box::new(Dgc::new(cfg.nodes, n_mid, cfg.alpha, ramp, cfg.momentum)),
        Method::ScaleCom => Box::new(ScaleCom::new(cfg.nodes, n_mid, cfg.alpha, cfg.momentum)),
        Method::Qsgd => {
            Box::new(Qsgd::new(cfg.qsgd_levels, 512, cfg.nodes, cfg.seed ^ 0x45D0))
        }
        Method::Threshold => Box::new(HardThreshold::new(cfg.nodes, n_mid, cfg.alpha)),
        Method::LgcPs => {
            let p = lgc::LgcParams {
                momentum: cfg.momentum,
                innovation_frac: cfg.innovation_frac,
                ae_lr: cfg.ae_lr,
                lambda2: cfg.lambda2,
                ae_inner_steps: cfg.ae_inner_steps,
                ae_gate: cfg.ae_gate,
                seed: cfg.seed ^ 0xAE,
            };
            Box::new(lgc::LgcPs::new(engine, cfg.nodes, n_mid, mu, p)?)
        }
        Method::LgcRar => {
            let p = lgc::LgcParams {
                momentum: cfg.momentum,
                innovation_frac: cfg.innovation_frac,
                ae_lr: cfg.ae_lr,
                lambda2: 0.0,
                ae_inner_steps: cfg.ae_inner_steps,
                ae_gate: cfg.ae_gate,
                seed: cfg.seed ^ 0xAE,
            };
            Box::new(lgc::LgcRar::new(engine, cfg.nodes, n_mid, mu, p)?)
        }
    })
}

/// The assembled training loop for one [`TrainConfig`]: model, data
/// shards, mid-group strategy, per-node EF memories and scratch arenas.
pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub cfg: TrainConfig,
    pub model: Model,
    dataset: Box<dyn Dataset>,
    strategy: Box<dyn MidStrategy>,
    /// Per-node EF memories for the last-layer group (sparse methods).
    last_fbs: Vec<FeedbackMemory>,
    /// Per-node scratch arenas (DESIGN.md §6.11), created once next to
    /// the ledger shards and lent to every exchange stage; buffers reach
    /// their high-water mark in the first iterations and the steady state
    /// allocates nothing on the encode path.
    arenas: Vec<Scratch>,
    /// Mid-group bucket plan (DESIGN.md §13): layer-boundary-derived
    /// contiguous ranges for bucketable methods, single-bucket otherwise.
    plan: BucketPlan,
    /// Last-group plan: always single-bucket (the classifier head is
    /// small; bucketing it would buy nothing and complicate the wire
    /// ledger contract).
    last_plan: BucketPlan,
    /// Effective overlap mode: `cfg.overlap` and a real multi-bucket plan.
    overlap: bool,
    rng: Rng,
}

impl<'e> Trainer<'e> {
    /// Resolve the model, build the strategy and all per-node state.
    pub fn new(engine: &'e Engine, mut cfg: TrainConfig) -> Result<Trainer<'e>> {
        // Backend-portable model resolution: missing names fall back to
        // the manifest's reference workload (native backend).
        let meta = engine.manifest.resolve_model(&cfg.model).clone();
        cfg.model = meta.name.clone();
        let mut model = Model::new(&meta, cfg.seed);
        // Momentum lives in the optimizer for Baseline/QSGD, and in the
        // EF memories (momentum correction) for the sparse methods
        // (Table III / DGC §3.2) — not in both.
        model.momentum = match cfg.method {
            Method::Baseline | Method::Qsgd => cfg.momentum,
            _ => 0.0,
        };
        model.weight_decay = cfg.weight_decay;
        let dataset = data::for_model(&meta, cfg.seed ^ 0xDA7A);
        let n_mid = meta.group_len(&meta.mid_param_idx);
        let strategy = make_strategy(engine, &cfg, n_mid, meta.mu)?;
        let n_last = meta.group_len(&meta.last_param_idx);
        let last_correction = match cfg.method {
            Method::SparseGd | Method::Threshold => Correction::Plain,
            _ => Correction::Momentum,
        };
        let last_fbs = (0..cfg.nodes)
            .map(|_| FeedbackMemory::new(n_last, last_correction, cfg.momentum))
            .collect();
        let arenas = Scratch::for_nodes(cfg.nodes);
        // Bucket plan over the mid group's layer boundaries (§13); the
        // same pure derivation runs in the TCP coordinator and in every
        // worker process, so no plan negotiation happens on the wire.
        let plan = if method_bucketable(cfg.method) {
            let layers: Vec<std::ops::Range<usize>> =
                model.layer_slices(Group::Mid).into_iter().map(|(_, r)| r).collect();
            BucketPlan::for_group(n_mid, &layers, &cfg)
        } else {
            BucketPlan::single(n_mid)
        };
        let overlap = cfg.overlap && !plan.is_single();
        let last_plan = BucketPlan::single(n_last);
        let rng = Rng::new(cfg.seed ^ 0x7124);
        Ok(Trainer {
            engine,
            cfg,
            model,
            dataset,
            strategy,
            last_fbs,
            arenas,
            plan,
            last_plan,
            overlap,
            rng,
        })
    }

    /// Last-layer exchange: dense for Baseline/QSGD (and everyone's dense
    /// phase), top-k + EF otherwise (§VI-A: "top-magnitude values ...
    /// without further compression").  The sparse branch routes through
    /// the same [`sparse_ef_exchange`] machinery as SparseGd/Dgc — one
    /// owner of the EF -> select -> encode -> scatter-mean sequence
    /// instead of a duplicated copy here — always on the single-bucket
    /// last-group plan, with value payloads at full precision (the
    /// paper's "without further compression").
    fn last_exchange(
        &mut self,
        phase: Phase,
        grads: &[Vec<f32>],
        shards: &mut [NodeLedger],
        net: &mut NetSim,
    ) -> Result<Vec<f32>> {
        let n = grads[0].len();
        let dense = matches!(self.cfg.method, Method::Baseline | Method::Qsgd)
            || phase == Phase::Dense;
        if dense {
            let mean = dense_mean_accounted(grads, shards);
            net.fanout((n * 4) as u64);
            return Ok(mean);
        }
        sparse_ef_exchange(
            &mut self.last_fbs,
            grads,
            self.cfg.alpha,
            false,
            shards,
            &mut self.arenas,
            self.cfg.threads,
            &self.last_plan,
            false,
            net,
        )
    }

    /// Run the full training loop.
    pub fn run(mut self) -> Result<TrainResult> {
        let meta = self.model.meta.clone();
        let threads = self.cfg.threads;
        let mut ledger = Ledger::new();
        let mut shards = NodeLedger::for_nodes(self.cfg.nodes);
        // The simulated network fabric records this run's event trace
        // alongside the byte ledger (DESIGN.md §11).
        let mut net = NetSim::new(self.cfg.fabric(), self.cfg.nodes);
        let mut curve = Vec::with_capacity(self.cfg.steps);
        let mut evals = Vec::new();
        let mut phase_time = [Duration::ZERO; 3];
        let mut phase_iters = [0usize; 3];
        let mut time_grad = Duration::ZERO;
        let mut time_exchange = Duration::ZERO;
        let mut time_update = Duration::ZERO;

        for it in 0..self.cfg.steps {
            let (phase, _alpha) = phase_and_alpha(&self.cfg, it);
            ledger.set_phase(phase.index() as u8 + 1);
            let t0 = Instant::now();

            // --- local compute: one grad step per node, fanned out ------
            let t_grad0 = Instant::now();
            let engine = self.engine;
            let model = &self.model;
            let dataset = &*self.dataset;
            let method_name = self.cfg.method.name();
            let lr_cfg = self.cfg.lr;
            type NodeGrads = (f32, f32, Vec<f32>, Vec<f32>, Vec<f32>);
            let per_node = parallel::collect_node_results(parallel::par_map_indexed(
                threads,
                self.cfg.nodes,
                |node| -> Result<NodeGrads> {
                    let batch = dataset.batch(node, it);
                    let (loss, acc, grads) = model.grad_step(engine, &batch)?;
                    anyhow::ensure!(
                        loss.is_finite(),
                        "training diverged: non-finite loss at iter {it}, node {node} \
                         (method {method_name}, lr {lr_cfg})"
                    );
                    Ok((
                        loss,
                        acc,
                        model.flatten_group(&grads, Group::First),
                        model.flatten_group(&grads, Group::Mid),
                        model.flatten_group(&grads, Group::Last),
                    ))
                },
            ))?;
            let mut first_g = Vec::with_capacity(self.cfg.nodes);
            let mut mid_g = Vec::with_capacity(self.cfg.nodes);
            let mut last_g = Vec::with_capacity(self.cfg.nodes);
            let mut loss_sum = 0.0f32;
            let mut acc_sum = 0.0f32;
            for (loss, acc, first, mid, last) in per_node {
                loss_sum += loss;
                acc_sum += acc;
                first_g.push(first);
                mid_g.push(mid);
                last_g.push(last);
            }
            time_grad += t_grad0.elapsed();

            // --- exchanges (synchronization barriers) -------------------
            let t_ex0 = Instant::now();
            // First layer: always dense (all methods, §VI-A), PS-style
            // scatter of the aggregate on the fabric.
            let first_mean = dense_mean_accounted(&first_g, &mut shards);
            net.fanout((first_mean.len() * 4) as u64);

            let mid_mean = {
                let mut ctx = ExchangeCtx {
                    engine: self.engine,
                    ledger: &mut ledger,
                    shards: &mut shards,
                    iter: it,
                    phase,
                    alpha: self.cfg.alpha,
                    fp16: self.cfg.fp16_values,
                    rng: &mut self.rng,
                    threads,
                    scratches: &mut self.arenas,
                    net: &mut net,
                    plan: &self.plan,
                    overlap: self.overlap,
                };
                self.strategy.exchange(&mut ctx, &mid_g)?
            };
            let last_mean = self.last_exchange(phase, &last_g, &mut shards, &mut net)?;
            time_exchange += t_ex0.elapsed();

            // --- update -------------------------------------------------
            let t_up0 = Instant::now();
            self.model.apply_update(
                &[
                    (Group::First, first_mean),
                    (Group::Mid, mid_mean),
                    (Group::Last, last_mean),
                ],
                lr_at(&self.cfg, it),
            );
            time_update += t_up0.elapsed();
            // Close the iteration through the scheduler — the single
            // owner of the close-out sequence (fan-in round, shard merge,
            // iteration boundaries) shared with the TCP coordinator.
            scheduler::close_iteration(&mut ledger, &mut shards, &mut net);

            let dt = t0.elapsed();
            phase_time[phase.index()] += dt;
            phase_iters[phase.index()] += 1;

            curve.push(CurvePoint {
                iter: it,
                train_loss: loss_sum / self.cfg.nodes as f32,
                train_acc: acc_sum / self.cfg.nodes as f32,
            });

            if self.cfg.eval_every > 0 && (it + 1) % self.cfg.eval_every == 0 {
                let (l, a) = self.evaluate()?;
                evals.push((it, l, a));
                if self.cfg.verbose {
                    eprintln!(
                        "[{}] it {:>5} phase {:<10} train_loss {:.4} eval_loss {:.4} eval_acc {:.4}",
                        self.strategy.name(),
                        it,
                        phase.name(),
                        curve.last().unwrap().train_loss,
                        l,
                        a
                    );
                }
            }
        }

        let final_eval = self.evaluate()?;
        if let Some(path) = &self.cfg.checkpoint {
            self.model.save_checkpoint(path)?;
        }
        Ok(TrainResult {
            method: self.cfg.method,
            model: self.cfg.model.clone(),
            nodes: self.cfg.nodes,
            steps: self.cfg.steps,
            curve,
            evals,
            ledger,
            phase_time,
            phase_iters,
            ae_losses: self.strategy.ae_losses().to_vec(),
            final_eval,
            dense_bytes_per_node: (meta.n_params * 4) as u64,
            time_grad,
            time_exchange,
            time_update,
            net: net.into_report(),
        })
    }

    /// Mean loss/acc over the held-out eval batches.
    pub fn evaluate(&self) -> Result<(f32, f32)> {
        let mut l = 0.0;
        let mut a = 0.0;
        for i in 0..self.cfg.eval_batches {
            let b = self.dataset.eval_batch(i);
            let (li, ai) = self.model.evaluate(self.engine, &b)?;
            l += li;
            a += ai;
        }
        let n = self.cfg.eval_batches as f32;
        Ok((l / n, a / n))
    }
}

/// Train under the configured transport: the in-process simulator
/// (default), or real worker processes over sockets
/// (`cfg.transport == Tcp`, [`remote::train_tcp`]).  The two backends
/// produce bit-identical results for the supported methods
/// (tests/tcp_e2e.rs).
pub fn train(engine: &Engine, cfg: TrainConfig) -> Result<TrainResult> {
    match cfg.transport {
        TransportKind::Sim => Trainer::new(engine, cfg)?.run(),
        TransportKind::Tcp => remote::train_tcp(engine, cfg),
    }
}
