//! Worker side of the real multi-process transport (DESIGN.md §12).
//!
//! One `lgc worker` process owns exactly one simulated node of the
//! distributed run: its model replica, its data stream, its
//! error-feedback memories, and (for LGC) its copy of the trained
//! encoder.  The per-node pipeline executed here — EF accumulation,
//! top-k / gather-at-support, innovation selection, AE encode, index
//! coding — is line-for-line the node-local stage of the in-process
//! simulator ([`crate::coordinator::Trainer`], [`crate::coordinator::lgc`],
//! [`crate::baselines`]), so a TCP run is bit-identical to a sim run of
//! the same config (tests/tcp_e2e.rs).
//!
//! Replica consistency is inductive: every worker builds the same
//! deterministic `Model::new(meta, cfg.seed)` and applies the same
//! broadcast [`Msg::SyncInfo`] means with the same `lr_at` schedule, so
//! parameters stay identical across processes without ever shipping
//! them.  Gradients therefore depend only on (seed, node, iter), exactly
//! as in the simulator.
//!
//! Per-iteration protocol (worker's view):
//!
//! 1. recv [`Msg::IterPlan`] (or [`Msg::Shutdown`] — clean exit);
//! 2. if `weights_follow`: recv [`Msg::Model`] (the trained encoder);
//! 3. grad step on `dataset.batch(node, iter)`;
//! 4. LGC non-dense iterations: the leader uploads [`Msg::Support`],
//!    everyone receives [`Msg::SupportBcast`] (the leader included —
//!    one uniform decode path);
//! 5. send [`Msg::Gradient`] (+ [`Msg::Latent`] when the learned coder
//!    is engaged), then recv [`Msg::SyncInfo`] and apply the update.

use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::baselines::pack_values_in_place;
use crate::compress::autoencoder::{rms, AeCompressor, Pattern};
use crate::compress::index_coding::IndexCodec;
use crate::compress::{index_coding, topk, Correction, FeedbackMemory, Scratch};
use crate::config::{Method, OnFault, TrainConfig};
use crate::coordinator::bucket::{method_bucketable, BucketPlan};
use crate::coordinator::faults;
use crate::coordinator::lr_at;
use crate::coordinator::scheduler::{exponential_alpha, phase_and_alpha, Phase};
use crate::data::{self, Dataset};
use crate::model::{Group, Model};
use crate::obs::trace;
use crate::runtime::Engine;
use crate::transport::{BucketUp, Conn, HeartbeatPump, LastUp, MidUp, Msg, PROTO_VERSION};
use crate::util::ser::{self, Reader};

/// Connection knobs for one worker process (`lgc worker`).
#[derive(Debug, Clone)]
pub struct WorkerOpts {
    /// Coordinator address: `host:port` or `unix:/path/to.sock`.
    pub connect: String,
    /// Session id; must match the coordinator's (stale joins are
    /// rejected with a descriptive error).
    pub session: u64,
    /// Connect attempts before giving up (exponential backoff covers a
    /// coordinator that is slow to bind).
    pub retries: usize,
    /// Initial backoff between connect attempts; doubles per retry.
    pub backoff_ms: u64,
    /// Read timeout while awaiting coordinator messages.  Generous by
    /// default: the coordinator runs AE training and eval between
    /// iterations.
    pub net_timeout: Duration,
    /// When set, reconnect to a live elastic run as this node via the
    /// token-checked rejoin handshake instead of a fresh join
    /// (`--on-fault wait-rejoin`, DESIGN.md §14.3).
    pub rejoin_node: Option<u32>,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            connect: String::new(),
            session: 0,
            retries: 40,
            backoff_ms: 50,
            net_timeout: Duration::from_secs(120),
            rejoin_node: None,
        }
    }
}

/// Connect, join, and serve the full training run.  Returns when the
/// coordinator sends [`Msg::Shutdown`] (clean end of training, or a
/// coordinator-side error relayed as the shutdown reason).
pub fn run(engine: &Engine, opts: &WorkerOpts) -> Result<()> {
    // Per-process jitter (session ^ pid) keeps a thundering herd of
    // simultaneously restarted workers from retrying in lockstep.
    let pid = std::process::id() as u64;
    let mut conn = Conn::connect_with_retry_jittered(
        &opts.connect,
        opts.retries,
        opts.backoff_ms,
        opts.session ^ pid,
    )?;
    conn.set_read_timeout(Some(opts.net_timeout))?;
    if let Some(rejoin) = opts.rejoin_node {
        return run_rejoin(engine, opts, conn, rejoin);
    }
    conn.send(&Msg::Join { proto: PROTO_VERSION, session: opts.session, pid })?;
    let (node, nodes, platform, cfg) = match conn.expect("JoinAck")? {
        Msg::JoinAck { node, nodes, platform, cfg } => {
            (node as usize, nodes as usize, platform, cfg)
        }
        other => bail!("expected JoinAck, got {}", other.name()),
    };
    ensure!(
        platform == engine.platform(),
        "backend mismatch: coordinator runs on {:?}, this worker on {:?} — results would \
         not be bit-identical; relaunch the worker with a matching --backend/$LGC_BACKEND",
        platform,
        engine.platform()
    );
    // Telemetry knobs ride in the config blob (CFG v4): adopt the
    // coordinator's log level, and when the run traces, record this
    // process's pipeline spans for the part-file flush at shutdown.
    crate::obs::log::set_level(cfg.log_level);
    if cfg.trace_out.is_some() {
        trace::install(nodes);
    }
    crate::log_info!(
        "lgc worker: joined as node {node}/{nodes} (method {}, model {})",
        cfg.method.name(),
        cfg.model
    );
    let _pump = spawn_pump(&conn, &cfg);
    let mut n = Node::new(engine, node, nodes, cfg)?;
    if n.cfg.on_fault == OnFault::WaitRejoin {
        // Initial state sync (sentinel iter u32::MAX): gives even an
        // iteration-0 kill a resurrection payload.  Rejoiners skip this —
        // the coordinator keeps the blob it just shipped them.
        conn.send(&Msg::StateSync { iter: u32::MAX, blob: n.export_state() })?;
    }
    n.serve(&mut conn)
}

/// Heartbeat pump for this connection when the run enables liveness
/// monitoring; `None` (no thread at all) when `heartbeat_ms == 0`.
fn spawn_pump(conn: &Conn, cfg: &TrainConfig) -> Option<HeartbeatPump> {
    (cfg.heartbeat_ms > 0)
        .then(|| HeartbeatPump::spawn(conn.writer(), Duration::from_millis(cfg.heartbeat_ms)))
}

/// The elastic re-entry path: prove identity with the session token,
/// receive the full resync (run parameters, model replica, this node's
/// own strategy state from the end of the last completed iteration, and
/// the current AE encoder when one was ever broadcast), then serve as if
/// nothing happened.  Bit-exactness argument in DESIGN.md §14.3.
fn run_rejoin(engine: &Engine, opts: &WorkerOpts, mut conn: Conn, node: u32) -> Result<()> {
    let token = faults::rejoin_token(opts.session, node as usize);
    conn.send(&Msg::Rejoin { proto: PROTO_VERSION, session: opts.session, node, token })?;
    let (node, nodes, platform, cfg, iter, model, state, encoder) =
        match conn.expect("RejoinAck")? {
            Msg::RejoinAck { node, nodes, platform, cfg, iter, model, state, encoder } => {
                (node as usize, nodes as usize, platform, cfg, iter, model, state, encoder)
            }
            other => bail!("expected RejoinAck, got {}", other.name()),
        };
    ensure!(
        platform == engine.platform(),
        "backend mismatch: coordinator runs on {:?}, this worker on {:?} — results would \
         not be bit-identical; relaunch the worker with a matching --backend/$LGC_BACKEND",
        platform,
        engine.platform()
    );
    crate::obs::log::set_level(cfg.log_level);
    if cfg.trace_out.is_some() {
        trace::install(nodes);
    }
    crate::log_info!(
        "lgc worker: node {node}/{nodes} rejoined at iteration {iter} (method {})",
        cfg.method.name()
    );
    let _pump = spawn_pump(&conn, &cfg);
    let mut n = Node::new(engine, node, nodes, cfg)?;
    n.model.load_state_bytes(&model).context("restoring model replica on rejoin")?;
    n.import_state(&state).context("restoring strategy state on rejoin")?;
    if let Some(enc) = encoder {
        match &mut n.mid {
            MidState::Lgc { ae, .. } => ae.import_encoder(&enc)?,
            _ => bail!("received AE encoder weights for a non-LGC method"),
        }
    }
    n.serve(&mut conn)
}

/// Mid-group method state owned by this node — the single-node slice of
/// what the simulator's strategy objects hold for all K nodes.
enum MidState {
    /// Baseline: dense uplink, no per-node state.
    Dense,
    /// SparseGd (`ramp: None`) / DGC (`ramp: Some`): EF + top-k.
    Sparse { fb: FeedbackMemory, ramp: Option<usize> },
    /// Hard threshold: EF + self-calibrating AIMD threshold.
    Threshold { fb: FeedbackMemory, threshold: f32 },
    /// LGC (both patterns): EF + the learned encoder copy.
    Lgc { fb: FeedbackMemory, ae: AeCompressor, ps: bool },
}

/// One distributed node: model replica, data stream, EF memories,
/// method state, scratch arena.
struct Node<'e> {
    engine: &'e Engine,
    node: usize,
    nodes: usize,
    cfg: TrainConfig,
    model: Model,
    dataset: Box<dyn Dataset>,
    last_fb: FeedbackMemory,
    mid: MidState,
    sc: Scratch,
    /// The leader's broadcast support (signed-descending order).
    support: Vec<u32>,
    /// Value-vector gathered at the support (mu-length).
    vv: Vec<f32>,
    n_mid: usize,
    n_last: usize,
    mu: usize,
    /// Mid-group bucket plan, derived from the same (cfg, layer-slice)
    /// inputs as the coordinator's — both sides must agree frame-for-frame.
    plan: BucketPlan,
    /// Effective overlap: configured on *and* the plan actually splits.
    overlap: bool,
}

impl<'e> Node<'e> {
    /// Rebuild the node-local slice of the simulator's state from the
    /// joined config — same constructors, same seeds, same momentum
    /// routing as [`crate::coordinator::Trainer::new`].
    fn new(engine: &'e Engine, node: usize, nodes: usize, cfg: TrainConfig) -> Result<Self> {
        let meta = engine.manifest.resolve_model(&cfg.model).clone();
        ensure!(
            meta.name == cfg.model,
            "model {:?} resolves to {:?} on this worker's backend — coordinator and \
             workers must resolve identically",
            cfg.model,
            meta.name
        );
        let mut model = Model::new(&meta, cfg.seed);
        model.momentum = match cfg.method {
            Method::Baseline | Method::Qsgd => cfg.momentum,
            _ => 0.0,
        };
        model.weight_decay = cfg.weight_decay;
        let dataset = data::for_model(&meta, cfg.seed ^ 0xDA7A);
        let n_mid = meta.group_len(&meta.mid_param_idx);
        let n_last = meta.group_len(&meta.last_param_idx);
        let last_correction = match cfg.method {
            Method::SparseGd | Method::Threshold => Correction::Plain,
            _ => Correction::Momentum,
        };
        let last_fb = FeedbackMemory::new(n_last, last_correction, cfg.momentum);
        let ramp = cfg.warmup_iters + cfg.ae_train_iters;
        let mid = match cfg.method {
            Method::Baseline => MidState::Dense,
            Method::SparseGd => MidState::Sparse {
                fb: FeedbackMemory::new(n_mid, Correction::Plain, 0.0),
                ramp: None,
            },
            Method::Dgc => MidState::Sparse {
                fb: FeedbackMemory::new(n_mid, Correction::Momentum, cfg.momentum),
                ramp: Some(ramp),
            },
            Method::Threshold => MidState::Threshold {
                fb: FeedbackMemory::new(n_mid, Correction::Plain, 0.0),
                threshold: 0.0,
            },
            Method::LgcPs | Method::LgcRar => {
                let ps = matches!(cfg.method, Method::LgcPs);
                let pattern = if ps {
                    Pattern::ParamServer
                } else {
                    Pattern::RingAllreduce
                };
                // Same construction as the coordinator's compressor; the
                // encoder params are overwritten by the one-shot weight
                // transfer at engagement, so only shapes must agree.
                let ae = AeCompressor::new(engine, meta.mu, nodes, pattern, cfg.seed ^ 0xAE)?;
                MidState::Lgc {
                    fb: FeedbackMemory::new(n_mid, Correction::Momentum, cfg.momentum),
                    ae,
                    ps,
                }
            }
            Method::ScaleCom | Method::Qsgd => bail!(
                "method {} is not supported over the tcp transport",
                cfg.method.name()
            ),
        };
        let mu = meta.mu;
        let plan = if method_bucketable(cfg.method) {
            let layers: Vec<std::ops::Range<usize>> =
                model.layer_slices(Group::Mid).into_iter().map(|(_, r)| r).collect();
            BucketPlan::for_group(n_mid, &layers, &cfg)
        } else {
            BucketPlan::single(n_mid)
        };
        let overlap = cfg.overlap && !plan.is_single();
        Ok(Node {
            engine,
            node,
            nodes,
            cfg,
            model,
            dataset,
            last_fb,
            mid,
            sc: Scratch::new(),
            support: Vec::new(),
            vv: Vec::new(),
            n_mid,
            n_last,
            mu,
            plan,
            overlap,
        })
    }

    /// The iteration loop: one [`Msg::IterPlan`] per step until the
    /// coordinator's [`Msg::Shutdown`].
    fn serve(&mut self, conn: &mut Conn) -> Result<()> {
        // The whole serve loop runs on this one thread on behalf of this
        // one node: route every span it opens to the node's lane.
        let _lane = trace::lane_scope(self.node);
        loop {
            match conn.expect("IterPlan")? {
                Msg::Shutdown { reason } => {
                    crate::log_info!(
                        "lgc worker: node {} shutting down ({reason})",
                        self.node
                    );
                    if let Some(path) = &self.cfg.trace_out {
                        // Clean exit: flush this process's spans to the
                        // part file the coordinator merges (§15.2).  A
                        // killed worker simply never writes one.
                        trace::write_part(path, self.node)?;
                    }
                    return Ok(());
                }
                Msg::IterPlan { iter, engaged, weights_follow } => {
                    let it = iter as usize;
                    trace::set_iter(it);
                    self.step(conn, it, engaged, weights_follow)
                        .with_context(|| format!("worker node {} at iter {it}", self.node))?;
                }
                other => bail!("expected IterPlan or Shutdown, got {}", other.name()),
            }
        }
    }

    /// One training iteration over the wire.
    fn step(
        &mut self,
        conn: &mut Conn,
        it: usize,
        engaged: bool,
        weights_follow: bool,
    ) -> Result<()> {
        if weights_follow {
            match conn.expect("AE weights")? {
                Msg::Model { payload, .. } => match &mut self.mid {
                    MidState::Lgc { ae, .. } => ae.import_encoder(&payload)?,
                    _ => bail!("received AE weights for a non-LGC method"),
                },
                other => bail!("expected Model (AE weights), got {}", other.name()),
            }
        }
        let (phase, _alpha) = phase_and_alpha(&self.cfg, it);

        // Local compute: identical inputs (deterministic replica + data
        // stream) => identical gradients to the simulator's node closure.
        let batch = self.dataset.batch(self.node, it);
        let sp_grad = trace::span(trace::Stage::Grad);
        let (loss, acc, grads) = self.model.grad_step(self.engine, &batch)?;
        let first = self.model.flatten_group(&grads, Group::First);
        let mid_g = self.model.flatten_group(&grads, Group::Mid);
        let last_g = self.model.flatten_group(&grads, Group::Last);
        drop(sp_grad);

        let (mid_up, ctrl_mid, latent) = self.mid_upload(conn, it, phase, engaged, &mid_g)?;
        let last_up = self.last_upload(phase, last_g)?;
        // Loss is sent raw (NaN included): the coordinator raises the
        // simulator's canonical divergence error so both transports fail
        // with the same message.
        // The worker's exchange span covers uplink send through SyncInfo
        // receipt — the wire wait the coordinator's central replay sits
        // inside.
        let sp_ex = trace::span(trace::Stage::Exchange);
        conn.send(&Msg::Gradient {
            iter: it as u32,
            loss,
            acc,
            first,
            mid: mid_up,
            last: last_up,
            ctrl_mid,
        })?;
        if let Some(l) = latent {
            conn.send(&l)?;
        }

        let sync = conn.expect("SyncInfo")?;
        drop(sp_ex);
        match sync {
            Msg::SyncInfo { iter, first, mid, last } => {
                ensure!(
                    iter as usize == it,
                    "protocol desync: SyncInfo for iter {iter}, expected {it}"
                );
                let _sp = trace::span(trace::Stage::Update);
                self.model.apply_update(
                    &[(Group::First, first), (Group::Mid, mid), (Group::Last, last)],
                    lr_at(&self.cfg, it),
                );
            }
            Msg::Shutdown { reason } => {
                bail!("coordinator shut the run down mid-iteration: {reason}")
            }
            other => bail!("expected SyncInfo, got {}", other.name()),
        }
        if self.cfg.on_fault == OnFault::WaitRejoin {
            // Elastic runs: ship the post-step strategy state so the
            // coordinator can resurrect this node bit-identically if it
            // dies before the next step completes.  The coordinator
            // reads this synchronously before the next IterPlan.
            conn.send(&Msg::StateSync { iter: it as u32, blob: self.export_state() })?;
        }
        Ok(())
    }

    /// Serialize everything this node owns beyond the (deterministic)
    /// model replica: the mid-group method state and the last-group EF
    /// memory.  `ramp`/`ps` and all shapes are config-derived and not
    /// serialized; [`Node::import_state`] into a freshly built node of
    /// the same config continues bit-identically.
    fn export_state(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match &self.mid {
            MidState::Dense => out.push(0),
            MidState::Sparse { fb, .. } => {
                out.push(1);
                fb.write_state(&mut out);
            }
            MidState::Threshold { fb, threshold } => {
                out.push(2);
                fb.write_state(&mut out);
                ser::put_f32(&mut out, *threshold);
            }
            MidState::Lgc { fb, .. } => {
                out.push(3);
                fb.write_state(&mut out);
            }
        }
        self.last_fb.write_state(&mut out);
        out
    }

    /// Inverse of [`Node::export_state`]; the blob's variant tag must
    /// match what this node's config dictates.
    fn import_state(&mut self, blob: &[u8]) -> Result<()> {
        let mut r = Reader::new(blob);
        let tag = r.u8()?;
        match (&mut self.mid, tag) {
            (MidState::Dense, 0) => {}
            (MidState::Sparse { fb, .. }, 1) => fb.read_state(&mut r)?,
            (MidState::Threshold { fb, threshold }, 2) => {
                fb.read_state(&mut r)?;
                *threshold = r.f32()?;
            }
            (MidState::Lgc { fb, .. }, 3) => fb.read_state(&mut r)?,
            (_, t) => bail!(
                "worker state blob variant tag {t} does not match method {}",
                self.cfg.method.name()
            ),
        }
        self.last_fb.read_state(&mut r)?;
        r.finish().context("worker state blob")
    }

    /// Build the mid-group uplink: the node-local half of the selected
    /// strategy's exchange.  Returns the payload, the raw mid gradient
    /// (engaged LGC iterations only — the coordinator's trust-region
    /// clip needs it), and the AE latent message when this node encodes.
    fn mid_upload(
        &mut self,
        conn: &mut Conn,
        it: usize,
        phase: Phase,
        engaged: bool,
        mid_g: &[f32],
    ) -> Result<(MidUp, Option<Vec<f32>>, Option<Msg>)> {
        let fp16 = self.cfg.fp16_values;
        match &mut self.mid {
            MidState::Dense => {
                if self.overlap {
                    // Stream one dense slice per bucket, exchange order of
                    // the task graph (= ascending bucket id).
                    for (b, range) in self.plan.ranges().iter().enumerate() {
                        conn.send(&Msg::GradientBucket {
                            iter: it as u32,
                            bucket: b as u32,
                            up: BucketUp::Dense(mid_g[range.clone()].to_vec()),
                        })?;
                    }
                    return Ok((MidUp::Buckets(self.plan.len() as u32), None, None));
                }
                Ok((MidUp::Dense(mid_g.to_vec()), None, None))
            }
            MidState::Sparse { fb, ramp } => {
                let a = match ramp {
                    Some(r) => exponential_alpha(it, *r, self.cfg.alpha),
                    None => self.cfg.alpha,
                };
                let k_sel = topk::k_of(self.n_mid, a);
                {
                    let _sp = trace::span(trace::Stage::Ef);
                    fb.accumulate(mid_g);
                }
                // Bucketed selection is bit-identical to the monolithic
                // top-k for any plan (global threshold — DESIGN.md §13.2);
                // with a single-range plan it *is* the legacy path.
                {
                    let _sp = trace::span(trace::Stage::TopK);
                    fb.select_and_clear_bucketed_into(k_sel, self.plan.ranges(), &mut self.sc);
                }
                if self.overlap {
                    let up = send_sparse_buckets(
                        conn,
                        it,
                        &self.plan,
                        fp16,
                        self.cfg.index_codec,
                        &mut self.sc,
                    )?;
                    return Ok((up, None, None));
                }
                // Values ship post-pack: under fp16 the wire round-trip is
                // what every receiver aggregates (baselines::pack_values).
                pack_values_in_place(&mut self.sc.vals, fp16);
                let coded = index_coding::encode_with_into(
                    &self.sc.idx,
                    self.n_mid,
                    self.cfg.index_codec,
                    &mut self.sc.enc,
                )?
                .to_vec();
                Ok((MidUp::Sparse { coded_idx: coded, vals: self.sc.vals.clone() }, None, None))
            }
            MidState::Threshold { fb, threshold } => {
                let n = self.n_mid;
                let k_target = topk::k_of(n, self.cfg.alpha);
                {
                    let _sp = trace::span(trace::Stage::Ef);
                    fb.accumulate(mid_g);
                }
                let sp_sel = trace::span(trace::Stage::TopK);
                if *threshold == 0.0 {
                    *threshold = topk::threshold_for_k_in(fb.memory(), k_target, &mut self.sc.mags);
                }
                let thr = *threshold;
                let mem = fb.memory();
                self.sc.idx.clear();
                self.sc.idx.extend(
                    (0..n as u32)
                        .filter(|&i| mem[i as usize].abs() >= thr && mem[i as usize] != 0.0),
                );
                fb.take_at_into(&self.sc.idx, &mut self.sc.vals);
                drop(sp_sel);
                if self.sc.idx.len() > 2 * k_target {
                    *threshold *= 1.25;
                } else if self.sc.idx.len() < k_target / 2 {
                    *threshold *= 0.8;
                }
                if self.overlap {
                    // The threshold scan emits ascending indices, so the
                    // selection partitions cleanly into plan ranges.
                    self.plan.splits_of(&self.sc.idx, &mut self.sc.splits);
                    let up = send_sparse_buckets(
                        conn,
                        it,
                        &self.plan,
                        fp16,
                        self.cfg.index_codec,
                        &mut self.sc,
                    )?;
                    return Ok((up, None, None));
                }
                pack_values_in_place(&mut self.sc.vals, fp16);
                let coded = index_coding::encode_with_into(
                    &self.sc.idx,
                    n,
                    self.cfg.index_codec,
                    &mut self.sc.enc,
                )?
                .to_vec();
                Ok((MidUp::Sparse { coded_idx: coded, vals: self.sc.vals.clone() }, None, None))
            }
            MidState::Lgc { fb, ae, ps } => {
                if phase == Phase::Dense {
                    // Dense warmup: raw gradient uplink (PS mean or dense
                    // ring, both coordinator-side).  No EF accumulation —
                    // the memories start at the top-k phase.
                    return Ok((MidUp::Dense(mid_g.to_vec()), None, None));
                }
                let ps = *ps;
                {
                    let _sp = trace::span(trace::Stage::Ef);
                    fb.accumulate(mid_g);
                }
                let leader = if ps { 0 } else { it % self.nodes };
                if self.node == leader {
                    let sp_sel = trace::span(trace::Stage::TopK);
                    topk::top_k_into(
                        fb.memory(),
                        self.mu,
                        &mut self.sc.mags,
                        &mut self.support,
                        &mut self.sc.vals,
                    );
                    let mem = fb.memory();
                    self.support.sort_by(|&a, &b| {
                        mem[b as usize]
                            .partial_cmp(&mem[a as usize])
                            .unwrap_or(std::cmp::Ordering::Equal)
                    });
                    drop(sp_sel);
                    let coded = index_coding::encode_ordered_into(&self.support, &mut self.sc.enc)?
                        .to_vec();
                    conn.send(&Msg::Support { iter: it as u32, coded })?;
                }
                // Everyone (leader included) decodes the broadcast: one
                // uniform path, and the wire payload is what defines the
                // support order on every node.
                let coded = match conn.expect("SupportBcast")? {
                    Msg::SupportBcast { iter, coded } => {
                        ensure!(
                            iter as usize == it,
                            "protocol desync: SupportBcast for iter {iter}, expected {it}"
                        );
                        coded
                    }
                    Msg::Shutdown { reason } => {
                        bail!("coordinator shut the run down mid-iteration: {reason}")
                    }
                    other => bail!("expected SupportBcast, got {}", other.name()),
                };
                self.support = index_coding::decode_ordered(&coded)?;
                ensure!(
                    self.support.len() == self.mu,
                    "support broadcast has {} indices, expected mu={}",
                    self.support.len(),
                    self.mu
                );
                fb.take_at_into(&self.support, &mut self.vv);
                if !engaged {
                    // Top-k phase (or compressed with the AE still
                    // training): exact value-vector uplink.
                    return Ok((MidUp::Vv(self.vv.clone()), None, None));
                }
                // Compressed phase, learned coder engaged.
                let ctrl = Some(mid_g.to_vec());
                if ps {
                    // Innovation (top innovation_frac of |vv|, kept at
                    // position) + RMS scale; the leader also encodes the
                    // shared latent (lgc::innovation_into, Algorithm 1).
                    let k_inn = topk::k_of(self.vv.len(), self.cfg.innovation_frac);
                    {
                        let _sp = trace::span(trace::Stage::TopK);
                        topk::top_k_into(
                            &self.vv,
                            k_inn,
                            &mut self.sc.mags,
                            &mut self.sc.idx,
                            &mut self.sc.vals,
                        );
                    }
                    let coded_idx = index_coding::encode_with_into(
                        &self.sc.idx,
                        self.vv.len(),
                        self.cfg.index_codec,
                        &mut self.sc.enc,
                    )?
                    .to_vec();
                    let scale = rms(&self.vv);
                    let latent = if self.node == leader {
                        let _sp = trace::span(trace::Stage::AeEncode);
                        let (lat, s) = ae.encode(self.engine, &self.vv)?;
                        Some(Msg::Latent { iter: it as u32, latent: lat, scale: s })
                    } else {
                        None
                    };
                    Ok((
                        MidUp::Innovation { coded_idx, vals: self.sc.vals.clone(), scale },
                        ctrl,
                        latent,
                    ))
                } else {
                    // RAR: every node encodes; the latents ring-reduce on
                    // the coordinator (Algorithm 2, eq. 19).
                    let sp_ae = trace::span(trace::Stage::AeEncode);
                    let (lat, s) = ae.encode(self.engine, &self.vv)?;
                    drop(sp_ae);
                    let latent = Msg::Latent { iter: it as u32, latent: lat, scale: s };
                    Ok((MidUp::None, ctrl, Some(latent)))
                }
            }
        }
    }

    /// Last-group uplink: dense for Baseline/QSGD and everyone's dense
    /// phase; top-k + EF otherwise (mirrors `Trainer::last_exchange` —
    /// note: last-group values never fp16-pack, as in the simulator).
    fn last_upload(&mut self, phase: Phase, last_g: Vec<f32>) -> Result<LastUp> {
        let dense = matches!(self.cfg.method, Method::Baseline | Method::Qsgd)
            || phase == Phase::Dense;
        if dense {
            return Ok(LastUp::Dense(last_g));
        }
        let k_sel = topk::k_of(self.n_last, self.cfg.alpha);
        {
            let _sp = trace::span(trace::Stage::Ef);
            self.last_fb.accumulate(&last_g);
        }
        {
            let _sp = trace::span(trace::Stage::TopK);
            self.last_fb.select_and_clear_into(k_sel, &mut self.sc);
        }
        let coded = index_coding::encode_with_into(
            &self.sc.idx,
            self.n_last,
            self.cfg.index_codec,
            &mut self.sc.enc,
        )?
        .to_vec();
        Ok(LastUp::Sparse { coded_idx: coded, vals: self.sc.vals.clone() })
    }
}

/// Stream the selected sparse mid upload as one [`Msg::GradientBucket`]
/// frame per plan bucket (ascending bucket id — the task graph's exchange
/// order), then return the closing `MidUp::Buckets` tag.  Expects
/// `sc.idx`/`sc.vals` from a bucketed (or splits-annotated) selection:
/// `sc.splits[b]..sc.splits[b + 1]` is bucket *b*'s slice.  Indices go on
/// the wire bucket-local, coded over the bucket width — exactly the
/// framing `baselines::record_sparse_packet` prices in the sim.
fn send_sparse_buckets(
    conn: &mut Conn,
    it: usize,
    plan: &BucketPlan,
    fp16: bool,
    codec: IndexCodec,
    sc: &mut Scratch,
) -> Result<MidUp> {
    debug_assert_eq!(sc.splits.len(), plan.len() + 1);
    for (b, range) in plan.ranges().iter().enumerate() {
        let (lo, hi) = (sc.splits[b], sc.splits[b + 1]);
        let mut vals = sc.vals[lo..hi].to_vec();
        pack_values_in_place(&mut vals, fp16);
        sc.idx_local.clear();
        sc.idx_local.extend(sc.idx[lo..hi].iter().map(|&i| i - range.start as u32));
        let coded = index_coding::encode_with_into(
            &sc.idx_local,
            range.end - range.start,
            codec,
            &mut sc.enc,
        )?
        .to_vec();
        conn.send(&Msg::GradientBucket {
            iter: it as u32,
            bucket: b as u32,
            up: BucketUp::Sparse { coded_idx: coded, vals },
        })?;
    }
    Ok(MidUp::Buckets(plan.len() as u32))
}
