//! Comparator methods (paper §VI): uncompressed baseline, Sparse GD [19],
//! DGC [20], ScaleCom [25], QSGD [22].
//!
//! Every method implements [`MidStrategy`]: given each node's fresh
//! mid-group gradient, perform the (byte-accounted) exchange and return
//! the aggregated dense gradient the optimizer applies.  The LGC
//! strategies live in `coordinator::lgc` (they need the autoencoder and
//! the 3-phase schedule); everything here is schedule-independent apart
//! from DGC's own sparsity ramp.
//!
//! Execution model (DESIGN.md §6.5): each strategy's *node-local* stage —
//! error-feedback accumulation, selection, quantization, payload encoding
//! — runs across worker threads via [`crate::coordinator::parallel`],
//! with per-node state (feedback memory, RNG stream, ledger shard) owned
//! per node.  Aggregation back to the dense mean is the synchronization
//! barrier and always reduces in node order, so results and ledger totals
//! are independent of the thread count.

use anyhow::Result;

use crate::compress::index_coding::IndexCodec;
use crate::compress::{f16, index_coding, quantize, topk, Correction, FeedbackMemory, Scratch};
use crate::coordinator::bucket::BucketPlan;
use crate::coordinator::parallel;
use crate::coordinator::scheduler::{bucket_task_graph, exponential_alpha, Phase, StepTask};
use crate::metrics::{Kind, Ledger, NodeLedger};
use crate::net::NetSim;
use crate::obs::trace;
use crate::runtime::Engine;
use crate::util::rng::Rng;
use crate::util::ser::{self, Reader};

/// Per-iteration context handed to a strategy.
pub struct ExchangeCtx<'a> {
    pub engine: &'a Engine,
    /// Global ledger for *synchronization-stage* traffic (ring steps,
    /// leader index broadcasts).  Node-local traffic is recorded into
    /// `shards` instead and merged at end-of-iteration.
    pub ledger: &'a mut Ledger,
    /// One ledger shard per node, recorded lock-free by the node's worker.
    pub shards: &'a mut [NodeLedger],
    pub iter: usize,
    pub phase: Phase,
    /// Keep-fraction from the scheduler (LGC methods honour it; baselines
    /// use their own fixed/ramped values).
    pub alpha: f64,
    /// Transmit value payloads as f16 (rate ablation; lossy, the
    /// dequantized values are what the update actually applies).
    pub fp16: bool,
    /// Index-coding strategy for sparse support sets (`--index-codec`,
    /// DESIGN.md §16.2) — a pure rate knob: every strategy decodes to the
    /// same index set regardless.
    pub codec: IndexCodec,
    /// Coordinator-level RNG (AE sampling etc.); per-node stochastic work
    /// must use per-node streams owned by the strategy, never this.
    pub rng: &'a mut Rng,
    /// Worker threads for per-node stages (0 = one per core).
    pub threads: usize,
    /// One scratch arena per node, owned by the coordinator alongside the
    /// ledger shards (DESIGN.md §6.11): node-local stages borrow buffers
    /// from their node's arena instead of allocating per iteration.
    pub scratches: &'a mut [Scratch],
    /// The simulated network fabric's event collector (DESIGN.md §11).
    /// Shard-recorded uplinks reach it automatically at merge time;
    /// strategies only report their *synchronization* traffic here:
    /// server fan-outs ([`NetSim::fanout`]), leader/trainer broadcasts
    /// ([`NetSim::broadcast`]), and ring steps (via
    /// [`crate::coordinator::ring::ring_allreduce_mean_timed`]).
    pub net: &'a mut NetSim,
    /// The mid-group bucket plan (DESIGN.md §13).  Single-bucket for
    /// non-bucketable methods regardless of `--buckets`.
    pub plan: &'a BucketPlan,
    /// Effective overlap mode: `cfg.overlap` and the plan actually has
    /// more than one bucket.  When false, bucketed strategies emit the
    /// exact legacy accounting (one packet record pair, one fan-out
    /// round) — the `--no-overlap` bit-identity contract.
    pub overlap: bool,
    /// Liveness mask under `--on-fault continue` (DESIGN.md §14): dead
    /// nodes contribute no gradient, no EF work, and no bytes; every
    /// aggregate renormalizes over the survivors.  All-true in fault-free
    /// runs, where the masked paths are arithmetically identical to the
    /// unmasked ones.
    pub alive: &'a [bool],
}

/// Apply the configured value-payload precision: returns the values as
/// they arrive at the receiver plus the wire bytes.
pub fn pack_values(mut values: Vec<f32>, fp16: bool) -> (Vec<f32>, usize) {
    let bytes = pack_values_in_place(&mut values, fp16);
    (values, bytes)
}

/// In-place [`pack_values`] over an arena-resident value buffer: under
/// fp16 each value is replaced by its wire round-trip (what the receiver
/// applies), element-wise with no allocation; returns the wire bytes.
pub fn pack_values_in_place(values: &mut [f32], fp16: bool) -> usize {
    if fp16 {
        f16::roundtrip_in_place(values);
        values.len() * 2
    } else {
        values.len() * 4
    }
}

/// Dense mean with per-node byte accounting into the shards (the PS
/// uncompressed pattern; also every method's dense warmup phase).
pub fn dense_mean_accounted(grads: &[Vec<f32>], shards: &mut [NodeLedger]) -> Vec<f32> {
    assert_eq!(
        grads.len(),
        shards.len(),
        "dense_mean_accounted: one ledger shard per node"
    );
    let n = grads[0].len();
    let mut mean = vec![0.0f32; n];
    for (g, shard) in grads.iter().zip(shards.iter_mut()) {
        shard.record(Kind::Dense, n * 4);
        for (m, x) in mean.iter_mut().zip(g) {
            *m += x;
        }
    }
    let k = grads.len() as f32;
    mean.iter_mut().for_each(|m| *m /= k);
    mean
}

/// Number of live nodes in a liveness mask.
pub fn live_count(alive: &[bool]) -> usize {
    alive.iter().filter(|&&a| a).count()
}

/// Width of the exchanged gradient group: the first live node's length.
/// Dead nodes may carry empty placeholder vectors under `--on-fault
/// continue`, so `grads[0].len()` is not safe on masked paths.
pub(crate) fn live_width(grads: &[Vec<f32>], alive: &[bool]) -> usize {
    grads
        .iter()
        .zip(alive)
        .find(|&(_, &a)| a)
        .map(|(g, _)| g.len())
        .expect("live_width: no live nodes left")
}

/// [`dense_mean_accounted`] over the survivors of a liveness mask: dead
/// nodes contribute nothing (no bytes recorded, their EF residual is
/// documented as lost — DESIGN.md §14) and the mean renormalizes over
/// the live count.  With an all-true mask this is arithmetically
/// identical to [`dense_mean_accounted`].
pub fn dense_mean_masked(
    grads: &[Vec<f32>],
    alive: &[bool],
    shards: &mut [NodeLedger],
) -> Vec<f32> {
    assert_eq!(grads.len(), shards.len(), "dense_mean_masked: one ledger shard per node");
    assert_eq!(grads.len(), alive.len(), "dense_mean_masked: one liveness bit per node");
    let n = live_width(grads, alive);
    let mut mean = vec![0.0f32; n];
    for ((g, shard), &live) in grads.iter().zip(shards.iter_mut()).zip(alive) {
        if !live {
            continue;
        }
        shard.record(Kind::Dense, n * 4);
        for (m, x) in mean.iter_mut().zip(g) {
            *m += x;
        }
    }
    let k = live_count(alive) as f32;
    mean.iter_mut().for_each(|m| *m /= k);
    mean
}

/// Read + check the per-node row count prefix of a strategy state blob
/// (crash-safe resume, DESIGN.md §14).
pub(crate) fn check_node_count(r: &mut Reader, expect: usize, what: &str) -> Result<()> {
    let n = r.u64()? as usize;
    anyhow::ensure!(n == expect, "{what} state blob has {n} node rows, expected {expect}");
    Ok(())
}

/// A mid-group exchange method: the single seam every comparator and
/// both LGC instances plug into (strategy pattern over the §VI-A
/// mid-layer group).
pub trait MidStrategy {
    fn name(&self) -> &'static str;

    /// Exchange + aggregate the mid-group gradients (one vector per node).
    /// Returns the dense aggregated gradient (mean).
    fn exchange(&mut self, ctx: &mut ExchangeCtx, grads: &[Vec<f32>]) -> Result<Vec<f32>>;

    /// Reconstruction losses of the learned compressor, if any (Fig. 14).
    fn ae_losses(&self) -> &[(f32, f32)] {
        &[]
    }

    /// Serialize every piece of cross-iteration state this strategy owns
    /// (EF memories, per-node RNG streams, learned-compressor weights,
    /// latched gates) for crash-safe resume (DESIGN.md §14).  Transient
    /// per-iteration buffers (supports, scratch arenas) are rebuilt by
    /// the next exchange and are not serialized.
    fn save_state(&self, out: &mut Vec<u8>);

    /// Inverse of [`MidStrategy::save_state`]: restore into a freshly
    /// constructed strategy of the same configuration.  A resumed run
    /// must continue bit-identically to an uninterrupted one.
    fn load_state(&mut self, r: &mut Reader) -> Result<()>;
}

/// Dense mean + per-node dense bytes (PS-pattern uncompressed training).
pub struct Baseline;

impl MidStrategy for Baseline {
    fn name(&self) -> &'static str {
        "baseline"
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx, grads: &[Vec<f32>]) -> Result<Vec<f32>> {
        let mean = dense_mean_masked(grads, ctx.alive, &mut *ctx.shards);
        // The server scatters the dense aggregate back to every worker —
        // per bucket under the overlap pipeline (per-node `Dense` ledger
        // records are slice-size-independent, so the byte ledger is
        // identical in both modes; only the round structure differs).
        if ctx.overlap && !ctx.plan.is_single() {
            let per_bucket: Vec<u64> =
                ctx.plan.ranges().iter().map(|r| ((r.end - r.start) * 4) as u64).collect();
            fanout_rounds(ctx.net, true, ctx.plan.len(), &[per_bucket]);
        } else {
            ctx.net.fanout((mean.len() * 4) as u64);
        }
        Ok(mean)
    }

    fn save_state(&self, _out: &mut Vec<u8>) {}

    fn load_state(&mut self, _r: &mut Reader) -> Result<()> {
        Ok(())
    }
}

/// Pack + record one node's selected sparse packet under the bucket plan
/// (the selection — `sc.idx` / `sc.vals` / `sc.splits` — is already in
/// the arena).  Returns per-bucket wire bytes.
///
/// * `overlap == false` (the legacy shape): one whole-group packet —
///   values packed in one slab, indices coded once over `n` — recorded as
///   a single `Values` + `Indices` pair, byte-identical to the unbucketed
///   path for any plan.
/// * `overlap == true`: one packet per bucket — values slice packed per
///   bucket, indices rebased to the bucket range and coded over its
///   width — recorded as `plan.len()` `Values`/`Indices` pairs in bucket
///   order, the exact sequence the TCP coordinator replays from
///   bucket-tagged frames (DESIGN.md §13.4).
pub(crate) fn record_sparse_packet(
    n: usize,
    plan: &BucketPlan,
    overlap: bool,
    fp16: bool,
    codec: IndexCodec,
    shard: &mut NodeLedger,
    sc: &mut Scratch,
) -> Result<Vec<u64>> {
    if !overlap {
        let bytes = pack_values_in_place(&mut sc.vals, fp16);
        shard.record(Kind::Values, bytes);
        let coded = index_coding::encode_with_into(&sc.idx, n, codec, &mut sc.enc)?.len();
        shard.record(Kind::Indices, coded);
        return Ok(vec![(bytes + coded) as u64]);
    }
    debug_assert_eq!(sc.splits.len(), plan.len() + 1);
    let mut per_bucket = Vec::with_capacity(plan.len());
    for (b, range) in plan.ranges().iter().enumerate() {
        let (lo, hi) = (sc.splits[b], sc.splits[b + 1]);
        let bytes = pack_values_in_place(&mut sc.vals[lo..hi], fp16);
        shard.record(Kind::Values, bytes);
        sc.idx_local.clear();
        sc.idx_local.extend(sc.idx[lo..hi].iter().map(|&i| i - range.start as u32));
        let coded = index_coding::encode_with_into(
            &sc.idx_local,
            range.end - range.start,
            codec,
            &mut sc.enc,
        )?
        .len();
        shard.record(Kind::Indices, coded);
        per_bucket.push((bytes + coded) as u64);
    }
    Ok(per_bucket)
}

/// Emit the exchange rounds of a bucketed fan-out on the fabric,
/// walking [`bucket_task_graph`] (the single owner of per-iteration
/// ordering): overlapped mode prices one bucket-tagged round per bucket;
/// otherwise the legacy single aggregate round.  `per_node[node][b]` is
/// node `node`'s bucket-`b` wire bytes.
pub(crate) fn fanout_rounds(
    net: &mut NetSim,
    overlap: bool,
    buckets: usize,
    per_node: &[Vec<u64>],
) {
    if !overlap {
        net.fanout(per_node.iter().flatten().sum());
        return;
    }
    for task in bucket_task_graph(buckets, true) {
        if let StepTask::Exchange(b) = task {
            net.fanout_bucketed(b, per_node.iter().map(|v| v.get(b).copied().unwrap_or(0)).sum());
        }
    }
}

/// Shared machinery: per-node EF -> top-k -> (values + coded indices) ->
/// scatter-mean. Used by SparseGd, Dgc, and the trainer's last-group
/// exchange.  The per-node stage runs in parallel and leaves each node's
/// packet in its scratch arena (`sc.idx` / `sc.vals` / `sc.splits`); the
/// scatter-mean barrier reads the arenas in node order, so no per-packet
/// allocation survives into steady state.
///
/// Selection always runs bucketed
/// ([`FeedbackMemory::select_and_clear_bucketed_into`]) with one *global*
/// threshold, so the selected set, the EF clears, and the aggregate are
/// bit-identical to the monolithic path for any plan; only the packet
/// framing and the round structure differ between overlap modes
/// (see [`record_sparse_packet`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn sparse_ef_exchange(
    fbs: &mut [FeedbackMemory],
    grads: &[Vec<f32>],
    alpha: f64,
    fp16: bool,
    codec: IndexCodec,
    shards: &mut [NodeLedger],
    scratches: &mut [Scratch],
    threads: usize,
    plan: &BucketPlan,
    overlap: bool,
    net: &mut NetSim,
    alive: &[bool],
) -> Result<Vec<f32>> {
    let n = live_width(grads, alive);
    let overlap = overlap && !plan.is_single();
    let k_sel = topk::k_of(n, alpha);
    let packet_bytes = parallel::collect_node_results(parallel::par_zip3_mut(
        threads,
        fbs,
        shards,
        scratches,
        |node, fb, shard, sc| -> Result<Vec<u64>> {
            if !alive[node] {
                // Dead node: no EF work, no packet, no bytes.  Its arena
                // is cleared so the scatter barrier below sees nothing.
                sc.idx.clear();
                sc.vals.clear();
                return Ok(Vec::new());
            }
            let _lane = trace::lane_scope(node);
            {
                let _sp = trace::span(trace::Stage::Ef);
                fb.accumulate(&grads[node]);
            }
            {
                let _sp = trace::span(trace::Stage::TopK);
                fb.select_and_clear_bucketed_into(k_sel, plan.ranges(), sc);
            }
            record_sparse_packet(n, plan, overlap, fp16, codec, shard, sc)
        },
    ))?;
    let mut mean = vec![0.0f32; n];
    for (sc, &live) in scratches.iter().zip(alive) {
        if live {
            topk::scatter_add(&mut mean, &sc.idx, &sc.vals);
        }
    }
    let k = live_count(alive) as f32;
    mean.iter_mut().for_each(|m| *m /= k);
    // Fan-out round(s): the server relays the sparse aggregate, measured
    // as the concatenation of the per-node compressed packets (an upper
    // bound on the union-support encoding; DESIGN.md §11) — per bucket
    // when overlapping, in one aggregate round otherwise.
    fanout_rounds(net, overlap, plan.len(), &packet_bytes);
    Ok(mean)
}

/// Sparse GD [19]: fixed-alpha top-k with plain error feedback.
pub struct SparseGd {
    fbs: Vec<FeedbackMemory>,
    alpha: f64,
}

impl SparseGd {
    pub fn new(nodes: usize, n: usize, alpha: f64) -> Self {
        SparseGd {
            fbs: (0..nodes)
                .map(|_| FeedbackMemory::new(n, Correction::Plain, 0.0))
                .collect(),
            alpha,
        }
    }
}

impl MidStrategy for SparseGd {
    fn name(&self) -> &'static str {
        "sparse_gd"
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx, grads: &[Vec<f32>]) -> Result<Vec<f32>> {
        sparse_ef_exchange(
            &mut self.fbs,
            grads,
            self.alpha,
            ctx.fp16,
            ctx.codec,
            &mut *ctx.shards,
            &mut *ctx.scratches,
            ctx.threads,
            ctx.plan,
            ctx.overlap,
            &mut *ctx.net,
            ctx.alive,
        )
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        ser::put_u64(out, self.fbs.len() as u64);
        for fb in &self.fbs {
            fb.write_state(out);
        }
    }

    fn load_state(&mut self, r: &mut Reader) -> Result<()> {
        check_node_count(r, self.fbs.len(), "sparse_gd")?;
        for fb in &mut self.fbs {
            fb.read_state(r)?;
        }
        Ok(())
    }
}

/// DGC [20]: momentum-corrected EF + exponential sparsity warmup.
pub struct Dgc {
    fbs: Vec<FeedbackMemory>,
    alpha: f64,
    ramp: usize,
}

impl Dgc {
    pub fn new(nodes: usize, n: usize, alpha: f64, ramp: usize, momentum: f32) -> Self {
        Dgc {
            fbs: (0..nodes)
                .map(|_| FeedbackMemory::new(n, Correction::Momentum, momentum))
                .collect(),
            alpha,
            ramp,
        }
    }
}

impl MidStrategy for Dgc {
    fn name(&self) -> &'static str {
        "dgc"
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx, grads: &[Vec<f32>]) -> Result<Vec<f32>> {
        let a = exponential_alpha(ctx.iter, self.ramp, self.alpha);
        sparse_ef_exchange(
            &mut self.fbs,
            grads,
            a,
            ctx.fp16,
            ctx.codec,
            &mut *ctx.shards,
            &mut *ctx.scratches,
            ctx.threads,
            ctx.plan,
            ctx.overlap,
            &mut *ctx.net,
            ctx.alive,
        )
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        ser::put_u64(out, self.fbs.len() as u64);
        for fb in &self.fbs {
            fb.write_state(out);
        }
    }

    fn load_state(&mut self, r: &mut Reader) -> Result<()> {
        check_node_count(r, self.fbs.len(), "dgc")?;
        for fb in &mut self.fbs {
            fb.read_state(r)?;
        }
        Ok(())
    }
}

/// ScaleCom [25]: Cyclic Local Top-k — the leader's top-k index set is
/// followed by every node, so indices are coded once per iteration.
pub struct ScaleCom {
    fbs: Vec<FeedbackMemory>,
    alpha: f64,
    /// The leader's broadcast index set, refilled per iteration
    /// (persistent so the steady state allocates nothing; §6.11).
    support: Vec<u32>,
}

impl ScaleCom {
    pub fn new(nodes: usize, n: usize, alpha: f64, momentum: f32) -> Self {
        ScaleCom {
            fbs: (0..nodes)
                .map(|_| FeedbackMemory::new(n, Correction::Momentum, momentum))
                .collect(),
            alpha,
            support: Vec::new(),
        }
    }
}

impl MidStrategy for ScaleCom {
    fn name(&self) -> &'static str {
        "scalecom"
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx, grads: &[Vec<f32>]) -> Result<Vec<f32>> {
        // Leaderful method: `--on-fault continue` is rejected at config
        // validation, so the mask is always all-true here.
        debug_assert!(ctx.alive.iter().all(|&a| a), "scalecom does not support dead nodes");
        let n = grads[0].len();
        let k_sel = topk::k_of(n, self.alpha);
        let nodes = grads.len();
        // Node-local stage 1: EF accumulation.
        parallel::par_map_mut(ctx.threads, &mut self.fbs, |node, fb| {
            let _lane = trace::lane_scope(node);
            let _sp = trace::span(trace::Stage::Ef);
            fb.accumulate(&grads[node]);
        });
        // Barrier: the cyclic leader's local top-k defines everyone's
        // index set; the broadcast is leader traffic on the global ledger.
        // Selection + encode borrow the leader's arena; the index list is
        // staged into the persistent support buffer so the arenas are
        // free for the gather stage.
        let leader = ctx.iter % nodes;
        let coded = {
            let sc = &mut ctx.scratches[leader];
            let mem = self.fbs[leader].memory();
            {
                let _sp = trace::span(trace::Stage::TopK);
                topk::top_k_into(mem, k_sel, &mut sc.mags, &mut sc.idx, &mut sc.vals);
            }
            let coded = index_coding::encode_with_into(&sc.idx, n, ctx.codec, &mut sc.enc)?.len();
            ctx.ledger.record(leader, Kind::Indices, coded);
            self.support.clear();
            self.support.extend_from_slice(&sc.idx);
            coded
        };
        // The leader's index broadcast is a synchronization round of its
        // own on the fabric (DESIGN.md §11).
        ctx.net.send(leader, coded as u64);
        ctx.net.barrier();
        // Node-local stage 2: gather-at-support + value packing.
        let fp16 = ctx.fp16;
        let indices = &self.support;
        let value_bytes = parallel::par_zip3_mut(
            ctx.threads,
            &mut self.fbs,
            &mut *ctx.shards,
            &mut *ctx.scratches,
            |_node, fb, shard, sc| {
                fb.take_at_into(indices, &mut sc.vals);
                let bytes = pack_values_in_place(&mut sc.vals, fp16);
                shard.record(Kind::Values, bytes);
                bytes
            },
        );
        // Barrier: mean in node order.
        let mut mean = vec![0.0f32; n];
        for sc in ctx.scratches.iter() {
            topk::scatter_add(&mut mean, indices, &sc.vals);
        }
        mean.iter_mut().for_each(|m| *m /= nodes as f32);
        // Fan-out: the server scatters one aggregated value payload (the
        // support is already known to every node from the leader's
        // broadcast); every node packed the same support, so any node's
        // packet size is the aggregate's.
        debug_assert!(value_bytes.iter().all(|&b| b == value_bytes[0]));
        ctx.net.fanout(value_bytes[0] as u64);
        Ok(mean)
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        // The leader's support is refilled every iteration; only the EF
        // memories carry across.
        ser::put_u64(out, self.fbs.len() as u64);
        for fb in &self.fbs {
            fb.write_state(out);
        }
    }

    fn load_state(&mut self, r: &mut Reader) -> Result<()> {
        check_node_count(r, self.fbs.len(), "scalecom")?;
        for fb in &mut self.fbs {
            fb.read_state(r)?;
        }
        Ok(())
    }
}

/// QSGD [22]: stochastic quantization, no error feedback (as published).
/// Each node owns a private RNG stream so quantization draws are
/// independent of scheduling (and of every other node's draws).
pub struct Qsgd {
    pub levels: u32,
    pub bucket: usize,
    rngs: Vec<Rng>,
}

impl Qsgd {
    pub fn new(levels: u32, bucket: usize, nodes: usize, seed: u64) -> Self {
        let root = Rng::new(seed ^ 0x4546_4400);
        Qsgd {
            levels,
            bucket,
            rngs: (0..nodes).map(|node| root.fork(node as u64)).collect(),
        }
    }
}

impl MidStrategy for Qsgd {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx, grads: &[Vec<f32>]) -> Result<Vec<f32>> {
        // No error feedback: a dropped node's quantization noise is never
        // retransmitted, so `--on-fault continue` is rejected at config
        // validation and the mask is always all-true here.
        debug_assert!(ctx.alive.iter().all(|&a| a), "qsgd does not support dead nodes");
        let n = grads[0].len();
        let (levels, bucket) = (self.levels, self.bucket);
        // Node-local stage: quantize into each node's arena buffer.
        parallel::par_zip3_mut(
            ctx.threads,
            &mut self.rngs,
            &mut *ctx.shards,
            &mut *ctx.scratches,
            |node, rng, shard, sc| {
                let _lane = trace::lane_scope(node);
                let _sp = trace::span(trace::Stage::Quantize);
                let bytes = quantize::qsgd_into(&grads[node], levels, bucket, rng, &mut sc.vals);
                shard.record(Kind::Values, bytes);
            },
        );
        let mut mean = vec![0.0f32; n];
        for sc in ctx.scratches.iter() {
            for (m, x) in mean.iter_mut().zip(&sc.vals) {
                *m += x;
            }
        }
        let k = grads.len() as f32;
        mean.iter_mut().for_each(|m| *m /= k);
        // Fan-out: the dequantized aggregate is dense again.
        ctx.net.fanout((n * 4) as u64);
        Ok(mean)
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        // The per-node quantization RNG streams are the only
        // cross-iteration state.
        ser::put_u64(out, self.rngs.len() as u64);
        for rng in &self.rngs {
            rng.save_state(out);
        }
    }

    fn load_state(&mut self, r: &mut Reader) -> Result<()> {
        check_node_count(r, self.rngs.len(), "qsgd")?;
        for rng in &mut self.rngs {
            *rng = Rng::load_state(r)?;
        }
        Ok(())
    }
}

/// Per-node state of the hard-threshold method (owned as one unit so the
/// node-local stage threads cleanly).
struct ThresholdNode {
    fb: FeedbackMemory,
    /// Current threshold estimate.
    threshold: f32,
}

/// Hard-threshold sparsification (Aji & Heafield [29], paper SS II-B):
/// transmit every EF-memory coordinate whose magnitude exceeds a
/// threshold. The threshold self-calibrates each iteration from the
/// running byte budget implied by `alpha` (the keep-fraction), so payload
/// sizes are *variable* per iteration — the structural contrast to exact
/// top-k that [29] embodies.
pub struct HardThreshold {
    nodes: Vec<ThresholdNode>,
    alpha: f64,
}

impl HardThreshold {
    pub fn new(nodes: usize, n: usize, alpha: f64) -> Self {
        HardThreshold {
            nodes: (0..nodes)
                .map(|_| ThresholdNode {
                    fb: FeedbackMemory::new(n, Correction::Plain, 0.0),
                    threshold: 0.0,
                })
                .collect(),
            alpha,
        }
    }
}

impl MidStrategy for HardThreshold {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn exchange(&mut self, ctx: &mut ExchangeCtx, grads: &[Vec<f32>]) -> Result<Vec<f32>> {
        let n = live_width(grads, ctx.alive);
        let k_target = topk::k_of(n, self.alpha);
        let fp16 = ctx.fp16;
        let codec = ctx.codec;
        let plan = ctx.plan;
        let overlap = ctx.overlap && !plan.is_single();
        let alive = ctx.alive;
        let packet_bytes = parallel::collect_node_results(parallel::par_zip3_mut(
            ctx.threads,
            &mut self.nodes,
            &mut *ctx.shards,
            &mut *ctx.scratches,
            |node, st, shard, sc| -> Result<Vec<u64>> {
                if !alive[node] {
                    sc.idx.clear();
                    sc.vals.clear();
                    return Ok(Vec::new());
                }
                let _lane = trace::lane_scope(node);
                {
                    let _sp = trace::span(trace::Stage::Ef);
                    st.fb.accumulate(&grads[node]);
                }
                let sp_sel = trace::span(trace::Stage::TopK);
                if st.threshold == 0.0 {
                    // Calibrate from the first post-accumulation
                    // distribution.
                    st.threshold = topk::threshold_for_k_in(st.fb.memory(), k_target, &mut sc.mags);
                }
                let thr = st.threshold;
                let mem = st.fb.memory();
                sc.idx.clear();
                sc.idx.extend(
                    (0..n as u32)
                        .filter(|&i| mem[i as usize].abs() >= thr && mem[i as usize] != 0.0),
                );
                st.fb.take_at_into(&sc.idx, &mut sc.vals);
                drop(sp_sel);
                // Adapt the threshold toward the target payload size
                // (x2 AIMD).
                if sc.idx.len() > 2 * k_target {
                    st.threshold *= 1.25;
                } else if sc.idx.len() < k_target / 2 {
                    st.threshold *= 0.8;
                }
                // The filter scan above emits ascending indices, so the
                // plan can segment them directly.
                plan.splits_of(&sc.idx, &mut sc.splits);
                record_sparse_packet(n, plan, overlap, fp16, codec, shard, sc)
            },
        ))?;
        let mut mean = vec![0.0f32; n];
        for (sc, &live) in ctx.scratches.iter().zip(alive) {
            if live {
                topk::scatter_add(&mut mean, &sc.idx, &sc.vals);
            }
        }
        mean.iter_mut().for_each(|m| *m /= live_count(alive) as f32);
        // Fan-out: relay of the concatenated per-node packets (variable
        // payloads, so this is measured per iteration) — per bucket when
        // overlapping.
        fanout_rounds(ctx.net, overlap, plan.len(), &packet_bytes);
        Ok(mean)
    }

    fn save_state(&self, out: &mut Vec<u8>) {
        ser::put_u64(out, self.nodes.len() as u64);
        for st in &self.nodes {
            st.fb.write_state(out);
            ser::put_f32(out, st.threshold);
        }
    }

    fn load_state(&mut self, r: &mut Reader) -> Result<()> {
        check_node_count(r, self.nodes.len(), "threshold")?;
        for st in &mut self.nodes {
            st.fb.read_state(r)?;
            st.threshold = r.f32()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Ledger;

    // Strategies that need an `Engine` are exercised by the integration
    // suite in rust/tests/; the pure helpers are tested here.

    fn merged(shards: &mut [NodeLedger]) -> Ledger {
        let mut l = Ledger::new();
        l.merge_shards(shards);
        l.end_iteration();
        l
    }

    #[test]
    fn sparse_ef_exchange_conserves_mass() {
        let mut fbs = vec![
            FeedbackMemory::new(6, Correction::Plain, 0.0),
            FeedbackMemory::new(6, Correction::Plain, 0.0),
        ];
        let grads = vec![
            vec![1.0, 0.0, 0.0, 0.0, 0.0, 5.0],
            vec![0.0, 2.0, 0.0, 0.0, 0.0, -5.0],
        ];
        let mut shards = NodeLedger::for_nodes(2);
        let mut scratches = Scratch::for_nodes(2);
        let mut net = NetSim::new(Default::default(), 2);
        let mean = sparse_ef_exchange(
            &mut fbs,
            &grads,
            0.34,
            false,
            IndexCodec::Deflate,
            &mut shards,
            &mut scratches,
            1,
            &BucketPlan::single(6),
            false,
            &mut net,
            &[true; 2],
        )
        .unwrap();
        // k = ceil(0.34 * 6) = 3 coords per node transmitted; transmitted
        // + residual must equal the accumulated gradient per node (the
        // stronger invariant is proptested in tests/proptests.rs).
        assert_eq!(mean.len(), 6);
        let ledger = merged(&mut shards);
        assert!(ledger.total() > 0);
        assert_eq!(ledger.per_kind[&Kind::Values], 2 * 3 * 4);
    }

    #[test]
    fn sparse_ef_exchange_thread_invariant() {
        // Same seed, 1 worker vs many workers: bitwise-identical mean and
        // bitwise-identical merged ledger (the tentpole's determinism
        // contract at the strategy level).
        let run = |threads: usize| {
            let mut rng = Rng::new(0xBEEF);
            let nodes = 8;
            let n = 512;
            let mut fbs: Vec<FeedbackMemory> = (0..nodes)
                .map(|_| FeedbackMemory::new(n, Correction::Momentum, 0.9))
                .collect();
            let mut shards = NodeLedger::for_nodes(nodes);
            let mut scratches = Scratch::for_nodes(nodes);
            let mut ledger = Ledger::new();
            let mut net = NetSim::new(Default::default(), nodes);
            let mut means = Vec::new();
            for _ in 0..4 {
                let grads: Vec<Vec<f32>> =
                    (0..nodes).map(|_| rng.normal_vec(n, 1.0)).collect();
                let mean = sparse_ef_exchange(
                    &mut fbs,
                    &grads,
                    0.05,
                    false,
                    IndexCodec::Deflate,
                    &mut shards,
                    &mut scratches,
                    threads,
                    &BucketPlan::single(n),
                    false,
                    &mut net,
                    &vec![true; nodes],
                )
                .unwrap();
                for shard in shards.iter() {
                    let (msgs, bytes) = shard.pending_recurring();
                    net.send_many(shard.node(), msgs, bytes);
                }
                net.end_iteration();
                ledger.merge_shards(&mut shards);
                ledger.end_iteration();
                means.push(mean);
            }
            let report = net.into_report();
            (means, ledger.iter_bytes.clone(), ledger.total(), report)
        };
        let base = run(1);
        for threads in [2, 4, 8] {
            assert_eq!(run(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn bucketed_no_overlap_is_bit_identical_to_single_plan() {
        // Any bucket plan in --no-overlap mode must reproduce the
        // single-plan exchange exactly: mean, EF state, merged ledger,
        // and net trace (the tentpole's §13.2 contract at strategy level).
        let run = |plan: BucketPlan, overlap: bool| {
            let mut rng = Rng::new(0xB0C4);
            let (nodes, n) = (4, 600);
            let mut fbs: Vec<FeedbackMemory> = (0..nodes)
                .map(|_| FeedbackMemory::new(n, Correction::Momentum, 0.9))
                .collect();
            let mut shards = NodeLedger::for_nodes(nodes);
            let mut scratches = Scratch::for_nodes(nodes);
            let mut ledger = Ledger::new();
            let mut net = NetSim::new(Default::default(), nodes);
            let mut means = Vec::new();
            for _ in 0..3 {
                let grads: Vec<Vec<f32>> =
                    (0..nodes).map(|_| rng.normal_vec(n, 1.0)).collect();
                let mean = sparse_ef_exchange(
                    &mut fbs, &grads, 0.04, false, IndexCodec::Deflate, &mut shards,
                    &mut scratches, 1, &plan, overlap, &mut net, &[true; 4],
                )
                .unwrap();
                crate::coordinator::scheduler::close_iteration(
                    &mut ledger,
                    &mut shards,
                    &mut net,
                );
                means.push(mean);
            }
            let mems: Vec<Vec<f32>> = fbs.iter().map(|f| f.memory().to_vec()).collect();
            (means, mems, ledger.iter_bytes.clone(), ledger.total(), net.into_report())
        };
        let base = run(BucketPlan::single(600), false);
        for buckets in [2usize, 5, 32] {
            let plan = BucketPlan::from_layers(600, &[], buckets);
            assert_eq!(run(plan, false), base, "buckets={buckets}");
        }
        // Overlapped mode keeps the math identical — same means, same EF
        // state — while packet framing (per-bucket index coding) and
        // round structure legitimately differ.
        let over = run(BucketPlan::from_layers(600, &[], 8), true);
        assert_eq!(over.0, base.0);
        assert_eq!(over.1, base.1);
    }

    #[test]
    fn dense_mean_accounts_full_vectors() {
        let grads = vec![vec![2.0f32; 8], vec![4.0f32; 8]];
        let mut shards = NodeLedger::for_nodes(2);
        let mean = dense_mean_accounted(&grads, &mut shards);
        assert!(mean.iter().all(|&x| (x - 3.0).abs() < 1e-6));
        let ledger = merged(&mut shards);
        assert_eq!(ledger.total(), 2 * 8 * 4);
    }

    #[test]
    fn dgc_ramp_reduces_bytes_over_time() {
        // exponential_alpha is tested in scheduler; here check DGC wiring
        // through the public helper only.
        assert!(exponential_alpha(0, 100, 1e-3) > exponential_alpha(99, 100, 1e-3));
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn dense_mean_masked_renormalizes_over_survivors() {
        // A dead node (empty placeholder gradient) contributes nothing;
        // the mean divides by the survivor count.
        let grads = vec![vec![2.0f32; 8], Vec::new(), vec![4.0f32; 8]];
        let mut shards = NodeLedger::for_nodes(3);
        let mean = dense_mean_masked(&grads, &[true, false, true], &mut shards);
        assert!(mean.iter().all(|&x| (x - 3.0).abs() < 1e-6));
        let ledger = merged(&mut shards);
        assert_eq!(ledger.total(), 2 * 8 * 4, "the dead node sent no bytes");
        // All-alive path is bit-identical to the unmasked helper.
        let grads2 = vec![vec![2.0f32; 8], vec![4.0f32; 8]];
        let mut s1 = NodeLedger::for_nodes(2);
        let mut s2 = NodeLedger::for_nodes(2);
        let m1 = dense_mean_accounted(&grads2, &mut s1);
        let m2 = dense_mean_masked(&grads2, &[true; 2], &mut s2);
        assert_eq!(bits(&m1), bits(&m2));
    }

    #[test]
    fn sparse_ef_exchange_drops_dead_node_and_renormalizes() {
        let n = 6;
        let mut fbs: Vec<FeedbackMemory> =
            (0..3).map(|_| FeedbackMemory::new(n, Correction::Plain, 0.0)).collect();
        let grads = vec![
            vec![3.0, 0.0, 0.0, 0.0, 0.0, 0.0],
            Vec::new(), // dead node's placeholder under --on-fault continue
            vec![0.0, 0.0, 0.0, 0.0, 0.0, 9.0],
        ];
        let mut shards = NodeLedger::for_nodes(3);
        let mut scratches = Scratch::for_nodes(3);
        let mut net = NetSim::new(Default::default(), 3);
        let mean = sparse_ef_exchange(
            &mut fbs,
            &grads,
            0.2,
            false,
            IndexCodec::Deflate,
            &mut shards,
            &mut scratches,
            1,
            &BucketPlan::single(n),
            false,
            &mut net,
            &[true, false, true],
        )
        .unwrap();
        // k = ceil(0.2 * 6) = 2 coords per *live* node; the mean divides
        // by the two survivors, not three.
        assert_eq!(mean[0], 1.5);
        assert_eq!(mean[5], 4.5);
        // The dead node's EF memory is untouched and its shard recorded
        // no traffic.
        assert!(fbs[1].memory().iter().all(|&x| x == 0.0));
        let ledger = merged(&mut shards);
        assert_eq!(ledger.per_kind[&Kind::Values], 2 * 2 * 4);
    }

    #[test]
    fn sparse_gd_state_roundtrip_continues_bit_identically() {
        // Drive the EF memories through real exchanges, snapshot via
        // save_state, restore into a fresh instance, and check the next
        // exchange is bit-identical (the resume contract at strategy
        // level).
        let mut rng = Rng::new(0x57A7E);
        let (nodes, n) = (3usize, 96usize);
        let plan = BucketPlan::single(n);
        let alive = vec![true; nodes];
        let mut a = SparseGd::new(nodes, n, 0.1);
        let mut shards = NodeLedger::for_nodes(nodes);
        let mut scratches = Scratch::for_nodes(nodes);
        let mut net = NetSim::new(Default::default(), nodes);
        for _ in 0..3 {
            let grads: Vec<Vec<f32>> = (0..nodes).map(|_| rng.normal_vec(n, 1.0)).collect();
            sparse_ef_exchange(
                &mut a.fbs, &grads, 0.1, false, IndexCodec::Deflate, &mut shards,
                &mut scratches, 1, &plan, false, &mut net, &alive,
            )
            .unwrap();
        }
        let mut blob = Vec::new();
        a.save_state(&mut blob);
        let mut b = SparseGd::new(nodes, n, 0.1);
        let mut r = Reader::new(&blob);
        b.load_state(&mut r).unwrap();
        assert!(r.is_done());
        let grads: Vec<Vec<f32>> = (0..nodes).map(|_| rng.normal_vec(n, 1.0)).collect();
        let ma = sparse_ef_exchange(
            &mut a.fbs, &grads, 0.1, false, IndexCodec::Deflate, &mut shards, &mut scratches,
            1, &plan, false, &mut net, &alive,
        )
        .unwrap();
        let mut shards2 = NodeLedger::for_nodes(nodes);
        let mut scratches2 = Scratch::for_nodes(nodes);
        let mut net2 = NetSim::new(Default::default(), nodes);
        let mb = sparse_ef_exchange(
            &mut b.fbs, &grads, 0.1, false, IndexCodec::Deflate, &mut shards2, &mut scratches2,
            1, &plan, false, &mut net2, &alive,
        )
        .unwrap();
        assert_eq!(bits(&ma), bits(&mb));
        for (x, y) in a.fbs.iter().zip(&b.fbs) {
            assert_eq!(x.memory(), y.memory());
        }
        // A blob for the wrong node count is rejected.
        let mut c = SparseGd::new(nodes + 1, n, 0.1);
        assert!(c.load_state(&mut Reader::new(&blob)).is_err());
    }

    #[test]
    fn qsgd_and_threshold_state_roundtrip() {
        // QSGD: the per-node RNG streams resume mid-sequence.
        let mut q = Qsgd::new(16, 512, 2, 9);
        q.rngs[0].next_u64();
        q.rngs[0].normal();
        q.rngs[1].normal();
        let mut blob = Vec::new();
        q.save_state(&mut blob);
        let mut q2 = Qsgd::new(16, 512, 2, 9);
        let mut r = Reader::new(&blob);
        q2.load_state(&mut r).unwrap();
        assert!(r.is_done());
        assert_eq!(q.rngs[0].next_u64(), q2.rngs[0].next_u64());
        assert_eq!(q.rngs[1].normal().to_bits(), q2.rngs[1].normal().to_bits());
        // HardThreshold: EF memory + the calibrated threshold carry over.
        let mut a = HardThreshold::new(2, 8, 0.25);
        a.nodes[0].fb.accumulate(&[1.0; 8]);
        a.nodes[0].threshold = 0.75;
        let mut blob = Vec::new();
        a.save_state(&mut blob);
        let mut b = HardThreshold::new(2, 8, 0.25);
        let mut r = Reader::new(&blob);
        b.load_state(&mut r).unwrap();
        assert!(r.is_done());
        assert_eq!(b.nodes[0].threshold, 0.75);
        assert_eq!(b.nodes[0].fb.memory(), a.nodes[0].fb.memory());
        let mut blob2 = Vec::new();
        b.save_state(&mut blob2);
        assert_eq!(blob, blob2);
    }
}
